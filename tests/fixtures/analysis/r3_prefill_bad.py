"""R3 fixture: host syncs inside prefill-named hot paths.

The chunked-prefill ingest is a hot path like the decode step: a
`prefill`/`prefill_slot` entry that syncs the device or books the ledger
per call undoes the one-dispatch win."""

import jax


class Loop:
    def prefill(self, seq_id, k, v, ledger):
        rec = self.admit(seq_id, prompt=(k, v))
        ledger.record("spill", k.nbytes, k.nbytes)     # per-admit booking
        jax.block_until_ready(self.cache.state)        # mid-ingest sync
        return rec


class Cache:
    def prefill_slot(self, slot, k, v, ledger):
        st = self.state
        total = st["counter"].sum()
        n = total.item()                               # blocking sync
        ledger.record("repack", n, n)                  # per-call booking
        jax.block_until_ready(st["pages"])             # another sync
        return n
