"""R2 fixture: bypasses the compression registry three ways."""

import numpy as np

from repro.compression import fpc  # noqa: F401  (impl import, no sanction)


def pack_pair(a, b):  # impl-signature name outside the registry
    return np.packbits(a ^ b)  # bit-level packing is codec work
