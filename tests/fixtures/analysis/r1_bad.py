"""R1 fixture: re-derives a marker constant instead of importing it."""


def marker_for(slot: int) -> int:
    # the golden multiplier, inlined — must come from compression.framing
    return (slot * 0x9E3779B1) & 0xFFFFFFFF
