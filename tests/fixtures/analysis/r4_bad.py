"""R4 fixture: process-salted and global-state seeding."""

import numpy as np


def trace_seed(name: str) -> int:
    np.random.seed(0)          # global-state seeding
    return hash(name) & 0xFFFF  # salted per process (PYTHONHASHSEED)
