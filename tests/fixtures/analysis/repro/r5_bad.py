"""R5 fixture: unledgered tier crossing + byte math outside bandwidth/."""

from repro.bandwidth.adapters import kv_spill_event  # noqa: F401


def evict_page(store, page):
    # tier-crossing emitter that never reaches the imported adapter:
    # bytes move to the spill tier unledgered
    store.pages.pop(page)
    return page


def flush(ledger, nbytes):
    ledger.record("spill", nbytes, nbytes)  # direct booking, own byte math
