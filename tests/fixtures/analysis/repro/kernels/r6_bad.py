"""R6 fixture: swallowed kernel errors and float64 promotion."""

import jax.numpy as jnp


def safe_decode(kernel, pages):
    try:
        return kernel(pages)
    except:  # noqa: E722  bare except around a pallas_call
        pass
    acc = pages.astype(float)              # promotes to float64
    return jnp.zeros_like(acc, dtype=jnp.float64)
