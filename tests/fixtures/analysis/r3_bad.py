"""R3 fixture: host syncs inside a jit body and a hot-named method."""

import jax
import numpy as np


@jax.jit
def decode_one(x):
    y = np.asarray(x)          # materializes a traced value
    return float(y.sum())      # and again


class Loop:
    def step(self, cache, ledger):
        out = cache.attend()
        ledger.record("read", out.nbytes, out.nbytes)  # per-step booking
        total = out.sum()
        jax.block_until_ready(total)                   # mid-loop sync
        return total.item()                            # blocking sync
