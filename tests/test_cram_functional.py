"""The correctness contract of the CRAM hardware: read-your-writes, under
arbitrary access interleavings, with compression/relocation/markers/LIT all
active.  Plus the paper's corner cases: marker collisions, LIT overflow
(both options), dynamic policy, and the bandwidth stats."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import CRAMSystem
from repro.core.marker import MarkerSpec


def _data_strategy():
    return st.sampled_from(["zeros", "rep", "delta", "random"])


def _make(kind, rng):
    if kind == "zeros":
        return np.zeros(64, np.uint8)
    if kind == "rep":
        return np.tile(rng.integers(0, 256, 8).astype(np.uint8), 8)
    if kind == "delta":
        base = rng.integers(0, 2**30, dtype=np.int64)
        return (base + rng.integers(-50, 50, 16)).astype("<i4").view(
            np.uint8).copy()
    return rng.integers(0, 256, 64).astype(np.uint8)


@settings(max_examples=8)
@given(st.integers(0, 2**32 - 1),
       st.sampled_from(["static", "dynamic", "uncompressed"]))
def test_read_your_writes(seed, policy):
    rng = np.random.default_rng(seed)
    sysm = CRAMSystem(n_lines=256, llc_sets=8, llc_ways=2, policy=policy)
    ref = {}
    for _ in range(600):
        addr = int(rng.integers(0, 256))
        if rng.random() < 0.5:
            data = _make(_data_strategy().example() if False else
                         ["zeros", "rep", "delta", "random"][
                             int(rng.integers(0, 4))], rng)
            sysm.access(addr, is_write=True, data=data)
            ref[addr] = data.copy()
        else:
            got = sysm.access(addr)
            want = ref.get(addr, np.zeros(64, np.uint8))
            assert np.array_equal(got, want), (addr, policy)
    sysm.flush()
    for addr, want in ref.items():
        assert np.array_equal(sysm.access(addr), want)


def test_compression_actually_happens():
    sysm = CRAMSystem(n_lines=64, llc_sets=2, llc_ways=1, policy="static")
    z = np.zeros(64, np.uint8)
    for addr in range(32):
        sysm.access(addr, is_write=True, data=z)
    sysm.flush()
    # zero lines pack 4:1; re-reading lane 0 of a group yields 3 prefetches
    before = sysm.stats.prefetch_installed
    sysm.access(0)
    assert sysm.stats.prefetch_installed - before == 3
    assert sysm.stats.wb_dirty > 0
    assert sysm.stats.il_writes > 0  # packing vacated slots


def test_marker_collision_via_forced_write():
    sysm = CRAMSystem(n_lines=64, llc_sets=4, llc_ways=2, policy="static")
    # craft a line that collides with the marker of its own slot
    addr = 5
    line = np.random.default_rng(0).integers(0, 256, 64).astype(np.uint8)
    line[-4:] = np.frombuffer(sysm.spec.marker2(addr), np.uint8)
    sysm.access(addr, is_write=True, data=line)
    sysm.flush()
    assert addr in sysm.lit.entries  # stored inverted, tracked by LIT
    got = sysm.access(addr)
    assert np.array_equal(got, line)
    # overwriting with a non-colliding value clears the LIT entry
    plain = np.zeros(64, np.uint8)
    sysm.access(addr, is_write=True, data=plain)
    sysm.flush()
    assert addr not in sysm.lit.entries


def test_lit_overflow_memory_mapped():
    sysm = CRAMSystem(n_lines=256, llc_sets=8, llc_ways=2,
                      policy="uncompressed", lit_capacity=2,
                      lit_overflow="memory_mapped")
    rng = np.random.default_rng(1)
    addrs = [9, 13, 17, 21, 25]
    lines = {}
    for a in addrs:  # force five concurrent collisions
        line = rng.integers(0, 256, 64).astype(np.uint8)
        line[-4:] = np.frombuffer(sysm.spec.marker4(a), np.uint8)
        sysm.access(a, is_write=True, data=line)
        lines[a] = line
    sysm.flush()
    assert sysm.lit.overflowed
    for a in addrs:
        assert np.array_equal(sysm.access(a), lines[a])
    assert sysm.lit.extra_accesses > 0  # memory-mapped lookups cost b/w


def test_lit_overflow_regenerates_markers():
    sysm = CRAMSystem(n_lines=128, llc_sets=8, llc_ways=2,
                      policy="uncompressed", lit_capacity=1,
                      lit_overflow="regenerate")
    rng = np.random.default_rng(2)
    gen0 = sysm.spec.generation
    lines = {}
    for a in (3, 7, 11):
        line = rng.integers(0, 256, 64).astype(np.uint8)
        line[-4:] = np.frombuffer(sysm.spec.marker2(a), np.uint8)
        sysm.access(a, is_write=True, data=line)
        lines[a] = line
        sysm.flush()
    assert sysm.spec.generation > gen0
    for a, want in lines.items():
        assert np.array_equal(sysm.access(a), want)


def test_uncompressed_policy_never_compresses():
    sysm = CRAMSystem(n_lines=64, llc_sets=2, llc_ways=1,
                      policy="uncompressed")
    z = np.zeros(64, np.uint8)
    for addr in range(32):
        sysm.access(addr, is_write=True, data=z)
    sysm.flush()
    assert sysm.stats.wb_clean == 0
    assert sysm.stats.il_writes == 0
    assert sysm.stats.prefetch_installed == 0


def test_llp_predicts_page_coherent_compressibility():
    sysm = CRAMSystem(n_lines=1024, llc_sets=8, llc_ways=2, policy="static")
    z = np.zeros(64, np.uint8)
    # one pass to establish compressed layout
    for addr in range(512):
        sysm.access(addr, is_write=True, data=z)
    sysm.flush()
    sysm.llp.predictions = sysm.llp.correct = 0
    for addr in range(512):
        sysm.access(addr)
    assert sysm.llp.accuracy > 0.9  # paper: ~98% on coherent pages
