"""Pallas compressibility-scan kernel vs the bit-true numpy references.

Sizes must equal core/compress.compressed_sizes exactly (that module stays
the reference codec); marker classification must equal the uint32 numpy
reference, including on adversarial marker-colliding lines.
"""

import numpy as np
import pytest

from repro.core.compress import compressed_sizes
from repro.core.marker import LineStatus
from repro.kernels.compress_scan import (
    classify_image_ref,
    compress_scan,
    device_il_words,
    device_markers,
)


def _corpus(n: int, seed: int = 0) -> np.ndarray:
    """Random + structured lines exercising every FPC/BDI mode family."""
    rng = np.random.default_rng(seed)
    lines = rng.integers(0, 256, (n, 64)).astype(np.uint8)
    lines[0::7] = 0                                            # M_ZEROS
    lines[1::7] = np.tile(rng.integers(0, 256, 8).astype(np.uint8), 8)
    base = rng.integers(0, 2**31, dtype=np.int64)              # M_REP8
    k = len(lines[2::5])
    lines[2::5] = (base + rng.integers(-100, 100, (k, 8))).astype(
        "<i8").view(np.uint8).reshape(k, 64)                   # B8D1/D2
    k = len(lines[3::5])
    lines[3::5] = rng.integers(-7, 8, (k, 16)).astype(
        "<i4").view(np.uint8).reshape(k, 64)                   # FPC SE4
    k = len(lines[4::5])
    lines[4::5] = (1000 + rng.integers(-120, 120, (k, 32))).astype(
        "<i2").view(np.uint8).reshape(k, 64)                   # B2D1 / SE16
    return lines


def test_sizes_match_reference_exactly():
    lines = _corpus(1024)
    out = compress_scan(lines, interpret=True)
    ref = np.asarray(compressed_sizes(lines))
    assert np.array_equal(out["sizes"], ref)
    assert out["sizes"].min() >= 1 and out["sizes"].max() <= 65


def test_sizes_match_on_non_block_multiple():
    lines = _corpus(301, seed=3)  # exercises the padding path
    out = compress_scan(lines, interpret=True, block=128)
    assert np.array_equal(out["sizes"], np.asarray(compressed_sizes(lines)))
    assert out["sizes"].shape == (301,)


def test_status_matches_reference_on_random_lines():
    lines = _corpus(512, seed=1)
    out = compress_scan(lines, interpret=True)
    assert np.array_equal(out["status"], classify_image_ref(lines))
    # random data essentially never collides with a 32-bit marker
    assert (out["status"] == int(LineStatus.UNCOMP)).mean() > 0.99


def test_status_on_adversarial_marker_collisions():
    """Lines crafted to collide with their slot's marker family must be
    classified exactly as the implicit-metadata rules dictate."""
    n = 64
    rng = np.random.default_rng(2)
    lines = rng.integers(0, 256, (n, 64)).astype(np.uint8)
    m2, m4 = device_markers(np.arange(n))
    il = device_il_words(np.arange(n))
    lines[0, -4:] = np.frombuffer(m2[0].tobytes(), np.uint8)
    lines[1, -4:] = np.frombuffer(m4[1].tobytes(), np.uint8)
    lines[2] = il[2].astype("<u4").view(np.uint8)
    lines[3, -4:] = np.frombuffer((~m2[3]).tobytes(), np.uint8)
    lines[4, -4:] = np.frombuffer((~m4[4]).tobytes(), np.uint8)
    lines[5] = (~il[5]).astype("<u4").view(np.uint8)
    out = compress_scan(lines, interpret=True)
    assert out["status"][0] == int(LineStatus.COMP2)
    assert out["status"][1] == int(LineStatus.COMP4)
    assert out["status"][2] == int(LineStatus.INVALID)
    assert out["status"][3] == int(LineStatus.MAYBE_INVERTED)
    assert out["status"][4] == int(LineStatus.MAYBE_INVERTED)
    assert out["status"][5] == int(LineStatus.MAYBE_INVERTED)
    assert np.array_equal(out["status"], classify_image_ref(lines))


def test_marker_collision_does_not_change_size():
    """Marker collision affects *classification* (the LIT/inversion path),
    never the codec's size accounting."""
    n = 32
    rng = np.random.default_rng(4)
    lines = rng.integers(0, 256, (n, 64)).astype(np.uint8)
    m2, _ = device_markers(np.arange(n))
    collided = lines.copy()
    collided[:, -4:] = np.stack(
        [np.frombuffer(m.tobytes(), np.uint8) for m in m2])
    out = compress_scan(collided, interpret=True)
    assert np.array_equal(out["sizes"],
                          np.asarray(compressed_sizes(collided)))


def test_fpc_bdi_components_bound_hybrid():
    lines = _corpus(512, seed=5)
    out = compress_scan(lines, interpret=True)
    hybrid = np.minimum(np.minimum(out["fpc"], out["bdi"]), 64) + 1
    assert np.array_equal(out["sizes"], hybrid)


@pytest.mark.parametrize("key", [0x5EED, 0, 0xDEADBEEF])
def test_marker_key_regeneration(key):
    """Same protocol as marker.MarkerSpec.regenerate: a new key gives a new
    marker family, so prior collisions disappear."""
    n = 16
    lines = np.zeros((n, 64), np.uint8)
    m2, _ = device_markers(np.arange(n), key)
    lines[:, -4:] = np.stack(
        [np.frombuffer(m.tobytes(), np.uint8) for m in m2])
    got = compress_scan(lines, key=key, interpret=True)["status"]
    assert (got == int(LineStatus.COMP2)).all()
    other = compress_scan(lines, key=key + 1, interpret=True)["status"]
    assert (other == int(LineStatus.COMP2)).mean() < 0.1
