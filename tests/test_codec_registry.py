"""Codec/layout registry: cross-backend round-trips (ISSUE 4 suite).

Each registered codec must agree across its three truths: the bit-true
numpy pack/unpack, the vectorized xp-generic size function (numpy AND
jax.numpy), and the Pallas device backend (interpret mode) — plus the
layout registry invariants the engine and KV cache build on.
"""

import numpy as np
import pytest

from repro.compression import codecs, framing, layouts, pagepack
from repro.compression.framing import LINE_BYTES

# ----------------------------------------------------------- deterministic
# structured line corpus: exercises every FPC pattern and BDI mode without
# needing hypothesis (the property-based variants live in test_codecs.py)


def _corpus():
    rng = np.random.default_rng(0xC0DEC)
    lines = [np.zeros(LINE_BYTES, np.uint8)]
    lines.append(np.tile(np.arange(8, dtype=np.uint8), 8))          # rep8
    lines.append(np.repeat(rng.integers(0, 256, 16), 4)
                 .astype(np.uint8)[:LINE_BYTES])                    # rep bytes
    lines.append(rng.integers(-8, 8, 16).astype("<i4")
                 .view(np.uint8))                                   # se4
    lines.append((np.int64(10**15) + np.arange(8)).astype("<i8")
                 .view(np.uint8))                                   # b8d1
    lines.append((np.int64(2**29) + rng.integers(-100, 100, 16))
                 .astype("<i4").view(np.uint8))                     # b4d1
    lines.append(rng.integers(-128, 128, 32).astype("<i2")
                 .view(np.uint8))                                   # halfwords
    for _ in range(8):
        lines.append(rng.integers(0, 256, LINE_BYTES).astype(np.uint8))
    # zero-run boundaries
    z = np.zeros(LINE_BYTES, np.uint8)
    z[4:8] = 0xAB
    lines.append(z)
    return np.stack([np.ascontiguousarray(l) for l in lines])


@pytest.mark.parametrize("name", ["raw", "bdi", "fpc", "hybrid"])
def test_line_codec_roundtrip_and_size(name):
    codec = codecs.get_codec(name)
    assert codec.unit == "line64"
    lines = _corpus()
    sizes = np.asarray(codec.sizes(lines))
    for i, line in enumerate(lines):
        blob = codec.pack_line(line)
        out, consumed = codec.unpack_line(blob, 0)
        assert np.array_equal(out, line), f"{name} line {i}"
        assert consumed == len(blob) == int(sizes[i]), f"{name} line {i}"


@pytest.mark.parametrize("name", ["raw", "bdi", "fpc", "hybrid"])
def test_line_codec_xp_size_parity(name):
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    codec = codecs.get_codec(name)
    lines = _corpus()
    np_sizes = np.asarray(codec.sizes(lines))
    with enable_x64():
        j_sizes = np.asarray(codec.sizes(jnp.asarray(lines), xp=jnp))
    assert np.array_equal(np_sizes, j_sizes)


def test_compress_scan_is_a_registered_backend():
    """The Pallas scan's size columns equal the registry size functions."""
    from repro.kernels.compress_scan import compress_scan

    lines = _corpus()
    out = compress_scan(lines, interpret=True)
    hybrid = np.asarray(codecs.get_codec("hybrid").sizes(lines))
    fpc = np.asarray(codecs.get_codec("fpc").sizes(lines))
    bdi = np.asarray(codecs.get_codec("bdi").sizes(lines))
    assert np.array_equal(out["sizes"], hybrid)
    assert np.array_equal(out["fpc"], fpc)
    # the scan's bdi column is the raw payload; the registry adds the
    # 1-byte self-describing mode header
    assert np.array_equal(out["bdi"] + 1, bdi)


# ------------------------------------------------------------- page codecs

def _kv_pages(rng, n, compressible=True, *, page=8, hkv=2, d2=16):
    row = rng.integers(-1000, 1000, (1, hkv, d2)).astype(np.int16)
    base = np.broadcast_to(row, (page, hkv, d2))
    out = []
    for _ in range(n):
        if compressible:
            p = base + rng.integers(-8, 8, base.shape)
        else:
            p = rng.integers(-(2**14), 2**14, base.shape)
        out.append(p.astype(np.int16))
    out[0][0] = base[0]          # lane A's token-0 row IS the base
    return out


@pytest.mark.parametrize("name,n", [("int8-delta", 2), ("int4-delta", 4)])
@pytest.mark.parametrize("compressible", [True, False])
def test_page_codec_three_backends_agree(name, n, compressible):
    """numpy pagepack == jnp ref == Pallas kernel (interpret), bit-for-bit."""
    import jax.numpy as jnp

    codec = codecs.get_codec(name)
    assert codec.unit == "page" and codec.group_lanes == n
    rng = np.random.default_rng(7 + n)
    pages = _kv_pages(rng, n, compressible)
    # numpy bit-true reference
    ok_np, packed_np, base_np = codec.pack_pages(*pages, xp=np)
    assert bool(ok_np) == compressible
    if ok_np:
        rt = codec.unpack_pages(packed_np, base_np, xp=np)
        for got, want in zip(rt, pages, strict=True):
            assert np.array_equal(got, want)
    # jnp path
    ok_j, packed_j, base_j = codec.pack_pages(
        *[jnp.asarray(p) for p in pages], xp=jnp)
    assert bool(ok_j) == bool(ok_np)
    assert np.array_equal(np.asarray(packed_j), packed_np)
    # Pallas backend (pack returns (packed, base, ok))
    pack_k, unpack_k = codec.pallas()
    packed_k, base_k, ok_k = pack_k(
        *[jnp.asarray(p) for p in pages], interpret=True)
    assert bool(ok_k) == bool(ok_np)
    assert np.array_equal(np.asarray(packed_k), packed_np)
    assert np.array_equal(np.asarray(base_k), base_np)
    out_k = unpack_k(jnp.asarray(packed_np), jnp.asarray(base_np),
                     interpret=True)
    want = codec.unpack_pages(packed_np, base_np, xp=np)
    for got, ref in zip(out_k, want, strict=True):
        assert np.array_equal(np.asarray(got), ref)


def test_marker_domains_never_alias():
    pair = framing.slot_markers(256, domain=framing.DOMAIN_PAIR)
    quad = framing.slot_markers(256, domain=framing.DOMAIN_QUAD)
    assert not (pair == quad).any()
    # pair domain is bit-identical to the historical marker family
    from repro.kernels import ref

    assert np.array_equal(pair, ref.slot_markers(256))


# ---------------------------------------------------------------- registry

def test_registry_surface():
    assert set(codecs.codec_names("line64")) == {
        "raw", "bdi", "fpc", "hybrid"}
    assert set(codecs.codec_names("page")) == {"int8-delta", "int4-delta"}
    assert set(layouts.layout_names()) == {"group4", "kv-pair", "kv-quad"}
    with pytest.raises(KeyError):
        codecs.get_codec("lz77")
    with pytest.raises(KeyError):
        layouts.get_layout("group8")


def test_schemes_name_registry_entries():
    from repro.core import schemes

    assert schemes.get("cram").codec == "hybrid"
    assert schemes.get("cram").layout == "group4"
    assert schemes.get("baseline").codec == "raw"
    with pytest.raises(KeyError):
        schemes.Scheme("bogus", codec="nope")
    with pytest.raises(KeyError):
        schemes.Scheme("bogus", layout="nope")


def test_layout_probe_chain_and_predictor_table():
    from repro.compression.predictor import probe_count_table

    g4 = layouts.get_layout("group4")
    assert g4.probe_chain(3, 2) == [2, 3, 0]
    t = probe_count_table(g4)
    assert t.shape == (5, 4, 3)
    # lane 0 always takes exactly one probe
    assert (t[:, 0, :] == 1).all()
    kvp = layouts.get_layout("kv-pair")
    tp = probe_count_table(kvp)
    # packed state, lane 1, predicted packed (level 1 -> slot 0... via
    # pred_slot[1][1] = 0): hit on first probe
    assert tp[1, 1, 1] == 1
    # packed state, lane 1, predicted uncompressed: probes slot 1 (IL),
    # then slot 0 -> 2 probes
    assert tp[1, 1, 0] == 2


def test_checkpoint_codec_uses_registry():
    from repro.checkpoint.codec import (
        cram_compress_bytes,
        cram_decompress_bytes,
    )

    raw = (np.arange(4096, dtype=np.int32) // 7).tobytes() + b"tail"
    for name in ("bdi", "hybrid", "fpc", "raw"):
        blob = cram_compress_bytes(raw, codec=name)
        assert cram_decompress_bytes(blob) == raw, name
    with pytest.raises(ValueError):
        cram_compress_bytes(raw, codec="int8-delta")
