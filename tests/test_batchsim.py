"""Batched sweep engine vs the scalar per-workload simulator: exact parity.

The batched engine's contract is bit-identical stats (batchsim's step is the
flag-gated twin of memsim's per-scheme specialized steps), so these tests
use array_equal / exact float equality, not allclose.
"""

import numpy as np
import pytest

from repro.core.batchsim import scheme_flags, sweep, sweep_workloads
from repro.core.memsim import (
    N_STATS,
    SCHEMES,
    SimConfig,
    _STAT_NAMES,
    run_workload,
    simulate,
)
from repro.core.traces import build_workload

CFG = SimConfig()
N_EVENTS = 12_000
# one compressible SPEC workload, one hostile GAP workload, one mix: covers
# the compression win, the dynamic-disable path, and interleaved traces
NAMES = ("libq", "pr_twi", "mix3")


@pytest.fixture(scope="module")
def wls():
    return {n: build_workload(n, N_EVENTS, seed=1) for n in NAMES}


@pytest.fixture(scope="module")
def batched(wls):
    ws = [wls[n] for n in NAMES]
    return sweep(
        SCHEMES,
        np.stack([w[1] for w in ws]),
        np.stack([w[2] for w in ws]),
        np.stack([w[3] for w in ws]),
        np.stack([w[4] for w in ws]),
        np.stack([w[5] for w in ws]),
        CFG,
    )


def test_sweep_shape(batched):
    assert batched.shape == (len(SCHEMES), len(NAMES), N_STATS)
    assert batched.dtype == np.int32


@pytest.mark.parametrize("scheme", SCHEMES)
def test_stats_exactly_match_scalar_path(batched, wls, scheme):
    si = SCHEMES.index(scheme)
    for wi, name in enumerate(NAMES):
        _, addrs, wr, pab, pcd, pq, _ = wls[name]
        ref = simulate(scheme, addrs, wr, pab, pcd, pq, CFG)
        ref_vec = np.asarray([ref.stats[k] for k in _STAT_NAMES], np.int32)
        assert np.array_equal(batched[si, wi], ref_vec), (
            f"{scheme}/{name}: batched {batched[si, wi]} != scalar {ref_vec}")


def test_sweep_workloads_matches_run_workload():
    got = sweep_workloads(names=["libq"], n_events=N_EVENTS, seed=1, cfg=CFG)
    ref = run_workload("libq", n_events=N_EVENTS, seed=1, cfg=CFG)
    assert got["libq"] == ref  # same summary dict, exact floats included


def test_scheme_subset_includes_baseline_normalization():
    got = sweep_workloads(names=["libq"], schemes=("cram",),
                          n_events=N_EVENTS, seed=1, cfg=CFG)["libq"]
    assert set(got["schemes"]) == {"cram"}
    assert got["baseline_accesses"] > 0
    assert got["schemes"]["cram"]["speedup"] > 0


def test_scheme_flags_table():
    from repro.core.engine import FLAG_COMP, FLAG_DYNAMIC, FLAG_LCT_UPDATE, N_FLAGS

    f = scheme_flags(SCHEMES)
    assert f.shape == (len(SCHEMES), N_FLAGS)
    # baseline has no behaviour flags; dynamic is a compressed+llp scheme
    assert not f[SCHEMES.index("baseline")].any()
    d = f[SCHEMES.index("dynamic")]
    assert d[FLAG_COMP] and d[FLAG_DYNAMIC] and d[FLAG_LCT_UPDATE]
