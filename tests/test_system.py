"""System-level behaviour: the paper's end-to-end story on both simulators.

1. On a compressible, high-reuse stream the CRAM system services the same
   reads with FEWER memory accesses than an uncompressed memory.
2. On an incompressible stream it never corrupts data and the dynamic gate
   bounds the overhead.
3. The exact functional model and the fast trace simulator tell the same
   qualitative story (they share evict_logic).
"""

import numpy as np

from repro.core import CRAMSystem
from repro.core.memsim import SimConfig, simulate


def _stream(sysm, lines, passes=3):
    for _ in range(passes):
        for a in range(lines):
            sysm.access(a)


def test_compressible_stream_saves_bandwidth():
    n = 512
    zeros = np.zeros(64, np.uint8)
    cram = CRAMSystem(n_lines=n, llc_sets=4, llc_ways=2, policy="static")
    base = CRAMSystem(n_lines=n, llc_sets=4, llc_ways=2,
                      policy="uncompressed")
    for s in (cram, base):
        for a in range(n):
            s.access(a, is_write=True, data=zeros)
        s.flush()
        _stream(s, n, passes=6)  # enough reuse to amortize the IL writes
    assert cram.total_mem_accesses() < 0.55 * base.total_mem_accesses(), (
        cram.total_mem_accesses(), base.total_mem_accesses())


def test_incompressible_stream_is_safe():
    n = 256
    rng = np.random.default_rng(0)
    lines = {a: rng.integers(0, 256, 64).astype(np.uint8)
             for a in range(n)}
    cram = CRAMSystem(n_lines=n, llc_sets=4, llc_ways=2, policy="dynamic")
    for a, d in lines.items():
        cram.access(a, is_write=True, data=d)
    cram.flush()
    for a, d in lines.items():
        assert np.array_equal(cram.access(a), d)
    # nothing packed -> no invalidates were ever needed
    assert cram.stats.il_writes == 0


def test_simulators_agree_on_scheme_ordering():
    from repro.core.traces import build_workload

    wl = build_workload("libq", n_events=30_000, seed=7)
    _, addrs, wr, pa, pc, pq, f = wl
    cfg = SimConfig()
    acc = {s: simulate(s, addrs, wr, pa, pc, pq, cfg).accesses
           for s in ("baseline", "ideal", "cram")}
    assert acc["ideal"] <= acc["cram"]
    assert acc["ideal"] < acc["baseline"]
