"""Marker/implicit-metadata and restricted-mapping properties."""

import numpy as np
from hypothesis import given, strategies as st

from repro.core import mapping
from repro.core.evict_logic import (
    EvictPlan,
    build_evict_table,
    evict_plan,
    evict_table_index,
)
from repro.core.marker import (
    LineStatus,
    MarkerSpec,
    classify_line,
    invert_line,
    needs_inversion,
)


def test_marker_classification_basic():
    spec = MarkerSpec()
    rng = np.random.default_rng(0)
    line = rng.integers(0, 256, 64).astype(np.uint8)
    # random line: astronomically unlikely to match any marker
    assert classify_line(line, 5, spec) in (
        LineStatus.UNCOMP, LineStatus.MAYBE_INVERTED)
    # a line ending with marker2 classifies as COMP2
    line2 = line.copy()
    line2[-4:] = np.frombuffer(spec.marker2(5), np.uint8)
    assert classify_line(line2, 5, spec) == LineStatus.COMP2
    line4 = line.copy()
    line4[-4:] = np.frombuffer(spec.marker4(5), np.uint8)
    assert classify_line(line4, 5, spec) == LineStatus.COMP4
    il = np.frombuffer(spec.marker_il(5), np.uint8)
    assert classify_line(il, 5, spec) == LineStatus.INVALID
    # markers are per-slot: slot 6 must not see slot 5's marker
    assert classify_line(line2, 6, spec) in (
        LineStatus.UNCOMP, LineStatus.MAYBE_INVERTED)


def test_inversion_handles_collisions():
    spec = MarkerSpec()
    rng = np.random.default_rng(1)
    line = rng.integers(0, 256, 64).astype(np.uint8)
    line[-4:] = np.frombuffer(spec.marker2(9), np.uint8)  # force collision
    assert needs_inversion(line, 9, spec)
    inv = invert_line(line)
    # inverted form no longer matches any marker as compressed
    assert classify_line(inv, 9, spec) == LineStatus.MAYBE_INVERTED
    assert np.array_equal(invert_line(inv), line)


def test_marker_regeneration_changes_values():
    spec = MarkerSpec()
    before = spec.marker2(3), spec.marker_il(7)
    spec.regenerate()
    assert spec.marker2(3) != before[0]
    assert spec.marker_il(7) != before[1]


def test_mapping_tables_consistent():
    # lane 0 never moves; every lane's candidates match the LOC column
    for lane in range(4):
        locs = {int(mapping.LOC[s][lane]) for s in range(5)}
        assert locs == set(mapping.CANDIDATES[lane])
    assert set(mapping.CANDIDATES[0]) == {0}
    # avg candidate count is 2 (paper: "on average two locations")
    counts = [len(mapping.CANDIDATES[l]) for l in range(4)]
    assert sum(counts) / 4 == 2.0
    # vacated slots + occupied slots partition the group
    for s in range(5):
        for slot in range(4):
            lanes = int(mapping.LANES_IN_SLOT[s][slot])
            assert bool(lanes) == bool(mapping.OCCUPIED[s][slot])


@given(st.integers(0, 4), st.booleans(), st.booleans(), st.booleans(),
       st.integers(0, 15), st.integers(0, 15), st.booleans())
def test_evict_plan_invariants(prior, fab, fcd, fq, valid, dirty, enabled):
    plan = evict_plan(prior, fab, fcd, fq, valid, dirty, enabled)
    dirty &= valid
    # every dirty lane is covered by some write
    written_lanes = {l for w in plan.writes for l in w[1]}
    for lane in range(4):
        if dirty & (1 << lane):
            assert lane in written_lanes
    # packed writes only contain valid lanes and only pack fitting units
    for slot, lanes, packed, _ in plan.writes:
        for l in lanes:
            assert valid & (1 << l)
        if packed:
            assert enabled
            assert len(lanes) in (2, 4)
    # disabled compression never creates packed slots
    if not enabled:
        assert all(not w[2] for w in plan.writes)
    # IL writes only on slots that previously held data
    prior_slots = {int(mapping.LOC[prior][l]) for l in range(4)
                   if valid & (1 << l)}
    assert set(plan.il_slots) <= prior_slots
    # clean drop: nothing happens without dirty data unless enabled packing
    if dirty == 0 and not enabled:
        assert not plan.writes and not plan.il_slots
        assert plan.new_state == prior


@given(st.integers(0, 4), st.integers(0, 1), st.integers(0, 1),
       st.integers(0, 1), st.integers(0, 15), st.integers(0, 15),
       st.integers(0, 1))
def test_evict_table_matches_function(prior, fab, fcd, fq, valid, dirty,
                                      enabled):
    table = build_evict_table()
    idx = int(evict_table_index(enabled, prior, fab, fcd, fq, valid, dirty))
    plan = evict_plan(prior, bool(fab), bool(fcd), bool(fq), valid, dirty,
                      bool(enabled))
    assert table["wb_dirty"][idx] == plan.wb_dirty
    assert table["wb_clean"][idx] == plan.wb_clean
    assert table["il"][idx] == plan.il_count
    assert table["new_state"][idx] == plan.new_state


def test_probe_chain():
    assert mapping.probe_chain(1, 0) == [0, 1]
    assert mapping.probe_chain(3, 3) == [3, 2, 0]
    assert mapping.probe_chain(3, 0) == [0, 3, 2]
