"""Benchmark-layer unit tests: fig14 missing-scheme robustness and the
consolidated report's registry-extra sections (no simulation involved —
the suite dict is synthesized)."""

import pathlib
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]
if str(ROOT) not in sys.path:
    sys.path.insert(0, str(ROOT))


def _summary(schemes, accuracy=0.97):
    from repro.bandwidth.adapters import engine_traffic

    breakdown = {
        "data_reads": 10, "mispredict_extra": 1, "wb_dirty": 2,
        "wb_clean+invalidate": 3, "metadata": 4, "prefetch_extra": 0,
    }
    # the equivalent STAT counters, so the embedded ledger "traffic" view
    # (what bandwidth_breakdowns reads) is consistent with the breakdown
    stats = {"demand_reads": 10, "read_probes": 11, "wb_dirty": 2,
             "wb_clean": 3, "il_writes": 0, "meta_reads": 4, "meta_wb": 0,
             "pf_extra_access": 0}
    return {
        "workload": "x", "": 0.5, "baseline_accesses": 100,
        "schemes": {
            s: {"accesses": 90, "speedup": 1.05, "llp_accuracy": accuracy,
                "meta_hit_rate": 0.5, "breakdown": dict(breakdown),
                "traffic": engine_traffic(stats).as_dict()}
            for s in schemes
        },
    }


def _suite(schemes):
    return {"n_events": 1000, "sweep_wall_s": 0.1,
            "workloads": {"libq": _summary(schemes),
                          "mcf17": _summary(schemes)}}


def test_fig14_skips_missing_schemes(monkeypatch):
    import benchmarks.fig14_llp as fig14

    monkeypatch.setattr(fig14, "suite_results",
                        lambda: _suite(("baseline", "dynamic")))
    rows = fig14.run()  # must not KeyError on the cram/explicit columns
    labels = {r[0]: r[2] for r in rows}
    assert "suite cache lacks: cram,explicit" in labels["fig14/omitted_schemes"]
    assert labels["fig14/mean_llp_accuracy"].startswith("n/a")
    assert labels["fig14/libq"] == "n/a"


def test_fig14_full_suite(monkeypatch):
    import benchmarks.fig14_llp as fig14

    monkeypatch.setattr(fig14, "suite_results",
                        lambda: _suite(("cram", "explicit")))
    rows = fig14.run()
    labels = {r[0]: r[2] for r in rows}
    assert "llp=0.970" in labels["fig14/libq"]
    assert "metaHR=0.500" in labels["fig14/libq"]
    assert not any("omitted" in name for name, _, _ in rows)


def test_build_report_registry_sections():
    from benchmarks.sweep_report import build_report

    suite = _suite(("baseline", "cram", "cram-nollp",
                    "cram@lct64", "cram@lct128"))
    rep = build_report(suite)
    # paper aggregates stay restricted to the six paper schemes
    assert set(rep["fig16_geomean"]) == {"cram"}
    # the extras feed their own sections
    assert set(rep["lct_sensitivity"]) == {"64", "128", "512"}
    assert rep["llp_value"]["llp_gain_pct"] == pytest.approx(0.0)
    assert rep["lct_sensitivity"]["512"]["geomean_speedup"] == \
        pytest.approx(1.05)


def test_fig15_breakdowns_from_ledger_match_legacy_counters():
    """The Fig. 8/15 render path now reads engine_traffic ledger rows
    (engine_breakdown); pin it category-for-category equal to the legacy
    SimResult.bandwidth_breakdown math on a real (small) simulation."""
    from benchmarks.sweep_report import bandwidth_breakdowns
    from repro.core.memsim import run_workload

    summary = run_workload("libq", schemes=("baseline", "cram", "explicit"),
                           n_events=4000, seed=3)
    workloads = {"libq": summary}
    got = bandwidth_breakdowns(workloads)
    base = summary["baseline_accesses"]
    for sch in ("explicit", "cram"):
        b = summary["schemes"][sch]["breakdown"]
        legacy = {
            "data": (b["data_reads"] + b["wb_dirty"]) / base,
            "metadata": b["metadata"] / base,
            "mispredict": b["mispredict_extra"] / base,
            "wbclean+inv": b["wb_clean+invalidate"] / base,
            "total": summary["schemes"][sch]["accesses"] / base,
        }
        assert got[sch]["libq"] == legacy, sch


def test_fig15_rows_render_from_ledger_view(monkeypatch):
    import benchmarks.fig15_bandwidth as fig15

    monkeypatch.setattr(fig15, "suite_results",
                        lambda: _suite(("cram", "explicit")))
    rows = fig15.run()
    labels = {r[0]: r[2] for r in rows}
    # 10 reads + 2 dirty wb over 100 baseline accesses, from ledger rows
    assert labels["fig15/libq"].startswith("data=0.12")
    assert "wbclean+inv=0.03" in labels["fig15/libq"]
    assert "mispred=0.010" in labels["fig8/libq"]
