"""Blockwise attention vs naive reference; decode-vs-forward consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (blockwise_attention,
                                    chunked_decode_attention)


def naive_attention(q, k, v, causal, kv_length=None):
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    kr = jnp.repeat(k, G, axis=2)
    vr = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bshd,bthd->bhst", q, kr) / jnp.sqrt(jnp.float32(D))
    T = k.shape[1]
    if causal:
        mask = jnp.tril(jnp.ones((S, T), bool), k=T - S)
        s = jnp.where(mask[None, None], s, -1e30)
    if kv_length is not None:
        s = jnp.where((jnp.arange(T) < kv_length)[None, None, None],
                      s, -1e30)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    return jnp.einsum("bhst,bthd->bshd", p.astype(v.dtype), vr)


@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("qc,kc", [(16, 32), (64, 64)])
def test_blockwise_matches_naive(hq, hkv, causal, qc, kc):
    key = jax.random.key(0)
    B, S, D = 2, 64, 16
    q = jax.random.normal(key, (B, S, hq, D), jnp.float32)
    k = jax.random.normal(jax.random.key(1), (B, S, hkv, D), jnp.float32)
    v = jax.random.normal(jax.random.key(2), (B, S, hkv, D), jnp.float32)
    out = blockwise_attention(q, k, v, causal=causal, q_chunk=qc,
                              k_chunk=kc)
    ref = naive_attention(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_unrolled_matches_scan():
    key = jax.random.key(3)
    B, S, D = 1, 64, 8
    q = jax.random.normal(key, (B, S, 4, D))
    k = jax.random.normal(jax.random.key(4), (B, S, 2, D))
    v = jax.random.normal(jax.random.key(5), (B, S, 2, D))
    a = blockwise_attention(q, k, v, causal=True, q_chunk=16, k_chunk=16)
    b = blockwise_attention(q, k, v, causal=True, q_chunk=16, k_chunk=16,
                            unroll=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_decode_attention_matches_masked_naive():
    key = jax.random.key(6)
    B, T, Hq, Hkv, D = 2, 128, 8, 2, 16
    q = jax.random.normal(key, (B, Hq, D))
    k = jax.random.normal(jax.random.key(7), (B, T, Hkv, D))
    v = jax.random.normal(jax.random.key(8), (B, T, Hkv, D))
    length = 57
    out = chunked_decode_attention(q, k, v, length=length, k_chunk=32)
    ref = naive_attention(q[:, None], k, v, causal=False,
                          kv_length=length)[:, 0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("arch", ["phi4_mini_3_8b", "mamba2_130m",
                                  "zamba2_2_7b", "whisper_base"])
def test_decode_matches_teacher_forcing(arch):
    """Stepwise decode logits == full-sequence forward logits."""
    import numpy as np

    from repro.configs import get_smoke
    from repro.models import build
    from repro.models.layers import logits_last

    cfg = get_smoke(arch).replace(remat=False)
    model = build(cfg)
    params, _ = model.init(jax.random.key(0))
    B, S = 2, 16
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
    if cfg.family == "encdec":
        frames = jax.random.normal(jax.random.key(2), (B, S, cfg.d_model),
                                   cfg.dtype)
        from repro.models import whisper as wh

        enc = wh.encode(params, cfg, frames)
        h = wh.decode_train(params, cfg, toks, enc)
        full_logits = jax.vmap(
            lambda hh: logits_last(hh, params["embed"]), in_axes=1,
            out_axes=1)(h)
        cache = model.init_cache(B, S, enc_len=S)
        cache = wh.whisper_prefill_cross(params, cfg, enc, cache)
        step = jax.jit(model.decode_step)
    else:
        h = model.forward(params, {"tokens": toks})
        full_logits = jax.vmap(
            lambda hh: logits_last(hh, params["embed"]), in_axes=1,
            out_axes=1)(h)
        cache = model.init_cache(B, S)
        step = jax.jit(model.decode_step)
    for i in range(S):
        logits, cache = step(params, toks[:, i:i + 1], cache, jnp.int32(i))
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full_logits[:, i]),
            atol=2e-2, rtol=2e-2)
