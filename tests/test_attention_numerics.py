"""Blockwise attention vs naive reference; decode-vs-forward consistency;
batched fused CRAM decode kernel parity (numerics + bytes output).

Deliberately hypothesis-free: the fused-kernel parity suite here is the
tier-1 gate for `cram_decode_attention_batched` (the hypothesis-sweep
variants live in tests/test_kernels.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as kops
from repro.kernels.cram_attention import cram_decode_attention
from repro.models.attention import (blockwise_attention,
                                    chunked_decode_attention)


def naive_attention(q, k, v, causal, kv_length=None):
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    kr = jnp.repeat(k, G, axis=2)
    vr = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bshd,bthd->bhst", q, kr) / jnp.sqrt(jnp.float32(D))
    T = k.shape[1]
    if causal:
        mask = jnp.tril(jnp.ones((S, T), bool), k=T - S)
        s = jnp.where(mask[None, None], s, -1e30)
    if kv_length is not None:
        s = jnp.where((jnp.arange(T) < kv_length)[None, None, None],
                      s, -1e30)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    return jnp.einsum("bhst,bthd->bshd", p.astype(v.dtype), vr)


@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("qc,kc", [(16, 32), (64, 64)])
def test_blockwise_matches_naive(hq, hkv, causal, qc, kc):
    key = jax.random.key(0)
    B, S, D = 2, 64, 16
    q = jax.random.normal(key, (B, S, hq, D), jnp.float32)
    k = jax.random.normal(jax.random.key(1), (B, S, hkv, D), jnp.float32)
    v = jax.random.normal(jax.random.key(2), (B, S, hkv, D), jnp.float32)
    out = blockwise_attention(q, k, v, causal=causal, q_chunk=qc,
                              k_chunk=kc)
    ref = naive_attention(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_unrolled_matches_scan():
    key = jax.random.key(3)
    B, S, D = 1, 64, 8
    q = jax.random.normal(key, (B, S, 4, D))
    k = jax.random.normal(jax.random.key(4), (B, S, 2, D))
    v = jax.random.normal(jax.random.key(5), (B, S, 2, D))
    a = blockwise_attention(q, k, v, causal=True, q_chunk=16, k_chunk=16)
    b = blockwise_attention(q, k, v, causal=True, q_chunk=16, k_chunk=16,
                            unroll=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_decode_attention_matches_masked_naive():
    key = jax.random.key(6)
    B, T, Hq, Hkv, D = 2, 128, 8, 2, 16
    q = jax.random.normal(key, (B, Hq, D))
    k = jax.random.normal(jax.random.key(7), (B, T, Hkv, D))
    v = jax.random.normal(jax.random.key(8), (B, T, Hkv, D))
    length = 57
    out = chunked_decode_attention(q, k, v, length=length, k_chunk=32)
    ref = naive_attention(q[:, None], k, v, causal=False,
                          kv_length=length)[:, 0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("arch", ["phi4_mini_3_8b", "mamba2_130m",
                                  "zamba2_2_7b", "whisper_base"])
def test_decode_matches_teacher_forcing(arch):
    """Stepwise decode logits == full-sequence forward logits."""
    import numpy as np

    from repro.configs import get_smoke
    from repro.models import build
    from repro.models.layers import logits_last

    cfg = get_smoke(arch).replace(remat=False)
    model = build(cfg)
    params, _ = model.init(jax.random.key(0))
    B, S = 2, 16
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
    if cfg.family == "encdec":
        frames = jax.random.normal(jax.random.key(2), (B, S, cfg.d_model),
                                   cfg.dtype)
        from repro.models import whisper as wh

        enc = wh.encode(params, cfg, frames)
        h = wh.decode_train(params, cfg, toks, enc)
        full_logits = jax.vmap(
            lambda hh: logits_last(hh, params["embed"]), in_axes=1,
            out_axes=1)(h)
        cache = model.init_cache(B, S, enc_len=S)
        cache = wh.whisper_prefill_cross(params, cfg, enc, cache)
        step = jax.jit(model.decode_step)
    else:
        h = model.forward(params, {"tokens": toks})
        full_logits = jax.vmap(
            lambda hh: logits_last(hh, params["embed"]), in_axes=1,
            out_axes=1)(h)
        cache = model.init_cache(B, S)
        step = jax.jit(model.decode_step)
    for i in range(S):
        logits, cache = step(params, toks[:, i:i + 1], cache, jnp.int32(i))
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full_logits[:, i]),
            atol=2e-2, rtol=2e-2)


# ----------------- fused CRAM decode kernel: batched parity + bytes

PAGE, HKV, HD = 8, 1, 32
D2 = 2 * HD


def _cram_pages(rng, lanes, n_groups, comp):
    """Logical pages (lanes*n_groups, PAGE, HKV, D2) int16 where group g is
    delta-compressible iff comp[g].  The codec's base is page A's token-0
    row, so compressible groups put EVERY token of every lane within a
    small signed delta of one shared (HKV, D2) row (fits the int4 quad
    range too); incompressible groups are fresh bf16 bit patterns whose
    token rows never fit the delta budget."""
    pages = np.zeros((lanes * n_groups, PAGE, HKV, D2), np.int16)
    for g in range(n_groups):
        base = np.asarray(jnp.asarray(
            rng.normal(size=(HKV, D2)).astype(np.float32),
            jnp.bfloat16).view(jnp.int16))
        for ln in range(lanes):
            if comp[g]:
                delta = rng.integers(-3, 4, size=(PAGE, HKV, D2))
                pages[g * lanes + ln] = base[None] + delta.astype(np.int16)
            else:
                pages[g * lanes + ln] = np.asarray(jnp.asarray(
                    rng.normal(size=(PAGE, HKV, D2)).astype(np.float32),
                    jnp.bfloat16).view(jnp.int16))
    return pages


def _batched_cram_cache(rng, lanes, n_groups, batch):
    """Per-sequence caches (stacked leaves, shared markers) with mixed
    packed/raw groups and per-sequence partial-page valid counts."""
    build = (kops.build_cram_cache if lanes == 2
             else kops.build_cram_cache_quad)
    caches, valids = [], []
    n_pages = lanes * n_groups
    for b in range(batch):
        comp = rng.random(n_groups) < 0.5
        caches.append(build(jnp.asarray(_cram_pages(rng, lanes, n_groups,
                                                    comp)), interpret=True))
        # odd token counts: partial last page + dead tail groups
        tokens = int(rng.integers(1, n_pages * PAGE + 1))
        valids.append(np.clip(tokens - np.arange(n_pages) * PAGE,
                              0, PAGE).astype(np.int32))
    cache = {k: jnp.stack([c[k] for c in caches])
             for k in ("slots", "slots_overflow", "strips", "packed_mask")}
    cache["markers"] = caches[0]["markers"]
    # mixed layouts must actually be exercised
    ok = np.asarray(cache["packed_mask"])
    assert ok.any() and not ok.all(), "want mixed packed/raw groups"
    return cache, jnp.asarray(np.stack(valids))


def _legacy_vmap_decode(q, cache, vp, lanes):
    """The pre-batched path: the single-sequence kernel vmapped over
    per-sequence physical views (what decode_attention_*_batched did
    before the 2-D grid kernel) — pinned as a parity reference."""
    pv = kops.physical_view if lanes == 2 else kops.physical_view_quad

    def one(qi, slots, over, strips, ok, vpi):
        c = {"slots": slots, "slots_overflow": over, "strips": strips,
             "markers": cache["markers"], "packed_mask": ok}
        s, st, m, v = pv(c, vpi)
        return cram_decode_attention(qi, s, st, m, v, lanes=lanes,
                                     interpret=True)

    return jax.vmap(one)(q, cache["slots"], cache["slots_overflow"],
                         cache["strips"], cache["packed_mask"], vp)


@pytest.mark.parametrize("lanes,batch", [(2, 3), (4, 5)])
def test_fused_batched_kernel_matches_oracle_and_legacy(lanes, batch):
    rng = np.random.default_rng(42 + lanes)
    n_groups = 4
    cache, vp = _batched_cram_cache(rng, lanes, n_groups, batch)
    q = jnp.asarray(rng.normal(size=(batch, 4, HD)).astype(np.float32),
                    jnp.bfloat16)
    ref_fn = (kops.decode_attention_ref_batched if lanes == 2
              else kops.decode_attention_quad_ref_batched)
    ref = np.asarray(ref_fn(q, cache, vp))
    legacy = np.asarray(_legacy_vmap_decode(q, cache, vp, lanes))
    for bg in (1, None, n_groups):
        out, _, _ = kops.decode_attention_fused(q, cache, vp, lanes=lanes,
                                                block_groups=bg,
                                                interpret=True)
        np.testing.assert_allclose(np.asarray(out), ref,
                                   atol=1e-4, rtol=1e-4)
        # vs the old per-sequence vmap path: same kernel math, same
        # accumulation order within a slot — tight tolerance
        np.testing.assert_allclose(np.asarray(out), legacy,
                                   atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("lanes", [2, 4])
def test_fused_kernel_bytes_output_bit_exact(lanes):
    """The kernel's second output IS the byte model: per-sequence (raw,
    cram) totals equal `hbm_bytes_moved` exactly, including the
    LLP-mispredict re-probe term under a random predictor."""
    rng = np.random.default_rng(7 + lanes)
    n_groups = 4
    cache, vp = _batched_cram_cache(rng, lanes, n_groups, 3)
    q = jnp.asarray(rng.normal(size=(3, 4, HD)).astype(np.float32),
                    jnp.bfloat16)
    for pred in (None, jnp.asarray(rng.random((3, n_groups)) < 0.5),
                 ~cache["packed_mask"]):   # worst case: every group missed
        bw = kops.hbm_bytes_moved(cache, vp, predictor=pred, lanes=lanes)
        for bg in (1, 2):
            _, raw_s, cram_s = kops.decode_attention_fused(
                q, cache, vp, pred, lanes=lanes, block_groups=bg,
                interpret=True)
            assert np.array_equal(np.asarray(raw_s), bw["raw_per_seq"])
            assert np.array_equal(np.asarray(cram_s), bw["cram_per_seq"])


def test_fused_kernel_shared_cache_path():
    """`decode_attention` (many query rows, ONE shared cache) rides the
    same batched kernel with the batch coordinate pinned in the index
    maps; bytes repeat per row and match the unbatched byte model."""
    rng = np.random.default_rng(3)
    comp = np.array([True, False, True, True])
    cache = kops.build_cram_cache(
        jnp.asarray(_cram_pages(rng, 2, 4, comp)), interpret=True)
    vp = np.clip(50 - np.arange(8) * PAGE, 0, PAGE).astype(np.int32)
    q = jnp.asarray(rng.normal(size=(5, 4, HD)).astype(np.float32),
                    jnp.bfloat16)
    ref = np.asarray(kops.decode_attention_ref(q, cache, vp))
    out, raw_s, cram_s = kops.decode_attention_fused(
        q, cache, jnp.asarray(vp), lanes=2, interpret=True)
    np.testing.assert_allclose(np.asarray(out), ref, atol=1e-4, rtol=1e-4)
    bw = kops.hbm_bytes_moved(cache, vp, lanes=2)
    assert np.asarray(raw_s).tolist() == [bw["raw_bytes"]] * 5
    assert np.asarray(cram_s).tolist() == [bw["cram_bytes"]] * 5
