"""The analyzer analyzes the analyzer's fixtures — and the real tree.

Three contracts:

  * each rule FIRES on its known-bad fixture (and the CLI exits non-zero
    on it), so a rule that silently stops matching is caught here, not by
    the absence of findings in CI;
  * the rule engine is CLEAN on today's src/repro + benchmarks — the
    invariants in DESIGN.md §11 actually hold on the shipped tree;
  * the jaxpr audit matches its committed golden, and `compare` actually
    detects drift (a perturbed pinned count fails).
"""

from __future__ import annotations

import json
from pathlib import Path

import pytest

from repro.analysis import analyze, default_paths, render_report
from repro.analysis.__main__ import main as cli_main

FIXTURES = Path(__file__).parent / "fixtures" / "analysis"

RULE_FIXTURES = {
    "r1": FIXTURES / "r1_bad.py",
    "r2": FIXTURES / "r2_bad.py",
    "r3": FIXTURES / "r3_bad.py",
    "r4": FIXTURES / "r4_bad.py",
    "r5": FIXTURES / "repro" / "r5_bad.py",
    "r6": FIXTURES / "repro" / "kernels" / "r6_bad.py",
}

# every fixture encodes >= this many distinct violations of its rule
MIN_FINDINGS = {"r1": 1, "r2": 3, "r3": 5, "r4": 2, "r5": 2, "r6": 3}


@pytest.mark.parametrize("rule", sorted(RULE_FIXTURES))
def test_rule_fires_on_fixture(rule):
    path = RULE_FIXTURES[rule]
    found = analyze([path], rules=[rule])
    assert len(found) >= MIN_FINDINGS[rule], \
        f"{rule} found {len(found)} on its bad fixture: {found}"
    assert all(v.rule == rule for v in found)
    assert all(v.line > 0 for v in found)


@pytest.mark.parametrize("rule", sorted(RULE_FIXTURES))
def test_cli_exits_nonzero_on_fixture(rule):
    assert cli_main([str(RULE_FIXTURES[rule])]) == 1


def test_r3_fires_on_prefill_hot_paths():
    """The chunked-prefill ingest counts as a hot path: `prefill` /
    `prefill_slot` entries with host syncs or per-call ledger booking
    must be flagged like any decode-step method."""
    found = analyze([FIXTURES / "r3_prefill_bad.py"], rules=["r3"])
    assert len(found) >= 5, found
    assert all(v.rule == "r3" for v in found)
    msgs = " ".join(v.message for v in found)
    assert "'prefill'" in msgs and "'prefill_slot'" in msgs, msgs


def test_fixture_findings_are_rule_scoped():
    """A fixture only has to be bad its OWN way: with all rules on, the
    r5/r6 fixtures (path-scoped) still report their own rule."""
    for rule, path in RULE_FIXTURES.items():
        found = analyze([path])
        assert any(v.rule == rule for v in found), (rule, found)


def test_tree_is_clean():
    violations = analyze(default_paths())
    assert violations == [], "\n".join(str(v) for v in violations)


def test_cli_clean_tree_exit_zero(tmp_path):
    out = tmp_path / "report.json"
    assert cli_main(["--report", "json", "--out", str(out)]) == 0
    report = json.loads(out.read_text())
    assert report["ok"] is True
    assert report["counts"] == {}
    assert report["files_scanned"] > 100
    assert set(report["rules"]) == set(RULE_FIXTURES)


def test_render_report_shape():
    found = analyze([RULE_FIXTURES["r4"]], rules=["r4"])
    report = render_report(found, files_scanned=1)
    assert report["ok"] is False
    assert report["counts"]["r4"] == len(found)
    assert report["violations"][0]["rule"] == "r4"


# ------------------------------------------------------------- jaxpr audit


@pytest.fixture(scope="module")
def jaxpr_report():
    from repro.analysis import jaxpr_audit

    return jaxpr_audit.audit()


def test_jaxpr_hard_invariants(jaxpr_report):
    from repro.analysis import jaxpr_audit

    assert jaxpr_audit.hard_violations(jaxpr_report) == []


def test_jaxpr_matches_golden(jaxpr_report):
    from repro.analysis import jaxpr_audit

    golden = json.loads(jaxpr_audit.GOLDEN_PATH.read_text())
    assert jaxpr_audit.compare(jaxpr_report, golden) == []


def test_jaxpr_compare_detects_drift(jaxpr_report):
    from repro.analysis import jaxpr_audit

    golden = json.loads(json.dumps(jaxpr_audit.golden_view(jaxpr_report)))
    golden["entries"]["fused_decode_pair"]["pinned"]["pallas_call"] = 2
    drift = jaxpr_audit.compare(jaxpr_report, golden)
    assert any("fused_decode_pair" in m and "pallas_call" in m
               for m in drift)


def test_jaxpr_golden_pins_the_kernel_budget():
    """The committed golden itself encodes the paper-level claims: one
    fused pallas_call per decode shape, zero host callbacks anywhere."""
    golden = json.loads(
        (Path(__file__).parent / "golden" / "jaxpr_audit.json").read_text())
    entries = golden["entries"]
    for shape in ("fused_decode_pair", "fused_decode_quad",
                  "fused_decode_batched"):
        assert entries[shape]["pinned"]["pallas_call"] == 1
    for entry in entries.values():
        for cb in ("pure_callback", "io_callback", "debug_callback"):
            assert entry["pinned"].get(cb, 0) == 0
        assert entry["f64"] is False
    assert entries["serve_scatters"]["donation"] is True
    assert entries["serve_scatters"]["pinned"]["scatter_tokens_donation"]
    assert entries["ckpt_pack_batch"]["pinned"]["jax_arrays_created"] == 0
