"""End-to-end launcher tests: train loop with fault injection + serving."""

import json

import pytest


def test_train_with_fault_and_resume(tmp_path):
    from repro.launch.train import main as train_main

    out = train_main([
        "--preset", "lm2m", "--steps", "14", "--batch", "2",
        "--ckpt-every", "5", "--ckpt-dir", str(tmp_path / "ckpt"),
        "--inject-fault", "8", "--seed", "3",
    ])
    assert out["steps"] == 14
    assert out["restarts"] == 1
    # resumed run must have continued past the fault
    assert (tmp_path / "ckpt").exists()


def test_serve_generates_and_mirrors_serve_tier():
    from repro.launch.serve import main as serve_main

    out = serve_main(["--preset", "lm2m", "--batch", "2",
                      "--prompt-len", "12", "--gen", "6"])
    assert len(out["sample"]) >= 6
    tier = out["serve_tier"]
    assert tier is not None
    assert tier["admitted"] == 2 and tier["retired"] == 2
    assert tier["evicted"] == 0          # slots default to one per seq


def test_serve_spills_compressed_under_slot_pressure():
    from repro.launch.serve import main as serve_main

    # 2 sequences into 1 lane: every step of the cold sequence crosses
    # the spill tier, and every crossing books a ledger spill event
    out = serve_main(["--preset", "lm2m", "--batch", "2",
                      "--prompt-len", "12", "--gen", "6",
                      "--slots", "1", "--admit-rate", "2",
                      "--spill-packing", "quad"])
    tier = out["serve_tier"]
    assert tier["evicted"] >= 1 and tier["woken"] >= 1
    assert tier["retired"] == 2          # churn still drains every seq
    sp = tier["spill_tier"]
    assert sp["spills"] == tier["evicted"]
    assert sp["restores"] == tier["woken"]
    spill_rows = [ev for tc in out["traffic"].get("kv", {}).values()
                  for name, ev in tc.items() if name == "spill"]
    assert sum(r["count"] for r in spill_rows) == \
        tier["evicted"] + tier["woken"]
