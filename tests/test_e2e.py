"""End-to-end launcher tests: train loop with fault injection + serving."""

import json

import pytest


def test_train_with_fault_and_resume(tmp_path):
    from repro.launch.train import main as train_main

    out = train_main([
        "--preset", "lm2m", "--steps", "14", "--batch", "2",
        "--ckpt-every", "5", "--ckpt-dir", str(tmp_path / "ckpt"),
        "--inject-fault", "8", "--seed", "3",
    ])
    assert out["steps"] == 14
    assert out["restarts"] == 1
    # resumed run must have continued past the fault
    assert (tmp_path / "ckpt").exists()


def test_serve_generates_and_mirrors_cram_kv():
    from repro.launch.serve import main as serve_main

    out = serve_main(["--preset", "lm2m", "--batch", "2",
                      "--prompt-len", "12", "--gen", "6"])
    assert len(out["sample"]) >= 6
    kv = out["cram_kv"]
    assert kv is not None
    assert kv["kernel_vs_oracle_err"] < 1e-3
