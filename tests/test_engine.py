"""Unified engine: golden parity, execution modes, and the scheme registry.

The golden fixture (tests/golden/engine_stats.json) was produced by the
PRE-refactor per-scheme simulator on the deterministic trace generator —
the unified engine must reproduce every stats vector bit-identically
through both the scalar (1×1) and batched (vmapped) instantiations, and
through the chunked and sharded execution modes.
"""

import json
import os
import pathlib
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import schemes as schemes_registry
from repro.core.batchsim import sweep
from repro.core.engine import (
    N_FLAGS,
    N_PARAMS,
    N_STATS,
    ST_PRED_HIT,
    ST_READ_PROBES,
    STAT_NAMES,
    SimConfig,
)
from repro.core.memsim import SCHEMES, _STAT_NAMES, simulate
from repro.core.schemes import Scheme
from repro.core.traces import build_workload

GOLDEN = json.loads(
    (pathlib.Path(__file__).parent / "golden" / "engine_stats.json")
    .read_text())
NAMES = ("libq", "pr_twi", "mix3")
CFG = SimConfig()


@pytest.fixture(scope="module")
def wls():
    return {n: build_workload(n, GOLDEN["n_events"], seed=GOLDEN["seed"])
            for n in NAMES}


def _golden_vec(scheme: str, workload: str) -> np.ndarray:
    return np.asarray(GOLDEN["stats"][scheme][workload], np.int32)


def test_stat_names_single_source():
    assert tuple(GOLDEN["stat_names"]) == STAT_NAMES == _STAT_NAMES
    assert len(STAT_NAMES) == N_STATS


@pytest.mark.parametrize("scheme", SCHEMES)
def test_scalar_reproduces_prerefactor_golden(wls, scheme):
    for name in NAMES:
        _, a, w, pab, pcd, pq, _ = wls[name]
        r = simulate(scheme, a, w, pab, pcd, pq, CFG)
        got = np.asarray([r.stats[k] for k in STAT_NAMES], np.int32)
        assert np.array_equal(got, _golden_vec(scheme, name)), (
            f"{scheme}/{name}: {got} != golden")


@pytest.fixture(scope="module")
def stacked(wls):
    ws = [wls[n] for n in NAMES]
    return tuple(np.stack([w[i] for w in ws]) for i in range(1, 6))


def test_batched_reproduces_prerefactor_golden(stacked):
    stats = sweep(SCHEMES, *stacked, CFG)
    for si, sch in enumerate(SCHEMES):
        for wi, name in enumerate(NAMES):
            assert np.array_equal(stats[si, wi], _golden_vec(sch, name)), (
                f"{sch}/{name}")


def test_chunked_sweep_bit_identical(stacked):
    whole = sweep(SCHEMES, *stacked, CFG)
    # chunk boundary not dividing T exercises the remainder dispatch
    chunked = sweep(SCHEMES, *stacked, CFG, chunk_size=5_000)
    assert np.array_equal(whole, chunked)


def test_scalar_chunked_bit_identical(wls):
    _, a, w, pab, pcd, pq, _ = wls["libq"]
    r = simulate("dynamic", a, w, pab, pcd, pq, CFG, chunk_size=5_000)
    got = np.asarray([r.stats[k] for k in STAT_NAMES], np.int32)
    assert np.array_equal(got, _golden_vec("dynamic", "libq"))


def test_config_axis_rides_same_dispatch(wls):
    """Config variants (params rows) batch with behaviour schemes in ONE
    dispatch: full-size variants are bit-equal to their base scheme;
    shrunken LCT / metadata-cache ablations change the stats."""
    from repro.core.engine import ST_META_READS

    _, a, w, pab, pcd, pq, _ = wls["libq"]
    lct_full = Scheme("lct-full-test", comp=True, llp=True, lct_size=512)
    meta_full = Scheme("meta-full-test", comp=True, meta=True,
                       meta_sets=CFG.meta_sets)
    meta_small = Scheme("meta-small-test", comp=True, meta=True, meta_sets=4)
    stats = sweep(("cram", "cram@lct64", lct_full,
                   "explicit", meta_full, meta_small),
                  a[None], w[None], pab[None], pcd[None], pq[None], CFG)
    assert np.array_equal(stats[0, 0], _golden_vec("cram", "libq"))
    assert np.array_equal(stats[2, 0], stats[0, 0])
    assert not np.array_equal(stats[1, 0], stats[0, 0])
    assert np.array_equal(stats[3, 0], _golden_vec("explicit", "libq"))
    assert np.array_equal(stats[4, 0], stats[3, 0])
    # a 4-set (2KB) metadata cache must miss more than the 64-set (32KB) one
    assert stats[5, 0][ST_META_READS] > stats[3, 0][ST_META_READS]


def test_cram_nollp_pays_for_missing_predictor():
    """Force packed-state refetches: pass 1 installs + evicts groups packed
    (everything quad-able), pass 2 refetches them.  With the LCT frozen at
    level 0 (cram-nollp) every non-home lane pays the probe chain; the
    learned LCT (cram) mispredicts only once per page."""
    cfg = SimConfig(llc_sets=8, llc_ways=2, n_groups=256)
    lines = cfg.n_groups * 4
    # pass 2 touches only lane 1 of each (now packed, evicted) group, so
    # every access is a non-home-lane miss that needs the slot prediction
    addrs = np.concatenate([
        np.arange(lines, dtype=np.int32),
        np.arange(cfg.n_groups, dtype=np.int32) * 4 + 1,
    ])[None]
    wr = np.zeros_like(addrs, dtype=bool)
    ones = np.ones((1, cfg.n_groups), dtype=bool)
    stats = sweep(("cram", "cram-nollp"), addrs, wr, ones, ones, ones, cfg)
    cram, nollp = stats[0, 0], stats[1, 0]
    assert nollp[ST_READ_PROBES] > cram[ST_READ_PROBES]
    assert nollp[ST_PRED_HIT] < cram[ST_PRED_HIT]


def test_sharded_sweep_bit_identical_to_single_device():
    """shard_map over a forced 2-device CPU must match the single-device
    dispatch exactly (fresh process: device count is fixed at jax init)."""
    code = textwrap.dedent("""
        import os
        os.environ["JAX_PLATFORMS"] = "cpu"
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                                   + " --xla_force_host_platform_device_count=2")
        import numpy as np
        import jax
        from repro.core.batchsim import sweep
        from repro.core.engine import SimConfig

        assert len(jax.devices()) == 2
        cfg = SimConfig(llc_sets=16, llc_ways=2, n_groups=512)
        rng = np.random.default_rng(7)
        T, W = 800, 2
        addrs = rng.integers(0, cfg.n_groups * 4, (W, T)).astype(np.int32)
        wr = rng.random((W, T)) < 0.3
        pab = rng.random((W, cfg.n_groups)) < 0.6
        pcd = rng.random((W, cfg.n_groups)) < 0.6
        quad = rng.random((W, cfg.n_groups)) < 0.3
        schemes = ("baseline", "cram", "dynamic")
        sharded = sweep(schemes, addrs, wr, pab, pcd, quad, cfg, shard=True)
        single = sweep(schemes, addrs, wr, pab, pcd, quad, cfg, shard=False)
        assert np.array_equal(sharded, single), (sharded, single)
        print("SHARD-OK")
    """)
    env = dict(os.environ)
    src = str(pathlib.Path(__file__).resolve().parents[1] / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SHARD-OK" in out.stdout


def test_registry_round_trip():
    for name in SCHEMES:
        sch = schemes_registry.get(name)
        assert sch.name == name
        assert sch.flags().shape == (N_FLAGS,)
        assert sch.params(CFG).shape == (N_PARAMS,)
    assert "cram-nollp" in schemes_registry.names()
    with pytest.raises(KeyError, match="unknown scheme"):
        schemes_registry.get("not-a-scheme")
    with pytest.raises(ValueError, match="already registered"):
        schemes_registry.register(schemes_registry.get("cram"))
    with pytest.raises(ValueError, match="lct_size"):
        Scheme("bad", lct_size=0)


def test_variant_derivation():
    v = schemes_registry.variant("dynamic", "dyn-test-variant",
                                 sample_rate=0.5, overwrite=True)
    assert v.dynamic and v.comp and v.llp
    from repro.core.engine import PARAM_SAMPLE_THRESH
    assert v.params(CFG)[PARAM_SAMPLE_THRESH] == 512
    assert schemes_registry.get("dyn-test-variant") is v
