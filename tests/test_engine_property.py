"""Property test: random (flags, params) points through the engine's scalar
vs batched instantiations must agree bit-for-bit.

The scalar and batched front-ends share the engine step by construction;
what can still diverge is the batching itself (vmap lowering, gather/
scatter batching rules).  So: drive arbitrary flag combinations — including
nonsensical ones like ideal-without-comp — and traced config params through
both instantiations and require exact int32 equality.

Both callables are compiled ONCE (flags/params are traced arguments here,
not closed-over constants), so each hypothesis example only pays two
dispatches of a short scan.
"""

import numpy as np
from hypothesis import given, strategies as st

from repro.core.dynamic import COUNTER_MAX
from repro.core.engine import (
    N_FLAGS,
    N_PARAMS,
    PARAM_COUNTER_INIT,
    PARAM_LCT_SIZE,
    PARAM_META_SETS,
    PARAM_SAMPLE_THRESH,
    SimConfig,
    build_engine,
)

CFG = SimConfig(llc_sets=16, llc_ways=2, n_groups=512)
T = 600

_FNS = {}


def _fns():
    if not _FNS:
        import jax

        eng = build_engine(CFG)
        run_w = jax.vmap(eng.run_one, in_axes=(None, None, 0, 0, 0, 0, 0))
        run_sw = jax.vmap(run_w, in_axes=(0, 0, None, None, None, None, None))
        _FNS["scalar"] = jax.jit(eng.run_one)
        _FNS["batched"] = jax.jit(run_sw)
    return _FNS["scalar"], _FNS["batched"]


@given(
    flags=st.lists(st.booleans(), min_size=N_FLAGS, max_size=N_FLAGS),
    lct_size=st.sampled_from((1, 7, 64, 512)),
    thresh=st.integers(0, 1024),
    cinit=st.integers(0, COUNTER_MAX),
    meta_sets=st.sampled_from((1, 16, 64)),
    seed=st.integers(0, 2**16),
)
def test_random_flag_points_scalar_equals_batched(flags, lct_size, thresh,
                                                  cinit, meta_sets, seed):
    rng = np.random.default_rng(seed)
    addrs = rng.integers(0, CFG.n_groups * 4, T).astype(np.int32)
    wr = rng.random(T) < 0.4
    pab = rng.random(CFG.n_groups) < 0.6
    pcd = rng.random(CFG.n_groups) < 0.6
    quad = rng.random(CFG.n_groups) < 0.3

    fl = np.asarray(flags, np.int32)
    pr = np.zeros(N_PARAMS, np.int32)
    pr[PARAM_LCT_SIZE] = lct_size
    pr[PARAM_SAMPLE_THRESH] = thresh
    pr[PARAM_COUNTER_INIT] = cinit
    pr[PARAM_META_SETS] = meta_sets

    scalar, batched = _fns()
    a = np.asarray(scalar(fl, pr, addrs, wr, pab, pcd, quad))
    b = np.asarray(batched(fl[None], pr[None], addrs[None], wr[None],
                           pab[None], pcd[None], quad[None]))[0, 0]
    assert np.array_equal(a, b), (fl.tolist(), pr.tolist(), a, b)
