"""Bandwidth ledger + autotune policy layer (ISSUE 5).

Cross-consumer parity: the ledger adapter views must reproduce each
consumer's legacy counters exactly (engine STAT accesses, KV byte dicts,
checkpoint manifests, gradient wire math).  AutoTuner: deterministic
golden decision table, the no-slowdown fallback, and the §VI
ledger-driven gate.  Plus the vectorized fpc/hybrid exact pack paths
(byte-identical to the per-line packers) that `codec="auto"` relies on.

Deliberately hypothesis-free: these run in tier-1 from a clean checkout.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.bandwidth import (
    EV_READ,
    EV_WRITE,
    AutoTuner,
    Ledger,
    device_record,
    device_totals,
    engine_traffic,
    probe_kv_fit_rates,
)
from repro.bandwidth.adapters import (
    classify_tensor,
    int8_wire_bytes,
    tree_wire_bytes,
)
from repro.compression import codecs as codecs_reg
from repro.compression.framing import LINE_BYTES
from repro.kv import CRAMKVCache, synthetic_kv_stream

PAGE, HKV, HD = 8, 1, 32


def _adversarial_lines(n_random=40, seed=0):
    rng = np.random.default_rng(seed)
    blocks = [
        rng.integers(0, 256, (n_random, 64)).astype(np.uint8),
        np.zeros((4, 64), np.uint8),
        rng.integers(-8, 8, (12, 16)).astype("<i4").view(np.uint8)
        .reshape(-1, 64),
        np.tile(rng.integers(0, 256, (6, 1)).astype(np.uint8), (1, 64)),
        rng.integers(0, 2 ** 16, (12, 16)).astype("<i4").view(np.uint8)
        .reshape(-1, 64),
    ]
    z = rng.integers(0, 256, (8, 64)).astype(np.uint8)
    z[:, 12:52] = 0                      # interior zero runs (RLE chunking)
    blocks.append(z)
    lines = np.concatenate(blocks)
    rng.shuffle(lines)
    return lines


# ------------------------------------------------------------------ ledger

def test_ledger_record_totals_and_saving():
    led = Ledger("kv")
    led.record(EV_READ, raw=100, compressed=60)
    led.record(EV_READ, raw=50, compressed=50, tensor_class="other")
    led.record("write", raw=10)          # name form; compressed defaults raw
    t = led.total()
    assert (t["raw_bytes"], t["compressed_bytes"], t["count"]) == (160, 120, 3)
    assert led.total(EV_READ, tensor_class="default")["raw_bytes"] == 100
    assert led.saving(EV_READ, tensor_class="default") == pytest.approx(0.4)
    with pytest.raises(KeyError):
        led.record("bogus", raw=1)


def test_ledger_merge_and_as_dict_roundtrip():
    a, b = Ledger("one"), Ledger("two")
    a.record(EV_READ, raw=10, compressed=5)
    b.record(EV_WRITE, raw=7, compressed=7, tensor_class="weights")
    a.merge(b)
    d = a.as_dict()
    assert d["one"]["default"]["read"]["raw_bytes"] == 10
    assert d["two"]["weights"]["write"]["compressed_bytes"] == 7
    assert a.consumers() == ("one", "two")


def test_device_accumulator_absorbs_into_host_ledger():
    tot = device_totals(jnp)
    tot = device_record(tot, EV_READ, 128, 64)
    tot = device_record(tot, EV_READ, 128, 64, count=2)
    led = Ledger("dev")
    led.absorb(tot)
    t = led.total(EV_READ)
    assert (t["raw_bytes"], t["compressed_bytes"], t["count"]) == (256, 128, 3)


def test_device_record_traceable_under_jit():
    @jax.jit
    def step(tot, nbytes):
        return device_record(tot, EV_WRITE, nbytes, nbytes // 2)

    tot = device_totals(jnp)
    for _ in range(3):
        tot = step(tot, jnp.int32(100))
    led = Ledger()
    led.absorb(tot)
    assert led.total(EV_WRITE)["raw_bytes"] == 300


# ------------------------------------------------- engine adapter parity

def test_engine_ledger_matches_legacy_access_count():
    from repro.core.memsim import simulate
    from repro.core.traces import build_workload

    _, a, w, pab, pcd, pq, _ = build_workload("libq", 4000, seed=3)
    for scheme in ("baseline", "cram", "dynamic", "explicit"):
        r = simulate(scheme, a, w, pab, pcd, pq)
        led = engine_traffic(r.stats)
        assert led.total()["raw_bytes"] == r.accesses * LINE_BYTES
        assert led.total()["compressed_bytes"] == r.accesses * LINE_BYTES
        # category rows partition the access count exactly
        assert led.total()["count"] == r.accesses


def test_engine_ledger_category_partition():
    stats = dict.fromkeys(
        ("demand_reads", "read_probes", "wb_dirty", "wb_clean", "il_writes",
         "meta_reads", "meta_wb", "pf_extra_access"), 0)
    stats.update(demand_reads=10, read_probes=12, wb_dirty=3, meta_reads=2)
    led = engine_traffic(stats)
    assert led.total("read", tensor_class="lines")["count"] == 10
    assert led.total("probe", tensor_class="lines")["count"] == 2
    assert led.total("write", tensor_class="lines")["count"] == 3
    assert led.total(tensor_class="metadata")["count"] == 2
    # the untagged aggregate equals the access count — no summary rows
    assert led.total()["raw_bytes"] == 17 * LINE_BYTES


# ----------------------------------------------------- KV adapter parity

def test_kv_ledger_matches_per_step_byte_dicts():
    rng = np.random.default_rng(0)
    cache = CRAMKVCache(max_pages=8, page=PAGE, n_kv=HKV, head_dim=HD,
                        batch=2, policy="static")
    raw_sum = cram_sum = 0
    for t in (2 * PAGE, 3, PAGE, 1):
        cache.append(*synthetic_kv_stream(rng, 2, t, HKV, HD))
        bw = cache.account_step()
        raw_sum += int(bw["raw_bytes"])
        cram_sum += int(bw["cram_bytes"])
    # decode accounting is device-resident: nothing reaches the host
    # ledger until the window fold...
    assert cache.ledger.total("read", consumer="kv")["raw_bytes"] == 0
    cache.sync_ledger()
    # ...which lands the exact per-step sums, one count per step
    tot = cache.ledger.total("read", consumer="kv")
    assert tot["raw_bytes"] == raw_sum
    assert tot["compressed_bytes"] == cram_sum
    assert tot["count"] == 4
    # folding again books nothing new (the window resets)
    cache.sync_ledger()
    assert cache.ledger.total("read", consumer="kv")["count"] == 4
    assert cache.saving() == pytest.approx(1 - cram_sum / raw_sum)
    # repack write traffic booked too, raw == groups * lanes * slot bytes
    rp = cache.ledger.total("repack", consumer="kv")
    assert rp["raw_bytes"] == (cache.stats.pack_pairs_processed
                               * cache.group_lanes * cache.slot_bytes)


def test_kv_shared_ledger_keeps_consumer_rows():
    led = Ledger("serve")
    rng = np.random.default_rng(1)
    cache = CRAMKVCache(max_pages=4, page=PAGE, n_kv=HKV, head_dim=HD,
                        policy="static", ledger=led)
    cache.append(*synthetic_kv_stream(rng, 1, 2 * PAGE, HKV, HD))
    cache.account_step()
    cache.sync_ledger()
    assert led.total("read", consumer="kv")["raw_bytes"] > 0
    assert led is cache.ledger


# --------------------------------------------- checkpoint adapter parity

def test_checkpoint_manifest_equals_ledger(tmp_path):
    pytest.importorskip("msgpack")
    from repro.checkpoint import load_checkpoint, save_checkpoint

    rng = np.random.default_rng(0)
    tree = {"w": rng.standard_normal((32, 64)).astype(np.float32),
            "opt/moments": np.zeros((64, 64), np.float32)}
    led = Ledger("train")
    save_checkpoint(tmp_path, 1, tree, codec="cram", ledger=led)
    out, man = load_checkpoint(tmp_path, 1, jax.tree.map(np.zeros_like,
                                                         tree))
    t = led.total("write")
    assert t["raw_bytes"] == sum(m["raw_bytes"] for m in man["leaves"])
    assert t["compressed_bytes"] == sum(m["stored_bytes"]
                                        for m in man["leaves"])
    # the embedded traffic view agrees with the shared ledger
    embedded = man["traffic"]["checkpoint"]
    total_raw = sum(ev["raw_bytes"] for tc in embedded.values()
                    for ev in tc.values())
    assert total_raw == t["raw_bytes"]
    # tensor classes split by the taxonomy
    assert led.total("write", tensor_class="moments")["raw_bytes"] > 0
    assert classify_tensor("opt/moments") == "moments"
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree),
                    strict=True):
        assert np.array_equal(a, b)


def test_checkpoint_auto_roundtrip_and_never_worse_than_raw(tmp_path):
    pytest.importorskip("msgpack")
    from repro.checkpoint import load_checkpoint, save_checkpoint

    rng = np.random.default_rng(0)
    tree = {"weights": rng.standard_normal(4096).astype(np.float32),
            "opt/moments": np.zeros(8192, np.float32),
            "misc": rng.integers(0, 256, 512, dtype=np.uint8),
            "step": np.int32(7)}   # tiny leaf: framing must not inflate it
    led_auto, led_raw = Ledger(), Ledger()
    save_checkpoint(tmp_path / "auto", 1, tree, codec="auto",
                    ledger=led_auto)
    save_checkpoint(tmp_path / "raw", 1, tree, codec="raw", ledger=led_raw)
    out, man = load_checkpoint(tmp_path / "auto", 1,
                               jax.tree.map(np.zeros_like, tree))
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree),
                    strict=True):
        assert np.array_equal(a, b)
    # per-leaf codecs recorded; zero-heavy moments leaf must compress
    by_key = {m["key"]: m for m in man["leaves"]}
    assert by_key["opt/moments"]["codec"] != "raw"
    assert by_key["opt/moments"]["stored_bytes"] < \
        by_key["opt/moments"]["raw_bytes"] / 4
    # per-leaf no-slowdown: no leaf — scalar included — stores more than
    # the plain raw writer would (stream framing must not eat the win)
    for m in man["leaves"]:
        assert m["stored_bytes"] <= m["raw_bytes"], m
    assert (led_auto.total("write")["compressed_bytes"]
            <= led_raw.total("write")["compressed_bytes"])


# ------------------------------------------------- gradient wire parity

def test_grad_wire_bytes_adapters():
    tree = {"a": jnp.zeros((16, 16), jnp.float32),
            "b": jnp.zeros((8,), jnp.bfloat16)}
    assert tree_wire_bytes(tree) == 16 * 16 * 4 + 8 * 2
    assert int8_wire_bytes(tree) == 16 * 16 + 4 + 8 + 4


def test_dp_step_books_wire_bytes_per_policy():
    from repro.optim.grad_compress import make_dp_compressed_step

    class _Quad:
        def loss(self, params, batch):
            return jnp.mean((params["w"] - batch) ** 2)

    mesh = jax.make_mesh((1,), ("data",))
    params = {"w": jnp.ones((8, 8), jnp.float32)}
    err = jax.tree.map(jnp.zeros_like, params)
    batch = jnp.zeros((1, 8, 8), jnp.float32)
    raw = tree_wire_bytes(params)
    for policy, want_comp in (("static", int8_wire_bytes(params)),
                              ("off", raw), ("auto", int8_wire_bytes(params))):
        led = Ledger()
        step = make_dp_compressed_step(_Quad(), mesh, policy=policy,
                                       ledger=led)
        from repro.compression.gate import COUNTER_INIT
        counter = jnp.int32(COUNTER_INIT)
        p, e, counter, loss = step(params, err, counter, batch)
        t = led.total("write", consumer="grad")
        assert t["raw_bytes"] == raw
        assert t["compressed_bytes"] == want_comp
        assert np.isfinite(float(loss))


def test_gate_update_routes_through_shared_wire_gate():
    from repro.compression import gate
    from repro.optim import grad_compress as gc

    c = jnp.int32(gate.ENABLE_THRESHOLD + 10)
    # defaults reproduce the historical inline constants: +12 / -64
    c1 = gc.gate_update(c, jnp.float32(0.01))
    assert int(c1) == int(c) + int(0.75 * gate.WIRE_BENEFIT_SCALE)
    c2 = gc.gate_update(c, jnp.float32(0.5))
    assert int(c2) == int(c) + int(0.75 * gate.WIRE_BENEFIT_SCALE) \
        - gate.WIRE_COST_OVER_BUDGET


# ------------------------------------------------------------- autotuner

def test_autotuner_golden_decision_table():
    tuner = AutoTuner()
    table = {
        # (pair_fit, quad_fit) -> packing the §VI economy must pick
        (0.0, 0.0): "off",
        (0.95, 0.0): "pair",
        (0.9, 0.85): "quad",
        (0.1, 0.05): "off",     # below breakeven (~0.22): strip overhead
                                # of the unpacked groups beats the fits
    }
    for (p, q), want in table.items():
        got = tuner.choose_kv_packing({"pair": p, "quad": q})
        assert got.choice == want, (p, q, got)
        # deterministic: same telemetry, same decision
        again = tuner.choose_kv_packing({"pair": p, "quad": q})
        assert got.choice == again.choice and got.expected == again.expected


def test_autotuner_ckpt_codec_probe():
    tuner = AutoTuner()
    zeros = np.zeros((64, 64), np.uint8)
    rand = np.random.default_rng(0).integers(0, 256, (64, 64),
                                             dtype=np.uint8)
    assert tuner.choose_ckpt_codec(zeros).choice in ("bdi", "hybrid", "fpc")
    assert tuner.choose_ckpt_codec(rand).choice == "raw"
    # expected sizes cover every registered line codec
    got = tuner.choose_ckpt_codec(zeros)
    assert set(got.expected) == set(codecs_reg.codec_names("line64"))


def test_autotuner_ledger_gate_disables_and_reenables():
    """observe() judges each WINDOW of new traffic: a regime change flips
    the gate within a bounded number of windows regardless of how much
    history the long-lived ledger has accumulated."""
    tuner = AutoTuner()
    led = Ledger("kv")
    # long compressible history: counter saturates enabled
    for _ in range(50):
        led.record(EV_READ, raw=100, compressed=50)
        tuner.observe(led, key="kv", consumer="kv")
    assert tuner.gate_enabled("kv")
    # an empty window is a no-op, not a benefit
    before = tuner.counter("kv")
    tuner.observe(led, key="kv", consumer="kv")
    assert tuner.counter("kv") == before
    # regime change: compression starts HURTING; despite the cumulative
    # saving still being positive, the per-window costs flip the MSB fast
    flips = 0
    while tuner.gate_enabled("kv"):
        led.record(EV_READ, raw=100, compressed=130)
        tuner.observe(led, key="kv", consumer="kv")
        flips += 1
        assert flips < 40, "gate failed to disable on bad windows"
    assert led.saving(EV_READ) > 0          # lifetime totals still look good
    choice = tuner.choose_kv_packing({"pair": 1.0, "quad": 1.0})
    assert choice.choice == "off"                     # forced by the gate
    # compressible traffic returns: §VI re-enable
    flips = 0
    while not tuner.gate_enabled("kv"):
        led.record(EV_READ, raw=100, compressed=40)
        tuner.observe(led, key="kv", consumer="kv")
        flips += 1
        assert flips < 40, "gate failed to re-enable on good windows"


def test_kv_cache_auto_constructor():
    rng = np.random.default_rng(0)
    tight = synthetic_kv_stream(rng, 1, 6 * PAGE, HKV, HD, scale=2e-4)
    noise = synthetic_kv_stream(rng, 1, 6 * PAGE, HKV, HD,
                                compressible=False)
    cache, choice = CRAMKVCache.auto(AutoTuner(), *tight, max_pages=8,
                                     page=PAGE, n_kv=HKV, head_dim=HD)
    assert choice.choice in ("pair", "quad")
    assert cache.policy == "auto" and cache.packing == choice.choice
    cache_off, choice_off = CRAMKVCache.auto(AutoTuner(), *noise,
                                             max_pages=8, page=PAGE,
                                             n_kv=HKV, head_dim=HD)
    assert choice_off.choice == "off" and cache_off.policy == "off"
    # the auto cache runs end-to-end
    cache.append(*tight)
    bw = cache.account_step()
    assert bw["cram_bytes"] < bw["raw_bytes"]


def test_kv_cache_auto_runs_the_dynamic_gate():
    """policy="auto" is the §VI gate over the tuner-chosen layout: when
    the live stream stops compressing, the counter must actually move and
    disable packing (regression: the repack counter update used to fire
    only for policy=="dynamic", leaving auto permanently static)."""
    from repro.compression.gate import ENABLE_THRESHOLD

    rng = np.random.default_rng(0)
    tight = synthetic_kv_stream(rng, 1, 4 * PAGE, HKV, HD, scale=2e-4)
    cache, choice = CRAMKVCache.auto(
        AutoTuner(), *tight, max_pages=32, page=PAGE, n_kv=HKV,
        head_dim=HD, counter_init=ENABLE_THRESHOLD + 1)
    assert cache.policy == "auto" and choice.choice != "off"
    cache.append(*tight)
    cache.repack()
    assert cache.enabled().all()
    # incompressible traffic must drag the counter below the MSB (each
    # complete unfit group costs one tick; the prefill credited a few)
    noise = synthetic_kv_stream(rng, 1, 16 * PAGE, HKV, HD,
                                compressible=False)
    cache.append(*noise)
    cache.repack()
    assert not cache.enabled().any()


def test_probe_kv_fit_rates_orders_compressibility():
    rng = np.random.default_rng(0)
    tight = synthetic_kv_stream(rng, 1, 8 * PAGE, HKV, HD, scale=2e-4)
    noise = synthetic_kv_stream(rng, 1, 8 * PAGE, HKV, HD,
                                compressible=False)
    rt = probe_kv_fit_rates(*tight, page=PAGE)
    rn = probe_kv_fit_rates(*noise, page=PAGE)
    assert rt["pair"] > 0.9 and rt["quad"] > 0.9
    assert rn["pair"] == 0.0 and rn["quad"] == 0.0


# ------------------------------------- vectorized exact pack path parity

@pytest.mark.parametrize("codec", ["raw", "bdi", "fpc", "hybrid"])
def test_pack_batch_bit_identical_to_per_line(codec):
    lines = _adversarial_lines()
    c = codecs_reg.get_codec(codec)
    ref = b"".join(bytes(c.pack_line(line)) for line in lines)
    got = np.asarray(c.pack_batch(lines)).tobytes()
    assert got == ref


@pytest.mark.parametrize("codec", ["fpc", "hybrid"])
def test_checkpoint_stream_roundtrip_vectorized(codec):
    from repro.checkpoint.codec import (
        cram_compress_bytes,
        cram_decompress_bytes,
    )

    raw = _adversarial_lines(seed=7).tobytes() + b"tail-bytes"
    blob = cram_compress_bytes(raw, codec=codec)
    assert cram_decompress_bytes(blob) == raw


# ------------------------------------------------- spill tier (ISSUE 6)

def test_kv_spill_event_books_exactly_one_row_per_crossing():
    from repro.bandwidth.adapters import kv_spill_event

    led = Ledger()
    kv_spill_event(led, raw=1000, compressed=400, direction="evict")
    kv_spill_event(led, raw=1000, compressed=400, direction="restore")
    for tc in ("kv-evict", "kv-restore"):
        t = led.total("spill", consumer="kv", tensor_class=tc)
        assert (t["raw_bytes"], t["compressed_bytes"], t["count"]) == \
            (1000, 400, 1)
    # the aggregate spill row carries the compressed duals
    assert led.saving("spill", consumer="kv") == pytest.approx(0.6)
    with pytest.raises(AssertionError):
        kv_spill_event(led, raw=1, compressed=1, direction="sideways")


def test_serve_loop_spill_crossings_hit_the_shared_ledger():
    """Every evict and every wake books exactly ONE `spill` event, with
    the compressed payload strictly under raw on a compressible stream."""
    from repro.kv import synthetic_kv_stream as _skv
    from repro.serving import ServeLoop

    rng = np.random.default_rng(0)
    led = Ledger("serve")
    loop = ServeLoop(slots=2, max_pages=8, page=PAGE, n_kv=HKV, head_dim=HD,
                     policy="static", spill_packing="quad", ledger=led)
    k, v = _skv(rng, 1, 6 * PAGE, HKV, HD)
    loop.admit(0, k[0], v[0])
    loop.evict(0)
    loop.spill.flush()       # async pipeline: ledger commit is at collection
    ev = led.total("spill", consumer="kv", tensor_class="kv-evict")
    assert ev["count"] == 1
    assert 0 < ev["compressed_bytes"] < ev["raw_bytes"]
    loop.wake(0)
    rs = led.total("spill", consumer="kv", tensor_class="kv-restore")
    assert rs["count"] == 1
    assert (rs["raw_bytes"], rs["compressed_bytes"]) == \
        (ev["raw_bytes"], ev["compressed_bytes"])    # same payload back
    # no other crossing was booked
    assert led.total("spill", consumer="kv")["count"] == 2


def test_device_totals_folding_is_overflow_safe():
    """The device accumulator is int32-windowed; the HOST ledger must keep
    counting in python ints — repeated absorbs well past 2^31 stay exact."""
    tot = device_totals(jnp)
    tot = device_record(tot, EV_READ, 2 ** 30, 2 ** 30 - 1)
    led = Ledger("dev")
    for _ in range(8):                        # 8 GiB raw > int32, > uint32
        led.absorb(tot)
    t = led.total(EV_READ)
    assert t["raw_bytes"] == 8 * 2 ** 30
    assert t["compressed_bytes"] == 8 * (2 ** 30 - 1)
    assert t["count"] == 8


def test_autotuner_per_tier_golden_decision_table():
    """PR-5 golden table, extended with the per-tier packing axis.  The
    spill-link model charges raw groups no strip, so at mid fit rates the
    tiers legitimately DIVERGE: hot stays off (no-slowdown margin) while
    the spill tier still packs."""
    from repro.bandwidth.autotune import (
        kv_expected_bytes_per_page,
        kv_spill_bytes_per_page,
    )

    tuner = AutoTuner()
    table = {
        # (pair_fit, quad_fit) -> (hot choice, spill choice)
        (0.0, 0.0): ("off", "off"),
        (0.15, 0.15): ("off", "quad"),        # <- the divergence point
        (0.95, 0.0): ("pair", "pair"),
        (0.9, 0.85): ("quad", "quad"),
    }
    for (p, q), (want_hot, want_spill) in table.items():
        fits = {"pair": p, "quad": q}
        hot = tuner.choose_kv_packing(fits, strip_bytes=1 / 8)
        spl = tuner.choose_kv_packing(fits, page=8, tier="spill")
        assert (hot.choice, spl.choice) == (want_hot, want_spill), (p, q)
        assert hot.target == "kv" and spl.target == "kv-spill"
    # the model-level reason: raw groups cross the link with no strip
    assert kv_spill_bytes_per_page(0.5, 4, page=8) < \
        kv_expected_bytes_per_page(0.5, 4, strip_bytes=1 / 8)
    # and a packed group's overhead is the REAL payload base row
    # (slot/page, one token row) — not a strip-sized term
    assert kv_spill_bytes_per_page(1.0, 4, 1.0, page=16) == \
        pytest.approx((1.0 + 1.0 / 16) / 4)
    # each tier gates on its OWN ledger key: poisoning the spill gate must
    # not touch the hot decision
    led = Ledger("kv")
    while tuner.gate_enabled("kv-spill"):
        led.record("spill", raw=100, compressed=150)
        tuner.observe(led, key="kv-spill", consumer="kv", event="spill")
    spl = tuner.choose_kv_packing({"pair": 0.9, "quad": 0.85},
                                  page=8, tier="spill")
    hot = tuner.choose_kv_packing({"pair": 0.9, "quad": 0.85},
                                  strip_bytes=1 / 8)
    assert spl.choice == "off" and hot.choice == "quad"
