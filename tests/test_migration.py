"""Incremental live migration (ISSUE 9 / DESIGN.md §12).

The zero-stall claim rests on three invariants, each pinned bitwise:

  1. convergence identity — after ANY prefix of budgeted migration quanta
     (gate flip or packing switch), every slot's physical layout equals
     the per-slot from-scratch rebuild under the applied gate
     (`slot_reference_state`): mixed packed/raw mid-states are exactly
     what a stop-the-world rebuild of that mixture would produce;
  2. bounded work — one quantum claims at most `budget` page-group
     columns, so a decode step never stalls on a flip;
  3. schedule independence — interleaved admits / steps / evicts / wakes
     (including waking a spilled sequence into a half-migrated pool)
     never break 1: pending is DERIVED from applied-vs-target, so no
     event ordering can drift it.

The fused megastep is additionally pinned equal to the unfused dispatch
sequence (state, §VI counters, traffic) and trace-stable across same-
shape steps.  The deterministic tests run in tier-1; the hypothesis
schedule sweep rides along when the dev dependency is present.
"""

import numpy as np
import pytest

from repro.bandwidth import AutoTuner, Ledger
from repro.compression.gate import COUNTER_MAX
from repro.kernels import ops as kops
from repro.kv import synthetic_kv_stream
from repro.serving import ServeLoop, SlotKVCache

PAGE, HKV, HD = 8, 1, 16

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False


def _mk(policy="static", packing="pair", batch=3, max_pages=8, **kw):
    return SlotKVCache(max_pages, PAGE, HKV, HD, batch=batch,
                       policy=policy, packing=packing, **kw)


def _kv(rng, s, t, compressible=True):
    return synthetic_kv_stream(rng, s, t, HKV, HD,
                               compressible=compressible)


def _assert_oracle(cache, ctx=""):
    """Every non-empty slot's physical layout == its per-slot rebuild
    under the PER-GROUP applied gate — the mid-migration identity."""
    for sl in range(cache.batch):
        if cache.tokens_b[sl] == 0:
            continue
        ref = cache.slot_reference_state(sl)
        phys = cache.slot_physical_state(sl)
        for key in ref:
            assert np.array_equal(np.asarray(ref[key]),
                                  np.asarray(phys[key])), (ctx, sl, key)


def _oracle_if_settled(cache, ctx=""):
    """Schedule sweeps interleave bare admits, whose appends stay dirty
    until the next step's repack — the oracle judges settled layouts."""
    if not cache._dirty_b.any():
        _assert_oracle(cache, ctx)


def _fill(cache, rng, steps, t=PAGE):
    ids = np.arange(cache.batch)
    for _ in range(steps):
        cache.append_active(ids, *_kv(rng, cache.batch, t))
    cache.repack()


# ------------------------------------------------------------- gate flips

def test_gate_flip_off_converges_one_column_per_quantum():
    rng = np.random.default_rng(0)
    c = _mk("static")
    _fill(c, rng, 8)                       # 64 tokens = 4 pair groups/slot
    assert not c.migration_pending().any()
    assert np.asarray(c.state["packed_mask"]).any(), "fixture must pack"
    c.set_gate_override(False)
    pend = c.migration_status()
    assert pend["migrating"] and pend["pending_columns"] == 4
    steps = 0
    while c.migration_pending().any():
        before = c.migration_status()["pending_columns"]
        assert c.migration_quantum(1) == 1          # bounded work
        c.repack(gate=c._gate_b)
        after = c.migration_status()["pending_columns"]
        assert after == before - 1
        steps += 1
        _assert_oracle(c, f"flip-off step {steps}")
        for sl in range(c.batch):                   # watermark is monotone
            assert c.migrated_upto(sl) >= 0
    assert steps == 4
    assert not np.asarray(c.state["packed_mask"]).any()
    for sl in range(c.batch):
        assert c.migrated_upto(sl) == c.slot_groups(sl)


def test_gate_reenable_promotes_raw_layout_to_packed():
    rng = np.random.default_rng(1)
    c = _mk("static")
    c.set_gate_override(False)
    _fill(c, rng, 8)                       # laid raw under the override
    assert not np.asarray(c.state["packed_mask"]).any()
    c.set_gate_override(True)
    assert c.migration_status()["migrating"]
    while c.migration_pending().any():
        c.migration_quantum(2)
        c.repack(gate=c._gate_b)
        _assert_oracle(c, "re-enable")
    assert np.asarray(c.state["packed_mask"]).any(), \
        "compressible stream must pack once the gate returns"


def test_zero_budget_never_migrates():
    rng = np.random.default_rng(2)
    c = _mk("static")
    _fill(c, rng, 4)
    c.set_gate_override(False)
    before = c.migration_status()["pending_groups"]
    assert before > 0
    assert c.migration_quantum(0) == 0
    c.repack(gate=c._gate_b)               # nothing dirty -> no-op
    assert c.migration_status()["pending_groups"] == before
    _assert_oracle(c, "zero-budget")


# -------------------------------------------------------- packing switches

@pytest.mark.parametrize("target", ["quad", "pair"])
def test_packing_switch_live_promotes_bit_identical(target):
    src = "pair" if target == "quad" else "quad"
    rng = np.random.default_rng(3)
    c = _mk("static", packing=src)
    _fill(c, rng, 8)
    pages_before = np.asarray(c.pages_view()).copy()
    tokens_before = c.tokens_b.copy()
    c.switch_packing(target)
    c.refresh_gate()
    assert c.packing == target
    # the logical pages survive the structural swap untouched
    assert np.array_equal(np.asarray(c.pages_view()), pages_before)
    assert np.array_equal(c.tokens_b, tokens_before)
    # raw new-geometry layout is immediately consistent...
    _assert_oracle(c, "post-switch raw")
    assert c.migration_status()["migrating"]
    # ...and the budgeted quanta promote it without ever breaking identity
    while c.migration_pending().any():
        c.migration_quantum(1)
        c.repack(gate=c._gate_b)
        _assert_oracle(c, f"promote->{target}")
    assert not c.migration_status()["migrating"]


def test_packing_switch_round_trip_preserves_logical_pages():
    rng = np.random.default_rng(4)
    c = _mk("static", packing="pair")
    _fill(c, rng, 6)
    pages0 = np.asarray(c.pages_view()).copy()
    for target in ("quad", "pair"):
        c.switch_packing(target)
        c.refresh_gate()
        while c.migration_pending().any():
            c.migration_quantum(2)
            c.repack(gate=c._gate_b)
    assert np.array_equal(np.asarray(c.pages_view()), pages0)
    _assert_oracle(c, "round-trip")


# ------------------------------------------------------------ fused megastep

def test_megastep_bit_identical_to_unfused_dispatches():
    rng = np.random.default_rng(5)
    fused, unfused = _mk("dynamic"), _mk("dynamic")
    ids = np.arange(3)
    for step in range(8):
        k, v = _kv(rng, 3, PAGE, compressible=step % 3 != 2)
        unfused.append_active(ids, k, v)
        unfused.repack(gate=unfused._gate_b)
        unfused.account_step()
        fused.megastep(ids, k, v)
    for sl in range(3):
        a = unfused.slot_physical_state(sl)
        b = fused.slot_physical_state(sl)
        for key in a:
            assert np.array_equal(np.asarray(a[key]),
                                  np.asarray(b[key])), (sl, key)
    assert np.array_equal(np.asarray(unfused.state["counter"]),
                          np.asarray(fused.state["counter"]))
    assert np.array_equal(np.asarray(unfused.state["traffic"]),
                          np.asarray(fused.state["traffic"]))


def test_megastep_carries_migration_quanta():
    rng = np.random.default_rng(6)
    c = _mk("static")
    for _ in range(6):
        c.megastep(np.arange(3), *_kv(rng, 3, PAGE))
    c.set_gate_override(False)
    assert c.migration_status()["migrating"]
    steps = 0
    while c.migration_pending().any():
        before = c.migration_status()["pending_columns"]
        c.megastep(np.arange(3), *_kv(rng, 3, 1), budget=1)
        after = c.migration_status()["pending_columns"]
        assert before - after <= 1, "budget bounds per-step migration work"
        steps += 1
        _assert_oracle(c, f"megastep quantum {steps}")
        assert steps < 100
    assert not np.asarray(c.state["packed_mask"]).any()


def test_megastep_trace_is_cached_across_same_shape_steps(monkeypatch):
    """After warm-up, same-bucket decode steps must reuse the compiled
    megastep — re-tracing would re-enter the window kernels' python."""
    rng = np.random.default_rng(7)
    c = _mk("static", batch=2)
    c.megastep(np.arange(2), *_kv(rng, 2, PAGE))    # prefill trace (t=8)
    for _ in range(2):                              # decode trace (t=1)
        c.megastep(np.arange(2), *_kv(rng, 2, 1))

    def boom(*a, **kw):
        raise AssertionError("megastep re-traced: layout_window re-entered")
    monkeypatch.setattr(kops, "layout_window", boom)
    for _ in range(4):                              # same pow2 buckets
        c.megastep(np.arange(2), *_kv(rng, 2, 1))
    monkeypatch.undo()
    _assert_oracle(c, "cached-trace")


# ------------------------------------------- serve loop: flips under load

def _loop(rng, *, slots=2, policy="static", **kw):
    loop = ServeLoop(slots=slots, max_pages=8, page=PAGE, n_kv=HKV,
                     head_dim=HD, policy=policy, **kw)
    return loop


def test_wake_into_half_migrated_cache_regression():
    """A sequence evicted under gate=on and woken after the pool's target
    flipped off resurrects under its RECORDED gate, joins the derived
    pending set, and converges with everyone else — bit-identically."""
    rng = np.random.default_rng(8)
    loop = _loop(rng)
    k0, v0 = _kv(rng, 1, 4 * PAGE)
    k1, v1 = _kv(rng, 1, 4 * PAGE)
    loop.admit(0, k0[0], v0[0])
    loop.admit(1, k1[0], v1[0])
    for _ in range(2):
        loop.step({s: tuple(x[0] for x in _kv(rng, 1, 1))
                   for s in (0, 1)})
    loop.evict(0)                          # settled under gate=True
    loop.cache.set_gate_override(False)    # target moves while 0 is cold
    loop.step({1: tuple(x[0] for x in _kv(rng, 1, 1))})  # partial migration
    assert loop.cache.migration_status()["migrating"]
    _assert_oracle(loop.cache, "half-migrated before wake")
    loop.wake(0)
    slot0 = loop.seqs[0].slot
    # the woken slot's layout came back under gate=True -> it is pending
    assert loop.cache.migration_pending()[slot0].any()
    _assert_oracle(loop.cache, "just woken")
    steps = 0
    while loop.cache.migration_pending().any():
        loop.step({s: tuple(x[0] for x in _kv(rng, 1, 1))
                   for s in (0, 1)})
        steps += 1
        _assert_oracle(loop.cache, f"post-wake step {steps}")
        assert steps < 100
    assert not np.asarray(loop.cache.state["packed_mask"]).any()


def test_scripted_interleaving_admit_step_evict_wake_flip():
    """Deterministic tier-1 cut of the schedule sweep: every migration-
    relevant event class interleaved, oracle checked after each."""
    rng = np.random.default_rng(9)
    loop = _loop(rng, slots=2)
    nxt = 0

    def admit():
        nonlocal nxt
        k, v = _kv(rng, 1, 2 * PAGE)
        loop.admit(nxt, k[0], v[0])
        nxt += 1

    def prefill():
        nonlocal nxt
        k, v = _kv(rng, 1, 3 * PAGE + 3)
        loop.prefill(nxt, k[0], v[0])
        nxt += 1

    def step():
        act = loop.active_seqs()
        if act:
            loop.step({s: tuple(x[0] for x in _kv(rng, 1, 1))
                       for s in act})

    script = [admit, step, prefill, step,
              lambda: loop.cache.set_gate_override(False),
              step, prefill, step,               # admit evicts the coldest
              step, lambda: loop.wake(loop.spilled_seqs()[0]),
              step, lambda: loop.cache.set_gate_override(True),
              prefill,                           # prefill mid-migration
              step, step, lambda: loop.evict(loop.active_seqs()[0]),
              step, lambda: loop.cache.set_gate_override(None),
              step, step, step]
    for i, op in enumerate(script):
        op()
        _oracle_if_settled(loop.cache, f"script op {i}")
    # drain whatever is still pending and land settled
    loop.cache.drain_migration()
    _assert_oracle(loop.cache, "script drained")
    assert not loop.cache.migration_status()["migrating"]


def test_prefill_admit_into_half_migrated_pool():
    """A prompt bulk-packed into a pool whose residents are mid-flip lays
    out under the CURRENT target gate (nothing pending on the new slot),
    advances applied state only through the recorded per-group gates, and
    the convergence identity holds at every point."""
    rng = np.random.default_rng(13)
    loop = _loop(rng, slots=3)
    for s in range(2):
        k, v = _kv(rng, 1, 4 * PAGE)
        loop.admit(s, k[0], v[0])
    loop.step({s: tuple(x[0] for x in _kv(rng, 1, 1)) for s in (0, 1)})
    assert np.asarray(loop.cache.state["packed_mask"]).any()
    loop.cache.set_gate_override(False)    # flip while residents are live
    loop.step({0: tuple(x[0] for x in _kv(rng, 1, 1))})
    assert loop.cache.migration_status()["migrating"]
    _assert_oracle(loop.cache, "half-migrated before prefill")
    kp, vp = _kv(rng, 1, 3 * PAGE + 3)
    loop.prefill(5, kp[0], vp[0])
    _assert_oracle(loop.cache, "prefill mid-migration")
    slot5 = loop.seqs[5].slot
    assert not loop.cache.migration_pending()[slot5].any(), \
        "a bulk-packed prompt is born settled under the target gate"
    steps = 0
    while loop.cache.migration_pending().any():
        loop.step({s: tuple(x[0] for x in _kv(rng, 1, 1))
                   for s in loop.active_seqs()})
        steps += 1
        _assert_oracle(loop.cache, f"post-prefill drain {steps}")
        assert steps < 100
    assert not np.asarray(loop.cache.state["packed_mask"]).any()


def test_migrate_to_packing_mid_serve_converges():
    rng = np.random.default_rng(10)
    loop = _loop(rng)
    for s in range(2):
        k, v = _kv(rng, 1, 4 * PAGE)
        loop.admit(s, k[0], v[0])
    status = loop.migrate_to(packing="quad")
    assert status["migrating"] and loop.cache.packing == "quad"
    steps = 0
    while loop.cache.migration_pending().any():
        loop.step({s: tuple(x[0] for x in _kv(rng, 1, 1))
                   for s in (0, 1)})
        steps += 1
        _assert_oracle(loop.cache, f"quad promote {steps}")
        assert steps < 100
    assert loop.summary()["migration"]["migrating"] is False


# ------------------------------------------------- §VI live gate decisions

def test_suppressed_packing_reenables_into_tuner_pick():
    """auto with the hot gate forced off records the tuner's real pick;
    a re-enabling observation window migrates the LIVE cache to it."""
    rng = np.random.default_rng(11)
    k, v = synthetic_kv_stream(rng, 1, 8 * PAGE, HKV, HD, scale=2e-4)
    tuner = AutoTuner()
    tuner._counters["kv-hot"] = 0          # §VI gate: measured harm
    loop, ch = ServeLoop.auto(tuner, k, v, slots=2, max_pages=8,
                              page=PAGE, n_kv=HKV, head_dim=HD)
    assert ch["hot"].choice == "off"
    assert ch["hot"].preferred in ("pair", "quad")
    assert loop.suppressed_packing == ch["hot"].preferred
    assert loop.cache.policy == "off"
    loop.admit(0, k[0, :4 * PAGE], v[0, :4 * PAGE])
    loop.step({0: tuple(x[0] for x in _kv(rng, 1, 1))})
    # the next window clears the gate (raw traffic is never judged
    # harmful: saving is 0, not negative, so the forced counter holds)
    tuner._counters["kv-hot"] = COUNTER_MAX
    loop.observe_tiers()
    assert loop.cache.policy == "auto"
    assert loop.cache.packing == ch["hot"].preferred
    assert loop.suppressed_packing is None
    assert loop.cache.migration_status()["migrating"]
    for i in range(20):
        loop.step({0: tuple(x[0] for x in _kv(rng, 1, 1))})
        _assert_oracle(loop.cache, f"re-enable step {i}")
        if not loop.cache.migration_pending().any():
            break
    assert not loop.cache.migration_pending().any()


def test_gate_disable_records_suppressed_packing():
    """The symmetric transition: a window that turns the gate OFF
    remembers the running packing and degrades the layout to raw."""
    rng = np.random.default_rng(12)
    k, v = synthetic_kv_stream(rng, 1, 8 * PAGE, HKV, HD, scale=2e-4)
    tuner = AutoTuner()
    loop, ch = ServeLoop.auto(tuner, k, v, slots=2, max_pages=8,
                              page=PAGE, n_kv=HKV, head_dim=HD,
                              ledger=Ledger("t"))
    assert loop.cache.policy != "off"
    running = loop.cache.packing
    loop.admit(0, k[0, :4 * PAGE], v[0, :4 * PAGE])
    loop.step({0: tuple(x[0] for x in _kv(rng, 1, 1))})
    tuner._counters["kv-hot"] = 0          # window measured harm
    loop.observe_tiers()
    assert loop.cache.policy == "off"
    assert loop.suppressed_packing == running
    assert loop.summary()["hot_packing"] == "off"


# ------------------------------------------------- hypothesis schedule sweep

if HAVE_HYPOTHESIS:

    @settings(max_examples=12, deadline=None)
    @given(ops=st.lists(st.integers(0, 6), min_size=4, max_size=14),
           seed=st.integers(0, 2**16))
    def test_schedule_sweep_migration_oracle(ops, seed):
        """Random admit/prefill/step/evict/wake/flip schedules with
        per-step migration quanta: the applied-gate oracle holds after
        EVERY op (prefill-admits land settled, so they are oracle-checked
        immediately, mid-migration included)."""
        rng = np.random.default_rng(seed)
        loop = _loop(rng, slots=2)
        nxt = 0
        overrides = [True, False, None]
        for i, op in enumerate(ops):
            if op == 0:
                k, v = _kv(rng, 1, 2 * PAGE)
                loop.admit(nxt, k[0], v[0])
                nxt += 1
            elif op == 6:
                k, v = _kv(rng, 1, int(rng.integers(1, 4 * PAGE)))
                loop.prefill(nxt, k[0], v[0])
                nxt += 1
            elif op in (1, 2):
                act = loop.active_seqs()
                if act:
                    loop.step({s: tuple(x[0] for x in _kv(rng, 1, 1))
                               for s in act})
            elif op == 3 and len(loop.active_seqs()) > 1:
                loop.evict(loop.active_seqs()[0])
            elif op == 4 and loop.spilled_seqs():
                loop.wake(loop.spilled_seqs()[0])
            elif op == 5:
                loop.cache.set_gate_override(overrides[i % 3])
            _oracle_if_settled(loop.cache, f"sweep op {i}:{op}")
        loop.cache.drain_migration()
        _assert_oracle(loop.cache, "sweep drained")
        assert not loop.cache.migration_status()["migrating"]
