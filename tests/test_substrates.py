"""Checkpoint codec/manager, data pipeline, optimizer, grad compression,
straggler detector, elastic resharding."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.checkpoint import (
    cram_compress_bytes,
    cram_decompress_bytes,
    load_checkpoint,
    save_checkpoint,
)
from repro.checkpoint.ckpt import CheckpointManager, latest_step
from repro.data import DataConfig, SyntheticLM, make_batch_iterator
from repro.optim.adamw import adamw_init, make_train_step
from repro.optim import grad_compress as gc
from repro.runtime.straggler import StragglerDetector


@given(st.binary(min_size=0, max_size=2048),
       st.sampled_from([False, True]))
def test_codec_roundtrip(raw, use_zstd):
    blob = cram_compress_bytes(raw, use_zstd=use_zstd)
    assert cram_decompress_bytes(blob) == raw


def test_codec_compresses_compressible():
    zeros = bytes(1 << 14)
    blob = cram_compress_bytes(zeros)
    assert len(blob) < len(zeros) / 20


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": np.arange(1000, dtype=np.float32).reshape(10, 100),
        "nested": {"b": np.zeros((64, 64), np.float16),
                   "c": np.int32(7)},
    }
    save_checkpoint(tmp_path, 3, tree, codec="cram")
    like = jax.tree.map(lambda x: np.zeros_like(x), tree)
    out, manifest = load_checkpoint(tmp_path, None, like)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree), strict=True):
        assert np.array_equal(a, b)
    assert manifest["step"] == 3
    assert latest_step(tmp_path) == 3


def test_checkpoint_manager_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, codec="raw")
    tree = {"x": np.ones(8, np.float32)}
    for s in (1, 2, 3, 4):
        mgr.save_async(s, tree)
        mgr.wait()
    steps = sorted(int(p.name.split("_")[1])
                   for p in tmp_path.glob("step_*"))
    assert steps == [3, 4]


def test_data_pipeline_deterministic_and_resumable():
    cfg = DataConfig(vocab=512, seq_len=32, global_batch=4, seed=9)
    gen = SyntheticLM(cfg)
    b1 = gen.batch(10)
    b2 = gen.batch(10)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    assert np.array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])
    it = make_batch_iterator(cfg, start_step=10)
    step, batch = next(it)
    it.close()
    assert step == 10
    assert np.array_equal(batch["tokens"], b1["tokens"])
    # host sharding slices the global batch
    half = gen.batch(10, host_slice=slice(0, 2))
    assert np.array_equal(half["tokens"], b1["tokens"][:2])


def test_adamw_learns_and_microbatch_equivalence():
    from repro.launch.train import PRESETS
    from repro.models import build

    cfg = PRESETS["lm2m"]
    model = build(cfg)
    params, _ = model.init(jax.random.key(0))
    batch = {
        "tokens": jax.random.randint(jax.random.key(1), (4, 32), 0,
                                     cfg.vocab),
        "labels": jax.random.randint(jax.random.key(2), (4, 32), 0,
                                     cfg.vocab),
    }
    step1 = jax.jit(make_train_step(model, lr_peak=1e-2, microbatches=1))
    step4 = jax.jit(make_train_step(model, lr_peak=1e-2, microbatches=4))
    s1 = adamw_init(params)
    losses = []
    for _ in range(5):
        s1, m = step1(s1, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]  # learns
    _, m1 = step1(adamw_init(params), batch)
    _, m4 = step4(adamw_init(params), batch)
    # same data, same params: grad-accumulated loss must match
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-3
    np.testing.assert_allclose(float(m1["gnorm"]), float(m4["gnorm"]),
                               rtol=1e-2)


def test_grad_compression_error_feedback():
    grads = {"w": jnp.asarray(np.random.default_rng(0)
                              .standard_normal((64, 64)), jnp.float32)}
    err = jax.tree.map(jnp.zeros_like, grads)
    dq, err, rel = gc.compress_tree(grads, err)
    assert float(rel) < 0.02  # int8 per-tensor is accurate on gaussians
    # error feedback: the residual is exactly the quantization error
    np.testing.assert_allclose(
        np.asarray(dq["w"] + err["w"]), np.asarray(grads["w"]), atol=1e-6)
    # over repeated steps with error feedback the accumulated bias vanishes
    total_dq = jnp.zeros_like(grads["w"])
    e = jax.tree.map(jnp.zeros_like, grads)
    for _ in range(16):
        dq, e, _ = gc.compress_tree(grads, e)
        total_dq = total_dq + dq["w"]
    np.testing.assert_allclose(np.asarray(total_dq / 16),
                               np.asarray(grads["w"]), atol=2e-3)


def test_grad_compression_gate():
    c = jnp.int32(gc.ENABLE + 10)
    # low error keeps it enabled, high error disables after enough steps
    for _ in range(4):
        c = gc.gate_update(c, jnp.float32(0.01))
    assert bool(gc.gate_enabled(c))
    for _ in range(20):
        c = gc.gate_update(c, jnp.float32(0.5))
    assert not bool(gc.gate_enabled(c))


def test_straggler_detector_flags_slow_host():
    det = StragglerDetector(n_hosts=4, min_samples=4)
    flagged = set()
    for step in range(30):
        d = [0.1, 0.1, 0.1, 0.1]
        if step >= 10:
            d[2] = 0.5  # host 2 degrades
        for h in det.record(step, d):
            flagged.add(h)
    assert flagged == {2}
    assert 2 in det.persistent_stragglers(window=20, threshold=5)


def test_elastic_shrink_mesh_and_reshard():
    from repro.runtime.elastic import reshard_tree, shrink_mesh

    mesh = shrink_mesh(set(), model_axis=1)
    assert mesh.shape["data"] == len(jax.devices())
    tree = {"w": jnp.ones((8, 4))}
    axes = {"w": ("batch", None)}
    out = reshard_tree(tree, axes, mesh)
    assert np.array_equal(np.asarray(out["w"]), np.ones((8, 4)))
