"""Fast-simulator invariants + cross-checks against scheme semantics."""

import numpy as np
import pytest

from repro.core.memsim import SCHEMES, SimConfig, simulate
from repro.core.traces import build_workload

CFG = SimConfig()


@pytest.fixture(scope="module")
def wl():
    return build_workload("libq", n_events=40_000, seed=1)


def _run(wl, scheme):
    _, addrs, wr, pa, pc, pq, f = wl
    return simulate(scheme, addrs, wr, pa, pc, pq, CFG)


def test_baseline_has_no_compression_traffic(wl):
    r = _run(wl, "baseline")
    s = r.stats
    assert s["wb_clean"] == 0 and s["il_writes"] == 0
    assert s["meta_reads"] == 0 and s["pf_installed"] == 0
    assert s["read_probes"] == s["demand_reads"]


def test_ideal_dominates_all_schemes(wl):
    accesses = {sch: _run(wl, sch).accesses
                for sch in ("baseline", "ideal", "explicit", "cram")}
    assert accesses["ideal"] <= accesses["baseline"]
    assert accesses["ideal"] <= accesses["cram"]
    assert accesses["ideal"] <= accesses["explicit"]


def test_cram_beats_explicit_on_metadata(wl):
    cram = _run(wl, "cram")
    expl = _run(wl, "explicit")
    assert cram.stats["meta_reads"] == 0
    assert expl.stats["meta_reads"] > 0
    # the two compression schemes do the same data-side work
    assert cram.stats["wb_clean"] == expl.stats["wb_clean"]
    assert cram.stats["il_writes"] == expl.stats["il_writes"]


def test_llp_high_accuracy_on_page_coherent_data(wl):
    r = _run(wl, "cram")
    assert r.llp_accuracy > 0.95


def test_determinism(wl):
    a = _run(wl, "dynamic").stats
    b = _run(wl, "dynamic").stats
    assert a == b


def test_dynamic_bounded_by_static_cost():
    """On hostile (incompressible, no-reuse) traffic the dynamic scheme
    must stay close to baseline while static pays the compression tax."""
    wl = build_workload("pr_twi", n_events=60_000, seed=3)
    base = _run(wl, "baseline").accesses
    cram = _run(wl, "cram").accesses
    dyn = _run(wl, "dynamic").accesses
    assert cram >= base  # static compression hurts here
    assert dyn <= cram   # the gate can only help
    # (full mitigation needs longer traces for the counter to settle; the
    #  300k-event benchmark suite shows dyn ~= base on GAP workloads)


def test_prefetch_hits_only_when_compression_on(wl):
    assert _run(wl, "cram").stats["pf_used"] > 0
    assert _run(wl, "baseline").stats["pf_used"] == 0


def test_nextline_costs_bandwidth(wl):
    nl = _run(wl, "nextline")
    assert nl.stats["pf_extra_access"] == nl.stats["llc_misses"]
