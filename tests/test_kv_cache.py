"""Batched incremental CRAM-KV cache: bit-exactness vs full rebuild,
dynamic-gate re-enable, mispredict bandwidth charges, the no-pack
guarantee of `policy="off"` (ISSUE 3 regression suite), and the
registry-provided 4:1 quad packing layout (ISSUE 4)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.compression.gate import ENABLE_THRESHOLD
from repro.kernels import ops
from repro.kv import CRAMKVCache, synthetic_kv_stream

PAGE, HKV, HD = 8, 1, 16


def _stream(rng, batch, n_tokens, compressible=True, scale=2e-3):
    return synthetic_kv_stream(rng, batch, n_tokens, HKV, HD,
                               compressible=compressible, scale=scale)


def _assert_state_equals_rebuild(cache):
    ref, act = cache.reference_rebuild(), cache.active_state()
    for key in ("slots", "slots_overflow", "strips", "packed_mask",
                "markers"):
        assert jnp.array_equal(act[key], ref[key]), key


# ---------------------------------------------------------------- bit parity
@pytest.mark.parametrize("policy", ["static", "dynamic", "off"])
@pytest.mark.parametrize("pattern", [
    (2 * PAGE, 3, 1, 1, PAGE),        # bulk, partial pages, single tokens
    (1,) * 9,                         # token-by-token decode
    (4 * PAGE + 1, 1, 1),             # prefill then decode, odd page count
], ids=["mixed", "decode", "prefill+decode"])
def test_incremental_matches_full_rebuild(policy, pattern):
    rng = np.random.default_rng(42)
    cache = CRAMKVCache(max_pages=12, page=PAGE, n_kv=HKV, head_dim=HD,
                        batch=2, policy=policy)
    for i, t in enumerate(pattern):
        # alternate compressibility so both layouts appear
        cache.append(*_stream(rng, 2, t, compressible=(i % 2 == 0)))
        cache.repack()
        _assert_state_equals_rebuild(cache)


def test_decode_step_packs_only_new_pairs():
    rng = np.random.default_rng(0)
    cache = CRAMKVCache(max_pages=12, page=PAGE, n_kv=HKV, head_dim=HD,
                        batch=3, policy="static")
    cache.append(*_stream(rng, 3, 4 * PAGE))     # grow to 2 complete pairs
    cache.repack()
    assert cache.n_active_pairs == 2
    for _ in range(4):                           # decode: 1 token per step
        before = cache.stats.pack_pairs_processed
        cache.append(*_stream(rng, 3, 1))
        cache.repack()
        # O(new pairs): exactly one dirty pair per sequence, never the
        # full ladder of active pairs
        assert cache.stats.pack_pairs_processed - before == 3
    _assert_state_equals_rebuild(cache)


def test_attend_matches_oracle_batched():
    rng = np.random.default_rng(7)
    cache = CRAMKVCache(max_pages=8, page=PAGE, n_kv=HKV, head_dim=HD,
                        batch=3, policy="static")
    # per-sequence data differs (only seq 0 compressible) + partial page
    k_c, v_c = _stream(rng, 1, 2 * PAGE + 3, compressible=True)
    k_r, v_r = _stream(rng, 2, 2 * PAGE + 3, compressible=False)
    cache.append(np.concatenate([k_c, k_r]), np.concatenate([v_c, v_r]))
    cache.repack()
    pm = np.asarray(cache.state["packed_mask"])
    assert pm[0].any() and not pm[1:].any()
    q = jnp.asarray(rng.standard_normal((3, 2, HD)), jnp.float32)
    out = cache.attend(q)
    ref = cache.attend_ref(q)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-2, rtol=2e-2)


# ------------------------------------------------------------- dynamic gate
def test_dynamic_policy_reenables_after_compressible_traffic():
    """§VI regression: fitness is sampled even while disabled, so the
    counter can climb back over the MSB threshold (the old code zeroed the
    packed mask first and fed that into the update — a one-way ratchet)."""
    rng = np.random.default_rng(1)
    cache = CRAMKVCache(max_pages=28, page=PAGE, n_kv=HKV, head_dim=HD,
                        batch=1, policy="dynamic",
                        counter_init=ENABLE_THRESHOLD + 2)
    for _ in range(3):                       # incompressible complete pairs
        cache.append(*_stream(rng, 1, 2 * PAGE, compressible=False))
        cache.repack()
    assert not cache.enabled().any()         # gate dropped
    assert cache.stats.packed_pairs == 0     # nothing packed while disabled
    steps = 0
    while not cache.enabled().all():         # compressible traffic returns
        cache.append(*_stream(rng, 1, 2 * PAGE, compressible=True))
        cache.repack()
        steps += 1
        assert steps <= 10, "dynamic gate never re-enabled"
    before = cache.stats.packed_pairs
    cache.append(*_stream(rng, 1, 2 * PAGE, compressible=True))
    cache.repack()
    assert cache.stats.packed_pairs > before          # packing resumed
    _assert_state_equals_rebuild(cache)               # parity across flips


def test_gate_flip_does_not_recount_history():
    """Each pair feeds the §VI counter exactly once, when it completes: a
    gate flip re-lays the prefix out but must not re-apply historical
    fitness (that could slam a saturated counter straight back over the
    threshold and re-enable packing on incompressible traffic)."""
    rng = np.random.default_rng(5)
    cache = CRAMKVCache(max_pages=16, page=PAGE, n_kv=HKV, head_dim=HD,
                        batch=1, policy="dynamic",
                        counter_init=ENABLE_THRESHOLD + 2)
    for _ in range(3):                        # -3 -> threshold-1: disable
        cache.append(*_stream(rng, 1, 2 * PAGE, compressible=False))
        cache.repack()
    assert int(cache.state["counter"][0]) == ENABLE_THRESHOLD - 1
    assert not cache.enabled().any()
    # flip marked the whole prefix dirty; the next repack re-lays out all
    # 4 pairs but must count only the one new pair: exactly +1
    cache.append(*_stream(rng, 1, 2 * PAGE, compressible=True))
    cache.repack()
    assert int(cache.state["counter"][0]) == ENABLE_THRESHOLD
    assert cache.enabled().all()
    _assert_state_equals_rebuild(cache)


def test_dynamic_gate_disables_on_incompressible():
    rng = np.random.default_rng(2)
    cache = CRAMKVCache(max_pages=16, page=PAGE, n_kv=HKV, head_dim=HD,
                        batch=2, policy="dynamic",
                        counter_init=ENABLE_THRESHOLD + 2)
    for _ in range(4):
        cache.append(*_stream(rng, 2, 2 * PAGE, compressible=False))
        cache.repack()
    assert not cache.enabled().any()
    assert np.asarray(cache.state["packed_mask"]).sum() == 0


# ------------------------------------------------------- bandwidth accounting
def test_hbm_bytes_mispredict_pinned():
    """Exact byte counts for every (packed, predicted) x live combination."""
    n, page, hkv, d2 = 4, 4, 1, 8
    slot = page * hkv * d2 * 2                # 64
    strip = hkv * (d2 + 2) * 2                # 20
    cache = {
        "slots": jnp.zeros((n, page, hkv, d2), jnp.int16),
        "packed_mask": jnp.asarray([True, True, False, False]),
    }
    # pairs: packed/hit, packed/miss, raw(2 live)/hit, raw(1 live)/miss
    predictor = jnp.asarray([True, False, False, True])
    valid = jnp.asarray([page, page, page, page, page, page, page, 0],
                        jnp.int32)
    bw = ops.hbm_bytes_moved(cache, valid, predictor=predictor)
    assert bw["raw_bytes"] == 7 * slot
    expected = ((slot + strip)                # packed, predicted packed
                + (slot + strip) + slot       # packed, mispredicted: re-probe
                + 2 * (slot + strip)          # raw, predicted raw
                + 1 * (slot + strip) + slot)  # raw 1 live, mispredicted
    assert bw["cram_bytes"] == expected
    # perfect predictor (None) drops both re-probes
    bw0 = ops.hbm_bytes_moved(cache, valid)
    assert bw0["cram_bytes"] == expected - 2 * slot
    # a fully dead pair costs nothing even when mispredicted
    valid_dead = jnp.asarray([page] * 4 + [0] * 4, jnp.int32)
    bw_dead = ops.hbm_bytes_moved(cache, valid_dead, predictor=predictor)
    assert bw_dead["cram_bytes"] == (slot + strip) + (slot + strip) + slot


def test_cache_charges_reprobe_on_layout_change():
    """The pair-indexed predictor lags one step: the access after a pair
    flips raw->packed pays one extra slot DMA, then the predictor learns."""
    rng = np.random.default_rng(3)
    cache = CRAMKVCache(max_pages=4, page=PAGE, n_kv=HKV, head_dim=HD,
                        batch=1, policy="static")
    slot = PAGE * HKV * (2 * HD) * 2
    strip = HKV * (2 * HD + 2) * 2
    k, v = _stream(rng, 1, 2 * PAGE)
    cache.append(k[:, :PAGE], v[:, :PAGE])   # half pair: raw (zeros tail)
    bw = cache.account_step()
    assert bw["cram_bytes"] == slot + strip  # raw, predictor agrees (raw)
    assert cache.stats.predictor_misses == 0
    cache.append(k[:, PAGE:], v[:, PAGE:])   # completes the pair -> packs
    bw = cache.account_step()
    assert bool(np.asarray(cache.state["packed_mask"])[0, 0])
    assert bw["cram_bytes"] == (slot + strip) + slot   # LLP-miss re-probe
    assert cache.stats.predictor_misses == 1
    bw = cache.account_step()                # predictor has learned
    assert bw["cram_bytes"] == slot + strip
    assert cache.stats.predictor_misses == 1


# ------------------------------------------------------------- quad layout
def _quad_cache(batch=2, policy="static", max_pages=16, **kw):
    return CRAMKVCache(max_pages=max_pages, page=PAGE, n_kv=HKV, head_dim=HD,
                       batch=batch, policy=policy, packing="quad", **kw)


@pytest.mark.parametrize("policy", ["static", "dynamic", "off"])
def test_quad_incremental_matches_full_rebuild(policy):
    """The int4-delta/KV_QUAD registry policy keeps the same incremental ==
    from-scratch-rebuild contract as the pair layout."""
    rng = np.random.default_rng(42)
    cache = _quad_cache(policy=policy)
    pattern = (4 * PAGE, 3, 1, 4 * PAGE - 4, PAGE)
    for i, t in enumerate(pattern):
        # alternate compressibility so both layouts appear
        cache.append(*_stream(rng, 2, t, compressible=(i % 2 == 0),
                              scale=2e-4))
        cache.repack()
        _assert_state_equals_rebuild(cache)


def test_quad_packs_and_attends_end_to_end():
    """Compressible traffic quad-packs (4 pages -> ONE slot) and the fused
    decode kernel walks the packed layout correctly."""
    rng = np.random.default_rng(9)
    cache = _quad_cache(batch=2)
    cache.append(*_stream(rng, 2, 8 * PAGE, scale=2e-4))
    cache.repack()
    pm = np.asarray(cache.state["packed_mask"])
    assert pm[:, :2].all(), "compressible quads must pack 4:1"
    q = jnp.asarray(rng.standard_normal((2, 2, HD)), jnp.float32)
    out = cache.attend(q)
    ref = cache.attend_ref(q)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-2, rtol=2e-2)
    # 4:1 bandwidth: a packed quad group moves one slot + strip instead of
    # four raw slots
    slot = PAGE * HKV * (2 * HD) * 2
    strip = HKV * (2 * HD + 2) * 2
    bw = cache.account_step()
    assert bw["cram_bytes"] == 2 * 2 * (slot + strip)   # B=2 x 2 groups
    assert bw["raw_bytes"] == 2 * 8 * slot              # B=2 x 8 live pages
    # cumulative saving includes the first step's predictor-miss re-probes
    # (the LLP lag), still well above the 2:1 pair ceiling of ~0.5
    assert cache.saving() > 0.55


def test_quad_raw_layout_attends_correctly():
    """Incompressible quads stay raw (4 slots/group) and still decode."""
    rng = np.random.default_rng(10)
    cache = _quad_cache(batch=1)
    cache.append(*_stream(rng, 1, 5 * PAGE + 3, compressible=False))
    cache.repack()
    assert not np.asarray(cache.state["packed_mask"]).any()
    q = jnp.asarray(rng.standard_normal((1, 2, HD)), jnp.float32)
    np.testing.assert_allclose(np.asarray(cache.attend(q)),
                               np.asarray(cache.attend_ref(q)),
                               atol=2e-2, rtol=2e-2)
    _assert_state_equals_rebuild(cache)


def test_quad_dynamic_gate_disables_on_incompressible():
    rng = np.random.default_rng(11)
    cache = _quad_cache(batch=1, policy="dynamic", max_pages=32,
                        counter_init=ENABLE_THRESHOLD + 2)
    for _ in range(4):
        cache.append(*_stream(rng, 1, 4 * PAGE, compressible=False))
        cache.repack()
    assert not cache.enabled().any()
    assert np.asarray(cache.state["packed_mask"]).sum() == 0
    _assert_state_equals_rebuild(cache)


def test_quad_hbm_bytes_pinned():
    """Exact quad byte counts per (packed, predicted, live) combination."""
    n, page, hkv, d2 = 3, 4, 1, 8
    slot = page * hkv * d2 * 2                # 64
    strip = hkv * (d2 + 2) * 2                # 20
    cache = {
        "slots": jnp.zeros((n, page, hkv, d2), jnp.int16),
        "packed_mask": jnp.asarray([True, True, False]),
    }
    predictor = jnp.asarray([True, False, False])
    # group 0: packed, 4 live; group 1: packed, mispredicted, 4 live;
    # group 2: raw, 3 live pages
    valid = jnp.asarray([page] * 4 + [page] * 4 + [page] * 3 + [0],
                        jnp.int32)
    bw = ops.hbm_bytes_moved(cache, valid, predictor=predictor, lanes=4)
    assert bw["raw_bytes"] == 11 * slot
    assert bw["cram_bytes"] == ((slot + strip)
                                + (slot + strip) + slot
                                + 3 * (slot + strip))


# ----------------------------------------------------------------- off path
def test_off_policy_never_launches_pack_kernel(monkeypatch):
    calls = []
    orig = ops.pack_window

    def counting(*a, **kw):
        calls.append(1)
        return orig(*a, **kw)

    monkeypatch.setattr(ops, "pack_window", counting)
    rng = np.random.default_rng(4)
    cache = CRAMKVCache(max_pages=8, page=PAGE, n_kv=HKV, head_dim=HD,
                        batch=2, policy="off")
    cache.append(*_stream(rng, 2, 3 * PAGE))
    q = jnp.asarray(rng.standard_normal((2, 2, HD)), jnp.float32)
    out = cache.attend(q)
    assert not calls, "policy='off' must not launch the pack kernel"
    assert np.asarray(cache.state["packed_mask"]).sum() == 0
    assert cache.stats.pack_attempts == 0
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(cache.attend_ref(q)),
                               atol=2e-2, rtol=2e-2)
    # sanity: the same traffic through "static" does go through the kernel
    cache2 = CRAMKVCache(max_pages=8, page=PAGE, n_kv=HKV, head_dim=HD,
                         batch=2, policy="static")
    cache2.append(*_stream(rng, 2, 3 * PAGE))
    cache2.repack()
    assert calls
