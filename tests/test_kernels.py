"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode on CPU; the kernels target TPU BlockSpecs)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.bdi_pack import pack_pair, unpack_pair


def _pages(rng, n, page, hkv, d2, compressible=True, scale=2e-3):
    base = 2.0 + rng.standard_normal((1, 1, hkv, d2)) * 0.25
    if compressible:
        x = base * (1 + rng.standard_normal((n, page, hkv, d2)) * scale)
    else:
        x = rng.standard_normal((n, page, hkv, d2))
    return jnp.asarray(x.astype(jnp.bfloat16)).view(jnp.int16)


@pytest.mark.parametrize("page,hkv,d", [(8, 1, 32), (16, 2, 64),
                                        (32, 4, 128)])
def test_pack_unpack_shapes(page, hkv, d):
    rng = np.random.default_rng(page * 131 + hkv)
    a, b = _pages(rng, 2, page, hkv, 2 * d)
    packed, base, ok = pack_pair(a, b)
    ok_r, packed_r, base_r = ref.pack_pair_ref(a, b)
    assert bool(ok) == bool(ok_r)
    assert jnp.array_equal(packed, packed_r)
    assert jnp.array_equal(base, base_r)
    if bool(ok):
        ra, rb = unpack_pair(packed, base)
        assert jnp.array_equal(ra, a) and jnp.array_equal(rb, b)


@settings(max_examples=10)
@given(st.integers(0, 2**31 - 1), st.floats(1e-4, 0.5))
def test_pack_fit_decision_matches_ref(seed, scale):
    rng = np.random.default_rng(seed)
    a, b = _pages(rng, 2, 8, 1, 64, compressible=True, scale=scale)
    _, _, ok = pack_pair(a, b)
    ok_r, _, _ = ref.pack_pair_ref(a, b)
    assert bool(ok) == bool(ok_r)


@pytest.mark.parametrize("hq,hkv", [(4, 1), (4, 2), (8, 4)])
@pytest.mark.parametrize("mix", ["all_packed", "all_raw", "mixed"])
def test_fused_attention_vs_oracle(hq, hkv, mix):
    rng = np.random.default_rng(hash((hq, hkv, mix)) & 0xFFFF)
    page, d = 16, 32
    d2 = 2 * d
    n_pages = 6
    pages = []
    for i in range(n_pages):
        comp = (mix == "all_packed") or (mix == "mixed" and i < 4)
        pages.append(np.asarray(
            _pages(rng, 1, page, hkv, d2, compressible=comp)[0]))
    # pairs must be jointly compressible: regenerate pairs coherently
    pages = jnp.asarray(np.stack(pages))
    cache = ops.build_cram_cache(pages)
    valid = jnp.asarray([page] * (n_pages - 1) + [page // 2], jnp.int32)
    q = jnp.asarray(rng.standard_normal((3, hq, d)), jnp.float32)
    out_k = ops.decode_attention(q, cache, valid)
    out_r = ops.decode_attention_ref(q, cache, valid)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=2e-2, rtol=2e-2)


def test_packed_pairs_bit_exact_attention():
    """CRAM packing is lossless: attention over packed == over raw pages."""
    rng = np.random.default_rng(5)
    page, hkv, d = 16, 2, 32
    pages = _pages(rng, 4, page, hkv, 2 * d, compressible=True)
    cache_packed = ops.build_cram_cache(pages)
    assert bool(np.asarray(cache_packed["packed_mask"]).all())
    # force-raw cache of the same pages
    cache_raw = ops.build_cram_cache(pages)
    cache_raw["packed_mask"] = jnp.zeros_like(cache_raw["packed_mask"])
    cache_raw["slots"] = pages[0::2]
    cache_raw["slots_overflow"] = pages[1::2]
    cache_raw["strips"] = jnp.zeros_like(cache_raw["strips"])
    valid = jnp.full((4,), page, jnp.int32)
    q = jnp.asarray(rng.standard_normal((2, 4, d)), jnp.float32)
    a = ops.decode_attention(q, cache_packed, valid)
    b = ops.decode_attention(q, cache_raw, valid)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_bandwidth_accounting():
    rng = np.random.default_rng(6)
    page, hkv, d = 16, 2, 32
    pages = _pages(rng, 8, page, hkv, 2 * d, compressible=True)
    cache = ops.build_cram_cache(pages)
    valid = jnp.full((8,), page, jnp.int32)
    bw = ops.hbm_bytes_moved(cache, valid)
    # all packed: ~2x effective bandwidth minus the strip overhead
    assert 0.40 < bw["saving"] <= 0.5
    # incompressible: small overhead, never catastrophic
    pages_bad = _pages(rng, 8, page, hkv, 2 * d, compressible=False)
    cache_bad = ops.build_cram_cache(pages_bad)
    bw_bad = ops.hbm_bytes_moved(cache_bad, valid)
    assert -0.15 < bw_bad["saving"] <= 0.0


def _batched_case(rng, lanes, n_groups, batch, page=8, hkv=1, d=32):
    """Stacked per-sequence caches with random packed/raw mixes and
    random partial-page valid counts (the batched kernel's full input
    space)."""
    d2 = 2 * d
    build = (ops.build_cram_cache if lanes == 2
             else ops.build_cram_cache_quad)
    n_pages = lanes * n_groups
    caches, valids = [], []
    for _ in range(batch):
        groups = [np.asarray(_pages(rng, lanes, page, hkv, d2,
                                    compressible=bool(rng.random() < 0.6),
                                    scale=1e-4))
                  for _ in range(n_groups)]
        caches.append(build(jnp.asarray(np.concatenate(groups))))
        tokens = int(rng.integers(1, n_pages * page + 1))
        valids.append(np.clip(tokens - np.arange(n_pages) * page,
                              0, page).astype(np.int32))
    cache = {k: jnp.stack([c[k] for c in caches])
             for k in ("slots", "slots_overflow", "strips", "packed_mask")}
    cache["markers"] = caches[0]["markers"]
    q = jnp.asarray(rng.standard_normal((batch, 4, d)), jnp.bfloat16)
    return q, cache, jnp.asarray(np.stack(valids))


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([2, 4]),
       st.integers(1, 3), st.integers(2, 6), st.sampled_from([1, 2, 0]))
def test_fused_batched_blockspec_sweep(seed, lanes, batch, n_groups, bg):
    """The BlockSpec tuning axis is semantics-free: any block_groups
    tiling (bg=0 → auto) gives oracle-parity numerics and byte totals
    bit-exact vs `hbm_bytes_moved`, across random lanes/batch/groups/
    valid mixes."""
    rng = np.random.default_rng(seed)
    q, cache, vp = _batched_case(rng, lanes, n_groups, batch)
    block_groups = bg if bg else None
    out, raw_s, cram_s = ops.decode_attention_fused(
        q, cache, vp, lanes=lanes, block_groups=block_groups,
        interpret=True)
    ref_fn = (ops.decode_attention_ref_batched if lanes == 2
              else ops.decode_attention_quad_ref_batched)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref_fn(q, cache, vp)),
                               atol=2e-2, rtol=2e-2)
    bw = ops.hbm_bytes_moved(cache, vp, lanes=lanes)
    assert np.array_equal(np.asarray(raw_s), bw["raw_per_seq"])
    assert np.array_equal(np.asarray(cram_s), bw["cram_per_seq"])


def test_kv_cache_dynamic_gate():
    from repro.kv import CRAMKVCache

    rng = np.random.default_rng(7)
    page, hkv, d = 8, 1, 32
    kvc = CRAMKVCache(max_pages=8, page=page, n_kv=hkv, head_dim=d,
                      policy="dynamic")
    # incompressible traffic: the gate should eventually disable packing
    for _ in range(12):
        k = rng.standard_normal((page, hkv, d)).astype(np.float32)
        v = rng.standard_normal((page, hkv, d)).astype(np.float32)
        kvc.append(k[: page // 2], v[: page // 2])
        q = jnp.asarray(rng.standard_normal((1, 2, d)), jnp.float32)
        kvc.attend(q)
        if kvc.tokens + page // 2 > kvc.max_pages * page:
            break
    assert kvc.stats.raw_pairs > 0
    assert kvc.stats.packed_pairs == 0
