"""Serving tier: continuous batching + compressed KV spill (ISSUE 6).

Three invariants make the serve tier trustworthy, and each is pinned
bitwise here:

  1. slot/solo parity — under any join/retire/step schedule, a slot's
     physical layout and its attend output equal a standalone batch=1
     cache fed the same stream (the batch axis adds nothing);
  2. spill round-trip — evict + wake resurrects the slot's physical
     state, logical pages, and attend outputs bit-identically, across
     spill packings, partial pages and gate states;
  3. slot reuse — retiring hands the lane back; the batch axis never
     grows.

The deterministic versions run in tier-1 from a clean checkout; the
hypothesis sweep (random schedules / shapes) rides along when the
optional dev dependency is present (gated in-module, not via conftest,
because this module mixes both kinds).
"""

import os
import pathlib
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.bandwidth import AutoTuner, Ledger
from repro.kv import synthetic_kv_stream
from repro.serving import SPILL_LANES, ServeLoop, SlotKVCache
from repro.serving.shard import shard_kv_attend

PAGE, HKV, HD = 8, 1, 16

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False


def _stream(rng, t, compressible=True):
    k, v = synthetic_kv_stream(rng, 1, t, HKV, HD, compressible=compressible)
    return k[0], v[0]


def _assert_state_equal(a: dict, b: dict, ctx=""):
    assert a.keys() == b.keys()
    for kk in a:
        assert jnp.array_equal(a[kk], jnp.asarray(b[kk])), (ctx, kk)


def _snap(state: dict) -> dict:
    return {kk: np.asarray(vv) for kk, vv in state.items()}


# --------------------------------------------- continuous-batching parity

def _solo_like(loop: ServeLoop) -> SlotKVCache:
    c = loop.cache
    return SlotKVCache(c.max_pages, c.page, c.n_kv, c.d, batch=1,
                       policy=c.policy, packing=c.packing, key=c.key)


def _check_parity(loop: ServeLoop, solos: dict, rng):
    """Every active sequence: physical state bitwise == solo replay, and
    the batched (masked-lane) attend == the solo attend, bit-for-bit."""
    loop.cache.repack()
    active = loop.active_seqs()
    if not active:
        return
    q = {sid: np.asarray(_stream(rng, 1)[0][0], np.float32)
         for sid in active}
    out = loop.attend(q)
    for sid in active:
        solo = solos[sid]
        solo.repack()
        _assert_state_equal(
            loop.cache.slot_physical_state(loop.seqs[sid].slot),
            solo.slot_physical_state(0), ctx=sid)
        ref = shard_kv_attend(solo, np.asarray(q[sid])[None], shard=False)
        assert np.array_equal(np.asarray(out[sid]), np.asarray(ref[0])), sid


def _check_parity_all(loop: ServeLoop, solos: dict, rng):
    """Parity for every LIVE sequence: active ones directly, spilled ones
    woken one by one — live may exceed the slot pool, so they can never
    all be active at once (each wake may re-spill an already-checked
    one)."""
    checked: set = set()
    while True:
        _check_parity(loop, solos, rng)
        checked |= set(loop.active_seqs())
        rest = [s for s in loop.spilled_seqs() if s not in checked]
        if not rest:
            return
        loop.wake(rest[0])


def _run_schedule(loop: ServeLoop, rng, n_ops: int, check_every: int = 4,
                  extra_live: int = 2):
    """Random join/step/retire/evict/wake schedule with a solo replay of
    every sequence; parity-checked along the way.  Admits OVERSUBSCRIBE
    the pool by up to `extra_live` (admit evicts automatically), and half
    the steps name every live sequence — more than the slot pool, so the
    wake/evict waves (the launcher's primary spill scenario) are on the
    tested path.  Returns the replay."""
    solos: dict[int, SlotKVCache] = {}
    next_sid = 0
    cap = loop.cache.max_pages * loop.cache.page
    for op_i in range(n_ops):
        live = sorted(loop.seqs)
        op = rng.choice(("admit", "step", "step", "retire", "evict", "wake"))
        if op == "admit" and len(live) < loop.n_slots + extra_live:
            k, v = _stream(rng, int(rng.integers(1, 3 * PAGE)))
            if rng.random() < 0.5:    # fused chunked-prefill ingest: must
                loop.prefill(next_sid, k, v)    # be indistinguishable from
            else:                               # the incremental admit
                loop.admit(next_sid, k, v)
            solo = _solo_like(loop)
            solo.append_slot(0, k, v)
            solos[next_sid] = solo
            next_sid += 1
        elif op == "step" and live:
            ids = [sid for sid in live
                   if int(solos[sid].tokens_b[0]) + 1 <= cap]
            if not ids:
                continue
            if rng.random() < 0.5:            # full step: EVERY live seq,
                pass                          # oversubscribed on purpose
            else:
                ids = [sid for sid in ids if rng.random() < 0.7] or ids[:1]
            kvs = {sid: _stream(rng, 1) for sid in ids}
            loop.step_all(kvs)
            for sid, (kk, vv) in kvs.items():
                solos[sid].append_slot(0, kk, vv)
        elif op == "retire" and live:
            sid = int(rng.choice(live))
            loop.retire(sid)
            del solos[sid]
        elif op == "evict" and loop.active_seqs():
            loop.evict(int(rng.choice(loop.active_seqs())))
        elif op == "wake" and loop.spilled_seqs():
            loop.wake(int(rng.choice(loop.spilled_seqs())))
        if op_i % check_every == check_every - 1:
            _check_parity_all(loop, solos, rng)
    _check_parity_all(loop, solos, rng)
    return solos


def test_random_schedule_matches_solo_reference():
    rng = np.random.default_rng(0)
    loop = ServeLoop(slots=3, max_pages=8, page=PAGE, n_kv=HKV, head_dim=HD,
                     policy="static", packing="pair", spill_packing="quad")
    _run_schedule(loop, rng, n_ops=28)
    assert loop.counts["admitted"] > 0


def test_random_schedule_quad_dynamic_matches_solo():
    rng = np.random.default_rng(7)
    loop = ServeLoop(slots=2, max_pages=8, page=PAGE, n_kv=HKV, head_dim=HD,
                     policy="static", packing="quad", spill_packing="pair")
    _run_schedule(loop, rng, n_ops=20)


def test_retired_slots_are_reused_no_batch_growth():
    rng = np.random.default_rng(1)
    loop = ServeLoop(slots=2, max_pages=4, page=PAGE, n_kv=HKV, head_dim=HD,
                     policy="static")
    loop.admit(100, *_stream(rng, PAGE))      # long-lived: pins lane 0
    seen_slots = set()
    for sid in range(8):                      # churn 8 sequences through
        rec = loop.admit(sid, *_stream(rng, PAGE + 3))   # the OTHER lane
        seen_slots.add(rec.slot)
        loop.step({sid: _stream(rng, 1), 100: _stream(rng, 1)})
        loop.retire(sid)
    assert seen_slots == {1}                  # lane 1 recycled, none added
    loop.retire(100)
    assert loop.cache.batch == 2
    assert loop.cache.state["pages"].shape[0] == 2
    assert sorted(loop._free) == [0, 1]
    # a reused lane starts pristine: admit after retire matches solo
    loop.admit(99, *_stream(rng, 2 * PAGE))
    loop.cache.repack()
    # replay is impossible if the lane kept ghosts: rebuild the oracle
    # over the slot's own prefix
    _assert_state_equal(
        loop.cache.slot_physical_state(loop.seqs[99].slot),
        _snap(loop.cache.slot_reference_state(loop.seqs[99].slot)))


def test_admit_evicts_coldest_when_full():
    rng = np.random.default_rng(2)
    loop = ServeLoop(slots=2, max_pages=4, page=PAGE, n_kv=HKV, head_dim=HD,
                     policy="static")
    loop.admit(0, *_stream(rng, PAGE))
    loop.admit(1, *_stream(rng, PAGE))
    loop.step({1: _stream(rng, 1)})           # seq 0 is now the coldest
    loop.admit(2, *_stream(rng, PAGE))        # no free slot -> spills 0
    assert loop.seqs[0].spilled and 0 in loop.spill
    assert sorted(loop.active_seqs()) == [1, 2]
    loop.wake(0)                              # full again -> evicts 1 or 2
    assert not loop.seqs[0].spilled
    assert len(loop.active_seqs()) == 2 and len(loop.spilled_seqs()) == 1


def test_step_never_evicts_a_step_named_sequence():
    """The launcher's '--slots 2 --batch 4' shape: a step naming a
    spilled sequence plus the coldest ACTIVE one.  Waking the spilled
    sequence must evict an UNNAMED sequence — an unprotected coldest-
    active pick would evict the step-named one (its last_step only
    advances after the append), leaving slot=-1, which numpy wraps to
    the last lane and corrupts whichever sequence owns it."""
    rng = np.random.default_rng(3)
    loop = ServeLoop(slots=2, max_pages=4, page=PAGE, n_kv=HKV, head_dim=HD,
                     policy="static")
    solos = {}
    for sid in range(4):
        k, v = _stream(rng, PAGE)
        loop.admit(sid, k, v)
        solo = _solo_like(loop)
        solo.append_slot(0, k, v)
        solos[sid] = solo
    assert loop.spilled_seqs() == [0, 1] and loop.active_seqs() == [2, 3]
    # seq 2 is the coldest active (same clock, lowest seq id): name it
    # together with spilled seq 0 — the wake must evict 3, never 2
    kvs = {0: _stream(rng, 1), 2: _stream(rng, 1)}
    loop.step(kvs)
    for sid, (kk, vv) in kvs.items():
        solos[sid].append_slot(0, kk, vv)
    assert not loop.seqs[2].spilled and loop.seqs[2].slot >= 0
    assert loop.seqs[3].spilled           # the unnamed one was evicted
    _check_parity(loop, solos, rng)
    # more named sequences than slots cannot share one fused append ...
    with pytest.raises(ValueError, match="step names 3"):
        loop.step({s: _stream(rng, 1) for s in (0, 2, 3)})
    # ... but step_all chunks them into waves, appending every named seq
    kvs = {s: _stream(rng, 1) for s in (0, 2, 3)}
    assert set(loop.step_all(kvs)) == {0, 2, 3}
    for sid, (kk, vv) in kvs.items():
        solos[sid].append_slot(0, kk, vv)
    _check_parity_all(loop, solos, rng)


# ------------------------------------- fused chunked-prefill ingest


def _window_cols(cache: SlotKVCache, tokens: int) -> int:
    span = cache.group_lanes * cache.page
    return -(-tokens // span)


@pytest.mark.parametrize("policy,packing", [
    ("static", "pair"), ("static", "quad"), ("off", "pair"),
    ("dynamic", "pair"), ("dynamic", "quad")])
@pytest.mark.parametrize("tokens", [16, 35, 56, 64])
def test_prefill_bit_identical_to_append_oracle(policy, packing, tokens):
    """prefill_slot (ONE bulk-pack launch) == append_slot + repack under
    the pre-count gate, bit-for-bit: physical layout, §VI counter,
    uncounted set, and the attend output.  Ledger duals are compared on
    pow2 windows (the bulk kernel pads the window to pow2 by repeating a
    real column — idempotent for layout, overbooked for bytes, the SAME
    convention the fused megastep uses)."""
    rng = np.random.default_rng(21)
    k, v = _stream(rng, tokens)
    fused = SlotKVCache(8, PAGE, HKV, HD, batch=2, policy=policy,
                        packing=packing)
    fused.prefill_slot(0, k, v)
    oracle = SlotKVCache(8, PAGE, HKV, HD, batch=2, policy=policy,
                         packing=packing)
    oracle.append_slot(0, k, v)
    oracle.repack(gate=oracle._gate_b)
    for slot in (0, 1):                      # lane 1 (all-zero) untouched
        _assert_state_equal(fused.slot_physical_state(slot),
                            _snap(oracle.slot_physical_state(slot)),
                            ctx=(policy, packing, tokens, slot))
    assert np.array_equal(np.asarray(fused.state["counter"]),
                          np.asarray(oracle.state["counter"]))
    assert (fused._uncounted_b == oracle._uncounted_b).all()
    q = np.asarray(_stream(rng, 1)[0], np.float32)      # (1, HKV, HD)
    q2 = np.broadcast_to(q, (2,) + q.shape[1:])
    assert np.array_equal(
        np.asarray(shard_kv_attend(fused, q2, shard=False)),
        np.asarray(shard_kv_attend(oracle, q2, shard=False)))
    w = _window_cols(fused, tokens)
    if w & (w - 1) == 0:                     # pow2 window: exact duals
        assert np.array_equal(np.asarray(fused.state["traffic"]),
                              np.asarray(oracle.state["traffic"]))
        assert np.array_equal(np.asarray(fused.state["packed_n"]),
                              np.asarray(oracle.state["packed_n"]))
        assert np.array_equal(np.asarray(fused.state["raw_n"]),
                              np.asarray(oracle.state["raw_n"]))


@pytest.mark.parametrize("policy,packing", [("static", "pair"),
                                            ("dynamic", "quad")])
def test_prefill_matches_token_by_token_replay(policy, packing):
    """Loop-level: one prefill admit == admitting the first token and
    replaying the rest through the fused decode megastep — state,
    counter and attend all bit-identical."""
    rng = np.random.default_rng(24)
    k, v = _stream(rng, 5 * PAGE + 3)
    mk = dict(slots=2, max_pages=8, page=PAGE, n_kv=HKV, head_dim=HD,
              policy=policy, packing=packing)
    fused, replay = ServeLoop(**mk), ServeLoop(**mk)
    fused.prefill(0, k, v)
    replay.admit(0, k[:1], v[:1])
    for i in range(1, k.shape[0]):
        replay.step({0: (k[i:i + 1], v[i:i + 1])})
    fused.cache.repack()
    replay.cache.repack()
    _assert_state_equal(fused.cache.slot_physical_state(0),
                        _snap(replay.cache.slot_physical_state(0)))
    assert np.array_equal(np.asarray(fused.cache.state["counter"]),
                          np.asarray(replay.cache.state["counter"]))
    q = {0: np.asarray(_stream(rng, 1)[0][0], np.float32)}
    assert np.array_equal(np.asarray(fused.attend(q)[0]),
                          np.asarray(replay.attend(q)[0]))


def test_admit_beyond_pool_ordering_spills_incoming_coldest():
    """ISSUE 10 bugfix pin: a prompt admitted into a FULL pool whose
    would-be recency key orders below every resident goes straight to the
    spill tier (no lane, no eviction) — thrashing a hotter resident to
    make room for the coldest sequence in the system is strictly worse.
    Waking it later must be bit-identical to a hot-lane prefill."""
    rng = np.random.default_rng(22)
    loop = ServeLoop(slots=2, max_pages=8, page=PAGE, n_kv=HKV, head_dim=HD,
                     policy="static", packing="pair", spill_packing="quad")
    loop.admit(10, *_stream(rng, 2 * PAGE))
    loop.admit(11, *_stream(rng, 2 * PAGE))
    loop.cache.repack()
    resident = {sid: _snap(loop.cache.slot_physical_state(
        loop.seqs[sid].slot)) for sid in (10, 11)}
    kp, vp = _stream(rng, 3 * PAGE + 3)
    # same clock, smaller seq id: the incoming key sorts below both
    # residents' — it must NOT displace either of them
    rec = loop.prefill(3, kp, vp)
    assert rec.spilled and rec.slot == -1 and 3 in loop.spill
    assert loop.counts["spilled_direct"] == 1
    assert loop.counts["evicted"] == 0
    assert sorted(loop.active_seqs()) == [10, 11]
    for sid in (10, 11):
        _assert_state_equal(loop.cache.slot_physical_state(
            loop.seqs[sid].slot), resident[sid], ctx=sid)
    # a spill-direct admit is a real admit: wake == hot-lane prefill
    solo = _solo_like(loop)
    solo.prefill_slot(0, kp, vp)
    loop.retire(10)
    loop.wake(3)
    loop.cache.repack()
    solo.repack()
    _assert_state_equal(
        loop.cache.slot_physical_state(loop.seqs[3].slot),
        _snap(solo.slot_physical_state(0)))
    assert (int(np.asarray(loop.cache.state["counter"][loop.seqs[3].slot]))
            == int(np.asarray(solo.state["counter"][0])))
    # once anything has stepped, a NEW admit is the hottest sequence and
    # takes the eviction path as before
    loop.step({11: _stream(rng, 1)})
    rec2 = loop.admit(20, *_stream(rng, PAGE))
    assert not rec2.spilled and rec2.slot >= 0
    assert loop.counts["evicted"] == 1


def test_prefill_makes_zero_host_ledger_records(monkeypatch):
    """The prefill ingest obeys the PR-7 accounting contract: ALL of its
    traffic lands in the device accumulators — zero host Ledger.record
    calls per admit, one fold at the report boundary."""
    from repro.bandwidth.ledger import N_EVENTS, Ledger

    calls: list = []
    orig = Ledger.record

    def counting(self, *a, **kw):
        calls.append(a)
        return orig(self, *a, **kw)

    monkeypatch.setattr(Ledger, "record", counting)
    rng = np.random.default_rng(23)
    loop = ServeLoop(slots=3, max_pages=8, page=PAGE, n_kv=HKV, head_dim=HD,
                     policy="static", packing="pair")
    for sid in range(3):
        loop.prefill(sid, *_stream(rng, 4 * PAGE + sid))
    assert calls == [], (
        f"prefill admits reached the host ledger {len(calls)} times")
    loop.sync_ledger()
    assert 0 < len(calls) <= N_EVENTS


# ------------------------------------------------------- spill round-trip

@pytest.mark.parametrize("spk", ["off", "pair", "quad"])
def test_spill_roundtrip_bit_identical(spk):
    rng = np.random.default_rng(10)
    loop = ServeLoop(slots=2, max_pages=16, page=PAGE, n_kv=HKV,
                     head_dim=HD, policy="static", packing="pair",
                     spill_packing=spk)
    loop.admit(0, *_stream(rng, 8 * PAGE))
    loop.cache.repack()                       # settle, then snapshot
    snap = _snap(loop.cache.slot_physical_state(0))
    pages_snap = np.asarray(loop.cache.pages_view()[0])
    q = {0: np.asarray(_stream(rng, 1)[0][0], np.float32)}
    before = np.asarray(loop.attend(q)[0])
    loop.evict(0)
    loop.spill.flush()        # async evict: join before reading counters
    assert loop.seqs[0].spilled and loop.spill.spills == 1
    loop.wake(0)
    slot = loop.seqs[0].slot
    _assert_state_equal(loop.cache.slot_physical_state(slot), snap, ctx=spk)
    assert np.array_equal(np.asarray(loop.cache.pages_view()[slot]),
                          pages_snap)
    assert np.array_equal(np.asarray(loop.attend(q)[0]), before)
    s = loop.spill.summary()
    assert s["spills"] == s["restores"] == 1 and s["held"] == 0


def test_spill_savings_order_on_compressible_stream():
    """Tighter spill packing moves fewer link bytes (the whole point):
    stored(quad) < stored(pair) < raw, and "off" adds ~no overhead."""
    rng = np.random.default_rng(11)
    k, v = _stream(rng, 8 * PAGE)
    stored = {}
    for spk in ("off", "pair", "quad"):
        loop = ServeLoop(slots=1, max_pages=8, page=PAGE, n_kv=HKV,
                         head_dim=HD, policy="static", spill_packing=spk)
        loop.admit(0, k, v)
        loop.evict(0)
        loop.spill.flush()
        stored[spk] = loop.spill.stored_bytes
        assert loop.spill.raw_bytes == 8 * loop.cache.slot_bytes
    assert stored["quad"] < stored["pair"] < stored["off"]
    assert stored["off"] <= loop.spill.raw_bytes * 1.01  # fit bits only


def test_spill_roundtrip_partial_page_incompressible_dynamic():
    """The hard corner: dynamic gate, noise stream (raw groups + trimmed
    dead lanes), token count off page/group granularity.  Counter and
    §VI bookkeeping must survive the round-trip too."""
    rng = np.random.default_rng(12)
    loop = ServeLoop(slots=2, max_pages=8, page=PAGE, n_kv=HKV, head_dim=HD,
                     policy="dynamic", packing="quad", spill_packing="pair")
    loop.admit(7, *_stream(rng, 19, compressible=False))
    loop.cache.repack()
    snap = _snap(loop.cache.slot_physical_state(0))
    ctr = int(np.asarray(loop.cache.state["counter"][0]))
    unc = loop.cache._uncounted_b[0].copy()
    loop.evict(7)
    loop.wake(7)
    slot = loop.seqs[7].slot
    _assert_state_equal(loop.cache.slot_physical_state(slot), snap)
    assert int(np.asarray(loop.cache.state["counter"][slot])) == ctr
    assert (loop.cache._uncounted_b[slot] == unc).all()


@pytest.mark.parametrize("spk,tokens,want_tail", [
    # pair: a partial page leaves <=1 full page in its 2-lane group, so
    # that group always goes raw-trimmed (tail unused)
    ("pair", 4 * PAGE + 5, False),
    # quad: 2 full pages + the partial share one 4-lane group — it packs
    # with the partial page crossing raw in `tail`
    ("quad", 2 * PAGE + 5, True),
])
def test_spill_roundtrip_partial_page_compressible(spk, tokens, want_tail):
    """Off-page-granularity length on a COMPRESSIBLE stream: full pages
    still pack (the partial page must not poison its group) and the
    round-trip stays bit-identical, payload strictly smaller than raw."""
    rng = np.random.default_rng(16)
    loop = ServeLoop(slots=1, max_pages=8, page=PAGE, n_kv=HKV, head_dim=HD,
                     policy="static", spill_packing=spk)
    loop.admit(0, *_stream(rng, tokens))
    loop.cache.repack()
    snap = _snap(loop.cache.slot_physical_state(0))
    pages_snap = np.asarray(loop.cache.pages_view()[0])
    loop.evict(0)
    loop.spill.flush()
    p = loop.spill._store[0]
    assert p.fit.any()
    assert (p.tail is not None) == want_tail
    assert loop.spill.stored_bytes < loop.spill.raw_bytes
    loop.wake(0)
    _assert_state_equal(loop.cache.slot_physical_state(0), snap, ctx=spk)
    assert np.array_equal(np.asarray(loop.cache.pages_view()[0]),
                          pages_snap)


def test_restore_decodes_under_the_payloads_packing():
    """A payload evicted under one packing must decode under THAT packing
    even if the store's setting changed while the sequence was cold
    (per-tier retuning): restore() reads the recorded `p.packing`, not
    the store's current one."""
    rng = np.random.default_rng(17)
    loop = ServeLoop(slots=1, max_pages=8, page=PAGE, n_kv=HKV, head_dim=HD,
                     policy="static", spill_packing="quad")
    loop.admit(0, *_stream(rng, 8 * PAGE))
    loop.cache.repack()
    snap = _snap(loop.cache.slot_physical_state(0))
    pages_snap = np.asarray(loop.cache.pages_view()[0])
    loop.evict(0)
    loop.spill.flush()
    assert loop.spill._store[0].packing == "quad"
    loop.spill.packing, loop.spill.lanes = "pair", SPILL_LANES["pair"]
    loop.wake(0)
    _assert_state_equal(loop.cache.slot_physical_state(0), snap)
    assert np.array_equal(np.asarray(loop.cache.pages_view()[0]),
                          pages_snap)


def test_spill_roundtrip_with_pending_dirty_appends():
    """Evict settles the layout first: appends not yet repacked at evict
    time must still round-trip (the payload is the settled state)."""
    rng = np.random.default_rng(13)
    loop = ServeLoop(slots=1, max_pages=8, page=PAGE, n_kv=HKV, head_dim=HD,
                     policy="static", spill_packing="quad")
    loop.admit(0, *_stream(rng, 2 * PAGE + 3))
    loop.step({0: _stream(rng, 1)})           # dirty groups pending
    loop.cache.repack()
    ref = _snap(loop.cache.slot_physical_state(0))
    loop.cache.append_slot(0, *_stream(rng, 2))   # dirty again, no repack
    loop.evict(0)
    loop.wake(0)
    got = loop.cache.slot_physical_state(loop.seqs[0].slot)
    for kk in ("markers",):
        assert jnp.array_equal(got[kk], jnp.asarray(ref[kk]))
    # and the woken slot equals its own rebuild oracle
    _assert_state_equal(
        got, _snap(loop.cache.slot_reference_state(loop.seqs[0].slot)))


def test_spill_capacity_bound_and_retire_while_cold():
    rng = np.random.default_rng(14)
    loop = ServeLoop(slots=2, max_pages=8, page=PAGE, n_kv=HKV, head_dim=HD,
                     policy="static", spill_pages=4)
    loop.admit(0, *_stream(rng, 4 * PAGE))
    loop.admit(1, *_stream(rng, 4 * PAGE))
    loop.evict(0)                             # 4 pages held == capacity
    with pytest.raises(RuntimeError, match="spill store full"):
        loop.evict(1)
    loop.retire(0)                            # retired while cold: dropped
    assert 0 not in loop.spill and len(loop.spill) == 0
    loop.evict(1)                             # capacity freed
    assert 1 in loop.spill


# ------------------------------------------------------ per-tier autotune

def test_serve_loop_auto_picks_per_tier_packings():
    rng = np.random.default_rng(15)
    k, v = synthetic_kv_stream(rng, 1, 8 * PAGE, HKV, HD, scale=2e-4)
    loop, choices = ServeLoop.auto(
        AutoTuner(), k, v, slots=2, max_pages=8, page=PAGE, n_kv=HKV,
        head_dim=HD)
    assert choices["hot"].target == "kv"
    assert choices["spill"].target == "kv-spill"
    assert loop.spill.packing == choices["spill"].choice != "off"
    # the loop runs end-to-end under the chosen layouts
    loop.admit(0, k[0], v[0])
    loop.evict(0)
    loop.wake(0)
    obs = loop.observe_tiers()
    assert set(obs) == {"kv-hot", "kv-spill"}
    noise = synthetic_kv_stream(rng, 1, 8 * PAGE, HKV, HD,
                                compressible=False)
    _, off_choices = ServeLoop.auto(
        AutoTuner(), *noise, slots=2, max_pages=8, page=PAGE, n_kv=HKV,
        head_dim=HD)
    assert off_choices["hot"].choice == "off"
    assert off_choices["spill"].choice == "off"


# ----------------------------------------------------------- sharded serve

def test_sharded_attend_bit_identical_to_single_device():
    """shard_map over the slot axis on a forced 2-device CPU must match
    the single-device dispatch exactly (fresh process: the device count is
    fixed at jax init)."""
    code = textwrap.dedent("""
        import os
        os.environ["JAX_PLATFORMS"] = "cpu"
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                                   + " --xla_force_host_platform_device_count=2")
        import numpy as np
        import jax
        from repro.kv import synthetic_kv_stream
        from repro.serving import ServeLoop
        from repro.serving.shard import shard_kv_attend

        assert len(jax.devices()) == 2
        PAGE, HKV, HD = 8, 1, 16
        rng = np.random.default_rng(4)
        loop = ServeLoop(slots=4, max_pages=4, page=PAGE, n_kv=HKV,
                         head_dim=HD, policy="static")
        for sid, t in enumerate((5, PAGE, 2 * PAGE, 3 * PAGE + 1)):
            k, v = synthetic_kv_stream(rng, 1, t, HKV, HD)
            loop.admit(sid, k[0], v[0])
        q = np.asarray(synthetic_kv_stream(rng, 4, 1, HKV, HD)[0][:, 0],
                       np.float32)
        sharded = shard_kv_attend(loop.cache, q, shard=True)
        single = shard_kv_attend(loop.cache, q, shard=False)
        assert np.array_equal(np.asarray(sharded), np.asarray(single))
        # an odd slot count doesn't divide 2 devices: "auto" must fall
        # back to the single-device dispatch, bit-identically
        loop3 = ServeLoop(slots=3, max_pages=4, page=PAGE, n_kv=HKV,
                          head_dim=HD, policy="static")
        for sid in range(3):
            k, v = synthetic_kv_stream(rng, 1, PAGE + sid, HKV, HD)
            loop3.admit(sid, k[0], v[0])
        q3 = np.asarray(synthetic_kv_stream(rng, 3, 1, HKV, HD)[0][:, 0],
                        np.float32)
        fb = shard_kv_attend(loop3.cache, q3, shard="auto")
        ref = shard_kv_attend(loop3.cache, q3, shard=False)
        assert np.array_equal(np.asarray(fb), np.asarray(ref))
        print("SHARD-OK")
    """)
    env = dict(os.environ)
    src = str(pathlib.Path(__file__).resolve().parents[1] / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SHARD-OK" in out.stdout


# ------------------------------------- device-resident ledger window


def _ledger_schedule(sync_every_step: bool) -> ServeLoop:
    """A fixed non-spilling serve schedule: 3 admits + 12 fused decode
    steps, optionally folding the device window after every step."""
    rng = np.random.default_rng(11)
    loop = ServeLoop(slots=3, max_pages=8, page=PAGE, n_kv=HKV,
                     head_dim=HD, policy="static", packing="pair")
    for sid in range(3):
        loop.admit(sid, *_stream(rng, PAGE))
    for _ in range(12):
        loop.step_all({sid: _stream(rng, 1) for sid in range(3)})
        if sync_every_step:
            loop.sync_ledger()
    return loop


def test_n_step_serve_makes_o1_host_ledger_records(monkeypatch):
    """The device-resident accounting contract: an N-step decode run
    performs ZERO host `Ledger.record` calls (every step's read/repack
    bytes land in the cache's device accumulators), one `sync_ledger`
    fold costs at most N_EVENTS records, and the folded totals are
    identical to syncing after every step."""
    from repro.bandwidth.ledger import N_EVENTS, Ledger

    ref = _ledger_schedule(sync_every_step=True).ledger.as_dict()
    assert ref, "schedule must book some traffic"

    calls: list = []
    orig = Ledger.record

    def counting(self, *a, **kw):
        calls.append(a)
        return orig(self, *a, **kw)

    monkeypatch.setattr(Ledger, "record", counting)
    loop = _ledger_schedule(sync_every_step=False)
    assert calls == [], (
        f"decode steps reached the host ledger {len(calls)} times; "
        "all step accounting must stay device-resident")
    loop.sync_ledger()
    assert 0 < len(calls) <= N_EVENTS
    assert loop.ledger.as_dict() == ref
    # the fold drained the window: re-syncing is a no-op
    n = len(calls)
    loop.sync_ledger()
    assert len(calls) == n


# ---------------------------------------------------- hypothesis sweep

if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(
        spk=st.sampled_from(["off", "pair", "quad"]),
        policy=st.sampled_from(["static", "dynamic"]),
        tokens=st.integers(min_value=1, max_value=6 * PAGE),
        compressible=st.booleans(),
        pending=st.booleans(),
        seed=st.integers(min_value=0, max_value=2 ** 16),
    )
    def test_spill_roundtrip_property(spk, policy, tokens, compressible,
                                      pending, seed):
        """evict+wake == identity on the settled slot state, for every
        spill packing x gate policy x token count x stream regime, with
        or without un-repacked appends pending at evict time."""
        rng = np.random.default_rng(seed)
        loop = ServeLoop(slots=2, max_pages=8, page=PAGE, n_kv=HKV,
                         head_dim=HD, policy=policy, spill_packing=spk)
        loop.admit(0, *_stream(rng, tokens, compressible=compressible))
        if pending and tokens + 2 <= loop.cache.max_pages * PAGE:
            loop.cache.repack()
            loop.cache.append_slot(0, *_stream(rng, 2))
        loop.cache.repack()
        snap = _snap(loop.cache.slot_physical_state(0))
        pages = np.asarray(loop.cache.pages_view()[0])
        ctr = int(np.asarray(loop.cache.state["counter"][0]))
        loop.evict(0)
        loop.wake(0)
        slot = loop.seqs[0].slot
        _assert_state_equal(loop.cache.slot_physical_state(slot), snap)
        assert np.array_equal(np.asarray(loop.cache.pages_view()[slot]),
                              pages)
        assert int(np.asarray(loop.cache.state["counter"][slot])) == ctr

    @settings(max_examples=20, deadline=None)
    @given(
        policy=st.sampled_from(["static", "dynamic", "off"]),
        packing=st.sampled_from(["pair", "quad"]),
        tokens=st.integers(min_value=1, max_value=6 * PAGE),
        compressible=st.booleans(),
        seed=st.integers(min_value=0, max_value=2 ** 16),
    )
    def test_prefill_oracle_property(policy, packing, tokens, compressible,
                                     seed):
        """The bulk-pack prefill equals the append+repack oracle for every
        packing x gate policy x token count x stream regime — partial
        pages, partial groups, raw fallbacks and all."""
        rng = np.random.default_rng(seed)
        k, v = _stream(rng, tokens, compressible=compressible)
        fused = SlotKVCache(8, PAGE, HKV, HD, batch=2, policy=policy,
                            packing=packing)
        fused.prefill_slot(0, k, v)
        oracle = SlotKVCache(8, PAGE, HKV, HD, batch=2, policy=policy,
                             packing=packing)
        oracle.append_slot(0, k, v)
        oracle.repack(gate=oracle._gate_b)
        _assert_state_equal(fused.slot_physical_state(0),
                            _snap(oracle.slot_physical_state(0)))
        assert np.array_equal(np.asarray(fused.state["counter"]),
                              np.asarray(oracle.state["counter"]))
        assert (fused._uncounted_b == oracle._uncounted_b).all()
