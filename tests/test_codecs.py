"""FPC / BDI / hybrid codec properties: exact round-trips + size laws +
numpy/jax.numpy backend parity (property-based; see test_codec_registry.py
for the deterministic cross-backend suite)."""

import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.compression import bdi, fpc
from repro.compression import hybrid as compress

LINE = 64


def lines_strategy():
    # mix of structured and random lines: the structured ones exercise
    # every FPC pattern and BDI mode
    return st.sampled_from([
        "zeros", "small_words", "rep_bytes", "rep8", "base_delta8",
        "base_delta4", "halfwords", "random",
    ]).flatmap(lambda kind: st.integers(0, 2**32 - 1).map(
        lambda seed: _make_line(kind, seed)))


def _make_line(kind: str, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    if kind == "zeros":
        out = np.zeros(LINE, np.uint8)
        if seed % 3 == 0:  # sprinkle a couple of nonzeros
            out[rng.integers(0, LINE, 2)] = rng.integers(1, 255, 2)
        return out
    if kind == "small_words":
        w = rng.integers(-8, 8, 16).astype("<i4")
        return w.view(np.uint8).copy()
    if kind == "rep_bytes":
        w = np.repeat(rng.integers(0, 256, 16).astype(np.uint8), 4)
        return w[:LINE].copy()
    if kind == "rep8":
        return np.tile(rng.integers(0, 256, 8).astype(np.uint8), 8)
    if kind == "base_delta8":
        base = rng.integers(-2**62, 2**62, dtype=np.int64)
        d = rng.integers(-100, 100, 8).astype(np.int64)
        return (base + d).astype("<i8").view(np.uint8).copy()
    if kind == "base_delta4":
        base = rng.integers(-2**30, 2**30, dtype=np.int64)
        d = rng.integers(-100, 100, 16)
        return (base + d).astype("<i4").view(np.uint8).copy()
    if kind == "halfwords":
        h = rng.integers(-128, 128, 32).astype("<i2")
        return h.view(np.uint8).copy()
    return rng.integers(0, 256, LINE).astype(np.uint8)


@given(lines_strategy())
def test_fpc_roundtrip_and_size(line):
    packed = fpc.fpc_pack(line)
    out = fpc.fpc_unpack(packed)
    assert np.array_equal(out, line)
    assert len(packed) == int(fpc.fpc_size_bytes(line.reshape(1, LINE))[0])
    assert 1 <= len(packed) <= LINE + 6  # worst case: 3-bit prefix overhead


@given(lines_strategy())
def test_bdi_roundtrip(line):
    arr = line.reshape(1, LINE)
    sizes, modes = bdi.bdi_sizes(arr)
    mode = int(modes[0])
    payload = bdi.bdi_pack_batch(arr, mode)
    assert payload.shape[1] == bdi.PAYLOAD_BYTES[mode] == int(sizes[0])
    out = bdi.bdi_unpack_batch(payload, mode)
    assert np.array_equal(out, arr)


@given(lines_strategy())
def test_hybrid_roundtrip(line):
    blob = compress.compress_line(line)
    out, consumed = compress.decompress_line(blob)
    assert consumed == len(blob)
    assert np.array_equal(out, line)
    assert len(blob) == int(
        compress.compressed_sizes(line.reshape(1, LINE))[0])
    assert len(blob) <= LINE + 1 + 6


def test_bdi_modes_exact_sizes():
    # zeros -> 0B payload; rep8 -> 8B; B8D1 -> 17B
    zeros = np.zeros((1, LINE), np.uint8)
    s, m = bdi.bdi_sizes(zeros)
    assert int(m[0]) == bdi.M_ZEROS and int(s[0]) == 0
    rep = np.tile(np.arange(8, dtype=np.uint8), 8).reshape(1, LINE)
    s, m = bdi.bdi_sizes(rep)
    assert int(m[0]) == bdi.M_REP8 and int(s[0]) == 8
    b8 = (np.int64(10**15) + np.arange(8)).astype("<i8").view(
        np.uint8).reshape(1, LINE)
    s, m = bdi.bdi_sizes(b8)
    assert int(m[0]) == bdi.M_B8D1 and int(s[0]) == 17


def test_vectorized_batch_consistency():
    batch = np.stack([_make_line(k, i) for i, k in enumerate(
        ["zeros", "rep8", "base_delta4", "random"] * 8)])
    sizes = compress.compressed_sizes(batch)
    for i, line in enumerate(batch):
        assert int(sizes[i]) == len(compress.compress_line(line))


def test_jnp_size_path_matches_numpy():
    import jax.numpy as jnp
    from jax import enable_x64

    batch = np.stack([_make_line("base_delta4", i) for i in range(16)]
                     + [_make_line("random", i) for i in range(16)])
    np_sizes = fpc.fpc_size_bytes(batch)
    with enable_x64():
        j_sizes = np.asarray(fpc.fpc_size_bytes(jnp.asarray(batch), xp=jnp))
        nb, jb = bdi.bdi_sizes(batch), bdi.bdi_sizes(jnp.asarray(batch),
                                                     xp=jnp)
    assert np.array_equal(np_sizes, j_sizes)
    assert np.array_equal(np.asarray(nb[0]), np.asarray(jb[0]))


# ----------------------------------------------------- xp-parity (property)
# Adversarial word menu: each 32-bit word is drawn to sit ON a pattern/size
# boundary (sign flips, exact range edges, zero-run splice points), the
# places where a vectorized size law and a bit-exact packer most easily
# disagree.

_WORD_MENU = (
    0, 1, 7, 8, -8 & 0xFFFFFFFF, -9 & 0xFFFFFFFF,           # se4 edges
    127, 128, -128 & 0xFFFFFFFF, -129 & 0xFFFFFFFF,         # se8 edges
    32767, 32768, -32768 & 0xFFFFFFFF, -32769 & 0xFFFFFFFF,  # se16 edges
    0x00010000, 0xFFFF0000, 0x7FFF0000,                     # pad16
    0x00800080, 0x7F807F80, 0x0080FF80,                     # half_se8 edges
    0xABABABAB, 0x01010101,                                 # repeated bytes
    0xDEADBEEF, 0x80000000, 0x7FFFFFFF,                     # raw
)


def adversarial_lines():
    """Lines assembled word-by-word from boundary values + random words."""
    word = st.one_of(st.sampled_from(_WORD_MENU),
                     st.integers(0, 2**32 - 1))
    return st.lists(word, min_size=16, max_size=16).map(
        lambda ws: np.asarray(ws, dtype="<u4").view(np.uint8).copy())


@given(adversarial_lines())
def test_xp_parity_sizes_vs_exact_pack(line):
    """fpc_size_bits / compressed_sizes agree between the numpy and
    jax.numpy backends AND with the exact bit-level packers."""
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    arr = line.reshape(1, LINE)
    words = arr.view("<u4").reshape(1, 16)
    np_bits = int(fpc.fpc_size_bits(words)[0])
    np_hybrid = int(compress.compressed_sizes(arr)[0])
    with enable_x64():
        j_bits = int(np.asarray(
            fpc.fpc_size_bits(jnp.asarray(words), xp=jnp))[0])
        j_hybrid = int(np.asarray(
            compress.compressed_sizes(jnp.asarray(arr), xp=jnp))[0])
    assert np_bits == j_bits
    assert np_hybrid == j_hybrid
    # the exact packers pin the vectorized size laws
    assert len(fpc.fpc_pack(line)) == (np_bits + 7) // 8
    assert len(compress.compress_line(line)) == np_hybrid
    assert np.array_equal(fpc.fpc_unpack(fpc.fpc_pack(line)), line)


@given(adversarial_lines())
def test_xp_parity_bdi_sizes(line):
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    arr = line.reshape(1, LINE)
    ns, nm = bdi.bdi_sizes(arr)
    with enable_x64():
        js, jm = bdi.bdi_sizes(jnp.asarray(arr), xp=jnp)
    assert int(ns[0]) == int(js[0]) and int(nm[0]) == int(jm[0])
    payload = bdi.bdi_pack_batch(arr, int(nm[0]))
    assert payload.shape[1] == int(ns[0])


def test_group_packing():
    from repro.compression.marker import MarkerSpec

    spec = MarkerSpec()
    lines = [np.zeros(LINE, np.uint8),
             np.tile(np.arange(8, dtype=np.uint8), 8)]
    slot = compress.pack_group(lines, spec.marker2(0))
    assert slot is not None and slot.shape == (LINE,)
    out = compress.unpack_group(slot, 2)
    assert np.array_equal(out[0], lines[0])
    assert np.array_equal(out[1], lines[1])
    # incompressible pair must not fit
    rng = np.random.default_rng(0)
    bad = [rng.integers(0, 256, LINE).astype(np.uint8) for _ in range(2)]
    assert compress.pack_group(bad, spec.marker2(0)) is None
