"""Per-architecture smoke tests (assignment deliverable f).

Every assigned arch instantiates a REDUCED same-family config and runs one
forward/train step + one decode step on CPU, asserting shapes and finiteness.
The FULL configs are exercised only via the dry-run (ShapeDtypeStruct)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get, get_smoke
from repro.models import build, count_params


@pytest.fixture(scope="module")
def key():
    return jax.random.key(0)


def _batch(cfg, key, B=2, S=64):
    ks = jax.random.split(key, 4)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab),
    }
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(ks[2], (B, S, cfg.d_model),
                                            cfg.dtype)
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            ks[3], (B, cfg.n_image_tokens, cfg.d_model), cfg.dtype)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch, key):
    cfg = get_smoke(arch)
    model = build(cfg)
    params, axes = model.init(key)
    # axes tree mirrors the param tree
    assert jax.tree.structure(params) == jax.tree.structure(
        axes, is_leaf=lambda x: isinstance(x, tuple))
    batch = _batch(cfg, key)
    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch, key):
    cfg = get_smoke(arch)
    model = build(cfg)
    params, _ = model.init(key)
    B = 2
    cache = model.init_cache(B, 32)
    kw = {}
    if cfg.family == "vlm":
        kw["image_embeds"] = jax.random.normal(
            key, (B, cfg.n_image_tokens, cfg.d_model), cfg.dtype)
    tok = jax.random.randint(key, (B, 1), 0, cfg.vocab)
    step = jax.jit(lambda p, t, c, i: model.decode_step(p, t, c, i, **kw))
    logits, cache = step(params, tok, cache, jnp.int32(0))
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    logits2, _ = step(params, tok, cache, jnp.int32(1))
    assert np.isfinite(np.asarray(logits2)).all()


@pytest.mark.parametrize("arch,expected_b", [
    ("phi4_mini_3_8b", 3.8), ("mistral_large_123b", 122.0),
    ("qwen3_8b", 7.6), ("nemotron_4_15b", 14.0),
    ("mamba2_130m", 0.13), ("zamba2_2_7b", 2.3),
    ("llama4_maverick_400b_a17b", 397.0), ("olmoe_1b_7b", 6.8),
    ("llama_3_2_vision_90b", 90.0), ("whisper_base", 0.07),
])
def test_full_config_param_counts(arch, expected_b):
    n = count_params(get(arch)) / 1e9
    assert abs(n - expected_b) / expected_b < 0.12, (arch, n, expected_b)


def test_family_features_present():
    assert get("qwen3_8b").qk_norm
    assert get("nemotron_4_15b").mlp_act == "relu2"
    assert get("olmoe_1b_7b").top_k == 8
    assert get("llama4_maverick_400b_a17b").shared_expert_ff > 0
    assert get("zamba2_2_7b").attn_every == 6
    assert get("llama_3_2_vision_90b").cross_attn_every == 5
    assert get("mamba2_130m").ssm_state == 128
