"""Regression tests for the R3 timed-region fixes (analysis rule R3: no
device->host syncs inside timed hot loops).

Two real findings the static analyzer surfaced on the tree:
  * `launch/serve._timed_decode` used to materialize every decode token
    with `np.asarray(tok)` INSIDE the timed loop (one blocking host sync
    per generated token) and read the wall clock without syncing the last
    step.  The test pins the fixed ordering structurally: between the two
    wall-clock reads there is no host materialization, and
    `block_until_ready` runs before the timer stops.
  * `benchmarks/serve_bench._timed_decode_loop` used to read the
    device-syncing `CRAMKVCache.stats` property (four device counters per
    access) and `int()` the byte duals on every timed step.  The test
    poisons `stats` and runs the loop — the timed region must never touch
    it — and checks the pack tallies still match the device-synced path.
"""

import pathlib
import sys
import time

import jax
import numpy as np

ROOT = pathlib.Path(__file__).resolve().parents[1]
if str(ROOT) not in sys.path:
    sys.path.insert(0, str(ROOT))


class _SpyModule:
    """Attribute proxy that logs every access before delegating."""

    def __init__(self, target, log, tag):
        self._target, self._log, self._tag = target, log, tag

    def __getattr__(self, name):
        self._log.append(f"{self._tag}.{name}")
        return getattr(self._target, name)


def test_launch_decode_no_host_sync_in_timed_region(monkeypatch):
    from repro.launch import serve as serve_mod

    log = []
    monkeypatch.setattr(serve_mod, "time", _SpyModule(time, log, "time"))
    monkeypatch.setattr(serve_mod, "np", _SpyModule(np, log, "np"))
    monkeypatch.setattr(serve_mod, "jax", _SpyModule(jax, log, "jax"))

    @jax.jit
    def serve_step(params, tok, cache, i):
        return tok + 1, cache

    prompts = np.arange(12, dtype=np.int32).reshape(3, 4)
    gen, cache, prefill_wall, decode_wall = serve_mod._timed_decode(
        serve_step, None, prompts, {"k": np.zeros(2)}, gen=5)

    # the stub increments the last prompt token once per step
    want = prompts[:, -1:] + 1 + np.arange(5)[None, :]
    np.testing.assert_array_equal(gen, want)
    assert prefill_wall >= 0.0 and decode_wall >= 0.0

    # prefill and decode are SEPARATELY timed regions: four clock reads,
    # each region obeying the R3 discipline on its own
    clocks = [i for i, e in enumerate(log) if e == "time.time"]
    assert len(clocks) == 4, log
    for t0, t1 in ((clocks[0], clocks[1]), (clocks[2], clocks[3])):
        timed = log[t0 + 1:t1]
        # no host materialization between the clock reads ...
        assert not any(e.startswith("np.") for e in timed), timed
        # ... and the device work is synced before the timer stops
        assert "jax.block_until_ready" in timed, timed
    # the host copies happen, but only after the last timed region
    assert any(e.startswith("np.") for e in log[clocks[3]:]), log


def test_serve_bench_timed_loop_never_syncs_stats(monkeypatch):
    import benchmarks.serve_bench as sb
    from repro.kv import CRAMKVCache

    def _make(seed=0):
        rng = np.random.default_rng(seed)
        cache = CRAMKVCache(max_pages=4, page=sb.PAGE, n_kv=sb.HKV,
                            head_dim=sb.HD, batch=1, policy="static")
        cache.append(*sb._stream(rng, 1, 2 * sb.PAGE, True))
        cache.account_step()
        return cache, rng

    # reference run: the device-synced stats path agrees with host_stats
    cache, rng = _make()
    before = cache.stats.pack_pairs_processed
    assert before == cache.host_stats.pack_pairs_processed

    def _poisoned(self):
        raise AssertionError("device-syncing stats read inside timed loop")

    monkeypatch.setattr(CRAMKVCache, "stats", property(_poisoned))
    cache, rng = _make()
    seq_len, pack_pairs, total_pairs, cram_b, raw_b, wall = \
        sb._timed_decode_loop(cache, rng, 1, 3, True)
    assert len(seq_len) == len(cram_b) == len(raw_b) == 3
    assert all(isinstance(v, int) and v > 0 for v in raw_b)
    assert all(isinstance(v, int) and v > 0 for v in cram_b)
    assert all(p >= 0 for p in pack_pairs)
    assert wall >= 0.0


def test_serve_bench_decode_curve_unchanged_values():
    """The R3 restructure must not change what decode_curve reports."""
    import benchmarks.serve_bench as sb

    rep = sb.decode_curve(policy="static", batch=1, prefill_pages=2,
                          decode_steps=4, compressible=True, seed=3)
    assert len(rep["cram_bytes_per_step"]) == 4
    assert rep["seq_len"] == sorted(rep["seq_len"])
    # compressible static stream saves bytes and the duals are consistent
    assert 0.0 < rep["cumulative_saving"] < 1.0
    assert all(c <= r for c, r in zip(rep["cram_bytes_per_step"],
                                      rep["raw_bytes_per_step"],
                                      strict=True))
