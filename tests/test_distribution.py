"""Distribution tests on 8 fake CPU devices (subprocess-isolated: jax locks
the device count at first init, so each scenario runs in its own python).

Covers: pjit train step under the sharding rules (DP x TP), decode with a
sequence-sharded KV cache (SP), GPipe pipeline == sequential forward, the
shard_map compressed-gradient DP step, and a miniature dry-run lowering."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(body: str) -> dict:
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        os.environ.pop("JAX_PLATFORMS", None)
        import json
        import jax
        import jax.numpy as jnp
        import numpy as np
        assert len(jax.devices()) == 8
    """) + textwrap.dedent(body)
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=560,
        env={**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")},
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_pjit_train_step_dp_tp():
    out = run_py("""
        from jax.sharding import Mesh
        from repro.launch.train import PRESETS
        from repro.launch.steps import build_cell
        from repro.models import build, ShapeSpec, input_specs
        from repro.optim.adamw import adamw_init, make_train_step
        from repro.runtime.sharding import RuleSet, tree_shardings, activation_sharding

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = PRESETS["lm2m"]
        model = build(cfg)
        rules = RuleSet()
        params, axes = model.init(jax.random.key(0))
        shards = tree_shardings(axes, jax.eval_shape(lambda: params), mesh, rules)
        params = jax.device_put(params, shards)
        state = adamw_init(params)
        step = jax.jit(make_train_step(model, microbatches=2))
        batch = {
            "tokens": jax.random.randint(jax.random.key(1), (8, 64), 0, cfg.vocab),
            "labels": jax.random.randint(jax.random.key(2), (8, 64), 0, cfg.vocab),
        }
        losses = []
        with mesh, activation_sharding(mesh, rules):
            for _ in range(8):
                state, m = step(state, batch)
                losses.append(float(m["loss"]))
        assert np.isfinite(losses[-1])
        assert losses[-1] < losses[0], losses  # lr warms up over steps
        print(json.dumps({"loss": losses[-1]}))
    """)
    assert out["loss"] > 0


def test_sp_decode_kv_sharded_matches_single_device():
    out = run_py("""
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.models.attention import chunked_decode_attention

        mesh = jax.make_mesh((8, 1), ("data", "model"))
        B, T, Hq, Hkv, D = 2, 256, 4, 2, 16
        q = jax.random.normal(jax.random.key(0), (B, Hq, D))
        k = jax.random.normal(jax.random.key(1), (B, T, Hkv, D))
        v = jax.random.normal(jax.random.key(2), (B, T, Hkv, D))
        ref = chunked_decode_attention(q, k, v, length=199, k_chunk=32)
        kv_shard = NamedSharding(mesh, P(None, "data"))
        k_s = jax.device_put(k, kv_shard)
        v_s = jax.device_put(v, kv_shard)
        with mesh:
            out = jax.jit(lambda q, k, v: chunked_decode_attention(
                q, k, v, length=199, k_chunk=32))(q, k_s, v_s)
        err = float(jnp.max(jnp.abs(out - ref)))
        assert err < 1e-4, err
        print(json.dumps({"err": err}))
    """)
    assert out["err"] < 1e-4


def test_gpipe_matches_sequential():
    out = run_py("""
        from repro.runtime.pipeline import gpipe_apply, split_stages
        mesh = jax.make_mesh((4, 2), ("stage", "data"))
        L, D = 8, 32
        key = jax.random.key(0)
        Ws = jax.random.normal(key, (L, D, D)) * 0.2

        def layer(w, x):
            return jnp.tanh(x @ w)

        def stage_fn(w_stage, x):
            for i in range(w_stage.shape[0]):
                x = layer(w_stage[i], x)
            return x

        M, mb, S = 4, 2, 8
        x = jax.random.normal(jax.random.key(1), (M, mb, S, D))
        seq = x
        for i in range(L):
            seq = layer(Ws[i], seq)
        stages = split_stages(Ws, 4)
        outp = gpipe_apply(stages, x, mesh=mesh, stage_fn=stage_fn)
        err = float(jnp.max(jnp.abs(outp - seq)))
        assert err < 1e-5, err
        # gradients flow through the pipeline
        def loss(ws):
            return jnp.sum(gpipe_apply(split_stages(ws, 4), x, mesh=mesh,
                                       stage_fn=stage_fn) ** 2)
        g = jax.grad(loss)(Ws)
        gn = float(jnp.linalg.norm(g))
        assert np.isfinite(gn) and gn > 0
        print(json.dumps({"err": err, "gnorm": gn}))
    """)
    assert out["err"] < 1e-5


def test_dp_compressed_gradients():
    out = run_py("""
        from repro.launch.train import PRESETS
        from repro.models import build
        from repro.optim import grad_compress as gc

        mesh = jax.make_mesh((8,), ("data",))
        cfg = PRESETS["lm2m"]
        model = build(cfg)
        params, _ = model.init(jax.random.key(0))
        step = gc.make_dp_compressed_step(model, mesh, lr=5e-3)
        err = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        counter = jnp.int32(gc.ENABLE + 64)
        batch = {
            "tokens": jax.random.randint(jax.random.key(1), (8, 32), 0, cfg.vocab),
            "labels": jax.random.randint(jax.random.key(2), (8, 32), 0, cfg.vocab),
        }
        losses = []
        for _ in range(6):
            params, err, counter, loss = step(params, err, counter, batch)
            losses.append(float(loss))
        assert losses[-1] < losses[0], losses
        print(json.dumps({"first": losses[0], "last": losses[-1],
                          "enabled": bool(counter >= gc.ENABLE)}))
    """)
    assert out["last"] < out["first"]


def test_mini_dryrun_lowering():
    out = run_py("""
        from repro.configs import get_smoke
        from repro.launch.steps import build_cell
        from repro.models import ShapeSpec
        from repro.runtime.sharding import RuleSet, activation_sharding
        from repro.launch.hlo_analysis import analyze_compiled

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = get_smoke("olmoe_1b_7b")
        spec = ShapeSpec("mini", 128, 8, "train")
        fn, shapes, shards, _ = build_cell(cfg, spec, mesh, RuleSet())
        with mesh, activation_sharding(mesh, RuleSet()):
            compiled = jax.jit(fn, in_shardings=shards).lower(*shapes).compile()
        info = analyze_compiled(compiled)
        assert info["flops"] > 0
        assert info["collectives"]["total_ops"] > 0
        print(json.dumps({"flops": info["flops"],
                          "colls": info["collectives"]["total_ops"]}))
    """)
    assert out["colls"] > 0
