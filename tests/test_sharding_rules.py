"""Sharding-rule engine: divisibility fallbacks, ZeRO spec, cache axes."""

import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.runtime.sharding import RuleSet, spec_for, zero_spec


@pytest.fixture(scope="module")
def mesh():
    d = np.asarray(jax.devices())  # 1 CPU device: mesh math still applies
    return Mesh(d.reshape(1, 1), ("data", "model"))


def fake_mesh(shape=(16, 16), axes=("data", "model")):
    class _M:  # duck-typed mesh: spec_for only reads .shape
        pass

    m = _M()
    m.shape = dict(zip(axes, shape, strict=True))
    return m


def test_divisible_dims_shard():
    m = fake_mesh()
    spec = spec_for(("vocab", "embed"), (200064, 3072), m)
    assert spec == P(("model",))


def test_indivisible_dims_replicate():
    m = fake_mesh()
    # 8 kv heads cannot shard 16 ways -> replicated
    spec = spec_for(("embed", "kv_heads"), (4096, 1024), m)
    assert spec == P(None, ("model",)) or spec == P(None, "model") \
        or spec[1] is not None  # 1024 divisible: sharded
    spec2 = spec_for((None, "kv_heads"), (4, 8), m)
    assert len(spec2) == 0 or spec2[-1] is None


def test_batch_spans_pod_and_data():
    m = fake_mesh((2, 16, 16), ("pod", "data", "model"))
    spec = spec_for(("batch", "seq"), (256, 4096), m)
    assert tuple(spec[0]) == ("pod", "data")
    assert spec[1] in (("model",), "model")  # Megatron-SP default seq rule


def test_rule_override():
    m = fake_mesh()
    rules = RuleSet().override(seq=())
    spec = spec_for(("batch", "seq"), (256, 4096), m, rules)
    assert len(spec) == 1  # seq entry trimmed (replicated)


def test_zero_spec_adds_data_axis():
    m = fake_mesh()
    base = spec_for(("vocab", "embed"), (32768, 12288), m)
    z = zero_spec(base, (32768, 12288), m, "data")
    assert z == P(("model",), "data")
    # does not double-assign an axis already used
    z2 = zero_spec(P("data"), (32,), m, "data")
    assert z2 == P("data")
    # respects divisibility
    z3 = zero_spec(P(), (7, 3), m, "data")
    assert z3 == P()


def test_constrain_noop_outside_context():
    import jax.numpy as jnp

    from repro.runtime.sharding import constrain

    x = jnp.ones((4, 4))
    assert constrain(x, ("batch", None)) is x
