import os

# Tests must see the single real CPU device (the 512-device override is
# exclusively for launch/dryrun.py runs).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

from hypothesis import HealthCheck, settings

settings.register_profile(
    "ci",
    max_examples=25,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
    derandomize=True,
)
settings.load_profile("ci")
