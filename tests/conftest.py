import os

# Tests must see the single real CPU device (the 512-device override is
# exclusively for launch/dryrun.py runs).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# `hypothesis` is an optional dev dependency (declared in pyproject.toml).
# When it is absent, skip collecting the property-based test modules instead
# of erroring out of the whole suite: the deterministic tier-1 tests must be
# runnable from a clean checkout with only jax+numpy+pytest.
try:
    from hypothesis import HealthCheck, settings
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False
    collect_ignore = [
        "test_codecs.py",
        "test_cram_functional.py",
        "test_engine_property.py",
        "test_kernels.py",
        "test_marker_mapping.py",
        "test_substrates.py",
    ]
else:
    HAVE_HYPOTHESIS = True
    settings.register_profile(
        "ci",
        max_examples=25,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
        derandomize=True,
    )
    settings.load_profile("ci")
