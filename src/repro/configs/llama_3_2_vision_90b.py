"""llama-3.2-vision-90b [vlm] — cross-attn image layers every 5th layer;
the vision tower is a STUB (input_specs provides patch embeddings)
[hf:meta-llama/Llama-3.2-11B-Vision, scaled per assignment]."""
from ..models import ModelConfig
import jax.numpy as jnp

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=28672, vocab=128_256, mlp_act="swiglu",
    cross_attn_every=5, n_image_tokens=4096,
    param_dtype=jnp.bfloat16,
)
