"""mamba2-130m [ssm] — SSD (state-space duality), attn-free [arXiv:2405.21060]."""
from ..models import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=0, n_kv_heads=0, head_dim=1,
    d_ff=0, vocab=50_280,
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_ngroups=1,
)
