"""whisper-base [audio] — enc-dec; conv frontend is a STUB (input_specs
provides precomputed frame embeddings) [arXiv:2212.04356]."""
from ..models import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="encdec",
    n_layers=6, enc_layers=6, dec_layers=6,
    d_model=512, n_heads=8, n_kv_heads=8, head_dim=64,
    d_ff=2048, vocab=51_865, mlp_act="gelu", max_seq=32_768,
)
