"""mistral-large-123b [dense] [hf:mistralai/Mistral-Large-Instruct-2407]."""
from ..models import ModelConfig
import jax.numpy as jnp

CONFIG = ModelConfig(
    name="mistral-large-123b", family="dense",
    n_layers=88, d_model=12288, n_heads=96, n_kv_heads=8, head_dim=128,
    d_ff=28672, vocab=32_768, mlp_act="swiglu",
    # 123B: bf16 weights + FSDP sharding to fit 16GB/chip at 256 chips
    param_dtype=jnp.bfloat16,
)
