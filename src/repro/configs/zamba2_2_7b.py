"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention block every 6
layers [arXiv:2411.15242]."""
from ..models import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
    d_ff=10240, vocab=32_000, mlp_act="swiglu",
    ssm_state=64, ssm_expand=2, ssm_headdim=64, ssm_ngroups=1,
    attn_every=6,
)
