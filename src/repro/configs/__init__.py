"""Assigned architecture configs (exact numbers from the public pool).

Each module exposes CONFIG (full-size) — selectable via --arch <id> in the
launchers.  `get(name)` returns the full config; `get_smoke(name)` returns
the reduced same-family config used by CPU smoke tests.
"""

from __future__ import annotations

import importlib

ARCHS = (
    "phi4_mini_3_8b",
    "mistral_large_123b",
    "qwen3_8b",
    "nemotron_4_15b",
    "whisper_base",
    "mamba2_130m",
    "zamba2_2_7b",
    "llama4_maverick_400b_a17b",
    "olmoe_1b_7b",
    "llama_3_2_vision_90b",
)

# accept dashed ids from the assignment table too
ALIASES = {a.replace("_", "-"): a for a in ARCHS}


def canonical(name: str) -> str:
    name = name.replace(".", "_")
    return ALIASES.get(name, name)


def get(name: str):
    mod = importlib.import_module(f".{canonical(name)}", __package__)
    return mod.CONFIG


def get_smoke(name: str):
    from ..models import smoke_config

    return smoke_config(get(name))


def all_configs():
    return {a: get(a) for a in ARCHS}
