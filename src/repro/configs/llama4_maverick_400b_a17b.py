"""llama4-maverick-400b-a17b [moe] — 128 routed experts top-1 + shared
expert, MoE every other layer [hf:meta-llama/Llama-4 family]."""
from ..models import ModelConfig
import jax.numpy as jnp

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab=202_048, mlp_act="swiglu",
    n_experts=128, top_k=1, moe_every=2, shared_expert_ff=8192,
    # 400B params: fp32 Adam moments exceed v5e HBM at 256 chips -> bf16
    optimizer_dtype=jnp.bfloat16, param_dtype=jnp.bfloat16,
    microbatches=8,
)
