"""qwen3-8b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B]."""
from ..models import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=12288, vocab=151_936, mlp_act="swiglu", qk_norm=True,
)
