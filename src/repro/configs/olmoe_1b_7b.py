"""olmoe-1b-7b [moe] — 64 experts, top-8 routing [arXiv:2409.02060]."""
from ..models import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1024, vocab=50_304, mlp_act="swiglu",
    n_experts=64, top_k=8, moe_every=1,
)
