"""nemotron-4-15b [dense] — GQA, squared-ReLU MLP [arXiv:2402.16819]."""
from ..models import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b", family="dense",
    n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab=256_000, mlp_act="relu2",
)
