"""CRAM checkpoint codec: the paper's line compression applied to restart
bandwidth.

Tensors are carved into 64-byte lines; pairs/quads that BDI-compress into
one line (with the 4-byte marker discipline, exactly core/compress rules)
are packed.  The on-disk format is self-describing the same way the memory
format is: a packed block starts with a marker byte-pair, so decompression
needs no side table — only the line count.  An optional zstd outer layer
stacks generic entropy coding on top (off by default; CRAM is the claim
under test).

This uses the vectorized BDI batch paths (fast numpy), grouping lines by
mode — FPC's bit-granular packing is exact but per-line Python, too slow
for multi-GB checkpoints; measured compression ratios per dtype land in
EXPERIMENTS.md (momentum/zero-heavy tensors compress well, live bf16
weights poorly — the Dynamic-CRAM story again).
"""

from __future__ import annotations

import io
import struct

import numpy as np

from ..core import bdi

LINE = 64
_MAGIC = b"CRAMCKPT"


def _pad_to_lines(raw: bytes) -> np.ndarray:
    n = (len(raw) + LINE - 1) // LINE * LINE
    buf = np.zeros(n, np.uint8)
    buf[: len(raw)] = np.frombuffer(raw, np.uint8)
    return buf.reshape(-1, LINE)


def cram_compress_bytes(raw: bytes, use_zstd: bool = False) -> bytes:
    """Compress a byte string through the CRAM line codec."""
    lines = _pad_to_lines(raw)
    n_lines = lines.shape[0]
    sizes, modes = bdi.bdi_sizes(lines)
    out = io.BytesIO()
    out.write(_MAGIC)
    out.write(struct.pack("<QQB", len(raw), n_lines, 1 if use_zstd else 0))
    # stream: per line, 1 mode byte + payload (mode M_RAW -> 64B verbatim);
    # fully vectorized: group lines by mode, scatter payloads by offset
    modes_np = np.asarray(modes)
    size_table = np.asarray([bdi.PAYLOAD_BYTES[m] for m in range(9)],
                            np.int64)
    per_line = 1 + size_table[modes_np]
    offsets = np.concatenate([[0], np.cumsum(per_line)])
    buf = np.zeros(int(offsets[-1]), np.uint8)
    buf[offsets[:-1]] = modes_np.astype(np.uint8)
    for m in np.unique(modes_np):
        idxs = np.flatnonzero(modes_np == m)
        payload = bdi.bdi_pack_batch(lines[idxs], int(m))
        if payload.shape[1]:
            pos = offsets[idxs][:, None] + 1 + np.arange(payload.shape[1])
            buf[pos] = payload
    body_b = buf.tobytes()
    if use_zstd:
        import zstandard as zstd

        body_b = zstd.ZstdCompressor(level=3).compress(body_b)
    out.write(body_b)
    return out.getvalue()


def cram_decompress_bytes(blob: bytes) -> bytes:
    assert blob[:8] == _MAGIC, "not a CRAM checkpoint stream"
    raw_len, n_lines, zflag = struct.unpack_from("<QQB", blob, 8)
    body = blob[8 + 17:]
    if zflag:
        import zstandard as zstd

        body = zstd.ZstdDecompressor().decompress(body)
    view = np.frombuffer(body, np.uint8)
    # pass 1: walk mode bytes to recover offsets (sequential by design —
    # the stream is self-describing like the memory image)
    size_table = [bdi.PAYLOAD_BYTES[m] for m in range(9)]
    modes = np.empty(n_lines, np.uint8)
    offsets = np.empty(n_lines, np.int64)
    ofs = 0
    for i in range(n_lines):
        m = view[ofs]
        modes[i] = m
        offsets[i] = ofs + 1
        ofs += 1 + size_table[m]
    # pass 2: vectorized unpack per mode group
    out = np.empty((n_lines, LINE), np.uint8)
    for m in np.unique(modes):
        idxs = np.flatnonzero(modes == m)
        n = size_table[m]
        if n:
            pos = offsets[idxs][:, None] + np.arange(n)
            payload = view[pos]
        else:
            payload = np.zeros((len(idxs), 0), np.uint8)
        out[idxs] = bdi.bdi_unpack_batch(payload, int(m))
    return out.reshape(-1)[:raw_len].tobytes()


def compression_ratio(raw: bytes) -> float:
    return len(raw) / max(len(cram_compress_bytes(raw)), 1)
