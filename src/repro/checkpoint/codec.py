"""CRAM checkpoint codec: the paper's line compression applied to restart
bandwidth.

Tensors are carved into 64-byte lines and streamed through a *registered*
line codec (repro.compression.codecs): each line is stored in the codec's
self-describing format (BDI's 1-byte mode header, the hybrid codec's
algorithm header, FPC's self-terminating stream), so decompression needs no
side table — only the line count, exactly like the memory image.  An
optional zstd outer layer stacks generic entropy coding on top (off by
default; CRAM is the claim under test).

Every registered line codec packs through its vectorized batch path
(`Codec.pack_batch`: numpy batch over lines, byte-identical to the
per-line exact packers) — including the bit-granular FPC/hybrid streams —
so multi-GB checkpoints can use the better-ratio codecs; measured
compression ratios per dtype land in EXPERIMENTS.md (momentum/zero-heavy
tensors compress well, live bf16 weights poorly — the Dynamic-CRAM story
again).
"""

from __future__ import annotations

import io
import struct

import numpy as np

from ..compression import bdi
from ..compression.codecs import codec_names, get_codec
from ..compression.framing import LINE_BYTES as LINE

# v2 streams carry a codec-id byte in the header; v1 (pre-registry) blobs
# had no codec byte and are always BDI — still readable below.
_MAGIC = b"CRAMCKP2"
_MAGIC_V1 = b"CRAMCKPT"
# stream codec ids (stable on-disk values)
_CODEC_IDS = {"bdi": 0, "hybrid": 1, "fpc": 2, "raw": 3}
_CODEC_BY_ID = {v: k for k, v in _CODEC_IDS.items()}


def pad_to_lines(raw: bytes) -> np.ndarray:
    """(len,) bytes -> (N, 64) uint8 lines, zero-padded to a line multiple
    — THE line framing both the stored stream and the AutoTuner's codec
    probes use (probe on anything else and the choice is made on
    differently-framed data than what gets packed)."""
    n = (len(raw) + LINE - 1) // LINE * LINE
    buf = np.zeros(n, np.uint8)
    buf[: len(raw)] = np.frombuffer(raw, np.uint8)
    return buf.reshape(-1, LINE)


def _bdi_unpack_stream(view: np.ndarray, n_lines: int) -> np.ndarray:
    # pass 1: walk mode bytes to recover offsets (sequential by design —
    # the stream is self-describing like the memory image)
    size_table = [bdi.PAYLOAD_BYTES[m] for m in range(9)]
    modes = np.empty(n_lines, np.uint8)
    offsets = np.empty(n_lines, np.int64)
    ofs = 0
    for i in range(n_lines):
        m = view[ofs]
        modes[i] = m
        offsets[i] = ofs + 1
        ofs += 1 + size_table[m]
    # pass 2: vectorized unpack per mode group
    out = np.empty((n_lines, LINE), np.uint8)
    for m in np.unique(modes):
        idxs = np.flatnonzero(modes == m)
        n = size_table[m]
        if n:
            pos = offsets[idxs][:, None] + np.arange(n)
            payload = view[pos]
        else:
            payload = np.zeros((len(idxs), 0), np.uint8)
        out[idxs] = bdi.bdi_unpack_batch(payload, int(m))
    return out


def cram_compress_bytes(raw: bytes, use_zstd: bool = False,
                        codec: str = "bdi") -> bytes:
    """Compress a byte string through a registered CRAM line codec."""
    if codec not in _CODEC_IDS:
        raise ValueError(
            f"unknown checkpoint codec {codec!r}; valid: {sorted(_CODEC_IDS)}"
            f" (registered line codecs: {sorted(codec_names('line64'))})")
    lines = pad_to_lines(raw)
    n_lines = lines.shape[0]
    out = io.BytesIO()
    out.write(_MAGIC)
    out.write(struct.pack("<QQBB", len(raw), n_lines,
                          1 if use_zstd else 0, _CODEC_IDS[codec]))
    # every registered codec carries a vectorized exact pack stream (numpy
    # batch over lines, byte-identical to per-line pack_line joins), so
    # multi-GB checkpoints can use the better-ratio fpc/hybrid codecs too
    body_b = get_codec(codec).pack_batch(lines).tobytes()
    if use_zstd:
        import zstandard as zstd

        body_b = zstd.ZstdCompressor(level=3).compress(body_b)
    out.write(body_b)
    return out.getvalue()


def cram_decompress_bytes(blob: bytes) -> bytes:
    if blob[:8] == _MAGIC_V1:           # legacy header: no codec byte, BDI
        raw_len, n_lines, zflag = struct.unpack_from("<QQB", blob, 8)
        codec_id, body = _CODEC_IDS["bdi"], blob[8 + 17:]
    else:
        assert blob[:8] == _MAGIC, "not a CRAM checkpoint stream"
        raw_len, n_lines, zflag, codec_id = struct.unpack_from(
            "<QQBB", blob, 8)
        body = blob[8 + 18:]
    if zflag:
        import zstandard as zstd

        body = zstd.ZstdDecompressor().decompress(body)
    codec = _CODEC_BY_ID[codec_id]
    if codec == "bdi":
        out = _bdi_unpack_stream(np.frombuffer(body, np.uint8), n_lines)
    else:
        unpack_line = get_codec(codec).unpack_line
        out = np.empty((n_lines, LINE), np.uint8)
        ofs = 0
        for i in range(n_lines):
            out[i], ofs = unpack_line(body, ofs)
    return out.reshape(-1)[:raw_len].tobytes()


def compression_ratio(raw: bytes, codec: str = "bdi") -> float:
    return len(raw) / max(len(cram_compress_bytes(raw, codec=codec)), 1)
