"""Sharded, resharding-on-restore, async checkpointing.

Layout: <dir>/step_<n>/
    manifest.msgpack   — tree structure, shapes, dtypes, codec, checksums
    <leaf-id>.bin      — raw or CRAM-compressed little-endian bytes

Restore never assumes the saving mesh: arrays are written as full logical
tensors (gathered per leaf) and re-sharded by the caller's in_shardings on
load — that is what makes elastic restarts (different device count) work.
For multi-host production this becomes one shard-file per host with the
same manifest; the single-process container exercises the full-logical
path.  Writes go to a temp dir + atomic rename; a background thread makes
them async; `latest_step` only trusts directories with a COMMIT stamp.
"""

from __future__ import annotations

import hashlib
import os
import shutil
import threading
from pathlib import Path

import jax
import msgpack
import numpy as np

from ..bandwidth import AutoTuner, Ledger
from ..bandwidth.adapters import (
    checkpoint_leaf_event,
    checkpoint_restore_event,
)
from .codec import cram_compress_bytes, cram_decompress_bytes, pad_to_lines


def _leaves_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key or "root", leaf))
    return out, treedef


def _line_codec_of(codec: str) -> str:
    """'cram' -> 'bdi' (the historical default), 'cram:<name>' -> name."""
    return codec.split(":", 1)[1] if ":" in codec else "bdi"


def save_checkpoint(directory, step: int, tree, *, codec: str = "cram",
                    blocking: bool = True, ledger: Ledger | None = None,
                    tuner: AutoTuner | None = None) -> Path:
    """codec: 'raw' | 'cram[:line-codec][+zstd]' | 'auto'.

    'cram' streams every leaf through one registered line codec (default
    bdi; 'cram:fpc' / 'cram:hybrid' pick another).  'auto' lets the
    bandwidth AutoTuner pick the line codec PER LEAF from a sample of its
    64-byte lines (raw when nothing beats raw — the no-slowdown rule);
    each blob is self-describing, so restore needs no policy knowledge.

    Byte accounting goes through the bandwidth ledger: manifest
    raw/stored entries are read back from the ledger booking, and the
    save's traffic view is embedded as manifest["traffic"].  Pass a shared
    `ledger` to fold this save into a launcher-wide accounting.
    """
    directory = Path(directory)
    final = directory / f"step_{step:08d}"
    tmp = directory / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves, _ = _leaves_with_paths(tree)
    local = Ledger("checkpoint")
    auto = codec == "auto"
    if auto and tuner is None:
        tuner = AutoTuner()
    zstd = codec.endswith("+zstd")
    base = codec[: -len("+zstd")] if zstd else codec
    manifest = {"step": step,
                "codec": "cram:auto" if auto else codec, "leaves": []}
    for i, (key, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        raw = arr.tobytes()
        if auto:
            choice = tuner.choose_ckpt_codec(pad_to_lines(raw),
                                             tensor_class=key)
            leaf_codec = choice.choice
            # the raw fallback stores the PLAIN blob — auto must never
            # cost more than the static raw writer, not even the CRAM
            # stream's header + line padding
            blob = (raw if leaf_codec == "raw"
                    else cram_compress_bytes(raw, codec=leaf_codec))
            if len(blob) >= len(raw):
                # hard per-leaf no-slowdown: the codec won on sampled line
                # sizes but the stream framing ate the win (tiny leaves)
                leaf_codec, blob = "raw", raw
        elif base.startswith("cram"):
            leaf_codec = _line_codec_of(base)
            blob = cram_compress_bytes(raw, use_zstd=zstd, codec=leaf_codec)
        else:
            leaf_codec = "raw"
            blob = raw
        framed = blob is not raw
        fname = f"leaf_{i:05d}.bin"
        (tmp / fname).write_bytes(blob)
        raw_n, stored_n = checkpoint_leaf_event(
            local, key=key, raw_len=len(raw), stored_len=len(blob),
            dtype=arr.dtype)
        manifest["leaves"].append({
            "key": key, "file": fname, "shape": list(arr.shape),
            "dtype": str(arr.dtype), "raw_bytes": raw_n,
            "stored_bytes": stored_n, "codec": leaf_codec,
            "framed": framed,
            "sha1": hashlib.sha1(blob).hexdigest(),
        })
    manifest["traffic"] = local.as_dict()
    if ledger is not None:
        ledger.merge(local)
    (tmp / "manifest.msgpack").write_bytes(msgpack.packb(manifest))
    (tmp / "COMMIT").write_text("ok")
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def load_checkpoint(directory, step: int | None, tree_like, *,
                    ledger: Ledger | None = None):
    """Restore into the structure of `tree_like` (shapes must match).
    A `ledger` books the restore read traffic (raw vs stored bytes)."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {directory}")
    d = directory / f"step_{step:08d}"
    manifest = msgpack.unpackb((d / "manifest.msgpack").read_bytes())
    by_key = {m["key"]: m for m in manifest["leaves"]}
    leaves, treedef = _leaves_with_paths(tree_like)
    out = []
    for key, leaf in leaves:
        m = by_key[key]
        blob = (d / m["file"]).read_bytes()
        assert hashlib.sha1(blob).hexdigest() == m["sha1"], \
            f"checksum mismatch for {key}"
        # per-leaf framed flag (auto stores raw-fallback leaves plain);
        # pre-flag manifests decide by the checkpoint-wide codec string
        framed = m.get("framed", manifest["codec"].startswith("cram"))
        raw = cram_decompress_bytes(blob) if framed else blob
        if ledger is not None:
            checkpoint_restore_event(ledger, key=key, raw_len=len(raw),
                                     stored_len=len(blob), dtype=m["dtype"])
        arr = np.frombuffer(raw, dtype=np.dtype(m["dtype"])).reshape(
            m["shape"]).copy()
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out), manifest


def latest_step(directory) -> int | None:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in directory.glob("step_*")
             if (p / "COMMIT").exists()]
    return max(steps) if steps else None


class CheckpointManager:
    """Async writer with bounded retention."""

    def __init__(self, directory, *, keep: int = 3, codec: str = "cram",
                 ledger: Ledger | None = None,
                 tuner: AutoTuner | None = None):
        self.directory = Path(directory)
        self.keep = keep
        self.codec = codec
        self.ledger = ledger if ledger is not None else Ledger("checkpoint")
        self.tuner = tuner
        self._thread: threading.Thread | None = None

    def save_async(self, step: int, tree) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def work():
            save_checkpoint(self.directory, step, host_tree,
                            codec=self.codec, ledger=self.ledger,
                            tuner=self.tuner)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_latest(self, tree_like):
        self.wait()
        return load_checkpoint(self.directory, None, tree_like)

    def _gc(self) -> None:
        steps = sorted(int(p.name.split("_")[1])
                       for p in self.directory.glob("step_*")
                       if (p / "COMMIT").exists())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.directory / f"step_{s:08d}",
                          ignore_errors=True)
