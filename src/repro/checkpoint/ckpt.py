"""Sharded, resharding-on-restore, async checkpointing.

Layout: <dir>/step_<n>/
    manifest.msgpack   — tree structure, shapes, dtypes, codec, checksums
    <leaf-id>.bin      — raw or CRAM-compressed little-endian bytes

Restore never assumes the saving mesh: arrays are written as full logical
tensors (gathered per leaf) and re-sharded by the caller's in_shardings on
load — that is what makes elastic restarts (different device count) work.
For multi-host production this becomes one shard-file per host with the
same manifest; the single-process container exercises the full-logical
path.  Writes go to a temp dir + atomic rename; a background thread makes
them async; `latest_step` only trusts directories with a COMMIT stamp.
"""

from __future__ import annotations

import hashlib
import os
import shutil
import threading
from pathlib import Path

import jax
import msgpack
import numpy as np

from .codec import cram_compress_bytes, cram_decompress_bytes


def _leaves_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key or "root", leaf))
    return out, treedef


def save_checkpoint(directory, step: int, tree, *, codec: str = "cram",
                    blocking: bool = True) -> Path:
    """codec: 'raw' | 'cram' | 'cram+zstd'."""
    directory = Path(directory)
    final = directory / f"step_{step:08d}"
    tmp = directory / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves, _ = _leaves_with_paths(tree)
    manifest = {"step": step, "codec": codec, "leaves": []}
    for i, (key, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        raw = arr.tobytes()
        if codec.startswith("cram"):
            blob = cram_compress_bytes(raw, use_zstd=codec.endswith("zstd"))
        else:
            blob = raw
        fname = f"leaf_{i:05d}.bin"
        (tmp / fname).write_bytes(blob)
        manifest["leaves"].append({
            "key": key, "file": fname, "shape": list(arr.shape),
            "dtype": str(arr.dtype), "raw_bytes": len(raw),
            "stored_bytes": len(blob),
            "sha1": hashlib.sha1(blob).hexdigest(),
        })
    (tmp / "manifest.msgpack").write_bytes(msgpack.packb(manifest))
    (tmp / "COMMIT").write_text("ok")
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def load_checkpoint(directory, step: int | None, tree_like):
    """Restore into the structure of `tree_like` (shapes must match)."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {directory}")
    d = directory / f"step_{step:08d}"
    manifest = msgpack.unpackb((d / "manifest.msgpack").read_bytes())
    by_key = {m["key"]: m for m in manifest["leaves"]}
    leaves, treedef = _leaves_with_paths(tree_like)
    out = []
    for key, leaf in leaves:
        m = by_key[key]
        blob = (d / m["file"]).read_bytes()
        assert hashlib.sha1(blob).hexdigest() == m["sha1"], \
            f"checksum mismatch for {key}"
        raw = (cram_decompress_bytes(blob)
               if manifest["codec"].startswith("cram") else blob)
        arr = np.frombuffer(raw, dtype=np.dtype(m["dtype"])).reshape(
            m["shape"]).copy()
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out), manifest


def latest_step(directory) -> int | None:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in directory.glob("step_*")
             if (p / "COMMIT").exists()]
    return max(steps) if steps else None


class CheckpointManager:
    """Async writer with bounded retention."""

    def __init__(self, directory, *, keep: int = 3, codec: str = "cram"):
        self.directory = Path(directory)
        self.keep = keep
        self.codec = codec
        self._thread: threading.Thread | None = None

    def save_async(self, step: int, tree) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def work():
            save_checkpoint(self.directory, step, host_tree,
                            codec=self.codec)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_latest(self, tree_like):
        self.wait()
        return load_checkpoint(self.directory, None, tree_like)

    def _gc(self) -> None:
        steps = sorted(int(p.name.split("_")[1])
                       for p in self.directory.glob("step_*")
                       if (p / "COMMIT").exists())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.directory / f"step_{s:08d}",
                          ignore_errors=True)
