"""Sharded checkpointing with the CRAM line codec."""

from .codec import cram_compress_bytes, cram_decompress_bytes
from .ckpt import CheckpointManager, load_checkpoint, save_checkpoint

__all__ = [
    "CheckpointManager", "save_checkpoint", "load_checkpoint",
    "cram_compress_bytes", "cram_decompress_bytes",
]
