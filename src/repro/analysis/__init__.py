"""repro.analysis: the repo-invariant enforcement layer (DESIGN.md §11).

Two levels:

  * Level 1 — an AST-based rule engine (`analysis.rules`) that lints the
    tree for CRAM's domain invariants: marker literals stay in
    `compression/framing.py` (R1), codec implementations stay behind the
    `compression` registry (R2), hot paths stay host-sync free (R3),
    seeding stays process-stable (R4), every tier crossing books a ledger
    event (R5), kernel wrappers never swallow errors or promote dtypes
    (R6).  Each rule is a plugin in a small registry; fixtures under
    `tests/fixtures/analysis/` prove each one fires.

  * Level 2 — `analysis.jaxpr_audit`: traces the REAL hot entry points
    (engine chunk, fused decode, pack window, serve-loop inner jits,
    checkpoint pack) to jaxprs and pins what the wall-clock benches only
    see on hardware: zero host callbacks, no float64 promotion, donation
    taking effect, and an exact `pallas_call` budget — golden-tested
    against `tests/golden/jaxpr_audit.json`.

CLI: `python -m repro.analysis [--report json] [--jaxpr] [paths...]` —
exit 0 clean, non-zero on any violation.  `benchmarks/run.py --analyze`
wraps the same entry point.
"""

from .engine import Violation, analyze, default_paths, render_report

__all__ = ["Violation", "analyze", "default_paths", "render_report"]
