"""R1 — marker constants are defined once, in compression/framing.py.

The in-band marker discipline (DESIGN.md §3) only works if every consumer
derives markers from THE same key and PRF multipliers; a re-typed literal
that drifts from framing's value silently desynchronizes the packers from
the decoders (the marker-aliasing bug class Pekhimenko's thesis catalogs).
The protected set is derived from framing.py itself: every int literal in
it that is large enough to be a key/multiplier and is not a plain mask or
power of two.  Any of those values appearing as a literal in another
module is a violation — import the named constant instead.
"""

from __future__ import annotations

import ast
import functools

from .base import Rule, int_constants, register

_EXEMPT_SUFFIX = "compression/framing.py"
_MIN_PROTECTED = 0x1000     # sizes, shifts and small masks live below this


def _is_mask_like(v: int) -> bool:
    """Powers of two and all-ones masks are generic bit twiddling, not
    marker material."""
    return v <= 0 or (v & (v - 1)) == 0 or (v & (v + 1)) == 0


@functools.lru_cache(maxsize=1)
def protected_constants() -> frozenset[int]:
    import inspect

    from ...compression import framing

    tree = ast.parse(inspect.getsource(framing))
    return frozenset(v for v, _ in int_constants(tree)
                     if v >= _MIN_PROTECTED and not _is_mask_like(v))


@register
class MarkerLiterals(Rule):
    name = "r1"
    title = ("no raw marker-word literals outside compression/framing.py "
             "(import the named constant)")

    def check(self, ctx):
        if ctx.rel.endswith(_EXEMPT_SUFFIX):
            return []
        protected = protected_constants()
        out = []
        for value, node in int_constants(ctx.tree):
            if value in protected:
                out.append(ctx.violation(
                    node, self.name,
                    f"marker constant {value:#x} hardcoded; import it "
                    "from repro.compression.framing"))
        return out
