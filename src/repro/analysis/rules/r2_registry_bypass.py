"""R2 — codec/layout implementations stay behind the compression registry.

PR 4 collapsed four private pack/unpack implementations into the single
`compression` registry; this rule keeps them collapsed.  Outside the
registry surface (compression/ itself, the core/ legacy shims, and
kernels/ — the registry's device backends), a module may consume codecs
only through the public API (`get_codec`, `get_layout`, framing, marker,
gate, predictor).  Violations:

  * importing a codec implementation module (fpc/bdi/hybrid/pagepack/bits)
    — except at the three sanctioned integration points, where the
    registry intentionally exposes batch/page helpers;
  * defining a function with a codec-implementation signature name
    (pack_pair, unpack_quad, pack_batch, compressed_sizes, ...);
  * calling np.packbits/np.unpackbits (bit-level packing is codec work).
"""

from __future__ import annotations

import ast

from .base import Rule, call_name, register, walk_functions

IMPL_MODULES = frozenset({"fpc", "bdi", "hybrid", "pagepack", "bits"})

# the registry surface: implementations and their sanctioned re-exports
SURFACE = ("repro/compression/", "repro/core/", "repro/kernels/")

# sanctioned integration points: (rel-path suffix, impl module).  These
# consume REGISTRY implementations (batch unpack, page helpers) that the
# Codec records don't carry; adding a pair here is a reviewed decision.
SANCTIONED = frozenset({
    ("repro/serving/spill.py", "pagepack"),
    ("repro/checkpoint/codec.py", "bdi"),
})

IMPL_DEF_NAMES = frozenset({
    "pack_pair", "unpack_pair", "pack_quad", "unpack_quad",
    "pack_line", "unpack_line", "pack_batch", "unpack_batch",
    "compressed_sizes", "fpc_size_bits", "bdi_sizes", "classify_line",
})


def _on_surface(rel: str) -> bool:
    return any(s in rel for s in SURFACE)


def _imported_impls(tree: ast.Module):
    """Yield (impl_name, node) for every codec-impl module import."""
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            base = mod.split(".")[-1]
            if base == "compression":
                for alias in node.names:
                    if alias.name in IMPL_MODULES:
                        yield alias.name, node
            elif "compression." in mod + "." and base in IMPL_MODULES:
                yield base, node
        elif isinstance(node, ast.Import):
            for alias in node.names:
                parts = alias.name.split(".")
                if "compression" in parts and parts[-1] in IMPL_MODULES:
                    yield parts[-1], node


@register
class RegistryBypass(Rule):
    name = "r2"
    title = ("no codec/layout pack-unpack implementations or imports "
             "bypassing the compression registry")

    def check(self, ctx):
        if _on_surface(ctx.rel):
            return []
        out = []
        for impl, node in _imported_impls(ctx.tree):
            if any(ctx.rel.endswith(p) and impl == m
                   for p, m in SANCTIONED):
                continue
            out.append(ctx.violation(
                node, self.name,
                f"imports compression implementation module '{impl}'; "
                "consume it through the registry (get_codec/get_layout) "
                "or sanction the integration point in rule r2"))
        for fn, qual in walk_functions(ctx.tree):
            if fn.name in IMPL_DEF_NAMES:
                out.append(ctx.violation(
                    fn, self.name,
                    f"defines codec-implementation function '{qual}' "
                    "outside the compression registry"))
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call) and call_name(node).endswith(
                    ("packbits", "unpackbits")):
                out.append(ctx.violation(
                    node, self.name,
                    "bit-level packbits/unpackbits outside the registry — "
                    "codec byte layouts live in compression/"))
        return out
