"""R3 — hot paths stay device-resident: no host syncs in jit bodies or
the serving hot methods.

PR 7 made an N-step serve run cost O(1) host ledger records; one stray
`.item()` or `np.asarray(traced)` re-serializes the device every step and
the CPU-interpret benches never notice (the regression only shows on real
hardware).  Two scopes, different strictness:

  * jit-decorated functions (the body is traced): any host
    materialization is at best a silent constant-fold, at worst a tracer
    leak — flag `.item()`, `float()/int()` on expressions, `np.asarray`/
    `np.array`, `jax.device_get`, `block_until_ready`, and ledger
    record/absorb calls;
  * hot-NAMED methods (`step`, `step_all`, `attend`, `repack`,
    `account_step`, ...) are host orchestrators — np conversions of HOST
    state are legitimate there, but blocking syncs and per-step ledger
    booking are not: flag `.item()`, `block_until_ready`, and ledger
    record/absorb.
"""

from __future__ import annotations

import ast

from .base import Rule, call_name, is_jit_decorated, register, walk_functions

HOT_NAMES = frozenset({
    "step", "step_all", "attend", "repack", "account_step",
    "append_active", "_absorb_step", "megastep", "prefill",
    "prefill_slot", "_prefill",
})

_JIT_FORBIDDEN_CALLS = frozenset({
    "np.asarray", "np.array", "np.ascontiguousarray", "numpy.asarray",
    "numpy.array", "jax.device_get",
})


def _is_ledger_call(call: ast.Call) -> bool:
    name = call_name(call)
    head, _, tail = name.rpartition(".")
    return tail in ("record", "absorb") and "ledger" in head.lower()


def _scan_body(fn: ast.FunctionDef, ctx, rule, *, in_jit: bool):
    out = []
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        name = call_name(node)
        if name.endswith(".item") and not node.args:
            out.append(ctx.violation(
                node, rule, "'.item()' host sync inside hot path "
                f"'{fn.name}'"))
        elif name.endswith("block_until_ready"):
            out.append(ctx.violation(
                node, rule, "'block_until_ready' inside hot path "
                f"'{fn.name}' — sync at the window boundary instead"))
        elif _is_ledger_call(node):
            out.append(ctx.violation(
                node, rule, "per-step ledger booking inside hot path "
                f"'{fn.name}' — use the device accumulator and fold at "
                "the report boundary"))
        elif in_jit and name in _JIT_FORBIDDEN_CALLS:
            out.append(ctx.violation(
                node, rule, f"'{name}' on traced values inside "
                f"jit-compiled '{fn.name}'"))
        elif in_jit and name in ("float", "int") and node.args and not \
                isinstance(node.args[0], ast.Constant):
            out.append(ctx.violation(
                node, rule, f"'{name}()' materializes a traced value "
                f"inside jit-compiled '{fn.name}'"))
    return out


@register
class HostSyncInHotPath(Rule):
    name = "r3"
    title = ("no Ledger.record/host-sync calls (.item, np.asarray, "
             "block_until_ready) inside jit or step/attend/repack hot "
             "paths")

    def check(self, ctx):
        out = []
        for fn, _qual in walk_functions(ctx.tree):
            if is_jit_decorated(fn):
                out.extend(_scan_body(fn, ctx, self.name, in_jit=True))
            elif fn.name in HOT_NAMES:
                out.extend(_scan_body(fn, ctx, self.name, in_jit=False))
        return out
