"""The rule registry: one plugin per domain invariant.

Adding a rule = writing a module with a `@register`-decorated subclass of
`rules.base.Rule` and importing it here.  `get_rules(None)` returns every
registered rule; `get_rules(["r1", "r3"])` a subset by name.
"""

from __future__ import annotations

from .base import RULES, Rule, register

# importing a rule module registers its rule (order fixes report order)
from . import r1_marker_literals    # noqa: E402,F401
from . import r2_registry_bypass    # noqa: E402,F401
from . import r3_host_sync          # noqa: E402,F401
from . import r4_seeding            # noqa: E402,F401
from . import r5_ledger_coverage    # noqa: E402,F401
from . import r6_kernel_hygiene     # noqa: E402,F401


def get_rules(names=None) -> list[Rule]:
    if names is None:
        return list(RULES.values())
    unknown = [n for n in names if n not in RULES]
    if unknown:
        raise KeyError(f"unknown rules {unknown}; have {sorted(RULES)}")
    return [RULES[n] for n in names]


__all__ = ["Rule", "RULES", "register", "get_rules"]
