"""R5 — every function that emits bytes across a tier books a ledger
event, and all byte math lives in bandwidth/.

The 40%-metadata-overhead trap the paper exists to avoid: a byte that
moves but is never charged makes compression look free.  Two checks:

  * (a) accounting stays centralized — outside `bandwidth/`, nobody calls
    `<ledger>.record/.absorb` or the device accumulator primitive
    directly; consumers go through the adapter functions
    (`bandwidth/adapters.py`, "the only place consumer byte math lives");
  * (b) call-graph coverage — in any module that imports from
    bandwidth.adapters, every tier-crossing function (name contains an
    emitter verb: evict/restore/spill/save/load) must transitively reach
    an imported adapter call.  A spill path that forgets its
    `kv_spill_event` fails here, not in a benchmark six PRs later.
"""

from __future__ import annotations

import ast

from .base import Rule, call_name, register, walk_functions

EMITTER_VERBS = frozenset({"evict", "restore", "spill", "save", "load"})


def _is_ledger_call(call: ast.Call) -> bool:
    name = call_name(call)
    head, _, tail = name.rpartition(".")
    return tail in ("record", "absorb") and "ledger" in head.lower()


def _adapter_imports(tree: ast.Module) -> set[str]:
    """Names imported from bandwidth.adapters (module- or function-level)."""
    names: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and \
                (node.module or "").endswith("adapters"):
            names.update(a.asname or a.name for a in node.names)
    return names


def _is_emitter(name: str) -> bool:
    return not name.startswith("__") and \
        bool(EMITTER_VERBS & set(name.lower().split("_")))


@register
class LedgerCoverage(Rule):
    name = "r5"
    title = ("every tier-crossing emitter books a ledger event via a "
             "bandwidth/adapters call; byte math never leaves bandwidth/")

    def check(self, ctx):
        in_bandwidth = "repro/bandwidth/" in ctx.rel
        out = []
        if not in_bandwidth:
            for node in ast.walk(ctx.tree):
                if isinstance(node, ast.Call) and _is_ledger_call(node):
                    out.append(ctx.violation(
                        node, self.name,
                        f"direct ledger '{call_name(node)}' outside "
                        "bandwidth/ — book through a bandwidth.adapters "
                        "function"))
                elif isinstance(node, ast.Call) and \
                        call_name(node).endswith("device_record"):
                    out.append(ctx.violation(
                        node, self.name,
                        "device_record outside bandwidth/ — the device "
                        "byte model belongs in bandwidth/adapters"))

        # (b) call-graph coverage over adapter consumers (src tree only —
        # benchmarks orchestrate, they don't own tier crossings)
        if in_bandwidth or "repro/" not in ctx.rel:
            return out
        adapters = _adapter_imports(ctx.tree)
        if not adapters:
            return out
        funcs = dict(walk_functions(ctx.tree))   # node -> qualname
        by_last: dict[str, list[ast.FunctionDef]] = {}
        for node, qual in funcs.items():
            by_last.setdefault(qual.rsplit(".", 1)[-1], []).append(node)

        def calls_in(fn: ast.FunctionDef) -> set[str]:
            return {call_name(n).rsplit(".", 1)[-1]
                    for n in ast.walk(fn) if isinstance(n, ast.Call)}

        def reaches_adapter(fn: ast.FunctionDef, seen: set[int]) -> bool:
            if id(fn) in seen:
                return False
            seen.add(id(fn))
            called = calls_in(fn)
            if called & adapters:
                return True
            return any(reaches_adapter(target, seen)
                       for name in called
                       for target in by_last.get(name, ()))

        for fn, qual in funcs.items():
            if _is_emitter(fn.name) and not reaches_adapter(fn, set()):
                out.append(ctx.violation(
                    fn, self.name,
                    f"tier-crossing '{qual}' never reaches a "
                    f"bandwidth.adapters booking ({sorted(adapters)}) — "
                    "bytes would move unledgered"))
        return out
