"""Rule base class + the AST helpers every rule shares."""

from __future__ import annotations

import ast

RULES: dict[str, "Rule"] = {}


class Rule:
    """One domain invariant.  Subclasses set `name` (r1..r6), `title`
    (one line, lands in the report), and implement `check(ctx)`."""

    name: str = ""
    title: str = ""

    def check(self, ctx) -> list:
        raise NotImplementedError


def register(cls):
    inst = cls()
    assert inst.name and inst.name not in RULES, inst.name
    RULES[inst.name] = inst
    return cls


# ---------------------------------------------------------------- AST helpers


def dotted_name(node: ast.AST) -> str:
    """Best-effort dotted name of an expression ('np.asarray',
    'self.ledger.record', '' when not a plain attribute chain)."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def call_name(call: ast.Call) -> str:
    return dotted_name(call.func)


def walk_functions(tree: ast.Module):
    """Yield (node, qualname) for every function/method, with class
    prefixes ('SlotKVCache.repack')."""

    def visit(node, prefix):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = f"{prefix}{child.name}"
                yield child, q
                yield from visit(child, f"{q}.")
            elif isinstance(child, ast.ClassDef):
                yield from visit(child, f"{prefix}{child.name}.")
            else:
                yield from visit(child, prefix)

    yield from visit(tree, "")


def is_jit_decorated(fn: ast.FunctionDef) -> bool:
    """True when any decorator mentions `jit` — catches jax.jit, bare jit,
    and functools.partial(jax.jit, ...) forms."""
    for dec in fn.decorator_list:
        for node in ast.walk(dec):
            if isinstance(node, ast.Attribute) and node.attr == "jit":
                return True
            if isinstance(node, ast.Name) and node.id == "jit":
                return True
    return False


def int_constants(tree: ast.AST):
    """Yield (value, node) for every int literal (bools excluded)."""
    for node in ast.walk(tree):
        if (isinstance(node, ast.Constant) and isinstance(node.value, int)
                and not isinstance(node.value, bool)):
            yield node.value, node
