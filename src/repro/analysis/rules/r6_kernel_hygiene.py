"""R6 — kernel wrappers never swallow errors or promote dtypes.

A silent `except: pass` around a pallas_call turns a mis-tiled kernel
into wrong numbers; an accidental float64 promotion (python `float`
dtype, `np.float64`) doubles the DMA bytes the whole byte model charges
for — and TPUs don't even have f64, so the bug only reproduces on the
interpret path.  Scope: `kernels/` (wrappers and device code).
"""

from __future__ import annotations

import ast

from .base import Rule, call_name, register


def _is_silent_handler(h: ast.ExceptHandler) -> bool:
    return all(isinstance(s, ast.Pass)
               or (isinstance(s, ast.Expr)
                   and isinstance(s.value, ast.Constant))
               for s in h.body)


@register
class KernelHygiene(Rule):
    name = "r6"
    title = "no silent except / float64 dtype promotion in kernel wrappers"

    def check(self, ctx):
        if "repro/kernels/" not in ctx.rel:
            return []
        out = []
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ExceptHandler):
                if node.type is None:
                    out.append(ctx.violation(
                        node, self.name,
                        "bare 'except:' in a kernel wrapper"))
                elif _is_silent_handler(node):
                    out.append(ctx.violation(
                        node, self.name,
                        "silent exception handler in a kernel wrapper — "
                        "a swallowed kernel error is wrong numbers"))
            elif isinstance(node, ast.Attribute) and node.attr == "float64":
                out.append(ctx.violation(
                    node, self.name,
                    "float64 in kernel code — doubles DMA bytes and has "
                    "no TPU lowering"))
            elif isinstance(node, ast.Call):
                name = call_name(node)
                promotes = (
                    name.endswith(".astype") and node.args
                    and isinstance(node.args[0], ast.Name)
                    and node.args[0].id == "float")
                promotes |= any(
                    kw.arg == "dtype" and isinstance(kw.value, ast.Name)
                    and kw.value.id == "float" for kw in node.keywords)
                if promotes:
                    out.append(ctx.violation(
                        node, self.name,
                        "python 'float' dtype promotes to float64 in "
                        "kernel code"))
        return out
