"""R4 — no process-salted or global-state seeding (the PR-2 bug class).

The nondeterministic-trace bug: builtin `hash()` is salted per process
(PYTHONHASHSEED), so seeding anything from it makes runs unreproducible —
PR 2 replaced it with crc32.  Global `np.random.seed`/`random.seed`
mutate process state behind every other consumer's back; the repo's
convention is explicit `np.random.default_rng(seed)` generators.
"""

from __future__ import annotations

import ast

from .base import Rule, call_name, register


@register
class SaltedSeeding(Rule):
    name = "r4"
    title = "no hash()/process-salted or global-state seeding"

    def check(self, ctx):
        out = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            name = call_name(node)
            if name == "hash":
                out.append(ctx.violation(
                    node, self.name,
                    "builtin hash() is salted per process "
                    "(PYTHONHASHSEED) — derive seeds with zlib.crc32 or "
                    "np.random.default_rng"))
            elif name in ("np.random.seed", "numpy.random.seed",
                          "random.seed"):
                out.append(ctx.violation(
                    node, self.name,
                    f"global-state seeding '{name}' — pass an explicit "
                    "np.random.default_rng(seed) generator instead"))
        return out
