"""Level 2: trace the REAL hot entry points to jaxprs and pin what the
interpret-mode benches can't see.

Every entry below is the actual production function (not a test double):
the engine's jitted chunk scan, the fused decode-on-compressed kernel in
its three deployment shapes, the incremental pack window, the serve
tier's donated scatters, the fused serve megastep (append + repack +
booking as one donated dispatch), and the KV cache's device-side booking
jits.
For each, the audit statically asserts:

  * zero `pure_callback`/`io_callback`/`debug_callback` primitives — a
    host callback inside a hot jaxpr is a per-step device->host round
    trip that CPU wall-clock numbers hide;
  * no float64 anywhere in the jaxpr — f64 doubles every DMA the byte
    model charges for and has no TPU lowering;
  * donation taking effect where configured — checked on the lowered
    StableHLO (`tf.aliasing_output`), because a silently-dropped donation
    doubles peak HBM for the KV buffers;
  * a pinned primitive-count budget (exactly ONE `pallas_call` for each
    fused-decode shape; structural `scan`/`while`/`cond` counts) — a
    refactor that splits the fused kernel or sneaks in a host loop moves
    these counts and fails against `tests/golden/jaxpr_audit.json`.

The checkpoint `pack_batch` path is audited for the inverse property: it
is host-resident BY DESIGN (cold path, vectorized numpy), so it must
create zero jax arrays and return numpy.

Regenerate the golden after an intentional kernel change with
`python -m repro.analysis --jaxpr --update-golden`.
"""

from __future__ import annotations

import json
from collections import Counter
from pathlib import Path

import numpy as np

from .engine import REPO_ROOT

GOLDEN_PATH = REPO_ROOT / "tests" / "golden" / "jaxpr_audit.json"

CALLBACK_PRIMITIVES = ("pure_callback", "io_callback", "debug_callback")
PINNED_PRIMITIVES = CALLBACK_PRIMITIVES + ("pallas_call", "scan", "while",
                                           "cond")


def _walk(jaxpr, counts: Counter) -> Counter:
    """Recursive primitive histogram (descends into closed sub-jaxprs)."""
    import jax

    for eqn in jaxpr.eqns:
        counts[eqn.primitive.name] += 1
        for v in eqn.params.values():
            for item in (v if isinstance(v, (tuple, list)) else (v,)):
                if isinstance(item, jax.core.ClosedJaxpr):
                    _walk(item.jaxpr, counts)
                elif isinstance(item, jax.core.Jaxpr):
                    _walk(item, counts)
    return counts


def _dtypes(jaxpr, acc: set) -> set:
    import jax

    for v in list(jaxpr.invars) + list(jaxpr.outvars) + list(
            jaxpr.constvars):
        if hasattr(v.aval, "dtype"):
            acc.add(str(v.aval.dtype))
    for eqn in jaxpr.eqns:
        for v in list(eqn.invars) + list(eqn.outvars):
            if hasattr(v.aval, "dtype"):
                acc.add(str(v.aval.dtype))
        for p in eqn.params.values():
            for item in (p if isinstance(p, (tuple, list)) else (p,)):
                if isinstance(item, jax.core.ClosedJaxpr):
                    _dtypes(item.jaxpr, acc)
                elif isinstance(item, jax.core.Jaxpr):
                    _dtypes(item, acc)
    return acc


def _traced_entry(fn, *args, donated_fn=None, donate_args=None,
                  donate_kwargs=None, **kwargs) -> dict:
    """Trace fn(*args, **kwargs); optionally check donation on
    `donated_fn` (a jitted callable lowered with `donate_args` /
    `donate_kwargs` — the latter carries static_argnames)."""
    import jax

    closed = jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args)
    counts = _walk(closed.jaxpr, Counter())
    dts = sorted(_dtypes(closed.jaxpr, set()))
    donation = None
    if donated_fn is not None:
        text = donated_fn.lower(*(donate_args or args),
                                **(donate_kwargs or {})).as_text()
        donation = "tf.aliasing_output" in text
    return {
        "pinned": {p: int(counts.get(p, 0)) for p in PINNED_PRIMITIVES},
        "f64": any("float64" in d for d in dts),
        "donation": donation,
        "info": {"eqns": int(sum(counts.values())), "dtypes": dts,
                 "primitives": {k: int(v) for k, v in sorted(
                     counts.items())}},
    }


# --------------------------------------------------------------- the entries


def _entry_engine_chunk() -> dict:
    """core/engine step: one jitted chunk scan of the cram scheme."""
    import jax.numpy as jnp

    from ..core import schemes as schemes_registry
    from ..core.memsim import SimConfig, _jit_sim_chunked
    from ..core.traces import build_workload

    sch = schemes_registry.resolve("cram")
    init, chunk = _jit_sim_chunked(sch, SimConfig())
    _spec, addrs, wr, pa, pc, qd, _f = build_workload("libq", 256)
    carry = init()
    args = (carry, jnp.asarray(addrs[:64], jnp.int32),
            jnp.asarray(wr[:64]), jnp.asarray(pa), jnp.asarray(pc),
            jnp.asarray(qd))
    return _traced_entry(chunk, *args)


def _fused_decode(lanes: int, batched: bool) -> dict:
    import jax.numpy as jnp

    from ..kernels import ops as kops

    rng = np.random.default_rng(0)
    pages = jnp.asarray(rng.integers(-4, 4, (4, 8, 1, 64)), jnp.int16)
    build = (kops.build_cram_cache if lanes == 2
             else kops.build_cram_cache_quad)
    cache = build(pages, interpret=True)
    if batched:
        cache = {k: (jnp.stack([v, v]) if k != "markers" else v)
                 for k, v in cache.items()}
        q = jnp.zeros((2, 1, 32), jnp.float32)
        vp = jnp.full((2, 4), 8, jnp.int32)
    else:
        q = jnp.zeros((2, 1, 32), jnp.float32)
        vp = jnp.full((4,), 8, jnp.int32)

    def run(q, cache, vp):
        return kops.decode_attention_fused(q, cache, vp, lanes=lanes,
                                           interpret=True)

    return _traced_entry(run, q, cache, vp)


def _entry_pack_window() -> dict:
    """SlotKVCache repack: the jitted incremental pack window."""
    import jax.numpy as jnp

    from ..kernels import ops as kops

    a = jnp.zeros((1, 2, 8, 1, 64), jnp.int16)
    b = jnp.zeros((1, 2, 8, 1, 64), jnp.int16)
    ml = jnp.zeros((2, 2), jnp.int16)
    en = jnp.ones((1,), bool)

    def run(a, b, ml, en):
        return kops.pack_window(a, b, ml, en, interpret=True)

    return _traced_entry(run, a, b, ml, en)


def _kv_fixture():
    """A tiny real cache, one step past prefill (correct shapes/dtypes
    for the inner-jit entries)."""
    import jax.numpy as jnp

    from ..kv import CRAMKVCache, synthetic_kv_stream

    rng = np.random.default_rng(0)
    cache = CRAMKVCache(max_pages=4, page=8, n_kv=1, head_dim=32, batch=2,
                        policy="static")
    cache.append(*synthetic_kv_stream(rng, 2, 16, 1, 32))
    cache.account_step()
    return cache, jnp


def _entry_serve_scatters() -> dict:
    """ServeLoop.step_all inner jits: the donated append scatters.
    Donation is the assertion here (a dropped donation doubles the KV
    buffer's peak HBM); the jaxpr must also stay callback-free."""
    import jax.numpy as jnp

    from ..kv.cache import _scatter_tokens
    from ..serving.slots import _scatter_active

    pages = jnp.zeros((2, 32, 1, 64), jnp.int16)
    kv = jnp.zeros((2, 1, 1, 64), jnp.int16)
    starts = jnp.zeros((2,), jnp.int32)
    active = jnp.ones((2,), bool)
    rep = _traced_entry(_scatter_active, pages, kv, starts, active,
                        donated_fn=_scatter_active)
    tok = _traced_entry(_scatter_tokens, pages, kv, jnp.int32(0),
                        donated_fn=_scatter_tokens)
    rep["pinned"]["scatter_tokens_donation"] = bool(tok["donation"])
    return rep


def _entry_kv_step_booking() -> dict:
    """The device-resident accounting jits (`_absorb_step_device` +
    `_book_repack_device` via a real repack) — PR 7's O(1)-host-record
    invariant depends on these staying callback-free."""
    import jax.numpy as jnp

    from ..kv.cache import _absorb_step_device

    cache, _ = _kv_fixture()
    st = cache.state
    n = cache.n_active_groups
    valid = jnp.asarray(
        cache.valid_per_page()[:, : cache.group_lanes * n])
    raw = jnp.zeros((2,), jnp.int32)

    def run(traffic, hits, misses, predictor, packed_mask, valid, r, c):
        return _absorb_step_device(
            traffic, hits, misses, predictor, packed_mask, valid, r, c,
            lanes=cache.group_lanes, n=n)

    return _traced_entry(run, st["traffic"], st["pred_hits"],
                         st["pred_misses"], st["predictor"],
                         st["packed_mask"], valid, raw, raw)


def _entry_serve_megastep() -> dict:
    """The fused serve decode step (`SlotKVCache._megastep`): append
    scatter, window repack, §VI counter update, byte booking and the LLP
    observation as ONE donated jit.  The zero-stall serving contract —
    zero callbacks, whole-state donation taking effect, exactly one
    pallas_call (the pack kernel's) per step."""
    import jax.numpy as jnp

    from ..kv import synthetic_kv_stream
    from ..serving.slots import SlotKVCache, _megastep

    rng = np.random.default_rng(0)
    cache = SlotKVCache(max_pages=4, page=8, n_kv=1, head_dim=32, batch=2,
                        policy="static", interpret=True)
    k0, v0 = synthetic_kv_stream(rng, 2, 8, 1, 32)
    cache.megastep([0, 1], k0, v0)
    # one decode-token step's arguments, built exactly as the wrapper does
    k, v = synthetic_kv_stream(rng, 2, 1, 1, 32)
    idx = np.array([0], np.int32)
    n = cache._active_bucket()
    kwargs = dict(lanes=cache.group_lanes, slot_bytes=cache.slot_bytes,
                  strip_bytes=cache.strip_bytes, use_pack=True, dyn=False,
                  interpret=True)
    args = (cache.state, cache._marker_lanes, jnp.asarray(k),
            jnp.asarray(v), jnp.asarray([0, 1], jnp.int32),
            jnp.asarray(cache.tokens_b, jnp.int32),
            jnp.ones((2,), bool), jnp.asarray(idx),
            jnp.asarray(cache._gate_b), jnp.zeros((2, 1), bool),
            jnp.asarray(
                cache.valid_per_page()[:, : cache.group_lanes * n]))
    return _traced_entry(_megastep, *args, donated_fn=_megastep,
                         donate_kwargs=kwargs, **kwargs)


def _entry_serve_prefill() -> dict:
    """The fused chunked-prefill ingest (`SlotKVCache._prefill`): prompt
    scatter, bulk pack of every touched page group
    (`kernels.prefill_pack`), byte booking, §VI counter update and LLP
    predictor seeding as ONE donated jit — a whole prompt costs exactly
    one pallas_call (the bulk pack kernel's), zero callbacks, donated
    state."""
    import jax.numpy as jnp

    from ..kv import synthetic_kv_stream
    from ..serving.slots import SlotKVCache, _prefill

    rng = np.random.default_rng(0)
    cache = SlotKVCache(max_pages=4, page=8, n_kv=1, head_dim=32, batch=2,
                        policy="static", interpret=True)
    # one whole-prompt ingest's arguments, built exactly as the wrapper
    # does: two full page groups into slot 0 (T = 32, pow2 token bucket)
    k, v = synthetic_kv_stream(rng, 1, 32, 1, 32)
    idx = np.array([0, 1], np.int32)
    kwargs = dict(lanes=cache.group_lanes, slot_bytes=cache.slot_bytes,
                  strip_bytes=cache.strip_bytes, use_pack=True, dyn=False,
                  interpret=True)
    args = (cache.state, cache._marker_lanes, jnp.asarray(k[0]),
            jnp.asarray(v[0]), jnp.int32(0), jnp.int32(0),
            jnp.asarray(idx), jnp.asarray(cache._gate_b),
            jnp.zeros((2, 2), bool))
    return _traced_entry(_prefill, *args, donated_fn=_prefill,
                         donate_kwargs=kwargs, **kwargs)


def _entry_ckpt_pack_batch() -> dict:
    """checkpoint pack_batch: host-resident by design — zero jax arrays
    created, numpy in, numpy out, for every registered batch codec."""
    import jax

    from ..compression.codecs import codec_names, get_codec

    lines = np.arange(4 * 64, dtype=np.uint8).reshape(4, 64)
    audited, jax_created = [], 0
    before = len(jax.live_arrays())
    for name in codec_names():
        codec = get_codec(name)
        if codec.pack_batch is None:
            continue
        out = codec.pack_batch(lines)
        audited.append(name)
        if not isinstance(out, np.ndarray):
            jax_created += 1
    jax_created += max(0, len(jax.live_arrays()) - before)
    return {
        "pinned": {"jax_arrays_created": jax_created,
                   "codecs_audited": len(audited)},
        "f64": False,
        "donation": None,
        "info": {"codecs": audited},
    }


ENTRIES = {
    "engine_chunk": _entry_engine_chunk,
    "fused_decode_pair": lambda: _fused_decode(2, batched=False),
    "fused_decode_quad": lambda: _fused_decode(4, batched=False),
    "fused_decode_batched": lambda: _fused_decode(2, batched=True),
    "pack_window": _entry_pack_window,
    "serve_scatters": _entry_serve_scatters,
    "serve_megastep": _entry_serve_megastep,
    "serve_prefill": _entry_serve_prefill,
    "kv_step_booking": _entry_kv_step_booking,
    "ckpt_pack_batch": _entry_ckpt_pack_batch,
}


def audit() -> dict:
    """Trace every entry; returns {entry: {pinned, f64, donation, info}}."""
    return {name: build() for name, build in ENTRIES.items()}


def hard_violations(report: dict) -> list[str]:
    """Golden-independent invariants: zero host callbacks, no f64, every
    configured donation taking effect, exactly one pallas_call per fused
    decode.  These hold even right after --update-golden."""
    bad = []
    for name, entry in report.items():
        pinned = entry["pinned"]
        for cb in CALLBACK_PRIMITIVES:
            if pinned.get(cb, 0):
                bad.append(f"{name}: {pinned[cb]} {cb} primitive(s) — "
                           "host round trip inside a hot jaxpr")
        if entry.get("f64"):
            bad.append(f"{name}: float64 promotion in the jaxpr")
        if entry.get("donation") is False:
            bad.append(f"{name}: configured donation not taking effect")
        if name.startswith("fused_decode") and \
                pinned.get("pallas_call") != 1:
            bad.append(f"{name}: expected exactly 1 pallas_call, found "
                       f"{pinned.get('pallas_call')}")
        if name == "serve_megastep" and pinned.get("pallas_call") != 1:
            bad.append(f"{name}: the fused serve step must carry exactly "
                       f"1 pallas_call (the pack kernel), found "
                       f"{pinned.get('pallas_call')}")
        if name == "serve_prefill" and pinned.get("pallas_call") != 1:
            bad.append(f"{name}: the fused prefill ingest must carry "
                       f"exactly 1 pallas_call (the bulk pack kernel), "
                       f"found {pinned.get('pallas_call')}")
    if report.get("ckpt_pack_batch", {})["pinned"].get("jax_arrays_created"):
        bad.append("ckpt_pack_batch: checkpoint batch pack dispatched jax "
                   "work — it is a host-numpy cold path by design")
    return bad


def compare(report: dict, golden: dict) -> list[str]:
    """Pinned-budget drift vs the committed golden."""
    bad = []
    for name, gentry in golden.get("entries", {}).items():
        entry = report.get(name)
        if entry is None:
            bad.append(f"{name}: entry missing from audit")
            continue
        for key, want in gentry["pinned"].items():
            got = entry["pinned"].get(key)
            if got != want:
                bad.append(f"{name}: pinned {key} = {got}, golden pins "
                           f"{want}")
        for key in ("f64", "donation"):
            if entry.get(key) != gentry.get(key):
                bad.append(f"{name}: {key} = {entry.get(key)}, golden "
                           f"pins {gentry.get(key)}")
    return bad


def golden_view(report: dict) -> dict:
    """What --update-golden writes: the compared fields only."""
    return {"entries": {
        name: {"pinned": e["pinned"], "f64": e["f64"],
               "donation": e["donation"]}
        for name, e in report.items()}}


def run(golden_path: Path | None = None, *, update: bool = False) -> dict:
    """Audit + compare; the dict the CLI embeds in the JSON report."""
    golden_path = Path(golden_path or GOLDEN_PATH)
    report = audit()
    mismatches = hard_violations(report)
    if update:
        golden_path.parent.mkdir(parents=True, exist_ok=True)
        golden_path.write_text(json.dumps(golden_view(report), indent=2,
                                          sort_keys=True) + "\n")
    elif golden_path.exists():
        mismatches += compare(report,
                              json.loads(golden_path.read_text()))
    else:
        mismatches.append(f"golden file {golden_path} missing — run "
                          "--jaxpr --update-golden")
    return {"entries": report, "golden": str(golden_path),
            "updated": update, "mismatches": mismatches}
