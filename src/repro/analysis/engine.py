"""Rule-engine core: file walking, rule dispatch, and the JSON report.

A rule is a plugin (see `rules/__init__.py`) with a `name`, a one-line
`title`, and a `check(ctx) -> list[Violation]`.  The engine parses each
file once and hands every rule the same `FileContext`; rules that need
cross-file state (R5's call graph is per-module, R1's protected constants
come from framing.py) derive it from the context lazily.
"""

from __future__ import annotations

import ast
from dataclasses import asdict, dataclass, field
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[3]


@dataclass(frozen=True)
class Violation:
    rule: str
    path: str          # repo-relative posix path
    line: int
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


@dataclass
class FileContext:
    path: Path         # absolute
    rel: str           # repo-relative posix (or absolute posix if outside)
    source: str
    tree: ast.Module
    root: Path = field(default=REPO_ROOT)

    def violation(self, node: ast.AST | int, rule: str,
                  message: str) -> Violation:
        line = node if isinstance(node, int) else getattr(node, "lineno", 0)
        return Violation(rule=rule, path=self.rel, line=line, message=message)


def default_paths(root: Path | None = None) -> list[Path]:
    root = root or REPO_ROOT
    return [root / "src" / "repro", root / "benchmarks"]


def iter_py_files(paths) -> list[Path]:
    files: list[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        elif p.suffix == ".py":
            files.append(p)
    return files


def _rel(path: Path, root: Path) -> str:
    try:
        return path.resolve().relative_to(root).as_posix()
    except ValueError:
        return path.resolve().as_posix()


def analyze(paths=None, rules=None, root: Path | None = None
            ) -> list[Violation]:
    """Run the rule registry over `paths` (default: src/repro plus
    benchmarks).  Returns every violation, file-ordered."""
    from .rules import get_rules

    root = Path(root) if root else REPO_ROOT
    active = get_rules(rules)
    out: list[Violation] = []
    for path in iter_py_files(paths or default_paths(root)):
        source = path.read_text()
        try:
            tree = ast.parse(source, filename=str(path))
        except SyntaxError as e:
            out.append(Violation(rule="parse", path=_rel(path, root),
                                 line=e.lineno or 0,
                                 message=f"syntax error: {e.msg}"))
            continue
        ctx = FileContext(path=path, rel=_rel(path, root), source=source,
                          tree=tree, root=root)
        for rule in active:
            out.extend(rule.check(ctx))
    out.sort(key=lambda v: (v.path, v.line, v.rule))
    return out


def render_report(violations: list[Violation], *, files_scanned: int,
                  jaxpr: dict | None = None) -> dict:
    """The JSON report shape the CI job uploads as an artifact."""
    from .rules import get_rules

    counts: dict[str, int] = {}
    for v in violations:
        counts[v.rule] = counts.get(v.rule, 0) + 1
    report = {
        "ok": not violations and not (jaxpr or {}).get("mismatches"),
        "files_scanned": files_scanned,
        "rules": {r.name: r.title for r in get_rules(None)},
        "counts": counts,
        "violations": [asdict(v) for v in violations],
    }
    if jaxpr is not None:
        report["jaxpr_audit"] = jaxpr
    return report
