"""CLI: `python -m repro.analysis [paths...] [options]`.

Exit status is the contract CI keys off:

  0  clean — no rule violations, jaxpr audit (if requested) matches golden
  1  violations found, or jaxpr audit drifted from the golden

Examples:

  python -m repro.analysis                      # rule engine over src+benchmarks
  python -m repro.analysis --report json        # machine-readable report
  python -m repro.analysis --jaxpr              # + trace the hot entries
  python -m repro.analysis --jaxpr-only         # audit only (kernel-smoke CI)
  python -m repro.analysis --jaxpr --update-golden   # re-pin after a kernel change
  python -m repro.analysis --rules r1,r3 path/  # subset, custom roots
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .engine import analyze, default_paths, iter_py_files, render_report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-invariant static analyzer + jaxpr hot-path auditor")
    ap.add_argument("paths", nargs="*", type=Path,
                    help="files/dirs to scan (default: src/repro, benchmarks)")
    ap.add_argument("--report", choices=("text", "json"), default="text")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule subset (e.g. r1,r3)")
    ap.add_argument("--jaxpr", action="store_true",
                    help="also run the jaxpr hot-path audit")
    ap.add_argument("--jaxpr-only", action="store_true",
                    help="skip the rule engine; run only the jaxpr audit")
    ap.add_argument("--golden", type=Path, default=None,
                    help="jaxpr golden path (default tests/golden/"
                         "jaxpr_audit.json)")
    ap.add_argument("--update-golden", action="store_true",
                    help="rewrite the jaxpr golden from this tree")
    ap.add_argument("--out", type=Path, default=None,
                    help="also write the JSON report to this file")
    args = ap.parse_args(argv)

    violations, files_scanned = [], 0
    rules = ([r.strip() for r in args.rules.split(",") if r.strip()]
             if args.rules else None)
    if not args.jaxpr_only:
        paths = args.paths or default_paths()
        files_scanned = len(iter_py_files(paths))
        violations = analyze(paths, rules=rules)

    jaxpr = None
    if args.jaxpr or args.jaxpr_only or args.update_golden:
        from . import jaxpr_audit
        jaxpr = jaxpr_audit.run(args.golden, update=args.update_golden)

    report = render_report(violations, files_scanned=files_scanned,
                           jaxpr=jaxpr)
    if args.out:
        args.out.parent.mkdir(parents=True, exist_ok=True)
        args.out.write_text(json.dumps(report, indent=2) + "\n")

    if args.report == "json":
        print(json.dumps(report, indent=2))
    else:
        for v in violations:
            print(f"{v.path}:{v.line}: [{v.rule}] {v.message}")
        if not args.jaxpr_only:
            print(f"{len(violations)} violation(s) across "
                  f"{files_scanned} file(s)")
        if jaxpr is not None:
            for m in jaxpr["mismatches"]:
                print(f"jaxpr-audit: {m}")
            state = ("updated golden" if jaxpr["updated"] else
                     "drifted" if jaxpr["mismatches"] else "matches golden")
            print(f"jaxpr audit: {len(jaxpr['entries'])} entries, {state}")

    bad = bool(violations) or bool(jaxpr and jaxpr["mismatches"])
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
