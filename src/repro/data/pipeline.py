"""Deterministic synthetic LM data pipeline.

Generates Zipf-distributed token streams with Markov bigram structure so a
model actually has something learnable (loss decreases measurably within a
few hundred steps), plus modality stubs (frames / image embeddings) for the
enc-dec and VLM families.

Production shape: each host generates only its shard of the global batch
(`host_slice`), batches are double-buffered through a background thread,
and every batch is addressable by (seed, step) — restart-safe by
construction, which is what the fault-tolerant loop (runtime/ft.py) relies
on: no data-state checkpointing is needed beyond the step counter.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    n_states: int = 64          # Markov states for learnable structure
    family: str = "dense"
    d_model: int = 0            # for frames/image stubs
    n_image_tokens: int = 0


class SyntheticLM:
    """Stateless (seed, step) -> batch generator."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        root = np.random.default_rng(cfg.seed)
        v = cfg.vocab
        # fixed Markov structure: per state, a Zipf-ish distribution over a
        # random slice of the vocabulary
        self._state_offsets = root.integers(0, v, cfg.n_states)
        ranks = np.arange(1, min(v, 1024) + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self._zipf_p = p / p.sum()
        self._trans = root.integers(0, cfg.n_states,
                                    (cfg.n_states, 8))

    def batch(self, step: int, host_slice: slice | None = None) -> dict:
        cfg = self.cfg
        sl = host_slice or slice(0, cfg.global_batch)
        rows = range(sl.start, sl.stop)
        n = len(rows)
        toks = np.empty((n, cfg.seq_len + 1), np.int32)
        for j, r in enumerate(rows):
            # per-(seed, step, sequence) RNG: any host slice of the global
            # batch is bit-identical to the same rows of the full batch
            rng = np.random.default_rng((cfg.seed, step, r))
            state = int(rng.integers(0, cfg.n_states))
            draws = rng.choice(len(self._zipf_p), size=cfg.seq_len + 1,
                               p=self._zipf_p)
            for t in range(cfg.seq_len + 1):
                toks[j, t] = (self._state_offsets[state] + draws[t]) \
                    % cfg.vocab
                state = self._trans[state, draws[t] % 8]
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if cfg.family == "encdec":
            out["frames"] = np.stack([
                np.random.default_rng((cfg.seed, step, r, 1))
                .standard_normal((cfg.seq_len, cfg.d_model))
                for r in rows]).astype(np.float32)
        if cfg.family == "vlm":
            out["image_embeds"] = np.stack([
                np.random.default_rng((cfg.seed, step, r, 2))
                .standard_normal((cfg.n_image_tokens, cfg.d_model))
                for r in rows]).astype(np.float32)
        return out


def make_batch_iterator(cfg: DataConfig, start_step: int = 0,
                        host_slice: slice | None = None,
                        prefetch: int = 2):
    """Background-thread double-buffered iterator, resumable at any step."""
    gen = SyntheticLM(cfg)
    q: queue.Queue = queue.Queue(maxsize=prefetch)
    stop = threading.Event()

    def worker():
        step = start_step
        while not stop.is_set():
            try:
                q.put((step, gen.batch(step, host_slice)), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    t = threading.Thread(target=worker, daemon=True)
    t.start()

    class _Iter:
        def __iter__(self):
            return self

        def __next__(self):
            return q.get()

        def close(self):
            stop.set()

    return _Iter()
