"""Data pipeline: deterministic synthetic LM streams, sharded per host."""

from .pipeline import DataConfig, SyntheticLM, make_batch_iterator

__all__ = ["DataConfig", "SyntheticLM", "make_batch_iterator"]
