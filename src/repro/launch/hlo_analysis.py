"""Extract roofline terms from a compiled XLA executable.

collective_bytes is NOT in cost_analysis(): we parse the optimized HLO text
and sum operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute (including the -start async variants).
"""

from __future__ import annotations

import re

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f32": 4, "s32": 4, "u32": 4,
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
}

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\("
)
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective data volume, parsed from the optimized HLO.

    Post-optimization HLO prints operands without inline shapes, so we use
    the RESULT shape: equal to the operand volume for all-reduce /
    all-to-all / collective-permute, equal to the full gathered volume for
    all-gather (what moves on the wire up to (g-1)/g), and multiplied by
    the group size for reduce-scatter (result is the scattered slice).
    Only op definitions (lines with '=') are counted; -done ops and loop
    condition references don't match the opcode( pattern.
    """
    out = {c: 0 for c in COLLECTIVES}
    counts = {c: 0 for c in COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m or "=" not in line[: m.start()]:
            continue
        op = m.group(1)
        result_part = line[: m.start()].split("=", 1)[1]
        total = sum(shape_bytes(d, s)
                    for d, s in _SHAPE_RE.findall(result_part))
        if op == "reduce-scatter":
            g = _GROUPS_RE.search(line)
            if g:
                total *= int(g.group(2))
        out[op] += total
        counts[op] += 1
    return {
        "bytes_by_type": out,
        "counts_by_type": counts,
        "total_bytes": sum(out.values()),
        "total_ops": sum(counts.values()),
    }


def analyze_compiled(compiled) -> dict:
    """cost_analysis + memory_analysis + collective bytes, best-effort."""
    info: dict = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        info["cost_analysis"] = {
            k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float)) and (
                "flops" in k or "bytes" in k or "utilization" in k.lower())
        }
        info["flops"] = float(ca.get("flops", 0.0))
        info["bytes_accessed"] = float(ca.get("bytes accessed", 0.0))
    except Exception as e:  # pragma: no cover
        info["cost_analysis_error"] = repr(e)
    try:
        ma = compiled.memory_analysis()
        for attr in (
            "argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "host_argument_size_in_bytes",
        ):
            if hasattr(ma, attr):
                info.setdefault("memory_analysis", {})[attr] = int(
                    getattr(ma, attr))
    except Exception as e:  # pragma: no cover
        info["memory_analysis_error"] = repr(e)
    try:
        text = compiled.as_text()
        info["collectives"] = collective_bytes(text)
        info["hlo_bytes"] = len(text)
    except Exception as e:  # pragma: no cover
        info["collectives_error"] = repr(e)
    return info
