import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimbing driver: run a cell under several variants, compare
the roofline terms, and append the hypothesis log.

  python -m repro.launch.perf --arch qwen3_8b --shape train_4k \
      --variants base no_fsdp bf16_params no_fsdp+bf16_params
"""

import argparse
import json
from pathlib import Path

from .dryrun import run_cell

LOG = Path(__file__).resolve().parents[3] / "experiments" / "perf_log.json"


def compare(arch: str, shape: str, variants: list[str], multi_pod=False,
            force=False) -> list[dict]:
    rows = []
    for v in variants:
        rec = run_cell(arch, shape, multi_pod, v, force=force)
        if not rec.get("ok"):
            rows.append({"variant": v, "error": rec.get("error")})
            continue
        r = rec["roofline"]
        rows.append({
            "variant": v,
            "compute_s": r["compute_s"],
            "memory_s": r["memory_s"],
            "collective_s": r["collective_s"],
            "dominant": r["dominant"],
            "bound_s": max(r["compute_s"], r["memory_s"],
                           r["collective_s"]),
            "roofline_frac": r["compute_s"] / max(
                r["compute_s"], r["memory_s"], r["collective_s"]),
            "temp_bytes": rec.get("memory_analysis", {}).get(
                "temp_size_in_bytes"),
            "arg_bytes": rec.get("memory_analysis", {}).get(
                "argument_size_in_bytes"),
            "coll_by_type": rec.get("extrapolated", {}).get(
                "collective_bytes_by_type"),
        })
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variants", nargs="+", default=["base"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    rows = compare(args.arch, args.shape, args.variants, args.multi_pod,
                   args.force)
    base = next((r for r in rows if r["variant"] == "base" and "error"
                 not in r), None)
    print(f"\n== {args.arch} {args.shape} ==")
    hdr = (f"{'variant':<28}{'bound_s':>10}{'comp':>9}{'mem':>9}"
           f"{'coll':>9}{'dom':>6}{'vs base':>9}")
    print(hdr + "\n" + "-" * len(hdr))
    for r in rows:
        if "error" in r:
            print(f"{r['variant']:<28}ERROR {str(r['error'])[:60]}")
            continue
        rel = (base["bound_s"] / r["bound_s"]
               if base and r["bound_s"] else float("nan"))
        print(f"{r['variant']:<28}{r['bound_s']:>10.3f}"
              f"{r['compute_s']:>9.3f}{r['memory_s']:>9.3f}"
              f"{r['collective_s']:>9.3f}{r['dominant'][:4]:>6}"
              f"{rel:>8.2f}x")
    log = json.loads(LOG.read_text()) if LOG.exists() else []
    log.append({"arch": args.arch, "shape": args.shape, "rows": rows})
    LOG.write_text(json.dumps(log, indent=1))


if __name__ == "__main__":
    main()
