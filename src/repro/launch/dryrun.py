import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first: jax locks the device count on first
initialization, and the production meshes need 512 placeholder CPU devices.
(Do NOT import this module from tests — run it as a script.)

For each cell we AOT-compile the appropriate step function against
ShapeDtypeStruct inputs (zero allocation), then record:
  * memory_analysis()    — proves the cell fits per-device HBM
  * cost_analysis()      — HLO FLOPs / bytes for the roofline terms
  * collective operand bytes parsed from the optimized HLO
into experiments/dryrun/<arch>__<shape>__<mesh>[__<variant>].json.

Usage:
  python -m repro.launch.dryrun --arch qwen3_8b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--force]
  python -m repro.launch.dryrun --arch ... --shape ... --variant opt_v1
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from .. import configs
from ..models import SHAPES_BY_NAME, STANDARD_SHAPES, count_params, active_params
from ..runtime.sharding import activation_sharding
from .hlo_analysis import analyze_compiled
from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS, make_production_mesh, mesh_chip_count
from .steps import build_cell
from .variants import apply_variant

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

# long_500k runs only for sub-quadratic (SSM/hybrid) archs; full-attention
# archs skip it (noted in DESIGN.md §Arch-applicability).
LONG_OK_FAMILIES = ("ssm", "hybrid")


def _with_supers(cfg, k: int, seq_len: int):
    """Config scaled to k super-blocks, fully unrolled, for cost probes.

    Attention/xent chunk counts are capped for long sequences so the
    unrolled probe HLO stays compilable; the matmul volume (FLOPs) is
    chunking-invariant, bytes-accessed is mildly optimistic for the big
    chunks (noted in EXPERIMENTS.md §Roofline).
    """
    from ..models.transformer import super_block_spec

    kw = {"microbatches": 1, "unroll": True, "remat": False}
    if seq_len > 8192:
        kw.update(
            attn_q_chunk=max(cfg.attn_q_chunk, seq_len // 8),
            attn_k_chunk=max(cfg.attn_k_chunk, seq_len // 4),
            xent_chunk=max(cfg.xent_chunk, 4096),
            # SSD: cap unrolled chunk count at 32. The O(c^2) intra-chunk
            # term inflates <= (seq/32)/ssm_chunk x, making SSM prefill
            # compute terms upper bounds (EXPERIMENTS.md §Roofline note).
            ssm_chunk=max(cfg.ssm_chunk, seq_len // 16),
        )
    if cfg.family == "encdec":
        kw.update(n_layers=k, enc_layers=k, dec_layers=k)
    else:
        per = len([b for b in super_block_spec(cfg) if b != "shared"])
        kw.update(n_layers=k * per)
    return cfg.replace(**kw)


def probe_costs(cfg, spec, mesh, rules, opts=None) -> dict:
    """Extrapolate true per-step FLOPs/bytes/collective-bytes.

    XLA cost_analysis counts a lax.scan body ONCE regardless of trip count,
    so the full-config numbers under-report by ~n_layers (and microbatches).
    Every per-step quantity is linear in the super-block count NS:
    p(NS) = a + b*NS.  We lower NS=2 and NS=4 probes (microbatches=1),
    solve for (a, b), and evaluate at the real NS.  Exact for everything
    that scales with depth, including the ZeRO optimizer update.
    """
    from ..models.transformer import n_supers as _ns
    from .steps import build_cell as _bc

    def measure(k):
        c = _with_supers(cfg, k, spec.seq_len)
        fn, shapes, shards, _ = _bc(c, spec, mesh, rules,
                                    fsdp=(opts or {}).get("fsdp", True))
        donate = (2,) if (opts or {}).get("donate_cache") \
            and spec.kind == "decode" else ()
        with mesh, activation_sharding(mesh, rules):
            compiled = jax.jit(fn, in_shardings=shards,
                               donate_argnums=donate).lower(
                *shapes).compile()
        info = analyze_compiled(compiled)
        coll = info.get("collectives", {}).get("bytes_by_type", {})
        return (info.get("flops", 0.0), info.get("bytes_accessed", 0.0),
                coll)

    if cfg.family == "encdec":
        ns_full = cfg.enc_layers
    else:
        ns_full = _ns(cfg)
    f2, b2, c2 = measure(2)
    f4, b4, c4 = measure(4)
    lin = lambda p2, p4: p2 + (p4 - p2) / 2.0 * (ns_full - 2)
    coll = {k: lin(c2.get(k, 0), c4.get(k, 0)) for k in set(c2) | set(c4)}
    mb = max(1, cfg.microbatches) if spec.kind == "train" else 1
    return {
        "ns_full": ns_full,
        "flops": lin(f2, f4),
        "bytes_accessed": lin(b2, b4),
        "collective_bytes_by_type": coll,
        # mb>1 repeats the fwd/bwd FSDP gathers per microbatch
        "collective_bytes_total": sum(coll.values()),
        "collective_bytes_total_mb_scaled": sum(coll.values()) * mb,
        "microbatches": mb,
    }


def cell_applicable(cfg, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return cfg.family in LONG_OK_FAMILIES
    return True


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             variant: str = "base", force: bool = False) -> dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    tag = f"{arch}__{shape_name}__{mesh_name}" + (
        f"__{variant}" if variant != "base" else "")
    out_path = OUT_DIR / f"{tag}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    cfg = configs.get(arch)
    spec = SHAPES_BY_NAME[shape_name]
    if not cell_applicable(cfg, shape_name):
        rec = {"tag": tag, "skipped": True,
               "reason": "full-attention arch: long_500k needs "
                         "sub-quadratic attention (DESIGN.md)"}
        OUT_DIR.mkdir(parents=True, exist_ok=True)
        out_path.write_text(json.dumps(rec, indent=2))
        return rec

    cfg, rules, opts = apply_variant(cfg, spec, variant)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh_chip_count(mesh)
    t0 = time.time()
    rec = {
        "tag": tag, "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "variant": variant, "chips": chips, "family": cfg.family,
        "params": count_params(cfg), "active_params": active_params(cfg),
        "seq_len": spec.seq_len, "global_batch": spec.global_batch,
        "kind": spec.kind,
    }
    try:
        fn, arg_shapes, in_shardings, out_shardings = build_cell(
            cfg, spec, mesh, rules, fsdp=opts.get("fsdp", True))
        donate = (2,) if opts.get("donate_cache") \
            and spec.kind == "decode" else ()
        with mesh, activation_sharding(mesh, rules):
            lowered = jax.jit(fn, in_shardings=in_shardings,
                              donate_argnums=donate).lower(
                *arg_shapes)
            t_lower = time.time()
            compiled = lowered.compile()
            t_compile = time.time()
        try:
            print(compiled.memory_analysis())
        except Exception:
            pass
        try:
            print({k: v for k, v in (compiled.cost_analysis() or {}).items()
                   if k in ("flops", "bytes accessed")})
        except Exception:
            pass
        rec.update(analyze_compiled(compiled))
        rec["lower_s"] = round(t_lower - t0, 2)
        rec["compile_s"] = round(t_compile - t_lower, 2)
        rec["ok"] = True
        # scan bodies are cost-counted once: extrapolate true per-step costs
        try:
            if os.environ.get("REPRO_SKIP_PROBES"):
                raise RuntimeError("probes disabled (REPRO_SKIP_PROBES)")
            probe = probe_costs(cfg, spec, mesh, rules, opts)
        except Exception as pe:  # compile proof stands; roofline is flagged
            rec["probe_error"] = repr(pe)[:300]
            probe = {
                "flops": rec.get("flops", 0.0),
                "bytes_accessed": rec.get("bytes_accessed", 0.0),
                "collective_bytes_total_mb_scaled": rec.get(
                    "collectives", {}).get("total_bytes", 0),
                "collective_bytes_by_type": rec.get(
                    "collectives", {}).get("bytes_by_type", {}),
                "note": "probe failed: scan-undercounted fallback numbers",
            }
        rec["extrapolated"] = probe
        flops = probe["flops"]
        bytes_acc = probe["bytes_accessed"]
        coll = probe["collective_bytes_total_mb_scaled"]
        rec["roofline"] = {
            "compute_s": flops / PEAK_FLOPS,
            "memory_s": bytes_acc / HBM_BW,
            "collective_s": coll / ICI_BW,
        }
        terms = rec["roofline"]
        rec["roofline"]["dominant"] = max(
            ("compute_s", "memory_s", "collective_s"),
            key=lambda k: terms[k])
    except Exception as e:
        rec["ok"] = False
        rec["error"] = repr(e)
        rec["traceback"] = traceback.format_exc()[-4000:]
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rec, indent=2))
    status = "OK" if rec.get("ok") else ("SKIP" if rec.get("skipped")
                                         else "FAIL")
    print(f"[{status}] {tag} lower={rec.get('lower_s')}s "
          f"compile={rec.get('compile_s')}s "
          f"dominant={rec.get('roofline', {}).get('dominant')}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--variant", default="base")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    if args.all:
        failures = 0
        for arch in configs.ARCHS:
            for spec in STANDARD_SHAPES:
                for mp in meshes:
                    rec = run_cell(arch, spec.name, mp, args.variant,
                                   args.force)
                    failures += 0 if rec.get("ok") or rec.get("skipped") \
                        else 1
        print(f"dry-run sweep complete; failures={failures}")
        raise SystemExit(1 if failures else 0)

    assert args.arch and args.shape, "--arch and --shape (or --all)"
    for mp in meshes:
        rec = run_cell(configs.canonical(args.arch), args.shape, mp,
                       args.variant, args.force)
        if not (rec.get("ok") or rec.get("skipped")):
            print(rec.get("traceback", rec.get("error")))
            raise SystemExit(1)


if __name__ == "__main__":
    main()
