"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — smoke tests and benchmarks see 1 CPU
device; only dryrun.py (which sets XLA_FLAGS before any jax import) sees the
512 placeholder devices.

Target hardware: TPU v5e pods — 16x16 = 256 chips/pod, 2 pods = 512 chips.
  peak bf16:      197 TFLOP/s per chip
  HBM bandwidth:  819 GB/s per chip (16 GB capacity)
  ICI:            ~50 GB/s per link
"""

from __future__ import annotations

import jax

PEAK_FLOPS = 197e12        # bf16, per chip
HBM_BW = 819e9             # bytes/s per chip
HBM_BYTES = 16 * 2**30     # per chip
ICI_BW = 50e9              # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over the real local devices (tests / CPU training)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))


def mesh_chip_count(mesh) -> int:
    import numpy as np

    return int(np.prod(list(mesh.shape.values())))
