"""Named configuration variants for the §Perf hillclimbing loop.

A variant is (config transform, sharding-rule override, lowering options)
applied on top of an architecture's base config.  Every §Perf iteration in
EXPERIMENTS.md references the variant name used.

Lowering options:
  fsdp: bool — ZeRO-shard parameters over the data axis (default True).
"""

from __future__ import annotations

import jax.numpy as jnp

from ..runtime.sharding import RuleSet


def apply_variant(cfg, shape, variant: str):
    """Returns (cfg, rules, opts) for the named variant."""
    rules = RuleSet()
    opts: dict = {}
    for part in variant.split("+"):
        cfg, rules, opts = _apply_one(cfg, rules, opts, part)
    return cfg, rules, opts


def _apply_one(cfg, rules, opts, v: str):
    if v == "base":
        return cfg, rules, opts
    if v == "no_remat":
        return cfg.replace(remat=False), rules, opts
    if v == "attn_gather":   # one seq-gather per attention (Megatron-SP)
        return cfg.replace(attn_gather=True), rules, opts
    if v == "donate":        # decode: alias the KV cache in/out (in-place)
        return cfg, rules, {**opts, "donate_cache": True}
    if v == "no_fsdp":       # params TP-sharded only: no per-layer gathers
        return cfg, rules, {**opts, "fsdp": False}
    if v == "bf16_params":   # halve FSDP gather + grad reduce bytes
        return cfg.replace(param_dtype=jnp.bfloat16), rules, opts
    if v == "bf16_opt":
        return cfg.replace(optimizer_dtype=jnp.bfloat16), rules, opts
    if v.startswith("mb"):   # microbatch count, e.g. mb1 / mb2 / mb8
        return cfg.replace(microbatches=int(v[2:])), rules, opts
    if v.startswith("qc"):
        return cfg.replace(attn_q_chunk=int(v[2:])), rules, opts
    if v.startswith("kc"):
        return cfg.replace(attn_k_chunk=int(v[2:])), rules, opts
    if v.startswith("xent"):
        return cfg.replace(xent_chunk=int(v[4:])), rules, opts
    if v == "no_sp":         # activations keep full sequence (no SP)
        return cfg, rules.override(seq=()), opts
    if v == "sp_data":       # shard activation seq over data instead
        return cfg, rules.override(seq=("data",)), opts
    if v == "kv_seq_replicated":  # decode: no sequence-parallel KV
        return cfg, rules.override(kv_seq=()), opts
    if v == "kv_seq_model":  # decode: KV sequence over the model axis
        return cfg, rules.override(kv_seq=("model",)), opts
    if v == "batch_model":   # decode: spread batch over model too
        return cfg, rules.override(batch=("pod", "data", "model")), opts
    if v == "embed_shard":   # Megatron-SP on the hidden dim
        return cfg, rules.override(embed=("model",)), opts
    if v == "expert_data":   # experts sharded over data axis
        return cfg, rules.override(experts=("data",)), opts
    raise KeyError(f"unknown variant {v!r}")
