"""Step functions + sharding trees shared by dryrun.py / train.py / serve.py."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models import ShapeSpec, build, input_specs
from ..optim.adamw import abstract_opt_state, make_train_step
from ..runtime.sharding import (
    RuleSet,
    spec_for,
    tree_shardings,
    zero_shardings,
)

# logical axes for model inputs, by name
INPUT_AXES = {
    "tokens": ("batch", "seq"),
    "labels": ("batch", "seq"),
    "frames": ("batch", "seq", "embed"),
    "image_embeds": ("batch", "image", "embed"),
    "token": ("batch", None),
    "index": (),
}

# logical axes for decode-cache leaves, by leaf name
CACHE_AXES = {
    "k": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
    "v": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
    "xk": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
    "xv": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
    "conv_x": ("layers", "batch", None, "mlp"),
    "conv_B": ("layers", "batch", None, None),
    "conv_C": ("layers", "batch", None, None),
    "h": ("layers", "batch", "heads", None, None),
}

# whisper caches lack the stacked 'layers' handling difference: same names


def batch_shardings(cfg, shape: ShapeSpec, mesh, rules: RuleSet):
    specs = input_specs(cfg, shape)
    return {
        k: NamedSharding(mesh, spec_for(INPUT_AXES[k], v.shape, mesh, rules))
        for k, v in specs.items()
    }


def cache_shardings(cache_shapes, mesh, rules: RuleSet):
    def leaf_sharding(path, leaf):
        name = None
        for entry in reversed(path):
            if hasattr(entry, "key"):
                name = entry.key
                break
        axes = CACHE_AXES.get(name, tuple([None] * len(leaf.shape)))
        if len(axes) != len(leaf.shape):
            axes = tuple([None] * len(leaf.shape))
        return NamedSharding(mesh, spec_for(axes, leaf.shape, mesh, rules))

    return jax.tree_util.tree_map_with_path(leaf_sharding, cache_shapes)


def make_prefill_step(model):
    def prefill_step(params, batch):
        h = model.forward(params, batch)
        # last-position logits only (next-token after the prompt)
        from ..models.layers import logits_last

        return logits_last(h[:, -1], params["embed"])

    return prefill_step


def make_serve_step(model):
    cfg = model.config

    def serve_step(params, token, cache, index, image_embeds=None):
        kw = {}
        if cfg.family == "vlm":
            kw["image_embeds"] = image_embeds
        logits, cache = model.decode_step(params, token, cache, index, **kw)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_token, cache

    return serve_step


def build_cell(arch_cfg, shape: ShapeSpec, mesh, rules: RuleSet | None = None,
               *, fsdp: bool = True):
    """Everything needed to lower one (arch x shape x mesh) cell abstractly.

    Returns (fn, arg_shapes tuple, in_shardings tuple, out_shardings).
    """
    rules = rules or RuleSet()
    model = build(arch_cfg)
    pshapes, paxes = model.abstract_params()
    # FSDP/ZeRO-3 style (default): params are TP-sharded on `model` AND
    # additionally sharded over `data` on their largest replicated dim; XLA
    # all-gathers per layer.  This is what lets 123B/400B cells fit
    # 16GB/chip.  fsdp=False keeps TP-only params (no per-layer gathers) —
    # the right call for models whose weights fit, see §Perf.
    if fsdp:
        pshard = zero_shardings(paxes, pshapes, mesh, rules)
    else:
        pshard = tree_shardings(paxes, pshapes, mesh, rules)
    bshard = batch_shardings(arch_cfg, shape, mesh, rules)
    bshapes = input_specs(arch_cfg, shape)

    if shape.kind == "train":
        from ..optim.adamw import TrainState

        state_shapes = abstract_opt_state(pshapes, arch_cfg.optimizer_dtype)
        repl = NamedSharding(mesh, P())
        zshard = zero_shardings(paxes, pshapes, mesh, rules)
        state_shard = TrainState(params=pshard, m=zshard, v=zshard,
                                 step=repl, dyn_counter=repl)
        fn = make_train_step(model)
        return fn, (state_shapes, bshapes), (state_shard, bshard), None

    if shape.kind == "prefill":
        fn = make_prefill_step(model)
        return fn, (pshapes, bshapes), (pshard, bshard), None

    # decode
    B, T = shape.global_batch, shape.seq_len
    cache_shapes = jax.eval_shape(lambda: model.init_cache(B, T))
    cshard = cache_shardings(cache_shapes, mesh, rules)
    repl = NamedSharding(mesh, P())
    token = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    tok_shard = NamedSharding(
        mesh, spec_for(("batch", None), (B, 1), mesh, rules))
    index = jax.ShapeDtypeStruct((), jnp.int32)
    fn = make_serve_step(model)
    args = [pshapes, token, cache_shapes, index]
    shards = [pshard, tok_shard, cshard, repl]
    if arch_cfg.family == "vlm":
        img = jax.ShapeDtypeStruct(
            (B, arch_cfg.n_image_tokens, arch_cfg.d_model), arch_cfg.dtype)
        args.append(img)
        shards.append(NamedSharding(
            mesh, spec_for(INPUT_AXES["image_embeds"], img.shape, mesh,
                           rules)))
    return fn, tuple(args), tuple(shards), None
