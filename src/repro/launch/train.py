"""Training launcher (real execution on the local devices).

For the production mesh this is the same step function the dry-run
AOT-compiles; on the CPU container it runs reduced configs end-to-end with
the full substrate engaged: synthetic data pipeline, AdamW(+ZeRO specs),
remat, microbatching, fault-tolerant checkpoint/restart loop, straggler
detection, and optional CRAM-compressed checkpoints.

  python -m repro.launch.train --arch qwen3_8b --smoke --steps 200
  python -m repro.launch.train --preset lm20m --steps 300 --inject-fault 120
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from .. import configs
from ..data import DataConfig, make_batch_iterator
from ..models import ModelConfig, build, count_params, smoke_config
from ..optim.adamw import adamw_init, make_train_step
from ..runtime.ft import LoopConfig, SimulatedFault, run_with_restarts

PRESETS = {
    # ~20M-param LM for the e2e example (trains visibly in minutes on CPU)
    "lm20m": ModelConfig(
        name="lm20m", family="dense", n_layers=4, d_model=384, n_heads=6,
        n_kv_heads=6, head_dim=64, d_ff=1024, vocab=8192, max_seq=256,
        microbatches=1, remat=False, attn_q_chunk=128, attn_k_chunk=128,
        xent_chunk=128, dtype=jnp.float32, param_dtype=jnp.float32),
    "lm2m": ModelConfig(
        name="lm2m", family="dense", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=4, head_dim=32, d_ff=256, vocab=2048, max_seq=128,
        microbatches=1, remat=False, attn_q_chunk=64, attn_k_chunk=64,
        xent_chunk=64, dtype=jnp.float32, param_dtype=jnp.float32),
}


def build_config(args) -> ModelConfig:
    if args.preset:
        return PRESETS[args.preset]
    cfg = configs.get(configs.canonical(args.arch))
    return smoke_config(cfg) if args.smoke else cfg


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--preset", default=None, choices=[*PRESETS, None])
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--codec", default="cram")
    ap.add_argument("--inject-fault", type=int, default=0,
                    help="raise a SimulatedFault once at this step")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)

    cfg = build_config(args)
    seq = args.seq or min(cfg.max_seq, 256)
    model = build(cfg)
    print(f"training {cfg.name}: {count_params(cfg)/1e6:.1f}M params, "
          f"batch {args.batch} x seq {seq}")

    dcfg = DataConfig(vocab=cfg.vocab, seq_len=seq, global_batch=args.batch,
                      seed=args.seed, family=cfg.family,
                      d_model=cfg.d_model,
                      n_image_tokens=cfg.n_image_tokens)

    def make_state():
        params, _ = model.init(jax.random.key(args.seed))
        return adamw_init(params, cfg.optimizer_dtype)

    def make_step_fn():
        return jax.jit(make_train_step(model, lr_peak=args.lr,
                                       lr_total=args.steps))

    def make_batch_iter(start_step):
        it = make_batch_iterator(dcfg, start_step=start_step)
        return it

    fired = {"done": False}

    def injector(step):
        if args.inject_fault and step == args.inject_fault \
                and not fired["done"]:
            fired["done"] = True
            raise SimulatedFault(f"injected at step {step}")

    loop_cfg = LoopConfig(total_steps=args.steps,
                          ckpt_every=args.ckpt_every,
                          ckpt_dir=args.ckpt_dir, codec=args.codec)
    t0 = time.time()
    res, state = run_with_restarts(
        make_step_fn, make_state, make_batch_iter, loop_cfg,
        fault_injector=injector if args.inject_fault else None)
    wall = time.time() - t0
    first = float(np.mean(res.losses[:10]))
    last = float(np.mean(res.losses[-10:]))
    out = {
        "name": cfg.name, "steps": res.final_step, "wall_s": round(wall, 1),
        "loss_first10": round(first, 4), "loss_last10": round(last, 4),
        "restarts": res.restarts,
        "straggler_flags": len(res.straggler_flags),
        "mean_step_ms": round(1e3 * float(np.mean(res.step_times)), 1),
    }
    print(json.dumps(out, indent=2))
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump({**out, "losses": res.losses}, f)
    return out


if __name__ == "__main__":
    main()
