"""Serving launcher: model decode + continuous-batching CRAM-KV tier.

Runs a reduced model end-to-end (prefill via teacher-forced forward, then
step decoding with the dense cache) and mirrors one layer's REAL KV
stream through the production serve tier (`repro.serving.ServeLoop`):
a fixed pool of `--slots` batch lanes with slot reuse, staggered admits
every `--admit-rate` steps, and a compressed host spill tier behind them
(`--spill-pages` caps it).  With `--slots` smaller than the batch, cold
sequences spill COMPRESSED and wake on their next decode step — every
crossing books a ledger `spill` event with compressed duals, so the
printed traffic is the serve tier's whole byte story.

This module is deliberately thin: scheduling, spill, sharded attend and
per-tier autotuning all live in `repro.serving`; the launcher only maps
CLI flags onto one ServeLoop and feeds it the model's KV traffic.

  python -m repro.launch.serve --arch phi4_mini_3_8b --smoke \
      --batch 4 --slots 2 --admit-rate 4 --kv-policy auto
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from .. import configs
from ..bandwidth import AutoTuner, Ledger
from ..models import build, smoke_config
from ..serving import ServeLoop
from .steps import make_serve_step
from .train import PRESETS


def _serve_tier(args, cfg, cache, ledger, *, prompt_len, total_tokens):
    """Continuous-batching mirror of one layer's KV stream: staggered
    admits into `--slots` lanes, per-step decode appends, retire at end
    of stream — spill crossings happen whenever live > slots."""
    hkv, hd = cfg.n_kv_heads, cfg.hd
    spec_key = next((k for k in sorted(cache) if k.startswith("b")
                     and "attn" in cache[k]), None)
    if spec_key is None:
        return None, ledger
    page = 16
    P, T = prompt_len, total_tokens
    kcache = np.asarray(cache[spec_key]["attn"]["k"])[0]   # (B, T, hkv, hd)
    vcache = np.asarray(cache[spec_key]["attn"]["v"])[0]
    B = kcache.shape[0]
    n_need = -(-T // page)
    kw = dict(slots=args.slots or B, max_pages=max(n_need, 2), page=page,
              n_kv=hkv, head_dim=hd, spill_pages=args.spill_pages,
              ledger=ledger, fused=not args.unfused,
              migrate_budget=args.migrate_budget,
              async_spill=not args.sync_spill)
    choices = None
    if args.kv_policy == "auto":
        # auto picks BOTH tiers' packings; --spill-packing only applies
        # to the explicit-policy path
        loop, ch = ServeLoop.auto(AutoTuner(), kcache[:, :P],
                                  vcache[:, :P], **kw)
        choices = {tier: c.as_dict() for tier, c in ch.items()}
    else:
        loop = ServeLoop(policy=args.kv_policy, packing=args.kv_packing,
                         spill_packing=args.spill_packing, **kw)

    admit_every = max(args.admit_rate, 1)
    admit_at = {i: i * admit_every for i in range(B)}
    fed: dict[int, int] = {}                  # seq -> tokens consumed
    step_no = 0
    while len(fed) < B or any(t < T for t in fed.values()):
        for i in range(B):
            if admit_at[i] == step_no:
                # whole prompt in ONE bulk-pack dispatch (or straight to
                # the spill tier when the pool is full and this admit is
                # the coldest) — not a token-by-token replay
                loop.prefill(i, kcache[i, :P], vcache[i, :P])
                fed[i] = P
        kvs = {i: (kcache[i, fed[i]:fed[i] + 1],
                   vcache[i, fed[i]:fed[i] + 1])
               for i in loop.seqs if fed[i] < T}
        if kvs:
            loop.step_all(kvs)      # wakes spilled seqs named this step;
            # with live > slots the appends run in waves of `slots`
            for i in kvs:
                fed[i] += 1
                if fed[i] >= T:
                    loop.retire(i)
        step_no += 1
    obs = loop.observe_tiers()
    stats = {
        **loop.summary(),
        "serve_steps": step_no,
        "policy": args.kv_policy,
        "policy_choice": choices,
        "tier_observations": obs or None,   # per-tier §VI counters
    }
    return stats, ledger


def _timed_decode(serve_step, params, prompts, cache, *, gen):
    """Prefill and step decode as two SEPARATELY timed regions, each with
    ZERO device->host materialization inside (analysis R3): the prefill
    region syncs the cache before its clock stops, per-step decode tokens
    stay device arrays, the last step is synced before the decode timer
    stops, and the host copies happen after both.
    tests/test_launch_timing.py pins the ordering."""
    P = prompts.shape[1]
    t0 = time.time()
    for i in range(P - 1):
        _, cache = serve_step(params, jnp.asarray(prompts[:, i:i + 1]),
                              cache, jnp.int32(i))
    jax.block_until_ready(cache)
    prefill_wall = time.time() - t0
    generated = []
    tok = jnp.asarray(prompts[:, -1:])
    t1 = time.time()
    for i in range(P - 1, P + gen - 1):
        tok, cache = serve_step(params, tok, cache, jnp.int32(i))
        generated.append(tok)            # device array — no per-step sync
    jax.block_until_ready((generated, cache))
    decode_wall = time.time() - t1
    gen_arr = np.stack([np.asarray(t)[:, 0] for t in generated], 1)
    return gen_arr, cache, prefill_wall, decode_wall


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4_mini_3_8b")
    ap.add_argument("--preset", default=None)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--slots", type=int, default=0,
                    help="serve-tier batch lanes (0 = one per sequence; "
                         "fewer than --batch exercises the spill tier)")
    ap.add_argument("--spill-pages", type=int, default=None,
                    help="host spill-tier capacity in pages (default "
                         "unbounded)")
    ap.add_argument("--admit-rate", type=int, default=1,
                    help="admit one new sequence every N serve steps "
                         "(staggered continuous batching)")
    ap.add_argument("--kv-policy", default="dynamic",
                    choices=["dynamic", "static", "off", "auto"])
    ap.add_argument("--kv-packing", default="pair",
                    choices=["pair", "quad"],
                    help="hot-tier packing (ignored with --kv-policy "
                         "auto, where the AutoTuner picks per tier)")
    ap.add_argument("--spill-packing", default="quad",
                    choices=["off", "pair", "quad"],
                    help="spill-tier packing (auto overrides it)")
    ap.add_argument("--migrate-budget", type=int, default=1,
                    help="page-group columns re-laid per decode step when "
                         "a live gate flip / packing switch is migrating "
                         "the cache (0 disables incremental migration)")
    ap.add_argument("--unfused", action="store_true",
                    help="run the legacy append/repack/account dispatch "
                         "sequence instead of the fused megastep")
    ap.add_argument("--sync-spill", action="store_true",
                    help="re-encode spill payloads inline on evict "
                         "instead of on the background worker")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (PRESETS[args.preset] if args.preset
           else smoke_config(configs.get(configs.canonical(args.arch))))
    model = build(cfg)
    params, _ = model.init(jax.random.key(args.seed))
    rng = np.random.default_rng(args.seed)
    B, P, G = args.batch, args.prompt_len, args.gen
    max_len = P + G
    prompts = rng.integers(0, cfg.vocab, (B, P)).astype(np.int32)

    serve_step = jax.jit(make_serve_step(model))

    # warm-up on a throwaway cache so tokens_per_s excludes jit compile time
    jax.block_until_ready(serve_step(
        params, jnp.asarray(prompts[:, :1]),
        model.init_cache(B, max_len), jnp.int32(0)))
    cache = model.init_cache(B, max_len)

    # model prefill: teacher-forced token by token (correct for every
    # family); the serve TIER below ingests each prompt in one bulk pack
    gen, cache, prefill_wall, decode_wall = _timed_decode(
        serve_step, params, prompts, cache, gen=G)

    ledger = Ledger("serve")
    kv_stats = None
    if cfg.family in ("dense", "moe", "vlm", "hybrid"):
        kv_stats, ledger = _serve_tier(args, cfg, cache, ledger,
                                       prompt_len=P,
                                       total_tokens=P + G - 1)

    out = {
        "name": cfg.name, "batch": B, "prompt_len": P, "generated": G,
        "prefill_tokens_per_s": round(B * (P - 1)
                                      / max(prefill_wall, 1e-9), 1),
        "tokens_per_s": round(B * G / max(decode_wall, 1e-9), 1),
        "sample": gen[0][:16].tolist(),
        "serve_tier": kv_stats,
        "traffic": ledger.as_dict(),
    }
    print(json.dumps(out, indent=2))
    return out


if __name__ == "__main__":
    main()
