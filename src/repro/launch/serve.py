"""Serving launcher: batched greedy decoding + CRAM-KV bandwidth accounting.

Runs a reduced model end-to-end: prefill via teacher-forced forward, then
step decoding with the dense cache, while mirroring one layer's KV stream
through the CRAM-KV paged cache (kernels path) to report the compression /
bandwidth profile of real decode traffic.

  python -m repro.launch.serve --arch phi4_mini_3_8b --smoke \
      --batch 4 --prompt-len 32 --gen 32
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from .. import configs
from ..bandwidth import AutoTuner, Ledger
from ..kv import CRAMKVCache
from ..models import build, smoke_config
from .steps import make_serve_step
from .train import PRESETS


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4_mini_3_8b")
    ap.add_argument("--preset", default=None)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--kv-policy", default="dynamic",
                    choices=["dynamic", "static", "off", "auto"])
    ap.add_argument("--kv-packing", default="pair",
                    choices=["pair", "quad"],
                    help="packing layout (ignored with --kv-policy auto, "
                         "where the AutoTuner picks it)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (PRESETS[args.preset] if args.preset
           else smoke_config(configs.get(configs.canonical(args.arch))))
    model = build(cfg)
    params, _ = model.init(jax.random.key(args.seed))
    rng = np.random.default_rng(args.seed)
    B, P, G = args.batch, args.prompt_len, args.gen
    max_len = P + G
    prompts = rng.integers(0, cfg.vocab, (B, P)).astype(np.int32)

    serve_step = jax.jit(make_serve_step(model))

    # warm-up on a throwaway cache so tokens_per_s excludes jit compile time
    jax.block_until_ready(serve_step(
        params, jnp.asarray(prompts[:, :1]),
        model.init_cache(B, max_len), jnp.int32(0)))
    cache = model.init_cache(B, max_len)

    # prefill: feed prompt tokens one by one (correct for every family)
    t0 = time.time()
    for i in range(P - 1):
        _, cache = serve_step(params, jnp.asarray(prompts[:, i:i + 1]),
                              cache, jnp.int32(i))
    generated = []
    tok = jnp.asarray(prompts[:, -1:])
    for i in range(P - 1, P + G - 1):
        tok, cache = serve_step(params, tok, cache, jnp.int32(i))
        generated.append(np.asarray(tok)[:, 0])
    wall = time.time() - t0
    gen = np.stack(generated, 1)

    # CRAM-KV mirror of one attention layer's real decode traffic: every
    # batch sequence streams through the batched cache, prefill in one
    # vectorized append, then token-by-token (the incremental-repack path).
    # All KV traffic lands in one serve-wide bandwidth ledger.
    page = 16
    kv_stats = None
    ledger = Ledger("serve")
    if cfg.family in ("dense", "moe", "vlm", "hybrid"):
        hkv, hd = cfg.n_kv_heads, cfg.hd
        spec_key = next((k for k in sorted(cache) if k.startswith("b")
                         and "attn" in cache[k]), None)
        if spec_key is not None:
            T = P + G - 1
            n_need = (T + page - 1) // page
            kcache = np.asarray(cache[spec_key]["attn"]["k"])[0]  # (B,T,..)
            vcache = np.asarray(cache[spec_key]["attn"]["v"])[0]
            policy_choice = None
            if args.kv_policy == "auto":
                # AutoTuner picks the packing layout from the prefill KV
                kvc, choice = CRAMKVCache.auto(
                    AutoTuner(), kcache[:, :P], vcache[:, :P],
                    max_pages=max(n_need, 2), page=page, n_kv=hkv,
                    head_dim=hd, batch=B, ledger=ledger)
                policy_choice = choice.as_dict()
            else:
                kvc = CRAMKVCache(max_pages=max(n_need, 2), page=page,
                                  n_kv=hkv, head_dim=hd, batch=B,
                                  policy=args.kv_policy,
                                  packing=args.kv_packing, ledger=ledger)
            kvc.append(kcache[:, :P], vcache[:, :P])
            kvc.account_step()
            pairs_before_decode = kvc.stats.pack_pairs_processed
            for t in range(P, T):
                kvc.append(kcache[:, t:t + 1], vcache[:, t:t + 1])
                kvc.account_step()
            decode_pairs = kvc.stats.pack_pairs_processed - pairs_before_decode
            q = jnp.asarray(rng.standard_normal((B, cfg.n_heads, hd)),
                            jnp.float32)
            out_k = kvc.attend(q, account=False)  # parity probe, not a step
            out_r = kvc.attend_ref(q)
            err = float(jnp.max(jnp.abs(out_k - out_r)))
            kv_stats = {
                "batch_streamed": B,
                "packed_pairs": kvc.stats.packed_pairs,
                "raw_pairs": kvc.stats.raw_pairs,
                "bandwidth_saving": round(kvc.saving(), 4),
                "pack_pairs_per_decode_step": round(
                    decode_pairs / max(T - P, 1), 3),
                "predictor_miss_rate": round(
                    kvc.stats.predictor_misses
                    / max(kvc.stats.predictor_hits
                          + kvc.stats.predictor_misses, 1), 4),
                "kernel_vs_oracle_err": err,
                "policy": args.kv_policy,
                "packing": kvc.packing if kvc.policy != "off" else "off",
                "policy_choice": policy_choice,
            }

    out = {
        "name": cfg.name, "batch": B, "prompt_len": P, "generated": G,
        "tokens_per_s": round(B * G / wall, 1),
        "sample": gen[0][:16].tolist(),
        "cram_kv": kv_stats,
        "traffic": ledger.as_dict(),
    }
    print(json.dumps(out, indent=2))
    return out


if __name__ == "__main__":
    main()
