"""Pallas kernel: one-pass compressibility scan of a whole memory image.

Computes, for every 64-byte line of an image, in a single kernel pass:
  * the hybrid FPC+BDI compressed size (header byte included) — the same
    quantity as core/compress.compressed_sizes, which stays the bit-true
    numpy reference (cross-checked in tests/test_compress_scan.py);
  * the implicit-metadata marker classification of the line against its
    slot's marker family (COMP2 / COMP4 / INVALID / MAYBE_INVERTED /
    UNCOMP, same enum as core/marker.LineStatus).

This is the sweep-side replacement for looping compress.compressed_sizes +
marker.classify_line over an image line by line: figure-level benchmarks
(Fig. 4 compressibility CDFs, Table III/IV capacity accounting) call it on
multi-MB images in one dispatch.

All kernel arithmetic is int32 (TPU has no int64): the 8-byte-base BDI
modes emulate 64-bit compares with (hi, lo) word pairs, and the marker PRF
is a multiply-add family that wraps identically in int32 (device) and
uint32 (host reference below).  Markers here are the *device* marker family
(core/marker.py's keyed blake2b is the host path; the protocol — per-slot
values, regenerate on LIT overflow — is what matters, not the PRF).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.compression.framing import (DEFAULT_MARKER_KEY, HEADER_BYTES,
                                       IL_MULT, LINE_BYTES, M2_MULT, M4_MULT)
from repro.compression.marker import LineStatus

WORDS_PER_LINE = 16
BLOCK_LINES = 256

# multiply-add marker family constants (odd multipliers; wrap mod 2^32) —
# defined once in compression.framing, aliased here for kernel-local use
_M2_MULT = M2_MULT
_M4_MULT = M4_MULT
_IL_MULT = IL_MULT

# BDI modes as (base_bytes, delta_bytes, payload_bytes), evaluated from the
# largest payload to the smallest exactly like core/bdi.bdi_sizes
_BDI_MODES = ((8, 4, 41), (4, 2, 38), (2, 1, 38), (8, 2, 25), (4, 1, 22),
              (8, 1, 17))


# ---------------------------------------------------------------------------
# host-side helpers + numpy reference (uint32 arithmetic, bit-identical)
# ---------------------------------------------------------------------------

def device_markers(slot_idx, key: int = DEFAULT_MARKER_KEY):
    """(m2, m4) uint32 device markers for an array of slot indices."""
    idx = np.asarray(slot_idx, dtype=np.uint64) & np.uint64(0xFFFFFFFF)
    two = (np.uint64(2) * idx + np.uint64(1)) & np.uint64(0xFFFFFFFF)
    k = np.uint64(key & 0xFFFFFFFF)
    m2 = (two * np.uint64(_M2_MULT) + k) & np.uint64(0xFFFFFFFF)
    m4 = (two * np.uint64(_M4_MULT) + k) & np.uint64(0xFFFFFFFF)
    return m2.astype(np.uint32), m4.astype(np.uint32)


def device_il_words(slot_idx, key: int = DEFAULT_MARKER_KEY) -> np.ndarray:
    """(N, 16) uint32 invalid-line (Marker-IL) pattern per slot."""
    idx = np.asarray(slot_idx, dtype=np.uint64)[..., None]
    j = np.arange(WORDS_PER_LINE, dtype=np.uint64)[None, :]
    w = ((idx * np.uint64(WORDS_PER_LINE) + j + np.uint64(1))
         * np.uint64(_IL_MULT) + np.uint64(key & 0xFFFFFFFF))
    return (w & np.uint64(0xFFFFFFFF)).astype(np.uint32)


def classify_image_ref(lines: np.ndarray, key: int = DEFAULT_MARKER_KEY) -> np.ndarray:
    """Numpy reference for the kernel's marker classification.

    lines: (N, 64) uint8, line i living in slot i. Returns (N,) int32 of
    core/marker.LineStatus values, with the same priority order as the
    kernel (COMP2 > COMP4 > INVALID > MAYBE_INVERTED > UNCOMP).
    """
    lines = np.ascontiguousarray(lines, dtype=np.uint8)
    n = lines.shape[0]
    words = lines.view("<u4").reshape(n, WORDS_PER_LINE)
    tail = words[:, -1]
    idx = np.arange(n)
    m2, m4 = device_markers(idx, key)
    il = device_il_words(idx, key)
    is2 = tail == m2
    is4 = tail == m4
    is_il = (words == il).all(axis=1)
    inv = (tail == ~m2) | (tail == ~m4) | (words == ~il).all(axis=1)
    out = np.full(n, int(LineStatus.UNCOMP), dtype=np.int32)
    out[inv] = int(LineStatus.MAYBE_INVERTED)
    out[is_il] = int(LineStatus.INVALID)
    out[is4] = int(LineStatus.COMP4)
    out[is2] = int(LineStatus.COMP2)
    return out


def lines_to_words_i32(lines) -> jnp.ndarray:
    """(N, 64) uint8 -> (N, 16) int32 little-endian word bit patterns."""
    b = jnp.asarray(lines).astype(jnp.uint32)
    w = (b[..., 0::4] | (b[..., 1::4] << 8) | (b[..., 2::4] << 16)
         | (b[..., 3::4] << 24))
    return jax.lax.bitcast_convert_type(w, jnp.int32)


# ---------------------------------------------------------------------------
# in-kernel int32 size + classification math
# ---------------------------------------------------------------------------

def _fpc_bytes_i32(w):
    """FPC compressed size in bytes; w: (B, 16) int32 word bit patterns.

    Same pattern table and zero-run encoding as core/fpc.fpc_size_bits, in
    pure int32 (word-as-signed-int32 == the reference's sign-extended view).
    """
    zero = w == 0
    lo16 = ((w & 0xFFFF) ^ 0x8000) - 0x8000
    hi16 = (((w >> 16) & 0xFFFF) ^ 0x8000) - 0x8000
    b0 = w & 0xFF
    repb = ((b0 == ((w >> 8) & 0xFF)) & (b0 == ((w >> 16) & 0xFF))
            & (b0 == ((w >> 24) & 0xFF)))
    # priority chain (last where wins): raw < half_se8 < pad16 < se16 <
    # repb < se8 < se4 — identical to fpc._classify_nonzero
    bits = jnp.full(w.shape, 32, jnp.int32)
    bits = jnp.where((lo16 >= -128) & (lo16 < 128)
                     & (hi16 >= -128) & (hi16 < 128), 16, bits)
    bits = jnp.where((w & 0xFFFF) == 0, 16, bits)
    bits = jnp.where((w >= -32768) & (w < 32768), 16, bits)
    bits = jnp.where(repb, 8, bits)
    bits = jnp.where((w >= -128) & (w < 128), 8, bits)
    bits = jnp.where((w >= -8) & (w < 8), 4, bits)
    nz_bits = jnp.where(zero, 0, 3 + bits)
    total = nz_bits.sum(axis=-1)

    # zero runs: a run of length L costs ceil(L/8) chunks of (3+3) bits
    prev = jnp.concatenate(
        [jnp.zeros(zero.shape[:-1] + (1,), bool), zero[..., :-1]], axis=-1)
    starts = zero & ~prev
    run_id = jnp.cumsum(starts.astype(jnp.int32), axis=-1)
    chunks = jnp.zeros(zero.shape[:-1], jnp.int32)
    for k in range(1, WORDS_PER_LINE + 1):
        len_k = (zero & (run_id == k)).sum(axis=-1)
        chunks = chunks + (len_k + 7) // 8 * (len_k > 0)
    return (total + chunks * 6 + 7) // 8


_SIGN = -(1 << 31)  # 0x80000000 bit pattern (python int: stays weakly typed)


def _as_i32(u: int) -> int:
    """uint32 constant -> equivalent int32 python int (avoids traced consts
    inside the kernel: pallas requires captured values to be inline scalars)."""
    return u - (1 << 32) if u >= (1 << 31) else u


_M2_I32, _M4_I32, _IL_I32 = _as_i32(_M2_MULT), _as_i32(_M4_MULT), _as_i32(_IL_MULT)


def _fits_i32(v, d):
    """Does int32 v fit in a signed d-byte integer (d in 1, 2, 4)?"""
    if d == 4:
        return jnp.full(v.shape, True)
    lim = 1 << (8 * d - 1)
    return (v >= -lim) & (v < lim)


def _fits_i64(hi, lo, d):
    """Does the 64-bit (hi, lo) int32 pair fit in a signed d-byte integer?"""
    ok32 = hi == (lo >> 31)          # value fits in 32 bits at all
    return ok32 & _fits_i32(lo, d)


def _ult(a, b):
    """Unsigned < on int32 bit patterns (for the 64-bit borrow)."""
    return (a ^ _SIGN) < (b ^ _SIGN)


def _pick(sel, e):
    """Row-wise gather e[i, sel[i]] as a select-sum (TPU-friendly)."""
    ids = jax.lax.broadcasted_iota(jnp.int32, e.shape, len(e.shape) - 1)
    return jnp.where(ids == sel[..., None], e, 0).sum(axis=-1)


def _bdi_fits_small(e, wrap_bits, d):
    """fits for b<=4 modes; e: (B, k) int32 elements (sign-extended)."""
    imm = _fits_i32(e, d)
    nonimm = ~imm
    any_non = nonimm.any(axis=-1)
    fi = jnp.argmax(nonimm, axis=-1)
    base = jnp.where(any_non, _pick(fi, e), 0)
    delta = e - base[..., None]
    if wrap_bits < 32:                     # wrap into the element width
        m = (1 << wrap_bits) - 1
        delta = ((delta & m) ^ (1 << (wrap_bits - 1))) - (1 << (wrap_bits - 1))
    return (imm | _fits_i32(delta, d)).all(axis=-1)


def _bdi_fits_b8(lo, hi, d):
    """fits for base-8 modes; lo/hi: (B, 8) int32 halves of 64-bit elems."""
    imm = _fits_i64(hi, lo, d)
    nonimm = ~imm
    any_non = nonimm.any(axis=-1)
    fi = jnp.argmax(nonimm, axis=-1)
    blo = jnp.where(any_non, _pick(fi, lo), 0)
    bhi = jnp.where(any_non, _pick(fi, hi), 0)
    dlo = lo - blo[..., None]
    borrow = _ult(lo, blo[..., None]).astype(jnp.int32)
    dhi = hi - bhi[..., None] - borrow
    return (imm | _fits_i64(dhi, dlo, d)).all(axis=-1)


def _bdi_bytes_i32(w):
    """Best BDI payload size; w: (B, 16) int32. Mirrors bdi.bdi_sizes."""
    e4 = w                                                    # (B, 16)
    lo16 = ((w & 0xFFFF) ^ 0x8000) - 0x8000
    hi16 = (((w >> 16) & 0xFFFF) ^ 0x8000) - 0x8000
    e2 = jnp.stack([lo16, hi16], axis=-1).reshape(*w.shape[:-1], 32)
    lo8, hi8 = w[..., 0::2], w[..., 1::2]                     # (B, 8)

    best = jnp.full(w.shape[:-1], LINE_BYTES, jnp.int32)
    for b, d, payload in _BDI_MODES:
        if b == 8:
            fits = _bdi_fits_b8(lo8, hi8, d)
        elif b == 4:
            fits = _bdi_fits_small(e4, 32, d)
        else:
            fits = _bdi_fits_small(e2, 16, d)
        best = jnp.where(fits & (payload < best), payload, best)

    rep8 = ((lo8 == lo8[..., :1]) & (hi8 == hi8[..., :1])).all(axis=-1)
    zeros = (w == 0).all(axis=-1)
    best = jnp.where(rep8 & ~zeros, 8, best)
    best = jnp.where(zeros, 0, best)
    return best


def _classify_i32(w, slot_idx, key: int):
    """Marker classification; w: (B, 16) int32, slot_idx: (B,) int32."""
    two = 2 * slot_idx + 1
    m2 = two * _M2_I32 + key
    m4 = two * _M4_I32 + key
    j = jax.lax.broadcasted_iota(jnp.int32, w.shape, len(w.shape) - 1)
    il = ((slot_idx[..., None] * WORDS_PER_LINE + j + 1) * _IL_I32 + key)
    tail = w[..., -1]
    is_il = (w == il).all(axis=-1)
    inv = (tail == ~m2) | (tail == ~m4) | (w == ~il).all(axis=-1)
    out = jnp.full(w.shape[:-1], int(LineStatus.UNCOMP), jnp.int32)
    out = jnp.where(inv, int(LineStatus.MAYBE_INVERTED), out)
    out = jnp.where(is_il, int(LineStatus.INVALID), out)
    out = jnp.where(tail == m4, int(LineStatus.COMP4), out)
    out = jnp.where(tail == m2, int(LineStatus.COMP2), out)
    return out


def _scan_kernel(words_ref, sizes_ref, fpc_ref, bdi_ref, status_ref, *, key):
    blk = words_ref.shape[0]
    w = words_ref[...]
    base = pl.program_id(0) * blk
    slot = base + jax.lax.broadcasted_iota(jnp.int32, (blk, 1), 0)[:, 0]
    fpc = _fpc_bytes_i32(w)
    bdi = _bdi_bytes_i32(w)
    hybrid = jnp.minimum(jnp.minimum(fpc, bdi), LINE_BYTES) + HEADER_BYTES
    sizes_ref[...] = hybrid
    fpc_ref[...] = fpc
    bdi_ref[...] = bdi
    status_ref[...] = _classify_i32(w, slot, key)


@functools.partial(jax.jit,
                   static_argnames=("key", "block", "interpret"))
def _scan_call(words, *, key, block, interpret):
    n = words.shape[0]
    grid = n // block
    spec = pl.BlockSpec((block, WORDS_PER_LINE), lambda i: (i, 0))
    out_spec = pl.BlockSpec((block,), lambda i: (i,))
    out = jax.ShapeDtypeStruct((n,), jnp.int32)
    return pl.pallas_call(
        functools.partial(_scan_kernel, key=key),
        grid=(grid,),
        in_specs=[spec],
        out_specs=(out_spec,) * 4,
        out_shape=(out,) * 4,
        interpret=interpret,
    )(words)


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def compress_scan(lines, *, key: int = DEFAULT_MARKER_KEY, block: int = BLOCK_LINES,
                  interpret: bool | None = None) -> dict:
    """Scan a memory image in one kernel pass.

    lines: (N, 64) uint8 (numpy or jax). Line i is taken to live in slot i.
    Returns a dict of (N,) int32 numpy arrays:
      sizes  — hybrid FPC+BDI compressed size, header included (== the
               bit-true core/compress.compressed_sizes)
      fpc    — FPC-only size in bytes (no header)
      bdi    — best BDI payload size in bytes (no header)
      status — marker classification (core/marker.LineStatus values)
    """
    if interpret is None:
        interpret = default_interpret()
    key = _as_i32(key & 0xFFFFFFFF)
    lines = np.ascontiguousarray(np.asarray(lines, dtype=np.uint8))
    n = lines.shape[0]
    pad = (-n) % block
    if pad:
        lines = np.concatenate(
            [lines, np.zeros((pad, LINE_BYTES), np.uint8)], axis=0)
    words = lines_to_words_i32(lines)
    sizes, fpc, bdi, status = _scan_call(
        words, key=key, block=block, interpret=interpret)
    return {
        "sizes": np.asarray(sizes[:n]),
        "fpc": np.asarray(fpc[:n]),
        "bdi": np.asarray(bdi[:n]),
        "status": np.asarray(status[:n]),
    }
