"""Pallas TPU kernels for the CRAM compute hot-spots (+ pure-jnp oracles).

  compress_scan.py  one-pass image compressibility + marker classification
  bdi_pack.py       CRAM-KV 2:1 pair packing / unpacking
  cram_attention.py fused marker-check/unpack/flash-decode attention
  ops.py            public jit'd wrappers over the KV kernels
  ref.py            pure-jnp oracles (the allclose/equality targets)

All kernels default to interpret mode off-TPU, so the package is fully
exercised on CPU; numpy reference paths stay the bit-true source of truth.
"""
