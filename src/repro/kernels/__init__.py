"""Pallas TPU kernels for the CRAM compute hot-spots (+ pure-jnp oracles).

  compress_scan.py  one-pass image compressibility + marker classification
                    (device backend of the line codecs in
                    repro.compression.codecs)
  bdi_pack.py       CRAM-KV 2:1 pair / 4:1 quad packing and unpacking
                    (device backends of the int8-delta / int4-delta codecs)
  cram_attention.py fused marker-check/unpack/flash-decode attention
  ops.py            public jit'd wrappers over the KV kernels
  ref.py            pure-jnp oracles (the allclose/equality targets;
                    thin jnp bindings of repro.compression.pagepack)

All kernels default to interpret mode off-TPU, so the package is fully
exercised on CPU; repro.compression's numpy paths stay the bit-true source
of truth.
"""
