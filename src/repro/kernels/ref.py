"""Pure-jnp oracles for the Pallas kernels (the allclose targets).

CRAM-KV on-TPU layout (DESIGN.md §3 hardware adaptation):
  * a *slot* is the DMA unit: (page, Hkv, D2) int16, D2 = 2*head_dim (K||V)
  * each slot has a *strip*: (Hkv, D2+2) int16 = elementwise base row
    + the 4-byte marker in the last two int16 lanes (in-band metadata:
    reading the strip with the slot tells the controller-kernel how to
    interpret the slot, no separate metadata fetch)
  * a PACKED slot holds two pages as int8 delta pairs vs the strip base:
    element (t,h,j) = (deltaB & 0xff) << 8 | (deltaA & 0xff)
  * marker values are per-slot (keyed hash, like the paper's DES markers)

A pair of pages is packable iff every element of both pages is within
int8 range of the base (pageA's token-0 row).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..compression import pagepack
from ..compression.framing import (  # noqa: F401  (re-exported for callers)
    MARKER_LANES,
    marker_to_lanes,
    slot_markers,
)


def pack_pair_ref(page_a, page_b):
    """Try to pack two (page, Hkv, D2) int16 pages (int8-delta codec).

    Returns (ok, packed (page,Hkv,D2) int16, base (Hkv, D2) int16).
    """
    return pagepack.pack_pair(page_a, page_b, xp=jnp)


def unpack_pair_ref(packed, base):
    """Inverse of pack_pair_ref -> (page_a, page_b) int16."""
    return pagepack.unpack_pair(packed, base, xp=jnp)


def pack_quad_ref(page_a, page_b, page_c, page_d):
    """Try to pack four pages into one slot (int4-delta codec).

    Returns (ok, packed (page,Hkv,D2) int16, base (Hkv, D2) int16).
    """
    return pagepack.pack_quad(page_a, page_b, page_c, page_d, xp=jnp)


def unpack_quad_ref(packed, base):
    """Inverse of pack_quad_ref -> 4-tuple of (page,Hkv,D2) int16."""
    return pagepack.unpack_quad(packed, base, xp=jnp)


def materialize_kv_ref(slots, strips, markers, lanes: int = 2):
    """Decode the physical cache into logical K/V pages.

    slots: (n_slots, page, Hkv, D2) int16; strips: (n_slots, Hkv, D2+2);
    markers: (n_slots,) uint32 expected pack-markers; lanes: pages a
    packed slot holds (2 = pair codec, 4 = quad codec).
    Returns (pages (lanes*n_slots, page, Hkv, D2) int16, n_pages_per_slot).
    A raw slot contributes its page at index lanes*s (the rest are zeros);
    a packed slot contributes pages at lanes*s .. lanes*s + lanes-1.
    """
    n_slots, page, Hkv, D2 = slots.shape
    tail = strips[:, :, -MARKER_LANES:].astype(jnp.int32)
    tail_u = (tail[..., 0] & 0xFFFF) | ((tail[..., 1] & 0xFFFF) << 16)
    is_packed = jnp.all(
        tail_u == markers.astype(jnp.int32)[:, None], axis=-1)
    base = strips[:, :, :D2]
    if lanes == 2:
        decoded = jax.vmap(unpack_pair_ref)(slots, base)
    else:
        decoded = jax.vmap(unpack_quad_ref)(slots, base)
    pages = jnp.zeros((lanes * n_slots, page, Hkv, D2), jnp.int16)
    sel = is_packed[:, None, None, None]
    for j, pg in enumerate(decoded):
        raw = slots if j == 0 else jnp.zeros_like(slots)
        pages = pages.at[j::lanes].set(jnp.where(sel, pg, raw))
    n_pages = jnp.where(is_packed, lanes, 1)
    return pages, n_pages


def cram_decode_attention_ref(q, slots, strips, markers, valid_tokens,
                              lanes: int = 2):
    """Oracle decode attention over the CRAM-packed cache.

    q: (Hq, D) bf16/f32; slots/strips/markers as above (int16 views of
    bf16 K/V data); valid_tokens: (lanes*n_slots,) int32 valid count per
    logical page (0 for absent pages).
    Returns (Hq, D) float32 attention output.
    """
    n_slots, page, Hkv, D2 = slots.shape
    D = D2 // 2
    Hq = q.shape[0]
    G = Hq // Hkv
    pages, _ = materialize_kv_ref(slots, strips, markers, lanes)
    kv = pages.view(jnp.bfloat16).astype(jnp.float32)  # (P, page, Hkv, D2)
    k = kv[..., :D]
    v = kv[..., D:]
    P2 = lanes * n_slots
    k = k.reshape(P2 * page, Hkv, D)
    v = v.reshape(P2 * page, Hkv, D)
    mask = (jnp.arange(page)[None, :]
            < valid_tokens[:, None]).reshape(P2 * page)
    kg = jnp.repeat(k, G, axis=1)                      # (T, Hq, D)
    vg = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("hd,thd->ht", q.astype(jnp.float32), kg)
    s = s / jnp.sqrt(jnp.float32(D))
    s = jnp.where(mask[None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("ht,thd->hd", p, vg)
