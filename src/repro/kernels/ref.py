"""Pure-jnp oracles for the Pallas kernels (the allclose targets).

CRAM-KV on-TPU layout (DESIGN.md §3 hardware adaptation):
  * a *slot* is the DMA unit: (page, Hkv, D2) int16, D2 = 2*head_dim (K||V)
  * each slot has a *strip*: (Hkv, D2+2) int16 = elementwise base row
    + the 4-byte marker in the last two int16 lanes (in-band metadata:
    reading the strip with the slot tells the controller-kernel how to
    interpret the slot, no separate metadata fetch)
  * a PACKED slot holds two pages as int8 delta pairs vs the strip base:
    element (t,h,j) = (deltaB & 0xff) << 8 | (deltaA & 0xff)
  * marker values are per-slot (keyed hash, like the paper's DES markers)

A pair of pages is packable iff every element of both pages is within
int8 range of the base (pageA's token-0 row).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

MARKER_LANES = 2  # 2 x int16 = 4 marker bytes, at the strip tail


def slot_markers(n_slots: int, key: int = 0x5EED) -> np.ndarray:
    """Per-slot 32-bit markers (keyed affine hash; regenerable)."""
    idx = np.arange(n_slots, dtype=np.uint64)
    h = (idx * np.uint64(0x9E3779B97F4A7C15) + np.uint64(key)) >> np.uint64(13)
    return (h & np.uint64(0xFFFFFFFF)).astype(np.uint32)


def marker_to_lanes(m: np.ndarray) -> np.ndarray:
    """uint32 marker -> two int16 lanes (little-endian halves)."""
    lo = (m & 0xFFFF).astype(np.uint16).view(np.int16)
    hi = ((m >> 16) & 0xFFFF).astype(np.uint16).view(np.int16)
    return np.stack([lo, hi], axis=-1)


def pack_pair_ref(page_a, page_b):
    """Try to pack two (page, Hkv, D2) int16 pages.

    Returns (ok, packed (page,Hkv,D2) int16, base (Hkv, D2) int16).
    """
    base = page_a[0]                                 # (Hkv, D2)
    da = page_a.astype(jnp.int32) - base.astype(jnp.int32)[None]
    db = page_b.astype(jnp.int32) - base.astype(jnp.int32)[None]
    ok = jnp.all((da >= -128) & (da <= 127) & (db >= -128) & (db <= 127))
    packed = ((db & 0xFF) << 8 | (da & 0xFF)).astype(jnp.uint16).view(
        jnp.int16)
    return ok, packed, base


def unpack_pair_ref(packed, base):
    """Inverse of pack_pair_ref -> (page_a, page_b) int16."""
    v = packed.view(jnp.uint16).astype(jnp.int32)
    lo = (v & 0xFF).astype(jnp.int8).astype(jnp.int32)        # sign-extend
    hi = ((v >> 8) & 0xFF).astype(jnp.int8).astype(jnp.int32)
    a = base.astype(jnp.int32)[None] + lo
    b = base.astype(jnp.int32)[None] + hi
    return a.astype(jnp.int16), b.astype(jnp.int16)


def materialize_kv_ref(slots, strips, markers):
    """Decode the physical cache into logical K/V pages.

    slots: (n_slots, page, Hkv, D2) int16; strips: (n_slots, Hkv, D2+2);
    markers: (n_slots,) uint32 expected pack-markers.
    Returns (pages (2*n_slots, page, Hkv, D2) int16, n_pages_per_slot).
    A raw slot contributes its page at index 2*s (2*s+1 is zeros); a packed
    slot contributes pages at 2*s and 2*s+1.
    """
    n_slots, page, Hkv, D2 = slots.shape
    tail = strips[:, :, -MARKER_LANES:].astype(jnp.int32)
    tail_u = (tail[..., 0] & 0xFFFF) | ((tail[..., 1] & 0xFFFF) << 16)
    is_packed = jnp.all(
        tail_u == markers.astype(jnp.int32)[:, None], axis=-1)
    base = strips[:, :, :D2]
    a, b = jax.vmap(unpack_pair_ref)(slots, base)
    pages = jnp.zeros((2 * n_slots, page, Hkv, D2), jnp.int16)
    pages = pages.at[0::2].set(jnp.where(is_packed[:, None, None, None],
                                         a, slots))
    pages = pages.at[1::2].set(jnp.where(is_packed[:, None, None, None],
                                         b, 0))
    n_pages = jnp.where(is_packed, 2, 1)
    return pages, n_pages


def cram_decode_attention_ref(q, slots, strips, markers, valid_tokens):
    """Oracle decode attention over the CRAM-packed cache.

    q: (Hq, D) bf16/f32; slots/strips/markers as above (int16 views of
    bf16 K/V data); valid_tokens: (2*n_slots,) int32 valid count per
    logical page (0 for absent pages).
    Returns (Hq, D) float32 attention output.
    """
    n_slots, page, Hkv, D2 = slots.shape
    D = D2 // 2
    Hq = q.shape[0]
    G = Hq // Hkv
    pages, _ = materialize_kv_ref(slots, strips, markers)
    kv = pages.view(jnp.bfloat16).astype(jnp.float32)  # (P2, page, Hkv, D2)
    k = kv[..., :D]
    v = kv[..., D:]
    P2 = 2 * n_slots
    k = k.reshape(P2 * page, Hkv, D)
    v = v.reshape(P2 * page, Hkv, D)
    mask = (jnp.arange(page)[None, :]
            < valid_tokens[:, None]).reshape(P2 * page)
    kg = jnp.repeat(k, G, axis=1)                      # (T, Hq, D)
    vg = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("hd,thd->ht", q.astype(jnp.float32), kg)
    s = s / jnp.sqrt(jnp.float32(D))
    s = jnp.where(mask[None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("ht,thd->hd", p, vg)
