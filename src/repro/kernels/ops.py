"""Public jit'd wrappers around the CRAM-KV Pallas kernels.

`build_cram_cache` packs logical KV pages pairwise into physical slots
(raw when the pair doesn't fit), writing base strips + in-band markers.
`decode_attention` runs the fused marker-check/unpack/flash-decode kernel,
vmapped over batch.  Both default to interpret mode off-TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import ref as _ref
from .bdi_pack import pack_pair
from .cram_attention import cram_decode_attention
from .ref import MARKER_LANES, marker_to_lanes, slot_markers


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def _pack_all(pages, markers_i16, *, interpret=True):
    """pages: (2n, page, Hkv, D2) int16 -> (slots, strips, packed_mask)."""
    a, b = pages[0::2], pages[1::2]
    packed, base, ok = jax.vmap(
        lambda x, y: pack_pair(x, y, interpret=interpret))(a, b)
    slots = jnp.where(ok[:, None, None, None], packed, a)
    n, _, hkv, d2 = slots.shape
    strips = jnp.zeros((n, hkv, d2 + MARKER_LANES), jnp.int16)
    strips = strips.at[:, :, :d2].set(base)
    # in-band marker only when actually packed; raw slots keep a zero tail
    tail = jnp.broadcast_to(markers_i16[:, None, :], (n, hkv, MARKER_LANES))
    strips = strips.at[:, :, d2:].set(
        jnp.where(ok[:, None, None], tail, 0))
    return slots, strips, ok


def build_cram_cache(pages, *, key: int = 0x5EED, interpret=None):
    """Pack logical pages (2n, page, Hkv, D2) int16 into a CRAM cache.

    Returns dict(slots, strips, markers (int32), packed_mask, pages_valid):
    for raw pairs, the odd page is left unpacked and must live in its own
    slot — callers lay pages out so hot pairs are adjacent (the paper's
    restricted mapping).  Here the second page of a non-fitting pair is
    stored raw in the *next* slot, mirroring the uncompressed layout.
    """
    if interpret is None:
        interpret = default_interpret()
    n2, page, hkv, d2 = pages.shape
    assert n2 % 2 == 0
    markers = slot_markers(n2 // 2, key)
    mk_lanes = jnp.asarray(marker_to_lanes(markers))
    slots, strips, ok = _pack_all(pages, mk_lanes, interpret=interpret)
    # raw layout for the non-fitting pairs: two slots, one page each
    raw_b = pages[1::2]
    slots_b = jnp.where(ok[:, None, None, None],
                        jnp.zeros_like(raw_b), raw_b)
    return {
        "slots": slots,
        "slots_overflow": slots_b,      # page B of unpacked pairs
        "strips": strips,
        "markers": jnp.asarray(markers.view(np.int32)),
        "packed_mask": ok,
    }


def physical_view(cache, valid_per_page):
    """Flatten the cache to the slot list the decode kernel walks.

    Packed pair -> 1 slot holding 2 pages; raw pair -> 2 slots (A, B).
    Returns (slots, strips, markers, valid (n,2)) covering every page.
    """
    slots = cache["slots"]
    over = cache["slots_overflow"]
    strips = cache["strips"]
    markers = cache["markers"]
    ok = cache["packed_mask"]
    n, page, hkv, d2 = slots.shape
    vp = valid_per_page.reshape(n, 2)
    # slot stream: [slot_i, overflow_i] for every pair; overflow slots of
    # packed pairs carry zero valid tokens (masked out).
    all_slots = jnp.stack([slots, over], 1).reshape(2 * n, page, hkv, d2)
    zstrip = jnp.zeros_like(strips)
    all_strips = jnp.stack([strips, zstrip], 1).reshape(
        2 * n, hkv, d2 + MARKER_LANES)
    all_markers = jnp.stack([markers, markers], 1).reshape(2 * n)
    v_packed = jnp.stack([vp[:, 0], vp[:, 1]], 1)          # in slot A
    v_raw_a = jnp.stack([vp[:, 0], jnp.zeros_like(vp[:, 0])], 1)
    v_raw_b = jnp.stack([vp[:, 1], jnp.zeros_like(vp[:, 1])], 1)
    va = jnp.where(ok[:, None], v_packed, v_raw_a)
    vb = jnp.where(ok[:, None], jnp.zeros_like(v_raw_b), v_raw_b)
    valid = jnp.stack([va, vb], 1).reshape(2 * n, 2)
    return all_slots, all_strips, all_markers, valid


def decode_attention(q, cache, valid_per_page, *, interpret=None):
    """q: (B, Hq, D) bf16; returns (B, Hq, D) float32."""
    if interpret is None:
        interpret = default_interpret()
    slots, strips, markers, valid = physical_view(cache, valid_per_page)
    fn = lambda qi: cram_decode_attention(
        qi, slots, strips, markers, valid, interpret=interpret)
    return jax.vmap(fn)(q)


def decode_attention_ref(q, cache, valid_per_page):
    """Oracle path (pure jnp) over the same physical cache view."""
    slots, strips, markers, valid = physical_view(cache, valid_per_page)
    valid_flat = valid.reshape(-1)
    fn = lambda qi: _ref.cram_decode_attention_ref(
        qi, slots, strips,
        jnp.asarray(np.asarray(markers).view(np.uint32)), valid_flat)
    return jax.vmap(fn)(q)


def hbm_bytes_moved(cache, valid_per_page) -> dict:
    """Bandwidth accounting: bytes a decode step DMAs with/without CRAM.

    raw  : one slot per live page (uncompressed layout, no strips)
    CRAM : packed pair -> ONE slot + strip serves both pages (the paper's
           one-access-two-lines win); unpacked pair -> one slot + strip per
           live page (the strip read is the in-band metadata overhead,
           ~1/page of a slot).
    """
    slots = cache["slots"]
    ok = np.asarray(cache["packed_mask"])
    n, page, hkv, d2 = slots.shape
    slot_bytes = page * hkv * d2 * 2
    strip_bytes = hkv * (d2 + MARKER_LANES) * 2
    v = np.asarray(valid_per_page).reshape(n, 2)
    live = v > 0
    raw = int(live.sum()) * slot_bytes
    cram = 0
    for i in range(n):
        if not live[i].any():
            continue
        if ok[i]:
            cram += slot_bytes + strip_bytes
        else:
            cram += int(live[i].sum()) * (slot_bytes + strip_bytes)
    return {"raw_bytes": raw, "cram_bytes": cram,
            "saving": 1.0 - cram / max(raw, 1)}
