"""Public jit'd wrappers around the CRAM-KV Pallas kernels.

`build_cram_cache` packs logical KV pages pairwise into physical slots
(raw when the pair doesn't fit), writing base strips + in-band markers;
`build_cram_cache_quad` is the 4:1 analogue over page quads (int4-delta
codec, quad-domain markers).  `pack_window` / `pack_quad_window` /
`raw_window` / `raw_quad_window` are the incremental variants: they
(re)pack only a gathered window of dirty groups, batched over sequences,
so a decode step costs O(new groups) instead of a full rebuild.
`decode_attention_fused` runs the batched 2-D grid kernel
(`cram_decode_attention_batched`) over per-sequence caches and returns
the attention output TOGETHER with the per-sequence (raw, cram)
bytes-moved the kernel measured for exactly the layout it walked —
`decode_attention` / `decode_attention_batched` /
`decode_attention_quad_batched` are thin aliases that drop the bytes.
`hbm_bytes_moved` is the standalone jitted, lanes-aware bandwidth
reduction (same model, incl. the LLP-mispredict re-probe): the kernel
byte output matches it bit-exactly (pinned by tests).  All kernels
default to interpret mode off-TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import ref as _ref
from .bdi_pack import pack_pair, pack_quad
from .cram_attention import (cram_decode_attention,
                             cram_decode_attention_batched)
from ..compression.framing import DEFAULT_MARKER_KEY
from .ref import MARKER_LANES, marker_to_lanes, slot_markers


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def _pack_all(pages, markers_i16, *, interpret=True):
    """pages: (2n, page, Hkv, D2) int16 -> (slots, strips, packed_mask)."""
    a, b = pages[0::2], pages[1::2]
    packed, base, ok = jax.vmap(
        lambda x, y: pack_pair(x, y, interpret=interpret))(a, b)
    slots = jnp.where(ok[:, None, None, None], packed, a)
    n, _, hkv, d2 = slots.shape
    strips = jnp.zeros((n, hkv, d2 + MARKER_LANES), jnp.int16)
    strips = strips.at[:, :, :d2].set(base)
    # in-band marker only when actually packed; raw slots keep a zero tail
    tail = jnp.broadcast_to(markers_i16[:, None, :], (n, hkv, MARKER_LANES))
    strips = strips.at[:, :, d2:].set(
        jnp.where(ok[:, None, None], tail, 0))
    return slots, strips, ok


def build_cram_cache(pages, *, key: int = DEFAULT_MARKER_KEY, interpret=None):
    """Pack logical pages (2n, page, Hkv, D2) int16 into a CRAM cache.

    Returns dict(slots, strips, markers (int32), packed_mask, pages_valid):
    for raw pairs, the odd page is left unpacked and must live in its own
    slot — callers lay pages out so hot pairs are adjacent (the paper's
    restricted mapping).  Here the second page of a non-fitting pair is
    stored raw in the *next* slot, mirroring the uncompressed layout.
    """
    if interpret is None:
        interpret = default_interpret()
    n2, page, hkv, d2 = pages.shape
    assert n2 % 2 == 0
    markers = slot_markers(n2 // 2, key)
    mk_lanes = jnp.asarray(marker_to_lanes(markers))
    slots, strips, ok = _pack_all(pages, mk_lanes, interpret=interpret)
    # raw layout for the non-fitting pairs: two slots, one page each
    raw_b = pages[1::2]
    slots_b = jnp.where(ok[:, None, None, None],
                        jnp.zeros_like(raw_b), raw_b)
    return {
        "slots": slots,
        "slots_overflow": slots_b,      # page B of unpacked pairs
        "strips": strips,
        "markers": jnp.asarray(markers.view(np.int32)),
        "packed_mask": ok,
    }


@functools.partial(jax.jit, static_argnames=("interpret",))
def pack_window(a, b, marker_lanes, enabled, *, interpret=True):
    """Incrementally (re)pack a gathered window of dirty page pairs.

    a/b: (B, W, page, Hkv, D2) int16 — pageA/pageB of each dirty pair;
    marker_lanes: (W, MARKER_LANES) int16 per-pair marker lanes (shared
    across the batch); enabled: (B,) bool per-sequence compression gate.

    Pack *fitness* is measured for every pair regardless of the gate (the
    §VI dynamic controller samples fitness even while disabled so it can
    re-enable); the *layout* honors the gate: disabled sequences store the
    raw two-slot layout with zeroed strips, exactly as a full rebuild with
    compression off would.

    Returns (slots, overflow, strips, layout_packed (B, W), fit (B, W)).
    """
    packed, base, fit = jax.vmap(jax.vmap(
        lambda x, y: pack_pair(x, y, interpret=interpret)))(a, b)
    bsz, w, _, hkv, d2 = a.shape
    lay = fit & enabled[:, None]
    sel = lay[:, :, None, None, None]
    slots = jnp.where(sel, packed, a)
    over = jnp.where(sel, jnp.zeros_like(b), b)
    strips = jnp.zeros((bsz, w, hkv, d2 + MARKER_LANES), jnp.int16)
    strips = strips.at[..., :d2].set(base)
    tail = jnp.broadcast_to(marker_lanes[None, :, None, :],
                            (bsz, w, hkv, MARKER_LANES))
    strips = strips.at[..., d2:].set(jnp.where(lay[:, :, None, None],
                                               tail, 0))
    strips = jnp.where(enabled[:, None, None, None], strips, 0)
    return slots, over, strips, lay, fit


@jax.jit
def raw_window(a, b):
    """Raw layout for a window of pairs — never touches the pack kernel.

    The `policy="off"` path: pageA/pageB land in their own slots, strips
    zeroed, nothing packed and no fitness measured.
    """
    bsz, w = a.shape[:2]
    hkv, d2 = a.shape[-2:]
    strips = jnp.zeros((bsz, w, hkv, d2 + MARKER_LANES), jnp.int16)
    none = jnp.zeros((bsz, w), bool)
    return a, b, strips, none, none


@functools.partial(jax.jit, static_argnames=("interpret",))
def pack_quad_window(pages, marker_lanes, enabled, *, interpret=True):
    """Incrementally (re)pack a gathered window of dirty page QUADS.

    pages: (B, W, 4, page, Hkv, D2) int16 — the four lanes of each dirty
    group; marker_lanes: (W, MARKER_LANES) int16 per-group quad-domain
    marker lanes; enabled: (B,) bool per-sequence gate.  Same gate
    semantics as pack_window: fitness measured regardless, layout honors
    the gate, disabled sequences get the raw layout with zeroed strips.

    Returns (slots, overflow (B, W, 3, ...), strips, layout_packed, fit).
    """
    a, b, c, d = (pages[:, :, j] for j in range(4))
    packed, base, fit = jax.vmap(jax.vmap(
        lambda w, x, y, z: pack_quad(w, x, y, z, interpret=interpret)))(
        a, b, c, d)
    bsz, w = a.shape[:2]
    hkv, d2 = a.shape[-2:]
    lay = fit & enabled[:, None]
    sel = lay[:, :, None, None, None]
    slots = jnp.where(sel, packed, a)
    over = jnp.where(lay[:, :, None, None, None, None],
                     jnp.zeros_like(pages[:, :, 1:]), pages[:, :, 1:])
    strips = jnp.zeros((bsz, w, hkv, d2 + MARKER_LANES), jnp.int16)
    strips = strips.at[..., :d2].set(base)
    tail = jnp.broadcast_to(marker_lanes[None, :, None, :],
                            (bsz, w, hkv, MARKER_LANES))
    strips = strips.at[..., d2:].set(jnp.where(lay[:, :, None, None],
                                               tail, 0))
    strips = jnp.where(enabled[:, None, None, None], strips, 0)
    return slots, over, strips, lay, fit


@jax.jit
def raw_quad_window(pages):
    """Raw layout for a window of quads (`policy="off"`): every page in its
    own slot, strips zeroed, no fitness measured."""
    bsz, w = pages.shape[:2]
    hkv, d2 = pages.shape[-2:]
    strips = jnp.zeros((bsz, w, hkv, d2 + MARKER_LANES), jnp.int16)
    none = jnp.zeros((bsz, w), bool)
    return pages[:, :, 0], pages[:, :, 1:], strips, none, none


def layout_window(win, marker_lanes, enabled, *, use_pack, interpret=True):
    """Dispatch one gathered dirty window to the right layout kernel.

    win: (B, W, lanes, page, Hkv, D2) int16 — lanes (2 or 4) selects the
    pair/quad family; `use_pack=False` is the `policy="off"` path (raw
    layout, never launches the pack kernel).  The shared entry for the
    incremental repack and the fused serve megastep — one place owns the
    pack/raw x pair/quad product.  Returns the five window outputs
    (slots, overflow, strips, layout_packed, fit)."""
    lanes = win.shape[2]
    assert lanes in (2, 4), lanes
    if lanes == 2:
        if not use_pack:
            return raw_window(win[:, :, 0], win[:, :, 1])
        return pack_window(win[:, :, 0], win[:, :, 1], marker_lanes,
                           enabled, interpret=interpret)
    if not use_pack:
        return raw_quad_window(win)
    return pack_quad_window(win, marker_lanes, enabled, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _pack_all_quad(pages, markers_i16, *, interpret=True):
    """pages: (4n, page, Hkv, D2) int16 -> (slots, overflow, strips, ok)."""
    a, b, c, d = pages[0::4], pages[1::4], pages[2::4], pages[3::4]
    packed, base, ok = jax.vmap(
        lambda w, x, y, z: pack_quad(w, x, y, z, interpret=interpret))(
        a, b, c, d)
    sel = ok[:, None, None, None]
    slots = jnp.where(sel, packed, a)
    over_pages = jnp.stack([b, c, d], axis=1)         # (n, 3, page, ...)
    over = jnp.where(ok[:, None, None, None, None],
                     jnp.zeros_like(over_pages), over_pages)
    n, _, hkv, d2 = slots.shape
    strips = jnp.zeros((n, hkv, d2 + MARKER_LANES), jnp.int16)
    strips = strips.at[:, :, :d2].set(base)
    tail = jnp.broadcast_to(markers_i16[:, None, :], (n, hkv, MARKER_LANES))
    strips = strips.at[:, :, d2:].set(jnp.where(ok[:, None, None], tail, 0))
    return slots, over, strips, ok


def build_cram_cache_quad(pages, *, key: int = DEFAULT_MARKER_KEY, interpret=None):
    """Pack logical pages (4n, page, Hkv, D2) int16 into a quad CRAM cache.

    The 4:1 analogue of build_cram_cache: groups of four consecutive pages
    pack into ONE slot via the int4-delta codec when they fit; non-fitting
    groups store all four pages raw (lead slot + 3 overflow slots).
    Markers come from the quad domain so a slot's pair marker can never
    alias its quad marker.
    """
    from ..compression.framing import DOMAIN_QUAD

    if interpret is None:
        interpret = default_interpret()
    n4 = pages.shape[0]
    assert n4 % 4 == 0
    markers = slot_markers(n4 // 4, key, domain=DOMAIN_QUAD)
    mk_lanes = jnp.asarray(marker_to_lanes(markers))
    slots, over, strips, ok = _pack_all_quad(pages, mk_lanes,
                                             interpret=interpret)
    return {
        "slots": slots,
        "slots_overflow": over,         # (n, 3, page, ...) lanes B/C/D
        "strips": strips,
        "markers": jnp.asarray(markers.view(np.int32)),
        "packed_mask": ok,
    }


def physical_view(cache, valid_per_page):
    """Flatten the cache to the slot list the decode kernel walks.

    Packed pair -> 1 slot holding 2 pages; raw pair -> 2 slots (A, B).
    Returns (slots, strips, markers, valid (n,2)) covering every page.
    """
    slots = cache["slots"]
    over = cache["slots_overflow"]
    strips = cache["strips"]
    markers = cache["markers"]
    ok = cache["packed_mask"]
    n, page, hkv, d2 = slots.shape
    vp = valid_per_page.reshape(n, 2)
    # slot stream: [slot_i, overflow_i] for every pair; overflow slots of
    # packed pairs carry zero valid tokens (masked out).
    all_slots = jnp.stack([slots, over], 1).reshape(2 * n, page, hkv, d2)
    zstrip = jnp.zeros_like(strips)
    all_strips = jnp.stack([strips, zstrip], 1).reshape(
        2 * n, hkv, d2 + MARKER_LANES)
    all_markers = jnp.stack([markers, markers], 1).reshape(2 * n)
    v_packed = jnp.stack([vp[:, 0], vp[:, 1]], 1)          # in slot A
    v_raw_a = jnp.stack([vp[:, 0], jnp.zeros_like(vp[:, 0])], 1)
    v_raw_b = jnp.stack([vp[:, 1], jnp.zeros_like(vp[:, 1])], 1)
    va = jnp.where(ok[:, None], v_packed, v_raw_a)
    vb = jnp.where(ok[:, None], jnp.zeros_like(v_raw_b), v_raw_b)
    valid = jnp.stack([va, vb], 1).reshape(2 * n, 2)
    return all_slots, all_strips, all_markers, valid


def decode_attention(q, cache, valid_per_page, *, interpret=None):
    """q: (B, Hq, D) bf16 over ONE shared cache; returns (B, Hq, D)
    float32.  Thin alias over `decode_attention_fused` (bytes dropped)."""
    out, _, _ = decode_attention_fused(q, cache, valid_per_page,
                                       lanes=2, interpret=interpret)
    return out


def decode_attention_ref(q, cache, valid_per_page):
    """Oracle path (pure jnp) over the same physical cache view."""
    slots, strips, markers, valid = physical_view(cache, valid_per_page)
    valid_flat = valid.reshape(-1)
    fn = lambda qi: _ref.cram_decode_attention_ref(
        qi, slots, strips,
        jnp.asarray(np.asarray(markers).view(np.uint32)), valid_flat)
    return jax.vmap(fn)(q)


def decode_attention_batched(q, cache, valid_per_page, *, interpret=None):
    """Per-sequence decode: q (B, Hq, D), cache leaves carry a leading
    batch axis except `markers` (per-pair values, shared across sequences);
    valid_per_page (B, 2n).  Returns (B, Hq, D) float32.  Thin alias over
    `decode_attention_fused` (bytes dropped)."""
    out, _, _ = decode_attention_fused(q, cache, valid_per_page,
                                       lanes=2, interpret=interpret)
    return out


def decode_attention_ref_batched(q, cache, valid_per_page):
    """Oracle counterpart of decode_attention_batched (pure jnp)."""
    markers_u = jnp.asarray(np.asarray(cache["markers"]).view(np.uint32))

    def one(qi, slots, over, strips, ok, vp):
        c = {"slots": slots, "slots_overflow": over, "strips": strips,
             "markers": cache["markers"], "packed_mask": ok}
        s, st, _, v = physical_view(c, vp)
        mk = jnp.stack([markers_u, markers_u], 1).reshape(-1)
        return _ref.cram_decode_attention_ref(qi, s, st, mk, v.reshape(-1))

    return jax.vmap(one)(q, cache["slots"], cache["slots_overflow"],
                         cache["strips"], cache["packed_mask"],
                         jnp.asarray(valid_per_page))


def physical_view_quad(cache, valid_per_page):
    """Quad analogue of physical_view: flatten to the slot list the decode
    kernel walks.  Packed group -> 1 slot holding 4 pages; raw group -> 4
    slots (lead + 3 overflow).  Returns (slots, strips, markers,
    valid (4n, 4)) covering every page."""
    slots = cache["slots"]                  # (n, page, hkv, d2)
    over = cache["slots_overflow"]          # (n, 3, page, hkv, d2)
    strips = cache["strips"]
    markers = cache["markers"]
    ok = cache["packed_mask"]
    n, page, hkv, d2 = slots.shape
    vp = valid_per_page.reshape(n, 4)
    all_slots = jnp.concatenate([slots[:, None], over], axis=1)
    all_slots = all_slots.reshape(4 * n, page, hkv, d2)
    zstrip = jnp.zeros_like(strips)
    all_strips = jnp.stack([strips, zstrip, zstrip, zstrip], 1).reshape(
        4 * n, hkv, d2 + MARKER_LANES)
    all_markers = jnp.repeat(markers, 4)
    zero = jnp.zeros_like(vp[:, 0])
    # lead slot: all four pages when packed, lane A only when raw
    v_lead_raw = jnp.stack([vp[:, 0], zero, zero, zero], 1)
    v_lead = jnp.where(ok[:, None], vp, v_lead_raw)
    # overflow slot j: lane j+1 when raw, dead when packed
    v_over = [
        jnp.where(ok[:, None],
                  jnp.zeros((n, 4), vp.dtype),
                  jnp.stack([vp[:, j + 1], zero, zero, zero], 1))
        for j in range(3)
    ]
    valid = jnp.stack([v_lead, *v_over], 1).reshape(4 * n, 4)
    return all_slots, all_strips, all_markers, valid


def decode_attention_quad_batched(q, cache, valid_per_page, *,
                                  interpret=None):
    """Per-sequence decode over a quad cache: q (B, Hq, D); cache leaves
    carry a leading batch axis except `markers`; valid_per_page (B, 4n).
    Thin alias over `decode_attention_fused` (bytes dropped)."""
    out, _, _ = decode_attention_fused(q, cache, valid_per_page,
                                       lanes=4, interpret=interpret)
    return out


def decode_attention_quad_ref_batched(q, cache, valid_per_page):
    """Oracle counterpart of decode_attention_quad_batched (pure jnp)."""
    markers_u = jnp.asarray(np.asarray(cache["markers"]).view(np.uint32))

    def one(qi, slots, over, strips, ok, vp):
        c = {"slots": slots, "slots_overflow": over, "strips": strips,
             "markers": cache["markers"], "packed_mask": ok}
        s, st, _, v = physical_view_quad(c, vp)
        mk = jnp.repeat(markers_u, 4)
        return _ref.cram_decode_attention_ref(qi, s, st, mk, v.reshape(-1),
                                              lanes=4)

    return jax.vmap(one)(q, cache["slots"], cache["slots_overflow"],
                         cache["strips"], cache["packed_mask"],
                         jnp.asarray(valid_per_page))


@functools.partial(jax.jit, static_argnames=("lanes", "block_groups",
                                             "interpret"))
def decode_attention_fused(q, cache, valid_per_page, predictor=None, *,
                           lanes: int = 2, block_groups: int | None = None,
                           interpret: bool | None = None):
    """The serve decode step as ONE device program: batched 2-D grid
    attention over the physical slot view + per-sequence bytes-moved.

    q (B, Hq, D); cache leaves carry a leading batch axis (per-sequence
    caches) or none (one shared cache walked by every query row) except
    `markers`, which is always shared; valid_per_page (B?, lanes * n)
    valid tokens per logical page; `predictor` is the (B?, n) predicted
    group packedness (the LLP analog) — None means a perfect predictor
    (no re-probe charge).  Returns (out (B, Hq, D) float32, raw_per_seq
    (B,) int32, cram_per_seq (B,) int32) where the byte columns are
    bit-identical to `hbm_bytes_moved`'s per-sequence totals for the
    same masks — measured by the kernel for the layout it walked, not by
    a second pass over the state.
    """
    if interpret is None:               # static arg: resolved at trace time
        interpret = default_interpret()
    pv = physical_view if lanes == 2 else physical_view_quad
    markers = cache["markers"]
    vp = jnp.asarray(valid_per_page)
    pred = cache["packed_mask"] if predictor is None else predictor
    if cache["slots"].ndim == 5:        # per-sequence caches
        def one(slots, over, strips, ok, vpi):
            c = {"slots": slots, "slots_overflow": over, "strips": strips,
                 "markers": markers, "packed_mask": ok}
            s, st, _, v = pv(c, vpi)
            return s, st, v

        s, st, v = jax.vmap(one)(cache["slots"], cache["slots_overflow"],
                                 cache["strips"], cache["packed_mask"], vp)
        mk = (jnp.stack([markers, markers], 1).reshape(-1) if lanes == 2
              else jnp.repeat(markers, lanes))
        out, bts = cram_decode_attention_batched(
            q, s, st, mk, v, pred, lanes=lanes, block_groups=block_groups,
            interpret=interpret)
    else:                               # one shared cache
        s, st, mk, v = pv(cache, vp)
        out, bts = cram_decode_attention_batched(
            q, s, st, mk, v, pred, lanes=lanes, block_groups=block_groups,
            shared_cache=True, interpret=interpret)
    return out, bts[:, 0], bts[:, 1]


@functools.partial(jax.jit, static_argnames=("slot_bytes", "strip_bytes"))
def _bytes_moved(packed_mask, live, predicted, *, slot_bytes, strip_bytes):
    """Jitted reduction over (..., n) pair masks -> (raw, cram) byte totals
    per leading batch element (scalar when unbatched)."""
    any_live = live.any(-1)
    n_live = live.sum(-1)
    raw = (n_live * slot_bytes).sum(-1)
    per_pair = jnp.where(packed_mask, slot_bytes + strip_bytes,
                         n_live * (slot_bytes + strip_bytes))
    # LLP-miss re-probe: a pair whose predicted packedness disagrees with
    # its actual layout costs one extra slot DMA on this access.
    reprobe = jnp.where(predicted != packed_mask, slot_bytes, 0)
    cram = jnp.where(any_live, per_pair + reprobe, 0).sum(-1)
    return raw, cram


def hbm_bytes_moved_device(cache, valid_per_page, predictor=None,
                           lanes: int = 2):
    """`hbm_bytes_moved` without the host sync: returns the per-sequence
    (raw, cram) int32 device arrays (scalars when unbatched), so jitted
    serve paths can fold them into a device accumulator instead of
    round-tripping to python ints every step."""
    slots = cache["slots"]
    page, hkv, d2 = slots.shape[-3:]
    slot_bytes = page * hkv * d2 * 2
    strip_bytes = hkv * (d2 + MARKER_LANES) * 2
    ok = jnp.asarray(cache["packed_mask"])
    v = jnp.asarray(valid_per_page).reshape(ok.shape + (lanes,))
    pred = ok if predictor is None else jnp.asarray(predictor)
    return _bytes_moved(ok, v > 0, pred, slot_bytes=slot_bytes,
                        strip_bytes=strip_bytes)


def hbm_bytes_moved(cache, valid_per_page, predictor=None,
                    lanes: int = 2) -> dict:
    """Bandwidth accounting: bytes a decode step DMAs with/without CRAM.

    raw  : one slot per live page (uncompressed layout, no strips)
    CRAM : packed group -> ONE slot + strip serves all `lanes` pages (the
           paper's one-access-N-lines win); unpacked group -> one slot +
           strip per live page (the strip read is the in-band metadata
           overhead, ~1/page of a slot); a *mispredicted* live group — the
           LLP analog predicted the wrong packedness — costs one extra
           slot DMA (the paper's LLP-miss re-probe).

    `predictor` is the (…, n) predicted packed-mask; None means a perfect
    predictor (no re-probe charge).  `lanes` is the group width (2 for the
    pair layout, 4 for quad).  Leading batch axes are reduced per sequence
    and summed into the scalar totals.
    """
    raw, cram = hbm_bytes_moved_device(cache, valid_per_page, predictor,
                                       lanes)
    raw_i, cram_i = int(raw.sum()), int(cram.sum())
    return {"raw_bytes": raw_i, "cram_bytes": cram_i,
            "raw_per_seq": np.asarray(raw), "cram_per_seq": np.asarray(cram),
            "saving": 1.0 - cram_i / max(raw_i, 1)}
