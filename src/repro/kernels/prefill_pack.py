"""Bulk chunked-prefill packing: compress a whole prompt in ONE launch.

The decode path packs incrementally — O(new groups) per token via
`layout_window` over the dirty columns.  Prefill is the bulk-transfer
dual: a T-token prompt lands as one scatter and every page group it
touches is codec-tried, marker-framed, and slot-placed in a single
vmapped pallas_call (the same registry codecs as the incremental path:
pair int8-delta, quad int4-delta).  A partial tail page arrives
zero-padded in its group and simply fails the fit check, staying raw —
exactly what the token-by-token replay would converge to, which is what
makes the fused path bit-identical to the append oracle.

`prefill_pack` is the kernel-layer entry; `SlotKVCache._prefill` fuses
it with the prompt scatter, traffic booking, and §VI counter update in
one donated dispatch (pinned by the `serve_prefill` jaxpr-audit golden:
one pallas_call, donation, zero host callbacks).
"""

from __future__ import annotations

import functools

import jax

from .ops import layout_window


@functools.partial(
    jax.jit, static_argnames=("lanes", "page", "use_pack", "interpret"))
def prefill_pack(pages, idx, marker_lanes, enabled, *, lanes, page,
                 use_pack=True, interpret=True):
    """Pack every touched page group of a freshly scattered prompt at once.

    pages:        (B, max_tokens, Hkv, D2) int16 logical page buffer AFTER
                  the prompt rows were scattered in (token-major;
                  max_tokens = n_groups * lanes * page)
    idx:          (W,) int32 touched group columns — the prompt's page run,
                  padded to a power of two by the caller (pad repeats a
                  real column, so relaying it is idempotent)
    marker_lanes: (n_groups, MARKER_LANES) int16 in-band marker words
    enabled:      (B,) bool §VI gate per slot

    Returns `(slots_w, over_w, strips_w, lay, fit)` for the W touched
    columns, same contract as `layout_window`: fitness measured regardless
    of the gate, layout honors it, markers written in-band only where laid.
    """
    b, max_tokens, hkv, d2 = pages.shape
    n_groups = max_tokens // (lanes * page)
    groups = pages.reshape(b, n_groups, lanes, page, hkv, d2)
    win = groups[:, idx]
    return layout_window(win, marker_lanes[idx], enabled,
                         use_pack=use_pack, interpret=interpret)
