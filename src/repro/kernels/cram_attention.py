"""Pallas TPU kernels: fused CRAM decode attention.

Flash-decode over a CRAM-packed paged KV cache: the grid walks physical
slots; each program DMAs a *block* of slots + their base strips into
VMEM, checks the strip-tail markers (implicit metadata — no separate
status fetch), inlines the delta unpack for packed slots (one DMA yields
TWO pages for the int8-delta pair codec or FOUR for the int4-delta quad
codec: the paper's bandwidth win), and accumulates online-softmax
partials in VMEM scratch.  The final step normalizes into the output.

Two kernels:

  * `cram_decode_attention` — the original single-sequence kernel,
    `grid=(n_slots,)`, one slot per program.  Kept as the bit-true
    reference for the batched kernel (tests pin new-vs-old parity) and
    for callers that walk one sequence.
  * `cram_decode_attention_batched` — the serve-path kernel: a 2-D grid
    `(batch, slot_block)` where each program DMAs `block_groups *
    lanes` slots of one sequence under tunable BlockSpecs (swept by
    `benchmarks/kernel_bench.py`, snapshot in BENCH_kernels.json).  It
    emits a SECOND output: per-sequence (raw, cram) bytes-moved for
    exactly the layout the kernel walked — packed slot+strip vs raw
    slots, including the LLP-mispredict re-probe term — so the serve
    loop's bandwidth accounting is a kernel by-product instead of a
    separate pass over the same state (`kernels/ops.hbm_bytes_moved`
    stays as the standalone/oracle reduction; the kernel output matches
    it bit-exactly, pinned by tests/test_attention_numerics.py).

The raw/packed selection is a jnp.where over both interpretations — on
real TPU hardware this becomes a pl.when branch; in interpret mode the
select keeps the kernel body simple and the numerics identical (noted in
DESIGN.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import MARKER_LANES

NEG_INF = -1e30

# Default slot-block width (page groups per program) for the batched
# kernel.  Swept by benchmarks/kernel_bench.blockspec_sweep; the committed
# BENCH_kernels.json records the measured curve this default came from.
DEFAULT_BLOCK_GROUPS = 4


def _kernel(q_ref, slot_ref, strip_ref, marker_ref, valid_ref,
            out_ref, m_s, l_s, acc_s, *, lanes):
    i = pl.program_id(0)
    n = pl.num_programs(0)

    @pl.when(i == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s[...], NEG_INF)
        l_s[...] = jnp.zeros_like(l_s[...])
        acc_s[...] = jnp.zeros_like(acc_s[...])

    q = q_ref[...].astype(jnp.float32)              # (Hq, D)
    slot = slot_ref[0]                              # (page, Hkv, D2) int16
    strip = strip_ref[0]                            # (Hkv, D2+2) int16
    page, hkv, d2 = slot.shape
    d = d2 // 2
    hq = q.shape[0]
    g = hq // hkv

    # --- implicit metadata: compare the strip-tail marker lanes
    tail = strip[:, -MARKER_LANES:].astype(jnp.int32)
    tail_u = (tail[:, 0] & 0xFFFF) | ((tail[:, 1] & 0xFFFF) << 16)
    expected = marker_ref[0]
    is_packed = jnp.all(tail_u == expected)

    # --- decode both interpretations, select by marker
    base = strip[:, :d2].astype(jnp.int32)          # (Hkv, D2)
    v_u = jax.lax.bitcast_convert_type(slot, jnp.uint16).astype(jnp.int32)
    if lanes == 2:                                  # int8-delta pair codec
        lo = ((v_u & 0xFF) ^ 0x80) - 0x80
        hi = (((v_u >> 8) & 0xFF) ^ 0x80) - 0x80
        packed_pages = [base[None] + lo, base[None] + hi]
    else:                                           # int4-delta quad codec
        se4 = lambda x: (x ^ 0x8) - 0x8
        packed_pages = [base[None] + se4((v_u >> s) & 0xF)
                        for s in (0, 4, 8, 12)]
    zeros = jnp.zeros_like(slot)
    pages = [jnp.where(is_packed, p.astype(jnp.int16),
                       slot if j == 0 else zeros)
             for j, p in enumerate(packed_pages)]

    kv = jnp.stack(pages)                           # (lanes, page, Hkv, D2)
    kvf = jax.lax.bitcast_convert_type(kv, jnp.bfloat16).astype(jnp.float32)
    k = kvf[..., :d].reshape(lanes * page, hkv, d)
    v = kvf[..., d:].reshape(lanes * page, hkv, d)

    valid = valid_ref[0]                            # (lanes,) int32 per page
    tok = jax.lax.broadcasted_iota(jnp.int32, (lanes, page), 1)
    mask = (tok < valid[:, None]).reshape(lanes * page)

    kg = jnp.repeat(k, g, axis=1)                   # (T, Hq, D)
    vg = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("hd,thd->ht", q, kg,
                   preferred_element_type=jnp.float32)
    s = s * (1.0 / (d ** 0.5))
    s = jnp.where(mask[None, :], s, NEG_INF)

    m_prev = m_s[:, 0]
    l_prev = l_s[:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_prev * alpha + jnp.sum(p, axis=-1)
    acc_s[...] = acc_s[...] * alpha[:, None] + jnp.einsum(
        "ht,thd->hd", p, vg, preferred_element_type=jnp.float32)
    m_s[...] = m_new[:, None]
    l_s[...] = l_new[:, None]

    @pl.when(i == n - 1)
    def _finalize():
        out_ref[...] = acc_s[...] / jnp.maximum(l_s[...][:, 0:1], 1e-30)


@functools.partial(jax.jit, static_argnames=("lanes", "interpret"))
def cram_decode_attention(q, slots, strips, markers, valid, *,
                          lanes: int = 2, interpret: bool = True):
    """q (Hq, D); slots (n,page,Hkv,D2) i16; strips (n,Hkv,D2+2) i16;
    markers (n,) int32 (expected pack markers); valid (n,lanes) int32 valid
    tokens per logical page.  `lanes` selects the slot format: 2 = pair
    (int8-delta), 4 = quad (int4-delta).  Returns (Hq, D) float32."""
    n, page, hkv, d2 = slots.shape
    hq, d = q.shape
    assert lanes in (2, 4)
    return pl.pallas_call(
        functools.partial(_kernel, lanes=lanes),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((hq, d), lambda i: (0, 0)),
            pl.BlockSpec((1, page, hkv, d2), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, hkv, d2 + MARKER_LANES), lambda i: (i, 0, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1, lanes), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((hq, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((hq, d), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((hq, 1), jnp.float32),
            pltpu.VMEM((hq, 1), jnp.float32),
            pltpu.VMEM((hq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, slots, strips, markers, valid)


# --------------------------------------------------------- batched kernel


def _batched_kernel(q_ref, slot_ref, strip_ref, marker_ref, valid_ref,
                    pred_ref, out_ref, bytes_ref, m_s, l_s, acc_s, byt_s,
                    *, lanes, slot_bytes, strip_bytes):
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s[...], NEG_INF)
        l_s[...] = jnp.zeros_like(l_s[...])
        acc_s[...] = jnp.zeros_like(acc_s[...])
        byt_s[...] = jnp.zeros_like(byt_s[...])

    q = q_ref[0].astype(jnp.float32)                # (Hq, D)
    slots = slot_ref[0]                             # (K, page, Hkv, D2) i16
    strips = strip_ref[0]                           # (K, Hkv, D2+2) i16
    kk, page, hkv, d2 = slots.shape
    d = d2 // 2
    hq = q.shape[0]
    g = hq // hkv

    # --- implicit metadata: strip-tail marker lanes, one check per slot
    tail = strips[:, :, -MARKER_LANES:].astype(jnp.int32)   # (K, Hkv, 2)
    tail_u = (tail[..., 0] & 0xFFFF) | ((tail[..., 1] & 0xFFFF) << 16)
    expected = marker_ref[...]                      # (K,)
    is_packed = jnp.all(tail_u == expected[:, None], axis=-1)   # (K,)

    # --- decode both interpretations for the whole block, select by marker
    base = strips[:, :, :d2].astype(jnp.int32)      # (K, Hkv, D2)
    v_u = jax.lax.bitcast_convert_type(slots, jnp.uint16).astype(jnp.int32)
    if lanes == 2:                                  # int8-delta pair codec
        lo = ((v_u & 0xFF) ^ 0x80) - 0x80
        hi = (((v_u >> 8) & 0xFF) ^ 0x80) - 0x80
        packed_pages = [base[:, None] + lo, base[:, None] + hi]
    else:                                           # int4-delta quad codec
        se4 = lambda x: (x ^ 0x8) - 0x8
        packed_pages = [base[:, None] + se4((v_u >> s) & 0xF)
                        for s in (0, 4, 8, 12)]
    zeros = jnp.zeros_like(slots)
    sel = is_packed[:, None, None, None]
    pages = [jnp.where(sel, p.astype(jnp.int16),
                       slots if i == 0 else zeros)
             for i, p in enumerate(packed_pages)]

    kv = jnp.stack(pages, axis=1)                   # (K, lanes, page, ...)
    kvf = jax.lax.bitcast_convert_type(kv, jnp.bfloat16).astype(jnp.float32)
    k = kvf[..., :d].reshape(kk * lanes * page, hkv, d)
    v = kvf[..., d:].reshape(kk * lanes * page, hkv, d)

    valid = valid_ref[0]                            # (K, lanes) int32
    tok = jax.lax.broadcasted_iota(jnp.int32, (kk, lanes, page), 2)
    mask = (tok < valid[:, :, None]).reshape(kk * lanes * page)

    kg = jnp.repeat(k, g, axis=1)                   # (T, Hq, D)
    vg = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("hd,thd->ht", q, kg,
                   preferred_element_type=jnp.float32)
    s = s * (1.0 / (d ** 0.5))
    s = jnp.where(mask[None, :], s, NEG_INF)

    m_prev = m_s[:, 0]
    l_prev = l_s[:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_prev * alpha + jnp.sum(p, axis=-1)
    acc_s[...] = acc_s[...] * alpha[:, None] + jnp.einsum(
        "ht,thd->hd", p, vg, preferred_element_type=jnp.float32)
    m_s[...] = m_new[:, None]
    l_s[...] = l_new[:, None]

    # --- bytes-moved for exactly the layout this block walked.  Flat-slot
    # form of the `ops.hbm_bytes_moved` group model: the lead slot of a
    # packed group carries all `lanes` valid counts (overflow slots dead),
    # a raw group spreads one live page per slot — so per-slot sums equal
    # the per-group sums bit-for-bit.
    live = valid > 0                                # (K, lanes)
    n_live = live.sum(-1).astype(jnp.int32)         # (K,) live pages/slot
    raw_b = jnp.sum(n_live) * slot_bytes
    per_slot = jnp.where(is_packed & (n_live > 0),
                         slot_bytes + strip_bytes,
                         n_live * (slot_bytes + strip_bytes))
    # LLP-miss re-probe, charged once per mispredicted LIVE group: group
    # packedness is the lead slot's marker verdict, group liveness is the
    # union over the group's flat slots.
    gk = kk // lanes
    grp_packed = is_packed.reshape(gk, lanes)[:, 0]
    grp_live = live.reshape(gk, lanes * lanes).any(-1)
    pred = pred_ref[0] != 0                         # (gk,) predicted packed
    reprobe = jnp.where((pred != grp_packed) & grp_live, slot_bytes, 0)
    cram_b = jnp.sum(per_slot) + jnp.sum(reprobe)
    byt_s[...] += jnp.stack([raw_b, cram_b]).astype(jnp.int32)[None]

    @pl.when(j == nj - 1)
    def _finalize():
        out_ref[...] = (acc_s[...]
                        / jnp.maximum(l_s[...][:, 0:1], 1e-30))[None]
        bytes_ref[...] = byt_s[...]


def resolve_block_groups(n_groups: int, block_groups: int | None) -> int:
    """Largest divisor of `n_groups` not exceeding the requested width."""
    bg = DEFAULT_BLOCK_GROUPS if block_groups is None else block_groups
    bg = max(1, min(bg, n_groups))
    while n_groups % bg:
        bg -= 1
    return bg


@functools.partial(jax.jit, static_argnames=("lanes", "block_groups",
                                             "shared_cache", "interpret"))
def cram_decode_attention_batched(q, slots, strips, markers, valid,
                                  predictor, *, lanes: int = 2,
                                  block_groups: int | None = None,
                                  shared_cache: bool = False,
                                  interpret: bool = True):
    """Batched fused decode: one 2-D grid `(batch, slot_block)` program.

    q (B, Hq, D); slots (B, n, page, Hkv, D2) int16 — or (n, page, Hkv,
    D2) with `shared_cache=True` (every query row walks the same slot
    list); strips (B?, n, Hkv, D2+2); markers (n,) int32 shared across
    the batch; valid (B?, n, lanes) int32 valid tokens per logical page;
    predictor (B?, n // lanes) predicted group packedness (the LLP
    analog; pass the actual packed mask for a perfect predictor).

    Each program DMAs `block_groups * lanes` consecutive slots + strips
    of one sequence (`block_groups` is the tunable BlockSpec axis, swept
    by benchmarks/kernel_bench.py).  Returns (out (B, Hq, D) float32,
    bytes (B, 2) int32) where bytes[b] = (raw, cram) bytes one decode
    step DMAs for sequence b under the layout the kernel walked —
    bit-identical to `ops.hbm_bytes_moved` per-sequence totals.
    """
    assert lanes in (2, 4)
    b, hq, d = q.shape
    if shared_cache:
        slots, strips = slots[None], strips[None]
        valid, predictor = valid[None], predictor[None]
    _, n, page, hkv, d2 = slots.shape
    n_groups = n // lanes
    bg = resolve_block_groups(n_groups, block_groups)
    kk = bg * lanes
    nj = n // kk
    slot_bytes = page * hkv * d2 * 2
    strip_bytes = hkv * (d2 + MARKER_LANES) * 2
    pred = jnp.asarray(predictor).astype(jnp.int32)
    # shared caches keep one copy in HBM: the index map pins the batch
    # coordinate to 0 instead of materializing B replicas
    bix = (lambda bi: 0) if shared_cache else (lambda bi: bi)
    return pl.pallas_call(
        functools.partial(_batched_kernel, lanes=lanes,
                          slot_bytes=slot_bytes, strip_bytes=strip_bytes),
        grid=(b, nj),
        in_specs=[
            pl.BlockSpec((1, hq, d), lambda bi, j: (bi, 0, 0)),
            pl.BlockSpec((1, kk, page, hkv, d2),
                         lambda bi, j: (bix(bi), j, 0, 0, 0)),
            pl.BlockSpec((1, kk, hkv, d2 + MARKER_LANES),
                         lambda bi, j: (bix(bi), j, 0, 0)),
            pl.BlockSpec((kk,), lambda bi, j: (j,)),
            pl.BlockSpec((1, kk, lanes), lambda bi, j: (bix(bi), j, 0)),
            pl.BlockSpec((1, bg), lambda bi, j: (bix(bi), j)),
        ],
        out_specs=[
            pl.BlockSpec((1, hq, d), lambda bi, j: (bi, 0, 0)),
            pl.BlockSpec((1, 2), lambda bi, j: (bi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hq, d), jnp.float32),
            jax.ShapeDtypeStruct((b, 2), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((hq, 1), jnp.float32),
            pltpu.VMEM((hq, 1), jnp.float32),
            pltpu.VMEM((hq, d), jnp.float32),
            pltpu.VMEM((1, 2), jnp.int32),
        ],
        interpret=interpret,
    )(q, slots, strips, markers, valid, pred)
