"""Pallas TPU kernel: fused CRAM decode attention.

Flash-decode over a CRAM-packed paged KV cache: the grid walks physical
slots; each step DMAs one slot + its base strip into VMEM, checks the
strip-tail marker (implicit metadata — no separate status fetch), inlines
the delta unpack for packed slots (one DMA yields TWO pages for the
int8-delta pair codec or FOUR for the int4-delta quad codec: the paper's
bandwidth win), and accumulates online-softmax partials in VMEM scratch.
The final step normalizes into the output.

The raw/packed selection is a jnp.where over both interpretations — on
real TPU hardware this becomes a pl.when branch; in interpret mode the
select keeps the kernel body simple and the numerics identical (noted in
DESIGN.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import MARKER_LANES

NEG_INF = -1e30


def _kernel(q_ref, slot_ref, strip_ref, marker_ref, valid_ref,
            out_ref, m_s, l_s, acc_s, *, lanes):
    i = pl.program_id(0)
    n = pl.num_programs(0)

    @pl.when(i == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s[...], NEG_INF)
        l_s[...] = jnp.zeros_like(l_s[...])
        acc_s[...] = jnp.zeros_like(acc_s[...])

    q = q_ref[...].astype(jnp.float32)              # (Hq, D)
    slot = slot_ref[0]                              # (page, Hkv, D2) int16
    strip = strip_ref[0]                            # (Hkv, D2+2) int16
    page, hkv, d2 = slot.shape
    d = d2 // 2
    hq = q.shape[0]
    g = hq // hkv

    # --- implicit metadata: compare the strip-tail marker lanes
    tail = strip[:, -MARKER_LANES:].astype(jnp.int32)
    tail_u = (tail[:, 0] & 0xFFFF) | ((tail[:, 1] & 0xFFFF) << 16)
    expected = marker_ref[0]
    is_packed = jnp.all(tail_u == expected)

    # --- decode both interpretations, select by marker
    base = strip[:, :d2].astype(jnp.int32)          # (Hkv, D2)
    v_u = jax.lax.bitcast_convert_type(slot, jnp.uint16).astype(jnp.int32)
    if lanes == 2:                                  # int8-delta pair codec
        lo = ((v_u & 0xFF) ^ 0x80) - 0x80
        hi = (((v_u >> 8) & 0xFF) ^ 0x80) - 0x80
        packed_pages = [base[None] + lo, base[None] + hi]
    else:                                           # int4-delta quad codec
        se4 = lambda x: (x ^ 0x8) - 0x8
        packed_pages = [base[None] + se4((v_u >> s) & 0xF)
                        for s in (0, 4, 8, 12)]
    zeros = jnp.zeros_like(slot)
    pages = [jnp.where(is_packed, p.astype(jnp.int16),
                       slot if j == 0 else zeros)
             for j, p in enumerate(packed_pages)]

    kv = jnp.stack(pages)                           # (lanes, page, Hkv, D2)
    kvf = jax.lax.bitcast_convert_type(kv, jnp.bfloat16).astype(jnp.float32)
    k = kvf[..., :d].reshape(lanes * page, hkv, d)
    v = kvf[..., d:].reshape(lanes * page, hkv, d)

    valid = valid_ref[0]                            # (lanes,) int32 per page
    tok = jax.lax.broadcasted_iota(jnp.int32, (lanes, page), 1)
    mask = (tok < valid[:, None]).reshape(lanes * page)

    kg = jnp.repeat(k, g, axis=1)                   # (T, Hq, D)
    vg = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("hd,thd->ht", q, kg,
                   preferred_element_type=jnp.float32)
    s = s * (1.0 / (d ** 0.5))
    s = jnp.where(mask[None, :], s, NEG_INF)

    m_prev = m_s[:, 0]
    l_prev = l_s[:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_prev * alpha + jnp.sum(p, axis=-1)
    acc_s[...] = acc_s[...] * alpha[:, None] + jnp.einsum(
        "ht,thd->hd", p, vg, preferred_element_type=jnp.float32)
    m_s[...] = m_new[:, None]
    l_s[...] = l_new[:, None]

    @pl.when(i == n - 1)
    def _finalize():
        out_ref[...] = acc_s[...] / jnp.maximum(l_s[...][:, 0:1], 1e-30)


@functools.partial(jax.jit, static_argnames=("lanes", "interpret"))
def cram_decode_attention(q, slots, strips, markers, valid, *,
                          lanes: int = 2, interpret: bool = True):
    """q (Hq, D); slots (n,page,Hkv,D2) i16; strips (n,Hkv,D2+2) i16;
    markers (n,) int32 (expected pack markers); valid (n,lanes) int32 valid
    tokens per logical page.  `lanes` selects the slot format: 2 = pair
    (int8-delta), 4 = quad (int4-delta).  Returns (Hq, D) float32."""
    n, page, hkv, d2 = slots.shape
    hq, d = q.shape
    assert lanes in (2, 4)
    return pl.pallas_call(
        functools.partial(_kernel, lanes=lanes),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((hq, d), lambda i: (0, 0)),
            pl.BlockSpec((1, page, hkv, d2), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, hkv, d2 + MARKER_LANES), lambda i: (i, 0, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1, lanes), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((hq, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((hq, d), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((hq, 1), jnp.float32),
            pltpu.VMEM((hq, 1), jnp.float32),
            pltpu.VMEM((hq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, slots, strips, markers, valid)
