"""Pallas TPU kernels: BDI-style page packing of KV pages (CRAM-KV).

The device backends of the registered page codecs
(repro.compression.codecs):

  * int8-delta (pack_pair/unpack_pair) — packs a pair of (page, Hkv, D2)
    int16 pages into a single slot of int8 delta-pairs against a shared
    base strip (pageA's token-0 row), reporting whether the pair fits;
  * int4-delta (pack_quad/unpack_quad) — packs FOUR pages into one slot of
    int4 delta-nibbles against the same base (4:1).

Layout/semantics match the xp-generic bit-true reference in
repro.compression.pagepack (and its jnp wrappers in kernels/ref.py)
exactly — allclose-tested in interpret mode by the cross-backend
round-trip tests.

BlockSpec notes (TPU target): D2 = 2*head_dim = 256 lanes (2x the 128-lane
register width); the whole page tile lives in VMEM (128 x 8 x 256 x 2B =
512KB for the default page) — one slot is one DMA.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pack_kernel(a_ref, b_ref, packed_ref, base_ref, ok_ref):
    a = a_ref[...].astype(jnp.int32)         # (page, Hkv, D2)
    b = b_ref[...].astype(jnp.int32)
    base = a[0]                              # (Hkv, D2)
    da = a - base[None]
    db = b - base[None]
    ok = jnp.all((da >= -128) & (da <= 127)
                 & (db >= -128) & (db <= 127))
    packed = ((db & 0xFF) << 8) | (da & 0xFF)
    packed_ref[...] = jax.lax.bitcast_convert_type(
        packed.astype(jnp.uint16), jnp.int16)
    base_ref[...] = base.astype(jnp.int16)
    ok_ref[...] = jnp.full((1,), ok, jnp.int32)


def _unpack_kernel(packed_ref, base_ref, a_ref, b_ref):
    v = jax.lax.bitcast_convert_type(
        packed_ref[...], jnp.uint16).astype(jnp.int32)
    base = base_ref[...].astype(jnp.int32)
    lo = ((v & 0xFF) ^ 0x80) - 0x80          # sign-extend low byte
    hi = (((v >> 8) & 0xFF) ^ 0x80) - 0x80
    a_ref[...] = (base[None] + lo).astype(jnp.int16)
    b_ref[...] = (base[None] + hi).astype(jnp.int16)


@functools.partial(jax.jit, static_argnames=("interpret",))
def pack_pair(page_a, page_b, *, interpret: bool = True):
    """(page,Hkv,D2) int16 x2 -> (packed int16, base int16 (Hkv,D2), ok)."""
    page, hkv, d2 = page_a.shape
    packed, base, ok = pl.pallas_call(
        _pack_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((page, hkv, d2), jnp.int16),
            jax.ShapeDtypeStruct((hkv, d2), jnp.int16),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ),
        interpret=interpret,
    )(page_a, page_b)
    return packed, base, ok[0] > 0


@functools.partial(jax.jit, static_argnames=("interpret",))
def unpack_pair(packed, base, *, interpret: bool = True):
    page, hkv, d2 = packed.shape
    return pl.pallas_call(
        _unpack_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((page, hkv, d2), jnp.int16),
            jax.ShapeDtypeStruct((page, hkv, d2), jnp.int16),
        ),
        interpret=interpret,
    )(packed, base)


def _pack_quad_kernel(a_ref, b_ref, c_ref, d_ref, packed_ref, base_ref,
                      ok_ref):
    a = a_ref[...].astype(jnp.int32)         # (page, Hkv, D2)
    base = a[0]                              # (Hkv, D2)
    da = a - base[None]
    db = b_ref[...].astype(jnp.int32) - base[None]
    dc = c_ref[...].astype(jnp.int32) - base[None]
    dd = d_ref[...].astype(jnp.int32) - base[None]
    fits = lambda x: (x >= -8) & (x <= 7)
    ok = jnp.all(fits(da) & fits(db) & fits(dc) & fits(dd))
    packed = ((dd & 0xF) << 12) | ((dc & 0xF) << 8) | ((db & 0xF) << 4) \
        | (da & 0xF)
    packed_ref[...] = jax.lax.bitcast_convert_type(
        packed.astype(jnp.uint16), jnp.int16)
    base_ref[...] = base.astype(jnp.int16)
    ok_ref[...] = jnp.full((1,), ok, jnp.int32)


def _unpack_quad_kernel(packed_ref, base_ref, a_ref, b_ref, c_ref, d_ref):
    v = jax.lax.bitcast_convert_type(
        packed_ref[...], jnp.uint16).astype(jnp.int32)
    base = base_ref[...].astype(jnp.int32)
    se4 = lambda x: (x ^ 0x8) - 0x8          # sign-extend int4
    a_ref[...] = (base[None] + se4(v & 0xF)).astype(jnp.int16)
    b_ref[...] = (base[None] + se4((v >> 4) & 0xF)).astype(jnp.int16)
    c_ref[...] = (base[None] + se4((v >> 8) & 0xF)).astype(jnp.int16)
    d_ref[...] = (base[None] + se4((v >> 12) & 0xF)).astype(jnp.int16)


@functools.partial(jax.jit, static_argnames=("interpret",))
def pack_quad(page_a, page_b, page_c, page_d, *, interpret: bool = True):
    """Four (page,Hkv,D2) int16 pages -> (packed i16, base i16, ok)."""
    page, hkv, d2 = page_a.shape
    packed, base, ok = pl.pallas_call(
        _pack_quad_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((page, hkv, d2), jnp.int16),
            jax.ShapeDtypeStruct((hkv, d2), jnp.int16),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ),
        interpret=interpret,
    )(page_a, page_b, page_c, page_d)
    return packed, base, ok[0] > 0


@functools.partial(jax.jit, static_argnames=("interpret",))
def unpack_quad(packed, base, *, interpret: bool = True):
    page, hkv, d2 = packed.shape
    return pl.pallas_call(
        _unpack_quad_kernel,
        out_shape=tuple(
            jax.ShapeDtypeStruct((page, hkv, d2), jnp.int16)
            for _ in range(4)),
        interpret=interpret,
    )(packed, base)
