"""Straggler detection: per-host step-time EMA with outlier flagging.

At 1000+ nodes, slow hosts (thermal throttling, failing HBM, network
degradation) stretch every synchronous step.  The detector keeps an EMA and
variance of per-host step durations and flags hosts whose recent times
exceed mean + k*std of the fleet; the FT loop (ft.py) surfaces flags so an
orchestrator can drain/replace the host (here: logged + tested with
injected delays).  Mitigation hooks: `should_skip_sync` implements the
bounded-staleness escape hatch — if the flagged host persists, the loop can
proceed with gradient accumulation skipping that host's contribution for a
bounded number of steps (off by default; an explicit, logged decision).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class StragglerDetector:
    n_hosts: int = 1
    alpha: float = 0.2
    k_sigma: float = 3.0
    min_samples: int = 8
    ema: np.ndarray = None
    var: np.ndarray = None
    samples: int = 0
    flagged_steps: dict = field(default_factory=dict)

    def __post_init__(self):
        self.ema = np.zeros(self.n_hosts)
        self.var = np.zeros(self.n_hosts)

    def record(self, step: int, durations) -> list[int]:
        """durations: per-host step seconds. Returns flagged host ids."""
        d = np.asarray(durations, dtype=np.float64).reshape(self.n_hosts)
        if self.samples == 0:
            self.ema[:] = d
        self.ema = (1 - self.alpha) * self.ema + self.alpha * d
        self.var = (1 - self.alpha) * self.var + self.alpha * (
            d - self.ema) ** 2
        self.samples += 1
        if self.samples < self.min_samples:
            return []
        fleet_mu = float(self.ema.mean())
        fleet_sd = float(max(np.sqrt(self.var.mean()), 1e-9))
        flags = [i for i in range(self.n_hosts)
                 if self.ema[i] > fleet_mu + self.k_sigma * fleet_sd
                 and self.ema[i] > 1.2 * fleet_mu]
        for i in flags:
            self.flagged_steps.setdefault(i, []).append(step)
        return flags

    def persistent_stragglers(self, window: int = 20,
                              threshold: int = 10) -> list[int]:
        return [h for h, steps in self.flagged_steps.items()
                if len([s for s in steps[-window:]]) >= threshold]
