"""GPipe-style pipeline parallelism over a mesh axis (optional feature).

The layer stack is split into `n_stages` contiguous stages; stage s's
parameters live only on the devices of mesh axis 'stage' index s.  A
shard_map loop runs M microbatches through the classic GPipe schedule:
T = M + P - 1 ticks, activations hopping stage->stage+1 by collective
permute each tick.  Backward is obtained by jax.grad through the loop
(ppermute is linear, so AD produces the reverse schedule automatically —
a hand-scheduled 1F1B would overlap better; noted as future §Perf work).

Multi-pod use: the 'pod' axis of the production mesh can serve as the
stage axis (2 stages across 2 pods), putting the low-bandwidth inter-pod
links on the once-per-tick activation hop instead of every collective.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

def shard_map(f, *, mesh, in_specs, out_specs):
    """Version-compat shard_map with replication checking off."""
    try:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    except (AttributeError, TypeError):  # pragma: no cover
        from jax.experimental.shard_map import shard_map as _sm

        return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=False)


def split_stages(stacked_params, n_stages: int):
    """(L, ...) stacked layer params -> (n_stages, L//n_stages, ...)."""
    def re(x):
        L = x.shape[0]
        assert L % n_stages == 0, (L, n_stages)
        return x.reshape((n_stages, L // n_stages) + x.shape[1:])

    return jax.tree.map(re, stacked_params)


def gpipe_apply(stage_params, x_mb, *, mesh: Mesh, stage_fn,
                axis: str = "stage"):
    """Run microbatches through the pipeline.

    stage_params: leaves (n_stages, layers_per_stage, ...), sharded on axis.
    x_mb: (M, mb, S, D) microbatched activations, replicated.
    stage_fn(params_local, x) applies one stage's layers.
    Returns (M, mb, S, D) outputs of the final stage (replicated).
    """
    n_stages = mesh.shape[axis]
    M = x_mb.shape[0]
    T = M + n_stages - 1
    perm = [(i, i + 1) for i in range(n_stages - 1)]

    def per_stage(params_local, x_all):
        params_local = jax.tree.map(lambda t: t[0], params_local)
        stage = jax.lax.axis_index(axis)
        cur = jnp.zeros_like(x_all[0])
        outs = jnp.zeros_like(x_all)
        for t in range(T):
            recv = jax.lax.ppermute(cur, axis, perm)
            mb_idx = min(t, M - 1)
            inp = jnp.where(stage == 0, x_all[mb_idx], recv)
            active = (t >= stage) & (t - stage < M)
            out = stage_fn(params_local, inp)
            cur = jnp.where(active, out, jnp.zeros_like(out))
            out_idx = t - (n_stages - 1)
            is_last = stage == n_stages - 1
            write = is_last & (out_idx >= 0)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs,
                jnp.where(write, cur, outs[max(out_idx, 0)]),
                max(out_idx, 0), 0)
        # surface the last stage's outputs everywhere
        last = jnp.where(stage == n_stages - 1, 1.0, 0.0).astype(outs.dtype)
        return jax.lax.psum(outs * last, axis)

    return shard_map(
        per_stage, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
    )(stage_params, x_mb)
