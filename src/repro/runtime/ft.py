"""Fault-tolerant training loop: checkpoint/restart, straggler flags,
simulated failures, elastic resume.

The loop is restart-idempotent: data batches are addressed by (seed, step)
(data/pipeline.py), checkpoints are atomic + committed, and `run` always
resumes from the latest committed step.  Failures are injected by tests via
`fault_injector(step) -> raise SimulatedFault` and by the train.py
`--inject-fault` flag; the outer supervisor (`run_with_restarts`) catches
them and restarts the loop exactly the way a cluster scheduler re-execs a
preempted job.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import numpy as np

from ..checkpoint.ckpt import CheckpointManager, latest_step
from .straggler import StragglerDetector


class SimulatedFault(RuntimeError):
    pass


@dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    codec: str = "cram"
    log_every: int = 10


@dataclass
class LoopResult:
    final_step: int
    losses: list = field(default_factory=list)
    step_times: list = field(default_factory=list)
    straggler_flags: list = field(default_factory=list)
    restarts: int = 0


def run(step_fn, state, batch_iter, cfg: LoopConfig, *,
        start_step: int = 0, fault_injector=None,
        detector: StragglerDetector | None = None,
        log=print) -> tuple[LoopResult, object]:
    mgr = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep, codec=cfg.codec)
    det = detector or StragglerDetector(n_hosts=1)
    res = LoopResult(final_step=start_step)
    for step, batch in batch_iter:
        if step >= cfg.total_steps:
            break
        if fault_injector is not None:
            fault_injector(step)
        t0 = time.perf_counter()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        flags = det.record(step, [dt])
        if flags:
            res.straggler_flags.append((step, flags))
        res.losses.append(loss)
        res.step_times.append(dt)
        res.final_step = step + 1
        if cfg.log_every and step % cfg.log_every == 0:
            log(f"step {step} loss {loss:.4f} ({dt*1e3:.0f} ms)")
        if cfg.ckpt_every and (step + 1) % cfg.ckpt_every == 0:
            mgr.save_async(step + 1, state)
    mgr.wait()
    if res.final_step > start_step:
        mgr.save_async(res.final_step, state)
        mgr.wait()
    return res, state


def run_with_restarts(make_step_fn, make_state, make_batch_iter,
                      cfg: LoopConfig, *, fault_injector=None,
                      max_restarts: int = 5, log=print):
    """Supervisor: restart from the latest committed checkpoint on faults.

    make_state() builds the step-0 state; on restart the state tree is
    restored from disk (full logical tensors -> any mesh, see elastic.py).
    """
    restarts = 0
    all_losses: list[float] = []
    while True:
        start = latest_step(cfg.ckpt_dir) or 0
        state = make_state()
        if start:
            mgr = CheckpointManager(cfg.ckpt_dir, codec=cfg.codec)
            restored, _ = mgr.restore_latest(state)
            state = jax.tree.map(
                lambda like, arr: jax.device_put(
                    np.asarray(arr).astype(like.dtype)), state, restored)
            log(f"resumed from step {start}")
        step_fn = make_step_fn()
        batch_iter = make_batch_iter(start)
        try:
            res, state = run(step_fn, state, batch_iter, cfg,
                             start_step=start,
                             fault_injector=fault_injector, log=log)
            res.restarts = restarts
            all_losses = all_losses[:start] + res.losses
            res.losses = all_losses
            return res, state
        except SimulatedFault as e:
            restarts += 1
            log(f"fault at restart #{restarts}: {e}")
            if restarts > max_restarts:
                raise
        finally:
            if hasattr(batch_iter, "close"):
                batch_iter.close()
