"""Elastic re-meshing: rebuild the mesh after host loss and re-shard state.

Checkpoints store full logical tensors (checkpoint/ckpt.py), so restore
onto ANY mesh is just device_put with the new shardings — the core of
elastic scaling.  `shrink_mesh` drops failed devices and finds the largest
(data, model) grid that still divides the model axis requirement;
`reshard_tree` moves a (restored) host tree onto the new mesh.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

from .sharding import RuleSet, tree_shardings


def shrink_mesh(failed: set[int] | int, *, model_axis: int | None = None,
                devices=None) -> Mesh:
    """Largest usable (data, model) mesh over the surviving devices."""
    devices = list(devices if devices is not None else jax.devices())
    if isinstance(failed, int):
        failed = set(range(failed))
    alive = [d for i, d in enumerate(devices) if i not in failed]
    n = len(alive)
    assert n >= 1, "no devices survive"
    model = model_axis or 1
    while model > 1 and n % model:
        model //= 2
    data = n // model
    grid = np.asarray(alive[: data * model]).reshape(data, model)
    return Mesh(grid, ("data", "model"))


def reshard_tree(tree, axes_tree, mesh: Mesh, rules: RuleSet | None = None):
    shardings = tree_shardings(axes_tree, jax.eval_shape(lambda: tree),
                               mesh, rules)
    return jax.device_put(tree, shardings)
