"""Logical-axis -> mesh-axis sharding rules (MaxText-style, with fallbacks).

Parameters and activations are annotated with *logical* axis names
("vocab", "heads", "mlp", "experts", "batch", "seq", ...).  A RuleSet maps
each logical name to a mesh axis (or tuple of axes).  `spec_for` checks
divisibility: a dimension that cannot be evenly sharded falls back to
replication (e.g. 8 KV heads on a 16-way model axis), never to an error —
this is what lets one rule set serve every architecture in the pool.

An active-mesh context (set by the launch layer) makes
`constrain(x, logical_axes)` apply jax.lax.with_sharding_constraint; outside
the context it is a no-op so model code runs unsharded on CPU tests.
"""

from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# default logical -> mesh-axis rules (single- and multi-pod)
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "seq": ("model",),         # Megatron-SP: activations shard sequence;
                               # XLA gathers around attention only
    # KV caches shard sequence over data AND model (SP decode): with GQA
    # kv_heads often < model-axis size (replicated fallback), the sequence
    # dim is what keeps 400B-class decode caches inside 16GB/chip
    "kv_seq": ("data", "model"),
    "vocab": ("model",),
    "embed": (),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    "mlp": ("model",),
    "experts": ("model",),
    "layers": (),
    "frames": (),
    "image": (),
}


@dataclass(frozen=True)
class RuleSet:
    rules: tuple = tuple(sorted(DEFAULT_RULES.items()))

    def as_dict(self) -> dict:
        return dict(self.rules)

    def override(self, **kw) -> "RuleSet":
        d = self.as_dict()
        for k, v in kw.items():
            d[k] = tuple(v) if not isinstance(v, str) else (v,)
        return RuleSet(tuple(sorted(d.items())))


def spec_for(logical_axes, shape, mesh: Mesh,
             rules: RuleSet | None = None) -> P:
    """PartitionSpec for one array, with divisibility fallbacks."""
    rules_d = (rules or RuleSet()).as_dict()
    used: set[str] = set()
    out = []
    for dim, name in zip(shape, logical_axes, strict=False):
        assigned = None
        if name is not None:
            for axis in rules_d.get(name, ()):
                if axis in mesh.shape and axis not in used:
                    size = mesh.shape[axis]
                    if dim % size == 0 and dim >= size:
                        # allow composite assignment (e.g. batch over
                        # pod+data) by accumulating axes for this dim
                        if assigned is None:
                            assigned = []
                        assigned.append(axis)
                        used.add(axis)
                        dim //= size
        out.append(tuple(assigned) if assigned else None)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def tree_specs(axes_tree, shape_tree, mesh, rules=None):
    return jax.tree.map(
        lambda ax, shp: spec_for(ax, shp.shape, mesh, rules),
        axes_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x),
    )


def tree_shardings(axes_tree, shape_tree, mesh, rules=None):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_specs(axes_tree, shape_tree, mesh, rules),
        is_leaf=lambda x: isinstance(x, P),
    )


def zero_spec(spec: P, shape, mesh: Mesh, axis: str = "data") -> P:
    """ZeRO-1: additionally shard the largest replicated dim over `axis`.

    Applied to optimizer moments (and optionally master weights): every data
    shard owns a slice, XLA inserts reduce-scatter/all-gather around the
    update.
    """
    if axis not in mesh.shape:
        return spec
    size = mesh.shape[axis]
    entries = list(spec) + [None] * (len(shape) - len(spec))
    used = {a for e in entries if e for a in
            ((e,) if isinstance(e, str) else e)}
    if axis in used:
        return spec
    best, best_dim = -1, -1
    for i, (dim, e) in enumerate(zip(shape, entries, strict=True)):
        if e is None and dim % size == 0 and dim >= size and dim > best:
            best, best_dim = dim, i
    if best_dim < 0:
        return spec
    entries[best_dim] = axis
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def zero_shardings(axes_tree, shape_tree, mesh, rules=None, axis="data"):
    specs = tree_specs(axes_tree, shape_tree, mesh, rules)
    return jax.tree.map(
        lambda s, shp: NamedSharding(mesh, zero_spec(s, shp.shape, mesh, axis)),
        specs, shape_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


# ------------------------------------------------------- activation context
_ctx = threading.local()


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, rules: RuleSet | None = None):
    prev = getattr(_ctx, "state", None)
    _ctx.state = (mesh, rules or RuleSet())
    try:
        yield
    finally:
        _ctx.state = prev


def constrain(x, logical_axes):
    """Apply with_sharding_constraint if a mesh context is active."""
    state = getattr(_ctx, "state", None)
    if state is None:
        return x
    mesh, rules = state
    spec = spec_for(logical_axes, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
