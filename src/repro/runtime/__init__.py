"""Distributed runtime: sharding rules, fault tolerance, pipeline, collectives."""
