"""Synthetic workload traces mirroring the paper's Table II suite.

Each workload is a physical line-address access stream with controllable
spatial locality (sequential-run statistics), reuse (hot working set),
write fraction, and *page-coherent compressibility* (the property the LLP
exploits: lines within a page tend to have similar compressibility, §V-B).

Footprints are capped at 256 MB of line-address space (scaling note in
DESIGN.md §2.2) — what matters for every mechanism under study is the
footprint/LLC ratio and the locality structure, both preserved.

MPKI per workload is taken from Table II and drives the memory-bound
fraction used by the bandwidth-bound speedup model (DESIGN.md §2.2).
"""

from __future__ import annotations

import zlib
from dataclasses import dataclass

import numpy as np

LINES_TOTAL = 1 << 20          # shared address space: 2^20 lines = 64 MB image
GROUPS_TOTAL = LINES_TOTAL // 4
LINES_PER_PAGE = 64


@dataclass(frozen=True)
class WorkloadSpec:
    name: str
    suite: str          # SPEC06 / SPEC17 / GAP / MIX
    mpki: float
    footprint_mb: int   # nominal (Table II); capped to the shared space
    p_seq: float        # probability a segment continues sequentially
    seq_len: int        # mean sequential run length (lines)
    hot_frac: float     # fraction of footprint forming the hot set
    p_hot: float        # probability a jump lands in the hot set (reuse)
    write_frac: float
    p2: float           # fraction of pages whose line-pairs fit 2:1
    p4: float           # fraction of pages that additionally fit 4:1


# Parameters are chosen per suite characteristics: SPEC-FP = streaming +
# compressible; mcf/omnetpp = pointer chasing; libq = extremely compressible;
# GAP = huge footprint, poor locality, poor reuse, modest compressibility.
WORKLOADS: tuple[WorkloadSpec, ...] = (
    WorkloadSpec("fotonik", "SPEC17", 26.2, 6800, 0.90, 24, 0.10, 0.84, 0.30, 0.45, 0.20),
    WorkloadSpec("lbm17",   "SPEC17", 25.5, 3400, 0.92, 32, 0.10, 0.84, 0.35, 0.40, 0.15),
    WorkloadSpec("soplex",  "SPEC06", 23.3, 2100, 0.80, 12, 0.15, 0.82, 0.25, 0.40, 0.18),
    WorkloadSpec("libq",    "SPEC06", 23.1, 418,  0.93, 48, 0.30, 0.9, 0.30, 0.80, 0.60),
    WorkloadSpec("mcf17",   "SPEC17", 22.8, 4400, 0.35, 4,  0.10, 0.72, 0.20, 0.35, 0.10),
    WorkloadSpec("milc",    "SPEC06", 21.9, 3100, 0.88, 20, 0.12, 0.82, 0.30, 0.45, 0.15),
    WorkloadSpec("Gems",    "SPEC06", 17.2, 5800, 0.90, 28, 0.10, 0.84, 0.30, 0.50, 0.20),
    WorkloadSpec("parest",  "SPEC17", 16.4, 465,  0.82, 16, 0.25, 0.85, 0.25, 0.45, 0.15),
    WorkloadSpec("sphinx",  "SPEC06", 11.9, 223,  0.85, 16, 0.30, 0.88, 0.20, 0.40, 0.12),
    WorkloadSpec("leslie",  "SPEC06", 11.9, 861,  0.90, 24, 0.15, 0.84, 0.30, 0.45, 0.15),
    WorkloadSpec("cactu17", "SPEC17", 10.6, 2100, 0.55, 6,  0.08, 0.68, 0.30, 0.40, 0.12),
    WorkloadSpec("omnet17", "SPEC17", 8.6,  1900, 0.45, 5,  0.15, 0.76, 0.30, 0.35, 0.10),
    WorkloadSpec("gcc06",   "SPEC06", 5.8,  205,  0.75, 10, 0.35, 0.88, 0.25, 0.50, 0.20),
    WorkloadSpec("xz",      "SPEC17", 5.7,  943,  0.40, 4,  0.05, 0.58, 0.30, 0.45, 0.15),
    WorkloadSpec("wrf17",   "SPEC17", 5.2,  798,  0.85, 18, 0.20, 0.85, 0.25, 0.45, 0.15),
    WorkloadSpec("bc_twi",  "GAP",    66.6, 9200, 0.15, 2,  0.05, 0.15, 0.15, 0.25, 0.05),
    WorkloadSpec("bc_web",  "GAP",    7.4, 10000, 0.30, 3,  0.08, 0.22, 0.15, 0.30, 0.08),
    WorkloadSpec("cc_twi",  "GAP",   101.8, 6000, 0.12, 2,  0.05, 0.12, 0.15, 0.25, 0.05),
    WorkloadSpec("cc_web",  "GAP",    8.1,  5300, 0.30, 3,  0.08, 0.22, 0.15, 0.30, 0.08),
    WorkloadSpec("pr_twi",  "GAP",   144.8, 8300, 0.10, 2,  0.05, 0.12, 0.20, 0.25, 0.05),
    WorkloadSpec("pr_web",  "GAP",    13.1, 8200, 0.25, 3,  0.08, 0.20, 0.20, 0.30, 0.08),
)

MIXES: tuple[tuple[str, tuple[str, ...]], ...] = (
    ("mix1", ("fotonik", "mcf17")),
    ("mix2", ("libq", "omnet17")),
    ("mix3", ("soplex", "xz")),
    ("mix4", ("milc", "gcc06")),
    ("mix5", ("Gems", "cactu17")),
    ("mix6", ("lbm17", "sphinx")),
)

BY_NAME = {w.name: w for w in WORKLOADS}


def all_workload_names() -> list[str]:
    return [w.name for w in WORKLOADS] + [m[0] for m in MIXES]


def memory_bound_fraction(mpki: float, k: float = 15.0) -> float:
    """Fraction of baseline time that is memory-bandwidth bound."""
    return mpki / (mpki + k)


def _page_levels(n_pages: int, p2: float, p4: float, seed: int) -> np.ndarray:
    """Per-page compressibility level: 2 (quad-able), 1 (pair-able), 0."""
    rng = np.random.default_rng(seed ^ 0xC0FFEE)
    u = rng.random(n_pages)
    return np.where(u < p4, 2, np.where(u < p4 + p2, 1, 0)).astype(np.int8)


def group_fits(spec: WorkloadSpec, seed: int = 0):
    """Static per-group packability (pair_ab, pair_cd, quad) bool arrays."""
    n_pages = LINES_TOTAL // LINES_PER_PAGE
    levels = _page_levels(n_pages, spec.p2, spec.p4, seed)
    g_page = (np.arange(GROUPS_TOTAL) * 4) // LINES_PER_PAGE
    g_level = levels[g_page]
    rng = np.random.default_rng(seed ^ 0xBADF00D)
    noise = rng.random((GROUPS_TOTAL, 3))
    # within a compressible page, ~12% of groups individually fail to fit
    pair_ab = (g_level >= 1) & (noise[:, 0] > 0.12)
    pair_cd = (g_level >= 1) & (noise[:, 1] > 0.12)
    quad = (g_level >= 2) & pair_ab & pair_cd & (noise[:, 2] > 0.15)
    return pair_ab, pair_cd, quad


def generate_trace(spec: WorkloadSpec, n_events: int, seed: int = 0):
    """Build (addrs int32 (T,), is_write bool (T,)) for one workload."""
    # crc32, not hash(): str hashing is salted per process, which made
    # traces (and every cached/golden stats vector) irreproducible across
    # runs.  The stream for a given (name, seed) is now deterministic.
    rng = np.random.default_rng(seed ^ zlib.crc32(spec.name.encode()))
    n_lines = min(int(spec.footprint_mb * (1 << 20) // 64), LINES_TOTAL)
    # hot set: large enough to dwarf the (scaled) LLC, small enough that a
    # few-hundred-k-event trace actually revisits it several times (reuse)
    hot_lines = max(4096, min(int(n_lines * spec.hot_frac), 1 << 14))

    segs_addr, total = [], 0
    # draw segments until we cover n_events
    while total < n_events:
        batch = max(1024, (n_events - total) // 8)
        lens = rng.geometric(1.0 / max(spec.seq_len, 1), size=batch)
        lens = np.minimum(lens, 256)
        non_seq = rng.random(batch) >= spec.p_seq
        lens = np.where(non_seq, 1, lens)
        in_hot = rng.random(batch) < spec.p_hot
        starts = np.where(
            in_hot,
            rng.integers(0, hot_lines, size=batch),
            rng.integers(0, n_lines, size=batch),
        )
        for s, l in zip(starts, lens, strict=True):
            segs_addr.append(np.arange(s, s + l, dtype=np.int64) % n_lines)
            total += int(l)
            if total >= n_events:
                break
    addrs = np.concatenate(segs_addr)[:n_events].astype(np.int32)
    is_write = rng.random(n_events) < spec.write_frac
    return addrs, is_write


def build_workload(name: str, n_events: int = 200_000, seed: int = 0):
    """Returns (spec-like meta, addrs, is_write, pair_ab, pair_cd, quad, f)."""
    if name in BY_NAME:
        spec = BY_NAME[name]
        addrs, is_write = generate_trace(spec, n_events, seed)
        fits = group_fits(spec, seed)
        f = memory_bound_fraction(spec.mpki)
        return spec, addrs, is_write, *fits, f
    mix = dict(MIXES).get(name)
    if mix is None:
        raise KeyError(f"unknown workload {name!r}")
    parts = [build_workload(m, n_events // len(mix), seed + i)
             for i, m in enumerate(mix)]
    # interleave the component streams event-by-event (rate-mode-ish)
    addrs = np.empty(sum(len(p[1]) for p in parts), dtype=np.int32)
    wr = np.empty_like(addrs, dtype=bool)
    k = len(parts)
    for i, p in enumerate(parts):
        # offset each component into its own quarter of the address space
        ofs = (i * (LINES_TOTAL // k)) & ~3
        addrs[i::k] = (p[1] + ofs) % LINES_TOTAL
        wr[i::k] = p[2]
    pa = np.zeros(GROUPS_TOTAL, dtype=bool)
    pc = np.zeros(GROUPS_TOTAL, dtype=bool)
    q = np.zeros(GROUPS_TOTAL, dtype=bool)
    for i, p in enumerate(parts):
        ofs_g = (i * (LINES_TOTAL // k)) // 4
        roll = lambda a: np.roll(a, ofs_g)
        pa |= roll(p[3])
        pc |= roll(p[4])
        q |= roll(p[5])
    mpki = float(np.mean([BY_NAME[m].mpki for m in mix]))
    f = memory_bound_fraction(mpki)
    meta = WorkloadSpec(name, "MIX", mpki, 0, 0, 0, 0, 0, 0, 0, 0)
    return meta, addrs, wr, pa, pc, q, f
