"""Exact functional model of a CRAM compressed memory system (§IV-§VI).

This is the bit-true reference: a real memory image (numpy uint8), real
FPC+BDI codecs, real markers, real inversion + LIT, real LLP, real ganged
eviction and a real group-granular LLC.  Reads interpret lines *only* via the
implicit-metadata markers (never via side-channel ground truth), exactly as
the proposed hardware would.  The correctness contract — every read returns
the last written value — is property-tested in tests/test_cram_functional.py.

Bandwidth accounting matches the paper's breakdown (Fig. 15):
  read probes (demand + misprediction re-probes), dirty writebacks,
  clean compressed writebacks, invalidate (Marker-IL) writes, LIT spills.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..compression import hybrid as cc
from ..compression.framing import LINE_BYTES, PAYLOAD_BUDGET
from ..compression.gate import DynamicController
from ..compression.layouts import LANE_LEVEL, PRED_SLOT, probe_chain
from ..compression.marker import (
    LineStatus,
    MarkerSpec,
    classify_line,
    invert_line,
    needs_inversion,
)
from ..compression.predictor import LLP
from .evict_logic import evict_plan
from .lit import LIT
from .llc import GroupEntry, GroupLLC


@dataclass
class CRAMStats:
    demand_reads: int = 0
    read_probes: int = 0          # memory reads incl. misprediction re-probes
    wb_dirty: int = 0
    wb_clean: int = 0             # compressed writebacks of clean data (cost)
    il_writes: int = 0            # invalidate writes (cost)
    prefetch_installed: int = 0
    prefetch_used: int = 0        # benefit events
    llc_hits: int = 0
    llc_misses: int = 0

    @property
    def extra_probes(self) -> int:
        return self.read_probes - self.demand_reads

    def total_mem_accesses(self, lit_extra: int = 0) -> int:
        return (
            self.read_probes + self.wb_dirty + self.wb_clean + self.il_writes
            + lit_extra
        )

    def as_dict(self) -> dict:
        return {k: getattr(self, k) for k in self.__dataclass_fields__}


class CRAMSystem:
    """LLC + memory controller + compressed memory image.

    policy: 'uncompressed' | 'static' (always compress) | 'dynamic'
    """

    def __init__(
        self,
        n_lines: int = 4096,
        llc_sets: int = 64,
        llc_ways: int = 4,
        policy: str = "static",
        compress_clean: bool = True,
        key: bytes = b"cram-key",
        lit_capacity: int = 16,
        lit_overflow: str = "memory_mapped",
    ):
        assert n_lines % 4 == 0
        self.n_lines = n_lines
        self.mem = np.zeros((n_lines, LINE_BYTES), dtype=np.uint8)
        self.spec = MarkerSpec(key=key)
        self.lit = LIT(capacity=lit_capacity, overflow_policy=lit_overflow)
        self.llp = LLP()
        self.dyn = DynamicController()
        self.llc = GroupLLC(n_sets=llc_sets, ways=llc_ways)
        self.stats = CRAMStats()
        self.policy = policy
        self.compress_clean = compress_clean

    # ---------------------------------------------------------------- helpers
    def _slot_addr(self, group: int, slot: int) -> int:
        return group * 4 + slot

    def _compression_enabled_for(self, group: int) -> bool:
        if self.policy == "uncompressed":
            return False
        if self.policy == "static":
            return True
        # dynamic: sampled sets always compress; followers obey the counter
        return self.llc.is_sampled(group) or self.dyn.enabled()

    def _write_uncompressed_slot(self, slot_addr: int, data: np.ndarray) -> None:
        """Store an uncompressed line, handling marker collisions (§V-A).

        On a LIT overflow with the 'regenerate' policy, markers are re-keyed
        and all of memory re-encoded BEFORE this slot is written, so the
        scan sees a consistent image; the write then retries under the new
        markers (which it will almost surely not collide with).
        """
        for _ in range(3):  # retry bound: repeated collisions ~ 2^-64
            if not needs_inversion(data, slot_addr, self.spec):
                self.mem[slot_addr] = data
                self.lit.remove(slot_addr)
                return
            if (self.lit.would_overflow(slot_addr)
                    and self.lit.overflow_policy == "regenerate"
                    and not getattr(self, "_regenerating", False)):
                self._regenerate_markers()
                continue  # retry under the new marker generation
            self.mem[slot_addr] = invert_line(data)
            self.lit.insert(slot_addr)
            return
        raise AssertionError("repeated marker collisions after re-keying")

    def _regenerate_markers(self) -> None:
        """LIT overflow Option-2: new keys, re-encode every resident line."""
        self._regenerating = True
        try:
            # decode the whole memory under old markers, re-key, re-encode
            contents = {}
            for g in range(self.n_lines // 4):
                st, lines = self._scan_group_state(g)
                contents[g] = (st, lines)
            self.spec.regenerate()
            self.lit.entries.clear()
            self.lit.overflow_map.clear()
            self.lit.overflowed = False
            for g, (st, lines) in contents.items():
                self._materialize_group(g, st, lines)
        finally:
            self._regenerating = False

    def _scan_group_state(self, group: int):
        """(test/maintenance path) read a whole group via markers."""
        lines = {}
        state_guess = None  # layout is re-materialized uncompressed
        for slot in range(4):
            sa = self._slot_addr(group, slot)
            raw = self.mem[sa]
            st = classify_line(raw, sa, self.spec)
            if st == LineStatus.COMP4:
                for i, l in enumerate(cc.unpack_group(raw, 4)):
                    lines[i] = l
            elif st == LineStatus.COMP2:
                lanes = [slot, slot + 1]
                for i, l in zip(lanes, cc.unpack_group(raw, 2), strict=True):
                    lines[i] = l
            elif st == LineStatus.INVALID:
                continue
            else:
                d = raw.copy()
                if st == LineStatus.MAYBE_INVERTED and self.lit.contains(sa):
                    d = invert_line(d)
                lines[slot] = d
        return state_guess, lines

    def _materialize_group(self, group: int, _state, lines: dict) -> None:
        """Rewrite a group uncompressed (used only by marker regeneration)."""
        for lane in range(4):
            sa = self._slot_addr(group, lane)
            data = lines.get(lane, np.zeros(LINE_BYTES, dtype=np.uint8))
            self._write_uncompressed_slot(sa, data)

    # ------------------------------------------------------------------ fetch
    def _fetch(self, addr: int):
        """Read line `addr` from compressed memory using markers + LLP.

        Returns (lines: {lane: (64,) uint8}, level: observed compressibility,
                 probes: memory accesses used).
        """
        group, lane = addr // 4, addr % 4
        if lane == 0:
            chain = [0]
            predicted = None
        else:
            pred_level = self.llp.predict_level(addr)
            predicted = int(PRED_SLOT[lane][pred_level])
            chain = probe_chain(lane, predicted)

        probes = 0
        found: dict[int, np.ndarray] = {}
        level = 0
        for slot in chain:
            sa = self._slot_addr(group, slot)
            raw = self.mem[sa]
            probes += 1
            st = classify_line(raw, sa, self.spec)
            if st == LineStatus.COMP4:
                # slot 0 only; contains the whole group
                for i, l in enumerate(cc.unpack_group(raw, 4)):
                    found[i] = l
                level = 2
                break
            if st == LineStatus.COMP2:
                lanes = (0, 1) if slot == 0 else (2, 3)
                if lane in lanes:
                    for i, l in zip(lanes, cc.unpack_group(raw, 2), strict=True):
                        found[i] = l
                    level = 1
                    break
                continue  # packed pair that does not include us
            if st == LineStatus.INVALID:
                continue  # stale slot; keep probing
            # uncompressed (possibly inverted): it is slot's own line
            if slot == lane:
                d = raw.copy()
                if st == LineStatus.MAYBE_INVERTED and self.lit.contains(sa):
                    d = invert_line(d)
                found[lane] = d
                level = 0
                break
            continue  # someone else's uncompressed line -> mispredict
        else:
            raise AssertionError(
                f"CRAM protocol failed to locate line {addr} (probe chain "
                "exhausted) — memory image corrupt"
            )

        if predicted is not None:
            # one-access success metric of Fig. 14
            self.llp.record_outcome(probes == 1)
        self.llp.update(addr, level)
        self.stats.demand_reads += 1
        self.stats.read_probes += probes
        return found, level, probes

    # ------------------------------------------------------------------ evict
    def _prior_state_from_levels(self, e: GroupEntry) -> int:
        """Reconstruct the group's memory layout from the LLC 2-bit tags."""
        from ..compression.layouts import S_QUAD, fits_to_state

        lv = [e.levels[l] if e.valid_mask & (1 << l) else -1 for l in range(4)]
        if 2 in lv:
            return S_QUAD
        ab = lv[0] == 1 or lv[1] == 1
        cd = lv[2] == 1 or lv[3] == 1
        return fits_to_state(ab, cd, False)

    def _evict(self, e: GroupEntry) -> None:
        group = e.group
        valid, dirty = e.valid_mask, e.dirty_mask & e.valid_mask
        sampled = self.llc.is_sampled(group)
        drive_counter = sampled and self.policy == "dynamic"
        enabled = self._compression_enabled_for(group)

        prior = self._prior_state_from_levels(e)
        if enabled:
            sizes = [LINE_BYTES + 1] * 4
            for lane in range(4):
                if valid & (1 << lane):
                    sizes[lane] = len(cc.compress_line(e.data[lane]))
            fits_ab = sizes[0] + sizes[1] <= PAYLOAD_BUDGET
            fits_cd = sizes[2] + sizes[3] <= PAYLOAD_BUDGET
            fits_quad = sum(sizes) <= PAYLOAD_BUDGET
        else:
            fits_ab = fits_cd = fits_quad = False

        plan = evict_plan(
            prior, fits_ab, fits_cd, fits_quad, valid, dirty, enabled,
            self.compress_clean,
        )

        for slot, lanes, packed, has_dirty in plan.writes:
            sa = self._slot_addr(group, slot)
            if not packed:
                self._write_uncompressed_slot(sa, e.data[lanes[0]])
            else:
                marker = (
                    self.spec.marker4(sa) if len(lanes) == 4
                    else self.spec.marker2(sa)
                )
                blob = cc.pack_group([e.data[l] for l in lanes], marker)
                assert blob is not None, "evict_plan admitted an unpackable group"
                self.mem[sa] = blob
                self.lit.remove(sa)
            if has_dirty:
                self.stats.wb_dirty += 1
            else:
                self.stats.wb_clean += 1
                if drive_counter:
                    self.dyn.cost()

        for slot in plan.il_slots:
            sa = self._slot_addr(group, slot)
            self.mem[sa] = np.frombuffer(self.spec.marker_il(sa), dtype=np.uint8)
            self.lit.remove(sa)
            self.stats.il_writes += 1
            if drive_counter:
                self.dyn.cost()

        # eviction is also a compressibility observation for the LCT
        for lane in range(4):
            if valid & (1 << lane):
                self.llp.update(
                    group * 4 + lane, int(LANE_LEVEL[plan.new_state][lane])
                )

    # ----------------------------------------------------------------- access
    def access(self, addr: int, is_write: bool = False,
               data: np.ndarray | None = None) -> np.ndarray:
        """One CPU access at 64B-line granularity. Returns the line's value."""
        assert 0 <= addr < self.n_lines
        group, lane = addr // 4, addr % 4
        bit = 1 << lane
        e = self.llc.lookup(group)
        if e is not None and e.valid_mask & bit:
            self.stats.llc_hits += 1
            self.llc.touch(e)
            if e.pf_mask & bit:  # a free prefetch proved useful (benefit)
                e.pf_mask &= ~bit
                self.stats.prefetch_used += 1
                if self.llc.is_sampled(group) and self.policy == "dynamic":
                    self.dyn.benefit()
            if is_write:
                e.data[lane] = data
                e.dirty_mask |= bit
            return e.data[lane].copy()

        self.stats.llc_misses += 1
        found, level, _ = self._fetch(addr)
        entry = GroupEntry(group=group)
        for l, v in found.items():
            entry.valid_mask |= 1 << l
            entry.levels[l] = level
            entry.data[l] = v
            if l != lane:
                entry.pf_mask |= 1 << l
                self.stats.prefetch_installed += 1
        victim = self.llc.install(entry)
        if victim is not None:
            self._evict(victim)
        e = self.llc.lookup(group)
        if is_write:
            e.data[lane] = data
            e.dirty_mask |= bit
        self.llc.touch(e)
        return e.data[lane].copy()

    def flush(self) -> None:
        """Evict everything (used by tests to force memory round-trips)."""
        for e in list(self.llc.entries()):
            self.llc.remove(e)
            self._evict(e)

    def total_mem_accesses(self) -> int:
        return self.stats.total_mem_accesses(self.lit.extra_accesses)
