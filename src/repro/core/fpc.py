"""Moved: repro.compression.fpc is the implementation (FPC line codec)."""

from ..compression.fpc import (  # noqa: F401
    P_HALF_SE8,
    P_PAD16,
    P_RAW,
    P_REPB,
    P_SE4,
    P_SE8,
    P_SE16,
    P_ZRUN,
    PREFIX_BITS,
    WORDS_PER_LINE,
    fpc_pack,
    fpc_size_bits,
    fpc_size_bytes,
    fpc_unpack,
)
