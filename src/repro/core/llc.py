"""Group-granular last-level cache model with ganged fill/eviction (§V-A).

CRAM's ganged-eviction rule guarantees that all members of a compressed group
are simultaneously present or absent in the LLC, which lets us model the LLC
at the granularity of 4-line groups: one entry = one group, with per-lane
valid/dirty/prefetch bits and the 2-bit prior-compressibility level the paper
stores in the LLC tag store.

Sets are indexed by group id (all four lanes co-locate in one set, the
arrangement ganged eviction requires — noted in DESIGN.md).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..compression.gate import is_sampled_set


@dataclass
class GroupEntry:
    group: int
    valid_mask: int = 0      # lanes with data present
    dirty_mask: int = 0      # lanes modified since fill
    pf_mask: int = 0         # lanes installed as free prefetches (not demanded)
    levels: list = field(default_factory=lambda: [0, 0, 0, 0])
    data: np.ndarray = None  # (4, 64) uint8
    lru: int = 0

    def __post_init__(self):
        if self.data is None:
            self.data = np.zeros((4, 64), dtype=np.uint8)


class GroupLLC:
    """Set-associative, LRU, group-granular cache."""

    def __init__(self, n_sets: int = 2048, ways: int = 4):
        self.n_sets = n_sets
        self.ways = ways
        self.sets: list[list[GroupEntry]] = [[] for _ in range(n_sets)]
        self._clock = 0

    def set_of(self, group: int) -> int:
        return group % self.n_sets

    def is_sampled(self, group: int) -> bool:
        return bool(is_sampled_set(self.set_of(group), self.n_sets))

    def lookup(self, group: int) -> GroupEntry | None:
        for e in self.sets[self.set_of(group)]:
            if e.group == group:
                return e
        return None

    def touch(self, entry: GroupEntry) -> None:
        self._clock += 1
        entry.lru = self._clock

    def install(self, entry: GroupEntry) -> GroupEntry | None:
        """Insert/merge an entry; returns the victim evicted to make room."""
        s = self.sets[self.set_of(entry.group)]
        existing = self.lookup(entry.group)
        if existing is not None:
            # merge newly fetched lanes into the resident entry
            for lane in range(4):
                bit = 1 << lane
                if entry.valid_mask & bit and not existing.valid_mask & bit:
                    existing.valid_mask |= bit
                    existing.pf_mask |= entry.pf_mask & bit
                    existing.levels[lane] = entry.levels[lane]
                    existing.data[lane] = entry.data[lane]
            self.touch(existing)
            return None
        victim = None
        if len(s) >= self.ways:
            victim = min(s, key=lambda e: e.lru)
            s.remove(victim)
        s.append(entry)
        self.touch(entry)
        return victim

    def remove(self, entry: GroupEntry) -> None:
        self.sets[self.set_of(entry.group)].remove(entry)

    def entries(self):
        for s in self.sets:
            yield from list(s)

    @property
    def capacity_lines(self) -> int:
        return self.n_sets * self.ways * 4
