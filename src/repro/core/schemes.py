"""Declarative scheme registry: schemes are data, not simulator code.

A `Scheme` is a named record of behaviour flags + config parameters that
compiles to the engine's (flags, params) int32 vectors (engine.FLAG_* /
engine.PARAM_*).  The six paper schemes, ablations like `cram-nollp`
(CRAM with the LCT frozen — quantifies the predictor's value) and
config-axis variants like `cram@lct64` (Fig. 14-style LCT-size
sensitivity) are all registry entries; adding a variant never touches the
step function.

Registry API:
  get(name) / names() / resolve(name_or_scheme) / register(scheme)
  variant(base, **overrides)       — derive + register a new entry
  flags_matrix(schemes)            — (S, N_FLAGS) int32 for the engine
  params_matrix(schemes, cfg)      — (S, N_PARAMS) int32 for the engine
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import numpy as np

from ..compression.codecs import get_codec
from ..compression.gate import COUNTER_INIT, COUNTER_MAX
from ..compression.layouts import get_layout
from ..compression.predictor import LCT_ENTRIES
from .engine import (
    FLAG_COMP,
    FLAG_DYNAMIC,
    FLAG_IDEAL,
    FLAG_LCT_UPDATE,
    FLAG_LLP,
    FLAG_META,
    FLAG_NEXTLINE,
    N_FLAGS,
    N_PARAMS,
    PARAM_COUNTER_INIT,
    PARAM_LCT_SIZE,
    PARAM_META_SETS,
    PARAM_SAMPLE_THRESH,
    SimConfig,
    sample_threshold,
)


@dataclass(frozen=True)
class Scheme:
    """One point in the simulator's design space.

    Behaviour flags mirror engine.FLAG_*; `lct_update=None` follows `llp`
    (the paper's schemes update the LCT iff they predict with it).  Config
    fields become the engine's traced params row: `sample_rate=None`
    defers to SimConfig.sample_rate at params_matrix time.

    `codec`/`layout` name the compression-registry entries the scheme's
    packability bits are defined against (repro.compression): the trace
    generator's pair/quad fit masks model the named codec packed into the
    named layout's states.  Both are validated against the registries.
    """
    name: str
    codec: str = "hybrid"
    layout: str = "group4"
    comp: bool = False
    llp: bool = False
    meta: bool = False
    nextline: bool = False
    ideal: bool = False
    dynamic: bool = False
    lct_update: bool | None = None
    lct_size: int = LCT_ENTRIES
    sample_rate: float | None = None
    counter_init: int = COUNTER_INIT
    meta_sets: int | None = None   # effective metadata-cache sets
    description: str = ""

    def __post_init__(self):
        get_codec(self.codec)        # raises on unknown registry names
        get_layout(self.layout)
        if not 1 <= self.lct_size <= LCT_ENTRIES:
            raise ValueError(
                f"lct_size must be in [1, {LCT_ENTRIES}], got {self.lct_size}")
        if not 0 <= self.counter_init <= COUNTER_MAX:
            raise ValueError(f"counter_init out of range: {self.counter_init}")

    def flags(self) -> np.ndarray:
        f = np.zeros(N_FLAGS, dtype=np.int32)
        f[FLAG_COMP] = self.comp
        f[FLAG_LLP] = self.llp
        f[FLAG_META] = self.meta
        f[FLAG_NEXTLINE] = self.nextline
        f[FLAG_IDEAL] = self.ideal
        f[FLAG_DYNAMIC] = self.dynamic
        f[FLAG_LCT_UPDATE] = (
            self.llp if self.lct_update is None else self.lct_update)
        return f

    def params(self, cfg: SimConfig) -> np.ndarray:
        p = np.zeros(N_PARAMS, dtype=np.int32)
        p[PARAM_LCT_SIZE] = self.lct_size
        rate = cfg.sample_rate if self.sample_rate is None else self.sample_rate
        p[PARAM_SAMPLE_THRESH] = sample_threshold(rate)
        p[PARAM_COUNTER_INIT] = self.counter_init
        ms = cfg.meta_sets if self.meta_sets is None else self.meta_sets
        if not 1 <= ms <= cfg.meta_sets:
            raise ValueError(
                f"meta_sets must be in [1, {cfg.meta_sets}], got {ms}")
        p[PARAM_META_SETS] = ms
        return p


_REGISTRY: dict[str, Scheme] = {}


def register(scheme: Scheme, *, overwrite: bool = False) -> Scheme:
    if scheme.name in _REGISTRY and not overwrite:
        raise ValueError(f"scheme {scheme.name!r} is already registered")
    _REGISTRY[scheme.name] = scheme
    return scheme


def get(name: str) -> Scheme:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown scheme(s) {[name]!r}; valid: {sorted(_REGISTRY)}"
        ) from None


def names() -> tuple[str, ...]:
    return tuple(_REGISTRY)


def resolve(scheme: "str | Scheme") -> Scheme:
    return scheme if isinstance(scheme, Scheme) else get(scheme)


def variant(base: "str | Scheme", name: str, *,
            overwrite: bool = False, **overrides) -> Scheme:
    """Derive a registry entry from an existing scheme (config ablations)."""
    sch = dataclasses.replace(resolve(base), name=name, **overrides)
    return register(sch, overwrite=overwrite)


def flags_matrix(schemes) -> np.ndarray:
    """(S, N_FLAGS) int32 flag matrix for the requested schemes."""
    unknown = [s for s in schemes
               if not isinstance(s, Scheme) and s not in _REGISTRY]
    if unknown:
        raise KeyError(
            f"unknown scheme(s) {unknown!r}; valid: {sorted(_REGISTRY)}")
    return np.stack([resolve(s).flags() for s in schemes])


def params_matrix(schemes, cfg: SimConfig = SimConfig()) -> np.ndarray:
    """(S, N_PARAMS) int32 config matrix — the vmappable config axis."""
    return np.stack([resolve(s).params(cfg) for s in schemes])


# ---------------------------------------------------------------- built-ins

BASE_SCHEMES = tuple(register(s).name for s in (
    Scheme("baseline", codec="raw",
           description="uncompressed memory (the normalization target)"),
    Scheme("nextline", codec="raw", nextline=True,
           description="uncompressed + next-line prefetch on miss (Table V)"),
    Scheme("ideal", comp=True, ideal=True,
           description="compression benefits, zero maintenance (Fig. 3/16)"),
    Scheme("explicit", comp=True, meta=True,
           description="CRAM strawman: explicit metadata behind a 32KB "
                       "metadata cache (Fig. 7/12)"),
    Scheme("cram", comp=True, llp=True,
           description="CRAM: implicit metadata + LLP, always compress "
                       "(Fig. 12/16)"),
    Scheme("dynamic", comp=True, llp=True, dynamic=True,
           description="Dynamic-CRAM: set-sampled cost/benefit gate "
                       "(Fig. 16/18)"),
))

register(Scheme(
    "cram-nollp", comp=True, llp=True, lct_update=False,
    description="CRAM with the LCT frozen at level 0 (static prediction) — "
                "the probe-chain cost without the predictor, quantifying "
                "the LLP's value"))

# Fig. 14-style LCT-size sensitivity: a config axis, one dispatch with the
# base schemes (cram itself is the 512-entry point).
LCT_SENSITIVITY = tuple(
    variant("cram", f"cram@lct{n}", lct_size=n,
            description=f"cram with a {n}-entry LCT (size sensitivity)").name
    for n in (64, 128, 256)
)
