"""Batched multi-workload × multi-scheme trace simulator (one lax.scan).

The scalar simulator (memsim.py) specializes one jitted scan per scheme and
walks one workload at a time, so the 27-workload × 6-scheme benchmark sweep
pays six compilations and 162 sequential dispatches of a 300k-step scan.
This module turns the *scheme* into a data axis: a single step function,
parameterized by a small per-scheme flag vector, performs the same integer
arithmetic as every specialized step in memsim, and is vmapped over schemes
and again over workloads.  The whole sweep is then ONE jitted `lax.scan`
over time with a (schemes × workloads) batch at every step.

Exactness contract: for each scheme the flag-gated step is arithmetically
identical to memsim._jit_sim's specialized step — every stat counter is
produced by the same sequence of int32 ops, only selected by traced flags
instead of Python conditionals.  tests/test_batchsim.py asserts the final
stats vectors match the scalar path exactly, per (scheme, workload).

Entry points:
  sweep(...)            — raw (S, W, N_STATS) stats from stacked traces
  sweep_workloads(...)  — build traces for named workloads, run one batched
                          dispatch, return {name: run_workload-style dict}
"""

from __future__ import annotations

import functools

import numpy as np

from .dynamic import (
    COUNTER_INIT,
    COUNTER_MAX,
    ENABLE_THRESHOLD,
    is_sampled_set,
)
from .evict_logic import build_evict_table, evict_table_index
from .llp import LCT_ENTRIES, LINES_PER_PAGE, _HASH_MULT
from .mapping import LANE_LEVEL, LANES_IN_SLOT, LOC
from .memsim import (
    N_STATS,
    SCHEMES,
    ST_DEMAND_READS,
    ST_IL_WRITES,
    ST_LLC_HITS,
    ST_LLC_MISSES,
    ST_META_HITS,
    ST_META_READS,
    ST_META_WB,
    ST_PF_EXTRA_ACCESS,
    ST_PF_INSTALLED,
    ST_PF_USED,
    ST_PRED_HIT,
    ST_PRED_TOTAL,
    ST_READ_PROBES,
    ST_WB_CLEAN,
    ST_WB_DIRTY,
    SimConfig,
    _probe_count_table,
    summarize_stats,
    summarize_workload,
)

# per-scheme behaviour flags (int32 vector fed to the traced step)
(
    FLAG_COMP,       # compressed layout transitions + ganged fills
    FLAG_LLP,        # implicit metadata: LLP probe chain + LCT updates
    FLAG_META,       # explicit metadata cache traffic
    FLAG_NEXTLINE,   # next-line prefetch on miss
    FLAG_IDEAL,      # compression benefits with zero maintenance cost
    FLAG_DYNAMIC,    # set-sampled cost/benefit gate
    N_FLAGS,
) = range(7)

_SCHEME_FLAGS = {
    "baseline": (0, 0, 0, 0, 0, 0),
    "nextline": (0, 0, 0, 1, 0, 0),
    "ideal":    (1, 0, 0, 0, 1, 0),
    "explicit": (1, 0, 1, 0, 0, 0),
    "cram":     (1, 1, 0, 0, 0, 0),
    "dynamic":  (1, 1, 0, 0, 0, 1),
}


def scheme_flags(schemes) -> np.ndarray:
    """(S, N_FLAGS) int32 flag matrix for the requested schemes."""
    unknown = [s for s in schemes if s not in _SCHEME_FLAGS]
    if unknown:
        raise KeyError(
            f"unknown scheme(s) {unknown!r}; valid: {sorted(_SCHEME_FLAGS)}")
    return np.asarray([_SCHEME_FLAGS[s] for s in schemes], dtype=np.int32)


@functools.lru_cache(maxsize=None)
def _jit_sweep(cfg: SimConfig):
    import jax
    import jax.numpy as jnp
    from jax import lax

    S, W = cfg.llc_sets, cfg.llc_ways
    MS, MW, GPM = cfg.meta_sets, cfg.meta_ways, cfg.groups_per_meta

    EVT = {k: jnp.asarray(v) for k, v in
           build_evict_table(cfg.compress_clean).items()}
    PROBE = jnp.asarray(_probe_count_table())
    LOC_J = jnp.asarray(LOC)
    LIS_J = jnp.asarray(LANES_IN_SLOT)
    LVL_J = jnp.asarray(LANE_LEVEL)
    SAMPLED = jnp.asarray(
        np.asarray([bool(is_sampled_set(i, S, rate=cfg.sample_rate))
                    for i in range(S)])
    )

    def popcount4(x):
        return ((x >> 0) & 1) + ((x >> 1) & 1) + ((x >> 2) & 1) + ((x >> 3) & 1)

    def meta_probe(mstate, mline, make_dirty):
        """One metadata-cache access; returns the would-be new state plus the
        stat deltas, application gated by the caller (explicit scheme only)."""
        mtag, mlru, mdirty, mclock = mstate
        ms = mline % MS
        row = mtag[ms]
        match = row == mline + 1
        hit = match.any()
        empty = row == 0
        vic = jnp.where(empty.any(), jnp.argmax(empty), jnp.argmin(mlru[ms]))
        way = jnp.where(hit, jnp.argmax(match), vic)
        vic_dirty = (~hit) & (row[way] != 0) & mdirty[ms, way]
        mtag = mtag.at[ms, way].set(mline + 1)
        mclock = mclock + 1
        mlru = mlru.at[ms, way].set(mclock)
        keep = jnp.where(hit, mdirty[ms, way], False)
        mdirty = mdirty.at[ms, way].set(keep | make_dirty)
        deltas = (
            jnp.where(hit, 0, 1),            # meta_reads
            jnp.where(vic_dirty, 1, 0),      # meta_wb
            jnp.where(hit, 1, 0),            # meta_hits
        )
        return (mtag, mlru, mdirty, mclock), deltas

    def _sel_state(apply, new, old):
        return tuple(jnp.where(apply, n, o) for n, o in zip(new, old))

    def run_one(flags, addrs, is_write, pair_ab, pair_cd, quad):
        f_comp = flags[FLAG_COMP] > 0
        f_llp = flags[FLAG_LLP] > 0
        f_meta = flags[FLAG_META] > 0
        f_next = flags[FLAG_NEXTLINE] > 0
        f_ideal = flags[FLAG_IDEAL] > 0
        f_dyn = flags[FLAG_DYNAMIC] > 0

        def step(carry, evn):
            (tag, lru, valid, dirty, pf, mem_state, lct, mstate, counter,
             clock, stats) = carry
            addr, wr = evn
            addr = addr.astype(jnp.int32)
            g = addr >> 2
            lane = addr & 3
            lane_bit = (jnp.int32(1) << lane)
            s = g % S
            clock = clock + 1

            row_tag = tag[s]
            match = row_tag == g + 1
            tag_hit = match.any()
            way = jnp.argmax(match)
            v_here = jnp.where(tag_hit, valid[s, way], 0)
            hit = tag_hit & ((v_here & lane_bit) != 0)
            miss = ~hit
            sampled = SAMPLED[s]
            dyn_on = counter >= ENABLE_THRESHOLD

            pf_bit = jnp.where(hit, (pf[s, way] & lane_bit) != 0, False)

            # ----------------------------- fetch accounting (miss path)
            st = mem_state[g].astype(jnp.int32)
            pidx = (
                (addr // LINES_PER_PAGE).astype(jnp.uint32)
                * np.uint32(_HASH_MULT) % np.uint32(LCT_ENTRIES)
            ).astype(jnp.int32)
            pred_level = lct[pidx].astype(jnp.int32)
            probes = jnp.where(
                f_llp & (lane != 0), PROBE[st, lane, pred_level], jnp.int32(1)
            )
            true_slot = LOC_J[st, lane]
            obt_next = lane_bit | jnp.where(lane < 3, lane_bit << 1, 0)
            obtained = jnp.where(
                f_comp, LIS_J[st, true_slot],
                jnp.where(f_next, obt_next, lane_bit),
            )

            # victim: merge into existing way when the group tag is present
            empty = row_tag == 0
            vway = jnp.where(
                tag_hit, way,
                jnp.where(empty.any(), jnp.argmax(empty), jnp.argmin(lru[s])),
            )
            evicting = miss & (~tag_hit) & (row_tag[vway] != 0)
            vg = row_tag[vway] - 1
            vst = mem_state[vg].astype(jnp.int32)
            v_valid = valid[s, vway]
            v_dirty = dirty[s, vway]

            ev_enabled = jnp.where(
                f_dyn, (sampled | dyn_on).astype(jnp.int32),
                f_comp.astype(jnp.int32),
            )
            eidx = evict_table_index(
                ev_enabled, vst,
                pair_ab[vg].astype(jnp.int32),
                pair_cd[vg].astype(jnp.int32),
                quad[vg].astype(jnp.int32),
                v_valid, v_dirty,
            )
            wb_d = jnp.where(evicting, EVT["wb_dirty"][eidx], 0)
            wb_c = jnp.where(evicting, EVT["wb_clean"][eidx], 0)
            ilw = jnp.where(evicting, EVT["il"][eidx], 0)
            ns = jnp.where(evicting, EVT["new_state"][eidx], vst)
            # ideal: benefits without maintenance overheads
            wb_c = jnp.where(f_ideal, 0, wb_c)
            ilw = jnp.where(f_ideal, 0, ilw)

            # ------------------------------------------------- stats
            stats = stats.at[ST_LLC_HITS].add(jnp.where(hit, 1, 0))
            stats = stats.at[ST_LLC_MISSES].add(jnp.where(miss, 1, 0))
            stats = stats.at[ST_PF_USED].add(jnp.where(hit & pf_bit, 1, 0))
            stats = stats.at[ST_DEMAND_READS].add(jnp.where(miss, 1, 0))
            stats = stats.at[ST_READ_PROBES].add(jnp.where(miss, probes, 0))
            stats = stats.at[ST_WB_DIRTY].add(wb_d)
            stats = stats.at[ST_WB_CLEAN].add(wb_c)
            stats = stats.at[ST_IL_WRITES].add(ilw)
            need_pred = f_llp & miss & (lane > 0)
            stats = stats.at[ST_PRED_TOTAL].add(jnp.where(need_pred, 1, 0))
            stats = stats.at[ST_PRED_HIT].add(
                jnp.where(need_pred & (probes == 1), 1, 0))
            stats = stats.at[ST_PF_EXTRA_ACCESS].add(
                jnp.where(f_next & miss, 1, 0))

            # dynamic cost/benefit counter (gated; others keep COUNTER_INIT)
            cost = jnp.where(evicting & sampled, wb_c + ilw, 0) + \
                jnp.where(miss & sampled, probes - 1, 0)
            benefit = jnp.where(hit & pf_bit & sampled, 1, 0)
            counter = jnp.where(
                f_dyn, jnp.clip(counter + benefit - cost, 0, COUNTER_MAX),
                counter,
            )

            # explicit metadata cache (two gated probes, sequenced like the
            # scalar path's lax.conds: demand miss first, then dirty update)
            mline = g // GPM
            m1, d1 = meta_probe(mstate, mline, False)
            apply1 = f_meta & miss
            mstate = _sel_state(apply1, m1, mstate)
            stats = stats.at[ST_META_READS].add(jnp.where(apply1, d1[0], 0))
            stats = stats.at[ST_META_WB].add(jnp.where(apply1, d1[1], 0))
            stats = stats.at[ST_META_HITS].add(jnp.where(apply1, d1[2], 0))
            vmline = vg // GPM
            m2, d2 = meta_probe(mstate, vmline, True)
            apply2 = f_meta & evicting & (ns != vst)
            mstate = _sel_state(apply2, m2, mstate)
            stats = stats.at[ST_META_READS].add(jnp.where(apply2, d2[0], 0))
            stats = stats.at[ST_META_WB].add(jnp.where(apply2, d2[1], 0))
            stats = stats.at[ST_META_HITS].add(jnp.where(apply2, d2[2], 0))

            # LCT update (cram/dynamic only)
            obs = LVL_J[st, lane].astype(lct.dtype)
            lct = jnp.where(f_llp & miss, lct.at[pidx].set(obs), lct)

            mem_state = mem_state.at[vg].set(
                jnp.where(evicting, ns.astype(mem_state.dtype), mem_state[vg])
            )

            # ------------------- LLC array updates (hit & miss merged)
            new_valid_miss = jnp.where(tag_hit, v_here | obtained, obtained)
            prev_pf = jnp.where(tag_hit, pf[s, vway], 0)
            fresh = obtained & ~jnp.where(tag_hit, v_here, 0) & ~lane_bit
            new_pf_miss = (prev_pf | fresh) & ~lane_bit
            stats = stats.at[ST_PF_INSTALLED].add(
                jnp.where(miss, popcount4(fresh), 0))
            wr_bit = jnp.where(wr, lane_bit, 0)
            new_dirty_miss = jnp.where(tag_hit, dirty[s, vway], 0) | wr_bit

            uway = jnp.where(hit, way, vway)
            tag = tag.at[s, uway].set(jnp.where(hit, row_tag[way], g + 1))
            lru = lru.at[s, uway].set(clock)
            valid = valid.at[s, uway].set(
                jnp.where(hit, v_here, new_valid_miss))
            dirty = dirty.at[s, uway].set(
                jnp.where(hit, dirty[s, way] | wr_bit, new_dirty_miss))
            pf = pf.at[s, uway].set(
                jnp.where(hit, pf[s, way] & ~lane_bit, new_pf_miss))

            return (tag, lru, valid, dirty, pf, mem_state, lct, mstate,
                    counter, clock, stats), None

        state = (
            jnp.zeros((S, W), jnp.int32),           # tag
            jnp.zeros((S, W), jnp.int32),           # lru
            jnp.zeros((S, W), jnp.int32),           # valid
            jnp.zeros((S, W), jnp.int32),           # dirty
            jnp.zeros((S, W), jnp.int32),           # pf
            jnp.zeros((cfg.n_groups,), jnp.int8),   # mem_state (all S_U)
            jnp.zeros((LCT_ENTRIES,), jnp.int8),    # lct
            (
                jnp.zeros((MS, MW), jnp.int32),
                jnp.zeros((MS, MW), jnp.int32),
                jnp.zeros((MS, MW), bool),
                jnp.asarray(0, jnp.int32),
            ),
            jnp.asarray(COUNTER_INIT, jnp.int32),
            jnp.asarray(0, jnp.int32),
            jnp.zeros((N_STATS,), jnp.int32),
        )
        final, _ = lax.scan(step, state, (addrs, is_write))
        return final[-1]

    # inner vmap: workloads share the scheme flags; outer vmap: schemes share
    # the stacked traces.  One jit, one dispatch, one compilation.
    run_w = jax.vmap(run_one, in_axes=(None, 0, 0, 0, 0, 0))
    run_sw = jax.vmap(run_w, in_axes=(0, None, None, None, None, None))
    return jax.jit(run_sw)


def sweep(schemes, addrs, is_write, pair_ab, pair_cd, quad,
          cfg: SimConfig = SimConfig()) -> np.ndarray:
    """Run every scheme × workload pair in one jitted dispatch.

    addrs/is_write: (W, T); pair_ab/pair_cd/quad: (W, n_groups) bool.
    Returns int32 stats of shape (len(schemes), W, N_STATS), laid out per
    memsim's ST_* indices.
    """
    import jax.numpy as jnp

    fn = _jit_sweep(cfg)
    out = fn(
        jnp.asarray(scheme_flags(schemes)),
        jnp.asarray(addrs, jnp.int32),
        jnp.asarray(is_write),
        jnp.asarray(pair_ab),
        jnp.asarray(pair_cd),
        jnp.asarray(quad),
    )
    return np.asarray(out)


def sweep_workloads(names=None, schemes=SCHEMES, n_events: int = 200_000,
                    seed: int = 0, cfg: SimConfig = SimConfig()) -> dict:
    """Batched replacement for {name: memsim.run_workload(name)} loops.

    Builds the named traces (identical generators/seeds to the scalar path),
    stacks them, and runs a single batched dispatch covering all schemes and
    workloads.  Returns {name: summary} where each summary is field-for-field
    identical to memsim.run_workload's output.
    """
    from .traces import all_workload_names, build_workload

    names = list(names) if names is not None else all_workload_names()
    schemes = list(schemes)
    # a baseline run is required for speedup normalization
    sim_schemes = schemes if "baseline" in schemes else ["baseline"] + schemes

    metas, fs = [], []
    addrs, wrs, pabs, pcds, pqs = [], [], [], [], []
    for name in names:
        meta, a, w, pab, pcd, pq, f = build_workload(name, n_events, seed)
        metas.append(meta)
        fs.append(f)
        addrs.append(a)
        wrs.append(w)
        pabs.append(pab)
        pcds.append(pcd)
        pqs.append(pq)

    stats = sweep(
        sim_schemes,
        np.stack(addrs), np.stack(wrs),
        np.stack(pabs), np.stack(pcds), np.stack(pqs),
        cfg,
    )

    out = {}
    base_row = sim_schemes.index("baseline")
    for wi, name in enumerate(names):
        results = {
            sch: summarize_stats(sch, stats[si, wi])
            for si, sch in enumerate(sim_schemes) if sch in schemes
        }
        base = summarize_stats("baseline", stats[base_row, wi]).accesses
        out[name] = summarize_workload(name, fs[wi], results, base)
    return out
