"""Batched multi-workload × multi-scheme sweep — the engine's vmapped side.

The step function lives in `core.engine` (shared verbatim with the scalar
simulator in memsim.py); this module owns the batched dispatch:

  * **scheme axis** — engine (flags, params) rows stacked to (S, N_FLAGS)
    / (S, N_PARAMS) and vmapped.  Because params are traced data, config
    ablations (LCT size, sampling threshold, counter init — see
    schemes.variant) ride in the same dispatch as behaviour variants:
    a Fig. 14-style LCT-size sensitivity sweep is just more rows.
  * **workload axis** — traces stacked to (W, T) and vmapped; optionally
    sharded across devices with `shard_map` (clean single-device
    fallback when only one device is present or W doesn't divide).
  * **time axis** — `chunk_size` splits the scan into a Python loop of
    jitted chunk dispatches with a donated carry (bounded compile/live
    memory for very long traces; donation is a no-op on CPU).

Exactness contract: all execution modes produce bit-identical int32 stats
to the scalar path — lax.scan is sequential whether run whole or chunked,
and sharding only partitions the already-independent workload axis.
tests/test_batchsim.py and tests/test_engine.py assert this exactly.

Entry points:
  sweep(...)            — raw (S, W, N_STATS) stats from stacked traces
  sweep_workloads(...)  — build traces for named workloads, run one batched
                          dispatch, return {name: run_workload-style dict}
"""

from __future__ import annotations

import functools

import numpy as np

from . import schemes as schemes_registry
from .engine import N_STATS, SimConfig, build_engine  # noqa: F401
from .memsim import SCHEMES, summarize_stats, summarize_workload


def scheme_flags(schemes) -> np.ndarray:
    """(S, N_FLAGS) int32 flag matrix (back-compat: schemes.flags_matrix)."""
    return schemes_registry.flags_matrix(schemes)


def _vmapped(run):
    """vmap over workloads (axis after flags/params), then over schemes."""
    import jax

    run_w = jax.vmap(run, in_axes=(None, None, 0, 0, 0, 0, 0))
    return jax.vmap(run_w, in_axes=(0, 0, None, None, None, None, None))


@functools.lru_cache(maxsize=None)
def _jit_sweep(cfg: SimConfig):
    import jax

    return jax.jit(_vmapped(build_engine(cfg).run_one))


@functools.lru_cache(maxsize=None)
def _jit_sweep_sharded(cfg: SimConfig, n_dev: int):
    """The same batched program with the workload axis sharded over
    `n_dev` devices via shard_map (no collectives: workloads are
    independent, each device runs the full scheme axis on its shard)."""
    import jax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P

    mesh = Mesh(np.asarray(jax.devices()[:n_dev]), ("w",))
    fn = shard_map(
        _vmapped(build_engine(cfg).run_one), mesh=mesh,
        in_specs=(P(), P(), P("w"), P("w"), P("w"), P("w"), P("w")),
        out_specs=P(None, "w"),
    )
    return jax.jit(fn)


@functools.lru_cache(maxsize=None)
def _jit_sweep_chunked(cfg: SimConfig):
    """(init, chunk) pair for the chunked batched path.  The chunk carry is
    donated so long sweeps reuse the state buffers in place (no-op on CPU,
    where XLA does not implement donation)."""
    import jax

    eng = build_engine(cfg)
    chunk_w = jax.vmap(eng.run_chunk, in_axes=(0, None, None, 0, 0, 0, 0, 0))
    chunk_sw = jax.vmap(chunk_w,
                        in_axes=(0, 0, 0, None, None, None, None, None))
    donate = () if jax.default_backend() == "cpu" else (0,)
    init_s = jax.jit(jax.vmap(eng.init_state))
    return init_s, jax.jit(chunk_sw, donate_argnums=donate)


def _resolve_axis(schemes, cfg):
    import jax.numpy as jnp

    resolved = [schemes_registry.resolve(s) for s in schemes]
    return (resolved,
            jnp.asarray(schemes_registry.flags_matrix(resolved)),
            jnp.asarray(schemes_registry.params_matrix(resolved, cfg)))


def sweep(schemes, addrs, is_write, pair_ab, pair_cd, quad,
          cfg: SimConfig = SimConfig(), *, chunk_size: int | None = None,
          shard: "bool | str" = "auto") -> np.ndarray:
    """Run every scheme × workload pair in one batched dispatch.

    schemes: registry names and/or schemes.Scheme records (the scheme AND
    config axis — variants with different params batch together).
    addrs/is_write: (W, T); pair_ab/pair_cd/quad: (W, n_groups) bool.
    chunk_size: optional time-chunked execution (Python loop of jitted
    chunk dispatches with a donated carry).  Chunked execution is
    single-device; combining it with shard=True raises.
    shard: "auto" shards the workload axis over all local devices when
    there are several and W divides evenly; True forces it (still falling
    back cleanly when impossible); False keeps a single-device dispatch.

    Returns int32 stats of shape (len(schemes), W, N_STATS), laid out per
    the engine's ST_* indices — bit-identical across execution modes.
    """
    import jax
    import jax.numpy as jnp

    _, flags, params = _resolve_axis(schemes, cfg)
    a = jnp.asarray(addrs, jnp.int32)
    w = jnp.asarray(is_write)
    tail = (jnp.asarray(pair_ab), jnp.asarray(pair_cd), jnp.asarray(quad))

    if chunk_size:
        if shard is True:
            raise ValueError(
                "chunk_size and shard=True cannot be combined; chunked "
                "execution runs the workload axis on one device")
        init_s, chunk = _jit_sweep_chunked(cfg)
        per_scheme = init_s(params)
        n_w = a.shape[0]
        carry = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(
                x[:, None], (x.shape[0], n_w) + x.shape[1:]),
            per_scheme)
        for lo in range(0, a.shape[1], chunk_size):
            hi = lo + chunk_size
            carry = chunk(carry, flags, params, a[:, lo:hi], w[:, lo:hi],
                          *tail)
        return np.asarray(carry[-1])

    n_dev = len(jax.devices())
    want_shard = shard is True or (shard == "auto" and n_dev > 1)
    if want_shard and n_dev > 1 and a.shape[0] % n_dev == 0:
        fn = _jit_sweep_sharded(cfg, n_dev)
    else:
        fn = _jit_sweep(cfg)
    return np.asarray(fn(flags, params, a, w, *tail))


def sweep_workloads(names=None, schemes=SCHEMES, n_events: int = 200_000,
                    seed: int = 0, cfg: SimConfig = SimConfig(), *,
                    chunk_size: int | None = None,
                    shard: "bool | str" = "auto") -> dict:
    """Batched replacement for {name: memsim.run_workload(name)} loops.

    Builds the named traces (identical generators/seeds to the scalar path),
    stacks them, and runs a single batched dispatch covering all schemes and
    workloads.  Returns {name: summary} where each summary is field-for-field
    identical to memsim.run_workload's output.
    """
    from .traces import all_workload_names, build_workload

    names = list(names) if names is not None else all_workload_names()
    requested = [schemes_registry.resolve(s) for s in schemes]
    req_names = [s.name for s in requested]
    # a baseline run is required for speedup normalization
    sim_schemes = (requested if "baseline" in req_names
                   else [schemes_registry.get("baseline"), *requested])

    metas, fs = [], []
    addrs, wrs, pabs, pcds, pqs = [], [], [], [], []
    for name in names:
        meta, a, w, pab, pcd, pq, f = build_workload(name, n_events, seed)
        metas.append(meta)
        fs.append(f)
        addrs.append(a)
        wrs.append(w)
        pabs.append(pab)
        pcds.append(pcd)
        pqs.append(pq)

    stats = sweep(
        sim_schemes,
        np.stack(addrs), np.stack(wrs),
        np.stack(pabs), np.stack(pcds), np.stack(pqs),
        cfg, chunk_size=chunk_size, shard=shard,
    )

    out = {}
    sim_names = [s.name for s in sim_schemes]
    base_row = sim_names.index("baseline")
    for wi, name in enumerate(names):
        results = {
            sch: summarize_stats(sch, stats[si, wi])
            for si, sch in enumerate(sim_names) if sch in req_names
        }
        base = summarize_stats("baseline", stats[base_row, wi]).accesses
        out[name] = summarize_workload(name, fs[wi], results, base)
    return out
