"""Single-source-of-truth trace-simulation engine (step, state, stats).

Both simulator front-ends are thin adapters over this module:

  * memsim.simulate  — the 1×1 instantiation: one scheme's (flags, params)
    row closed over as constants, so XLA folds the behaviour gates into a
    per-scheme specialized program (exactly what the old hand-written
    per-scheme steps compiled to).
  * batchsim.sweep   — the vmapped instantiation: the same step vmapped
    over a scheme axis (flag/param rows as data) and a workload axis
    (stacked traces), one jitted dispatch for the whole design space.

A scheme is a point in a small design space, not a separate simulator:

  flags  — int32 behaviour gates (compressed layout, LLP probing, explicit
           metadata, next-line prefetch, ideal zero-cost, dynamic gate,
           LCT updates), see FLAG_*;
  params — int32 config values that the step *traces* (effective LCT size,
           dynamic sampling threshold, counter init), see PARAM_*.  Because
           params are data, config-axis sweeps (e.g. Fig. 14-style LCT-size
           sensitivity) batch into the same dispatch as the scheme axis.

The engine also exposes chunked execution: `run_chunk` advances the carry
over one time slice of the trace, so callers can scan arbitrarily long
traces as a Python loop of jitted chunk dispatches with a donated carry
(bit-identical to one monolithic scan — lax.scan is sequential either way).

Exactness contract: for the six paper schemes with default params every
stat counter is produced by the same sequence of int32 ops as the
pre-refactor simulators; tests/test_engine.py pins the golden stats.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import numpy as np

from ..compression.gate import COUNTER_INIT, COUNTER_MAX, ENABLE_THRESHOLD
from ..compression.layouts import GROUP4, LANE_LEVEL, LANES_IN_SLOT, LOC
from ..compression.predictor import (
    HASH_MULT,
    LCT_ENTRIES,
    LINES_PER_PAGE,
    probe_count_table,
)
from .evict_logic import build_evict_table, evict_table_index

# stats vector layout (the one definition; memsim/batchsim re-export)
(
    ST_READ_PROBES,
    ST_DEMAND_READS,
    ST_WB_DIRTY,
    ST_WB_CLEAN,
    ST_IL_WRITES,
    ST_META_READS,
    ST_META_WB,
    ST_META_HITS,
    ST_PF_INSTALLED,
    ST_PF_USED,
    ST_PRED_TOTAL,
    ST_PRED_HIT,
    ST_LLC_HITS,
    ST_LLC_MISSES,
    ST_PF_EXTRA_ACCESS,
    N_STATS,
) = range(16)

STAT_NAMES = (
    "read_probes", "demand_reads", "wb_dirty", "wb_clean", "il_writes",
    "meta_reads", "meta_wb", "meta_hits", "pf_installed", "pf_used",
    "pred_total", "pred_hit", "llc_hits", "llc_misses", "pf_extra_access",
)

# per-scheme behaviour flags (int32 vector fed to the traced step)
(
    FLAG_COMP,       # compressed layout transitions + ganged fills
    FLAG_LLP,        # implicit metadata: LLP probe chain on non-home lanes
    FLAG_META,       # explicit metadata cache traffic
    FLAG_NEXTLINE,   # next-line prefetch on miss
    FLAG_IDEAL,      # compression benefits with zero maintenance cost
    FLAG_DYNAMIC,    # set-sampled cost/benefit gate
    FLAG_LCT_UPDATE,  # record observed levels into the LCT (off = the LLP
                      # predicts a frozen level 0 — the cram-nollp ablation)
    N_FLAGS,
) = range(8)

# per-scheme traced config parameters (the config axis)
(
    PARAM_LCT_SIZE,       # effective LCT entries (modulus; <= LCT_ENTRIES)
    PARAM_SAMPLE_THRESH,  # dynamic sampling threshold in 1024ths of the sets
    PARAM_COUNTER_INIT,   # dynamic cost/benefit counter start value
    PARAM_META_SETS,      # effective metadata-cache sets (<= cfg.meta_sets)
    N_PARAMS,
) = range(5)


def sample_threshold(rate: float) -> int:
    """dynamic.is_sampled_set's per-1024 threshold as a traceable int."""
    return max(1, int(rate * 1024))


def default_params(cfg: "SimConfig") -> tuple[int, int, int, int]:
    """The params row reproducing the pre-refactor fixed-config behaviour."""
    return (LCT_ENTRIES, sample_threshold(cfg.sample_rate), COUNTER_INIT,
            cfg.meta_sets)


@dataclass(frozen=True)
class SimConfig:
    # The paper's 8MB LLC is scaled with the footprint cap (DESIGN.md §2.2):
    # 128 sets x 8 ways x 4 lanes x 64B = 256KB against a <=64MB footprint
    # preserves the footprint/LLC ratio of Table II workloads.
    llc_sets: int = 128
    llc_ways: int = 8
    n_groups: int = 1 << 18       # matches traces.GROUPS_TOTAL
    meta_sets: int = 64           # 32KB metadata cache: 64 sets x 8 ways x 64B
    meta_ways: int = 8
    groups_per_meta: int = 128    # ~170 groups per 64B metadata line; pow2
    compress_clean: bool = True
    sample_rate: float = 0.08     # scaled from the paper's 1% (trace-length)


def _probe_count_table() -> np.ndarray:
    """PROBE[state, lane, predicted_level] for the GROUP4 layout (the one
    predictor implementation, parameterized by the layout's candidate-slot
    table, lives in compression.predictor)."""
    return probe_count_table(GROUP4)


def _set_hash_table(n_sets: int) -> np.ndarray:
    """(set * PHI) mod 1024 per LLC set; comparing against
    PARAM_SAMPLE_THRESH reproduces dynamic.is_sampled_set bit-for-bit with
    the sampling rate as traced data instead of a baked-in table."""
    h = (np.arange(n_sets, dtype=np.uint64) * HASH_MULT) & 0xFFFFFFFF
    return (h % 1024).astype(np.int32)


@dataclass(frozen=True)
class EngineParts:
    """The three engine entry points for one SimConfig.

    init_state(params)                       -> carry pytree
    run_chunk(carry, flags, params, *trace)  -> carry  (scan one time slice)
    run_one(flags, params, *trace)           -> (N_STATS,) int32 stats
    """
    init_state: callable
    run_chunk: callable
    run_one: callable


@functools.lru_cache(maxsize=None)
def build_engine(cfg: SimConfig) -> EngineParts:
    import jax.numpy as jnp
    from jax import lax

    S, W = cfg.llc_sets, cfg.llc_ways
    MS, MW, GPM = cfg.meta_sets, cfg.meta_ways, cfg.groups_per_meta

    EVT = {k: jnp.asarray(v) for k, v in
           build_evict_table(cfg.compress_clean).items()}
    PROBE = jnp.asarray(_probe_count_table())
    LOC_J = jnp.asarray(LOC)
    LIS_J = jnp.asarray(LANES_IN_SLOT)
    LVL_J = jnp.asarray(LANE_LEVEL)
    SET_HASH = jnp.asarray(_set_hash_table(S))

    def popcount4(x):
        return ((x >> 0) & 1) + ((x >> 1) & 1) + ((x >> 2) & 1) + ((x >> 3) & 1)

    def meta_probe(mstate, mline, make_dirty, meta_sets):
        """One metadata-cache access; returns the would-be new state plus the
        stat deltas, application gated by the caller (explicit scheme only).
        `meta_sets` (traced, <= cfg.meta_sets) is the effective set count —
        cache-size ablations index a subset of the allocated arrays."""
        mtag, mlru, mdirty, mclock = mstate
        ms = mline % meta_sets
        row = mtag[ms]
        match = row == mline + 1
        hit = match.any()
        empty = row == 0
        vic = jnp.where(empty.any(), jnp.argmax(empty), jnp.argmin(mlru[ms]))
        way = jnp.where(hit, jnp.argmax(match), vic)
        vic_dirty = (~hit) & (row[way] != 0) & mdirty[ms, way]
        mtag = mtag.at[ms, way].set(mline + 1)
        mclock = mclock + 1
        mlru = mlru.at[ms, way].set(mclock)
        keep = jnp.where(hit, mdirty[ms, way], False)
        mdirty = mdirty.at[ms, way].set(keep | make_dirty)
        deltas = (
            jnp.where(hit, 0, 1),            # meta_reads
            jnp.where(vic_dirty, 1, 0),      # meta_wb
            jnp.where(hit, 1, 0),            # meta_hits
        )
        return (mtag, mlru, mdirty, mclock), deltas

    def _sel_state(apply, new, old):
        return tuple(jnp.where(apply, n, o) for n, o in zip(new, old, strict=True))

    def init_state(params):
        return (
            jnp.zeros((S, W), jnp.int32),           # tag
            jnp.zeros((S, W), jnp.int32),           # lru
            jnp.zeros((S, W), jnp.int32),           # valid
            jnp.zeros((S, W), jnp.int32),           # dirty
            jnp.zeros((S, W), jnp.int32),           # pf
            jnp.zeros((cfg.n_groups,), jnp.int8),   # mem_state (all S_U)
            jnp.zeros((LCT_ENTRIES,), jnp.int8),    # lct
            (
                jnp.zeros((MS, MW), jnp.int32),
                jnp.zeros((MS, MW), jnp.int32),
                jnp.zeros((MS, MW), bool),
                jnp.asarray(0, jnp.int32),
            ),
            params[PARAM_COUNTER_INIT].astype(jnp.int32),   # dyn counter
            jnp.asarray(0, jnp.int32),              # clock
            jnp.zeros((N_STATS,), jnp.int32),
        )

    def run_chunk(carry, flags, params, addrs, is_write,
                  pair_ab, pair_cd, quad):
        f_comp = flags[FLAG_COMP] > 0
        f_llp = flags[FLAG_LLP] > 0
        f_meta = flags[FLAG_META] > 0
        f_next = flags[FLAG_NEXTLINE] > 0
        f_ideal = flags[FLAG_IDEAL] > 0
        f_dyn = flags[FLAG_DYNAMIC] > 0
        f_lct = flags[FLAG_LCT_UPDATE] > 0
        lct_size = params[PARAM_LCT_SIZE].astype(jnp.uint32)
        sample_thresh = params[PARAM_SAMPLE_THRESH]
        meta_sets = params[PARAM_META_SETS]

        def step(carry, evn):
            (tag, lru, valid, dirty, pf, mem_state, lct, mstate, counter,
             clock, stats) = carry
            addr, wr = evn
            addr = addr.astype(jnp.int32)
            g = addr >> 2
            lane = addr & 3
            lane_bit = (jnp.int32(1) << lane)
            s = g % S
            clock = clock + 1

            row_tag = tag[s]
            match = row_tag == g + 1
            tag_hit = match.any()
            way = jnp.argmax(match)
            v_here = jnp.where(tag_hit, valid[s, way], 0)
            hit = tag_hit & ((v_here & lane_bit) != 0)
            miss = ~hit
            sampled = SET_HASH[s] < sample_thresh
            dyn_on = counter >= ENABLE_THRESHOLD

            pf_bit = jnp.where(hit, (pf[s, way] & lane_bit) != 0, False)

            # ----------------------------- fetch accounting (miss path)
            st = mem_state[g].astype(jnp.int32)
            pidx = (
                (addr // LINES_PER_PAGE).astype(jnp.uint32)
                * np.uint32(HASH_MULT) % lct_size
            ).astype(jnp.int32)
            pred_level = lct[pidx].astype(jnp.int32)
            probes = jnp.where(
                f_llp & (lane != 0), PROBE[st, lane, pred_level], jnp.int32(1)
            )
            true_slot = LOC_J[st, lane]
            obt_next = lane_bit | jnp.where(lane < 3, lane_bit << 1, 0)
            obtained = jnp.where(
                f_comp, LIS_J[st, true_slot],
                jnp.where(f_next, obt_next, lane_bit),
            )

            # victim: merge into existing way when the group tag is present
            empty = row_tag == 0
            vway = jnp.where(
                tag_hit, way,
                jnp.where(empty.any(), jnp.argmax(empty), jnp.argmin(lru[s])),
            )
            evicting = miss & (~tag_hit) & (row_tag[vway] != 0)
            vg = row_tag[vway] - 1
            vst = mem_state[vg].astype(jnp.int32)
            v_valid = valid[s, vway]
            v_dirty = dirty[s, vway]

            ev_enabled = jnp.where(
                f_dyn, (sampled | dyn_on).astype(jnp.int32),
                f_comp.astype(jnp.int32),
            )
            eidx = evict_table_index(
                ev_enabled, vst,
                pair_ab[vg].astype(jnp.int32),
                pair_cd[vg].astype(jnp.int32),
                quad[vg].astype(jnp.int32),
                v_valid, v_dirty,
            )
            wb_d = jnp.where(evicting, EVT["wb_dirty"][eidx], 0)
            wb_c = jnp.where(evicting, EVT["wb_clean"][eidx], 0)
            ilw = jnp.where(evicting, EVT["il"][eidx], 0)
            ns = jnp.where(evicting, EVT["new_state"][eidx], vst)
            # ideal: benefits without maintenance overheads
            wb_c = jnp.where(f_ideal, 0, wb_c)
            ilw = jnp.where(f_ideal, 0, ilw)

            # ------------------------------------------------- stats
            stats = stats.at[ST_LLC_HITS].add(jnp.where(hit, 1, 0))
            stats = stats.at[ST_LLC_MISSES].add(jnp.where(miss, 1, 0))
            stats = stats.at[ST_PF_USED].add(jnp.where(hit & pf_bit, 1, 0))
            stats = stats.at[ST_DEMAND_READS].add(jnp.where(miss, 1, 0))
            stats = stats.at[ST_READ_PROBES].add(jnp.where(miss, probes, 0))
            stats = stats.at[ST_WB_DIRTY].add(wb_d)
            stats = stats.at[ST_WB_CLEAN].add(wb_c)
            stats = stats.at[ST_IL_WRITES].add(ilw)
            need_pred = f_llp & miss & (lane > 0)
            stats = stats.at[ST_PRED_TOTAL].add(jnp.where(need_pred, 1, 0))
            stats = stats.at[ST_PRED_HIT].add(
                jnp.where(need_pred & (probes == 1), 1, 0))
            stats = stats.at[ST_PF_EXTRA_ACCESS].add(
                jnp.where(f_next & miss, 1, 0))

            # dynamic cost/benefit counter (gated; others keep their init)
            cost = jnp.where(evicting & sampled, wb_c + ilw, 0) + \
                jnp.where(miss & sampled, probes - 1, 0)
            benefit = jnp.where(hit & pf_bit & sampled, 1, 0)
            counter = jnp.where(
                f_dyn, jnp.clip(counter + benefit - cost, 0, COUNTER_MAX),
                counter,
            )

            # explicit metadata cache (two gated probes, sequenced like the
            # old scalar path's lax.conds: demand miss first, dirty update)
            mline = g // GPM
            m1, d1 = meta_probe(mstate, mline, False, meta_sets)
            apply1 = f_meta & miss
            mstate = _sel_state(apply1, m1, mstate)
            stats = stats.at[ST_META_READS].add(jnp.where(apply1, d1[0], 0))
            stats = stats.at[ST_META_WB].add(jnp.where(apply1, d1[1], 0))
            stats = stats.at[ST_META_HITS].add(jnp.where(apply1, d1[2], 0))
            vmline = vg // GPM
            m2, d2 = meta_probe(mstate, vmline, True, meta_sets)
            apply2 = f_meta & evicting & (ns != vst)
            mstate = _sel_state(apply2, m2, mstate)
            stats = stats.at[ST_META_READS].add(jnp.where(apply2, d2[0], 0))
            stats = stats.at[ST_META_WB].add(jnp.where(apply2, d2[1], 0))
            stats = stats.at[ST_META_HITS].add(jnp.where(apply2, d2[2], 0))

            # LCT update (frozen when FLAG_LCT_UPDATE is off: cram-nollp)
            obs = LVL_J[st, lane].astype(lct.dtype)
            lct = jnp.where(f_lct & miss, lct.at[pidx].set(obs), lct)

            mem_state = mem_state.at[vg].set(
                jnp.where(evicting, ns.astype(mem_state.dtype), mem_state[vg])
            )

            # ------------------- LLC array updates (hit & miss merged)
            new_valid_miss = jnp.where(tag_hit, v_here | obtained, obtained)
            prev_pf = jnp.where(tag_hit, pf[s, vway], 0)
            fresh = obtained & ~jnp.where(tag_hit, v_here, 0) & ~lane_bit
            new_pf_miss = (prev_pf | fresh) & ~lane_bit
            stats = stats.at[ST_PF_INSTALLED].add(
                jnp.where(miss, popcount4(fresh), 0))
            wr_bit = jnp.where(wr, lane_bit, 0)
            new_dirty_miss = jnp.where(tag_hit, dirty[s, vway], 0) | wr_bit

            uway = jnp.where(hit, way, vway)
            tag = tag.at[s, uway].set(jnp.where(hit, row_tag[way], g + 1))
            lru = lru.at[s, uway].set(clock)
            valid = valid.at[s, uway].set(
                jnp.where(hit, v_here, new_valid_miss))
            dirty = dirty.at[s, uway].set(
                jnp.where(hit, dirty[s, way] | wr_bit, new_dirty_miss))
            pf = pf.at[s, uway].set(
                jnp.where(hit, pf[s, way] & ~lane_bit, new_pf_miss))

            return (tag, lru, valid, dirty, pf, mem_state, lct, mstate,
                    counter, clock, stats), None

        final, _ = lax.scan(step, carry, (addrs, is_write))
        return final

    def run_one(flags, params, addrs, is_write, pair_ab, pair_cd, quad):
        final = run_chunk(init_state(params), flags, params,
                          addrs, is_write, pair_ab, pair_cd, quad)
        return final[-1]

    return EngineParts(init_state=init_state, run_chunk=run_chunk,
                       run_one=run_one)


__all__ = [
    "ST_READ_PROBES", "ST_DEMAND_READS", "ST_WB_DIRTY", "ST_WB_CLEAN",
    "ST_IL_WRITES", "ST_META_READS", "ST_META_WB", "ST_META_HITS",
    "ST_PF_INSTALLED", "ST_PF_USED", "ST_PRED_TOTAL", "ST_PRED_HIT",
    "ST_LLC_HITS", "ST_LLC_MISSES", "ST_PF_EXTRA_ACCESS", "N_STATS",
    "STAT_NAMES",
    "FLAG_COMP", "FLAG_LLP", "FLAG_META", "FLAG_NEXTLINE", "FLAG_IDEAL",
    "FLAG_DYNAMIC", "FLAG_LCT_UPDATE", "N_FLAGS",
    "PARAM_LCT_SIZE", "PARAM_SAMPLE_THRESH", "PARAM_COUNTER_INIT",
    "PARAM_META_SETS", "N_PARAMS",
    "SimConfig", "EngineParts", "build_engine", "default_params",
    "sample_threshold",
]
