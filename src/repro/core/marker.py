"""Moved: repro.compression.marker is the implementation (host-side keyed
markers + implicit-metadata line classification, §V-A)."""

from ..compression.framing import LINE_BYTES, MARKER_BYTES  # noqa: F401
from ..compression.marker import (  # noqa: F401
    LineStatus,
    MarkerSpec,
    classify_line,
    collision_probability,
    invert_line,
    needs_inversion,
)
