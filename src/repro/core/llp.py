"""Line Location Predictor (LLP) — §V-B.

A 512-entry Last Compressibility Table (LCT), indexed by a hash of the page
address, records the last compressibility *level* observed for lines of that
page (0 = uncompressed, 1 = 2:1, 2 = 4:1).  Predicting the level predicts the
slot to probe (mapping.PRED_SLOT).  128 bytes of state at 2 bits/entry
(we store a byte per entry for simplicity; Table III accounting uses 2 bits).

Works both as a host-side object (functional model) and as pure functions on
a jnp array (trace simulator).
"""

from __future__ import annotations

import numpy as np

LCT_ENTRIES = 512
LINES_PER_PAGE = 64  # 4KB page / 64B lines

_HASH_MULT = 0x9E3779B1  # Fibonacci hashing


def page_of(line_addr):
    return line_addr // LINES_PER_PAGE


def lct_index(page, n_entries: int = LCT_ENTRIES):
    return ((page * _HASH_MULT) & 0xFFFFFFFF) % n_entries


class LLP:
    """Host-side predictor used by the exact functional model."""

    def __init__(self, n_entries: int = LCT_ENTRIES):
        self.n_entries = n_entries
        self.lct = np.zeros(n_entries, dtype=np.int8)
        self.predictions = 0
        self.correct = 0

    def predict_level(self, line_addr: int) -> int:
        return int(self.lct[lct_index(page_of(line_addr), self.n_entries)])

    def update(self, line_addr: int, observed_level: int) -> None:
        self.lct[lct_index(page_of(line_addr), self.n_entries)] = observed_level

    def record_outcome(self, was_correct: bool) -> None:
        self.predictions += 1
        self.correct += int(was_correct)

    @property
    def accuracy(self) -> float:
        return self.correct / self.predictions if self.predictions else 1.0

    @property
    def storage_bytes(self) -> int:
        return self.n_entries * 2 // 8  # 2 bits/entry as in Table III


# -- pure-function variants for lax.scan ------------------------------------

def llp_predict(lct, line_addr, xp):
    idx = lct_index(page_of(line_addr), lct.shape[0])
    return lct[idx]


def llp_update(lct, line_addr, level, xp):
    idx = lct_index(page_of(line_addr), lct.shape[0])
    if xp is np:
        lct = lct.copy()
        lct[idx] = level
        return lct
    return lct.at[idx].set(level)
