"""Moved: repro.compression.predictor is the implementation (THE line
location predictor, §V-B)."""

from ..compression.predictor import (  # noqa: F401
    _HASH_MULT,
    HASH_MULT,
    LCT_ENTRIES,
    LINES_PER_PAGE,
    LLP,
    lct_index,
    llp_predict,
    llp_update,
    page_of,
)
