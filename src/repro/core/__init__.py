"""CRAM core: the paper's contribution as a reusable library.

Layers:
  * codecs: fpc, bdi, compress (hybrid FPC+BDI with in-line headers)
  * protocol: marker (implicit metadata), mapping (restricted 4-line groups),
    lit (inversion table), llp (line-location predictor), dynamic (cost/benefit
    counter), evict_logic (layout transitions)
  * models: cram (exact functional compressed memory), llc (group LLC),
    memsim (fast trace-driven bandwidth simulator), traces (workload suite)
"""

from . import bdi, compress, dynamic, evict_logic, fpc, lit, llc, llp, mapping
from . import marker
from .batchsim import sweep, sweep_workloads
from .cram import CRAMStats, CRAMSystem
from .memsim import SCHEMES, SimConfig, run_workload, simulate, speedup

__all__ = [
    "bdi", "compress", "dynamic", "evict_logic", "fpc", "lit", "llc", "llp",
    "mapping", "marker", "CRAMSystem", "CRAMStats", "SCHEMES", "SimConfig",
    "run_workload", "simulate", "speedup", "sweep", "sweep_workloads",
]
