"""CRAM core: the trace-simulation layer over repro.compression.

The codec/layout/mechanism stack lives in `repro.compression` (codecs,
layouts, framing, gate, predictor, marker); this package keeps the
simulation models consuming it:
  * cram (exact functional compressed memory), llc (group LLC), lit
    (inversion table), evict_logic (layout transitions)
  * engine (the one trace-sim step/state/stats definition), schemes
    (declarative scheme registry — rows name a codec+layout), memsim
    (scalar front-end), batchsim (batched scheme × config × workload
    sweep), traces (workload suite)

The historical codec/mechanism module names (fpc, bdi, compress, marker,
mapping, llp, dynamic, bits) remain importable as re-export shims.
"""

from . import bdi, compress, dynamic, engine, evict_logic, fpc, lit, llc, llp
from . import mapping, marker, schemes
from .batchsim import sweep, sweep_workloads
from .cram import CRAMStats, CRAMSystem
from .engine import N_STATS, STAT_NAMES  # single definition, engine-owned
from .engine import (
    ST_DEMAND_READS,
    ST_IL_WRITES,
    ST_LLC_HITS,
    ST_LLC_MISSES,
    ST_META_HITS,
    ST_META_READS,
    ST_META_WB,
    ST_PF_EXTRA_ACCESS,
    ST_PF_INSTALLED,
    ST_PF_USED,
    ST_PRED_HIT,
    ST_PRED_TOTAL,
    ST_READ_PROBES,
    ST_WB_CLEAN,
    ST_WB_DIRTY,
)
from .memsim import SCHEMES, SimConfig, run_workload, simulate, speedup
from .schemes import Scheme

__all__ = [
    "bdi", "compress", "dynamic", "engine", "evict_logic", "fpc", "lit",
    "llc", "llp", "mapping", "marker", "schemes", "CRAMSystem", "CRAMStats",
    "Scheme", "SCHEMES", "SimConfig", "run_workload", "simulate", "speedup",
    "sweep", "sweep_workloads", "N_STATS", "STAT_NAMES",
    "ST_READ_PROBES", "ST_DEMAND_READS", "ST_WB_DIRTY", "ST_WB_CLEAN",
    "ST_IL_WRITES", "ST_META_READS", "ST_META_WB", "ST_META_HITS",
    "ST_PF_INSTALLED", "ST_PF_USED", "ST_PRED_TOTAL", "ST_PRED_HIT",
    "ST_LLC_HITS", "ST_LLC_MISSES", "ST_PF_EXTRA_ACCESS",
]
