"""Trace-driven bandwidth simulator for all schemes in the paper (jax.lax.scan).

Schemes:
  baseline   — uncompressed memory (the normalization target)
  nextline   — uncompressed + next-line prefetch on miss (Table V)
  ideal      — compression benefits with zero maintenance overheads (Fig. 3/16)
  explicit   — CRAM with explicit metadata + 32KB metadata cache (Fig. 7/12)
  cram       — CRAM + implicit metadata + LLP, always compress (Fig. 12/16)
  dynamic    — Dynamic-CRAM with 1% set sampling + 12-bit counter (Fig. 16/18)

The LLC is group-granular with ganged fill/eviction (see llc.py docstring);
eviction layout transitions and their bandwidth costs come from
evict_logic.build_evict_table — the same logic the exact functional model
executes, so the two simulators agree by construction.

Performance model (DESIGN.md §2.2): speedup = 1/((1-f) + f·ratio) with f the
workload's memory-bound fraction and ratio = scheme_accesses/baseline_accesses.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import numpy as np

from .dynamic import (
    COUNTER_INIT,
    COUNTER_MAX,
    ENABLE_THRESHOLD,
    is_sampled_set,
)
from .evict_logic import build_evict_table, evict_table_index
from .llp import LCT_ENTRIES, LINES_PER_PAGE, _HASH_MULT
from .mapping import LANE_LEVEL, LANES_IN_SLOT, LOC, PRED_SLOT, probe_chain

SCHEMES = ("baseline", "nextline", "ideal", "explicit", "cram", "dynamic")

# stats vector layout
(
    ST_READ_PROBES,
    ST_DEMAND_READS,
    ST_WB_DIRTY,
    ST_WB_CLEAN,
    ST_IL_WRITES,
    ST_META_READS,
    ST_META_WB,
    ST_META_HITS,
    ST_PF_INSTALLED,
    ST_PF_USED,
    ST_PRED_TOTAL,
    ST_PRED_HIT,
    ST_LLC_HITS,
    ST_LLC_MISSES,
    ST_PF_EXTRA_ACCESS,
    N_STATS,
) = range(16)

_STAT_NAMES = (
    "read_probes", "demand_reads", "wb_dirty", "wb_clean", "il_writes",
    "meta_reads", "meta_wb", "meta_hits", "pf_installed", "pf_used",
    "pred_total", "pred_hit", "llc_hits", "llc_misses", "pf_extra_access",
)


def _probe_count_table() -> np.ndarray:
    """PROBE[state, lane, predicted_level] -> memory accesses to locate line."""
    t = np.zeros((5, 4, 3), dtype=np.int32)
    for st in range(5):
        for lane in range(4):
            for lvl in range(3):
                pred = int(PRED_SLOT[lane][lvl]) if lane else 0
                chain = probe_chain(lane, pred) if lane else [0]
                t[st, lane, lvl] = chain.index(int(LOC[st][lane])) + 1
    return t


@dataclass(frozen=True)
class SimConfig:
    # The paper's 8MB LLC is scaled with the footprint cap (DESIGN.md §2.2):
    # 128 sets x 8 ways x 4 lanes x 64B = 256KB against a <=64MB footprint
    # preserves the footprint/LLC ratio of Table II workloads.
    llc_sets: int = 128
    llc_ways: int = 8
    n_groups: int = 1 << 18       # matches traces.GROUPS_TOTAL
    meta_sets: int = 64           # 32KB metadata cache: 64 sets x 8 ways x 64B
    meta_ways: int = 8
    groups_per_meta: int = 128    # ~170 groups per 64B metadata line; pow2
    compress_clean: bool = True
    sample_rate: float = 0.08     # scaled from the paper's 1% (trace-length)


@dataclass
class SimResult:
    scheme: str
    stats: dict
    accesses: int
    llp_accuracy: float
    meta_hit_rate: float

    def bandwidth_breakdown(self) -> dict:
        s = self.stats
        return {
            "data_reads": s["demand_reads"],
            "mispredict_extra": s["read_probes"] - s["demand_reads"],
            "wb_dirty": s["wb_dirty"],
            "wb_clean+invalidate": s["wb_clean"] + s["il_writes"],
            "metadata": s["meta_reads"] + s["meta_wb"],
            "prefetch_extra": s["pf_extra_access"],
        }


@functools.lru_cache(maxsize=None)
def _jit_sim(scheme: str, cfg: SimConfig):
    import jax
    import jax.numpy as jnp
    from jax import lax

    assert scheme in SCHEMES, scheme
    S, W = cfg.llc_sets, cfg.llc_ways
    MS, MW, GPM = cfg.meta_sets, cfg.meta_ways, cfg.groups_per_meta
    comp_scheme = scheme in ("ideal", "explicit", "cram", "dynamic")

    EVT = {k: jnp.asarray(v) for k, v in
           build_evict_table(cfg.compress_clean).items()}
    PROBE = jnp.asarray(_probe_count_table())
    LOC_J = jnp.asarray(LOC)
    LIS_J = jnp.asarray(LANES_IN_SLOT)
    LVL_J = jnp.asarray(LANE_LEVEL)
    SAMPLED = jnp.asarray(
        np.asarray([bool(is_sampled_set(i, S, rate=cfg.sample_rate))
                    for i in range(S)])
    )

    def popcount4(x):
        return ((x >> 0) & 1) + ((x >> 1) & 1) + ((x >> 2) & 1) + ((x >> 3) & 1)

    def meta_probe(mstate, mline, make_dirty, stats):
        """32KB metadata-cache access; returns updated (mstate, stats)."""
        mtag, mlru, mdirty, mclock = mstate
        ms = mline % MS
        row = mtag[ms]
        match = row == mline + 1
        hit = match.any()
        empty = row == 0
        vic = jnp.where(empty.any(), jnp.argmax(empty), jnp.argmin(mlru[ms]))
        way = jnp.where(hit, jnp.argmax(match), vic)
        vic_dirty = (~hit) & (row[way] != 0) & mdirty[ms, way]
        stats = stats.at[ST_META_READS].add(jnp.where(hit, 0, 1))
        stats = stats.at[ST_META_WB].add(jnp.where(vic_dirty, 1, 0))
        stats = stats.at[ST_META_HITS].add(jnp.where(hit, 1, 0))
        mtag = mtag.at[ms, way].set(mline + 1)
        mclock = mclock + 1
        mlru = mlru.at[ms, way].set(mclock)
        keep = jnp.where(hit, mdirty[ms, way], False)
        mdirty = mdirty.at[ms, way].set(keep | make_dirty)
        return (mtag, mlru, mdirty, mclock), stats

    def run(addrs, is_write, pair_ab, pair_cd, quad):
        def step(carry, evn):
            (tag, lru, valid, dirty, pf, mem_state, lct, mstate, counter,
             clock, stats) = carry
            addr, wr = evn
            addr = addr.astype(jnp.int32)
            g = addr >> 2
            lane = addr & 3
            lane_bit = (jnp.int32(1) << lane)
            s = g % S
            clock = clock + 1

            row_tag = tag[s]
            match = row_tag == g + 1
            tag_hit = match.any()
            way = jnp.argmax(match)
            v_here = jnp.where(tag_hit, valid[s, way], 0)
            hit = tag_hit & ((v_here & lane_bit) != 0)
            miss = ~hit
            sampled = SAMPLED[s]
            dyn_on = counter >= ENABLE_THRESHOLD

            pf_bit = jnp.where(hit, (pf[s, way] & lane_bit) != 0, False)

            # ----------------------------- fetch accounting (miss path)
            st = mem_state[g].astype(jnp.int32)
            pidx = (
                (addr // LINES_PER_PAGE).astype(jnp.uint32)
                * np.uint32(_HASH_MULT) % np.uint32(LCT_ENTRIES)
            ).astype(jnp.int32)
            pred_level = lct[pidx].astype(jnp.int32)
            if scheme in ("cram", "dynamic"):
                probes = jnp.where(lane == 0, 1, PROBE[st, lane, pred_level])
            else:
                probes = jnp.int32(1)
            if comp_scheme:
                true_slot = LOC_J[st, lane]
                obtained = LIS_J[st, true_slot]
            elif scheme == "nextline":
                obtained = lane_bit | jnp.where(lane < 3, lane_bit << 1, 0)
            else:
                obtained = lane_bit

            # victim: merge into existing way when the group tag is present
            empty = row_tag == 0
            vway = jnp.where(
                tag_hit, way,
                jnp.where(empty.any(), jnp.argmax(empty), jnp.argmin(lru[s])),
            )
            evicting = miss & (~tag_hit) & (row_tag[vway] != 0)
            vg = row_tag[vway] - 1
            vst = mem_state[vg].astype(jnp.int32)
            v_valid = valid[s, vway]
            v_dirty = dirty[s, vway]

            if scheme == "dynamic":
                ev_enabled = (sampled | dyn_on).astype(jnp.int32)
            elif comp_scheme:
                ev_enabled = jnp.int32(1)
            else:
                ev_enabled = jnp.int32(0)
            eidx = evict_table_index(
                ev_enabled, vst,
                pair_ab[vg].astype(jnp.int32),
                pair_cd[vg].astype(jnp.int32),
                quad[vg].astype(jnp.int32),
                v_valid, v_dirty,
            )
            wb_d = jnp.where(evicting, EVT["wb_dirty"][eidx], 0)
            wb_c = jnp.where(evicting, EVT["wb_clean"][eidx], 0)
            ilw = jnp.where(evicting, EVT["il"][eidx], 0)
            ns = jnp.where(evicting, EVT["new_state"][eidx], vst)
            if scheme == "ideal":  # benefits without maintenance overheads
                wb_c = jnp.zeros_like(wb_c)
                ilw = jnp.zeros_like(ilw)

            # ------------------------------------------------- stats
            stats = stats.at[ST_LLC_HITS].add(jnp.where(hit, 1, 0))
            stats = stats.at[ST_LLC_MISSES].add(jnp.where(miss, 1, 0))
            stats = stats.at[ST_PF_USED].add(jnp.where(hit & pf_bit, 1, 0))
            stats = stats.at[ST_DEMAND_READS].add(jnp.where(miss, 1, 0))
            stats = stats.at[ST_READ_PROBES].add(jnp.where(miss, probes, 0))
            stats = stats.at[ST_WB_DIRTY].add(wb_d)
            stats = stats.at[ST_WB_CLEAN].add(wb_c)
            stats = stats.at[ST_IL_WRITES].add(ilw)
            if scheme in ("cram", "dynamic"):
                need_pred = miss & (lane > 0)
                stats = stats.at[ST_PRED_TOTAL].add(
                    jnp.where(need_pred, 1, 0))
                stats = stats.at[ST_PRED_HIT].add(
                    jnp.where(need_pred & (probes == 1), 1, 0))
            if scheme == "nextline":
                stats = stats.at[ST_PF_EXTRA_ACCESS].add(
                    jnp.where(miss, 1, 0))

            if scheme == "dynamic":
                cost = jnp.where(evicting & sampled, wb_c + ilw, 0) + \
                    jnp.where(miss & sampled, probes - 1, 0)
                benefit = jnp.where(hit & pf_bit & sampled, 1, 0)
                counter = jnp.clip(counter + benefit - cost, 0, COUNTER_MAX)

            if scheme == "explicit":
                mline = g // GPM
                mstate, stats = lax.cond(
                    miss,
                    lambda a: meta_probe(a[0], mline, False, a[1]),
                    lambda a: a,
                    (mstate, stats),
                )
                vmline = vg // GPM
                mstate, stats = lax.cond(
                    evicting & (ns != vst),
                    lambda a: meta_probe(a[0], vmline, True, a[1]),
                    lambda a: a,
                    (mstate, stats),
                )

            if scheme in ("cram", "dynamic"):
                obs = LVL_J[st, lane].astype(lct.dtype)
                lct = jnp.where(miss, lct.at[pidx].set(obs), lct)

            mem_state = mem_state.at[vg].set(
                jnp.where(evicting, ns.astype(mem_state.dtype), mem_state[vg])
            )

            # ------------------- LLC array updates (hit & miss merged)
            new_valid_miss = jnp.where(tag_hit, v_here | obtained, obtained)
            prev_pf = jnp.where(tag_hit, pf[s, vway], 0)
            fresh = obtained & ~jnp.where(tag_hit, v_here, 0) & ~lane_bit
            new_pf_miss = (prev_pf | fresh) & ~lane_bit
            stats = stats.at[ST_PF_INSTALLED].add(
                jnp.where(miss, popcount4(fresh), 0))
            wr_bit = jnp.where(wr, lane_bit, 0)
            new_dirty_miss = jnp.where(tag_hit, dirty[s, vway], 0) | wr_bit

            uway = jnp.where(hit, way, vway)
            tag = tag.at[s, uway].set(jnp.where(hit, row_tag[way], g + 1))
            lru = lru.at[s, uway].set(clock)
            valid = valid.at[s, uway].set(
                jnp.where(hit, v_here, new_valid_miss))
            dirty = dirty.at[s, uway].set(
                jnp.where(hit, dirty[s, way] | wr_bit, new_dirty_miss))
            pf = pf.at[s, uway].set(
                jnp.where(hit, pf[s, way] & ~lane_bit, new_pf_miss))

            return (tag, lru, valid, dirty, pf, mem_state, lct, mstate,
                    counter, clock, stats), None

        state = (
            jnp.zeros((S, W), jnp.int32),           # tag
            jnp.zeros((S, W), jnp.int32),           # lru
            jnp.zeros((S, W), jnp.int32),           # valid
            jnp.zeros((S, W), jnp.int32),           # dirty
            jnp.zeros((S, W), jnp.int32),           # pf
            jnp.zeros((cfg.n_groups,), jnp.int8),   # mem_state (all S_U)
            jnp.zeros((LCT_ENTRIES,), jnp.int8),    # lct
            (
                jnp.zeros((MS, MW), jnp.int32),
                jnp.zeros((MS, MW), jnp.int32),
                jnp.zeros((MS, MW), bool),
                jnp.asarray(0, jnp.int32),
            ),
            jnp.asarray(COUNTER_INIT, jnp.int32),
            jnp.asarray(0, jnp.int32),
            jnp.zeros((N_STATS,), jnp.int32),
        )
        final, _ = lax.scan(step, state, (addrs, is_write))
        return final[-1]

    return jax.jit(run)


def summarize_stats(scheme: str, stats_vec) -> SimResult:
    """Fold a raw N_STATS vector into a SimResult (shared with batchsim)."""
    stats = dict(zip(_STAT_NAMES, (int(x) for x in np.asarray(stats_vec))))
    accesses = (
        stats["read_probes"] + stats["wb_dirty"] + stats["wb_clean"]
        + stats["il_writes"] + stats["meta_reads"] + stats["meta_wb"]
        + stats["pf_extra_access"]
    )
    llp_acc = (
        stats["pred_hit"] / stats["pred_total"] if stats["pred_total"] else 1.0
    )
    meta_tot = stats["meta_hits"] + stats["meta_reads"]
    meta_hr = stats["meta_hits"] / meta_tot if meta_tot else 1.0
    return SimResult(scheme, stats, accesses, llp_acc, meta_hr)


def simulate(scheme: str, addrs, is_write, pair_ab, pair_cd, quad,
             cfg: SimConfig = SimConfig()) -> SimResult:
    import jax.numpy as jnp

    fn = _jit_sim(scheme, cfg)
    stats_vec = np.asarray(
        fn(
            jnp.asarray(addrs, jnp.int32),
            jnp.asarray(is_write),
            jnp.asarray(pair_ab),
            jnp.asarray(pair_cd),
            jnp.asarray(quad),
        )
    )
    return summarize_stats(scheme, stats_vec)


def speedup(baseline_accesses: int, scheme_accesses: int, f: float) -> float:
    ratio = scheme_accesses / max(baseline_accesses, 1)
    return 1.0 / ((1.0 - f) + f * ratio)


def summarize_workload(name: str, f: float, results: dict[str, SimResult],
                       baseline_accesses: int) -> dict:
    """Per-workload summary dict (shared between the scalar and batched
    drivers so their reports are field-for-field comparable)."""
    summary = {
        sch: {
            "accesses": r.accesses,
            "speedup": speedup(baseline_accesses, r.accesses, f),
            "llp_accuracy": r.llp_accuracy,
            "meta_hit_rate": r.meta_hit_rate,
            "breakdown": r.bandwidth_breakdown(),
        }
        for sch, r in results.items()
    }
    return {"workload": name, "f": f,
            "baseline_accesses": baseline_accesses, "schemes": summary}


def run_workload(name: str, schemes=SCHEMES, n_events: int = 200_000,
                 seed: int = 0, cfg: SimConfig = SimConfig()):
    """Simulate one workload under several schemes; returns summary dict."""
    from .traces import build_workload

    meta, addrs, is_write, pab, pcd, pq, f = build_workload(name, n_events, seed)
    out, base = {}, None
    for sch in schemes:
        res = simulate(sch, addrs, is_write, pab, pcd, pq, cfg)
        out[sch] = res
        if sch == "baseline":
            base = res.accesses
    if base is None:
        base = simulate("baseline", addrs, is_write, pab, pcd, pq, cfg).accesses
    return summarize_workload(name, f, out, base)
