"""Scalar trace-driven bandwidth simulator — the engine's 1×1 instantiation.

The step function, state constructor, and stat layout live in
`core.engine` (the single source of truth shared with the batched sweep
in `core.batchsim`); scheme semantics live in the `core.schemes`
registry.  This module keeps the per-scheme front-end: `simulate` closes
one scheme's (flags, params) row over the engine step as compile-time
constants, so XLA folds the behaviour gates into the same specialized
per-scheme program the old hand-written steps produced — results are
bit-identical (tests/test_engine.py pins the golden stats).

Schemes (see schemes.py for the registry, DESIGN.md §4 for semantics):
  baseline   — uncompressed memory (the normalization target)
  nextline   — uncompressed + next-line prefetch on miss (Table V)
  ideal      — compression benefits with zero maintenance overheads (Fig. 3/16)
  explicit   — CRAM with explicit metadata + 32KB metadata cache (Fig. 7/12)
  cram       — CRAM + implicit metadata + LLP, always compress (Fig. 12/16)
  dynamic    — Dynamic-CRAM with 1% set sampling + 12-bit counter (Fig. 16/18)

Performance model (DESIGN.md §2.2): speedup = 1/((1-f) + f·ratio) with f the
workload's memory-bound fraction and ratio = scheme_accesses/baseline_accesses.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import numpy as np

from . import schemes as schemes_registry
from .engine import (  # noqa: F401  (stat indices re-exported for callers)
    N_STATS,
    ST_DEMAND_READS,
    ST_IL_WRITES,
    ST_LLC_HITS,
    ST_LLC_MISSES,
    ST_META_HITS,
    ST_META_READS,
    ST_META_WB,
    ST_PF_EXTRA_ACCESS,
    ST_PF_INSTALLED,
    ST_PF_USED,
    ST_PRED_HIT,
    ST_PRED_TOTAL,
    ST_READ_PROBES,
    ST_WB_CLEAN,
    ST_WB_DIRTY,
    STAT_NAMES,
    SimConfig,
    _probe_count_table,  # noqa: F401  (legacy import site)
    build_engine,
)
from .schemes import BASE_SCHEMES as SCHEMES

# back-compat alias; the canonical tuple is engine.STAT_NAMES
_STAT_NAMES = STAT_NAMES


@dataclass
class SimResult:
    scheme: str
    stats: dict
    accesses: int
    llp_accuracy: float
    meta_hit_rate: float

    def bandwidth_breakdown(self) -> dict:
        s = self.stats
        return {
            "data_reads": s["demand_reads"],
            "mispredict_extra": s["read_probes"] - s["demand_reads"],
            "wb_dirty": s["wb_dirty"],
            "wb_clean+invalidate": s["wb_clean"] + s["il_writes"],
            "metadata": s["meta_reads"] + s["meta_wb"],
            "prefetch_extra": s["pf_extra_access"],
        }


def _scheme_consts(scheme: schemes_registry.Scheme, cfg: SimConfig):
    import jax.numpy as jnp

    return jnp.asarray(scheme.flags()), jnp.asarray(scheme.params(cfg))


@functools.lru_cache(maxsize=64)
def _jit_sim(scheme: schemes_registry.Scheme, cfg: SimConfig):
    """Specialized jitted run for one scheme: engine step with the scheme's
    (flags, params) closed over as constants.

    The cache is bounded because the key space is open (any Scheme record);
    large config sweeps belong on batchsim.sweep, where variants are data
    rows of one compilation rather than one specialized program each."""
    import jax

    eng = build_engine(cfg)
    fl, pr = _scheme_consts(scheme, cfg)

    def run(addrs, is_write, pair_ab, pair_cd, quad):
        return eng.run_one(fl, pr, addrs, is_write, pair_ab, pair_cd, quad)

    return jax.jit(run)


@functools.lru_cache(maxsize=64)
def _jit_sim_chunked(scheme: schemes_registry.Scheme, cfg: SimConfig):
    """(init, chunk) pair for chunked scalar execution with a donated carry
    (donation is a no-op on CPU, where XLA does not implement it)."""
    import jax

    eng = build_engine(cfg)
    fl, pr = _scheme_consts(scheme, cfg)
    donate = () if jax.default_backend() == "cpu" else (0,)

    def init():
        return eng.init_state(pr)

    def chunk(carry, addrs, is_write, pair_ab, pair_cd, quad):
        return eng.run_chunk(carry, fl, pr, addrs, is_write,
                             pair_ab, pair_cd, quad)

    return jax.jit(init), jax.jit(chunk, donate_argnums=donate)


def summarize_stats(scheme: str, stats_vec) -> SimResult:
    """Fold a raw N_STATS vector into a SimResult (shared with batchsim)."""
    stats = dict(zip(STAT_NAMES, (int(x) for x in np.asarray(stats_vec)),
                     strict=True))
    accesses = (
        stats["read_probes"] + stats["wb_dirty"] + stats["wb_clean"]
        + stats["il_writes"] + stats["meta_reads"] + stats["meta_wb"]
        + stats["pf_extra_access"]
    )
    llp_acc = (
        stats["pred_hit"] / stats["pred_total"] if stats["pred_total"] else 1.0
    )
    meta_tot = stats["meta_hits"] + stats["meta_reads"]
    meta_hr = stats["meta_hits"] / meta_tot if meta_tot else 1.0
    return SimResult(scheme, stats, accesses, llp_acc, meta_hr)


def simulate(scheme, addrs, is_write, pair_ab, pair_cd, quad,
             cfg: SimConfig = SimConfig(),
             chunk_size: int | None = None) -> SimResult:
    """Run one scheme over one trace.  `scheme` is a registry name or a
    schemes.Scheme record; `chunk_size` splits the trace into jitted chunk
    dispatches (bit-identical to the monolithic scan)."""
    import jax.numpy as jnp

    sch = schemes_registry.resolve(scheme)
    args = (
        jnp.asarray(addrs, jnp.int32),
        jnp.asarray(is_write),
        jnp.asarray(pair_ab),
        jnp.asarray(pair_cd),
        jnp.asarray(quad),
    )
    if chunk_size:
        init, chunk = _jit_sim_chunked(sch, cfg)
        carry = init()
        a, w, tail = args[0], args[1], args[2:]
        for lo in range(0, a.shape[0], chunk_size):
            hi = lo + chunk_size
            carry = chunk(carry, a[lo:hi], w[lo:hi], *tail)
        stats_vec = np.asarray(carry[-1])
    else:
        stats_vec = np.asarray(_jit_sim(sch, cfg)(*args))
    return summarize_stats(sch.name, stats_vec)


def speedup(baseline_accesses: int, scheme_accesses: int, f: float) -> float:
    ratio = scheme_accesses / max(baseline_accesses, 1)
    return 1.0 / ((1.0 - f) + f * ratio)


def summarize_workload(name: str, f: float, results: dict[str, SimResult],
                       baseline_accesses: int) -> dict:
    """Per-workload summary dict (shared between the scalar and batched
    drivers so their reports are field-for-field comparable).  Each
    scheme's STAT counters also land as bandwidth-ledger rows ("traffic",
    repro.bandwidth.adapters.engine_traffic) — the adapter view the
    policy layer and cross-consumer parity tests read."""
    from ..bandwidth.adapters import engine_traffic

    summary = {
        sch: {
            "accesses": r.accesses,
            "speedup": speedup(baseline_accesses, r.accesses, f),
            "llp_accuracy": r.llp_accuracy,
            "meta_hit_rate": r.meta_hit_rate,
            "breakdown": r.bandwidth_breakdown(),
            "traffic": engine_traffic(r.stats).as_dict(),
        }
        for sch, r in results.items()
    }
    return {"workload": name, "f": f,
            "baseline_accesses": baseline_accesses, "schemes": summary}


def run_workload(name: str, schemes=SCHEMES, n_events: int = 200_000,
                 seed: int = 0, cfg: SimConfig = SimConfig()):
    """Simulate one workload under several schemes; returns summary dict.

    A baseline run is required for speedup normalization; when "baseline"
    is not among the requested schemes it is folded into the main loop
    (mirroring batchsim.sweep_workloads) instead of paying a separate
    simulate dispatch after the fact.
    """
    from .traces import build_workload

    meta, addrs, is_write, pab, pcd, pq, f = build_workload(name, n_events, seed)
    requested = [schemes_registry.resolve(s) for s in schemes]
    req_names = [s.name for s in requested]
    sim_schemes = (requested if "baseline" in req_names
                   else [schemes_registry.get("baseline"), *requested])
    out, base = {}, None
    for sch in sim_schemes:
        res = simulate(sch, addrs, is_write, pab, pcd, pq, cfg)
        if sch in requested:
            out[sch.name] = res
        if sch.name == "baseline":
            base = res.accesses
    return summarize_workload(name, f, out, base)
