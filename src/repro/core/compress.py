"""Moved: repro.compression.hybrid is the implementation (hybrid FPC+BDI
line codec and marker-framed group packing)."""

from ..compression.hybrid import (  # noqa: F401
    ALG_BDI,
    ALG_FPC,
    ALG_RAW,
    HEADER_BYTES,
    LINE_BYTES,
    compress_line,
    compressed_sizes,
    decompress_line,
    group_fits,
    pack_group,
    unpack_group,
)
