"""Moved: repro.compression.bdi is the implementation (BDI line codec)."""

from ..compression.bdi import (  # noqa: F401
    BD_MODES,
    LINE_BYTES,
    M_B2D1,
    M_B4D1,
    M_B4D2,
    M_B8D1,
    M_B8D2,
    M_B8D4,
    M_RAW,
    M_REP8,
    M_ZEROS,
    MODE_BY_ID,
    PAYLOAD_BYTES,
    bdi_pack_batch,
    bdi_sizes,
    bdi_unpack_batch,
)
