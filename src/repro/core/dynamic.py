"""Moved: repro.compression.gate is the implementation (THE Dynamic-CRAM
saturating-counter cost/benefit gate, §VI)."""

from ..compression.gate import (  # noqa: F401
    COUNTER_BITS,
    COUNTER_INIT,
    COUNTER_MAX,
    ENABLE_THRESHOLD,
    SAMPLE_RATE,
    DynamicController,
    counter_enabled,
    counter_step,
    is_sampled_set,
)
