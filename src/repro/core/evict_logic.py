"""Single source of truth for CRAM eviction-time layout transitions.

Both the exact functional model (cram.py, which executes the plan against a
real memory image) and the fast trace simulator (memsim.py, which tabulates
the counts) use `evict_plan`, so their bandwidth accounting agrees by
construction (cross-checked in tests/test_evict_logic.py).

Semantics (§IV-A write operation, §V-A invalidation, §VI dynamic policy):
  * packing units are the AB half, the CD half, or the whole quad;
  * a unit may be (re)packed only if all its lanes are cached (ganged
    fill/eviction guarantees packed units are co-resident);
  * with compression enabled, clean lines are packed too iff compress_clean
    (the paper's default — the "bandwidth cost of compression");
  * with compression disabled, dirty data lands uncompressed in home slots
    (unpacking its unit); untouched/clean units keep their prior layout;
  * a slot is written iff its lane-composition changes or it holds dirty
    data; slots vacated by the new layout get a Marker-IL write.
"""

from __future__ import annotations

from dataclasses import dataclass

from ..compression.layouts import (
    LOC,
    S_AB,
    S_AB_CD,
    S_CD,
    S_QUAD,
    fits_to_state,
)

_AB_MASK, _CD_MASK, _ALL = 0b0011, 0b1100, 0b1111


@dataclass(frozen=True)
class EvictPlan:
    new_state: int
    # slots to write: (slot, lanes tuple sorted, packed: bool, dirty: bool)
    writes: tuple = ()
    il_slots: tuple = ()

    @property
    def wb_dirty(self) -> int:
        return sum(1 for w in self.writes if w[3])

    @property
    def wb_clean(self) -> int:
        return sum(1 for w in self.writes if not w[3])

    @property
    def il_count(self) -> int:
        return len(self.il_slots)


def _prior_packed(prior: int) -> tuple[bool, bool, bool]:
    return (
        prior in (S_AB, S_AB_CD) or prior == S_QUAD,
        prior in (S_CD, S_AB_CD) or prior == S_QUAD,
        prior == S_QUAD,
    )


def evict_plan(
    prior: int,
    fits_ab: bool,
    fits_cd: bool,
    fits_quad: bool,
    valid: int,
    dirty: int,
    enabled: bool,
    compress_clean: bool = True,
) -> EvictPlan:
    valid &= _ALL
    dirty &= valid
    if valid == 0:
        return EvictPlan(prior)
    if dirty == 0 and (not enabled or not compress_clean):
        return EvictPlan(prior)  # silent clean drop

    p_ab, p_cd, p_quad = _prior_packed(prior)
    if enabled:
        quad_new = bool(fits_quad) and valid == _ALL
        ab_new = (bool(fits_ab) and (valid & _AB_MASK) == _AB_MASK) or (
            (valid & _AB_MASK) == 0 and p_ab and not p_quad
        )
        cd_new = (bool(fits_cd) and (valid & _CD_MASK) == _CD_MASK) or (
            (valid & _CD_MASK) == 0 and p_cd and not p_quad
        )
    else:
        quad_new = p_quad and not dirty
        ab_new = p_ab and not p_quad and not (dirty & _AB_MASK)
        cd_new = p_cd and not p_quad and not (dirty & _CD_MASK)
    new_state = fits_to_state(ab_new, cd_new, quad_new)

    # slot composition before/after, over valid lanes only
    prior_map: dict[int, set] = {}
    new_map: dict[int, set] = {}
    for lane in range(4):
        if valid & (1 << lane):
            prior_map.setdefault(int(LOC[prior][lane]), set()).add(lane)
            new_map.setdefault(int(LOC[new_state][lane]), set()).add(lane)

    writes = []
    for slot in sorted(new_map):
        lanes = tuple(sorted(new_map[slot]))
        changed = prior_map.get(slot, set()) != set(lanes)
        has_dirty = any(dirty & (1 << l) for l in lanes)
        if changed or has_dirty:
            writes.append((slot, lanes, len(lanes) > 1, has_dirty))
    il_slots = tuple(sorted(set(prior_map) - set(new_map)))
    return EvictPlan(new_state, tuple(writes), il_slots)


def build_evict_table(compress_clean: bool = True):
    """Dense lookup tables for the lax.scan simulator.

    Index: ((((enabled*5 + prior)*2 + fab)*2 + fcd)*2 + fq)*16 + valid)*16
           + dirty
    Returns dict of numpy arrays: wb_dirty, wb_clean, il, new_state.
    """
    import numpy as np

    n = 2 * 5 * 2 * 2 * 2 * 16 * 16
    wb_d = np.zeros(n, dtype=np.int32)
    wb_c = np.zeros(n, dtype=np.int32)
    il = np.zeros(n, dtype=np.int32)
    ns = np.zeros(n, dtype=np.int32)
    i = 0
    for enabled in range(2):
        for prior in range(5):
            for fab in range(2):
                for fcd in range(2):
                    for fq in range(2):
                        for valid in range(16):
                            for dirty in range(16):
                                p = evict_plan(
                                    prior, fab, fcd, fq, valid, dirty,
                                    bool(enabled), compress_clean,
                                )
                                wb_d[i] = p.wb_dirty
                                wb_c[i] = p.wb_clean
                                il[i] = p.il_count
                                ns[i] = p.new_state
                                i += 1
    return {"wb_dirty": wb_d, "wb_clean": wb_c, "il": il, "new_state": ns}


def evict_table_index(enabled, prior, fab, fcd, fq, valid, dirty):
    """Same flattening as build_evict_table, works on scalars or arrays."""
    return (
        ((((((enabled * 5 + prior) * 2 + fab) * 2 + fcd) * 2 + fq) * 16)
         + valid) * 16 + dirty
    )
