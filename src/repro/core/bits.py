"""Moved: repro.compression.bits is the implementation (codec bit plumbing)."""

from ..compression.bits import (  # noqa: F401
    BitReader,
    BitWriter,
    bytes_to_u32,
    sign_extend,
    u32_to_bytes,
)
