"""Restricted data mapping for groups of 4 lines (Fig. 6).

A group of four consecutive lines (lanes A=0, B=1, C=2, D=3) is stored in one
of exactly five layout states.  Lane 0 never moves; each lane has at most
three candidate slots (two on average), which is what makes the line-location
prediction problem small.

        lane:     A  B  C  D        vacated (Marker-IL) slots
  S_U          :  0  1  2  3        -
  S_AB         :  0  0  2  3        1
  S_CD         :  0  1  2  2        3
  S_AB_CD      :  0  0  2  2        1, 3
  S_QUAD       :  0  0  0  0        1, 2, 3

The Compression Status Information (CSI) for a group is one of these five
states = 3 bits/group = 0.75 bits/line (matches §IV-B's 24MB for 16GB).
"""

from __future__ import annotations

import numpy as np

GROUP_LINES = 4
SLOT_BUDGET = 64
MARKER_BYTES = 4
PAYLOAD_BUDGET = SLOT_BUDGET - MARKER_BYTES  # 60B usable when packed

S_U, S_AB, S_CD, S_AB_CD, S_QUAD = range(5)
N_STATES = 5
STATE_NAMES = ("uncomp", "AB", "CD", "AB+CD", "quad")

# LOC[state][lane] -> slot holding that lane's data
LOC = np.asarray(
    [
        [0, 1, 2, 3],
        [0, 0, 2, 3],
        [0, 1, 2, 2],
        [0, 0, 2, 2],
        [0, 0, 0, 0],
    ],
    dtype=np.int32,
)

# VACATED[state][slot] -> slot holds Marker-IL
VACATED = np.asarray(
    [
        [0, 0, 0, 0],
        [0, 1, 0, 0],
        [0, 0, 0, 1],
        [0, 1, 0, 1],
        [0, 1, 1, 1],
    ],
    dtype=bool,
)

# OCCUPIED[state][slot] -> slot holds data (lead slot of a packed run or a
# plain uncompressed line)
OCCUPIED = ~VACATED

# How many lines live in a given slot for a given state (0 if vacated)
LINES_IN_SLOT = np.asarray(
    [
        [1, 1, 1, 1],
        [2, 0, 1, 1],
        [1, 1, 2, 0],
        [2, 0, 2, 0],
        [4, 0, 0, 0],
    ],
    dtype=np.int32,
)

# Lanes resident in (state, slot): bitmask over lanes
LANES_IN_SLOT = np.asarray(
    [
        [0b0001, 0b0010, 0b0100, 0b1000],
        [0b0011, 0, 0b0100, 0b1000],
        [0b0001, 0b0010, 0b1100, 0],
        [0b0011, 0, 0b1100, 0],
        [0b1111, 0, 0, 0],
    ],
    dtype=np.int32,
)

# candidate probe order per lane: own/leader slots from "least compressed"
# to "most compressed". The controller probes from its *predicted* slot and
# then walks the remaining candidates.
CANDIDATES = ((0,), (1, 0), (2, 0), (3, 2, 0))

# Per-lane compressibility level observed from a state (0=uncomp, 1=2:1, 2=4:1)
LANE_LEVEL = np.asarray(
    [
        [0, 0, 0, 0],
        [1, 1, 0, 0],
        [0, 0, 1, 1],
        [1, 1, 1, 1],
        [2, 2, 2, 2],
    ],
    dtype=np.int32,
)

# Slot predicted for (lane, predicted_level): level 2 -> slot 0; level 1 ->
# pair-leader slot; level 0 -> own slot.
PRED_SLOT = np.asarray(
    [
        [0, 0, 0],
        [1, 0, 0],
        [2, 2, 0],
        [3, 2, 0],
    ],
    dtype=np.int32,
)


def choose_state(sizes, valid_mask: int = 0b1111, budget: int = PAYLOAD_BUDGET):
    """Best layout state for a group given per-line compressed sizes.

    sizes: 4 compressed sizes in bytes (including per-line headers).
    valid_mask: which lanes' data the controller actually holds (only lanes
      co-resident in the LLC may be packed together — ganged eviction).
    """
    s = [int(x) for x in sizes]
    have = lambda m: (valid_mask & m) == m
    quad = have(0b1111) and sum(s) <= budget
    ab = have(0b0011) and s[0] + s[1] <= budget
    cd = have(0b1100) and s[2] + s[3] <= budget
    if quad:
        return S_QUAD
    if ab and cd:
        return S_AB_CD
    if ab:
        return S_AB
    if cd:
        return S_CD
    return S_U


def fits_to_state(pair_ab: bool, pair_cd: bool, quad: bool) -> int:
    if quad:
        return S_QUAD
    if pair_ab and pair_cd:
        return S_AB_CD
    if pair_ab:
        return S_AB
    if pair_cd:
        return S_CD
    return S_U


def slot_of(state: int, lane: int) -> int:
    return int(LOC[state][lane])


def probe_chain(lane: int, predicted_slot: int) -> list[int]:
    """Probe order: predicted slot first, then remaining candidates."""
    cands = list(CANDIDATES[lane])
    if predicted_slot in cands:
        cands.remove(predicted_slot)
    return [predicted_slot] + cands
