"""Moved: repro.compression.layouts is the implementation (the Fig. 6
GROUP4 mapping as an instance of the marker-framed Layout protocol)."""

from ..compression.framing import (  # noqa: F401
    MARKER_BYTES,
    PAYLOAD_BUDGET,
    SLOT_BUDGET,
)
from ..compression.layouts import (  # noqa: F401
    CANDIDATES,
    GROUP4,
    GROUP_LINES,
    LANE_LEVEL,
    LANES_IN_SLOT,
    LINES_IN_SLOT,
    LOC,
    N_STATES,
    OCCUPIED,
    PRED_SLOT,
    S_AB,
    S_AB_CD,
    S_CD,
    S_QUAD,
    S_U,
    STATE_NAMES,
    VACATED,
    choose_state,
    fits_to_state,
    probe_chain,
    slot_of,
)
