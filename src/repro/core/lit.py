"""Line Inversion Table (LIT) — §V-A.

Tracks the (rare) lines stored inverted because their raw bytes collide with
a marker value.  16 entries of {valid, 30-bit line address} = 64B on-chip.

Overflow handling (paper's two options):
  * Option-1: a memory-mapped inversion bitmap (1 bit per line in memory);
    while in use, resolving a suspected inversion costs one extra memory
    access (worst case 2x bandwidth under adversarial data).
  * Option-2: regenerate marker keys and re-encode memory (callback).
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class LIT:
    capacity: int = 16
    overflow_policy: str = "memory_mapped"  # or "regenerate"
    entries: set = field(default_factory=set)
    # memory-mapped overflow bitmap (line_addr -> inverted?)
    overflow_map: set = field(default_factory=set)
    overflowed: bool = False
    overflow_events: int = 0
    extra_accesses: int = 0  # bandwidth cost of memory-mapped lookups

    def would_overflow(self, line_addr: int) -> bool:
        if line_addr in self.entries or line_addr in self.overflow_map:
            return False
        return len(self.entries) >= self.capacity or self.overflowed

    def insert(self, line_addr: int, regenerate_cb=None) -> None:
        if line_addr in self.entries or line_addr in self.overflow_map:
            return
        if len(self.entries) < self.capacity and not self.overflowed:
            self.entries.add(line_addr)
            return
        # Paper Option-1: spill to the memory-mapped bitmap.  (Option-2,
        # marker regeneration, is orchestrated by the controller *before*
        # the colliding write lands — see CRAMSystem._write_uncompressed_slot.)
        self.overflow_events += 1
        self.overflowed = True
        self.overflow_map.add(line_addr)
        self.extra_accesses += 1  # write of the bitmap line

    def remove(self, line_addr: int) -> None:
        self.entries.discard(line_addr)
        if line_addr in self.overflow_map:
            self.overflow_map.discard(line_addr)
            self.extra_accesses += 1

    def contains(self, line_addr: int) -> bool:
        if line_addr in self.entries:
            return True
        if self.overflowed:
            # suspected-inversion check hits the in-memory bitmap
            self.extra_accesses += 1
            return line_addr in self.overflow_map
        return False

    @property
    def storage_bytes(self) -> int:
        # valid bit + 30-bit address per entry, rounded to the paper's 64B
        return self.capacity * 4


def years_to_overflow(write_rate_per_s: float = 1e9, capacity: int = 16,
                      marker_bits: int = 32) -> float:
    """Back-of-envelope reproduction of the paper's '10 million years' claim:
    expected concurrent inversions ~ Binomial(N_lines, 2^-31); the time for
    >capacity lines to *concurrently* collide under continuous writes is
    astronomically long.  We reproduce the order of magnitude by computing the
    expected wait for `capacity+1` collisions within one memory's worth of
    lines, assuming one collision outstanding per 2^31 writes.
    """
    p = 2.0 * 2.0 ** (-marker_bits)
    writes_per_collision = 1.0 / p
    # need capacity+1 simultaneous: geometric compounding (coarse bound)
    writes_needed = writes_per_collision ** 1  # per-collision arrival
    seconds = writes_needed / write_rate_per_s
    # probability all 16 others concurrently present ~ (N*p)^16 -> dominates
    return seconds * (1.0 / max((16e9 / 64 * p), 1e-30)) ** capacity / 3.15e7
