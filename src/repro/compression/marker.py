"""Marker generation and implicit-metadata line interpretation (§V-A).

Compressed lines carry a 4-byte *marker* in their last four bytes: one marker
value class for 2-to-1 packed lines and one for 4-to-1.  Vacated slots are
overwritten with a full-line *invalid-line marker* (Marker-IL).  All marker
values are per-line (keyed by the physical slot address) so an adversary
cannot force collisions: the paper uses DES, we use keyed blake2b on the host
path and an affine hash on device paths — the protocol (regenerate keys on
LIT overflow) is what matters, not the particular PRF.

An uncompressed line that coincidentally ends with a marker is stored
*inverted* and its address recorded in the LIT.  The interpretation rules
implemented by `classify_line` are exactly the paper's:

  last4 == marker2      -> line holds 2 compressed lines
  last4 == marker4      -> line holds 4 compressed lines
  whole line == IL      -> slot is invalid (stale), line lives elsewhere
  last4 == ~marker2/4 or whole == ~IL
                        -> uncompressed, *possibly* inverted: consult LIT
  otherwise             -> uncompressed, as-is
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from enum import IntEnum

import numpy as np

from .framing import LINE_BYTES, MARKER_BYTES


class LineStatus(IntEnum):
    UNCOMP = 0          # plain uncompressed data
    COMP2 = 1           # two compressed lines
    COMP4 = 2           # four compressed lines
    INVALID = 3         # Marker-IL: slot vacated by relocation
    MAYBE_INVERTED = 4  # uncompressed; matches complement of a marker -> LIT


@dataclass
class MarkerSpec:
    """Per-machine marker key material (regenerated on LIT overflow)."""

    key: bytes = b"cram-default-key"
    generation: int = 0
    _cache: dict = field(default_factory=dict, repr=False)

    def _hash(self, domain: bytes, slot_addr: int, nbytes: int) -> bytes:
        ck = (domain, slot_addr)
        got = self._cache.get(ck)
        if got is None:
            h = hashlib.blake2b(
                domain + slot_addr.to_bytes(8, "little"),
                key=self.key + self.generation.to_bytes(4, "little"),
                digest_size=nbytes,
            )
            got = h.digest()
            self._cache[ck] = got
        return got

    def marker2(self, slot_addr: int) -> bytes:
        return self._hash(b"m2", slot_addr, MARKER_BYTES)

    def marker4(self, slot_addr: int) -> bytes:
        return self._hash(b"m4", slot_addr, MARKER_BYTES)

    def marker_il(self, slot_addr: int) -> bytes:
        return self._hash(b"il", slot_addr, LINE_BYTES)

    def regenerate(self) -> None:
        """New marker generation (paper: on LIT overflow, re-encode memory)."""
        self.generation += 1
        self._cache.clear()


def _inv(b: bytes) -> bytes:
    return bytes(255 - x for x in b)


def classify_line(line: np.ndarray, slot_addr: int, spec: MarkerSpec) -> LineStatus:
    """Interpret a 64-byte line fetched from `slot_addr` (implicit metadata)."""
    lb = bytes(np.asarray(line, dtype=np.uint8).tobytes())
    tail = lb[-MARKER_BYTES:]
    m2, m4 = spec.marker2(slot_addr), spec.marker4(slot_addr)
    if tail == m2:
        return LineStatus.COMP2
    if tail == m4:
        return LineStatus.COMP4
    il = spec.marker_il(slot_addr)
    if lb == il:
        return LineStatus.INVALID
    if tail == _inv(m2) or tail == _inv(m4) or lb == _inv(il):
        return LineStatus.MAYBE_INVERTED
    return LineStatus.UNCOMP


def needs_inversion(line: np.ndarray, slot_addr: int, spec: MarkerSpec) -> bool:
    """Would storing this uncompressed line collide with a marker?"""
    lb = bytes(np.asarray(line, dtype=np.uint8).tobytes())
    tail = lb[-MARKER_BYTES:]
    return (
        tail == spec.marker2(slot_addr)
        or tail == spec.marker4(slot_addr)
        or lb == spec.marker_il(slot_addr)
    )


def invert_line(line: np.ndarray) -> np.ndarray:
    return (255 - np.asarray(line, dtype=np.uint8)).astype(np.uint8)


def collision_probability(bits: int = 32) -> float:
    """P(random uncompressed line matches a marker); < 1e-9 per the paper
    (two 32-bit markers -> 2 * 2^-32 ~ 4.7e-10)."""
    return 2.0 * 2.0 ** (-bits)
