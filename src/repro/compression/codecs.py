"""Codec registry: every compression algorithm in the repo, as data.

A `Codec` record names, per algorithm, its bit-true numpy pack/unpack, its
vectorized xp-generic size function, and (optionally) its Pallas device
backend — so `kernels/compress_scan.py` and `kernels/bdi_pack.py` are
registered backends of the same codecs the simulator, KV cache, checkpoint
codec, and benchmarks consume, not parallel truths.

Two codec units exist:
  * "line64" — operates on 64-byte memory lines (raw / bdi / fpc / hybrid);
    `size_fn(lines_bytes, xp)` returns per-line compressed sizes in bytes
    (including the codec's self-describing header, where it has one), and
    `pack_line`/`unpack_line` are the exact host-side byte paths.
  * "page"  — operates on groups of KV pages ((page, Hkv, D2) int16 tiles);
    `pack_pages`/`unpack_pages` are the xp-generic bit-true group codecs
    (compression.pagepack) and the Pallas backend packs a group per kernel
    launch (kernels/bdi_pack).

Pallas backends are stored as dotted paths and resolved lazily, so importing
the registry never pulls in jax.experimental.pallas.
"""

from __future__ import annotations

import importlib
from dataclasses import dataclass
from typing import Callable

import numpy as np

from . import bdi as _bdi
from . import fpc as _fpc
from . import hybrid as _hybrid
from . import pagepack as _pagepack
from .framing import LINE_BYTES


def _resolve(dotted: str) -> Callable:
    mod, _, attr = dotted.rpartition(":")
    return getattr(importlib.import_module(mod), attr)


@dataclass(frozen=True)
class Codec:
    """One registered compression algorithm (see module docstring)."""

    name: str
    unit: str                                  # "line64" | "page"
    description: str = ""
    # line64 contract
    size_fn: Callable | None = None            # (lines_bytes, xp) -> sizes
    pack_line: Callable | None = None          # (line64,) -> bytes
    unpack_line: Callable | None = None        # (data, ofs) -> (line, next)
    # vectorized exact pack: (N,64) uint8 -> 1-D uint8 concatenated stream,
    # byte-identical to b"".join(pack_line(l) for l in lines) — the batch
    # path checkpoint streaming uses (no per-line Python loop)
    pack_batch: Callable | None = None
    # page contract
    group_lanes: int = 0                       # pages packed per slot
    pack_pages: Callable | None = None         # (*pages, xp) -> (ok, packed, base)
    unpack_pages: Callable | None = None       # (packed, base, xp) -> pages
    # lazy Pallas device backends (dotted "module:attr" paths): page codecs
    # register a (pack, unpack) kernel pair; line codecs register the
    # one-pass size/marker scan kernel plus the output column carrying
    # this codec's sizes.
    pallas_pack: str | None = None
    pallas_unpack: str | None = None
    pallas_scan: str | None = None
    scan_field: str | None = None              # compress_scan output column

    def sizes(self, lines_bytes, xp=np):
        if self.size_fn is None:
            raise ValueError(f"codec {self.name!r} has no size function")
        return self.size_fn(lines_bytes, xp=xp)

    def pallas(self) -> tuple[Callable, Callable] | None:
        """Resolve the (pack, unpack) Pallas kernel pair, if registered."""
        if self.pallas_pack is None:
            return None
        return _resolve(self.pallas_pack), _resolve(self.pallas_unpack)

    def scan(self) -> Callable | None:
        """Resolve the Pallas size-scan backend, if registered."""
        return None if self.pallas_scan is None else _resolve(self.pallas_scan)

    def has_pallas(self) -> bool:
        return self.pallas_pack is not None or self.pallas_scan is not None


_REGISTRY: dict[str, Codec] = {}


def register_codec(codec: Codec, *, overwrite: bool = False) -> Codec:
    if codec.name in _REGISTRY and not overwrite:
        raise ValueError(f"codec {codec.name!r} is already registered")
    _REGISTRY[codec.name] = codec
    return codec


def get_codec(name: str) -> Codec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown codec {name!r}; valid: {sorted(_REGISTRY)}") from None


def codec_names(unit: str | None = None) -> tuple[str, ...]:
    return tuple(n for n, c in _REGISTRY.items()
                 if unit is None or c.unit == unit)


# ------------------------------------------------------------- line64 codecs

def _raw_sizes(lines_bytes, xp=np):
    return xp.full(lines_bytes.shape[:-1], LINE_BYTES, dtype=xp.int32)


def _raw_pack(line) -> bytes:
    return np.asarray(line, dtype=np.uint8).tobytes()


def _raw_unpack(data: bytes, offset: int = 0):
    out = np.frombuffer(data[offset:offset + LINE_BYTES], dtype=np.uint8)
    return out.copy(), offset + LINE_BYTES


def _bdi_sizes(lines_bytes, xp=np):
    sizes, _ = _bdi.bdi_sizes(lines_bytes, xp=xp)
    return sizes + 1          # 1-byte self-describing mode header


def _bdi_pack(line) -> bytes:
    arr = np.asarray(line, dtype=np.uint8).reshape(1, LINE_BYTES)
    _, modes = _bdi.bdi_sizes(arr)
    mode = int(modes[0])
    return bytes([mode]) + _bdi.bdi_pack_batch(arr, mode)[0].tobytes()


def _bdi_unpack(data: bytes, offset: int = 0):
    mode = data[offset]
    n = _bdi.PAYLOAD_BYTES[mode]
    payload = np.frombuffer(data[offset + 1: offset + 1 + n], dtype=np.uint8)
    return _bdi.bdi_unpack_batch(payload.reshape(1, n), mode)[0], offset + 1 + n


def _fpc_unpack(data: bytes, offset: int = 0):
    line = _fpc.fpc_unpack(data[offset: offset + _fpc.MAX_LINE_BYTES])
    nbytes = int(_fpc.fpc_size_bytes(line.reshape(1, LINE_BYTES))[0])
    return line, offset + nbytes


def _raw_pack_batch(lines: np.ndarray) -> np.ndarray:
    return np.ascontiguousarray(lines, dtype=np.uint8).reshape(-1)


def _bdi_pack_batch(lines: np.ndarray) -> np.ndarray:
    """Vectorized BDI stream: per line, 1 mode byte + payload (identical to
    per-line `_bdi_pack` joins; payloads scatter by mode group)."""
    lines = np.ascontiguousarray(lines, dtype=np.uint8).reshape(
        -1, LINE_BYTES)
    sizes, modes = _bdi.bdi_sizes(lines)
    modes_np = np.asarray(modes)
    size_table = np.asarray([_bdi.PAYLOAD_BYTES[m] for m in range(9)],
                            np.int64)
    per_line = 1 + size_table[modes_np]
    offsets = np.cumsum(per_line) - per_line
    buf = np.zeros(int(per_line.sum()), np.uint8)
    buf[offsets] = modes_np.astype(np.uint8)
    for m in np.unique(modes_np):
        idxs = np.flatnonzero(modes_np == m)
        payload = _bdi.bdi_pack_batch(lines[idxs], int(m))
        if payload.shape[1]:
            buf[offsets[idxs][:, None] + 1 + np.arange(payload.shape[1])] \
                = payload
    return buf


register_codec(Codec(
    name="raw", unit="line64",
    description="identity (uncompressed 64B line)",
    size_fn=_raw_sizes, pack_line=_raw_pack, unpack_line=_raw_unpack,
    pack_batch=_raw_pack_batch,
))

register_codec(Codec(
    name="bdi", unit="line64",
    description="Base-Delta-Immediate [PACT 2012]; 1-byte mode header",
    size_fn=_bdi_sizes, pack_line=_bdi_pack, unpack_line=_bdi_unpack,
    pack_batch=_bdi_pack_batch,
    pallas_scan="repro.kernels.compress_scan:compress_scan",
    scan_field="bdi",
))

register_codec(Codec(
    name="fpc", unit="line64",
    description="Frequent Pattern Compression [ISCA 2004]; self-terminating",
    size_fn=lambda lines, xp=np: _fpc.fpc_size_bytes(lines, xp=xp),
    pack_line=_fpc.fpc_pack, unpack_line=_fpc_unpack,
    pack_batch=_fpc.fpc_pack_batch,
    pallas_scan="repro.kernels.compress_scan:compress_scan",
    scan_field="fpc",
))

register_codec(Codec(
    name="hybrid", unit="line64",
    description="best-of FPC+BDI with a 1-byte algorithm header (§III-A) — "
                "the paper's line codec",
    size_fn=lambda lines, xp=np: _hybrid.compressed_sizes(lines, xp=xp),
    pack_line=_hybrid.compress_line, unpack_line=_hybrid.decompress_line,
    pack_batch=_hybrid.compress_batch,
    pallas_scan="repro.kernels.compress_scan:compress_scan",
    scan_field="sizes",
))


# -------------------------------------------------------------- page codecs

register_codec(Codec(
    name="int8-delta", unit="page", group_lanes=2,
    description="KV 2:1 page pairs: int8 deltas vs the pair base row",
    pack_pages=_pagepack.pack_pair, unpack_pages=_pagepack.unpack_pair,
    pallas_pack="repro.kernels.bdi_pack:pack_pair",
    pallas_unpack="repro.kernels.bdi_pack:unpack_pair",
))

register_codec(Codec(
    name="int4-delta", unit="page", group_lanes=4,
    description="KV 4:1 page quads: int4 deltas vs the quad base row",
    pack_pages=_pagepack.pack_quad, unpack_pages=_pagepack.unpack_quad,
    pallas_pack="repro.kernels.bdi_pack:pack_quad",
    pallas_unpack="repro.kernels.bdi_pack:unpack_quad",
))
