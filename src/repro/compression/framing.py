"""Marker framing: THE constants of the in-band-metadata discipline.

Every consumer of the paper's implicit-metadata format — the bit-true
functional model, the trace engine, the Pallas scan/pack kernels, the
serving KV cache, the checkpoint codec — frames compressed data the same
way: a 64-byte slot whose last 4 bytes are a keyed per-slot marker, leaving
60 bytes of payload; compressed sub-lines carry a 1-byte algorithm header.
These numbers are defined once, here.

Two marker families exist (same protocol, different PRF strength):
  * the host family (marker.MarkerSpec, keyed blake2b) used by the exact
    functional memory model;
  * the device family (slot_markers below + compress_scan's in-kernel
    multiply-add variant), an affine keyed hash that wraps identically in
    int32 (TPU) and uint32 (host), used by every kernel path.
A `domain` salt separates marker classes (2:1 pair vs 4:1 quad) so a slot's
pair marker can never alias its quad marker.
"""

from __future__ import annotations

import numpy as np

LINE_BYTES = 64                 # the paper's cache-line / DMA granule
SLOT_BUDGET = 64                # one physical slot = one line
MARKER_BYTES = 4                # in-band marker at the slot tail
MARKER_LANES = 2                # the same 4 bytes as 2 int16 lanes (KV strips)
PAYLOAD_BUDGET = SLOT_BUDGET - MARKER_BYTES   # 60B usable when packed
HEADER_BYTES = 1                # per-sub-line algorithm header (counted)

# marker-class domains for the device family (salt the key, not the index,
# so domain 0 stays bit-identical to the historical pair markers)
DOMAIN_PAIR = 0
DOMAIN_QUAD = 1
_DOMAIN_SALT = 0x9E3779B9

# THE default marker key.  Every keyed entry point (build_cram_cache,
# CRAMKVCache, SlotKVCache, ServeLoop, the scan kernels) defaults to this
# value; analysis rule R1 forbids the literal anywhere else.
DEFAULT_MARKER_KEY = 0x5EED

# The golden-ratio odd multiplier (Fibonacci hashing) shared by the trace
# engine's address hash, the predictor's set hash and the gate's sampling
# hash — and, under the names below, the multiply-add device marker family
# that compress_scan evaluates in-kernel.  One definition; R1 keeps it so.
FIB_MULT = 0x9E3779B1                   # the odd 32-bit golden constant
M2_MULT = FIB_MULT                      # 2:1 pair-marker multiplier
M4_MULT = 0x85EBCA6B                    # 4:1 quad-marker multiplier
IL_MULT = 0x27D4EB2F                    # interleave/mix multiplier


def slot_markers(n_slots: int, key: int = DEFAULT_MARKER_KEY,
                 domain: int = DOMAIN_PAIR) -> np.ndarray:
    """Per-slot 32-bit device markers (keyed affine hash; regenerable)."""
    idx = np.arange(n_slots, dtype=np.uint64)
    k = np.uint64((key + domain * _DOMAIN_SALT) & 0xFFFFFFFFFFFFFFFF)
    h = (idx * np.uint64(0x9E3779B97F4A7C15) + k) >> np.uint64(13)
    return (h & np.uint64(0xFFFFFFFF)).astype(np.uint32)


def marker_to_lanes(m: np.ndarray) -> np.ndarray:
    """uint32 marker -> two int16 lanes (little-endian halves)."""
    lo = (m & 0xFFFF).astype(np.uint16).view(np.int16)
    hi = ((m >> 16) & 0xFFFF).astype(np.uint16).view(np.int16)
    return np.stack([lo, hi], axis=-1)


def lanes_to_marker_i32(tail, xp):
    """Two int16 tail lanes -> the int32 marker bit pattern (xp-generic)."""
    t = tail.astype(xp.int32)
    return (t[..., 0] & 0xFFFF) | ((t[..., 1] & 0xFFFF) << 16)
