"""Hybrid FPC+BDI line codec and group packing (§III-A, §V-A).

Each compressed sub-line is encoded as:
    [1-byte header][payload]
      header: high nibble = algorithm (0=BDI, 1=FPC, 2=RAW)
              low nibble  = BDI mode id (BDI only)
The header byte is counted toward the compressed size, as the paper requires
("information about the compression algorithm used ... are stored within the
compressed line, and are counted towards determining the size").

A packed group slot is:
    [sub-line 0][sub-line 1](...)[zero pad][4-byte marker]
with total payload <= 60 bytes (PAYLOAD_BUDGET).  Sub-lines decode strictly
in sequence; FPC is self-terminating at 16 words, BDI/RAW have fixed sizes.
"""

from __future__ import annotations

import numpy as np

from . import bdi as _bdi
from . import fpc as _fpc
from .framing import HEADER_BYTES, LINE_BYTES, PAYLOAD_BUDGET

ALG_BDI, ALG_FPC, ALG_RAW = 0, 1, 2


def compressed_sizes(lines_bytes, xp=np):
    """Hybrid FPC+BDI compressed size per line, header included.

    lines_bytes: (N, 64) uint8 -> (N,) int32 sizes in [1+0, 1+64].
    """
    fpc_sz = _fpc.fpc_size_bytes(lines_bytes, xp=xp)
    bdi_sz, _ = _bdi.bdi_sizes(lines_bytes, xp=xp)
    best = xp.minimum(xp.minimum(fpc_sz, bdi_sz), LINE_BYTES)
    return (best + HEADER_BYTES).astype(xp.int32)


def compress_line(line: np.ndarray) -> bytes:
    """Exact hybrid encoding of one 64-byte line (header + payload)."""
    line = np.asarray(line, dtype=np.uint8).reshape(1, LINE_BYTES)
    bdi_sz, bdi_mode = _bdi.bdi_sizes(line)
    bdi_sz, bdi_mode = int(bdi_sz[0]), int(bdi_mode[0])
    fpc_payload = _fpc.fpc_pack(line[0])
    fpc_sz = len(fpc_payload)
    best = min(bdi_sz, fpc_sz, LINE_BYTES)
    if best == bdi_sz and bdi_sz <= fpc_sz:
        hdr = (ALG_BDI << 4) | bdi_mode
        payload = _bdi.bdi_pack_batch(line, bdi_mode)[0].tobytes()
    elif best == fpc_sz:
        hdr = ALG_FPC << 4
        payload = fpc_payload
    else:
        hdr = ALG_RAW << 4
        payload = line[0].tobytes()
    return bytes([hdr]) + payload


def compress_batch(lines_bytes: np.ndarray) -> np.ndarray:
    """Vectorized exact hybrid encoding of (N, 64) lines.

    Byte-identical to ``b"".join(compress_line(l) for l in lines)`` with no
    per-line Python loop: the algorithm choice is vectorized, BDI payloads
    scatter per mode group (as in the checkpoint BDI stream), FPC payloads
    come from `fpc.fpc_pack_batch`.  Returns the 1-D uint8 stream.
    """
    lines = np.ascontiguousarray(lines_bytes, dtype=np.uint8).reshape(
        -1, LINE_BYTES)
    n = lines.shape[0]
    if n == 0:
        return np.zeros(0, np.uint8)
    fpc_sz = _fpc.fpc_size_bytes(lines).astype(np.int64)
    bdi_sz, bdi_mode = _bdi.bdi_sizes(lines)
    bdi_sz = bdi_sz.astype(np.int64)
    best = np.minimum(np.minimum(bdi_sz, fpc_sz), LINE_BYTES)
    # same precedence as compress_line: BDI on ties (incl. its RAW mode)
    take_bdi = (best == bdi_sz) & (bdi_sz <= fpc_sz)
    take_fpc = ~take_bdi & (best == fpc_sz)
    alg = np.where(take_bdi, ALG_BDI, np.where(take_fpc, ALG_FPC, ALG_RAW))
    payload_sz = np.where(take_bdi, bdi_sz,
                          np.where(take_fpc, fpc_sz, LINE_BYTES))
    stored = HEADER_BYTES + payload_sz
    off = np.cumsum(stored) - stored
    buf = np.zeros(int(off[-1] + stored[-1]), np.uint8)
    buf[off] = (alg << 4 | np.where(take_bdi, bdi_mode, 0)).astype(np.uint8)
    for m in np.unique(bdi_mode[take_bdi]):
        idxs = np.flatnonzero(take_bdi & (bdi_mode == m))
        payload = _bdi.bdi_pack_batch(lines[idxs], int(m))
        if payload.shape[1]:
            buf[off[idxs][:, None] + 1 + np.arange(payload.shape[1])] = \
                payload
    fidx = np.flatnonzero(take_fpc)
    if fidx.size:
        stream = _fpc.fpc_pack_batch(lines[fidx])
        sizes = fpc_sz[fidx]
        sub_off = np.cumsum(sizes) - sizes
        intra = np.arange(int(sizes.sum())) - np.repeat(sub_off, sizes)
        buf[np.repeat(off[fidx] + 1, sizes) + intra] = stream
    ridx = np.flatnonzero(alg == ALG_RAW)
    if ridx.size:
        buf[off[ridx][:, None] + 1 + np.arange(LINE_BYTES)] = lines[ridx]
    return buf


def decompress_line(data: bytes, offset: int = 0) -> tuple[np.ndarray, int]:
    """Decode one sub-line starting at `offset`; returns (line64, next_offset)."""
    hdr = data[offset]
    alg, mode = hdr >> 4, hdr & 0xF
    offset += 1
    if alg == ALG_RAW:
        out = np.frombuffer(data[offset : offset + LINE_BYTES], dtype=np.uint8)
        return out.copy(), offset + LINE_BYTES
    if alg == ALG_BDI:
        n = _bdi.PAYLOAD_BYTES[mode]
        payload = np.frombuffer(data[offset : offset + n], dtype=np.uint8)
        out = _bdi.bdi_unpack_batch(payload.reshape(1, n), mode)[0]
        return out, offset + n
    if alg == ALG_FPC:
        # FPC is self-terminating: decode 16 words, then advance by the
        # number of whole bytes consumed.  The slice is bounded by the
        # worst-case FPC line (16 x 35 bits = 70 B) so streaming decoders
        # stay O(total bytes) instead of copying the whole tail per line.
        line = _fpc.fpc_unpack(data[offset : offset + _fpc.MAX_LINE_BYTES])
        # recompute consumed bits via the size function (exact)
        nbytes = int(_fpc.fpc_size_bytes(line.reshape(1, LINE_BYTES))[0])
        return line, offset + nbytes
    raise ValueError(f"bad header {hdr:#x}")


def pack_group(lines: list[np.ndarray], marker: bytes) -> np.ndarray | None:
    """Pack 2 or 4 lines + marker into one 64B slot, or None if they don't fit."""
    assert len(lines) in (2, 4)
    blob = b"".join(compress_line(l) for l in lines)
    if len(blob) > PAYLOAD_BUDGET:
        return None
    slot = np.zeros(LINE_BYTES, dtype=np.uint8)
    slot[: len(blob)] = np.frombuffer(blob, dtype=np.uint8)
    slot[-len(marker):] = np.frombuffer(marker, dtype=np.uint8)
    return slot


def unpack_group(slot: np.ndarray, n_lines: int) -> list[np.ndarray]:
    """Decode `n_lines` sub-lines from a packed slot."""
    data = bytes(np.asarray(slot, dtype=np.uint8).tobytes())
    out, ofs = [], 0
    for _ in range(n_lines):
        line, ofs = decompress_line(data, ofs)
        out.append(line)
    if ofs > PAYLOAD_BUDGET:
        raise ValueError("packed group overruns the 60-byte payload budget")
    return out


def group_fits(sizes, lanes=(0, 1), budget: int = PAYLOAD_BUDGET) -> bool:
    return int(sum(int(sizes[l]) for l in lanes)) <= budget
