"""Frequent Pattern Compression (FPC) [Alameldeen & Wood 2004].

Per 32-bit word, a 3-bit prefix selects one of 8 patterns; zero words are
run-length encoded (up to 8 per run).  This is the per-line codec CRAM uses
(hybridized with BDI in compress.py), matching §III-A of the paper.

Two implementations are provided:
  * fpc_size_bits(...)  — vectorized size computation, works with numpy OR
    jax.numpy (pass the module as `xp`), used in simulator/benchmark hot paths.
  * fpc_pack / fpc_unpack — exact bit-level round-trip (host-side numpy),
    used by tests and by the checkpoint codec.

Pattern table (prefix: pattern -> payload bits):
  000 zero run (3-bit run length, 1..8 zeros)    -> 3
  001 4-bit sign-extended word                   -> 4
  010 8-bit sign-extended word                   -> 8
  011 16-bit sign-extended word                  -> 16
  100 halfword padded with a zero halfword       -> 16 (low half zero)
  101 two halfwords, each an 8-bit SE halfword   -> 16
  110 word of 4 repeated bytes                   -> 8
  111 uncompressed word                          -> 32
"""

from __future__ import annotations

import numpy as np

from .bits import BitReader, BitWriter, bytes_to_u32, u32_to_bytes

WORDS_PER_LINE = 16
PREFIX_BITS = 3
# worst case: every word raw (3 + 32 bits) -> ceil(16 * 35 / 8) bytes.
# Streaming decoders may slice their input to this bound per line.
MAX_LINE_BYTES = (WORDS_PER_LINE * (PREFIX_BITS + 32) + 7) // 8

P_ZRUN, P_SE4, P_SE8, P_SE16, P_PAD16, P_HALF_SE8, P_REPB, P_RAW = range(8)

_PAYLOAD_BITS = {
    P_ZRUN: 3,
    P_SE4: 4,
    P_SE8: 8,
    P_SE16: 16,
    P_PAD16: 16,
    P_HALF_SE8: 16,
    P_REPB: 8,
    P_RAW: 32,
}


def _classify_nonzero(w_i32, xp):
    """Pattern id for each (nonzero) word; vectorized. w_i32: int32 array."""
    w = w_i32.astype(xp.int64)
    se4 = (w >= -8) & (w < 8)
    se8 = (w >= -128) & (w < 128)
    se16 = (w >= -32768) & (w < 32768)
    u = w_i32.astype(xp.int64) & 0xFFFFFFFF
    pad16 = (u & 0xFFFF) == 0
    lo = ((u & 0xFFFF) ^ 0x8000) - 0x8000  # sign-extend low half
    hi = (((u >> 16) & 0xFFFF) ^ 0x8000) - 0x8000
    half_se8 = (lo >= -128) & (lo < 128) & (hi >= -128) & (hi < 128)
    b0 = u & 0xFF
    repb = (b0 == ((u >> 8) & 0xFF)) & (b0 == ((u >> 16) & 0xFF)) & (
        b0 == ((u >> 24) & 0xFF)
    )
    # priority: smallest encoding wins; repb (8) before se8 is irrelevant for
    # size but we fix an order so pack/size agree: se4 < se8 < repb < se16 <
    # pad16 < half_se8 < raw.
    pat = xp.full(w.shape, P_RAW, dtype=xp.int32)
    pat = xp.where(half_se8, P_HALF_SE8, pat)
    pat = xp.where(pad16, P_PAD16, pat)
    pat = xp.where(se16, P_SE16, pat)
    pat = xp.where(repb, P_REPB, pat)
    pat = xp.where(se8, P_SE8, pat)
    pat = xp.where(se4, P_SE4, pat)
    return pat


_NONZERO_BITS_BY_PAT = None


def _payload_bits_table(xp):
    return xp.asarray(
        [ _PAYLOAD_BITS[p] for p in range(8) ], dtype=xp.int32
    )


def fpc_size_bits(lines_u32, xp=np):
    """Compressed size in BITS for each line.

    lines_u32: (..., 16) uint32/int32 array of words.
    Returns (...,) int32 sizes (payload + prefixes, zero-run encoded).
    """
    w = lines_u32.astype(xp.int64)
    w_i32 = ((w & 0xFFFFFFFF) ^ 0x80000000) - 0x80000000  # as signed int32
    zero = w_i32 == 0
    pat = _classify_nonzero(w_i32, xp)
    tbl = _payload_bits_table(xp)
    nz_bits = xp.where(zero, 0, PREFIX_BITS + tbl[pat])
    total_nz = nz_bits.sum(axis=-1)

    # zero runs: each run of length L contributes ceil(L/8)*(3+3) bits.
    prev = xp.concatenate(
        [xp.zeros(zero.shape[:-1] + (1,), dtype=bool), zero[..., :-1]], axis=-1
    )
    starts = zero & ~prev
    run_id = xp.cumsum(starts.astype(xp.int32), axis=-1)  # 1-based on zeros
    chunks = xp.zeros(zero.shape[:-1], dtype=xp.int32)
    for k in range(1, WORDS_PER_LINE + 1):
        len_k = (zero & (run_id == k)).sum(axis=-1)
        chunks = chunks + (len_k + 7) // 8 * (len_k > 0)
    return (total_nz + chunks * (PREFIX_BITS + 3)).astype(xp.int32)


def fpc_size_bytes(lines_bytes, xp=np):
    """(…,64) uint8 -> (…,) int32 compressed size in bytes (ceil bits/8)."""
    if xp is np:
        words = bytes_to_u32(np.asarray(lines_bytes))
    else:
        b = lines_bytes.astype(xp.uint32)
        words = (
            b[..., 0::4]
            + (b[..., 1::4] << 8)
            + (b[..., 2::4] << 16)
            + (b[..., 3::4] << 24)
        )
    return (fpc_size_bits(words, xp=xp) + 7) // 8


# ---------------------------------------------------------------------------
# Exact pack / unpack (host-side, per line)
# ---------------------------------------------------------------------------

def fpc_pack(line_bytes: np.ndarray | bytes) -> bytes:
    """Exact FPC encoding of one 64-byte line."""
    arr = np.frombuffer(bytes(line_bytes), dtype=np.uint8) if isinstance(
        line_bytes, (bytes, bytearray)
    ) else np.asarray(line_bytes, dtype=np.uint8)
    words = bytes_to_u32(arr).astype(np.int64)
    w_signed = ((words & 0xFFFFFFFF) ^ 0x80000000) - 0x80000000
    pats = np.asarray(_classify_nonzero(w_signed, np))
    bw = BitWriter()
    i = 0
    while i < WORDS_PER_LINE:
        w = int(w_signed[i])
        u = w & 0xFFFFFFFF
        if w == 0:
            run = 0
            while i + run < WORDS_PER_LINE and int(w_signed[i + run]) == 0 and run < 8:
                run += 1
            bw.write(P_ZRUN, PREFIX_BITS)
            bw.write(run - 1, 3)
            i += run
            continue
        pat = int(pats[i])
        bw.write(pat, PREFIX_BITS)
        if pat == P_SE4:
            bw.write_signed(w, 4)
        elif pat == P_SE8:
            bw.write_signed(w, 8)
        elif pat == P_SE16:
            bw.write_signed(w, 16)
        elif pat == P_PAD16:
            bw.write((u >> 16) & 0xFFFF, 16)
        elif pat == P_HALF_SE8:
            lo = u & 0xFFFF
            hi = (u >> 16) & 0xFFFF
            bw.write_signed(((lo ^ 0x8000) - 0x8000), 8)
            bw.write_signed(((hi ^ 0x8000) - 0x8000), 8)
        elif pat == P_REPB:
            bw.write(u & 0xFF, 8)
        else:  # P_RAW
            bw.write(u, 32)
        i += 1
    return bw.getvalue()


def fpc_pack_batch(lines_bytes: np.ndarray) -> np.ndarray:
    """Vectorized exact FPC encoding of (N, 64) lines.

    Returns the 1-D uint8 concatenation of the per-line streams,
    byte-identical to ``b"".join(fpc_pack(line) for line in lines)`` but
    with no per-line Python loop (numpy batch over lines; the only loops
    are over the 16 word positions) — the path that lets multi-GB
    checkpoints use the FPC/hybrid codecs (tests pin the parity).
    """
    lines = np.ascontiguousarray(lines_bytes, dtype=np.uint8).reshape(
        -1, WORDS_PER_LINE * 4)
    n = lines.shape[0]
    if n == 0:
        return np.zeros(0, np.uint8)
    words = bytes_to_u32(lines).astype(np.int64)
    u = words & 0xFFFFFFFF
    w_signed = (u ^ 0x80000000) - 0x80000000
    zero = w_signed == 0
    pats = np.asarray(_classify_nonzero(w_signed, np))

    # zero-run chunking: a token is emitted at every run position that is
    # ≡ 0 (mod 8) within its run, covering min(remaining zeros, 8) words —
    # exactly the scalar packer's greedy 8-cap RLE.
    idx = np.arange(WORDS_PER_LINE)
    prev = np.concatenate([np.zeros((n, 1), bool), zero[:, :-1]], axis=1)
    start = zero & ~prev
    last_start = np.maximum.accumulate(np.where(start, idx, -1), axis=1)
    pos_in_run = idx[None, :] - last_start
    czl = np.zeros((n, WORDS_PER_LINE), np.int32)   # zeros from i rightward
    czl[:, -1] = zero[:, -1]
    for i in range(WORDS_PER_LINE - 2, -1, -1):
        czl[:, i] = np.where(zero[:, i], czl[:, i + 1] + 1, 0)
    chunk_start = zero & (pos_in_run % 8 == 0)
    chunk_len = np.minimum(czl, 8)

    # per-position token (value, nbits), MSB-first prefix+payload combined
    pb = _payload_bits_table(np)[pats].astype(np.int64)
    payload = np.zeros((n, WORDS_PER_LINE), np.int64)
    payload = np.where(pats == P_SE4, u & 0xF, payload)
    payload = np.where(pats == P_SE8, u & 0xFF, payload)
    payload = np.where(pats == P_SE16, u & 0xFFFF, payload)
    payload = np.where(pats == P_PAD16, (u >> 16) & 0xFFFF, payload)
    payload = np.where(pats == P_HALF_SE8,
                       ((u & 0xFF) << 8) | ((u >> 16) & 0xFF), payload)
    payload = np.where(pats == P_REPB, u & 0xFF, payload)
    payload = np.where(pats == P_RAW, u, payload)
    tok = ~zero | chunk_start
    val = np.where(zero, (P_ZRUN << 3) | (chunk_len - 1),
                   (pats.astype(np.int64) << pb) | payload)
    nbits = np.where(zero, PREFIX_BITS + 3, PREFIX_BITS + pb) * tok

    # bit assembly: exclusive per-line offsets, scatter MSB-first bits
    MAXB = PREFIX_BITS + 32                       # widest token (raw word)
    LINE_BITS = WORDS_PER_LINE * MAXB
    off = np.cumsum(nbits, axis=1) - nbits
    total_bits = off[:, -1] + nbits[:, -1]
    j = np.arange(MAXB)
    bits = ((val[:, :, None] >> np.maximum(
        nbits[:, :, None] - 1 - j, 0)) & 1).astype(np.uint8)
    valid = tok[:, :, None] & (j < nbits[:, :, None])
    pos = off[:, :, None] + j
    buf = np.zeros((n, LINE_BITS), np.uint8)
    flat = (np.arange(n)[:, None, None] * LINE_BITS + pos)[valid]
    buf.reshape(-1)[flat] = bits[valid]
    packed = np.packbits(buf, axis=1)             # MSB-first, as BitWriter

    line_nbytes = ((total_bits + 7) // 8).astype(np.int64)
    out_off = np.cumsum(line_nbytes) - line_nbytes
    total = int(out_off[-1] + line_nbytes[-1])
    which = np.repeat(np.arange(n), line_nbytes)
    intra = np.arange(total) - np.repeat(out_off, line_nbytes)
    return packed[which, intra]


def fpc_unpack(data: bytes) -> np.ndarray:
    """Decode FPC bytes back to a (64,) uint8 line."""
    br = BitReader(data)
    words: list[int] = []
    while len(words) < WORDS_PER_LINE:
        pat = br.read(PREFIX_BITS)
        if pat == P_ZRUN:
            run = br.read(3) + 1
            words.extend([0] * run)
        elif pat == P_SE4:
            words.append(br.read_signed(4) & 0xFFFFFFFF)
        elif pat == P_SE8:
            words.append(br.read_signed(8) & 0xFFFFFFFF)
        elif pat == P_SE16:
            words.append(br.read_signed(16) & 0xFFFFFFFF)
        elif pat == P_PAD16:
            words.append((br.read(16) << 16) & 0xFFFFFFFF)
        elif pat == P_HALF_SE8:
            lo = br.read_signed(8) & 0xFFFF
            hi = br.read_signed(8) & 0xFFFF
            words.append(((hi << 16) | lo) & 0xFFFFFFFF)
        elif pat == P_REPB:
            b = br.read(8)
            words.append(b | (b << 8) | (b << 16) | (b << 24))
        else:
            words.append(br.read(32))
    if len(words) != WORDS_PER_LINE:
        raise ValueError("FPC stream decoded to wrong word count")
    return u32_to_bytes(np.asarray(words, dtype="<u4"))
