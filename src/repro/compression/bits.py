"""Bit-stream reader/writer used by the exact (byte-level) codec paths.

These are deliberately simple, host-side (numpy/python) utilities: the exact
pack/unpack paths exist for correctness tests and the checkpoint codec, while
the simulator hot loops use the vectorized *size* functions in fpc.py/bdi.py.
"""

from __future__ import annotations

import numpy as np


class BitWriter:
    """MSB-first bit accumulator producing a byte string."""

    __slots__ = ("_bits",)

    def __init__(self) -> None:
        self._bits: list[int] = []

    def write(self, value: int, nbits: int) -> None:
        if nbits < 0:
            raise ValueError("nbits must be >= 0")
        if value < 0 or (nbits < 64 and value >> nbits):
            raise ValueError(f"value {value} does not fit in {nbits} bits")
        for i in range(nbits - 1, -1, -1):
            self._bits.append((value >> i) & 1)

    def write_signed(self, value: int, nbits: int) -> None:
        """Two's-complement write of a signed integer."""
        self.write(value & ((1 << nbits) - 1), nbits)

    def __len__(self) -> int:  # number of bits written
        return len(self._bits)

    def getvalue(self) -> bytes:
        bits = self._bits
        nbytes = (len(bits) + 7) // 8
        out = bytearray(nbytes)
        for i, b in enumerate(bits):
            if b:
                out[i >> 3] |= 0x80 >> (i & 7)
        return bytes(out)


class BitReader:
    """MSB-first bit reader over a byte string."""

    __slots__ = ("_data", "_pos")

    def __init__(self, data: bytes) -> None:
        self._data = data
        self._pos = 0

    def read(self, nbits: int) -> int:
        value = 0
        pos = self._pos
        data = self._data
        for _ in range(nbits):
            byte = data[pos >> 3]
            value = (value << 1) | ((byte >> (7 - (pos & 7))) & 1)
            pos += 1
        self._pos = pos
        return value

    def read_signed(self, nbits: int) -> int:
        v = self.read(nbits)
        if v & (1 << (nbits - 1)):
            v -= 1 << nbits
        return v

    @property
    def bit_position(self) -> int:
        return self._pos


def sign_extend(value: int, nbits: int) -> int:
    value &= (1 << nbits) - 1
    if value & (1 << (nbits - 1)):
        value -= 1 << nbits
    return value


def bytes_to_u32(line: np.ndarray) -> np.ndarray:
    """(…,64) uint8 -> (…,16) uint32, little-endian (x86 memory image)."""
    line = np.ascontiguousarray(line, dtype=np.uint8)
    return line.view("<u4").reshape(line.shape[:-1] + (16,))


def u32_to_bytes(words: np.ndarray) -> np.ndarray:
    words = np.ascontiguousarray(words, dtype="<u4")
    return words.view(np.uint8).reshape(words.shape[:-1] + (64,))
