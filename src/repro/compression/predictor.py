"""THE line-location predictor (LLP, §V-B), layout-parameterized.

A Last Compressibility Table (LCT) records, per indexed entry, the last
compressibility *level* observed; predicting the level predicts the slot to
probe via the layout's `pred_slot` table, and the layout's candidate-slot
table bounds the probe walk.  Both predictor deployments in this repo are
instances of this one mechanism:

  * the memory-system LLP — 512 entries indexed by a Fibonacci hash of the
    page address (lines of a page compress alike), predicting over
    layouts.GROUP4 (`probe_count_table(GROUP4)` is the engine's PROBE
    table);
  * the CRAM-KV predictor — one entry per page group, indexed directly
    (hash = identity), predicting packedness over layouts.KV_PAIR /
    KV_QUAD; `observe_layout` is its update rule.

128 bytes of state at 2 bits/entry for the 512-entry LCT (we store a byte
per entry for simplicity; Table III accounting uses 2 bits).  Works both as
a host-side object (functional model) and as pure functions on a jnp array
(trace simulator).
"""

from __future__ import annotations

import numpy as np

from .framing import FIB_MULT
from .layouts import Layout

LCT_ENTRIES = 512
LINES_PER_PAGE = 64  # 4KB page / 64B lines

HASH_MULT = FIB_MULT  # Fibonacci hashing (THE golden multiplier, framing.py)
_HASH_MULT = HASH_MULT  # legacy alias


def page_of(line_addr):
    return line_addr // LINES_PER_PAGE


def lct_index(page, n_entries: int = LCT_ENTRIES):
    return ((page * HASH_MULT) & 0xFFFFFFFF) % n_entries


class LLP:
    """Host-side predictor used by the exact functional model."""

    def __init__(self, n_entries: int = LCT_ENTRIES):
        self.n_entries = n_entries
        self.lct = np.zeros(n_entries, dtype=np.int8)
        self.predictions = 0
        self.correct = 0

    def predict_level(self, line_addr: int) -> int:
        return int(self.lct[lct_index(page_of(line_addr), self.n_entries)])

    def update(self, line_addr: int, observed_level: int) -> None:
        self.lct[lct_index(page_of(line_addr), self.n_entries)] = observed_level

    def record_outcome(self, was_correct: bool) -> None:
        self.predictions += 1
        self.correct += int(was_correct)

    @property
    def accuracy(self) -> float:
        return self.correct / self.predictions if self.predictions else 1.0

    @property
    def storage_bytes(self) -> int:
        return self.n_entries * 2 // 8  # 2 bits/entry as in Table III


# -- pure-function variants for lax.scan ------------------------------------

def llp_predict(lct, line_addr, xp):
    idx = lct_index(page_of(line_addr), lct.shape[0])
    return lct[idx]


def llp_update(lct, line_addr, level, xp):
    idx = lct_index(page_of(line_addr), lct.shape[0])
    if xp is np:
        lct = lct.copy()
        lct[idx] = level
        return lct
    return lct.at[idx].set(level)


# -- layout-parameterized probe accounting -----------------------------------

def probe_count_table(layout: Layout) -> np.ndarray:
    """PROBE[state, lane, predicted_level] -> accesses to locate the line.

    Lane 0 never moves (one probe); other lanes walk the layout's probe
    chain starting at the slot `pred_slot[lane, level]` resolves to.  This
    is the dense table the trace engine indexes per miss.
    """
    n_states, n_lanes = layout.loc.shape
    n_levels = layout.pred_slot.shape[1]
    t = np.zeros((n_states, n_lanes, n_levels), dtype=np.int32)
    for st in range(n_states):
        for lane in range(n_lanes):
            for lvl in range(n_levels):
                pred = int(layout.pred_slot[lane][lvl]) if lane else 0
                chain = layout.probe_chain(lane, pred) if lane else [0]
                t[st, lane, lvl] = chain.index(int(layout.loc[st][lane])) + 1
    return t


def observe_layout(observed_state):
    """Direct-indexed last-compressibility update (the KV predictor).

    One table entry per page group, hash = identity: the next access
    predicts whatever layout state the group last packed into.  Returns a
    fresh buffer (the observation often aliases donated cache state).
    """
    import jax.numpy as jnp

    return jnp.copy(observed_state)
