"""Layouts: restricted line-to-slot mappings as one marker-framed protocol.

A `Layout` describes how a group of `n_lanes` logical lines is placed into
physical slots: the per-state slot map, which slots are vacated (and hence
hold Marker-IL), each lane's *candidate-slot table* (what makes the
line-location prediction problem small, §V-B), and the slot predicted for a
given compressibility level.  The Fig. 6 four-line group mapping of the
memory system and the CRAM-KV page-pair / page-quad slot formats are
instances of the same protocol — one location-predictor implementation
(compression.predictor) works against any of them via `candidates` /
`pred_slot`.

The GROUP4 tables below are the single definition of the Fig. 6 mapping
(repro.core.mapping re-exports them):

        lane:     A  B  C  D        vacated (Marker-IL) slots
  S_U          :  0  1  2  3        -
  S_AB         :  0  0  2  3        1
  S_CD         :  0  1  2  2        3
  S_AB_CD      :  0  0  2  2        1, 3
  S_QUAD       :  0  0  0  0        1, 2, 3

The Compression Status Information (CSI) for a group is one of these five
states = 3 bits/group = 0.75 bits/line (matches §IV-B's 24MB for 16GB).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .framing import MARKER_BYTES, PAYLOAD_BUDGET, SLOT_BUDGET

GROUP_LINES = 4

S_U, S_AB, S_CD, S_AB_CD, S_QUAD = range(5)
N_STATES = 5
STATE_NAMES = ("uncomp", "AB", "CD", "AB+CD", "quad")

# LOC[state][lane] -> slot holding that lane's data
LOC = np.asarray(
    [
        [0, 1, 2, 3],
        [0, 0, 2, 3],
        [0, 1, 2, 2],
        [0, 0, 2, 2],
        [0, 0, 0, 0],
    ],
    dtype=np.int32,
)

# VACATED[state][slot] -> slot holds Marker-IL
VACATED = np.asarray(
    [
        [0, 0, 0, 0],
        [0, 1, 0, 0],
        [0, 0, 0, 1],
        [0, 1, 0, 1],
        [0, 1, 1, 1],
    ],
    dtype=bool,
)

# OCCUPIED[state][slot] -> slot holds data (lead slot of a packed run or a
# plain uncompressed line)
OCCUPIED = ~VACATED

# How many lines live in a given slot for a given state (0 if vacated)
LINES_IN_SLOT = np.asarray(
    [
        [1, 1, 1, 1],
        [2, 0, 1, 1],
        [1, 1, 2, 0],
        [2, 0, 2, 0],
        [4, 0, 0, 0],
    ],
    dtype=np.int32,
)

# Lanes resident in (state, slot): bitmask over lanes
LANES_IN_SLOT = np.asarray(
    [
        [0b0001, 0b0010, 0b0100, 0b1000],
        [0b0011, 0, 0b0100, 0b1000],
        [0b0001, 0b0010, 0b1100, 0],
        [0b0011, 0, 0b1100, 0],
        [0b1111, 0, 0, 0],
    ],
    dtype=np.int32,
)

# candidate probe order per lane: own/leader slots from "least compressed"
# to "most compressed". The controller probes from its *predicted* slot and
# then walks the remaining candidates.
CANDIDATES = ((0,), (1, 0), (2, 0), (3, 2, 0))

# Per-lane compressibility level observed from a state (0=uncomp, 1=2:1, 2=4:1)
LANE_LEVEL = np.asarray(
    [
        [0, 0, 0, 0],
        [1, 1, 0, 0],
        [0, 0, 1, 1],
        [1, 1, 1, 1],
        [2, 2, 2, 2],
    ],
    dtype=np.int32,
)

# Slot predicted for (lane, predicted_level): level 2 -> slot 0; level 1 ->
# pair-leader slot; level 0 -> own slot.
PRED_SLOT = np.asarray(
    [
        [0, 0, 0],
        [1, 0, 0],
        [2, 2, 0],
        [3, 2, 0],
    ],
    dtype=np.int32,
)


@dataclass(frozen=True)
class Layout:
    """A restricted mapping of `n_lanes` lines onto marker-framed slots.

    Tables are per-state (axis 0) x per-lane/slot (axis 1); `candidates`
    is the per-lane probe-candidate tuple the location predictor draws
    from, `pred_slot[lane, level]` the slot a predicted compressibility
    level resolves to.  `slot_budget`/`marker_bytes` frame each slot
    (framing.py constants for the 64B line layouts; the KV layouts carry
    the marker in the base strip's tail lanes instead, so their full slot
    budget holds payload).
    """
    name: str
    n_lanes: int
    loc: np.ndarray
    vacated: np.ndarray
    lines_in_slot: np.ndarray
    lanes_in_slot: np.ndarray
    lane_level: np.ndarray
    candidates: tuple
    pred_slot: np.ndarray
    state_names: tuple
    slot_budget: int = SLOT_BUDGET
    marker_bytes: int = MARKER_BYTES
    payload_budget: int = PAYLOAD_BUDGET
    description: str = ""

    @property
    def n_states(self) -> int:
        return self.loc.shape[0]

    def slot_of(self, state: int, lane: int) -> int:
        return int(self.loc[state][lane])

    def probe_chain(self, lane: int, predicted_slot: int) -> list[int]:
        """Probe order: predicted slot first, then remaining candidates."""
        cands = list(self.candidates[lane])
        if predicted_slot in cands:
            cands.remove(predicted_slot)
        return [predicted_slot] + cands


_REGISTRY: dict[str, Layout] = {}


def register_layout(layout: Layout, *, overwrite: bool = False) -> Layout:
    if layout.name in _REGISTRY and not overwrite:
        raise ValueError(f"layout {layout.name!r} is already registered")
    _REGISTRY[layout.name] = layout
    return layout


def get_layout(name: str) -> Layout:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown layout {name!r}; valid: {sorted(_REGISTRY)}") from None


def layout_names() -> tuple[str, ...]:
    return tuple(_REGISTRY)


# ------------------------------------------------------------- instances

GROUP4 = register_layout(Layout(
    name="group4",
    n_lanes=4,
    loc=LOC,
    vacated=VACATED,
    lines_in_slot=LINES_IN_SLOT,
    lanes_in_slot=LANES_IN_SLOT,
    lane_level=LANE_LEVEL,
    candidates=CANDIDATES,
    pred_slot=PRED_SLOT,
    state_names=STATE_NAMES,
    description="Fig. 6 restricted mapping: 4 consecutive 64B lines, "
                "5 layout states, 3-bit CSI per group",
))

# CRAM-KV 2:1 page pairs: lanes A/B; the packed state puts both pages in
# slot 0 (one DMA, two pages — the paper's win), slot 1 vacated.
KV_PAIR = register_layout(Layout(
    name="kv-pair",
    n_lanes=2,
    loc=np.asarray([[0, 1], [0, 0]], np.int32),
    vacated=np.asarray([[0, 0], [0, 1]], bool),
    lines_in_slot=np.asarray([[1, 1], [2, 0]], np.int32),
    lanes_in_slot=np.asarray([[0b01, 0b10], [0b11, 0]], np.int32),
    lane_level=np.asarray([[0, 0], [1, 1]], np.int32),
    candidates=((0,), (1, 0)),
    pred_slot=np.asarray([[0, 0], [1, 0]], np.int32),
    state_names=("uncomp", "pair"),
    description="CRAM-KV 2:1 page-pair slots (int8-delta codec, marker in "
                "the base-strip tail lanes)",
))

# CRAM-KV 4:1 page quads: lanes A..D; the packed state puts all four pages
# in slot 0 (int4-delta codec), slots 1-3 vacated.
KV_QUAD = register_layout(Layout(
    name="kv-quad",
    n_lanes=4,
    loc=np.asarray([[0, 1, 2, 3], [0, 0, 0, 0]], np.int32),
    vacated=np.asarray([[0, 0, 0, 0], [0, 1, 1, 1]], bool),
    lines_in_slot=np.asarray([[1, 1, 1, 1], [4, 0, 0, 0]], np.int32),
    lanes_in_slot=np.asarray(
        [[0b0001, 0b0010, 0b0100, 0b1000], [0b1111, 0, 0, 0]], np.int32),
    lane_level=np.asarray([[0, 0, 0, 0], [2, 2, 2, 2]], np.int32),
    candidates=((0,), (1, 0), (2, 0), (3, 0)),
    pred_slot=np.asarray(
        [[0, 0, 0], [1, 0, 0], [2, 0, 0], [3, 0, 0]], np.int32),
    state_names=("uncomp", "quad"),
    description="CRAM-KV 4:1 page-quad slots (int4-delta codec)",
))


# ------------------------------------------- GROUP4 state-choice helpers

def choose_state(sizes, valid_mask: int = 0b1111, budget: int = PAYLOAD_BUDGET):
    """Best GROUP4 layout state for a group given per-line compressed sizes.

    sizes: 4 compressed sizes in bytes (including per-line headers).
    valid_mask: which lanes' data the controller actually holds (only lanes
      co-resident in the LLC may be packed together — ganged eviction).
    """
    s = [int(x) for x in sizes]
    have = lambda m: (valid_mask & m) == m
    quad = have(0b1111) and sum(s) <= budget
    ab = have(0b0011) and s[0] + s[1] <= budget
    cd = have(0b1100) and s[2] + s[3] <= budget
    if quad:
        return S_QUAD
    if ab and cd:
        return S_AB_CD
    if ab:
        return S_AB
    if cd:
        return S_CD
    return S_U


def fits_to_state(pair_ab: bool, pair_cd: bool, quad: bool) -> int:
    if quad:
        return S_QUAD
    if pair_ab and pair_cd:
        return S_AB_CD
    if pair_ab:
        return S_AB
    if pair_cd:
        return S_CD
    return S_U


def slot_of(state: int, lane: int) -> int:
    return GROUP4.slot_of(state, lane)


def probe_chain(lane: int, predicted_slot: int) -> list[int]:
    """GROUP4 probe order (see Layout.probe_chain for the generic form)."""
    return GROUP4.probe_chain(lane, predicted_slot)
