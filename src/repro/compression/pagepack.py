"""KV page-packing codecs: int8-delta pairs (2:1) and int4-delta quads (4:1).

The serving-side line codec (DESIGN.md §3): a KV *page* is a (page, Hkv, D2)
int16 tile of bf16 bit patterns; a group of pages packs into ONE physical
slot when every element is within a signed delta range of a shared base row
(page A's token-0 row), mirroring BDI's base+delta idea at page granularity.

  * pair (int8 deltas):  element = (deltaB & 0xFF) << 8 | (deltaA & 0xFF)
  * quad (int4 deltas):  element = (dD & 0xF) << 12 | (dC & 0xF) << 8
                                 | (dB & 0xF) << 4  | (dA & 0xF)

These are the bit-true, xp-generic (numpy or jax.numpy) reference
implementations; `kernels/bdi_pack.py` provides the Pallas device backends
and `kernels/ref.py` the jnp oracles — all three are allclose-pinned by the
cross-backend round-trip tests.  Two's-complement wrapping makes the
encode/decode pair exact whenever the fit check passes.
"""

from __future__ import annotations

import numpy as np

PAIR_DELTA_BITS = 8
QUAD_DELTA_BITS = 4


def _deltas(page, base, xp):
    return page.astype(xp.int32) - base.astype(xp.int32)[None]


def _fits(delta, bits: int):
    lim = 1 << (bits - 1)
    return (delta >= -lim) & (delta <= lim - 1)


def pack_pair(page_a, page_b, xp=np):
    """(page,Hkv,D2) int16 x2 -> (ok, packed int16, base (Hkv,D2) int16)."""
    base = page_a[0]
    da = _deltas(page_a, base, xp)
    db = _deltas(page_b, base, xp)
    ok = xp.all(_fits(da, PAIR_DELTA_BITS) & _fits(db, PAIR_DELTA_BITS))
    packed = ((db & 0xFF) << 8 | (da & 0xFF)).astype(xp.uint16).view(xp.int16)
    return ok, packed, base


def unpack_pair(packed, base, xp=np):
    """Inverse of pack_pair -> (page_a, page_b) int16."""
    v = packed.view(xp.uint16).astype(xp.int32)
    lo = (v & 0xFF).astype(xp.int8).astype(xp.int32)        # sign-extend
    hi = ((v >> 8) & 0xFF).astype(xp.int8).astype(xp.int32)
    a = base.astype(xp.int32)[None] + lo
    b = base.astype(xp.int32)[None] + hi
    return a.astype(xp.int16), b.astype(xp.int16)


def pack_quad(page_a, page_b, page_c, page_d, xp=np):
    """Four (page,Hkv,D2) int16 pages -> (ok, packed int16, base int16).

    Each int16 element carries four int4 deltas vs the shared base (page
    A's token-0 row) — the 4:1 analogue of the pair codec.
    """
    base = page_a[0]
    ds = [_deltas(p, base, xp) for p in (page_a, page_b, page_c, page_d)]
    ok = xp.all(
        _fits(ds[0], QUAD_DELTA_BITS) & _fits(ds[1], QUAD_DELTA_BITS)
        & _fits(ds[2], QUAD_DELTA_BITS) & _fits(ds[3], QUAD_DELTA_BITS))
    packed = ((ds[3] & 0xF) << 12 | (ds[2] & 0xF) << 8
              | (ds[1] & 0xF) << 4 | (ds[0] & 0xF))
    packed = packed.astype(xp.uint16).view(xp.int16)
    return ok, packed, base


def unpack_quad(packed, base, xp=np):
    """Inverse of pack_quad -> (page_a, page_b, page_c, page_d) int16."""
    v = packed.view(xp.uint16).astype(xp.int32)
    b32 = base.astype(xp.int32)[None]
    out = []
    for shift in (0, 4, 8, 12):
        nib = ((v >> shift) & 0xF)
        nib = (nib ^ 0x8) - 0x8                             # sign-extend int4
        out.append((b32 + nib).astype(xp.int16))
    return tuple(out)
