"""repro.compression — the single source of truth for the paper's stack.

One subsystem owns the pieces every consumer previously re-implemented:

  * framing   — THE marker-framing constants (slot budget, marker bytes /
                lanes, payload budget, header byte) + device marker families
  * codecs    — `Codec` registry: raw / bdi / fpc / hybrid line codecs and
                int8-delta / int4-delta page codecs, each carrying its
                bit-true numpy pack/unpack, vectorized xp-generic size
                function, and (lazily resolved) Pallas backend
  * layouts   — `Layout` registry: the Fig. 6 group4 mapping and the KV
                pair/quad slot formats as instances of one marker-framed
                protocol (candidate-slot tables included)
  * gate      — THE saturating-counter Dynamic-CRAM gate (§VI)
  * predictor — THE line-location predictor (§V-B), parameterized by a
                layout's candidate-slot table
  * marker    — host-side keyed markers + implicit-metadata classification
  * fpc/bdi/hybrid/pagepack/bits — codec implementations behind the registry

Consumers: core.engine / core.schemes (scheme rows name a codec+layout),
core.cram (exact functional model), kernels (device backends), kv.cache,
checkpoint.codec, optim.grad_compress, benchmarks.  The old per-module
homes under repro.core re-export from here for compatibility.
"""

from . import bdi, bits, fpc, framing, gate, hybrid, layouts, marker
from . import pagepack, predictor
from .codecs import Codec, codec_names, get_codec, register_codec
from .framing import (
    HEADER_BYTES,
    LINE_BYTES,
    MARKER_BYTES,
    MARKER_LANES,
    PAYLOAD_BUDGET,
    SLOT_BUDGET,
)
from .layouts import (
    GROUP4,
    KV_PAIR,
    KV_QUAD,
    Layout,
    get_layout,
    layout_names,
    register_layout,
)

__all__ = [
    "bdi", "bits", "fpc", "framing", "gate", "hybrid", "layouts", "marker",
    "pagepack", "predictor",
    "Codec", "codec_names", "get_codec", "register_codec",
    "Layout", "get_layout", "layout_names", "register_layout",
    "GROUP4", "KV_PAIR", "KV_QUAD",
    "LINE_BYTES", "SLOT_BUDGET", "MARKER_BYTES", "MARKER_LANES",
    "PAYLOAD_BUDGET", "HEADER_BYTES",
]
