"""Base-Delta-Immediate (BDI) compression [Pekhimenko et al., PACT 2012].

A 64-byte line is viewed as k elements of `base_bytes` each; it compresses if
every element is either within a signed `delta_bytes` range of a common base
(taken as the first non-immediate element) or of zero ("immediate").  A k-bit
mask records which base each element used.  Special modes: all-zero line and
a line of one repeated 8-byte value.

Layout of a packed payload (mode-specific, fixed size):
    [base: b bytes LE][mask: ceil(k/8) bytes][deltas: k*d bytes LE]

All arithmetic is two's-complement wrapping, which makes the encode/decode
pair exact even when the "true" delta overflows: the decoder adds the
sign-extended residue back with wrapping.

`bdi_sizes` is vectorized and accepts numpy or jax.numpy as `xp`;
`bdi_pack_batch` / `bdi_unpack_batch` are exact vectorized numpy paths used
by tests and the checkpoint codec.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

LINE_BYTES = 64

# mode ids (stable; stored in the 1-byte hybrid header by compress.py)
M_ZEROS, M_REP8, M_B8D1, M_B8D2, M_B8D4, M_B4D1, M_B4D2, M_B2D1, M_RAW = range(9)


@dataclass(frozen=True)
class _Mode:
    mode: int
    base_bytes: int
    delta_bytes: int

    @property
    def k(self) -> int:
        return LINE_BYTES // self.base_bytes

    @property
    def mask_bytes(self) -> int:
        return (self.k + 7) // 8

    @property
    def payload_bytes(self) -> int:
        return self.base_bytes + self.mask_bytes + self.k * self.delta_bytes


BD_MODES = (
    _Mode(M_B8D1, 8, 1),   # 17
    _Mode(M_B8D2, 8, 2),   # 25
    _Mode(M_B8D4, 8, 4),   # 41
    _Mode(M_B4D1, 4, 1),   # 22
    _Mode(M_B4D2, 4, 2),   # 38
    _Mode(M_B2D1, 2, 1),   # 38
)
MODE_BY_ID = {m.mode: m for m in BD_MODES}

PAYLOAD_BYTES = {
    M_ZEROS: 0,
    M_REP8: 8,
    M_RAW: LINE_BYTES,
    **{m.mode: m.payload_bytes for m in BD_MODES},
}

_INT_DTYPES = {1: "<i1", 2: "<i2", 4: "<i4", 8: "<i8"}


def _elems_np(lines: np.ndarray, b: int) -> np.ndarray:
    """(N,64) uint8 -> (N, 64//b) signed ints, little-endian."""
    lines = np.ascontiguousarray(lines, dtype=np.uint8)
    return lines.view(_INT_DTYPES[b]).reshape(lines.shape[0], LINE_BYTES // b)


def _elems_jnp(lines, b: int):
    import jax.numpy as jnp
    from jax import lax

    k = LINE_BYTES // b
    dt = {1: jnp.int8, 2: jnp.int16, 4: jnp.int32, 8: jnp.int64}[b]
    x = lines.reshape(lines.shape[:-1] + (k, b))
    if b == 1:
        return x[..., 0].astype(jnp.int8)
    return lax.bitcast_convert_type(x, dt)


def _mode_fits(elems, d: int, xp):
    """elems: (N,k) signed. Returns (fits (N,), base (N,), imm_mask (N,k))."""
    e = elems.astype(xp.int64)
    lo, hi = -(1 << (8 * d - 1)), (1 << (8 * d - 1))
    imm = (e >= lo) & (e < hi)
    any_nonimm = ~imm.all(axis=-1)
    first_nonimm = xp.argmax(~imm, axis=-1)
    base = xp.take_along_axis(e, first_nonimm[..., None], axis=-1)[..., 0]
    base = xp.where(any_nonimm, base, 0)
    # wrapping residue; two's complement keeps encode/decode exact
    delta = (e - base[..., None]).astype(elems.dtype).astype(xp.int64)
    from_base = (delta >= lo) & (delta < hi)
    fits = (imm | from_base).all(axis=-1)
    return fits, base, imm


def bdi_sizes(lines_bytes, xp=np):
    """Vectorized best-BDI-mode search.

    lines_bytes: (N, 64) uint8.
    Returns (sizes (N,) int32 payload bytes, modes (N,) int32).
    """
    n = lines_bytes.shape[0]
    if xp is np:
        e8 = _elems_np(np.asarray(lines_bytes), 8)
    else:
        e8 = _elems_jnp(lines_bytes, 8)
    zeros = (e8 == 0).all(axis=-1)
    rep8 = (e8 == e8[..., :1]).all(axis=-1) & ~zeros

    best_size = xp.full((n,), LINE_BYTES, dtype=xp.int32)
    best_mode = xp.full((n,), M_RAW, dtype=xp.int32)
    # evaluate fixed modes from largest payload to smallest so that the
    # smallest fitting payload wins the final where-chain
    for m in sorted(BD_MODES, key=lambda m: -m.payload_bytes):
        if xp is np:
            elems = _elems_np(np.asarray(lines_bytes), m.base_bytes)
        else:
            elems = _elems_jnp(lines_bytes, m.base_bytes)
        fits, _, _ = _mode_fits(elems, m.delta_bytes, xp)
        take = fits & (m.payload_bytes < best_size)
        best_size = xp.where(take, m.payload_bytes, best_size)
        best_mode = xp.where(take, m.mode, best_mode)
    best_size = xp.where(rep8, PAYLOAD_BYTES[M_REP8], best_size)
    best_mode = xp.where(rep8, M_REP8, best_mode)
    best_size = xp.where(zeros, PAYLOAD_BYTES[M_ZEROS], best_size)
    best_mode = xp.where(zeros, M_ZEROS, best_mode)
    return best_size.astype(xp.int32), best_mode.astype(xp.int32)


# ---------------------------------------------------------------------------
# Exact vectorized pack / unpack (numpy)
# ---------------------------------------------------------------------------

def bdi_pack_batch(lines: np.ndarray, mode: int) -> np.ndarray:
    """Pack (N,64) lines, all with the given mode, -> (N, payload) uint8.

    Caller must have verified the mode fits (e.g. via bdi_sizes).
    """
    lines = np.ascontiguousarray(lines, dtype=np.uint8)
    n = lines.shape[0]
    if mode == M_ZEROS:
        return np.zeros((n, 0), dtype=np.uint8)
    if mode == M_REP8:
        return lines[:, :8].copy()
    if mode == M_RAW:
        return lines.copy()
    m = MODE_BY_ID[mode]
    elems = _elems_np(lines, m.base_bytes).astype(np.int64)
    fits, base, imm = _mode_fits(elems, m.delta_bytes, np)
    if not bool(np.all(fits)):
        raise ValueError(f"some lines do not fit BDI mode {mode}")
    chosen_base = np.where(imm, 0, base[:, None])
    delta = (elems - chosen_base).astype(_INT_DTYPES[m.delta_bytes])
    base_b = base.astype(_INT_DTYPES[m.base_bytes])[:, None].view(np.uint8)
    base_b = base_b.reshape(n, m.base_bytes)
    mask_bits = np.packbits(imm.astype(np.uint8), axis=-1, bitorder="little")
    delta_b = np.ascontiguousarray(delta).view(np.uint8).reshape(n, -1)
    return np.concatenate([base_b, mask_bits, delta_b], axis=1)


def bdi_unpack_batch(payload: np.ndarray, mode: int) -> np.ndarray:
    """Inverse of bdi_pack_batch: (N, payload) uint8 -> (N, 64) uint8."""
    payload = np.ascontiguousarray(payload, dtype=np.uint8)
    n = payload.shape[0]
    if mode == M_ZEROS:
        return np.zeros((n, LINE_BYTES), dtype=np.uint8)
    if mode == M_REP8:
        return np.tile(payload, (1, LINE_BYTES // 8))
    if mode == M_RAW:
        return payload.copy()
    m = MODE_BY_ID[mode]
    ofs = 0
    base = payload[:, ofs : ofs + m.base_bytes].copy().view(
        _INT_DTYPES[m.base_bytes]
    ).astype(np.int64)[:, 0]
    ofs += m.base_bytes
    mask = np.unpackbits(
        payload[:, ofs : ofs + m.mask_bytes], axis=-1, bitorder="little"
    )[:, : m.k].astype(bool)
    ofs += m.mask_bytes
    delta = (
        payload[:, ofs:].copy().view(_INT_DTYPES[m.delta_bytes]).astype(np.int64)
    )
    chosen_base = np.where(mask, 0, base[:, None])
    elems = (chosen_base + delta).astype(_INT_DTYPES[m.base_bytes])
    return np.ascontiguousarray(elems).view(np.uint8).reshape(n, LINE_BYTES)
