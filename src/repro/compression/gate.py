"""THE saturating-counter cost/benefit gate (Dynamic-CRAM, §VI).

One 12-bit saturating counter implementation, shared by every consumer of
the dynamic-compression idea:
  * the trace engine (core.engine) — set-sampled cost/benefit over LLC
    events, counter MSB gates compression for the follower sets;
  * the serving KV cache (kv.cache) — per-sequence counters driven by pack
    fitness of completed page groups;
  * the gradient collective (optim.grad_compress) — wire-bytes benefit vs
    quantization-error cost.

cost   (decrement): extra writebacks of compressible clean lines,
                    invalidate writes, misprediction second accesses
benefit (increment): useful bandwidth-free prefetches (a line installed
                    as a compression neighbor that later gets a hit)

The counter's MSB gates compression for the remaining 99% of sets.  The
per-core extension keeps one counter per core (3-bit core id tags on sampled
lines); our single-trace simulations use one counter, the object supports N.
"""

from __future__ import annotations

import numpy as np

from .framing import FIB_MULT

COUNTER_BITS = 12
COUNTER_MAX = (1 << COUNTER_BITS) - 1
# MSB gates compression; ENABLE_THRESHOLD is the MSB boundary. The counter
# starts saturated-enabled: compression is on until proven harmful (the
# paper does not specify the initial value; this choice reaches the Fig. 16
# behaviour — full win retained on SPEC, fast disable on GAP).
ENABLE_THRESHOLD = 1 << (COUNTER_BITS - 1)
# Start enabled with a margin: compression is on until a sustained net cost
# drags the counter below the MSB threshold.  (The margin and the simulator's
# sampling rate are scaled to our trace lengths — DESIGN.md §2.2; the
# hardware-faithful Table III accounting still uses 1% sampling + 12 bits.)
COUNTER_INIT = ENABLE_THRESHOLD + 128
SAMPLE_RATE = 0.01


class DynamicController:
    def __init__(self, n_cores: int = 1):
        self.counters = np.full(n_cores, COUNTER_INIT, dtype=np.int32)

    def cost(self, n: int = 1, core: int = 0) -> None:
        self.counters[core] = max(0, int(self.counters[core]) - n)

    def benefit(self, n: int = 1, core: int = 0) -> None:
        self.counters[core] = min(COUNTER_MAX, int(self.counters[core]) + n)

    def enabled(self, core: int = 0) -> bool:
        return bool(self.counters[core] >= ENABLE_THRESHOLD)

    @property
    def storage_bytes(self) -> int:
        return self.counters.size * COUNTER_BITS // 8


def is_sampled_set(set_idx, n_sets, rate: float = SAMPLE_RATE, xp=np):
    """Deterministic ~1% sampling of LLC sets (hash-spread, not contiguous)."""
    h = (set_idx * FIB_MULT) & 0xFFFFFFFF
    return (h % 1024) < max(1, int(rate * 1024))


def counter_step(counter, cost, benefit, xp):
    """Pure-functional saturating update for lax.scan / jit paths."""
    c = counter + benefit - cost
    return xp.clip(c, 0, COUNTER_MAX)


def counter_enabled(counter):
    return counter >= ENABLE_THRESHOLD


# --------------------------------------------------------------- wire gate
# §VI applied to the gradient collective (optim.grad_compress): benefit is
# the fraction of wire bytes the int8 collective saves, cost is a quality
# penalty when the relative quantization error exceeds its budget.  The
# scaling constants live HERE so every §VI threshold has one home.
WIRE_BENEFIT_SCALE = 16      # counter ticks per unit fraction of bytes saved
WIRE_COST_OVER_BUDGET = 64   # ticks charged when quality is over budget


def wire_counter_step(counter, bytes_saving, over_budget, xp):
    """One wire-gate update: `bytes_saving` is the fractional wire-byte win
    (e.g. 0.75 for fp32 -> int8), `over_budget` a (traceable) bool.  Same
    saturating semantics as every other §VI counter."""
    benefit = (xp.asarray(bytes_saving, xp.float32)
               * WIRE_BENEFIT_SCALE).astype(xp.int32)
    cost = xp.where(over_budget, xp.int32(WIRE_COST_OVER_BUDGET),
                    xp.int32(0))
    return counter_step(counter, cost, benefit, xp)
