"""Paged KV cache with CRAM packing (serving substrate)."""

from .cache import CRAMKVCache

__all__ = ["CRAMKVCache"]
