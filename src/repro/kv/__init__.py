"""Batched paged KV cache with incremental CRAM packing (serving substrate)."""

from .cache import CRAMKVCache, KVStats
from .traffic import synthetic_kv_stream

__all__ = ["CRAMKVCache", "KVStats", "synthetic_kv_stream"]
