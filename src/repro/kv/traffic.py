"""Synthetic KV decode traffic for benches and tests.

One generator, shared by benchmarks/serve_bench.py, benchmarks/
kernel_bench.py and tests/test_kv_cache.py, so the compressibility model
(the noise scale that makes page pairs BDI-packable in bf16) cannot drift
between what the tests assert and what the benches measure.
"""

from __future__ import annotations

import numpy as np


def synthetic_kv_stream(rng, batch: int, n_tokens: int, n_kv: int,
                        head_dim: int, *, compressible: bool = True,
                        scale: float = 2e-3):
    """(k, v) float32 arrays of shape (batch, n_tokens, n_kv, head_dim).

    Compressible streams hover multiplicatively (`scale`) around a shared
    per-(head, dim) base, so bf16 pages delta-pack against the pair base;
    incompressible streams are unit normals, which never fit int8 deltas.
    """
    base = 2.0 + rng.standard_normal((batch, 1, n_kv, head_dim)) * 0.2
    shape = (batch, n_tokens, n_kv, head_dim)
    if compressible:
        k = base * (1 + rng.standard_normal(shape) * scale)
        v = base * (1 + rng.standard_normal(shape) * scale)
    else:
        k = rng.standard_normal(shape)
        v = rng.standard_normal(shape)
    return k.astype(np.float32), v.astype(np.float32)
