"""CRAM-KV: batched paged serving cache with marker-packed page groups.

The serving-side embodiment of the paper (DESIGN.md §3): logical KV pages
pack groupwise into physical slots when delta-compressible, interpretation
is by in-band marker (kernels/cram_attention), a last-compressibility
predictor (the LLP analog, indexed by page group — compression.predictor's
`observe_layout` rule) decides whether the overflow slots need to be
fetched at all, and a per-sequence Dynamic-CRAM counter
(compression.gate, §VI) turns packing off when the data never compresses —
while *still sampling pack fitness on repacked groups*, so it can
re-enable when compressible traffic returns.

Two registry-provided packing layouts (compression.layouts):
  * packing="pair" — KV_PAIR: 2 pages per group, int8-delta codec (2:1);
  * packing="quad" — KV_QUAD: 4 pages per group, int4-delta codec (4:1),
    quad-domain markers (a slot's pair marker can never alias its quad
    marker).

Cache state is a JAX pytree with a batch axis (B sequences x page groups):
`append` is a vectorized token scatter (no per-token host loop), and
`repack` is incremental — a dirty-group mask tracks the page groups touched
since the last pack, so a decode step re-packs O(new groups) instead of
rebuilding every group (the old per-step full build made decode O(T^2) in
sequence length).  The incremental state is bit-identical to a from-scratch
`kernels/ops.build_cram_cache[_quad]` rebuild under the gate applied at the
last repack (`reference_rebuild` is the oracle; tests/test_kv_cache.py pins
it).

Bandwidth accounting (per decode step, kernels/ops.hbm_bytes_moved):
  raw        : one slot DMA per live page
  CRAM       : one slot DMA per packed GROUP (2 or 4 pages), plus the
               strip; unpacked groups cost one slot + strip per live page;
               mispredicted groups cost a second slot access (the paper's
               LLP-miss re-probe)
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..bandwidth import Ledger
from ..bandwidth.adapters import kv_decode_event, kv_repack_event
from ..compression.framing import DOMAIN_PAIR, DOMAIN_QUAD
from ..compression.gate import COUNTER_INIT, COUNTER_MAX, ENABLE_THRESHOLD
from ..compression.predictor import observe_layout
from ..kernels import ops as kops
from ..kernels.ref import MARKER_LANES, marker_to_lanes, slot_markers


@dataclass
class KVStats:
    """Pack/predictor event counters.  Byte accounting is NOT here: every
    byte a decode step or repack moves lands in the cache's `ledger`
    (repro.bandwidth), under consumer "kv"."""

    packed_pairs: int = 0
    raw_pairs: int = 0
    predictor_hits: int = 0
    predictor_misses: int = 0
    pack_attempts: int = 0
    pack_skipped_dynamic: int = 0
    pack_calls: int = 0
    pack_pairs_processed: int = 0  # sequences x groups run through repack


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_tokens(pages, kv, start):
    """pages (B, Tmax, Hkv, D2) <- kv (B, T, Hkv, D2) at token `start`."""
    return jax.lax.dynamic_update_slice(pages, kv, (0, start, 0, 0))


@functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3))
def _scatter_window(slots, over, strips, mask, idx, slots_w, over_w,
                    strips_w, lay):
    """One fused, donated update of the physical state at group `idx` —
    the per-step write stays O(new groups), no five-way full-buffer copy."""
    return (slots.at[:, idx].set(slots_w),
            over.at[:, idx].set(over_w),
            strips.at[:, idx].set(strips_w),
            mask.at[:, idx].set(lay))


class CRAMKVCache:
    """Batched paged KV cache: B sequences, uniform token counts."""

    def __init__(self, max_pages: int, page: int, n_kv: int, head_dim: int,
                 *, batch: int = 1, policy: str = "dynamic",
                 packing: str = "pair", key: int = 0x5EED,
                 counter_init: int = COUNTER_INIT,
                 interpret: bool | None = None,
                 ledger: Ledger | None = None):
        # "auto": the AutoTuner picked the packing (see `CRAMKVCache.auto`);
        # at runtime it is the §VI dynamic gate over that layout.
        assert policy in ("dynamic", "static", "off", "auto")
        assert packing in ("pair", "quad")
        self.packing = packing
        self.group_lanes = 2 if packing == "pair" else 4
        # capacity rounds UP to a whole number of page groups: callers ask
        # for the pages they need, the layout owns its own granularity
        max_pages = -(-max_pages // self.group_lanes) * self.group_lanes
        self.page, self.n_kv, self.d = page, n_kv, head_dim
        self.d2 = 2 * head_dim
        self.max_pages = max_pages
        self.n_groups = max_pages // self.group_lanes
        self.batch = batch
        self.policy = policy
        self.key = key
        self.interpret = (kops.default_interpret() if interpret is None
                          else interpret)
        self.tokens = 0
        domain = DOMAIN_PAIR if packing == "pair" else DOMAIN_QUAD
        markers = slot_markers(self.n_groups, key, domain=domain)
        self._marker_lanes = jnp.asarray(marker_to_lanes(markers))
        b, n, p = batch, self.n_groups, page
        over_shape = ((b, n, p, n_kv, self.d2) if packing == "pair"
                      else (b, n, self.group_lanes - 1, p, n_kv, self.d2))
        self.state = {
            "pages": jnp.zeros((b, max_pages * p, n_kv, self.d2), jnp.int16),
            "slots": jnp.zeros((b, n, p, n_kv, self.d2), jnp.int16),
            "slots_overflow": jnp.zeros(over_shape, jnp.int16),
            "strips": jnp.zeros((b, n, n_kv, self.d2 + MARKER_LANES),
                                jnp.int16),
            "packed_mask": jnp.zeros((b, n), bool),
            "predictor": jnp.zeros((b, n), bool),
            "counter": jnp.full((b,), counter_init, jnp.int32),
            "markers": jnp.asarray(markers.view(np.int32)),
        }
        # dirty-group mask: appends are uniform across the batch, so one
        # host-side mask covers every sequence; per-sequence gate flips
        # mark the whole active prefix dirty (rare — full re-layout).
        self._dirty = np.zeros(self.n_groups, bool)
        # groups with data not yet fed to the §VI counter: a gate flip
        # re-dirties the layout but must NOT re-count historical fitness
        # (that would re-apply the whole prefix's fit/unfit balance and
        # could slam the counter straight back across the threshold).
        self._uncounted = np.zeros(self.n_groups, bool)
        self._last_enabled = np.full(batch, policy != "off", bool)
        self.stats = KVStats()
        # traffic lands here (consumer "kv"); pass a shared ledger to fold
        # this cache's flows into a launcher-wide accounting
        self.ledger = ledger if ledger is not None else Ledger("kv")
        self.slot_bytes = page * n_kv * self.d2 * 2
        self.strip_bytes = n_kv * (self.d2 + MARKER_LANES) * 2

    @classmethod
    def auto(cls, tuner, k_sample, v_sample, *, max_pages: int, page: int,
             n_kv: int, head_dim: int, **kw):
        """`policy="auto"`: let an `bandwidth.AutoTuner` pick the packing
        layout (off / pair / quad) from a sample of the KV stream, then run
        the §VI dynamic gate over the chosen layout.  Returns (cache,
        PolicyChoice)."""
        d2 = 2 * head_dim
        choice = tuner.choose_kv_packing(
            k=k_sample, v=v_sample, page=page,
            slot_bytes=page * n_kv * d2 * 2,
            strip_bytes=n_kv * (d2 + MARKER_LANES) * 2)
        if choice.choice == "off":
            cache = cls(max_pages, page, n_kv, head_dim,
                        policy="off", packing="pair", **kw)
        else:
            cache = cls(max_pages, page, n_kv, head_dim, policy="auto",
                        packing=choice.choice, **kw)
        return cache, choice

    # legacy pair-era aliases (the default packing is the 2:1 pair layout)
    @property
    def n_pairs(self) -> int:
        return self.n_groups

    # ----------------------------------------------------------- appends
    def append(self, k, v):
        """k/v: (B, T, n_kv, d) — or (T, n_kv, d) when batch == 1 — new
        tokens, any float dtype (stored as bf16 bit patterns)."""
        k = jnp.asarray(k, jnp.bfloat16).view(jnp.int16)
        v = jnp.asarray(v, jnp.bfloat16).view(jnp.int16)
        if k.ndim == 3:
            assert self.batch == 1, "batched cache needs (B, T, n_kv, d)"
            k, v = k[None], v[None]
        kv = jnp.concatenate([k, v], axis=-1)        # (B, T, n_kv, d2)
        bsz, t = kv.shape[:2]
        assert bsz == self.batch
        assert self.tokens + t <= self.max_pages * self.page, "cache full"
        self.state["pages"] = _scatter_tokens(
            self.state["pages"], kv, self.tokens)
        span = self.group_lanes * self.page           # tokens per group
        lo = self.tokens // span
        hi = (self.tokens + t - 1) // span
        self._dirty[lo:hi + 1] = True
        self._uncounted[lo:hi + 1] = True
        self.tokens += t

    @property
    def n_pages(self) -> int:
        return (self.tokens + self.page - 1) // self.page

    @property
    def n_active_groups(self) -> int:
        return -(-self.n_pages // self.group_lanes)

    @property
    def n_active_pairs(self) -> int:
        return self.n_active_groups

    def valid_per_page(self) -> np.ndarray:
        """(B, max_pages) int32 valid tokens per logical page."""
        v = np.clip(self.tokens - np.arange(self.max_pages) * self.page,
                    0, self.page).astype(np.int32)
        return np.broadcast_to(v, (self.batch, self.max_pages)).copy()

    def pages_view(self):
        """Logical pages (B, max_pages, page, n_kv, d2)."""
        return self.state["pages"].reshape(
            self.batch, self.max_pages, self.page, self.n_kv, self.d2)

    # ------------------------------------------------------------- packing
    def enabled(self) -> np.ndarray:
        """(B,) bool: per-sequence compression gate (counter MSB, §VI)."""
        if self.policy == "off":
            return np.zeros(self.batch, bool)
        if self.policy == "static":
            return np.ones(self.batch, bool)
        return np.asarray(self.state["counter"]) >= ENABLE_THRESHOLD

    def _pack_window(self, win, idx_j, enabled):
        """Dispatch the dirty window to the layout's pack/raw kernels.

        win: (B, W, lanes, page, n_kv, d2) gathered dirty groups."""
        if self.packing == "pair":
            a, b = win[:, :, 0], win[:, :, 1]
            if self.policy == "off":
                return kops.raw_window(a, b)
            return kops.pack_window(a, b, self._marker_lanes[idx_j],
                                    jnp.asarray(enabled),
                                    interpret=self.interpret)
        if self.policy == "off":
            return kops.raw_quad_window(win)
        return kops.pack_quad_window(win, self._marker_lanes[idx_j],
                                     jnp.asarray(enabled),
                                     interpret=self.interpret)

    def repack(self):
        """Incrementally re-pack the dirty groups (no-op when clean)."""
        idx = np.nonzero(self._dirty)[0]
        if idx.size == 0:
            return
        w = int(idx.size)
        enabled = self.enabled()
        idx_j = jnp.asarray(idx, jnp.int32)
        groups = self.pages_view().reshape(
            self.batch, self.n_groups, self.group_lanes, self.page,
            self.n_kv, self.d2)
        win = groups[:, idx_j]                # (B, W, lanes, page, ...)
        slots_w, over_w, strips_w, lay, fit = self._pack_window(
            win, idx_j, enabled)
        if self.policy == "off":
            self.stats.pack_skipped_dynamic += self.batch * w
        else:
            self.stats.pack_attempts += self.batch * w
            self.stats.pack_skipped_dynamic += int((~enabled).sum()) * w
        st = self.state
        (st["slots"], st["slots_overflow"], st["strips"],
         st["packed_mask"]) = _scatter_window(
            st["slots"], st["slots_overflow"], st["strips"],
            st["packed_mask"], idx_j, slots_w, over_w, strips_w, lay)
        self.stats.pack_calls += 1
        self.stats.pack_pairs_processed += self.batch * w
        lay_n = int(np.asarray(lay).sum())
        self.stats.packed_pairs += lay_n
        self.stats.raw_pairs += self.batch * w - lay_n
        kv_repack_event(self.ledger, groups=self.batch * w, packed=lay_n,
                        lanes=self.group_lanes, slot_bytes=self.slot_bytes,
                        strip_bytes=self.strip_bytes)
        # §VI cost/benefit: fitness of *complete, not-yet-counted* repacked
        # groups drives the per-sequence counter — measured even while
        # disabled (the zeroed layout mask no longer feeds the update), so
        # the gate can re-enable once compressible traffic returns.  Each
        # group is counted exactly once, when it completes: gate-flip
        # re-dirt re-lays groups out but never re-counts their fitness.
        complete = (idx + 1) * self.group_lanes * self.page <= self.tokens
        if self.policy in ("dynamic", "auto"):
            countable = jnp.asarray(complete & self._uncounted[idx])
            fit_n = (fit & countable[None, :]).sum(1)
            unfit_n = ((~fit) & countable[None, :]).sum(1)
            st["counter"] = jnp.clip(
                st["counter"] + (fit_n - unfit_n).astype(jnp.int32),
                0, COUNTER_MAX)
        self._uncounted[idx[complete]] = False
        self._dirty[:] = False
        self._last_enabled = enabled
        flipped = self.enabled() != enabled
        if flipped.any():
            # gate changed for some sequence: its whole layout must be
            # rebuilt under the new gate at the next repack (keeps the
            # incremental state equal to a full rebuild).
            self._dirty[: self.n_active_groups] = True

    def reference_rebuild(self) -> dict:
        """From-scratch full pack of the active groups, per sequence, under
        the gate applied at the last repack — the bit-exactness oracle for
        the incremental path (compare with `active_state`)."""
        lanes = self.group_lanes
        n2 = lanes * self.n_active_groups
        pages = self.pages_view()[:, :n2]
        build = (kops.build_cram_cache if self.packing == "pair"
                 else kops.build_cram_cache_quad)
        out = []
        for bi in range(self.batch):
            if self._last_enabled[bi]:
                c = build(pages[bi], key=self.key, interpret=self.interpret)
            else:
                n = n2 // lanes
                grouped = pages[bi].reshape(
                    n, lanes, self.page, self.n_kv, self.d2)
                over = (grouped[:, 1] if self.packing == "pair"
                        else grouped[:, 1:])
                c = {
                    "slots": grouped[:, 0],
                    "slots_overflow": over,
                    "strips": jnp.zeros(
                        (n, self.n_kv, self.d2 + MARKER_LANES), jnp.int16),
                    "markers": self.state["markers"][:n],
                    "packed_mask": jnp.zeros((n,), bool),
                }
            out.append(c)
        keys = ("slots", "slots_overflow", "strips", "packed_mask")
        ref = {k: jnp.stack([c[k] for c in out]) for k in keys}
        ref["markers"] = self.state["markers"][: n2 // lanes]
        return ref

    def active_state(self) -> dict:
        """The physical cache restricted to the active group prefix."""
        return self._kernel_cache(self.n_active_groups)

    # -------------------------------------------------------------- attend
    def _active_bucket(self) -> int:
        """Active group count rounded up to a power of two: the decode grid
        walks O(sequence) slots, not O(capacity), while the pow2 bucketing
        bounds retraces to log2(capacity) shapes as the sequence grows."""
        n = max(1, self.n_active_groups)
        return min(1 << (n - 1).bit_length(), self.n_groups)

    def _kernel_cache(self, n: int) -> dict:
        st = self.state
        return {"slots": st["slots"][:, :n],
                "slots_overflow": st["slots_overflow"][:, :n],
                "strips": st["strips"][:, :n],
                "packed_mask": st["packed_mask"][:, :n],
                "markers": st["markers"][:n]}

    def account_step(self) -> dict:
        """One decode step's bandwidth accounting + LLP predictor update.

        Charges the CRAM byte model (incl. the mispredict re-probe against
        the group-indexed predictor), tallies predictor hits/misses on live
        groups, then lets the predictor observe the actual layout.
        """
        self.repack()
        return self._account()

    def _account(self) -> dict:
        st = self.state
        lanes = self.group_lanes
        n = self._active_bucket()
        valid = self.valid_per_page()[:, : lanes * n]
        bw = kops.hbm_bytes_moved(self._kernel_cache(n), valid,
                                  predictor=st["predictor"][:, :n],
                                  lanes=lanes)
        live = valid.reshape(self.batch, n, lanes).sum(-1) > 0
        mis = (np.asarray(st["predictor"][:, :n])
               != np.asarray(st["packed_mask"][:, :n]))
        self.stats.predictor_misses += int((mis & live).sum())
        self.stats.predictor_hits += int((~mis & live).sum())
        kv_decode_event(self.ledger, bw)
        # last-layout predictor observation (copy, not alias: packed_mask's
        # buffer is donated at the next repack scatter and the predictor
        # must survive it)
        st["predictor"] = observe_layout(st["packed_mask"])
        return bw

    def attend(self, q, *, account: bool = True):
        """q: (B, Hq, d) one query row per sequence -> (B, Hq, d) float32,
        with per-step bandwidth accounting (`account=False` for parity
        probes that must not charge an extra step)."""
        self.repack()
        q = jnp.asarray(q)
        if q.ndim == 2:
            q = q[None]
        n = self._active_bucket()
        decode = (kops.decode_attention_batched if self.packing == "pair"
                  else kops.decode_attention_quad_batched)
        out = decode(
            q, self._kernel_cache(n),
            self.valid_per_page()[:, : self.group_lanes * n],
            interpret=self.interpret)
        if account:
            self._account()   # bytes for the layout the kernel walked
        return out

    def attend_ref(self, q):
        """Oracle (pure jnp) attention over the same physical state."""
        self.repack()
        q = jnp.asarray(q)
        if q.ndim == 2:
            q = q[None]
        n = self._active_bucket()
        decode = (kops.decode_attention_ref_batched
                  if self.packing == "pair"
                  else kops.decode_attention_quad_ref_batched)
        return decode(q, self._kernel_cache(n),
                      self.valid_per_page()[:, : self.group_lanes * n])

    def saving(self) -> float:
        """Cumulative decode-bandwidth saving, read from the ledger (the
        "kv" consumer's read rows: raw layout bytes vs CRAM bytes)."""
        return self.ledger.saving("read", consumer="kv")
