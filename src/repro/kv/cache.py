"""CRAM-KV: paged serving cache with marker-packed page pairs.

The serving-side embodiment of the paper (DESIGN.md §3): logical KV pages
pack pairwise into physical slots when BDI-compressible (kernels/bdi_pack),
interpretation is by in-band marker (kernels/cram_attention), a
last-compressibility predictor (the LLP analog, indexed by page-pair)
decides whether the overflow slot needs to be fetched at all, and a
Dynamic-CRAM counter turns packing off when the data never compresses.

Bandwidth accounting (per decode step):
  raw        : one slot DMA per live page
  CRAM       : one slot DMA per packed PAIR (2 pages), plus the strip;
               unpacked pairs cost two slots; mispredicted pairs cost a
               second access (the paper's LLP-miss re-probe)
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..core.dynamic import COUNTER_INIT, COUNTER_MAX, ENABLE_THRESHOLD
from ..kernels import ops as kops


@dataclass
class KVStats:
    raw_bytes: int = 0
    cram_bytes: int = 0
    packed_pairs: int = 0
    raw_pairs: int = 0
    predictor_hits: int = 0
    predictor_misses: int = 0
    pack_attempts: int = 0
    pack_skipped_dynamic: int = 0


class CRAMKVCache:
    """Single-sequence paged KV cache (batch = one cache per sequence)."""

    def __init__(self, max_pages: int, page: int, n_kv: int, head_dim: int,
                 *, policy: str = "dynamic", key: int = 0x5EED):
        assert max_pages % 2 == 0
        self.page, self.n_kv, self.d = page, n_kv, head_dim
        self.d2 = 2 * head_dim
        self.max_pages = max_pages
        self.pages = np.zeros((max_pages, page, n_kv, self.d2), np.int16)
        self.tokens = 0
        self.policy = policy
        self.key = key
        self.counter = COUNTER_INIT
        self.predictor = np.zeros(max_pages // 2, bool)  # last packability
        self.stats = KVStats()
        self._cache = None
        self._dirty = True

    # ----------------------------------------------------------- appends
    def append(self, k, v):
        """k/v: (T, n_kv, d) bf16 new tokens."""
        k = np.asarray(jnp.asarray(k, jnp.bfloat16).view(jnp.int16))
        v = np.asarray(jnp.asarray(v, jnp.bfloat16).view(jnp.int16))
        T = k.shape[0]
        kv = np.concatenate([k, v], axis=-1)          # (T, n_kv, d2)
        for t in range(T):
            p, o = divmod(self.tokens, self.page)
            assert p < self.max_pages, "cache full"
            self.pages[p, o] = kv[t]
            self.tokens += 1
        self._dirty = True

    @property
    def n_pages(self) -> int:
        return (self.tokens + self.page - 1) // self.page

    def valid_per_page(self) -> np.ndarray:
        full, rem = divmod(self.tokens, self.page)
        v = np.zeros(2 * ((self.n_pages + 1) // 2), np.int32)
        v[:full] = self.page
        if rem:
            v[full] = rem
        return v

    # ------------------------------------------------------------- packing
    def _compression_enabled(self) -> bool:
        if self.policy == "off":
            return False
        if self.policy == "static":
            return True
        return self.counter >= ENABLE_THRESHOLD

    def repack(self):
        """(Re)build the physical view; called when pages changed."""
        n = 2 * ((self.n_pages + 1) // 2)
        pages = jnp.asarray(self.pages[:n])
        self.stats.pack_attempts += n // 2
        if self._compression_enabled():
            cache = kops.build_cram_cache(pages, key=self.key)
        else:
            self.stats.pack_skipped_dynamic += n // 2
            cache = kops.build_cram_cache(pages, key=self.key)
            cache["packed_mask"] = jnp.zeros_like(cache["packed_mask"])
            cache["slots"] = pages[0::2]
            cache["slots_overflow"] = pages[1::2]
            cache["strips"] = jnp.zeros_like(cache["strips"])
        self._cache = cache
        self._dirty = False

        ok = np.asarray(cache["packed_mask"])
        # predictor bookkeeping (LLP analog: last observed packability)
        hits = int((self.predictor[: len(ok)] == ok).sum())
        self.stats.predictor_hits += hits
        self.stats.predictor_misses += len(ok) - hits
        # dynamic counter: benefit = packed pairs (halved DMA), cost =
        # pack work for pairs that failed
        if self.policy == "dynamic":
            self.counter = int(np.clip(
                self.counter + int(ok.sum()) - int((~ok).sum()),
                0, COUNTER_MAX))
        self.predictor[: len(ok)] = ok
        self.stats.packed_pairs += int(ok.sum())
        self.stats.raw_pairs += int((~ok).sum())

    # -------------------------------------------------------------- attend
    def attend(self, q):
        """q: (B, Hq, d) -> (B, Hq, d) float32 + bandwidth accounting."""
        if self._dirty:
            self.repack()
        valid = jnp.asarray(self.valid_per_page())
        out = kops.decode_attention(jnp.asarray(q), self._cache, valid)
        bw = kops.hbm_bytes_moved(self._cache, valid)
        self.stats.raw_bytes += bw["raw_bytes"]
        self.stats.cram_bytes += bw["cram_bytes"]
        return out

    def attend_ref(self, q):
        if self._dirty:
            self.repack()
        valid = jnp.asarray(self.valid_per_page())
        return kops.decode_attention_ref(jnp.asarray(q), self._cache, valid)

    def saving(self) -> float:
        return 1.0 - self.stats.cram_bytes / max(self.stats.raw_bytes, 1)
