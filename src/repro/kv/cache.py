"""CRAM-KV: batched paged serving cache with marker-packed page groups.

The serving-side embodiment of the paper (DESIGN.md §3): logical KV pages
pack groupwise into physical slots when delta-compressible, interpretation
is by in-band marker (kernels/cram_attention), a last-compressibility
predictor (the LLP analog, indexed by page group — compression.predictor's
`observe_layout` rule) decides whether the overflow slots need to be
fetched at all, and a per-sequence Dynamic-CRAM counter
(compression.gate, §VI) turns packing off when the data never compresses —
while *still sampling pack fitness on repacked groups*, so it can
re-enable when compressible traffic returns.

Two registry-provided packing layouts (compression.layouts):
  * packing="pair" — KV_PAIR: 2 pages per group, int8-delta codec (2:1);
  * packing="quad" — KV_QUAD: 4 pages per group, int4-delta codec (4:1),
    quad-domain markers (a slot's pair marker can never alias its quad
    marker).

Cache state is a JAX pytree with a batch axis (B sequences x page groups):
`append` is a vectorized token scatter (no per-token host loop), and
`repack` is incremental — a dirty-group mask tracks the page groups touched
since the last pack, so a decode step re-packs O(new groups) instead of
rebuilding every group (the old per-step full build made decode O(T^2) in
sequence length).  The incremental state is bit-identical to a from-scratch
`kernels/ops.build_cram_cache[_quad]` rebuild under the gate applied at the
last repack (`reference_rebuild` is the oracle; tests/test_kv_cache.py pins
it).

Bandwidth accounting (per decode step):
  raw        : one slot DMA per live page
  CRAM       : one slot DMA per packed GROUP (2 or 4 pages), plus the
               strip; unpacked groups cost one slot + strip per live page;
               mispredicted groups cost a second slot access (the paper's
               LLP-miss re-probe)

The accounting is DEVICE-RESIDENT: the decode kernel emits the (raw,
cram) bytes for the layout it walked as a second output
(kernels/cram_attention), and every per-step tally — byte totals,
repack write traffic, predictor hit/miss counts — lands in int32
accumulators carried in the cache pytree (`traffic` is a
bandwidth.device_totals array; `pred_hits`/`pred_misses`/`packed_n`/
`raw_n` are counters).  Nothing crosses to the host per step; a window
fold (`sync_ledger`, called by `saving()` and the serve-loop report
boundaries) absorbs the accumulator into the host `Ledger` with O(1)
`Ledger.record` calls, and the `stats` property reads the counters back
on demand.  So an N-step decode run costs O(1) host syncs, not O(N).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..bandwidth import Ledger
from ..bandwidth.adapters import (kv_read_device, kv_repack_device,
                                  kv_window_fold)
from ..bandwidth.ledger import device_totals
from ..compression.framing import DEFAULT_MARKER_KEY, DOMAIN_PAIR, DOMAIN_QUAD
from ..compression.gate import COUNTER_INIT, COUNTER_MAX, ENABLE_THRESHOLD
from ..compression.predictor import observe_layout
from ..kernels import ops as kops
from ..kernels.ref import MARKER_LANES, marker_to_lanes, slot_markers


@dataclass
class KVStats:
    """Pack/predictor event counters.  Byte accounting is NOT here: every
    byte a decode step or repack moves lands in the cache's `ledger`
    (repro.bandwidth), under consumer "kv".

    Snapshot semantics: `CRAMKVCache.stats` builds one of these on read.
    The layout/predictor tallies (packed/raw groups, predictor hits and
    misses) accumulate in device counters inside the cache pytree and are
    synced back only here; the dispatch-shape counters (pack_attempts,
    pack_calls, …) are plain host ints — they count python-level repack
    dispatches, not device work."""

    packed_pairs: int = 0
    raw_pairs: int = 0
    predictor_hits: int = 0
    predictor_misses: int = 0
    pack_attempts: int = 0
    pack_skipped_dynamic: int = 0
    pack_calls: int = 0
    pack_pairs_processed: int = 0  # sequences x groups run through repack


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_tokens(pages, kv, start):
    """pages (B, Tmax, Hkv, D2) <- kv (B, T, Hkv, D2) at token `start`."""
    return jax.lax.dynamic_update_slice(pages, kv, (0, start, 0, 0))


@functools.partial(jax.jit, static_argnames=("lanes", "slot_bytes",
                                             "strip_bytes"))
def _book_repack_device(traffic, packed_n, raw_n, lay, *, lanes,
                        slot_bytes, strip_bytes):
    """Device-side repack booking.  The byte model lives in
    `adapters.kv_repack_device` (consumers never add byte counts — the
    ledger contract, enforced by analysis rule R5); this wrapper only
    carries the cache's packed/raw layout counters."""
    groups = lay.size
    traffic, lay_n = kv_repack_device(traffic, lay, lanes=lanes,
                                      slot_bytes=slot_bytes,
                                      strip_bytes=strip_bytes)
    return traffic, packed_n + lay_n, raw_n + (groups - lay_n)


@functools.partial(jax.jit, static_argnames=("lanes", "n"))
def _absorb_step_device(traffic, hits, misses, predictor, packed_mask,
                        valid, raw_seq, cram_seq, *, lanes, n):
    """Device-side decode-step booking: fold the kernel's per-sequence
    (raw, cram) bytes into the traffic accumulator as ONE read event,
    tally LLP hits/misses on live groups, and emit the next predictor
    state (last-layout observation, copied so it survives the donated
    repack scatter)."""
    pm = packed_mask[:, :n]
    pred = predictor[:, :n]
    live = valid.reshape(pm.shape[0], n, lanes).sum(-1) > 0
    mis = pred != pm
    hits = hits + ((~mis) & live).sum(1).astype(jnp.int32)
    misses = misses + (mis & live).sum(1).astype(jnp.int32)
    traffic = kv_read_device(traffic, raw_seq, cram_seq)
    return traffic, hits, misses, observe_layout(packed_mask)


def kernel_cache_slice(state: dict, n: int) -> dict:
    """The decode-kernel view of a cache state pytree, restricted to the
    first `n` page groups — the shape every fused consumer (attend,
    byte accounting, `SlotKVCache._megastep`) feeds the kernels.  Pure
    slicing: safe inside jit and on host state alike."""
    return {"slots": state["slots"][:, :n],
            "slots_overflow": state["slots_overflow"][:, :n],
            "strips": state["strips"][:, :n],
            "packed_mask": state["packed_mask"][:, :n],
            "markers": state["markers"][:n]}


@functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3))
def _scatter_window(slots, over, strips, mask, idx, slots_w, over_w,
                    strips_w, lay):
    """One fused, donated update of the physical state at group `idx` —
    the per-step write stays O(new groups), no five-way full-buffer copy."""
    return (slots.at[:, idx].set(slots_w),
            over.at[:, idx].set(over_w),
            strips.at[:, idx].set(strips_w),
            mask.at[:, idx].set(lay))


class CRAMKVCache:
    """Batched paged KV cache: B sequences, uniform token counts."""

    def __init__(self, max_pages: int, page: int, n_kv: int, head_dim: int,
                 *, batch: int = 1, policy: str = "dynamic",
                 packing: str = "pair", key: int = DEFAULT_MARKER_KEY,
                 counter_init: int = COUNTER_INIT,
                 interpret: bool | None = None,
                 ledger: Ledger | None = None):
        # "auto": the AutoTuner picked the packing (see `CRAMKVCache.auto`);
        # at runtime it is the §VI dynamic gate over that layout.
        assert policy in ("dynamic", "static", "off", "auto")
        assert packing in ("pair", "quad")
        self.packing = packing
        self.group_lanes = 2 if packing == "pair" else 4
        # capacity rounds UP to a whole number of page groups: callers ask
        # for the pages they need, the layout owns its own granularity
        max_pages = -(-max_pages // self.group_lanes) * self.group_lanes
        self.page, self.n_kv, self.d = page, n_kv, head_dim
        self.d2 = 2 * head_dim
        self.max_pages = max_pages
        self.n_groups = max_pages // self.group_lanes
        self.batch = batch
        self.policy = policy
        self.key = key
        self.interpret = (kops.default_interpret() if interpret is None
                          else interpret)
        self.tokens = 0
        domain = DOMAIN_PAIR if packing == "pair" else DOMAIN_QUAD
        markers = slot_markers(self.n_groups, key, domain=domain)
        self._marker_lanes = jnp.asarray(marker_to_lanes(markers))
        b, n, p = batch, self.n_groups, page
        over_shape = ((b, n, p, n_kv, self.d2) if packing == "pair"
                      else (b, n, self.group_lanes - 1, p, n_kv, self.d2))
        self.state = {
            "pages": jnp.zeros((b, max_pages * p, n_kv, self.d2), jnp.int16),
            "slots": jnp.zeros((b, n, p, n_kv, self.d2), jnp.int16),
            "slots_overflow": jnp.zeros(over_shape, jnp.int16),
            "strips": jnp.zeros((b, n, n_kv, self.d2 + MARKER_LANES),
                                jnp.int16),
            "packed_mask": jnp.zeros((b, n), bool),
            "predictor": jnp.zeros((b, n), bool),
            "counter": jnp.full((b,), counter_init, jnp.int32),
            "markers": jnp.asarray(markers.view(np.int32)),
            # device-resident accounting: decode/repack traffic window
            # (folded into the host ledger by `sync_ledger`) and the
            # layout/predictor tallies behind the `stats` property
            "traffic": device_totals(jnp),
            "pred_hits": jnp.zeros((b,), jnp.int32),
            "pred_misses": jnp.zeros((b,), jnp.int32),
            "packed_n": jnp.zeros((), jnp.int32),
            "raw_n": jnp.zeros((), jnp.int32),
        }
        # dirty-group mask: appends are uniform across the batch, so one
        # host-side mask covers every sequence; per-sequence gate flips
        # mark the whole active prefix dirty (rare — full re-layout).
        self._dirty = np.zeros(self.n_groups, bool)
        # groups with data not yet fed to the §VI counter: a gate flip
        # re-dirties the layout but must NOT re-count historical fitness
        # (that would re-apply the whole prefix's fit/unfit balance and
        # could slam the counter straight back across the threshold).
        self._uncounted = np.zeros(self.n_groups, bool)
        self._last_enabled = np.full(batch, policy != "off", bool)
        self._host_stats = KVStats()
        # traffic lands here (consumer "kv"); pass a shared ledger to fold
        # this cache's flows into a launcher-wide accounting
        self.ledger = ledger if ledger is not None else Ledger("kv")
        self.slot_bytes = page * n_kv * self.d2 * 2
        self.strip_bytes = n_kv * (self.d2 + MARKER_LANES) * 2

    @classmethod
    def auto(cls, tuner, k_sample, v_sample, *, max_pages: int, page: int,
             n_kv: int, head_dim: int, **kw):
        """`policy="auto"`: let an `bandwidth.AutoTuner` pick the packing
        layout (off / pair / quad) from a sample of the KV stream, then run
        the §VI dynamic gate over the chosen layout.  Returns (cache,
        PolicyChoice)."""
        d2 = 2 * head_dim
        choice = tuner.choose_kv_packing(
            k=k_sample, v=v_sample, page=page,
            slot_bytes=page * n_kv * d2 * 2,
            strip_bytes=n_kv * (d2 + MARKER_LANES) * 2)
        if choice.choice == "off":
            cache = cls(max_pages, page, n_kv, head_dim,
                        policy="off", packing="pair", **kw)
        else:
            cache = cls(max_pages, page, n_kv, head_dim, policy="auto",
                        packing=choice.choice, **kw)
        return cache, choice

    # legacy pair-era aliases (the default packing is the 2:1 pair layout)
    @property
    def n_pairs(self) -> int:
        return self.n_groups

    @property
    def host_stats(self) -> KVStats:
        """The host dispatch counters ALONE (pack_attempts, pack_calls,
        pack_pairs_processed, …) — NO device sync.  Timed loops that only
        need the python-level repack tallies read this instead of `stats`,
        which pulls four device counters back per access (analysis R3:
        no host syncs inside timed regions)."""
        return self._host_stats

    @property
    def stats(self) -> KVStats:
        """Snapshot of the event counters: host dispatch counters merged
        with the device tallies (the only place those sync back)."""
        from dataclasses import replace

        st = self.state
        return replace(
            self._host_stats,
            packed_pairs=int(st["packed_n"]),
            raw_pairs=int(st["raw_n"]),
            predictor_hits=int(jnp.sum(st["pred_hits"])),
            predictor_misses=int(jnp.sum(st["pred_misses"])))

    def sync_ledger(self) -> None:
        """Window fold: absorb the device traffic accumulator into the
        host ledger (O(1) `Ledger.record` calls however many decode steps
        the window covered), then reset it.  int32 bounds one window at
        2 GiB per event class — report boundaries (`saving`, the serve
        loop's `observe_tiers`/`summary`) fold well before that."""
        tot = np.asarray(self.state["traffic"])
        if tot.any():
            kv_window_fold(self.ledger, tot)
            self.state["traffic"] = device_totals(jnp)

    # ----------------------------------------------------------- appends
    def append(self, k, v):
        """k/v: (B, T, n_kv, d) — or (T, n_kv, d) when batch == 1 — new
        tokens, any float dtype (stored as bf16 bit patterns)."""
        k = jnp.asarray(k, jnp.bfloat16).view(jnp.int16)
        v = jnp.asarray(v, jnp.bfloat16).view(jnp.int16)
        if k.ndim == 3:
            assert self.batch == 1, "batched cache needs (B, T, n_kv, d)"
            k, v = k[None], v[None]
        kv = jnp.concatenate([k, v], axis=-1)        # (B, T, n_kv, d2)
        bsz, t = kv.shape[:2]
        assert bsz == self.batch
        assert self.tokens + t <= self.max_pages * self.page, "cache full"
        self.state["pages"] = _scatter_tokens(
            self.state["pages"], kv, self.tokens)
        span = self.group_lanes * self.page           # tokens per group
        lo = self.tokens // span
        hi = (self.tokens + t - 1) // span
        self._dirty[lo:hi + 1] = True
        self._uncounted[lo:hi + 1] = True
        self.tokens += t

    @property
    def n_pages(self) -> int:
        return (self.tokens + self.page - 1) // self.page

    @property
    def n_active_groups(self) -> int:
        return -(-self.n_pages // self.group_lanes)

    @property
    def n_active_pairs(self) -> int:
        return self.n_active_groups

    def valid_per_page(self) -> np.ndarray:
        """(B, max_pages) int32 valid tokens per logical page."""
        v = np.clip(self.tokens - np.arange(self.max_pages) * self.page,
                    0, self.page).astype(np.int32)
        return np.broadcast_to(v, (self.batch, self.max_pages)).copy()

    def pages_view(self):
        """Logical pages (B, max_pages, page, n_kv, d2)."""
        return self.state["pages"].reshape(
            self.batch, self.max_pages, self.page, self.n_kv, self.d2)

    # ------------------------------------------------------------- packing
    def enabled(self) -> np.ndarray:
        """(B,) bool: per-sequence compression gate (counter MSB, §VI)."""
        if self.policy == "off":
            return np.zeros(self.batch, bool)
        if self.policy == "static":
            return np.ones(self.batch, bool)
        return np.asarray(self.state["counter"]) >= ENABLE_THRESHOLD

    def _pack_window(self, win, idx_j, enabled):
        """Dispatch the dirty window to the layout's pack/raw kernels.

        win: (B, W, lanes, page, n_kv, d2) gathered dirty groups."""
        return kops.layout_window(win, self._marker_lanes[idx_j],
                                  jnp.asarray(enabled),
                                  use_pack=self.policy != "off",
                                  interpret=self.interpret)

    def _book_repack(self, w: int, enabled, lay) -> None:
        """Host dispatch counters + device byte/layout booking for one
        repack window (shared with SlotKVCache.repack)."""
        hs = self._host_stats
        if self.policy == "off":
            hs.pack_skipped_dynamic += self.batch * w
        else:
            hs.pack_attempts += self.batch * w
            hs.pack_skipped_dynamic += int((~enabled).sum()) * w
        hs.pack_calls += 1
        hs.pack_pairs_processed += self.batch * w
        st = self.state
        st["traffic"], st["packed_n"], st["raw_n"] = _book_repack_device(
            st["traffic"], st["packed_n"], st["raw_n"], lay,
            lanes=self.group_lanes, slot_bytes=self.slot_bytes,
            strip_bytes=self.strip_bytes)

    def repack(self):
        """Incrementally re-pack the dirty groups.

        Idempotency cheap-exit: a clean cache returns before touching any
        device state, so back-to-back repacks (attend -> account_step on
        the same decode step) dispatch the pack pipeline exactly once."""
        idx = np.nonzero(self._dirty)[0]
        if idx.size == 0:
            return
        w = int(idx.size)
        enabled = self.enabled()
        idx_j = jnp.asarray(idx, jnp.int32)
        groups = self.pages_view().reshape(
            self.batch, self.n_groups, self.group_lanes, self.page,
            self.n_kv, self.d2)
        win = groups[:, idx_j]                # (B, W, lanes, page, ...)
        slots_w, over_w, strips_w, lay, fit = self._pack_window(
            win, idx_j, enabled)
        st = self.state
        (st["slots"], st["slots_overflow"], st["strips"],
         st["packed_mask"]) = _scatter_window(
            st["slots"], st["slots_overflow"], st["strips"],
            st["packed_mask"], idx_j, slots_w, over_w, strips_w, lay)
        self._book_repack(w, enabled, lay)
        # §VI cost/benefit: fitness of *complete, not-yet-counted* repacked
        # groups drives the per-sequence counter — measured even while
        # disabled (the zeroed layout mask no longer feeds the update), so
        # the gate can re-enable once compressible traffic returns.  Each
        # group is counted exactly once, when it completes: gate-flip
        # re-dirt re-lays groups out but never re-counts their fitness.
        complete = (idx + 1) * self.group_lanes * self.page <= self.tokens
        if self.policy in ("dynamic", "auto"):
            countable = jnp.asarray(complete & self._uncounted[idx])
            fit_n = (fit & countable[None, :]).sum(1)
            unfit_n = ((~fit) & countable[None, :]).sum(1)
            st["counter"] = jnp.clip(
                st["counter"] + (fit_n - unfit_n).astype(jnp.int32),
                0, COUNTER_MAX)
        self._uncounted[idx[complete]] = False
        self._dirty[:] = False
        self._last_enabled = enabled
        flipped = self.enabled() != enabled
        if flipped.any():
            # gate changed for some sequence: its whole layout must be
            # rebuilt under the new gate at the next repack (keeps the
            # incremental state equal to a full rebuild).
            self._dirty[: self.n_active_groups] = True

    def reference_rebuild(self) -> dict:
        """From-scratch full pack of the active groups, per sequence, under
        the gate applied at the last repack — the bit-exactness oracle for
        the incremental path (compare with `active_state`)."""
        lanes = self.group_lanes
        n2 = lanes * self.n_active_groups
        pages = self.pages_view()[:, :n2]
        build = (kops.build_cram_cache if self.packing == "pair"
                 else kops.build_cram_cache_quad)
        out = []
        for bi in range(self.batch):
            if self._last_enabled[bi]:
                c = build(pages[bi], key=self.key, interpret=self.interpret)
            else:
                n = n2 // lanes
                grouped = pages[bi].reshape(
                    n, lanes, self.page, self.n_kv, self.d2)
                over = (grouped[:, 1] if self.packing == "pair"
                        else grouped[:, 1:])
                c = {
                    "slots": grouped[:, 0],
                    "slots_overflow": over,
                    "strips": jnp.zeros(
                        (n, self.n_kv, self.d2 + MARKER_LANES), jnp.int16),
                    "markers": self.state["markers"][:n],
                    "packed_mask": jnp.zeros((n,), bool),
                }
            out.append(c)
        keys = ("slots", "slots_overflow", "strips", "packed_mask")
        ref = {k: jnp.stack([c[k] for c in out]) for k in keys}
        ref["markers"] = self.state["markers"][: n2 // lanes]
        return ref

    def active_state(self) -> dict:
        """The physical cache restricted to the active group prefix."""
        return self._kernel_cache(self.n_active_groups)

    # -------------------------------------------------------------- attend
    def _active_bucket(self) -> int:
        """Active group count rounded up to a power of two: the decode grid
        walks O(sequence) slots, not O(capacity), while the pow2 bucketing
        bounds retraces to log2(capacity) shapes as the sequence grows."""
        n = max(1, self.n_active_groups)
        return min(1 << (n - 1).bit_length(), self.n_groups)

    def _kernel_cache(self, n: int) -> dict:
        return kernel_cache_slice(self.state, n)

    def account_step(self) -> dict:
        """One decode step's bandwidth accounting + LLP predictor update.

        Charges the CRAM byte model (incl. the mispredict re-probe against
        the group-indexed predictor), tallies predictor hits/misses on live
        groups, then lets the predictor observe the actual layout.  All of
        it lands in the device accumulators — no host ledger traffic until
        the next `sync_ledger` window fold.
        """
        self.repack()
        return self._account()

    def _absorb_step(self, raw_seq, cram_seq, valid, n: int) -> dict:
        """Fold one decode step's per-sequence byte columns + predictor
        observation into the device accumulators (one fused dispatch)."""
        st = self.state
        (st["traffic"], st["pred_hits"], st["pred_misses"],
         st["predictor"]) = _absorb_step_device(
            st["traffic"], st["pred_hits"], st["pred_misses"],
            st["predictor"], st["packed_mask"], valid, raw_seq, cram_seq,
            lanes=self.group_lanes, n=n)
        raw_t, cram_t = raw_seq.sum(), cram_seq.sum()
        return {"raw_bytes": raw_t, "cram_bytes": cram_t,
                "raw_per_seq": raw_seq, "cram_per_seq": cram_seq,
                "saving": 1.0 - cram_t / jnp.maximum(raw_t, 1)}

    def _account(self) -> dict:
        st = self.state
        lanes = self.group_lanes
        n = self._active_bucket()
        valid = jnp.asarray(self.valid_per_page()[:, : lanes * n])
        raw_seq, cram_seq = kops.hbm_bytes_moved_device(
            self._kernel_cache(n), valid,
            predictor=st["predictor"][:, :n], lanes=lanes)
        return self._absorb_step(raw_seq, cram_seq, valid, n)

    def attend(self, q, *, account: bool = True):
        """q: (B, Hq, d) one query row per sequence -> (B, Hq, d) float32,
        with per-step bandwidth accounting (`account=False` for parity
        probes that must not charge an extra step).

        One pass over the physical state: the fused kernel walks the slot
        list once and emits the step's byte columns alongside the
        attention output, so accounting adds no second traversal."""
        self.repack()
        q = jnp.asarray(q)
        if q.ndim == 2:
            q = q[None]
        n = self._active_bucket()
        st = self.state
        valid = jnp.asarray(
            self.valid_per_page()[:, : self.group_lanes * n])
        out, raw_seq, cram_seq = kops.decode_attention_fused(
            q, self._kernel_cache(n), valid,
            st["predictor"][:, :n] if account else None,
            lanes=self.group_lanes, interpret=self.interpret)
        if account:
            self._absorb_step(raw_seq, cram_seq, valid, n)
        return out

    def attend_ref(self, q):
        """Oracle (pure jnp) attention over the same physical state."""
        self.repack()
        q = jnp.asarray(q)
        if q.ndim == 2:
            q = q[None]
        n = self._active_bucket()
        decode = (kops.decode_attention_ref_batched
                  if self.packing == "pair"
                  else kops.decode_attention_quad_ref_batched)
        return decode(q, self._kernel_cache(n),
                      self.valid_per_page()[:, : self.group_lanes * n])

    def saving(self) -> float:
        """Cumulative decode-bandwidth saving, read from the ledger (the
        "kv" consumer's read rows: raw layout bytes vs CRAM bytes).  Folds
        the pending device window first, so the number is current."""
        self.sync_ledger()
        return self.ledger.saving("read", consumer="kv")
