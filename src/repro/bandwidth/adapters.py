"""Adapter views: each legacy consumer's counters expressed as ledger rows.

The golden contracts (engine `engine_stats.json`, KV `reference_rebuild`,
checkpoint manifests) are pinned to the consumers' existing counter
definitions, so the consumers keep producing those numbers — but the
*accounting* (what is raw, what is compressed, what category a byte
belongs to) lives here, once.  A consumer module itself never adds byte
counts; it calls one of these adapters (tests/test_bandwidth.py pins
adapter totals == legacy counters).
"""

from __future__ import annotations

import numpy as np

from ..compression.framing import LINE_BYTES
from .ledger import (EV_PROBE, EV_READ, EV_REPACK, EV_SPILL, EV_WRITE,
                     Ledger, device_record)

# ---------------------------------------------------------------- trace engine


def engine_traffic(stats: dict, *, consumer: str = "engine") -> Ledger:
    """Ledger view of one engine run's STAT counters (DESIGN.md §4 names).

    Every access is one 64-byte line.  Category mapping:
      read   — demand fetches (`demand_reads`)
      probe  — extra LLP probes (`read_probes - demand_reads`) on data
               lines; metadata-cache fills/writebacks on the "metadata"
               tensor class
      write  — dirty writebacks on "lines"; clean writebacks + invalidate
               line writes on "lines-clean" (split so the Fig. 8/15
               breakdown's data vs wbclean+inv categories are derivable
               from ledger rows alone — see `engine_breakdown`)
      spill  — next-line prefetch extra accesses (`pf_extra_access`)

    Invariant (pinned by tests, and holding for EVERY call — no summary
    rows that would double-count untagged queries): total ledger bytes ==
    `SimResult.accesses * LINE_BYTES`.  A scheme-vs-baseline comparison
    is a property of two runs, not of one run's traffic; the workload
    summaries carry it as `accesses`/`speedup`.
    """
    led = Ledger(consumer)
    L = LINE_BYTES

    def put(event, count, tensor_class):
        if count:
            led.record(event, raw=count * L, compressed=count * L,
                       count=count, tensor_class=tensor_class)

    put(EV_READ, stats["demand_reads"], "lines")
    put(EV_PROBE, stats["read_probes"] - stats["demand_reads"], "lines")
    put(EV_WRITE, stats["wb_dirty"], "lines")
    put(EV_WRITE, stats["wb_clean"] + stats["il_writes"], "lines-clean")
    put("spill", stats["pf_extra_access"], "lines")
    put(EV_READ, stats["meta_reads"], "metadata")
    put(EV_WRITE, stats["meta_wb"], "metadata")
    return led


def engine_breakdown(traffic: dict, *, consumer: str = "engine") -> dict:
    """Fig. 8/15 access categories re-derived from `engine_traffic` ledger
    rows, in line counts — so figures and the policy layer consume ONE
    view of the engine's byte economy instead of parallel private
    counters.  `traffic` is the `Ledger.as_dict()` form the workload
    summaries embed ("traffic"); equality with the legacy
    `SimResult.bandwidth_breakdown` counters is pinned by
    tests/test_benchmarks.py."""
    rows = traffic.get(consumer, {})

    def cnt(tensor_class, event):
        return rows.get(tensor_class, {}).get(event, {}).get("count", 0)

    return {
        "data": cnt("lines", "read") + cnt("lines", "write"),
        "metadata": cnt("metadata", "read") + cnt("metadata", "write"),
        "mispredict": cnt("lines", "probe"),
        "wbclean+inv": cnt("lines-clean", "write"),
        "prefetch": cnt("lines", "spill"),
        "total": sum(v["count"] for events in rows.values()
                     for v in events.values()),
    }


# ------------------------------------------------------------------- KV cache


def kv_decode_event(ledger: Ledger, bw: dict, *,
                    tensor_class: str = "kv") -> None:
    """One decode step's DMA traffic (a `kernels/ops.hbm_bytes_moved`
    result) as a read event: raw = uncompressed layout bytes, compressed =
    CRAM layout bytes including strip overhead and LLP-miss re-probes."""
    ledger.record(EV_READ, raw=bw["raw_bytes"], compressed=bw["cram_bytes"],
                  tensor_class=tensor_class, consumer="kv")


def kv_window_fold(ledger: Ledger, totals, *,
                   tensor_class: str = "kv") -> None:
    """Fold one decode window's DEVICE accumulator (bandwidth.device_totals
    carried in the KV cache pytree) into the host ledger under consumer
    "kv" — the batched form of `kv_decode_event`/`kv_repack_event`: the
    kernel-measured read bytes and the repack write bytes of every step in
    the window land as the same rows the per-step host path would have
    booked, in O(1) `Ledger.record` calls."""
    ledger.absorb(totals, tensor_class=tensor_class, consumer="kv")


def kv_repack_event(ledger: Ledger, *, groups: int, packed: int, lanes: int,
                    slot_bytes: int, strip_bytes: int,
                    tensor_class: str = "kv") -> None:
    """Write traffic of (re)packing `groups` page groups, `packed` of which
    fit: a packed group writes one slot + strip, an unpacked group writes
    its `lanes` pages raw.  Raw baseline: every page written raw."""
    raw = groups * lanes * slot_bytes
    comp = (packed * (slot_bytes + strip_bytes)
            + (groups - packed) * lanes * slot_bytes)
    ledger.record(EV_REPACK, raw=raw, compressed=comp, count=groups,
                  tensor_class=tensor_class, consumer="kv")


def kv_repack_device(traffic, lay, *, lanes: int, slot_bytes: int,
                     strip_bytes: int):
    """Device-side form of `kv_repack_event`: the SAME byte model (raw =
    every page written raw; a packed group writes slot + strip, an unpacked
    group its `lanes` pages raw), accumulated into a
    `bandwidth.device_totals` array instead of a host record.  Traceable —
    consumers call it from inside their jitted step/repack wrappers so no
    byte math (and no host sync) lives outside this module.  Returns the
    updated accumulator and the packed-group count (traced int32)."""
    groups = lay.size
    lay_n = lay.sum().astype("int32")
    raw = groups * lanes * slot_bytes
    comp = (lay_n * (slot_bytes + strip_bytes)
            + (groups - lay_n) * (lanes * slot_bytes))
    return device_record(traffic, EV_REPACK, raw, comp, count=groups), lay_n


def kv_read_device(traffic, raw_seq, cram_seq):
    """Device-side form of `kv_decode_event`: fold one decode step's
    per-sequence (raw, cram) byte duals — the fused kernel's second output —
    into the accumulator as ONE read event.  Traceable; see
    `kv_repack_device`."""
    return device_record(traffic, EV_READ, raw_seq.sum(), cram_seq.sum(),
                         count=1)


def kv_spill_event(ledger: Ledger, *, raw: int, compressed: int,
                   direction: str = "evict",
                   tensor_class: str | None = None) -> tuple[int, int]:
    """One sequence crossing the HBM<->host spill link still compressed
    (serving.SpillStore): raw = what evicting the decompressed KV pages
    would have moved, compressed = the packed payload bytes that actually
    crossed.  Exactly ONE spill event per evict and per restore (pinned by
    tests/test_bandwidth.py); `direction` tags the tensor class so the two
    flows stay separately queryable under consumer "kv"."""
    assert direction in ("evict", "restore"), direction
    return ledger.record(EV_SPILL, raw=raw, compressed=compressed, count=1,
                         tensor_class=tensor_class or f"kv-{direction}",
                         consumer="kv")


# ----------------------------------------------------------------- checkpoint


def classify_tensor(key: str, dtype=None) -> str:
    """Coarse tensor-class taxonomy for per-class policy decisions."""
    k = key.lower()
    if any(s in k for s in ("moment", "adam", "opt_state", "ema", "/mu",
                            "/nu")):
        return "moments"
    if "grad" in k:
        return "grads"
    if any(s in k for s in ("scale", "bias", "norm")):
        return "norms"
    return "weights"


def checkpoint_leaf_event(ledger: Ledger, *, key: str, raw_len: int,
                          stored_len: int, dtype=None) -> tuple[int, int]:
    """Book one checkpoint leaf's write; returns the (raw, stored) byte
    pair the manifest entry stores (read back from the ledger booking so
    the manifest and the ledger can never disagree)."""
    return ledger.record(EV_WRITE, raw=raw_len, compressed=stored_len,
                         tensor_class=classify_tensor(key, dtype))


def checkpoint_restore_event(ledger: Ledger, *, key: str, raw_len: int,
                             stored_len: int, dtype=None) -> None:
    ledger.record(EV_READ, raw=raw_len, compressed=stored_len,
                  tensor_class=classify_tensor(key, dtype))


# ----------------------------------------------------- gradient collective


def tree_wire_bytes(tree) -> int:
    """Raw wire bytes of an uncompressed gradient all-reduce (one traversal
    of the tree's leaves; dtype-true)."""
    import jax

    return sum(int(np.prod(x.shape)) * np.dtype(x.dtype).itemsize
               for x in jax.tree.leaves(tree))


def int8_wire_bytes(tree) -> int:
    """Wire bytes of the int8 per-tensor quantized collective: one byte per
    element plus a 4-byte fp32 scale per leaf."""
    import jax

    return sum(int(np.prod(x.shape)) + 4 for x in jax.tree.leaves(tree))


def grad_wire_event(ledger: Ledger, tree, *, enabled: bool,
                    steps: int = 1, tensor_class: str = "grads") -> None:
    """Book `steps` collective rounds: raw = uncompressed wire bytes,
    compressed = int8 bytes when the gate was enabled, raw otherwise."""
    raw = tree_wire_bytes(tree) * steps
    comp = (int8_wire_bytes(tree) if enabled else tree_wire_bytes(tree))
    ledger.record(EV_WRITE, raw=raw, compressed=comp * steps, count=steps,
                  tensor_class=tensor_class, consumer="grad")


__all__ = [
    "engine_traffic", "engine_breakdown",
    "kv_decode_event", "kv_repack_event", "kv_spill_event",
    "kv_window_fold", "kv_repack_device", "kv_read_device",
    "classify_tensor", "checkpoint_leaf_event", "checkpoint_restore_event",
    "tree_wire_bytes", "int8_wire_bytes", "grad_wire_event",
]
