"""THE traffic ledger: one accounting of every byte the system moves.

CRAM's evaluation is an economy of memory accesses per category (§VI and
the Fig. 8/15 breakdowns): compression is enabled or disabled by weighing
the bandwidth cost of storing compressed lines against the benefit of
fetching them.  Before this module, five consumers each kept a private
version of that economy (engine STAT counters, `kernels/ops` byte dicts,
`kv/cache.saving()`, checkpoint `raw_bytes/stored_bytes` manifests, the
gradient collective's inline wire-byte constants).  The ledger is the one
place those flows land:

  event    — what moved: read / write / probe / repack / spill
  consumer — who moved it: "engine", "kv", "checkpoint", "grad", "serve"…
  tensor_class — what kind of data: "kv", "weights", "moments", "grads"…

Every row accumulates (raw_bytes, compressed_bytes, count): raw is what an
uncompressed system would have moved for the same work, compressed is what
actually moved — so `saving()` is the paper's bandwidth win and a negative
saving is the §VI cost signal the AutoTuner gates on.

Two accumulation paths:

  * host path — `Ledger.record(...)`: plain-int accumulation, used by the
    non-jitted consumers (checkpoint writer, serve loop, KV step boundary).
  * device path — `device_totals()` / `device_record(...)`: a jit-safe
    (N_EVENTS, 3) int32 array that lives inside a jitted step (pytree
    leaf, scan carry, shard_map output) and is folded into the host ledger
    afterwards with `Ledger.absorb(...)`.  int32 bounds one absorb window
    at 2 GiB per event class; long-running consumers fold their window at
    report boundaries well inside that bound (e.g. the KV cache's
    `sync_ledger`: N decode steps accumulate on device and land in the
    host ledger as O(1) record calls), so the host-side totals (python
    ints) never overflow.
"""

from __future__ import annotations

import numpy as np

# traffic event kinds (stable ids: the device accumulator indexes by them)
EV_READ, EV_WRITE, EV_PROBE, EV_REPACK, EV_SPILL, N_EVENTS = range(6)
EVENT_NAMES = ("read", "write", "probe", "repack", "spill")
_EVENT_BY_NAME = {n: i for i, n in enumerate(EVENT_NAMES)}


def event_id(event) -> int:
    """Accept an EV_* id or an event name; return the stable id."""
    if isinstance(event, str):
        try:
            return _EVENT_BY_NAME[event]
        except KeyError:
            raise KeyError(f"unknown traffic event {event!r}; "
                           f"valid: {EVENT_NAMES}") from None
    e = int(event)
    if not 0 <= e < N_EVENTS:
        raise KeyError(f"event id {e} out of range 0..{N_EVENTS - 1}")
    return e


class Ledger:
    """Host-side traffic accumulator keyed by (consumer, tensor_class, event).

    Rows are created on first record; values are python ints (no overflow).
    A ledger can carry a default consumer so call sites inside one
    subsystem stay terse (`ledger.record(EV_READ, raw=..., compressed=...)`).
    """

    __slots__ = ("consumer", "_rows")

    def __init__(self, consumer: str = "anon"):
        self.consumer = consumer
        # (consumer, tensor_class, event_id) -> [raw, compressed, count]
        self._rows: dict[tuple[str, str, int], list[int]] = {}

    # ------------------------------------------------------------ recording
    def record(self, event, *, raw, compressed=None, count: int = 1,
               tensor_class: str = "default",
               consumer: str | None = None) -> tuple[int, int]:
        """Record one traffic flow; returns the (raw, compressed) ints it
        booked, so call sites that need the numbers (e.g. checkpoint
        manifests) read them back from the ledger rather than re-deriving
        them."""
        e = event_id(event)
        raw_i = int(raw)
        comp_i = raw_i if compressed is None else int(compressed)
        key = (consumer or self.consumer, tensor_class, e)
        row = self._rows.get(key)
        if row is None:
            row = self._rows[key] = [0, 0, 0]
        row[0] += raw_i
        row[1] += comp_i
        row[2] += int(count)
        return raw_i, comp_i

    def absorb(self, totals, *, tensor_class: str = "default",
               consumer: str | None = None) -> None:
        """Fold a device accumulator (see `device_totals`) into this ledger."""
        t = np.asarray(totals)
        assert t.shape == (N_EVENTS, 3), t.shape
        for e in range(N_EVENTS):
            raw, comp, cnt = (int(t[e, 0]), int(t[e, 1]), int(t[e, 2]))
            if raw or comp or cnt:
                self.record(e, raw=raw, compressed=comp, count=cnt,
                            tensor_class=tensor_class, consumer=consumer)

    def merge(self, other: "Ledger") -> "Ledger":
        """Add every row of `other` into this ledger (consumers preserved)."""
        for (cons, tc, e), (raw, comp, cnt) in other._rows.items():
            self.record(e, raw=raw, compressed=comp, count=cnt,
                        tensor_class=tc, consumer=cons)
        return self

    # -------------------------------------------------------------- queries
    def _select(self, event=None, consumer=None, tensor_class=None):
        e = None if event is None else event_id(event)
        for (cons, tc, ev), row in self._rows.items():
            if e is not None and ev != e:
                continue
            if consumer is not None and cons != consumer:
                continue
            if tensor_class is not None and tc != tensor_class:
                continue
            yield (cons, tc, ev), row

    def total(self, event=None, *, consumer=None,
              tensor_class=None) -> dict:
        raw = comp = cnt = 0
        for _, (r, c, n) in self._select(event, consumer, tensor_class):
            raw += r
            comp += c
            cnt += n
        return {"raw_bytes": raw, "compressed_bytes": comp, "count": cnt}

    def raw_bytes(self, event=None, **kw) -> int:
        return self.total(event, **kw)["raw_bytes"]

    def compressed_bytes(self, event=None, **kw) -> int:
        return self.total(event, **kw)["compressed_bytes"]

    def saving(self, event=None, **kw) -> float:
        """1 - compressed/raw over the selected rows (the paper's bandwidth
        win; negative when compression *cost* bytes — the §VI signal)."""
        t = self.total(event, **kw)
        return 1.0 - t["compressed_bytes"] / max(t["raw_bytes"], 1)

    def consumers(self) -> tuple[str, ...]:
        return tuple(sorted({c for c, _, _ in self._rows}))

    def tensor_classes(self, consumer=None) -> tuple[str, ...]:
        return tuple(sorted({tc for c, tc, _ in self._rows
                             if consumer is None or c == consumer}))

    def as_dict(self) -> dict:
        """{consumer: {tensor_class: {event: {raw, compressed, count}}}} —
        the JSON view benchmark reports embed."""
        out: dict = {}
        for (cons, tc, e), (raw, comp, cnt) in sorted(self._rows.items()):
            out.setdefault(cons, {}).setdefault(tc, {})[EVENT_NAMES[e]] = {
                "raw_bytes": raw, "compressed_bytes": comp, "count": cnt,
            }
        return out

    def __len__(self) -> int:
        return len(self._rows)

    def __repr__(self) -> str:
        t = self.total()
        return (f"Ledger({self.consumer!r}, rows={len(self._rows)}, "
                f"raw={t['raw_bytes']}, compressed={t['compressed_bytes']})")


# --------------------------------------------------------- device accumulator

def device_totals(xp=None):
    """A fresh jit-safe accumulator: (N_EVENTS, 3) int32 zeros of
    [raw_bytes, compressed_bytes, count] — a plain array, so it is a valid
    pytree leaf for scan carries / shard_map outputs / donated buffers."""
    if xp is None:
        import jax.numpy as xp
    return xp.zeros((N_EVENTS, 3), xp.int32)


def device_record(totals, event, raw, compressed=None, count=1):
    """Functional update of a device accumulator (usable under jit/vmap).

    `event` must be a static EV_* id (it indexes the row); raw/compressed/
    count may be traced scalars."""
    import jax.numpy as jnp

    e = event_id(event)
    comp = raw if compressed is None else compressed
    delta = jnp.stack([jnp.asarray(raw, jnp.int32),
                       jnp.asarray(comp, jnp.int32),
                       jnp.asarray(count, jnp.int32)])
    return totals.at[e].add(delta)


__all__ = [
    "EV_READ", "EV_WRITE", "EV_PROBE", "EV_REPACK", "EV_SPILL", "N_EVENTS",
    "EVENT_NAMES", "event_id", "Ledger", "device_totals", "device_record",
]
