"""Registry-driven autotune policy layer — THE §VI gate, generalized.

Dynamic-CRAM (§VI) enables or disables one mechanism (line compression) by
weighing measured bandwidth benefit against measured cost in a saturating
counter.  The AutoTuner generalizes that decision rule across the whole
registry: given ledger telemetry and/or the `--sweep codecs`
ratio/throughput tables, it selects

  * the KV packing layout per stream  — "off" | "pair" | "quad",
  * the checkpoint line codec per tensor class — "raw" | "bdi" | "fpc"
    | "hybrid" (any registered line64 codec),
  * the gradient-collective page codec — "off" | "int8",

each exposed as `policy="auto"` on the corresponding consumer (KV cache,
checkpoint writer, grad collective) and swept by
`benchmarks/run.py --sweep policy`.

Decision rule (the paper's "no slowdown" guarantee, Fig. 18): a candidate
is chosen only when its *expected* bytes-per-access beat the uncompressed
baseline by at least `margin`; ties and losses fall back to "off"/"raw".
On top of the expectation model, `observe(ledger)` runs the literal §VI
saturating counter per decision key over *measured* savings, so a consumer
whose live traffic stops compressing gets gated off even if the static
tables said otherwise — and can re-enable when compressible traffic
returns, exactly like the hardware gate.

Everything here is deterministic: the same telemetry produces the same
choices (tests/test_bandwidth.py pins a golden decision table).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..compression import codecs as _codecs
from ..compression.framing import LINE_BYTES
from ..compression.gate import (
    COUNTER_INIT,
    ENABLE_THRESHOLD,
    counter_enabled,
    counter_step,
)
from .ledger import Ledger

KV_PACKINGS = ("off", "pair", "quad")
# page codec backing each packing choice (registry names)
KV_PAGE_CODEC = {"pair": "int8-delta", "quad": "int4-delta"}
# ledger-gate scaling: one observation window is worth this many counter
# ticks (the trace engine ticks per sampled event; the tuner ticks per
# absorbed telemetry window, so a handful of bad windows flips the MSB)
OBSERVE_TICKS = 256


@dataclass(frozen=True)
class PolicyChoice:
    """One autotune decision with its evidence, JSON-ready."""

    target: str                    # "kv" | "checkpoint" | "grad"
    choice: str                    # selected registry entry / packing
    expected: dict = field(default_factory=dict)   # candidate -> bytes/unit
    basis: str = "tables"          # "tables" | "probe" | "ledger"
    preferred: str = ""            # the model pick BEFORE §VI gate
                                   # suppression (== choice unless the
                                   # gate forced "off") — a live re-enable
                                   # migrates to this, not to a default

    def as_dict(self) -> dict:
        return {"target": self.target, "choice": self.choice,
                "expected": dict(self.expected), "basis": self.basis,
                "preferred": self.preferred}


def kv_expected_bytes_per_page(fit_rate: float, lanes: int,
                               slot_bytes: float = 1.0,
                               strip_bytes: float | None = None) -> float:
    """Expected decode bytes per page under a packing layout, in the
    `kernels/ops.hbm_bytes_moved` model: a packed group costs one slot +
    strip for all `lanes` pages; an unpacked group costs slot + strip per
    page (the in-band metadata overhead).  Baseline ("off") is exactly
    `slot_bytes` per page."""
    if strip_bytes is None:
        strip_bytes = slot_bytes / 8.0   # strip ~ one row of a page-8 slot
    packed_group = slot_bytes + strip_bytes
    raw_group = lanes * (slot_bytes + strip_bytes)
    return (fit_rate * packed_group + (1.0 - fit_rate) * raw_group) / lanes


def kv_spill_bytes_per_page(fit_rate: float, lanes: int,
                            slot_bytes: float = 1.0,
                            page: int | None = None) -> float:
    """Expected bytes per page crossing the HBM<->host spill link per
    evict/restore, mirroring the actual `serving.SpillStore` payload: a
    fitting group moves one packed slot plus its BASE ROW — one token row,
    `slot_bytes / page`, NOT a full strip; the spill payload carries no
    in-band metadata — and an unfitting group moves its pages raw with no
    strip either.  Baseline ("off") spills every page raw: exactly
    `slot_bytes` per page.  `page` sizes the base-row term (default 8).

    Two deliberate approximations, both conservative toward packing: raw
    groups are charged all `lanes` pages although the store trims dead
    tail lanes (only LIVE lanes cross, so a short sequence's raw groups
    are cheaper than modeled), and the per-group fit bit is ignored
    (1 byte vs KiB-scale slots).

    The absent strip terms are why the two tiers genuinely diverge: at
    mid fit rates, packing can LOSE on the hot decode path (strips on
    every resident group, `kv_expected_bytes_per_page`) while still
    winning on the spill link."""
    base_bytes = slot_bytes / (page if page else 8)
    packed_group = slot_bytes + base_bytes
    raw_group = lanes * slot_bytes
    return (fit_rate * packed_group + (1.0 - fit_rate) * raw_group) / lanes


def probe_kv_fit_rates(k, v, *, page: int, max_groups: int = 64) -> dict:
    """Measure pair/quad pack-fit rates on a sample KV stream.

    k/v: (B, T, Hkv, D) float arrays (or (T, Hkv, D)); the same bf16
    bit-pattern view the cache stores.  Returns {"pair": r, "quad": r}.
    """
    k = np.asarray(k, np.float32)
    v = np.asarray(v, np.float32)
    if k.ndim == 3:
        k, v = k[None], v[None]
    kv = np.concatenate([k, v], axis=-1)
    bf16 = np.ascontiguousarray(
        (kv.view("<u4") >> 16).astype("<u2")).view("<i2")
    b, t = bf16.shape[:2]
    n_pages = t // page
    # group pages PER SEQUENCE, exactly as the cache lays them out — a
    # flat view would form probe groups spanning sequence boundaries
    # (different per-sequence bases) and under-report the fit
    pages = bf16[:, : n_pages * page].reshape(
        b, n_pages, page, *bf16.shape[2:])
    rates = {}
    for packing, lanes in (("pair", 2), ("quad", 4)):
        codec = _codecs.get_codec(KV_PAGE_CODEC[packing])
        fits = []
        for bi in range(b):
            for gi in range(n_pages // lanes):
                if len(fits) >= max_groups:
                    break
                grp = pages[bi, gi * lanes:(gi + 1) * lanes]
                ok, _, _ = codec.pack_pages(*grp, xp=np)
                fits.append(bool(ok))
        rates[packing] = float(np.mean(fits)) if fits else 0.0
    return rates


class AutoTuner:
    """Policy engine over the codec/layout registry (module docstring)."""

    def __init__(self, *, tables: dict | None = None, margin: float = 0.02,
                 counter_init: int = COUNTER_INIT):
        self.tables = tables or {}
        self.margin = float(margin)
        self._counter_init = int(counter_init)
        self._counters: dict[str, int] = {}   # §VI counter per decision key
        # per-key ledger snapshot: observe() judges the traffic since the
        # LAST observation, not the ledger's lifetime totals (a long-lived
        # ledger would otherwise dilute a regime change into invisibility)
        self._last_totals: dict[str, tuple[int, int]] = {}

    @classmethod
    def from_codec_sweep(cls, report: dict, **kw) -> "AutoTuner":
        """Build from a `--sweep codecs` report (or its "codecs" section)."""
        return cls(tables=report.get("codecs", report), **kw)

    # ------------------------------------------------ §VI ledger-driven gate
    def observe(self, ledger: Ledger, *, key: str, consumer=None,
                tensor_class=None, event=None) -> int:
        """Run one saturating-counter step for `key` from the traffic
        recorded since the previous observe() of that key (the observation
        window): benefit when the window saved at least `margin`, cost
        when compression *cost* bytes (negative saving).  An empty window
        leaves the counter untouched.  Returns the counter."""
        t = ledger.total(event, consumer=consumer, tensor_class=tensor_class)
        raw, comp = t["raw_bytes"], t["compressed_bytes"]
        last_raw, last_comp = self._last_totals.get(key, (0, 0))
        self._last_totals[key] = (raw, comp)
        raw_d, comp_d = raw - last_raw, comp - last_comp
        c = self._counters.get(key, self._counter_init)
        if raw_d <= 0:
            self._counters[key] = c
            return c
        saving = 1.0 - comp_d / raw_d
        benefit = OBSERVE_TICKS if saving >= self.margin else 0
        cost = OBSERVE_TICKS if saving < 0.0 else 0
        c = int(counter_step(np.int64(c), cost, benefit, np))
        self._counters[key] = c
        return c

    def gate_enabled(self, key: str) -> bool:
        """Counter MSB for a decision key (enabled until proven harmful)."""
        return bool(counter_enabled(
            self._counters.get(key, self._counter_init)))

    def counter(self, key: str) -> int:
        return self._counters.get(key, self._counter_init)

    # --------------------------------------------------------- KV packing
    def choose_kv_packing(self, fit_rates: dict | None = None, *,
                          k=None, v=None, page: int | None = None,
                          slot_bytes: float = 1.0,
                          strip_bytes: float | None = None,
                          stream: str | None = None,
                          tier: str = "hot",
                          gate_key: str | None = None) -> PolicyChoice:
        """Pick off/pair/quad from fit rates (given, probed from a k/v
        sample, or read from the codec-sweep kv_pages tables).

        `tier` makes packing a per-tier policy axis: "hot" judges
        candidates under the decode DMA model (`kv_expected_bytes_per_page`
        — strips on every resident group), "spill" under the spill-link
        model (`kv_spill_bytes_per_page` — a base row, no strip, on packed
        groups only; `page` sizes that base-row term), and each tier
        carries its own §VI ledger gate key ("kv" vs "kv-spill") so
        observe() windows are judged per tier."""
        assert tier in ("hot", "spill"), tier
        if gate_key is None:
            gate_key = "kv" if tier == "hot" else "kv-spill"
        basis = "tables"
        if fit_rates is None and k is not None:
            assert page is not None, "probe needs the page size"
            fit_rates = probe_kv_fit_rates(k, v, page=page)
            basis = "probe"
        if fit_rates is None:
            row = self.tables.get("kv_pages", {}).get(stream or "", {})
            fit_rates = {
                p: float(row.get(KV_PAGE_CODEC[p], {}).get("fit_rate", 0.0))
                for p in ("pair", "quad")
            }
        expected = {"off": float(slot_bytes)}
        for packing, lanes in (("pair", 2), ("quad", 4)):
            fr = float(fit_rates.get(packing, 0.0))
            expected[packing] = (
                kv_expected_bytes_per_page(fr, lanes, slot_bytes,
                                           strip_bytes)
                if tier == "hot" else
                kv_spill_bytes_per_page(fr, lanes, slot_bytes, page))
        choice = min(expected, key=lambda p: (expected[p],
                                              KV_PACKINGS.index(p)))
        # no-slowdown guarantee: a packing must beat "off" by the margin
        if expected[choice] > expected["off"] * (1.0 - self.margin):
            choice = "off"
        # `preferred` is the model's pick; a disabled §VI gate (measured
        # harm) suppresses it to "off" in `choice` — recording both lets a
        # later live re-enable migrate to the pick instead of a default
        preferred = choice
        if not self.gate_enabled(gate_key):
            choice = "off"
        target = "kv" if tier == "hot" else "kv-spill"
        return PolicyChoice(target, choice, expected, basis, preferred)

    # --------------------------------------------------- checkpoint codec
    def choose_ckpt_codec(self, sample_lines=None, *,
                          tensor_class: str | None = None,
                          max_lines: int = 4096,
                          gate_key: str = "checkpoint") -> PolicyChoice:
        """Pick the line codec whose measured mean compressed size over a
        sample of the tensor's 64-byte lines is smallest; "raw" unless the
        winner beats raw by the margin.  With no sample, falls back to the
        codec-sweep `tensors` ratio table for the tensor class."""
        names = [n for n in _codecs.codec_names("line64")]
        if sample_lines is not None:
            lines = np.asarray(sample_lines, np.uint8).reshape(-1, LINE_BYTES)
            if lines.shape[0] > max_lines:
                stride = lines.shape[0] // max_lines
                lines = lines[::stride][:max_lines]
            expected = {
                n: float(np.asarray(
                    _codecs.get_codec(n).sizes(lines)).mean())
                for n in names
            }
            basis = "probe"
        else:
            row = self.tables.get("tensors", {}).get(tensor_class or "", {})
            expected = {
                n: LINE_BYTES / float(row[n]) if n in row else
                float(LINE_BYTES)
                for n in names
            }
            basis = "tables"
        choice = min(expected, key=lambda n: (expected[n], names.index(n)))
        if (expected[choice] > expected["raw"] * (1.0 - self.margin)
                or not self.gate_enabled(gate_key)):
            choice = "raw"
        return PolicyChoice("checkpoint", choice, expected, basis)

    # ------------------------------------------------------- grad codec
    def choose_grad_codec(self, rel_err: float, *,
                          err_budget: float = 0.05,
                          bytes_saving: float = 0.75,
                          gate_key: str = "grad") -> PolicyChoice:
        """int8 collective iff the measured relative quantization error is
        within budget (the runtime gate then keeps watching, §VI)."""
        expected = {"off": 1.0, "int8": 1.0 - float(bytes_saving)}
        ok = (float(rel_err) <= float(err_budget)
              and self.gate_enabled(gate_key))
        return PolicyChoice("grad", "int8" if ok else "off", expected,
                            "probe")

    # ----------------------------------------------------------- combined
    def choose(self, telemetry: dict) -> dict:
        """Full policy from a telemetry dict.  Recognized keys:
        kv_fit_rates | (kv_sample_k, kv_sample_v, page); ckpt_samples
        ({tensor_class: lines}); grad_rel_err.  Deterministic: the golden
        autotuner test pins `choose(t) == choose(t)` and exact choices."""
        out: dict = {}
        if "kv_fit_rates" in telemetry:
            out["kv"] = self.choose_kv_packing(telemetry["kv_fit_rates"])
        elif "kv_sample_k" in telemetry:
            out["kv"] = self.choose_kv_packing(
                k=telemetry["kv_sample_k"], v=telemetry["kv_sample_v"],
                page=telemetry["page"])
        for tc, lines in telemetry.get("ckpt_samples", {}).items():
            out[f"checkpoint:{tc}"] = self.choose_ckpt_codec(
                lines, tensor_class=tc)
        if "grad_rel_err" in telemetry:
            out["grad"] = self.choose_grad_codec(telemetry["grad_rel_err"])
        return out


__all__ = [
    "AutoTuner", "PolicyChoice", "KV_PACKINGS", "KV_PAGE_CODEC",
    "kv_expected_bytes_per_page", "kv_spill_bytes_per_page",
    "probe_kv_fit_rates",
    "COUNTER_INIT", "ENABLE_THRESHOLD",
]
