"""repro.bandwidth — THE traffic-accounting and policy subsystem.

  ledger   — typed traffic events (read/write/probe/repack/spill, raw vs
             compressed bytes, per consumer and tensor class) with a host
             accumulator and a jit-safe device accumulator
  adapters — each consumer's legacy counters expressed as ledger rows
             (engine STATs, KV decode/repack, checkpoint manifests,
             gradient wire bytes); the only place consumer byte math lives
  autotune — the §VI saturating-counter gate generalized into a policy
             engine: picks KV packing, checkpoint codec, and grad codec
             from ledger telemetry + `--sweep codecs` tables, exposed as
             `policy="auto"` across the consumers

See DESIGN.md §8.
"""

from .adapters import (
    checkpoint_leaf_event,
    checkpoint_restore_event,
    classify_tensor,
    engine_breakdown,
    engine_traffic,
    grad_wire_event,
    int8_wire_bytes,
    kv_decode_event,
    kv_repack_event,
    kv_spill_event,
    tree_wire_bytes,
)
from .autotune import (
    KV_PACKINGS,
    AutoTuner,
    PolicyChoice,
    kv_expected_bytes_per_page,
    kv_spill_bytes_per_page,
    probe_kv_fit_rates,
)
from .ledger import (
    EV_PROBE,
    EV_READ,
    EV_REPACK,
    EV_SPILL,
    EV_WRITE,
    EVENT_NAMES,
    N_EVENTS,
    Ledger,
    device_record,
    device_totals,
    event_id,
)

__all__ = [
    "Ledger", "device_totals", "device_record", "event_id",
    "EV_READ", "EV_WRITE", "EV_PROBE", "EV_REPACK", "EV_SPILL",
    "N_EVENTS", "EVENT_NAMES",
    "engine_traffic", "engine_breakdown",
    "kv_decode_event", "kv_repack_event", "kv_spill_event",
    "classify_tensor", "checkpoint_leaf_event", "checkpoint_restore_event",
    "tree_wire_bytes", "int8_wire_bytes", "grad_wire_event",
    "AutoTuner", "PolicyChoice", "KV_PACKINGS",
    "kv_expected_bytes_per_page", "kv_spill_bytes_per_page",
    "probe_kv_fit_rates",
]
