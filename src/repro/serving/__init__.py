"""repro.serving — continuous-batching serve tier with compressed KV spill.

  slots — SlotKVCache: the batched CRAM-KV cache with per-slot sequence
          lifetimes (heterogeneous token counts, slot reset/reuse)
  spill — SpillStore: host-memory tier holding cold sequences still
          compressed under its own packing axis; bit-exact resurrection
  shard — shard_map'd decode-attend over the slot axis (single-device
          fallback, bit-identical)
  loop  — ServeLoop: SequenceSlot scheduler (admit / step / retire /
          evict / wake) + per-tier AutoTuner observation windows

See DESIGN.md §9.
"""

from .loop import SequenceSlot, ServeLoop
from .shard import shard_kv_attend
from .slots import SlotKVCache
from .spill import SPILL_LANES, SpilledSeq, SpillStore

__all__ = [
    "ServeLoop", "SequenceSlot", "SlotKVCache",
    "SpillStore", "SpilledSeq", "SPILL_LANES",
    "shard_kv_attend",
]
