"""Incremental live migration of a serving SlotKVCache (DESIGN.md §12).

CRAM §VI turns compression on and off *while the memory system keeps
serving traffic*.  This module is the serving embodiment: when the
hot-tier gate flips (AutoTuner observation window, forced override) or
the tuner picks a different packing layout mid-serve, the live cache
converges to the new layout **incrementally** — a bounded budget of
page-group columns per decode step — instead of a stop-the-world
rebuild.

The machinery is deliberately derivational, not stateful:

  * `cache._gate_b` (B,) is the per-slot TARGET gate, frozen between
    observation boundaries (`refresh_gate`) so the fused decode step
    never host-syncs the §VI counter;
  * `cache._applied_b` (B, n_groups) records the gate each group's
    physical layout was last laid under (written by every repack);
  * a group is *pending migration* iff it is inside its slot's active
    prefix and `applied != target` — there is no pending mask to keep
    consistent, so interleaved appends, evicts and wakes can never
    drift it.

`quantum(cache, budget)` marks at most `budget` pending group COLUMNS
dirty; the normal incremental repack then re-lays them under the target
gate in the same fused window dispatch as the step's append — migration
rides the existing dirty-mask machinery (PR 3) and is bit-identical to
a from-scratch rebuild at every intermediate step, because the decode
kernel already reads packed vs raw per group from the in-band marker.
`migrated_upto` exposes the per-slot watermark (leading groups already
at the target layout) that tests and reports read.

Packing changes (pair <-> quad) are STRUCTURAL: group geometry, marker
domain and physical shapes all change.  `switch_packing` rebuilds the
raw layout directly from the packing-independent logical `pages` buffer
in one jitted dispatch (booked as repack write traffic), re-allocates
the geometry-dependent state, and leaves every active group
`applied=False` — the same budgeted quanta then promote the cache to
the new packed layout without ever blocking a step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..bandwidth.adapters import kv_repack_device
from ..compression.framing import DOMAIN_PAIR, DOMAIN_QUAD
from ..kernels.ref import MARKER_LANES, marker_to_lanes, slot_markers


@functools.partial(jax.jit, static_argnames=("lanes", "page", "slot_bytes",
                                             "strip_bytes"))
def _raw_relayout(pages, lay0, traffic, *, lanes, page, slot_bytes,
                  strip_bytes):
    """Build the RAW physical layout of a new group geometry straight from
    the logical pages (one dispatch — the structural half of a packing
    switch), booking the active groups' raw re-lay as repack write
    traffic.  `lay0` is a zeros((active_groups,), bool) mask: nothing is
    packed yet; the budgeted migration quanta do the promotion."""
    b, t_max, hkv, d2 = pages.shape
    n = t_max // (lanes * page)
    grouped = pages.reshape(b, n, lanes, page, hkv, d2)
    slots = grouped[:, :, 0]
    over = grouped[:, :, 1] if lanes == 2 else grouped[:, :, 1:]
    strips = jnp.zeros((b, n, hkv, d2 + MARKER_LANES), jnp.int16)
    mask = jnp.zeros((b, n), bool)
    traffic, _ = kv_repack_device(traffic, lay0, lanes=lanes,
                                  slot_bytes=slot_bytes,
                                  strip_bytes=strip_bytes)
    return slots, over, strips, mask, traffic


def active_groups(cache) -> np.ndarray:
    """(B,) int: page-group count of each slot's own active prefix."""
    pages_b = -(-cache.tokens_b // cache.page)
    return (-(-pages_b // cache.group_lanes)).astype(np.int64)


def pending_mask(cache) -> np.ndarray:
    """(B, n_groups) bool: groups whose layout was laid under a gate that
    differs from the slot's target — DERIVED from `_applied_b` vs
    `_gate_b`, never stored, so it cannot drift."""
    g_b = active_groups(cache)
    active = np.arange(cache.n_groups)[None, :] < g_b[:, None]
    return active & (cache._applied_b != cache._gate_b[:, None])


def migrated_upto(cache, slot: int) -> int:
    """Per-slot migration watermark: leading group count already laid
    under the slot's target gate (== slot_groups(slot) when settled)."""
    pend = pending_mask(cache)[slot]
    nz = np.flatnonzero(pend)
    return int(nz[0]) if nz.size else int(active_groups(cache)[slot])


def quantum(cache, budget: int) -> int:
    """Mark at most `budget` pending group COLUMNS dirty; the next repack
    (or the fused megastep this rides inside) re-lays them under the
    target gate.  Returns the number of columns claimed — the per-step
    migration work is bounded, so a step never stalls on a flip."""
    if budget <= 0:
        return 0
    pend = pending_mask(cache)
    cols = np.flatnonzero(pend.any(0))[:budget]
    if cols.size:
        cache._dirty_b[:, cols] = True
    return int(cols.size)


def drain(cache, slot: int | None = None) -> int:
    """Settle migration now (evict capture, tests): mark every pending
    column — of one slot, or all — dirty and repack.  Returns the column
    count drained."""
    pend = pending_mask(cache)
    if slot is not None:
        only = np.zeros_like(pend)
        only[slot] = pend[slot]
        pend = only
    cols = np.flatnonzero(pend.any(0))
    if cols.size:
        cache._dirty_b[:, cols] = True
        # settle under the FROZEN target — drain converges to the current
        # gate, it is not an observation boundary
        cache.repack(gate=cache._gate_b)
    return int(cols.size)


def status(cache) -> dict:
    """Migration progress snapshot (serve-loop summaries, benchmarks)."""
    pend = pending_mask(cache)
    return {
        "migrating": bool(pend.any()),
        "pending_groups": int(pend.sum()),
        "pending_columns": int(pend.any(0).sum()),
        "watermarks": [migrated_upto(cache, b) for b in range(cache.batch)],
    }


def switch_packing(cache, packing: str) -> None:
    """Structurally re-geometry the live cache to a new packing layout.

    The logical `pages` buffer is packing-shape-independent, so the swap
    builds the raw layout of the NEW geometry from it in one jitted
    dispatch — no data loss, no pack kernel.  Every active group comes
    out `applied=False`; with the target gate on, they are all pending,
    and the budgeted quanta promote them to packed over the following
    steps (mixed packed/raw is exactly what the in-band-marker kernel
    reads).  §VI bookkeeping: the per-slot counter survives (it is the
    gate's memory, independent of geometry); the predictor and the
    uncounted-fitness mask are geometry-indexed and reset — history is
    not re-counted."""
    assert packing in ("pair", "quad"), packing
    if packing == cache.packing:
        return
    lanes = 2 if packing == "pair" else 4
    assert cache.max_pages % lanes == 0, (
        f"max_pages={cache.max_pages} not divisible by {lanes}-lane groups"
        " — SlotKVCache rounds capacity to 4 pages so both layouts fit")
    b, n_groups = cache.batch, cache.max_pages // lanes
    lay0 = jnp.zeros((int(active_groups(cache).sum()),), bool)
    st = cache.state
    slots, over, strips, mask, traffic = _raw_relayout(
        st["pages"], lay0, st["traffic"], lanes=lanes, page=cache.page,
        slot_bytes=cache.slot_bytes, strip_bytes=cache.strip_bytes)
    domain = DOMAIN_PAIR if packing == "pair" else DOMAIN_QUAD
    markers = slot_markers(n_groups, cache.key, domain=domain)
    cache.packing = packing
    cache.group_lanes = lanes
    cache.n_groups = n_groups
    cache._marker_lanes = jnp.asarray(marker_to_lanes(markers))
    st["slots"], st["slots_overflow"], st["strips"] = slots, over, strips
    st["packed_mask"], st["traffic"] = mask, traffic
    st["markers"] = jnp.asarray(markers.view(np.int32))
    st["predictor"] = jnp.zeros((b, n_groups), bool)
    cache._dirty_b = np.zeros((b, n_groups), bool)
    cache._uncounted_b = np.zeros((b, n_groups), bool)
    cache._applied_b = np.zeros((b, n_groups), bool)
    cache._last_enabled = np.zeros(b, bool)
    # base-class 1-D masks: unused by SlotKVCache but kept shape-true
    cache._dirty = np.zeros(n_groups, bool)
    cache._uncounted = np.zeros(n_groups, bool)


__all__ = ["active_groups", "pending_mask", "migrated_upto", "quantum",
           "drain", "status", "switch_packing"]
