"""Sharded decode-attend: shard_map over the KV batch (slot) axis.

The workload-axis pattern `core.batchsim` proved for the trace engine,
applied to serving: sequence slots are independent, so the batched decode
kernel partitions cleanly across local devices with no collectives — each
device walks its shard of (slots, overflow, strips, masks, valid) with
the full marker table replicated.  Falls back to the single-device
dispatch when only one device is present or the slot count doesn't
divide; both paths are bit-identical (tests/test_serving.py runs the
forced-2-device subprocess parity check).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops


def shard_kv_attend(cache, q, *, shard: "bool | str" = "auto",
                    devices=None):
    """One batched decode-attend over `cache` (a CRAMKVCache or
    SlotKVCache), optionally sharded over the slot axis.

    q: (B, Hq, d) one query row per slot.  Returns (B, Hq, d) float32.
    No bandwidth accounting here — callers charge the step explicitly
    (ServeLoop.attend / account_step)."""
    cache.repack()
    q = jnp.asarray(q)
    if q.ndim == 2:
        q = q[None]
    lanes = cache.group_lanes
    n = cache._active_bucket()
    kc = cache._kernel_cache(n)
    valid = jnp.asarray(cache.valid_per_page()[:, : lanes * n])
    decode = (kops.decode_attention_batched if cache.packing == "pair"
              else kops.decode_attention_quad_batched)
    devs = list(devices if devices is not None else jax.devices())
    n_dev = len(devs)
    b = q.shape[0]
    want = shard is True or (shard == "auto" and n_dev > 1)
    if not want or n_dev <= 1 or b % n_dev:
        return decode(q, kc, valid, interpret=cache.interpret)

    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P

    mesh = Mesh(np.asarray(devs), ("kv",))
    markers = kc["markers"]      # replicated (closed over, shared table)
    interpret = cache.interpret

    def one_shard(qq, slots, over, strips, mask, vv):
        c = {"slots": slots, "slots_overflow": over, "strips": strips,
             "packed_mask": mask, "markers": markers}
        return decode(qq, c, vv, interpret=interpret)

    fn = shard_map(one_shard, mesh=mesh,
                   in_specs=(P("kv"), P("kv"), P("kv"), P("kv"), P("kv"),
                             P("kv")),
                   out_specs=P("kv"), check_rep=False)  # pallas_call has
    # no replication rule; every spec is explicit so nothing is inferred
    return fn(q, kc["slots"], kc["slots_overflow"], kc["strips"],
              kc["packed_mask"], valid)


__all__ = ["shard_kv_attend"]
