"""ServeLoop: continuous batching over slot-reused KV lanes + spill tier.

The production serve loop (DESIGN.md §9, §12): a fixed pool of `slots`
batch lanes in one `SlotKVCache`, a `SequenceSlot` record per live
sequence, and a compressed `SpillStore` behind them.

  admit   — take the lowest free slot (evicting the coldest active
            sequence to the spill tier when none is free) and prefill it;
  step    — one fused decode append for every sequence named this step
            (spilled ones are woken first; wake evictions never pick a
            step-named sequence).  The default `fused=True` path runs the
            whole step — append scatter, window repack carrying the
            migration quantum, §VI counter update, byte booking — as ONE
            donated jitted `megastep` with zero host syncs; `fused=False`
            keeps the legacy append/repack/account dispatch sequence.
            One fused step carries at most `slots` sequences; `step_all`
            chunks an oversubscribed batch into waves and prefetches the
            later waves' spill payload decodes behind the current wave;
  attend  — one batched decode-attend over the whole slot axis (inactive
            lanes are masked by their zero valid counts), optionally
            sharded across devices (`serving.shard`);
  retire  — reset the lane and hand it to the next admit: the batch axis
            NEVER grows, slots are reused (tests pin this);
  evict / wake — explicit spill-tier crossings, each booking exactly one
            ledger `spill` event with compressed duals.  With the default
            `async_spill=True` the evict-side re-encode runs on a
            background worker and books at collection (`sync_ledger`
            flushes), so the crossing never serializes in front of a
            decode step.

Per-tier autotuning: `ServeLoop.auto` asks one `AutoTuner` for the hot
packing (decode DMA model, gate key "kv-hot") and the spill packing
(spill-link model, gate key "kv-spill") from the same KV sample, and
`observe_tiers()` feeds each tier's §VI counter from its own ledger rows
— hot from "read" traffic, spill from "spill" traffic — so a tier whose
live traffic stops compressing is gated off independently.  The gate
decision is LIVE: when an observation window re-enables a hot gate that
had suppressed the tuner's packing pick, `observe_tiers` migrates the
running cache to that recorded pick (`PolicyChoice.preferred`) via
`migrate_to` — incrementally, `migrate_budget` page-group columns per
decode step, never blocking a step (see `serving.migrate`).
"""

from __future__ import annotations

from bisect import insort
from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from ..bandwidth import AutoTuner, Ledger
from ..compression.framing import DEFAULT_MARKER_KEY
from ..compression.gate import COUNTER_INIT
from ..kernels.ref import MARKER_LANES
from .shard import shard_kv_attend
from .slots import SlotKVCache
from .spill import SpillStore


@dataclass
class SequenceSlot:
    """One live sequence's scheduling record."""

    seq_id: int
    slot: int                  # batch-lane index; -1 while spilled
    admitted_at: int
    last_step: int
    spilled: bool = False
    meta: dict = field(default_factory=dict)


class ServeLoop:
    """Continuous-batching serve tier over one SlotKVCache + SpillStore."""

    def __init__(self, *, slots: int, max_pages: int, page: int, n_kv: int,
                 head_dim: int, policy: str = "dynamic",
                 packing: str = "pair", spill_packing: str = "quad",
                 spill_pages: int | None = None,
                 tuner: AutoTuner | None = None,
                 ledger: Ledger | None = None, key: int = DEFAULT_MARKER_KEY,
                 counter_init: int = COUNTER_INIT,
                 interpret: bool | None = None,
                 fused: bool = True, migrate_budget: int = 1,
                 async_spill: bool = True):
        self.ledger = ledger if ledger is not None else Ledger("serve")
        self.cache = SlotKVCache(max_pages, page, n_kv, head_dim,
                                 batch=slots, policy=policy, packing=packing,
                                 key=key, counter_init=counter_init,
                                 interpret=interpret, ledger=self.ledger)
        self.spill = SpillStore(packing=spill_packing,
                                capacity_pages=spill_pages,
                                ledger=self.ledger,
                                async_spill=async_spill)
        self.tuner = tuner
        self.n_slots = slots
        self.fused = fused
        self.migrate_budget = migrate_budget
        self._free = list(range(slots))       # kept sorted: lowest first
        self.seqs: dict[int, SequenceSlot] = {}
        self.clock = 0
        self.counts = {"admitted": 0, "retired": 0, "evicted": 0,
                       "woken": 0, "spilled_direct": 0}
        self.choices: dict = {}
        # the tuner's hot pick while the gate suppressed it to "off" —
        # a live re-enable migrates to THIS, not to a default
        self.suppressed_packing: str | None = None
        self._gate_seen: dict[str, bool] = {}

    @classmethod
    def auto(cls, tuner: AutoTuner, k_sample, v_sample, *, slots: int,
             max_pages: int, page: int, n_kv: int, head_dim: int, **kw):
        """`--kv-policy auto`: per-tier packing from one KV sample — hot
        under the decode DMA model, spill under the spill-link model, each
        with its own gate key.  Returns (loop, {"hot": .., "spill": ..}).
        A gate-suppressed hot pick is RECORDED (`suppressed_packing`), so
        a later re-enabling observation window migrates the live cache to
        the tuner's actual pick instead of restarting at a default."""
        d2 = 2 * head_dim
        slot_bytes = page * n_kv * d2 * 2
        strip_bytes = n_kv * (d2 + MARKER_LANES) * 2
        hot = tuner.choose_kv_packing(
            k=k_sample, v=v_sample, page=page, slot_bytes=slot_bytes,
            strip_bytes=strip_bytes, tier="hot", gate_key="kv-hot")
        spl = tuner.choose_kv_packing(
            k=k_sample, v=v_sample, page=page, slot_bytes=slot_bytes,
            tier="spill")   # the spill-link model has no strip term
        policy, packing = (("off", "pair") if hot.choice == "off"
                           else ("auto", hot.choice))
        loop = cls(slots=slots, max_pages=max_pages, page=page, n_kv=n_kv,
                   head_dim=head_dim, policy=policy, packing=packing,
                   spill_packing=spl.choice, tuner=tuner, **kw)
        loop.choices = {"hot": hot, "spill": spl}
        if hot.choice == "off" and hot.preferred not in ("", "off"):
            loop.suppressed_packing = hot.preferred
        loop._gate_seen["kv-hot"] = tuner.gate_enabled("kv-hot")
        return loop, loop.choices

    # --------------------------------------------------------- scheduling
    def _coldest_active(self, protect: frozenset = frozenset()
                        ) -> SequenceSlot:
        cands = [s for s in self.seqs.values()
                 if not s.spilled and s.seq_id not in protect]
        assert cands, "no evictable active sequence"
        return min(cands, key=lambda s: (s.last_step, s.admitted_at,
                                         s.seq_id))

    def _take_slot(self, protect: frozenset = frozenset()) -> int:
        if not self._free:
            self.evict(protect=protect)
        return self._free.pop(0)

    def _incoming_is_coldest(self, seq_id) -> bool:
        """Admit-beyond-pool ordering: would the incoming sequence itself
        be the next eviction victim?  Its would-be record sorts at
        (last_step=clock, admitted_at=clock, seq_id); compare it against
        the coldest resident under the same ordering."""
        cold = self._coldest_active()
        return ((self.clock, self.clock, seq_id)
                < (cold.last_step, cold.admitted_at, cold.seq_id))

    def admit(self, seq_id, k=None, v=None, *, prompt=None) -> SequenceSlot:
        """Join a sequence mid-flight.

        k/v (T, n_kv, d) prefill its slot through the incremental append;
        `prompt=(k, v)` takes the fused chunked-prefill path instead
        (`SlotKVCache.prefill_slot`: scatter + bulk pack + booking in ONE
        donated dispatch).  When no slot is free the coldest active
        sequence is evicted — unless the incoming sequence would itself
        be the coldest under the eviction ordering, in which case its
        payload is encoded STRAIGHT into the spill tier
        (`SpillStore.spill_in`) without ever occupying a lane: evicting a
        hotter resident just to spill the newcomer next step would thrash
        two link crossings for nothing."""
        assert seq_id not in self.seqs, f"seq {seq_id} already live"
        if prompt is not None:
            assert k is None and v is None, "pass k/v or prompt=, not both"
            k, v = prompt
        if (k is not None and not self._free
                and self._incoming_is_coldest(seq_id)):
            rec = SequenceSlot(seq_id, -1, self.clock, self.clock,
                               spilled=True)
            self.seqs[seq_id] = rec
            self.spill.spill_in(self.cache, seq_id, k, v)
            self.counts["admitted"] += 1
            self.counts["spilled_direct"] += 1
            return rec
        slot = self._take_slot()
        rec = SequenceSlot(seq_id, slot, self.clock, self.clock)
        self.seqs[seq_id] = rec
        if k is not None:
            if prompt is not None:
                self.cache.prefill_slot(slot, k, v)
            else:
                self.cache.append_slot(slot, k, v)
        self.counts["admitted"] += 1
        return rec

    def prefill(self, seq_id, k, v) -> SequenceSlot:
        """Admit with the fused chunked-prefill ingest: the whole prompt
        k/v (T, n_kv, d) is compressed page-group-at-a-time in one
        dispatch — or encoded straight to the spill tier for an
        admit-beyond-pool that would itself be the coldest."""
        return self.admit(seq_id, prompt=(k, v))

    def retire(self, seq_id) -> None:
        """Finish a sequence: its lane resets and returns to the free pool
        (or its spill payload is dropped) — the batch axis never grows."""
        rec = self.seqs.pop(seq_id)
        if rec.spilled:
            self.spill.drop(seq_id)
        else:
            self.cache.reset_slot(rec.slot)
            insort(self._free, rec.slot)
        self.counts["retired"] += 1

    def evict(self, seq_id=None, *,
              protect: frozenset = frozenset()) -> SequenceSlot:
        """Spill one active sequence compressed — `seq_id`, or the coldest
        active one outside `protect`.  The slot frees immediately; with
        async spill the payload re-encode overlaps the next steps."""
        rec = self.seqs[seq_id] if seq_id is not None else (
            self._coldest_active(protect))
        self.spill.evict(self.cache, rec.slot, rec.seq_id)  # resets slot
        insort(self._free, rec.slot)
        rec.slot, rec.spilled = -1, True
        self.counts["evicted"] += 1
        return rec

    def wake(self, seq_id, *,
             protect: frozenset = frozenset()) -> SequenceSlot:
        """Restore a spilled sequence into a free slot (evicting the
        coldest active one outside `protect` if needed)."""
        rec = self.seqs[seq_id]
        if not rec.spilled:
            return rec
        slot = self._take_slot(protect)
        self.spill.restore(self.cache, slot, seq_id)
        rec.slot, rec.spilled = slot, False
        rec.last_step = self.clock
        self.counts["woken"] += 1
        return rec

    # ------------------------------------------------------------ serving
    def step(self, kv_by_seq: dict) -> dict:
        """One decode step: `{seq_id: (k, v)}` with k/v (T, n_kv, d), all
        the same T (usually 1).  Spilled sequences named here are woken
        first, and the wake evictions never pick a step-named sequence —
        its last_step only advances below, so the coldest-active ordering
        could otherwise evict a sequence this very step is about to
        append to, leaving slot=-1 in the scatter.  The per-step batch is
        assembled ON DEVICE (`jnp.stack` — device-resident k/v never
        round-trip through host), and the fused path runs append + repack
        + migration quantum + booking as one donated `megastep` dispatch.
        At most `n_slots` sequences fit one step; `step_all` chunks a
        larger batch into waves.  Returns {seq_id: slot}."""
        self.clock += 1
        ids = sorted(kv_by_seq)
        if len(ids) > self.n_slots:
            raise ValueError(
                f"step names {len(ids)} sequences but the pool has only "
                f"{self.n_slots} slots; use step_all() to run in waves")
        named = frozenset(ids)
        for sid in ids:
            if self.seqs[sid].spilled:
                self.wake(sid, protect=named)
        slot_ids = []
        for sid in ids:
            rec = self.seqs[sid]
            assert not rec.spilled and rec.slot >= 0, (sid, rec)
            slot_ids.append(rec.slot)
        k = jnp.stack([jnp.asarray(kv_by_seq[sid][0]) for sid in ids])
        v = jnp.stack([jnp.asarray(kv_by_seq[sid][1]) for sid in ids])
        if self.fused:
            self.cache.megastep(slot_ids, k, v,
                                budget=self.migrate_budget)
        else:
            self.cache.append_active(slot_ids, k, v)
            self.cache.migration_quantum(self.migrate_budget)
            self.cache.account_step()
        for sid in ids:
            self.seqs[sid].last_step = self.clock
        return dict(zip(ids, slot_ids, strict=True))

    def step_all(self, kv_by_seq: dict) -> dict:
        """`step` for an oversubscribed batch: more named sequences than
        slots cannot share one fused append, so they run in waves of at
        most `n_slots` — active sequences first (already resident), then
        spilled ones, whose wakes may evict earlier waves' members (those
        have been appended by then).  The spilled members' payload decodes
        are PREFETCHED onto the spill worker up front, so they expand
        behind the earlier waves' compute and their wakes find the pages
        ready.  Each wave is one fused append with its own byte
        accounting.  Returns the merged {seq_id: slot}, each slot from
        its sequence's own wave."""
        ids = sorted(kv_by_seq)
        order = ([s for s in ids if not self.seqs[s].spilled]
                 + [s for s in ids if self.seqs[s].spilled])
        for sid in order:
            if self.seqs[sid].spilled:
                self.spill.prefetch(sid, self.cache.page)
        out: dict = {}
        for i in range(0, len(order), self.n_slots):
            wave = order[i:i + self.n_slots]
            out.update(self.step({s: kv_by_seq[s] for s in wave}))
        return out

    def attend(self, q_by_seq: dict, *, shard: "bool | str" = "auto") -> dict:
        """Batched decode-attend for `{seq_id: q}` with q (Hq, d); one
        fused (optionally sharded) kernel over the whole slot axis,
        inactive lanes masked by valid.  Returns {seq_id: (Hq, d)}."""
        ids = sorted(q_by_seq)
        for sid in ids:
            assert not self.seqs[sid].spilled, f"seq {sid} is spilled"
        q0 = np.asarray(q_by_seq[ids[0]])
        q = np.zeros((self.n_slots,) + q0.shape, np.float32)
        for sid in ids:
            q[self.seqs[sid].slot] = np.asarray(q_by_seq[sid])
        out = shard_kv_attend(self.cache, q, shard=shard)
        return {sid: out[self.seqs[sid].slot] for sid in ids}

    # ------------------------------------------------------------- policy
    def sync_ledger(self) -> None:
        """Fold the cache's device traffic window into the host ledger.

        The decode path (`step`/`step_all`) books every step's read and
        repack bytes into device accumulators only — an N-step run makes
        ZERO host ledger records (spill crossings excepted: those are
        rare, host-driven events).  Report boundaries call this fold; it
        costs O(1) `Ledger.record` calls regardless of N.  In-flight
        async evictions are collected first so their exactly-once spill
        events are booked before anything reads the rows."""
        self.spill.flush()
        self.cache.sync_ledger()

    def migrate_to(self, *, packing: str | None = None,
                   policy: str | None = None) -> dict:
        """Re-target the LIVE hot cache: optionally switch policy and/or
        packing, then refresh the per-slot target gate.  Nothing is
        re-laid here — the layout converges incrementally, at most
        `migrate_budget` page-group columns per decode step, and
        mid-migration reads stay correct via the in-band markers.
        Returns the cache's migration status after re-targeting."""
        if policy is not None:
            assert policy in ("dynamic", "static", "off", "auto")
            self.cache.policy = policy
        if packing is not None:
            self.cache.switch_packing(packing)
        self.cache.refresh_gate()
        return self.cache.migration_status()

    def observe_tiers(self) -> dict:
        """One §VI observation window per tier: hot judged on the decode
        "read" rows, spill on the "spill" rows — independent counters.
        Folds the pending device window first so the rows are current.
        The hot gate decision is applied LIVE: a window that re-enables a
        gate which had suppressed the tuner's packing pick migrates the
        running cache to that pick; a window that turns the gate off
        re-targets the gate to off (both converge incrementally)."""
        if self.tuner is None:
            return {}
        self.sync_ledger()
        out = {
            "kv-hot": self.tuner.observe(self.ledger, key="kv-hot",
                                         consumer="kv", event="read"),
            "kv-spill": self.tuner.observe(self.ledger, key="kv-spill",
                                           consumer="kv", event="spill"),
        }
        hot_on = self.tuner.gate_enabled("kv-hot")
        prev = self._gate_seen.get("kv-hot")
        if prev is not None and hot_on != prev:
            if hot_on and self.suppressed_packing:
                # the gate came back and the tuner's pick was on hold:
                # migrate the live cache to it
                self.migrate_to(policy="auto",
                                packing=self.suppressed_packing)
                self.suppressed_packing = None
            elif not hot_on and self.cache.policy != "off":
                # measured harm: remember the running packing and degrade
                # the live layout to raw, incrementally
                self.suppressed_packing = self.cache.packing
                self.migrate_to(policy="off")
        self._gate_seen["kv-hot"] = hot_on
        return out

    # ------------------------------------------------------------ queries
    def active_seqs(self) -> list:
        return sorted(s for s, r in self.seqs.items() if not r.spilled)

    def spilled_seqs(self) -> list:
        return sorted(s for s, r in self.seqs.items() if r.spilled)

    def summary(self) -> dict:
        self.sync_ledger()
        return {
            "slots": self.n_slots, "clock": self.clock,
            "live": len(self.seqs), "active": len(self.active_seqs()),
            "spilled": len(self.spilled_seqs()),
            **self.counts,
            "spill_tier": self.spill.summary(),
            "hot_packing": (self.cache.packing
                            if self.cache.policy != "off" else "off"),
            "suppressed_packing": self.suppressed_packing,
            "migration": self.cache.migration_status(),
            "decode_saving": round(self.ledger.saving(
                "read", consumer="kv"), 4),
        }


__all__ = ["ServeLoop", "SequenceSlot"]
