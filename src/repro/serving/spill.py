"""SpillStore: compressed host-memory tier for cold sequences.

The serving analog of the paper's core move — ship COMPRESSED lines
across the slow link and expand only at the consumer — mapped onto
HBM->host KV tiering (the CXL story in PAPERS.md).  Evicting a cold
sequence does NOT decompress its KV: the store re-encodes the slot's
logical pages under the SPILL tier's own packing (off / pair / quad, an
independent `AutoTuner` axis — quad usually wins on the link because raw
groups cross with no strip), keeping

  * one packed slot per fitting group, plus its base row — the fit
    decision sees only the COMPLETE live pages (dead lanes and the
    partially-filled last page ride as base replicas, the partial page
    crossing raw in `tail`: its zero rows would otherwise poison the
    whole group),
  * the raw lanes of unfitting groups (no in-band metadata),
  * the slot's hot-tier bookkeeping: §VI counter, LLP predictor row, the
    uncounted-fitness mask, the gate its layout was settled under and
    the hot packing geometry it was evicted from.

Restore is the inverse: decode the payload back to logical pages
(`compression.pagepack` codecs are exact whenever the fit bit was set),
write them into a free slot with the saved gate state, mark the slot
dirty, and repack under the payload's recorded gate.  Because the hot
cache's incremental layout is pinned bit-identical to a from-scratch
rebuild (tests/test_kv_cache.py), the resurrected physical state — and
therefore every subsequent `attend` — is bit-identical to the
never-spilled execution.  A sequence waking into a half-migrated cache
simply joins the derived pending set: its layout was settled under
`gate` at evict, and if the pool's target moved while it was cold the
budgeted quanta converge it like any other slot (if the hot cache
switched PACKING while it was cold, the geometry-indexed bookkeeping is
reset and the slot lays directly under the current target).

Async pipeline (DESIGN.md §12): with `async_spill=True` the evict is
split in three — `_capture` snapshots the settled slot on the main
thread (the slot frees immediately), `_encode` re-encodes the payload on
a single background worker (pure numpy — no JAX contention with the
decode stream), and `_commit` books the store insert at *collection*,
back on the main thread.  Wakes overlap the other way: `prefetch`
enqueues the payload decode behind any in-flight encodes (one FIFO
worker makes the chaining deadlock-free), so `restore` finds the pages
already expanded.  Every evict and every restore still books exactly ONE
ledger `spill` event (`bandwidth.adapters.kv_spill_event`) with
compressed-byte duals — booked at completion, on the main thread, in
submission order, so the ledger stream is deterministic and
exactly-once-per-crossing no matter how the worker interleaves.
"""

from __future__ import annotations

from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from ..bandwidth import Ledger
from ..bandwidth.adapters import kv_spill_event
from ..compression import pagepack
from .slots import SlotKVCache

SPILL_LANES = {"off": 1, "pair": 2, "quad": 4}


@dataclass
class SpilledSeq:
    """One evicted sequence's payload, still compressed."""

    seq_id: int
    tokens: int
    packing: str                 # spill-tier packing the payload uses
    fit: np.ndarray              # (Gs,) bool — which spill groups packed
    slots: np.ndarray            # (Gs, page, Hkv, D2) packed slot / lane 0
    bases: np.ndarray            # (n_fit, Hkv, D2) base rows of fit groups
    overflow: list               # per raw group: (live-1, page, Hkv, D2)
                                 # raw lanes, dead tail lanes trimmed
    tail: "np.ndarray | None"    # the partially-filled last page, raw —
                                 # only when its group packed without it
    counter: int                 # hot-tier §VI counter at evict
    predictor: np.ndarray        # (Gh,) hot-tier LLP predictor row
    uncounted: np.ndarray        # (Gh,) hot-tier uncounted-fitness mask
    raw_bytes: int               # decompressed-page cost of this evict
    stored_bytes: int            # payload bytes that actually moved
    gate: bool = True            # gate the hot layout was settled under
    hot_packing: str = "pair"    # hot-tier geometry at evict (predictor/
                                 # uncounted are indexed in it)

    @property
    def n_groups(self) -> int:
        return int(self.fit.size)


def _payload_bytes(*arrays) -> int:
    return int(sum(a.nbytes for a in arrays))


class SpillStore:
    """Host-memory spill tier keyed by sequence id.

    `capacity_pages` bounds the tier (None = unbounded); `packing` is the
    spill-tier layout — independent of the hot cache's, chosen by
    `AutoTuner.choose_kv_packing(tier="spill")` under the spill-link byte
    model.  `async_spill=True` moves the payload re-encode off the decode
    path (see the module docstring); the observable store state is
    identical either way — `__contains__`, `__len__` and the capacity
    check all count in-flight evictions, and every read that needs a
    payload collects it first."""

    def __init__(self, *, packing: str = "quad",
                 capacity_pages: int | None = None,
                 ledger: Ledger | None = None,
                 async_spill: bool = False):
        assert packing in SPILL_LANES, packing
        self.packing = packing
        self.lanes = SPILL_LANES[packing]
        self.capacity_pages = capacity_pages
        self.ledger = ledger if ledger is not None else Ledger("spill")
        self.async_spill = async_spill
        self._store: dict[int, SpilledSeq] = {}
        self._inflight: dict[int, Future] = {}   # seq_id -> encode future
        self._inflight_pages: dict[int, int] = {}
        self._prefetched: dict[int, Future] = {}  # seq_id -> decode future
        self._pool: ThreadPoolExecutor | None = None
        self.spills = 0
        self.restores = 0
        self.raw_bytes = 0        # cumulative decompressed-page duals
        self.stored_bytes = 0     # cumulative payload bytes moved out

    def _worker(self) -> ThreadPoolExecutor:
        # ONE worker, FIFO: jobs complete in submission order, so a
        # prefetch enqueued after its sequence's encode can chain on the
        # future without deadlock, and collection order == evict order
        if self._pool is None:
            self._pool = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="kv-spill")
        return self._pool

    def __contains__(self, seq_id) -> bool:
        return seq_id in self._store or seq_id in self._inflight

    def __len__(self) -> int:
        return len(self._store) + len(self._inflight)

    # ------------------------------------------------------------- evict
    def evict(self, cache: SlotKVCache, slot: int, seq_id: int) -> None:
        """Move one slot out of the hot cache, still compressed; the slot
        is reset for reuse before this returns.  Sync mode encodes and
        books inline; async mode snapshots the settled slot, frees it,
        and ships the re-encode to the background worker — the ledger
        `spill` event (exactly one per crossing) is booked when the
        payload is collected."""
        assert seq_id not in self, f"seq {seq_id} already spilled"
        cap = self._capture(cache, slot, seq_id)
        if not self.async_spill:
            self._commit(self._encode(cap))
            return
        self._inflight_pages[seq_id] = cap["n_pages"]
        self._inflight[seq_id] = self._worker().submit(self._encode, cap)

    def spill_in(self, cache: SlotKVCache, seq_id: int, k, v) -> None:
        """Encode a prompt STRAIGHT into the spill tier — no hot lane.

        The spill-direct half of `ServeLoop.admit`: an admit-beyond-pool
        whose sequence would itself be the coldest encodes its prompt
        under the spill packing right away (one evict-direction crossing,
        exactly one ledger `spill` event) instead of thrashing a hotter
        resident out and straight back.  The payload records the hot
        bookkeeping a fresh hot-lane prefill would start from — counter
        at the policy init, every group's fitness uncounted, the default
        target gate — so a later `restore` + repack resurrects the slot's
        physical state, attend output and §VI counter exactly as if the
        sequence had been prefilled into a hot lane (only the LLP
        predictor row starts unseeded; it re-seeds at the next
        observation).  k/v: (T, n_kv, d), the prompt."""
        assert seq_id not in self, f"seq {seq_id} already spilled"
        kk = np.asarray(jnp.asarray(k, jnp.bfloat16).view(jnp.int16))
        vv = np.asarray(jnp.asarray(v, jnp.bfloat16).view(jnp.int16))
        assert kk.ndim == 3, "spill_in takes one sequence (T, n_kv, d)"
        kv = np.concatenate([kk, vv], axis=-1)
        tokens = kv.shape[0]
        assert tokens > 0, "spill_in needs a non-empty prompt"
        page = cache.page
        n_pages = -(-tokens // page)
        gs = -(-n_pages // self.lanes)
        if (self.capacity_pages is not None
                and self._pages_stored() + n_pages > self.capacity_pages):
            raise RuntimeError(
                f"spill store full ({self._pages_stored()}+{n_pages} pages "
                f"> capacity {self.capacity_pages})")
        pages = np.zeros((gs * self.lanes, page, cache.n_kv, cache.d2),
                         np.int16)
        pages.reshape(-1, cache.n_kv, cache.d2)[:tokens] = kv
        gh = -(-n_pages // cache.group_lanes)
        cap = {
            "seq_id": seq_id, "tokens": tokens, "n_pages": n_pages,
            "gs": gs, "pages": pages,
            "counter": cache._counter_init,
            "predictor": np.zeros(gh, bool),
            "uncounted": np.ones(gh, bool),
            "gate": cache.default_slot_gate(),
            "hot_packing": cache.packing,
            "raw_bytes": n_pages * cache.slot_bytes,
        }
        if not self.async_spill:
            self._commit(self._encode(cap))
            return
        self._inflight_pages[seq_id] = n_pages
        self._inflight[seq_id] = self._worker().submit(self._encode, cap)

    def _capture(self, cache: SlotKVCache, slot: int, seq_id: int) -> dict:
        """Main-thread half of an evict: settle the slot's layout (drain
        its pending migration under the frozen target, repack), snapshot
        everything the encode needs as host arrays, and reset the slot."""
        cache.drain_migration(slot)
        cache.repack(gate=cache._gate_b)   # settle appends, frozen target
        tokens = int(cache.tokens_b[slot])
        assert tokens > 0, "evicting an empty slot"
        page = cache.page
        n_pages = -(-tokens // page)
        gs = -(-n_pages // self.lanes)
        if (self.capacity_pages is not None
                and self._pages_stored() + n_pages > self.capacity_pages):
            raise RuntimeError(
                f"spill store full ({self._pages_stored()}+{n_pages} pages "
                f"> capacity {self.capacity_pages})")
        avail = min(gs * self.lanes, cache.max_pages)
        pages = np.zeros((gs * self.lanes, page, cache.n_kv, cache.d2),
                         np.int16)
        pages[:avail] = np.asarray(cache.pages_view()[slot, :avail])
        gh = cache.slot_groups(slot)
        cap = {
            "seq_id": seq_id, "tokens": tokens, "n_pages": n_pages,
            "gs": gs, "pages": pages,
            "counter": int(np.asarray(cache.state["counter"][slot])),
            "predictor": np.asarray(
                cache.state["predictor"][slot, :gh]).copy(),
            "uncounted": cache._uncounted_b[slot, :gh].copy(),
            "gate": bool(cache._gate_b[slot]),
            "hot_packing": cache.packing,
            "raw_bytes": n_pages * cache.slot_bytes,
        }
        cache.reset_slot(slot)
        return cap

    def _encode(self, cap: dict) -> SpilledSeq:
        """Pure re-encode of a captured slot under the spill packing —
        numpy only, safe on the background worker."""
        tokens, page = cap["tokens"], cap["pages"].shape[1]
        gs, pages = cap["gs"], cap["pages"]
        hkv, d2 = pages.shape[-2:]
        fit = np.zeros(gs, bool)
        slots = np.empty((gs, page, hkv, d2), np.int16)
        bases, overflow, tail = [], [], None
        n_full, rem = divmod(tokens, page)
        if self.packing == "off":
            slots[:] = pages                      # lanes == 1: page == group
        else:
            pack = (pagepack.pack_pair if self.packing == "pair"
                    else pagepack.pack_quad)
            for g in range(gs):
                orig = pages[g * self.lanes:(g + 1) * self.lanes]
                full = min(max(n_full - g * self.lanes, 0), self.lanes)
                partial = bool(rem) and full < self.lanes \
                    and g * self.lanes + full == n_full
                live = full + partial
                # the fit decision sees only the COMPLETE live pages:
                # dead lanes and the partially-filled last page ride as
                # base-page replicas (delta 0) — their zero rows against
                # a non-zero base would force the whole group raw.  The
                # partial page crosses raw in `tail`; restore re-zeroes
                # the dead lanes.  Fewer than 2 complete pages never
                # packs: slot+base would cost more than trimmed raw.
                grp = orig.copy()
                grp[full:] = grp[0]
                ok, packed, base = (pack(*grp) if full >= 2
                                    else (False, None, None))
                if bool(ok):
                    fit[g] = True
                    slots[g] = packed
                    bases.append(base)
                    if partial:
                        tail = orig[full].copy()
                else:
                    # raw group: lane 0 in the slot row, LIVE extra lanes
                    # in overflow — dead lanes never cross the link
                    slots[g] = orig[0]
                    overflow.append(orig[1:live].copy())
        bases = (np.stack(bases) if bases
                 else np.empty((0, hkv, d2), np.int16))
        return SpilledSeq(
            seq_id=cap["seq_id"], tokens=tokens, packing=self.packing,
            fit=fit, slots=slots, bases=bases, overflow=overflow, tail=tail,
            counter=cap["counter"], predictor=cap["predictor"],
            uncounted=cap["uncounted"], raw_bytes=cap["raw_bytes"],
            stored_bytes=_payload_bytes(
                slots, bases, fit, *overflow,
                *(() if tail is None else (tail,))),
            gate=cap["gate"], hot_packing=cap["hot_packing"],
        )

    def _commit(self, payload: SpilledSeq) -> None:
        """Book one completed evict: store insert, byte totals, and the
        single ledger `spill` event.  Always runs on the main thread."""
        self._store[payload.seq_id] = payload
        self.spills += 1
        self.raw_bytes += payload.raw_bytes
        self.stored_bytes += payload.stored_bytes
        kv_spill_event(self.ledger, raw=payload.raw_bytes,
                       compressed=payload.stored_bytes, direction="evict")

    def _collect(self, seq_id) -> None:
        """Join one in-flight evict and commit it (main thread).  Commit
        BEFORE dropping the in-flight entry: a worker-side `_payload`
        lookup then always finds the sequence in one map or the other."""
        fut = self._inflight.get(seq_id)
        if fut is not None:
            self._commit(fut.result())
            del self._inflight[seq_id]
            self._inflight_pages.pop(seq_id, None)

    def flush(self) -> int:
        """Join every in-flight evict, committing in submission order —
        the sync point before anything reads the ledger's spill rows.
        Returns the number collected."""
        pending = list(self._inflight)
        for sid in pending:
            self._collect(sid)
        return len(pending)

    # ----------------------------------------------------------- prefetch
    def _payload(self, seq_id) -> SpilledSeq:
        # single FIFO worker: an encode submitted before this job ran has
        # already finished, so .result() cannot block the worker on itself.
        # `_collect` commits to the store before dropping the in-flight
        # entry, so one of these lookups always lands.
        p = self._store.get(seq_id)
        if p is not None:
            return p
        fut = self._inflight.get(seq_id)
        if fut is not None:
            return fut.result()
        return self._store[seq_id]

    def _decode_pages(self, p: SpilledSeq, page: int) -> np.ndarray:
        """Payload -> logical pages (n_groups*lanes, page, Hkv, D2) — the
        pure half of a restore, runnable on the worker."""
        hkv, d2 = p.slots.shape[-2:]
        # decode under the packing the payload was EVICTED with, not the
        # store's current setting — per-tier retuning may change the
        # latter while sequences are cold
        lanes = SPILL_LANES[p.packing]
        pages = np.empty((p.n_groups * lanes, page, hkv, d2), np.int16)
        fi = ri = 0
        if p.packing == "off":
            pages[:] = p.slots
        else:
            unpack = (pagepack.unpack_pair if p.packing == "pair"
                      else pagepack.unpack_quad)
            for g in range(p.n_groups):
                dst = pages[g * lanes:(g + 1) * lanes]
                if p.fit[g]:
                    dst[:] = np.stack(unpack(p.slots[g], p.bases[fi]))
                    fi += 1
                else:
                    ov = p.overflow[ri]
                    dst[0] = p.slots[g]
                    dst[1:1 + len(ov)] = ov
                    ri += 1
        if p.tail is not None:             # partial page shipped raw beside
            pages[p.tokens // page] = p.tail        # its packed group
        pages[-(-p.tokens // page):] = 0   # dead lanes back to zeros (the
        # packed path decoded them as base replicas, the raw path trimmed)
        return pages

    def prefetch(self, seq_id, page: int) -> bool:
        """Start decoding a spilled payload on the background worker so a
        later `restore` finds the pages already expanded.  Chained behind
        any in-flight encode of the same sequence by FIFO order.  Returns
        False for unknown / already-prefetched sequences."""
        if seq_id not in self or seq_id in self._prefetched:
            return False
        if not self.async_spill:
            return False
        fut = self._worker().submit(
            lambda: self._decode_pages(self._payload(seq_id), page))
        self._prefetched[seq_id] = fut
        return True

    # ------------------------------------------------------------ restore
    def restore(self, cache: SlotKVCache, slot: int, seq_id: int) -> None:
        """Wake one sequence into a free slot: decode the payload back to
        logical pages (or consume the prefetched expansion), reinstall the
        gate state, and repack under the payload's recorded gate — the hot
        layout resurrects bit-identical to the never-spilled state, and
        joins the migration pending set if the pool's target gate moved
        while it was cold.  Books one ledger `spill` event."""
        self._collect(seq_id)              # join an in-flight encode first
        assert int(cache.tokens_b[slot]) == 0, "restore needs a free slot"
        page = cache.page
        # resolve the prefetch BEFORE popping the payload: the queued
        # decode job reads the store entry
        pre = self._prefetched.pop(seq_id, None)
        pages = pre.result() if pre is not None else None
        p = self._store.pop(seq_id)
        if pages is None:
            pages = self._decode_pages(p, page)
        hkv, d2 = p.slots.shape[-2:]
        n_rows = min(pages.shape[0], cache.max_pages) * page
        flat = pages.reshape(-1, hkv, d2)[:n_rows]
        st = cache.state
        st["pages"] = st["pages"].at[slot, :n_rows].set(jnp.asarray(flat))
        st["counter"] = st["counter"].at[slot].set(p.counter)
        cache.tokens_b[slot] = p.tokens
        cache.tokens = int(cache.tokens_b.max())
        gh = cache.slot_groups(slot)
        gate_vec = cache._gate_b.copy()
        if p.hot_packing == cache.packing:
            # same geometry: the payload's hot bookkeeping slots back in,
            # and the layout resurrects under the gate it was settled with
            # (a target that moved while cold leaves it derived-pending)
            assert gh == len(p.predictor), (gh, len(p.predictor))
            st["predictor"] = st["predictor"].at[slot, :gh].set(
                jnp.asarray(p.predictor))
            cache._uncounted_b[slot, :gh] = p.uncounted
            gate_vec[slot] = p.gate
        else:
            # the hot cache switched packing while this sequence was cold:
            # predictor/uncounted are indexed in the OLD geometry — reset
            # (history is not re-counted) and lay directly under the
            # current target gate
            cache._uncounted_b[slot, :gh] = False
        cache._dirty_b[slot, :gh] = True
        self.restores += 1
        kv_spill_event(self.ledger, raw=p.raw_bytes,
                       compressed=p.stored_bytes, direction="restore")
        cache.repack(gate=gate_vec)   # materialize the resurrected layout

    def drop(self, seq_id) -> None:
        """Discard a spilled sequence (retired while cold).  An in-flight
        evict is collected first — the crossing already happened, its
        bytes moved and its ledger event must still book exactly once."""
        self._collect(seq_id)
        pre = self._prefetched.pop(seq_id, None)
        if pre is not None:
            pre.result()   # let a queued decode finish reading the entry
        self._store.pop(seq_id)

    # ------------------------------------------------------------ queries
    def _pages_stored(self) -> int:
        return (sum(p.n_groups * SPILL_LANES[p.packing]
                    for p in self._store.values())
                + sum(self._inflight_pages.values()))

    def saving(self) -> float:
        """1 - stored/raw over every spill so far (the link-bytes win)."""
        return 1.0 - self.stored_bytes / max(self.raw_bytes, 1)

    def summary(self) -> dict:
        self.flush()
        return {"packing": self.packing, "held": len(self._store),
                "spills": self.spills, "restores": self.restores,
                "raw_bytes": self.raw_bytes,
                "stored_bytes": self.stored_bytes,
                "saving": round(self.saving(), 4)}


__all__ = ["SpillStore", "SpilledSeq", "SPILL_LANES"]
