"""SpillStore: compressed host-memory tier for cold sequences.

The serving analog of the paper's core move — ship COMPRESSED lines
across the slow link and expand only at the consumer — mapped onto
HBM->host KV tiering (the CXL story in PAPERS.md).  Evicting a cold
sequence does NOT decompress its KV: the store re-encodes the slot's
logical pages under the SPILL tier's own packing (off / pair / quad, an
independent `AutoTuner` axis — quad usually wins on the link because raw
groups cross with no strip), keeping

  * one packed slot per fitting group, plus its base row — the fit
    decision sees only the COMPLETE live pages (dead lanes and the
    partially-filled last page ride as base replicas, the partial page
    crossing raw in `tail`: its zero rows would otherwise poison the
    whole group),
  * the raw lanes of unfitting groups (no in-band metadata),
  * the slot's hot-tier bookkeeping: §VI counter, LLP predictor row, the
    uncounted-fitness mask, and the token count (the dirty mask is all
    clear by construction — evict settles the layout first).

Restore is the inverse: decode the payload back to logical pages
(`compression.pagepack` codecs are exact whenever the fit bit was set),
write them into a free slot with the saved gate state, mark the slot
dirty, and repack.  Because the hot cache's incremental layout is pinned
bit-identical to a from-scratch rebuild (tests/test_kv_cache.py), the
resurrected physical state — and therefore every subsequent `attend` —
is bit-identical to the never-spilled execution; tests/test_serving.py
holds that property across packings, partial pages and gate states.

Every evict and every restore books exactly ONE ledger `spill` event
(`bandwidth.adapters.kv_spill_event`) with compressed-byte duals: raw is
what moving the decompressed pages would have cost, compressed is the
payload that actually crossed.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from ..bandwidth import Ledger
from ..bandwidth.adapters import kv_spill_event
from ..compression import pagepack
from .slots import SlotKVCache

SPILL_LANES = {"off": 1, "pair": 2, "quad": 4}


@dataclass
class SpilledSeq:
    """One evicted sequence's payload, still compressed."""

    seq_id: int
    tokens: int
    packing: str                 # spill-tier packing the payload uses
    fit: np.ndarray              # (Gs,) bool — which spill groups packed
    slots: np.ndarray            # (Gs, page, Hkv, D2) packed slot / lane 0
    bases: np.ndarray            # (n_fit, Hkv, D2) base rows of fit groups
    overflow: list               # per raw group: (live-1, page, Hkv, D2)
                                 # raw lanes, dead tail lanes trimmed
    tail: "np.ndarray | None"    # the partially-filled last page, raw —
                                 # only when its group packed without it
    counter: int                 # hot-tier §VI counter at evict
    predictor: np.ndarray        # (Gh,) hot-tier LLP predictor row
    uncounted: np.ndarray        # (Gh,) hot-tier uncounted-fitness mask
    raw_bytes: int               # decompressed-page cost of this evict
    stored_bytes: int            # payload bytes that actually moved

    @property
    def n_groups(self) -> int:
        return int(self.fit.size)


def _payload_bytes(*arrays) -> int:
    return int(sum(a.nbytes for a in arrays))


class SpillStore:
    """Host-memory spill tier keyed by sequence id.

    `capacity_pages` bounds the tier (None = unbounded); `packing` is the
    spill-tier layout — independent of the hot cache's, chosen by
    `AutoTuner.choose_kv_packing(tier="spill")` under the spill-link byte
    model."""

    def __init__(self, *, packing: str = "quad",
                 capacity_pages: int | None = None,
                 ledger: Ledger | None = None):
        assert packing in SPILL_LANES, packing
        self.packing = packing
        self.lanes = SPILL_LANES[packing]
        self.capacity_pages = capacity_pages
        self.ledger = ledger if ledger is not None else Ledger("spill")
        self._store: dict[int, SpilledSeq] = {}
        self.spills = 0
        self.restores = 0
        self.raw_bytes = 0        # cumulative decompressed-page duals
        self.stored_bytes = 0     # cumulative payload bytes moved out

    def __contains__(self, seq_id) -> bool:
        return seq_id in self._store

    def __len__(self) -> int:
        return len(self._store)

    # ------------------------------------------------------------- evict
    def evict(self, cache: SlotKVCache, slot: int, seq_id: int) -> SpilledSeq:
        """Move one slot out of the hot cache, still compressed; the slot
        is reset for reuse.  Books one ledger `spill` event."""
        assert seq_id not in self._store, f"seq {seq_id} already spilled"
        cache.repack()                    # spill the settled layout
        tokens = int(cache.tokens_b[slot])
        assert tokens > 0, "evicting an empty slot"
        page, hkv, d2 = cache.page, cache.n_kv, cache.d2
        n_pages = -(-tokens // page)
        gs = -(-n_pages // self.lanes)
        if (self.capacity_pages is not None
                and self._pages_stored() + n_pages > self.capacity_pages):
            raise RuntimeError(
                f"spill store full ({self._pages_stored()}+{n_pages} pages "
                f"> capacity {self.capacity_pages})")
        # gather the logical pages to spill-group granularity
        avail = min(gs * self.lanes, cache.max_pages)
        pages = np.zeros((gs * self.lanes, page, hkv, d2), np.int16)
        pages[:avail] = np.asarray(cache.pages_view()[slot, :avail])
        fit = np.zeros(gs, bool)
        slots = np.empty((gs, page, hkv, d2), np.int16)
        bases, overflow, tail = [], [], None
        n_full, rem = divmod(tokens, page)
        if self.packing == "off":
            slots[:] = pages                      # lanes == 1: page == group
        else:
            pack = (pagepack.pack_pair if self.packing == "pair"
                    else pagepack.pack_quad)
            for g in range(gs):
                orig = pages[g * self.lanes:(g + 1) * self.lanes]
                full = min(max(n_full - g * self.lanes, 0), self.lanes)
                partial = bool(rem) and full < self.lanes \
                    and g * self.lanes + full == n_full
                live = full + partial
                # the fit decision sees only the COMPLETE live pages:
                # dead lanes and the partially-filled last page ride as
                # base-page replicas (delta 0) — their zero rows against
                # a non-zero base would force the whole group raw.  The
                # partial page crosses raw in `tail`; restore re-zeroes
                # the dead lanes.  Fewer than 2 complete pages never
                # packs: slot+base would cost more than trimmed raw.
                grp = orig.copy()
                grp[full:] = grp[0]
                ok, packed, base = (pack(*grp) if full >= 2
                                    else (False, None, None))
                if bool(ok):
                    fit[g] = True
                    slots[g] = packed
                    bases.append(base)
                    if partial:
                        tail = orig[full].copy()
                else:
                    # raw group: lane 0 in the slot row, LIVE extra lanes
                    # in overflow — dead lanes never cross the link
                    slots[g] = orig[0]
                    overflow.append(orig[1:live].copy())
        bases = (np.stack(bases) if bases
                 else np.empty((0, hkv, d2), np.int16))
        gh = cache.slot_groups(slot)
        payload = SpilledSeq(
            seq_id=seq_id, tokens=tokens, packing=self.packing,
            fit=fit, slots=slots, bases=bases, overflow=overflow, tail=tail,
            counter=int(np.asarray(cache.state["counter"][slot])),
            predictor=np.asarray(cache.state["predictor"][slot, :gh]).copy(),
            uncounted=cache._uncounted_b[slot, :gh].copy(),
            raw_bytes=n_pages * cache.slot_bytes,
            stored_bytes=_payload_bytes(
                slots, bases, fit, *overflow,
                *(() if tail is None else (tail,))),
        )
        self._store[seq_id] = payload
        self.spills += 1
        self.raw_bytes += payload.raw_bytes
        self.stored_bytes += payload.stored_bytes
        kv_spill_event(self.ledger, raw=payload.raw_bytes,
                       compressed=payload.stored_bytes, direction="evict")
        cache.reset_slot(slot)
        return payload

    # ------------------------------------------------------------ restore
    def restore(self, cache: SlotKVCache, slot: int, seq_id: int) -> None:
        """Wake one sequence into a free slot: decode the payload back to
        logical pages, reinstall the gate state, and repack — the hot
        layout resurrects bit-identical to the never-spilled state.  Books
        one ledger `spill` event."""
        p = self._store.pop(seq_id)
        assert int(cache.tokens_b[slot]) == 0, "restore needs a free slot"
        page, hkv, d2 = cache.page, cache.n_kv, cache.d2
        # decode under the packing the payload was EVICTED with, not the
        # store's current setting — per-tier retuning may change the
        # latter while sequences are cold
        lanes = SPILL_LANES[p.packing]
        pages = np.empty((p.n_groups * lanes, page, hkv, d2), np.int16)
        fi = ri = 0
        if p.packing == "off":
            pages[:] = p.slots
        else:
            unpack = (pagepack.unpack_pair if p.packing == "pair"
                      else pagepack.unpack_quad)
            for g in range(p.n_groups):
                dst = pages[g * lanes:(g + 1) * lanes]
                if p.fit[g]:
                    dst[:] = np.stack(unpack(p.slots[g], p.bases[fi]))
                    fi += 1
                else:
                    ov = p.overflow[ri]
                    dst[0] = p.slots[g]
                    dst[1:1 + len(ov)] = ov
                    ri += 1
        if p.tail is not None:             # partial page shipped raw beside
            pages[p.tokens // page] = p.tail        # its packed group
        pages[-(-p.tokens // page):] = 0   # dead lanes back to zeros (the
        # packed path decoded them as base replicas, the raw path trimmed)
        n_rows = min(pages.shape[0], cache.max_pages) * page
        flat = pages.reshape(-1, hkv, d2)[:n_rows]
        st = cache.state
        st["pages"] = st["pages"].at[slot, :n_rows].set(jnp.asarray(flat))
        gh = -(-(-(-p.tokens // page)) // cache.group_lanes)  # hot groups
        assert gh == len(p.predictor), (gh, len(p.predictor))
        st["predictor"] = st["predictor"].at[slot, :gh].set(
            jnp.asarray(p.predictor))
        st["counter"] = st["counter"].at[slot].set(p.counter)
        cache.tokens_b[slot] = p.tokens
        cache.tokens = int(cache.tokens_b.max())
        cache._uncounted_b[slot, :gh] = p.uncounted
        cache._dirty_b[slot, :gh] = True
        cache._last_enabled[slot] = cache.slot_enabled_from_counter(p.counter)
        self.restores += 1
        kv_spill_event(self.ledger, raw=p.raw_bytes,
                       compressed=p.stored_bytes, direction="restore")
        cache.repack()   # materialize the resurrected layout now

    def drop(self, seq_id: int) -> None:
        """Discard a spilled sequence (retired while cold)."""
        self._store.pop(seq_id)

    # ------------------------------------------------------------ queries
    def _pages_stored(self) -> int:
        return sum(p.n_groups * SPILL_LANES[p.packing]
                   for p in self._store.values())

    def saving(self) -> float:
        """1 - stored/raw over every spill so far (the link-bytes win)."""
        return 1.0 - self.stored_bytes / max(self.raw_bytes, 1)

    def summary(self) -> dict:
        return {"packing": self.packing, "held": len(self._store),
                "spills": self.spills, "restores": self.restores,
                "raw_bytes": self.raw_bytes,
                "stored_bytes": self.stored_bytes,
                "saving": round(self.saving(), 4)}


__all__ = ["SpillStore", "SpilledSeq", "SPILL_LANES"]
