"""SlotKVCache: the batched CRAM-KV cache with per-slot sequence lifetimes.

`kv.CRAMKVCache` assumes one uniform token count across its batch axis —
right for offline benches, wrong for serving, where sequences join and
retire mid-flight.  This subclass turns each batch lane into an
independently-progressing *slot*:

  * per-slot token counts (`tokens_b`) drive a per-slot `valid_per_page`
    mask, so the one batched attend/accounting dispatch stays fused while
    every lane sees only its own live pages;
  * appends come in three shapes — uniform (`append`, prefill of every
    slot together), per-slot (`append_slot`, admission prefill), and
    vectorized per-step (`append_active`: one fused scatter appends one
    token to an arbitrary subset of slots — no per-slot dispatch in the
    decode loop);
  * the dirty/uncounted masks become per-slot (B, n_groups): `repack`
    re-lays the UNION of dirty columns in one window dispatch (packing is
    a deterministic function of (pages, gate, markers), so re-laying a
    clean slot's column is idempotent), while §VI fitness is counted
    per slot — a group feeds slot b's counter only once b's own tokens
    complete it, exactly once, as in the base cache;
  * `reset_slot` returns a lane to pristine state for reuse by the next
    admitted sequence (continuous batching never grows the batch axis),
    and `slot_reference_state` is the per-slot rebuild oracle — the base
    `reference_rebuild` judges a uniform prefix, a slot's parity is
    judged on ITS OWN active prefix.

Gate semantics (DESIGN.md §12): the gate each repack lays under is the
frozen per-slot TARGET `_gate_b`, refreshed from the §VI counter only at
observation boundaries (`refresh_gate` — called by the plain `repack`
and by the serve loop's report points) or forced via
`set_gate_override`.  `_applied_b` records the gate every group's
layout was actually laid under; `serving.migrate` derives the pending
set from the two and converges the live layout with bounded per-step
quanta instead of stop-the-world re-dirtying.

`megastep` is the fused serve step: append scatter, window repack
(appends + migration quantum columns), §VI counter update, repack/read
byte booking and the LLP predictor observation all run in ONE donated
jitted dispatch, traced once per pow2-bucketed shape — the decode loop
makes zero host syncs per step (`jaxpr_audit` pins the entry).

The spill tier (`serving.spill.SpillStore`) moves slots out of and back
into this cache; bit-exact resurrection rides on the pinned
incremental==rebuild invariant (tests/test_kv_cache.py): restore writes
the logical pages + gate state and marks the slot dirty, and the next
repack reproduces the never-spilled physical layout bit-for-bit.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..bandwidth import Ledger
from ..bandwidth.adapters import kv_read_device, kv_repack_device
from ..compression.framing import DEFAULT_MARKER_KEY
from ..compression.gate import COUNTER_INIT, COUNTER_MAX, ENABLE_THRESHOLD
from ..compression.predictor import observe_layout
from ..kernels import ops as kops
from ..kernels.prefill_pack import prefill_pack
from ..kernels.ref import MARKER_LANES
from ..kv.cache import CRAMKVCache, _scatter_window, kernel_cache_slice
from . import migrate as _migrate


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_slot(pages, kv, slot, start):
    """pages (B, Tmax, Hkv, D2) <- kv (1, T, Hkv, D2) at (slot, start)."""
    return jax.lax.dynamic_update_slice(pages, kv, (slot, start, 0, 0))


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_active(pages, kv, starts, active):
    """Per-slot scatter at per-slot offsets: pages (B, Tmax, Hkv, D2) <-
    kv (B, T, Hkv, D2) row b at token starts[b], where active[b]."""
    def one(p, s, t0, a):
        return jnp.where(a, jax.lax.dynamic_update_slice(p, s, (t0, 0, 0)), p)
    return jax.vmap(one)(pages, kv, starts, active)


@functools.partial(
    jax.jit, donate_argnums=(0,),
    static_argnames=("lanes", "slot_bytes", "strip_bytes", "use_pack",
                     "dyn", "interpret"))
def _megastep(state, mk_lanes, k, v, slot_idx, starts, active, idx,
              enabled, countable, valid, *, lanes, slot_bytes, strip_bytes,
              use_pack, dyn, interpret):
    """One fused serve decode step over the whole cache pytree (donated).

    Collapses the per-step dispatch sequence — append scatter, window
    gather + pack/raw re-lay (the window covers the step's dirty appends
    AND the migration quantum's pending columns), physical scatter, §VI
    counter update, repack + read byte booking, LLP hit/miss tally and
    predictor observation — into one jitted call.  Bit-identical to the
    unfused append_active -> repack -> account path on the same window.

    k/v: (S, T, Hkv, D) rows aligned with slot_idx; idx: (W,) union dirty
    group columns, pow2-padded by REPEATING a real column (idempotent re-
    lay; the pad's `countable` entries are False so §VI never recounts);
    valid: (B, lanes*N) live-token counts at the attend bucket.
    """
    st = dict(state)
    kv = jnp.concatenate([jnp.asarray(k, jnp.bfloat16).view(jnp.int16),
                          jnp.asarray(v, jnp.bfloat16).view(jnp.int16)],
                         axis=-1)
    b = st["pages"].shape[0]
    t = kv.shape[1]
    full = jnp.zeros((b, t) + kv.shape[2:], kv.dtype).at[slot_idx].set(kv)

    def one(p, s, t0, a):
        return jnp.where(a, jax.lax.dynamic_update_slice(p, s, (t0, 0, 0)), p)
    st["pages"] = jax.vmap(one)(st["pages"], full, starts, active)

    hkv, d2 = st["pages"].shape[-2:]
    page = st["slots"].shape[2]
    n_groups = st["packed_mask"].shape[1]
    groups = st["pages"].reshape(b, n_groups, lanes, page, hkv, d2)
    win = groups[:, idx]
    slots_w, over_w, strips_w, lay, fit = kops.layout_window(
        win, mk_lanes[idx], enabled, use_pack=use_pack,
        interpret=interpret)
    st["slots"] = st["slots"].at[:, idx].set(slots_w)
    st["slots_overflow"] = st["slots_overflow"].at[:, idx].set(over_w)
    st["strips"] = st["strips"].at[:, idx].set(strips_w)
    st["packed_mask"] = st["packed_mask"].at[:, idx].set(lay)
    traffic, lay_n = kv_repack_device(st["traffic"], lay, lanes=lanes,
                                      slot_bytes=slot_bytes,
                                      strip_bytes=strip_bytes)
    st["packed_n"] = st["packed_n"] + lay_n
    st["raw_n"] = st["raw_n"] + (lay.size - lay_n)
    if dyn:
        fit_n = (fit & countable).sum(1)
        unfit_n = ((~fit) & countable).sum(1)
        st["counter"] = jnp.clip(
            st["counter"] + (fit_n - unfit_n).astype(jnp.int32),
            0, COUNTER_MAX)
    n = valid.shape[1] // lanes
    kc = kernel_cache_slice(st, n)
    raw_seq, cram_seq = kops.hbm_bytes_moved_device(
        kc, valid, predictor=st["predictor"][:, :n], lanes=lanes)
    pm = st["packed_mask"][:, :n]
    pred = st["predictor"][:, :n]
    live = valid.reshape(b, n, lanes).sum(-1) > 0
    mis = pred != pm
    st["pred_hits"] = st["pred_hits"] + ((~mis) & live).sum(1).astype(
        jnp.int32)
    st["pred_misses"] = st["pred_misses"] + (mis & live).sum(1).astype(
        jnp.int32)
    st["traffic"] = kv_read_device(traffic, raw_seq, cram_seq)
    st["predictor"] = observe_layout(st["packed_mask"])
    return st, raw_seq, cram_seq


@functools.partial(
    jax.jit, donate_argnums=(0,),
    static_argnames=("lanes", "slot_bytes", "strip_bytes", "use_pack",
                     "dyn", "interpret"))
def _prefill(state, mk_lanes, k, v, slot, start, idx, enabled, countable,
             *, lanes, slot_bytes, strip_bytes, use_pack, dyn, interpret):
    """Fused chunked-prefill ingest for one slot (donated).

    Installs a whole prompt — scatter at the slot's position, bulk pack of
    every touched page group (`kernels.prefill_pack`: ONE vmapped
    pallas_call for the per-page codec try + marker framing + slot
    placement), physical scatter, repack byte booking, §VI counter update
    from the pack results and LLP predictor initialization — in ONE jitted
    dispatch.  No read-side accounting: nothing was attended yet, so
    unlike `_megastep` there is no read event, no hit/miss tally, and no
    full-predictor observation (only the prefilled slot's row is seeded).

    k/v: (T, Hkv, D) the prompt rows (T pow2-padded by the caller with
    zeros, which land on never-written all-zero page rows); idx: (W,)
    union dirty group columns, pow2-padded by repeating a real column
    (idempotent re-lay, pad `countable` False).  Bit-identical to
    append_slot -> repack(gate) on the same window.
    """
    st = dict(state)
    kv = jnp.concatenate([jnp.asarray(k, jnp.bfloat16).view(jnp.int16),
                          jnp.asarray(v, jnp.bfloat16).view(jnp.int16)],
                         axis=-1)[None]                 # (1, T, Hkv, D2)
    st["pages"] = jax.lax.dynamic_update_slice(
        st["pages"], kv, (slot, start, 0, 0))
    page = st["slots"].shape[2]
    slots_w, over_w, strips_w, lay, fit = prefill_pack(
        st["pages"], idx, mk_lanes, enabled, lanes=lanes, page=page,
        use_pack=use_pack, interpret=interpret)
    st["slots"] = st["slots"].at[:, idx].set(slots_w)
    st["slots_overflow"] = st["slots_overflow"].at[:, idx].set(over_w)
    st["strips"] = st["strips"].at[:, idx].set(strips_w)
    st["packed_mask"] = st["packed_mask"].at[:, idx].set(lay)
    traffic, lay_n = kv_repack_device(st["traffic"], lay, lanes=lanes,
                                      slot_bytes=slot_bytes,
                                      strip_bytes=strip_bytes)
    st["traffic"] = traffic
    st["packed_n"] = st["packed_n"] + lay_n
    st["raw_n"] = st["raw_n"] + (lay.size - lay_n)
    if dyn:
        fit_n = (fit & countable).sum(1)
        unfit_n = ((~fit) & countable).sum(1)
        st["counter"] = jnp.clip(
            st["counter"] + (fit_n - unfit_n).astype(jnp.int32),
            0, COUNTER_MAX)
    # the prompt's own pack results seed the slot's LLP prediction; the
    # other slots' rows are untouched (no observation happened for them)
    st["predictor"] = st["predictor"].at[slot].set(st["packed_mask"][slot])
    return st


class SlotKVCache(CRAMKVCache):
    """CRAMKVCache whose batch lanes are independent sequence slots."""

    def __init__(self, max_pages: int, page: int, n_kv: int, head_dim: int,
                 *, batch: int = 1, policy: str = "dynamic",
                 packing: str = "pair", key: int = DEFAULT_MARKER_KEY,
                 counter_init: int = COUNTER_INIT,
                 interpret: bool | None = None,
                 ledger: Ledger | None = None):
        # a serve cache may live-migrate between pair and quad layouts:
        # round capacity to the 4-page lcm so both geometries tile it
        max_pages = -(-max_pages // 4) * 4
        super().__init__(max_pages, page, n_kv, head_dim, batch=batch,
                         policy=policy, packing=packing, key=key,
                         counter_init=counter_init, interpret=interpret,
                         ledger=ledger)
        self._counter_init = int(counter_init)
        # per-slot sequence positions; base `self.tokens` is kept at the
        # max so the shared pow2 attend bucket covers every live slot
        self.tokens_b = np.zeros(batch, np.int64)
        # per-slot dirty / §VI-uncounted group masks (the base cache's
        # shared 1-D masks assume uniform appends and are superseded here)
        self._dirty_b = np.zeros((batch, self.n_groups), bool)
        self._uncounted_b = np.zeros((batch, self.n_groups), bool)
        # migration state (serving.migrate): frozen per-slot target gate,
        # per-(slot, group) applied gate — pending is DERIVED, not stored
        self._gate_override: bool | None = None
        self._gate_b = self._policy_gate()
        self._applied_b = np.broadcast_to(
            self._gate_b[:, None], (batch, self.n_groups)).copy()

    # ------------------------------------------------------- slot geometry
    def slot_pages(self, slot: int) -> int:
        return int(-(-self.tokens_b[slot] // self.page))

    def slot_groups(self, slot: int) -> int:
        """Active page groups of one slot (its own prefix, not the max)."""
        return -(-self.slot_pages(slot) // self.group_lanes)

    def valid_per_page(self) -> np.ndarray:
        v = np.clip(self.tokens_b[:, None]
                    - np.arange(self.max_pages)[None, :] * self.page,
                    0, self.page)
        return v.astype(np.int32)

    # ------------------------------------------------------------ the gate
    def _policy_gate(self) -> np.ndarray:
        """(B,) bool target gate under the current policy / override.
        The only place the §VI counter crosses to the host."""
        if self._gate_override is not None:
            return np.full(self.batch, self._gate_override, bool)
        if self.policy == "off":
            return np.zeros(self.batch, bool)
        if self.policy == "static":
            return np.ones(self.batch, bool)
        return np.asarray(self.state["counter"]) >= ENABLE_THRESHOLD

    def refresh_gate(self) -> np.ndarray:
        """Re-sample the per-slot target gate (one observation boundary).
        Between refreshes the target is FROZEN: the fused decode step
        never reads the counter back — §VI flips take effect at window
        granularity and converge via budgeted migration quanta."""
        self._gate_b = self._policy_gate()
        return self._gate_b

    def set_gate_override(self, value: bool | None) -> np.ndarray:
        """Force the target gate on/off for every slot (None restores the
        policy-derived gate).  The live layout converges to the new
        target incrementally — see `serving.migrate`."""
        self._gate_override = value
        return self.refresh_gate()

    # ----------------------------------------------------------- migration
    def migration_pending(self) -> np.ndarray:
        """(B, n_groups) bool: groups still laid under a non-target gate."""
        return _migrate.pending_mask(self)

    def migrated_upto(self, slot: int) -> int:
        """Leading groups of `slot` already at the target layout."""
        return _migrate.migrated_upto(self, slot)

    def migration_quantum(self, budget: int = 1) -> int:
        """Claim <= budget pending columns for this step's repack window."""
        return _migrate.quantum(self, budget)

    def drain_migration(self, slot: int | None = None) -> int:
        """Settle all pending migration now (evict capture, oracles)."""
        return _migrate.drain(self, slot)

    def migration_status(self) -> dict:
        return _migrate.status(self)

    def switch_packing(self, packing: str) -> None:
        """Live structural migration to a new packing layout — see
        `serving.migrate.switch_packing`."""
        _migrate.switch_packing(self, packing)

    # ------------------------------------------------------------- appends
    def append(self, k, v):
        """Uniform append to EVERY slot (offline prefill convenience);
        requires all slots at the same position."""
        assert (self.tokens_b == self.tokens_b[0]).all(), (
            "uniform append on heterogeneous slots; use append_slot/"
            "append_active")
        t0 = int(self.tokens_b[0])
        super().append(k, v)            # scatters at t0, updates self.tokens
        span = self.group_lanes * self.page
        lo, hi = t0 // span, (self.tokens - 1) // span
        self._dirty_b[:, lo:hi + 1] = True
        self._uncounted_b[:, lo:hi + 1] = True
        self.tokens_b[:] = self.tokens

    def append_slot(self, slot: int, k, v):
        """k/v (T, n_kv, d): append T tokens to one slot (admission
        prefill) at its own position."""
        k = jnp.asarray(k, jnp.bfloat16).view(jnp.int16)
        v = jnp.asarray(v, jnp.bfloat16).view(jnp.int16)
        assert k.ndim == 3, "append_slot takes one sequence (T, n_kv, d)"
        kv = jnp.concatenate([k, v], axis=-1)[None]     # (1, T, Hkv, D2)
        t = kv.shape[1]
        start = int(self.tokens_b[slot])
        assert start + t <= self.max_pages * self.page, "slot full"
        self.state["pages"] = _scatter_slot(self.state["pages"], kv,
                                            slot, start)
        self._mark_dirty(slot, start, t)
        self.tokens_b[slot] += t
        self.tokens = int(self.tokens_b.max())

    def _check_slot_ids(self, slot_ids, t: int) -> None:
        assert ((slot_ids >= 0) & (slot_ids < self.batch)).all(), \
            f"slot ids out of range: {slot_ids}"      # -1 would wrap the
        # scatter to the LAST lane and corrupt whichever sequence owns it
        assert np.unique(slot_ids).size == slot_ids.size, \
            f"duplicate slot ids: {slot_ids}"
        assert (self.tokens_b[slot_ids] + t
                <= self.max_pages * self.page).all(), "slot full"

    def append_active(self, slot_ids, k, v):
        """One decode step for a subset of slots: k/v (S, T, n_kv, d) rows
        aligned with `slot_ids`, each landing at its slot's own position —
        ONE fused scatter, no per-slot dispatch."""
        slot_ids = np.asarray(slot_ids, np.int64)
        k = jnp.asarray(k, jnp.bfloat16).view(jnp.int16)
        v = jnp.asarray(v, jnp.bfloat16).view(jnp.int16)
        kv = jnp.concatenate([k, v], axis=-1)           # (S, T, Hkv, D2)
        s, t = kv.shape[:2]
        assert s == slot_ids.size
        self._check_slot_ids(slot_ids, t)
        full = jnp.zeros((self.batch, t) + kv.shape[2:], kv.dtype)
        full = full.at[jnp.asarray(slot_ids)].set(kv)
        active = np.zeros(self.batch, bool)
        active[slot_ids] = True
        self.state["pages"] = _scatter_active(
            self.state["pages"], full,
            jnp.asarray(self.tokens_b, jnp.int32), jnp.asarray(active))
        for sl in slot_ids:
            self._mark_dirty(int(sl), int(self.tokens_b[sl]), t)
        self.tokens_b[slot_ids] += t
        self.tokens = int(self.tokens_b.max())

    def _mark_dirty(self, slot: int, start: int, t: int):
        span = self.group_lanes * self.page
        lo, hi = start // span, (start + t - 1) // span
        self._dirty_b[slot, lo:hi + 1] = True
        self._uncounted_b[slot, lo:hi + 1] = True

    # ------------------------------------------------------------- packing
    def repack(self, gate: np.ndarray | None = None):
        """Incrementally re-pack the union of per-slot dirty groups.

        The window dispatch re-lays every slot's version of each union
        column (idempotent for clean slots — packing is deterministic in
        (pages, gate, markers)); §VI fitness is counted per slot, only on
        groups that slot's OWN tokens complete, each exactly once.

        `gate` overrides the layout gate per slot for THIS window (spill
        restore re-laying a payload under its recorded gate); the default
        refreshes the policy gate — an observation boundary.  Groups laid
        under a gate that later moves are NOT stop-the-world re-dirtied:
        they become pending in `migration_pending()` and converge via
        bounded quanta."""
        idx = np.nonzero(self._dirty_b.any(0))[0]
        if idx.size == 0:
            return
        w = int(idx.size)
        enabled = (self.refresh_gate() if gate is None
                   else np.asarray(gate, bool))
        idx_j = jnp.asarray(idx, jnp.int32)
        groups = self.pages_view().reshape(
            self.batch, self.n_groups, self.group_lanes, self.page,
            self.n_kv, self.d2)
        win = groups[:, idx_j]
        slots_w, over_w, strips_w, lay, fit = self._pack_window(
            win, idx_j, enabled)
        st = self.state
        (st["slots"], st["slots_overflow"], st["strips"],
         st["packed_mask"]) = _scatter_window(
            st["slots"], st["slots_overflow"], st["strips"],
            st["packed_mask"], idx_j, slots_w, over_w, strips_w, lay)
        self._book_repack(w, enabled, lay)
        # per-slot completeness: group idx[j] is complete FOR SLOT b once
        # b's own tokens cover it
        span = self.group_lanes * self.page
        complete = (idx[None, :] + 1) * span <= self.tokens_b[:, None]
        if self.policy in ("dynamic", "auto"):
            countable = jnp.asarray(complete & self._uncounted_b[:, idx])
            fit_n = (fit & countable).sum(1)
            unfit_n = ((~fit) & countable).sum(1)
            st["counter"] = jnp.clip(
                st["counter"] + (fit_n - unfit_n).astype(jnp.int32),
                0, COUNTER_MAX)
        u = self._uncounted_b[:, idx]
        u[complete] = False
        self._uncounted_b[:, idx] = u
        self._dirty_b[:] = False
        self._applied_b[:, idx] = enabled[:, None]
        self._last_enabled = enabled.copy()

    # ----------------------------------------------------- fused megastep
    def megastep(self, slot_ids, k, v, *, budget: int = 0) -> dict:
        """One fused serve decode step: append k/v (S, T, n_kv, d) rows to
        `slot_ids`, re-lay the dirty window (+ up to `budget` migration
        columns), and book the step's read/repack traffic — ONE donated
        jitted dispatch (`_megastep`), traced once per pow2-bucketed
        (window, attend) shape.  Device-resident k/v stay on device.

        Bit-identical to append_active -> migration_quantum -> repack ->
        account_step, minus their per-call dispatches and host syncs (the
        layout gate is the frozen `_gate_b`; the §VI counter still
        updates on device every step and is re-sampled at the next
        `refresh_gate`)."""
        slot_ids = np.asarray(slot_ids, np.int64)
        assert slot_ids.size > 0, "megastep needs at least one active slot"
        k = jnp.asarray(k)
        v = jnp.asarray(v)
        s, t = k.shape[:2]
        assert s == slot_ids.size
        self._check_slot_ids(slot_ids, t)
        starts = self.tokens_b.copy()
        active = np.zeros(self.batch, bool)
        active[slot_ids] = True
        for sl in slot_ids:
            self._mark_dirty(int(sl), int(self.tokens_b[sl]), t)
        self.tokens_b[slot_ids] += t
        self.tokens = int(self.tokens_b.max())
        if budget:
            self.migration_quantum(budget)
        idx = np.nonzero(self._dirty_b.any(0))[0]
        w = int(idx.size)
        wb = min(1 << (w - 1).bit_length(), self.n_groups)
        idx_pad = np.full(wb, idx[0], np.int32)
        idx_pad[:w] = idx
        enabled = self._gate_b
        span = self.group_lanes * self.page
        complete = (idx[None, :] + 1) * span <= self.tokens_b[:, None]
        countable = np.zeros((self.batch, wb), bool)
        countable[:, :w] = complete & self._uncounted_b[:, idx]
        n = self._active_bucket()
        valid = self.valid_per_page()[:, : self.group_lanes * n]
        self.state, raw_seq, cram_seq = _megastep(
            self.state, self._marker_lanes, k, v,
            jnp.asarray(slot_ids, jnp.int32),
            jnp.asarray(starts, jnp.int32), jnp.asarray(active),
            jnp.asarray(idx_pad), jnp.asarray(enabled),
            jnp.asarray(countable), jnp.asarray(valid),
            lanes=self.group_lanes, slot_bytes=self.slot_bytes,
            strip_bytes=self.strip_bytes, use_pack=self.policy != "off",
            dyn=self.policy in ("dynamic", "auto"),
            interpret=self.interpret)
        hs = self._host_stats        # same tallies as _book_repack, at the
        if self.policy == "off":     # padded window actually dispatched
            hs.pack_skipped_dynamic += self.batch * wb
        else:
            hs.pack_attempts += self.batch * wb
            hs.pack_skipped_dynamic += int((~enabled).sum()) * wb
        hs.pack_calls += 1
        hs.pack_pairs_processed += self.batch * wb
        u = self._uncounted_b[:, idx]
        u[complete] = False
        self._uncounted_b[:, idx] = u
        self._dirty_b[:] = False
        self._applied_b[:, idx] = enabled[:, None]
        self._last_enabled = enabled.copy()
        return {"raw_per_seq": raw_seq, "cram_per_seq": cram_seq}

    # ------------------------------------------------------ fused prefill
    def prefill_slot(self, slot: int, k, v, *, budget: int = 0) -> dict:
        """Install a whole prompt into one slot as ONE fused dispatch.

        k/v (T, n_kv, d): the prompt, landing at the slot's own position.
        Where the replay path pays T per-token `megastep` dispatches (T
        pack launches for what is one bulk write), this scatters the whole
        prompt and bulk-packs every touched page group in a single donated
        jitted call (`_prefill`): per-page codec try, in-band marker
        framing and packed-slot placement ride in ONE vmapped pallas_call
        (`kernels.prefill_pack`), the §VI counter takes the prompt's
        fitness in one clip, the LLP predictor row is seeded from the pack
        results, and the repack bytes are booked on the device accumulators
        — zero host ledger records.  A partial tail page stays raw (its
        group is zero-padded and fails the fit check), and the resulting
        cache state + attend output are bit-identical to the token-by-token
        append oracle (append_slot -> repack under the frozen `_gate_b`).

        Like `megastep`, an optional migration `budget` folds pending
        gate-flip columns into the same window, so mid-migration admits
        never add a dispatch."""
        k = jnp.asarray(k)
        v = jnp.asarray(v)
        assert k.ndim == 3, "prefill_slot takes one sequence (T, n_kv, d)"
        t = int(k.shape[0])
        assert t > 0, "prefill_slot needs a non-empty prompt"
        start = int(self.tokens_b[slot])
        cap = self.max_pages * self.page
        assert start + t <= cap, "slot full"
        self._mark_dirty(slot, start, t)
        self.tokens_b[slot] += t
        self.tokens = int(self.tokens_b.max())
        if budget:
            self.migration_quantum(budget)
        idx = np.nonzero(self._dirty_b.any(0))[0]
        w = int(idx.size)
        wb = min(1 << (w - 1).bit_length(), self.n_groups)
        idx_pad = np.full(wb, idx[0], np.int32)
        idx_pad[:w] = idx
        enabled = self._gate_b
        span = self.group_lanes * self.page
        complete = (idx[None, :] + 1) * span <= self.tokens_b[:, None]
        countable = np.zeros((self.batch, wb), bool)
        countable[:, :w] = complete & self._uncounted_b[:, idx]
        # pow2 token bucket bounds retraces across prompt lengths; the
        # zero pad rows land on never-written (all-zero) page rows
        t_pad = min(1 << (t - 1).bit_length(), cap - start)
        if t_pad > t:
            k = jnp.concatenate(
                [k, jnp.zeros((t_pad - t,) + k.shape[1:], k.dtype)])
            v = jnp.concatenate(
                [v, jnp.zeros((t_pad - t,) + v.shape[1:], v.dtype)])
        self.state = _prefill(
            self.state, self._marker_lanes, k, v, jnp.int32(slot),
            jnp.int32(start), jnp.asarray(idx_pad), jnp.asarray(enabled),
            jnp.asarray(countable),
            lanes=self.group_lanes, slot_bytes=self.slot_bytes,
            strip_bytes=self.strip_bytes, use_pack=self.policy != "off",
            dyn=self.policy in ("dynamic", "auto"),
            interpret=self.interpret)
        hs = self._host_stats        # same tallies as _book_repack, at the
        if self.policy == "off":     # padded window actually dispatched
            hs.pack_skipped_dynamic += self.batch * wb
        else:
            hs.pack_attempts += self.batch * wb
            hs.pack_skipped_dynamic += int((~enabled).sum()) * wb
        hs.pack_calls += 1
        hs.pack_pairs_processed += self.batch * wb
        u = self._uncounted_b[:, idx]
        u[complete] = False
        self._uncounted_b[:, idx] = u
        self._dirty_b[:] = False
        self._applied_b[:, idx] = enabled[:, None]
        self._last_enabled = enabled.copy()
        return {"tokens": t, "groups": w}

    # ------------------------------------------------------ slot lifecycle
    def reset_slot(self, slot: int):
        """Return a lane to pristine state for reuse (retire/evict)."""
        st = self.state
        for key in ("pages", "slots", "slots_overflow", "strips"):
            st[key] = st[key].at[slot].set(0)
        st["packed_mask"] = st["packed_mask"].at[slot].set(False)
        st["predictor"] = st["predictor"].at[slot].set(False)
        st["counter"] = st["counter"].at[slot].set(self._counter_init)
        self.tokens_b[slot] = 0
        self._dirty_b[slot] = False
        self._uncounted_b[slot] = False
        self._applied_b[slot] = self._gate_b[slot]
        self._last_enabled[slot] = bool(self._gate_b[slot])
        self.tokens = int(self.tokens_b.max())

    def slot_enabled_from_counter(self, counter: int) -> bool:
        """The gate a slot with this counter runs under (policy-resolved)."""
        if self.policy == "off":
            return False
        if self.policy == "static":
            return True
        return counter >= ENABLE_THRESHOLD

    def default_slot_gate(self) -> bool:
        """Target gate a freshly admitted slot would lay under — the
        override if one is forced, else the policy gate at the counter
        init.  Spill-direct admits record THIS as their payload gate so a
        later wake repacks like a fresh hot-lane prefill."""
        if self._gate_override is not None:
            return bool(self._gate_override)
        return self.slot_enabled_from_counter(self._counter_init)

    def slot_reference_state(self, slot: int) -> dict:
        """Per-slot from-scratch rebuild over the slot's OWN active prefix,
        under the PER-GROUP applied gate — the bit-exactness oracle for
        slot-level operations (spill round-trips, slot reuse) INCLUDING
        mid-migration states: groups already re-laid under the new target
        are judged packed, the rest raw (or vice versa), exactly as the
        in-band-marker kernel reads them."""
        g = self.slot_groups(slot)
        assert g > 0, "empty slot has no reference state"
        lanes = self.group_lanes
        pages = self.pages_view()[slot, : g * lanes]
        applied = self._applied_b[slot, :g]
        grouped = pages.reshape(g, lanes, self.page, self.n_kv, self.d2)
        over = (grouped[:, 1] if self.packing == "pair"
                else grouped[:, 1:])
        raw = {
            "slots": grouped[:, 0],
            "slots_overflow": over,
            "strips": jnp.zeros(
                (g, self.n_kv, self.d2 + MARKER_LANES), jnp.int16),
            "packed_mask": jnp.zeros((g,), bool),
        }
        if not applied.any():          # never launches the pack kernel
            c = raw
        else:
            build = (kops.build_cram_cache if self.packing == "pair"
                     else kops.build_cram_cache_quad)
            packed = dict(build(pages, key=self.key,
                                interpret=self.interpret))
            if applied.all():
                c = packed
            else:
                sel = jnp.asarray(applied)
                c = {k: jnp.where(
                        sel.reshape((g,) + (1,) * (raw[k].ndim - 1)),
                        packed[k], raw[k])
                     for k in raw}
        c = dict(c)
        c["markers"] = self.state["markers"][:g]
        return c

    def slot_physical_state(self, slot: int) -> dict:
        """The slot's physical rows over its own active prefix (compare
        against `slot_reference_state`)."""
        g = self.slot_groups(slot)
        st = self.state
        return {"slots": st["slots"][slot, :g],
                "slots_overflow": st["slots_overflow"][slot, :g],
                "strips": st["strips"][slot, :g],
                "packed_mask": st["packed_mask"][slot, :g],
                "markers": st["markers"][:g]}


__all__ = ["SlotKVCache"]
