"""SlotKVCache: the batched CRAM-KV cache with per-slot sequence lifetimes.

`kv.CRAMKVCache` assumes one uniform token count across its batch axis —
right for offline benches, wrong for serving, where sequences join and
retire mid-flight.  This subclass turns each batch lane into an
independently-progressing *slot*:

  * per-slot token counts (`tokens_b`) drive a per-slot `valid_per_page`
    mask, so the one batched attend/accounting dispatch stays fused while
    every lane sees only its own live pages;
  * appends come in three shapes — uniform (`append`, prefill of every
    slot together), per-slot (`append_slot`, admission prefill), and
    vectorized per-step (`append_active`: one fused scatter appends one
    token to an arbitrary subset of slots — no per-slot dispatch in the
    decode loop);
  * the dirty/uncounted masks become per-slot (B, n_groups): `repack`
    re-lays the UNION of dirty columns in one window dispatch (packing is
    a deterministic function of (pages, gate, markers), so re-laying a
    clean slot's column is idempotent), while §VI fitness is counted
    per slot — a group feeds slot b's counter only once b's own tokens
    complete it, exactly once, as in the base cache;
  * `reset_slot` returns a lane to pristine state for reuse by the next
    admitted sequence (continuous batching never grows the batch axis),
    and `slot_reference_state` is the per-slot rebuild oracle — the base
    `reference_rebuild` judges a uniform prefix, a slot's parity is
    judged on ITS OWN active prefix.

The spill tier (`serving.spill.SpillStore`) moves slots out of and back
into this cache; bit-exact resurrection rides on the pinned
incremental==rebuild invariant (tests/test_kv_cache.py): restore writes
the logical pages + gate state and marks the slot dirty, and the next
repack reproduces the never-spilled physical layout bit-for-bit.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..bandwidth import Ledger
from ..compression.framing import DEFAULT_MARKER_KEY
from ..compression.gate import COUNTER_INIT, COUNTER_MAX, ENABLE_THRESHOLD
from ..kernels import ops as kops
from ..kernels.ref import MARKER_LANES
from ..kv.cache import CRAMKVCache, _scatter_window


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_slot(pages, kv, slot, start):
    """pages (B, Tmax, Hkv, D2) <- kv (1, T, Hkv, D2) at (slot, start)."""
    return jax.lax.dynamic_update_slice(pages, kv, (slot, start, 0, 0))


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_active(pages, kv, starts, active):
    """Per-slot scatter at per-slot offsets: pages (B, Tmax, Hkv, D2) <-
    kv (B, T, Hkv, D2) row b at token starts[b], where active[b]."""
    def one(p, s, t0, a):
        return jnp.where(a, jax.lax.dynamic_update_slice(p, s, (t0, 0, 0)), p)
    return jax.vmap(one)(pages, kv, starts, active)


class SlotKVCache(CRAMKVCache):
    """CRAMKVCache whose batch lanes are independent sequence slots."""

    def __init__(self, max_pages: int, page: int, n_kv: int, head_dim: int,
                 *, batch: int = 1, policy: str = "dynamic",
                 packing: str = "pair", key: int = DEFAULT_MARKER_KEY,
                 counter_init: int = COUNTER_INIT,
                 interpret: bool | None = None,
                 ledger: Ledger | None = None):
        super().__init__(max_pages, page, n_kv, head_dim, batch=batch,
                         policy=policy, packing=packing, key=key,
                         counter_init=counter_init, interpret=interpret,
                         ledger=ledger)
        self._counter_init = int(counter_init)
        # per-slot sequence positions; base `self.tokens` is kept at the
        # max so the shared pow2 attend bucket covers every live slot
        self.tokens_b = np.zeros(batch, np.int64)
        # per-slot dirty / §VI-uncounted group masks (the base cache's
        # shared 1-D masks assume uniform appends and are superseded here)
        self._dirty_b = np.zeros((batch, self.n_groups), bool)
        self._uncounted_b = np.zeros((batch, self.n_groups), bool)

    # ------------------------------------------------------- slot geometry
    def slot_pages(self, slot: int) -> int:
        return int(-(-self.tokens_b[slot] // self.page))

    def slot_groups(self, slot: int) -> int:
        """Active page groups of one slot (its own prefix, not the max)."""
        return -(-self.slot_pages(slot) // self.group_lanes)

    def valid_per_page(self) -> np.ndarray:
        v = np.clip(self.tokens_b[:, None]
                    - np.arange(self.max_pages)[None, :] * self.page,
                    0, self.page)
        return v.astype(np.int32)

    # ------------------------------------------------------------- appends
    def append(self, k, v):
        """Uniform append to EVERY slot (offline prefill convenience);
        requires all slots at the same position."""
        assert (self.tokens_b == self.tokens_b[0]).all(), (
            "uniform append on heterogeneous slots; use append_slot/"
            "append_active")
        t0 = int(self.tokens_b[0])
        super().append(k, v)            # scatters at t0, updates self.tokens
        span = self.group_lanes * self.page
        lo, hi = t0 // span, (self.tokens - 1) // span
        self._dirty_b[:, lo:hi + 1] = True
        self._uncounted_b[:, lo:hi + 1] = True
        self.tokens_b[:] = self.tokens

    def append_slot(self, slot: int, k, v):
        """k/v (T, n_kv, d): append T tokens to one slot (admission
        prefill) at its own position."""
        k = jnp.asarray(k, jnp.bfloat16).view(jnp.int16)
        v = jnp.asarray(v, jnp.bfloat16).view(jnp.int16)
        assert k.ndim == 3, "append_slot takes one sequence (T, n_kv, d)"
        kv = jnp.concatenate([k, v], axis=-1)[None]     # (1, T, Hkv, D2)
        t = kv.shape[1]
        start = int(self.tokens_b[slot])
        assert start + t <= self.max_pages * self.page, "slot full"
        self.state["pages"] = _scatter_slot(self.state["pages"], kv,
                                            slot, start)
        self._mark_dirty(slot, start, t)
        self.tokens_b[slot] += t
        self.tokens = int(self.tokens_b.max())

    def append_active(self, slot_ids, k, v):
        """One decode step for a subset of slots: k/v (S, T, n_kv, d) rows
        aligned with `slot_ids`, each landing at its slot's own position —
        ONE fused scatter, no per-slot dispatch."""
        slot_ids = np.asarray(slot_ids, np.int64)
        assert ((slot_ids >= 0) & (slot_ids < self.batch)).all(), \
            f"slot ids out of range: {slot_ids}"      # -1 would wrap the
        # scatter to the LAST lane and corrupt whichever sequence owns it
        assert np.unique(slot_ids).size == slot_ids.size, \
            f"duplicate slot ids: {slot_ids}"
        k = jnp.asarray(k, jnp.bfloat16).view(jnp.int16)
        v = jnp.asarray(v, jnp.bfloat16).view(jnp.int16)
        kv = jnp.concatenate([k, v], axis=-1)           # (S, T, Hkv, D2)
        s, t = kv.shape[:2]
        assert s == slot_ids.size
        assert (self.tokens_b[slot_ids] + t
                <= self.max_pages * self.page).all(), "slot full"
        full = jnp.zeros((self.batch, t) + kv.shape[2:], kv.dtype)
        full = full.at[jnp.asarray(slot_ids)].set(kv)
        active = np.zeros(self.batch, bool)
        active[slot_ids] = True
        self.state["pages"] = _scatter_active(
            self.state["pages"], full,
            jnp.asarray(self.tokens_b, jnp.int32), jnp.asarray(active))
        for sl in slot_ids:
            self._mark_dirty(int(sl), int(self.tokens_b[sl]), t)
        self.tokens_b[slot_ids] += t
        self.tokens = int(self.tokens_b.max())

    def _mark_dirty(self, slot: int, start: int, t: int):
        span = self.group_lanes * self.page
        lo, hi = start // span, (start + t - 1) // span
        self._dirty_b[slot, lo:hi + 1] = True
        self._uncounted_b[slot, lo:hi + 1] = True

    # ------------------------------------------------------------- packing
    def repack(self):
        """Incrementally re-pack the union of per-slot dirty groups.

        The window dispatch re-lays every slot's version of each union
        column (idempotent for clean slots — packing is deterministic in
        (pages, gate, markers)); §VI fitness is counted per slot, only on
        groups that slot's OWN tokens complete, each exactly once."""
        idx = np.nonzero(self._dirty_b.any(0))[0]
        if idx.size == 0:
            return
        w = int(idx.size)
        enabled = self.enabled()
        idx_j = jnp.asarray(idx, jnp.int32)
        groups = self.pages_view().reshape(
            self.batch, self.n_groups, self.group_lanes, self.page,
            self.n_kv, self.d2)
        win = groups[:, idx_j]
        slots_w, over_w, strips_w, lay, fit = self._pack_window(
            win, idx_j, enabled)
        st = self.state
        (st["slots"], st["slots_overflow"], st["strips"],
         st["packed_mask"]) = _scatter_window(
            st["slots"], st["slots_overflow"], st["strips"],
            st["packed_mask"], idx_j, slots_w, over_w, strips_w, lay)
        self._book_repack(w, enabled, lay)
        # per-slot completeness: group idx[j] is complete FOR SLOT b once
        # b's own tokens cover it
        span = self.group_lanes * self.page
        complete = (idx[None, :] + 1) * span <= self.tokens_b[:, None]
        if self.policy in ("dynamic", "auto"):
            countable = jnp.asarray(complete & self._uncounted_b[:, idx])
            fit_n = (fit & countable).sum(1)
            unfit_n = ((~fit) & countable).sum(1)
            st["counter"] = jnp.clip(
                st["counter"] + (fit_n - unfit_n).astype(jnp.int32),
                0, COUNTER_MAX)
        u = self._uncounted_b[:, idx]
        u[complete] = False
        self._uncounted_b[:, idx] = u
        self._dirty_b[:] = False
        self._last_enabled = enabled
        flipped = self.enabled() != enabled
        for bi in np.nonzero(flipped)[0]:
            # that slot's whole layout rebuilds under the new gate at the
            # next repack (same invariant as the base cache, per slot)
            self._dirty_b[bi, : self.slot_groups(int(bi))] = True

    # ------------------------------------------------------ slot lifecycle
    def reset_slot(self, slot: int):
        """Return a lane to pristine state for reuse (retire/evict)."""
        st = self.state
        for key in ("pages", "slots", "slots_overflow", "strips"):
            st[key] = st[key].at[slot].set(0)
        st["packed_mask"] = st["packed_mask"].at[slot].set(False)
        st["predictor"] = st["predictor"].at[slot].set(False)
        st["counter"] = st["counter"].at[slot].set(self._counter_init)
        self.tokens_b[slot] = 0
        self._dirty_b[slot] = False
        self._uncounted_b[slot] = False
        self._last_enabled[slot] = self.policy != "off"
        self.tokens = int(self.tokens_b.max())

    def slot_enabled_from_counter(self, counter: int) -> bool:
        """The gate a slot with this counter runs under (policy-resolved)."""
        if self.policy == "off":
            return False
        if self.policy == "static":
            return True
        return counter >= ENABLE_THRESHOLD

    def slot_reference_state(self, slot: int) -> dict:
        """Per-slot from-scratch rebuild over the slot's OWN active prefix,
        under the gate applied at its last repack — the bit-exactness
        oracle for slot-level operations (spill round-trips, slot reuse)."""
        g = self.slot_groups(slot)
        assert g > 0, "empty slot has no reference state"
        lanes = self.group_lanes
        pages = self.pages_view()[slot, : g * lanes]
        if self._last_enabled[slot]:
            build = (kops.build_cram_cache if self.packing == "pair"
                     else kops.build_cram_cache_quad)
            c = dict(build(pages, key=self.key, interpret=self.interpret))
        else:
            grouped = pages.reshape(g, lanes, self.page, self.n_kv, self.d2)
            over = (grouped[:, 1] if self.packing == "pair"
                    else grouped[:, 1:])
            c = {
                "slots": grouped[:, 0],
                "slots_overflow": over,
                "strips": jnp.zeros(
                    (g, self.n_kv, self.d2 + MARKER_LANES), jnp.int16),
                "packed_mask": jnp.zeros((g,), bool),
            }
        c["markers"] = self.state["markers"][:g]
        return c

    def slot_physical_state(self, slot: int) -> dict:
        """The slot's physical rows over its own active prefix (compare
        against `slot_reference_state`)."""
        g = self.slot_groups(slot)
        st = self.state
        return {"slots": st["slots"][slot, :g],
                "slots_overflow": st["slots_overflow"][slot, :g],
                "strips": st["strips"][slot, :g],
                "packed_mask": st["packed_mask"][slot, :g],
                "markers": st["markers"][:g]}


__all__ = ["SlotKVCache"]
