"""Mixture-of-Experts layer with capacity-based sort-free dispatch.

Top-k routing -> argsort by expert -> scatter into a static (E, C, D)
dispatch buffer -> batched expert matmuls -> weighted scatter-add combine.
Expert weights carry the "experts" logical axis, sharded over the `model`
mesh axis (expert parallelism); under pjit the dispatch scatter lowers to an
all-to-all-like collective.

Tokens beyond an expert's capacity C = ceil(T*k/E * capacity_factor) are
dropped (their gate contribution is lost), the standard static-shape
discipline for TPU MoE.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import mlp_apply, mlp_init


def moe_init(ini, cfg, prefix_axes=()):
    ax = lambda *a: prefix_axes + a
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    p = {
        "router": ini.normal((d, e), ax("embed", "experts"), scale=0.02),
        "w1": ini.normal((e, d, f), ax("experts", "embed", "mlp")),
        "w2": ini.normal((e, f, d), ax("experts", "mlp", "embed")),
    }
    if cfg.mlp_act == "swiglu":
        p["w3"] = ini.normal((e, d, f), ax("experts", "embed", "mlp"))
    if cfg.shared_expert_ff:
        p["shared"] = mlp_init(ini, d, cfg.shared_expert_ff, cfg.mlp_act,
                               prefix_axes)
    return p


def moe_apply(p, cfg, x):
    """x: (B, S, D) -> (y, aux_loss)."""
    B, S, D = x.shape
    T = B * S
    E, k = cfg.n_experts, cfg.top_k
    cap = int((T * k / E) * cfg.capacity_factor + 0.999)
    cap = max(cap, 1)

    xf = x.reshape(T, D)
    logits = (xf @ p["router"].astype(x.dtype)).astype(jnp.float32)  # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, k)                            # (T,k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch-style)
    density = jnp.mean(
        jax.nn.one_hot(eidx[:, 0], E, dtype=jnp.float32), axis=0)
    mean_prob = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(density * mean_prob)

    # sort-free capacity dispatch
    flat_e = eidx.reshape(T * k)
    order = jnp.argsort(flat_e, stable=True)            # (T*k,) sorted by e
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=E)
    offsets = jnp.cumsum(counts) - counts               # exclusive prefix
    rank = jnp.arange(T * k) - offsets[sorted_e]        # slot within expert
    keep = rank < cap
    dest = sorted_e * cap + jnp.where(keep, rank, 0)

    tok = order // k
    buf = jnp.zeros((E * cap, D), x.dtype)
    buf = buf.at[dest].add(jnp.where(keep[:, None], xf[tok], 0))
    buf = buf.reshape(E, cap, D)

    h1 = jnp.einsum("ecd,edf->ecf", buf, p["w1"].astype(x.dtype))
    if cfg.mlp_act == "swiglu":
        h = jax.nn.silu(h1) * jnp.einsum(
            "ecd,edf->ecf", buf, p["w3"].astype(x.dtype))
    elif cfg.mlp_act == "relu2":
        h = jnp.square(jax.nn.relu(h1))
    else:
        h = jax.nn.gelu(h1)
    out = jnp.einsum("ecf,efd->ecd", h, p["w2"].astype(x.dtype))
    out = out.reshape(E * cap, D)

    g_sorted = gates.reshape(T * k)[order]
    contrib = out[dest] * (g_sorted * keep)[:, None].astype(x.dtype)
    y = jnp.zeros((T, D), x.dtype).at[tok].add(contrib)

    if cfg.shared_expert_ff:
        y = y + mlp_apply(p["shared"], xf, cfg.mlp_act)
    return y.reshape(B, S, D), aux
