"""Mamba2 (SSD — state-space duality) block, chunked-parallel + recurrent decode.

Training/prefill uses the chunked SSD formulation (arXiv:2405.21060 §6):
within a chunk of length c the output is a masked (c x c) matrix product
(the "attention-like" dual form); across chunks a compact recurrent state
h (H, N, P) is carried by a lax.scan.  Decode is the pure recurrence.

State/compute dtype is float32 for stability; projections run in the model's
compute dtype.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import rms_norm


def ssm_init(ini, cfg, prefix_axes=()):
    ax = lambda *a: prefix_axes + a
    d, din = cfg.d_model, cfg.d_inner
    H, N, G, K = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_ngroups, cfg.ssm_conv
    return {
        "wz": ini.normal((d, din), ax("embed", "mlp")),
        "wx": ini.normal((d, din), ax("embed", "mlp")),
        "wB": ini.normal((d, G * N), ax("embed", None)),
        "wC": ini.normal((d, G * N), ax("embed", None)),
        "wdt": ini.normal((d, H), ax("embed", None)),
        "conv_x": ini.normal((K, din), ax(None, "mlp"), scale=0.5),
        "conv_B": ini.normal((K, G * N), ax(None, None), scale=0.5),
        "conv_C": ini.normal((K, G * N), ax(None, None), scale=0.5),
        "A_log": ini.const(jnp.zeros(H), ax(None)),
        "D": ini.ones((H,), ax(None)),
        "dt_bias": ini.const(jnp.full(H, -2.0), ax(None)),
        "norm": ini.ones((din,), ax("mlp")),
        "out": ini.normal((din, d), ax("mlp", "embed")),
    }


def _causal_conv(x, w, state=None):
    """Depthwise causal conv. x: (B,S,C), w: (K,C).

    state: (B, K-1, C) trailing context (decode); returns (y, new_state).
    """
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i : i + x.shape[1]] * w[i].astype(x.dtype)
            for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else xp[:, :0]
    return y, new_state


def _project(p, cfg, x):
    z = x @ p["wz"].astype(x.dtype)
    xin = x @ p["wx"].astype(x.dtype)
    B_ = x @ p["wB"].astype(x.dtype)
    C_ = x @ p["wC"].astype(x.dtype)
    dt = (x @ p["wdt"].astype(x.dtype)).astype(jnp.float32)
    dt = jax.nn.softplus(dt + p["dt_bias"].astype(jnp.float32))
    return z, xin, B_, C_, dt


def ssm_apply(p, cfg, x):
    """Chunked SSD forward. x: (B,S,D) -> (B,S,D)."""
    Bb, S, _ = x.shape
    H, N, G, P = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_ngroups, cfg.ssm_headdim
    c = min(cfg.ssm_chunk, S)
    assert S % c == 0, (S, c)
    nc = S // c
    hpg = H // G

    z, xin, B_, C_, dt = _project(p, cfg, x)
    xin, _ = _causal_conv(xin, p["conv_x"])
    B_, _ = _causal_conv(B_, p["conv_B"])
    C_, _ = _causal_conv(C_, p["conv_C"])
    xin, B_, C_ = jax.nn.silu(xin), jax.nn.silu(B_), jax.nn.silu(C_)

    A = -jnp.exp(p["A_log"].astype(jnp.float32))            # (H,)
    xh = xin.reshape(Bb, nc, c, H, P).astype(jnp.float32)
    Bh = B_.reshape(Bb, nc, c, G, N).astype(jnp.float32)
    Ch = C_.reshape(Bb, nc, c, G, N).astype(jnp.float32)
    dts = dt.reshape(Bb, nc, c, H)
    a = dts * A                                              # (B,nc,c,H)
    cum = jnp.cumsum(a, axis=2)                              # within-chunk

    def chunk_step(h, xs):
        xc, Bc, Cc, ac, cumc, dtc = xs                       # per chunk
        # intra-chunk: w[i,j] = (C_i . B_j) * exp(cum_i - cum_j) * dt_j, i>=j
        CB = jnp.einsum("bign,bjgn->bijg", Cc, Bc)           # (B,c,c,G)
        CB = jnp.repeat(CB, hpg, axis=-1)                    # (B,c,c,H)
        decay = jnp.exp(
            cumc[:, :, None, :] - cumc[:, None, :, :])       # (B,c,c,H)
        mask = jnp.tril(jnp.ones((c, c), bool))
        w = jnp.where(mask[None, :, :, None], CB * decay, 0.0)
        y_intra = jnp.einsum("bijh,bjhp->bihp", w * dtc[:, None, :, :], xc)
        # inter-chunk: contribution of carried state
        state_decay = jnp.exp(cumc)                          # (B,c,H)
        Ch_heads = jnp.repeat(Cc, hpg, axis=2).reshape(Bb, c, H, N)
        y_inter = jnp.einsum("bchn,bhnp->bchp", Ch_heads, h) \
            * state_decay[..., None]
        # state update: h' = exp(sum a) h + sum_j exp(cum_last - cum_j) dt_j B_j x_j
        tail = jnp.exp(cumc[:, -1:, :] - cumc)               # (B,c,H)
        Bh_heads = jnp.repeat(Bc, hpg, axis=2).reshape(Bb, c, H, N)
        dstate = jnp.einsum(
            "bchn,bchp->bhnp", Bh_heads * (tail * dtc)[..., None], xc)
        h_new = h * jnp.exp(cumc[:, -1, :])[..., None, None] + dstate
        return h_new, y_intra + y_inter

    h0 = jnp.zeros((Bb, H, N, P), jnp.float32)
    xs_all = (xh.transpose(1, 0, 2, 3, 4), Bh.transpose(1, 0, 2, 3, 4),
              Ch.transpose(1, 0, 2, 3, 4), a.transpose(1, 0, 2, 3),
              cum.transpose(1, 0, 2, 3), dts.transpose(1, 0, 2, 3))
    if cfg.unroll:
        hcur, ys_list = h0, []
        for i in range(nc):
            hcur, yi = chunk_step(hcur, tuple(t[i] for t in xs_all))
            ys_list.append(yi)
        ys = jnp.stack(ys_list)
    else:
        _, ys = jax.lax.scan(chunk_step, h0, xs_all)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bb, S, H, P)
    y = y + xh.reshape(Bb, S, H, P) * p["D"].astype(jnp.float32)[:, None]
    y = y.reshape(Bb, S, cfg.d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    return y @ p["out"].astype(x.dtype)


def ssm_init_cache(cfg, batch, dtype=jnp.float32):
    H, N, P, K = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_headdim, cfg.ssm_conv
    return {
        "conv_x": jnp.zeros((batch, K - 1, cfg.d_inner), dtype),
        "conv_B": jnp.zeros((batch, K - 1, cfg.ssm_ngroups * N), dtype),
        "conv_C": jnp.zeros((batch, K - 1, cfg.ssm_ngroups * N), dtype),
        "h": jnp.zeros((batch, H, N, P), jnp.float32),
    }


def ssm_decode_step(p, cfg, x, cache):
    """Recurrent step. x: (B,1,D) -> (y (B,1,D), new_cache)."""
    Bb = x.shape[0]
    H, N, G, P = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_ngroups, cfg.ssm_headdim
    hpg = H // G
    z, xin, B_, C_, dt = _project(p, cfg, x)
    xin, conv_x = _causal_conv(xin, p["conv_x"], cache["conv_x"])
    B_, conv_B = _causal_conv(B_, p["conv_B"], cache["conv_B"])
    C_, conv_C = _causal_conv(C_, p["conv_C"], cache["conv_C"])
    xin, B_, C_ = jax.nn.silu(xin), jax.nn.silu(B_), jax.nn.silu(C_)

    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dt1 = dt[:, 0]                                          # (B,H)
    a = jnp.exp(dt1 * A)                                    # (B,H)
    xh = xin.reshape(Bb, H, P).astype(jnp.float32)
    Bh = jnp.repeat(B_.reshape(Bb, G, N), hpg, axis=1)      # (B,H,N)
    Ch = jnp.repeat(C_.reshape(Bb, G, N), hpg, axis=1)
    h = cache["h"] * a[..., None, None] + jnp.einsum(
        "bhn,bhp->bhnp", Bh * dt1[..., None], xh)
    y = jnp.einsum("bhn,bhnp->bhp", Ch, h)
    y = y + xh * p["D"].astype(jnp.float32)[:, None]
    y = y.reshape(Bb, 1, cfg.d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    out = y @ p["out"].astype(x.dtype)
    new_cache = {"conv_x": conv_x, "conv_B": conv_B, "conv_C": conv_C, "h": h}
    return out, new_cache
