"""Model configuration and parameter/axes utilities.

Pure-JAX module system: parameters are nested dicts of arrays; every init
function also produces a parallel tree of *logical axis names* per parameter
dimension (e.g. ("layers", "embed", "heads")).  The runtime sharding rules
(runtime/sharding.py) map logical axes onto mesh axes, falling back to
replication when a dimension is not divisible by the mesh axis size.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"   # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 512
    vocab: int = 1024
    head_dim: int = 0       # 0 -> d_model // n_heads
    mlp_act: str = "swiglu"  # swiglu | relu2 | gelu
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    tie_embeddings: bool = True
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 1
    moe_every: int = 1          # a MoE MLP every k-th layer (1 = all layers)
    shared_expert_ff: int = 0   # llama4-style always-on shared expert
    capacity_factor: float = 1.25
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 128
    # --- hybrid (zamba2): shared attention block every k ssm layers ---
    attn_every: int = 0
    # --- enc-dec (whisper) ---
    enc_layers: int = 0
    dec_layers: int = 0
    # --- vlm ---
    cross_attn_every: int = 0   # a cross-attn layer every k-th layer
    n_image_tokens: int = 0
    # --- numerics / training ---
    dtype: Any = jnp.bfloat16        # activation / compute dtype
    param_dtype: Any = jnp.float32   # parameter storage dtype
    optimizer_dtype: Any = jnp.float32  # AdamW moment dtype (bf16 for 400B)
    remat: bool = True
    microbatches: int = 4    # grad-accumulation steps per train step
    # unroll all internal lax.scan/map loops (cost-probe mode: XLA's
    # cost_analysis counts a scan body once, so roofline probes lower an
    # unrolled, depth-reduced copy and extrapolate — launch/dryrun.py)
    unroll: bool = False
    # Megatron-SP style: explicitly gather the sequence ONCE per attention
    # (q/k/v constrained to seq-unsharded, heads-sharded) instead of letting
    # SPMD re-gather per blockwise chunk.  §Perf iteration 2 (launch/
    # variants.py "attn_gather"); big collective-term win on train cells.
    attn_gather: bool = False
    attn_q_chunk: int = 512
    attn_k_chunk: int = 1024
    xent_chunk: int = 512
    max_seq: int = 4096

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS roofline terms)."""
        from .zoo import count_params
        return count_params(self)


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduce any architecture config to CPU-smoke-test size, preserving the
    family and every structural feature (GQA ratio, MoE, hybrid pattern...)."""
    kw: dict[str, Any] = dict(
        d_model=128,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 4) if cfg.n_kv_heads else 4),
        head_dim=32,
        d_ff=256,
        vocab=512,
        dtype=jnp.float32,
        param_dtype=jnp.float32,
        remat=False,
        attn_q_chunk=64,
        attn_k_chunk=64,
        xent_chunk=64,
        max_seq=128,
    )
    if cfg.family == "moe":
        layers = max(2, 2 * max(cfg.moe_every, 1))
        kw.update(n_layers=layers, n_experts=min(cfg.n_experts, 8),
                  top_k=min(cfg.top_k, 4), d_ff=64,
                  shared_expert_ff=64 if cfg.shared_expert_ff else 0)
    elif cfg.family == "ssm":
        kw.update(n_layers=2, ssm_state=min(cfg.ssm_state, 32),
                  ssm_headdim=32, ssm_chunk=32)
    elif cfg.family == "hybrid":
        kw.update(n_layers=2 * max(cfg.attn_every, 1),
                  ssm_state=min(cfg.ssm_state, 32), ssm_headdim=32,
                  ssm_chunk=32, attn_every=max(cfg.attn_every, 1))
    elif cfg.family == "encdec":
        kw.update(enc_layers=2, dec_layers=2, n_layers=2)
    elif cfg.family == "vlm":
        kw.update(n_layers=2 * max(cfg.cross_attn_every, 1),
                  cross_attn_every=max(cfg.cross_attn_every, 1),
                  n_image_tokens=16)
    else:
        kw.update(n_layers=2)
    return cfg.replace(**kw)


# --------------------------------------------------------------------------
# Parameter tree construction: values + logical axes in parallel
# --------------------------------------------------------------------------

class Initializer:
    """Collects (value, axes) pairs while building a parameter tree.

    With abstract=True every method returns jax.ShapeDtypeStruct instead of
    a real array: the whole parameter tree (and its logical axes) can be
    constructed with zero allocation — this is what the multi-pod dry-run
    lowers against.
    """

    def __init__(self, key, param_dtype=jnp.float32, abstract: bool = False):
        self._key = key
        self.param_dtype = param_dtype
        self.abstract = abstract

    def next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def _make(self, shape, fill) -> Any:
        if self.abstract:
            return jax.ShapeDtypeStruct(tuple(shape), self.param_dtype)
        return fill()

    def normal(self, shape, axes, scale=None):
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
        val = self._make(shape, lambda: (
            jax.random.normal(self.next_key(), shape, jnp.float32) * scale
        ).astype(self.param_dtype))
        return val, axes

    def zeros(self, shape, axes):
        return self._make(shape, lambda: jnp.zeros(shape, self.param_dtype)), axes

    def ones(self, shape, axes):
        return self._make(shape, lambda: jnp.ones(shape, self.param_dtype)), axes

    def const(self, value, axes):
        shape = jnp.shape(value)
        return self._make(
            shape, lambda: jnp.asarray(value, self.param_dtype)), axes


def split_tree(tree):
    """Split a tree of (value, axes) leaf pairs into (values, axes) trees."""
    is_leaf = lambda x: isinstance(x, tuple) and len(x) == 2 and (
        isinstance(x[1], tuple)
        and all(a is None or isinstance(a, str) for a in x[1])
    )
    values = jax.tree.map(lambda x: x[0], tree, is_leaf=is_leaf)
    axes = jax.tree.map(lambda x: x[1], tree, is_leaf=is_leaf)
    return values, axes


def tree_size(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def cast_tree(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating)
        else x,
        tree,
    )
