"""Pure-JAX model zoo: dense/MoE/SSM/hybrid/enc-dec/VLM backbones."""

from .common import ModelConfig, smoke_config
from .zoo import (
    Model,
    SHAPES_BY_NAME,
    STANDARD_SHAPES,
    ShapeSpec,
    active_params,
    build,
    cache_specs,
    count_params,
    input_specs,
)

__all__ = [
    "Model", "ModelConfig", "ShapeSpec", "STANDARD_SHAPES", "SHAPES_BY_NAME",
    "active_params", "build", "cache_specs", "count_params", "input_specs",
    "smoke_config",
]
