"""Shared layers: norms, RoPE, MLP variants, embedding, chunked cross-entropy."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x, weight, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(dt)


# ------------------------------------------------------------------ RoPE
def rope_freqs(head_dim: int, theta: float = 10_000.0):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (...,S,hd/2)
    angles = angles[..., None, :]                      # (..., S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, dim: int):
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, dim, 2, dtype=jnp.float32)
                  * (-jnp.log(10000.0) / dim))
    pe = jnp.zeros((seq, dim), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# ------------------------------------------------------------------ MLPs
def mlp_apply(p: dict, x, act: str):
    """SwiGLU (w1,w3,w2), squared-ReLU (w1,w2) or GELU (w1,w2)."""
    if act == "swiglu":
        h = jax.nn.silu(x @ p["w1"].astype(x.dtype)) * (
            x @ p["w3"].astype(x.dtype))
    elif act == "relu2":
        h = jnp.square(jax.nn.relu(x @ p["w1"].astype(x.dtype)))
    elif act == "gelu":
        h = jax.nn.gelu(x @ p["w1"].astype(x.dtype))
    else:
        raise ValueError(f"unknown mlp_act {act!r}")
    return h @ p["w2"].astype(x.dtype)


def mlp_init(ini, d_model: int, d_ff: int, act: str, prefix_axes=()):
    ax = lambda *a: prefix_axes + a
    p = {
        "w1": ini.normal((d_model, d_ff), ax("embed", "mlp")),
        "w2": ini.normal((d_ff, d_model), ax("mlp", "embed")),
    }
    if act == "swiglu":
        p["w3"] = ini.normal((d_model, d_ff), ax("embed", "mlp"))
    return p


# --------------------------------------------------- chunked cross-entropy
def chunked_softmax_xent(h, embed, labels, chunk: int = 512,
                         label_mask=None, unroll: bool = False):
    """Cross-entropy with logits never materialized at full (B,S,V).

    h: (B, S, D) final hidden states; embed: (V, D) tied output embedding;
    labels: (B, S) int32.  Scans over sequence chunks, computing each chunk's
    logits -> logsumexp -> NLL and discarding them.  Returns mean NLL.
    """
    B, S, D = h.shape
    chunk = min(chunk, S)
    n_chunks = S // chunk
    rem = S - n_chunks * chunk
    wt = embed.astype(h.dtype)

    @jax.checkpoint
    def one_chunk(hc, yc, mc):
        # rematerialized in backward: the (B, c, V) logits block never
        # survives the chunk — O(V * chunk) live memory, not O(V * S).
        logits = (hc @ wt.T).astype(jnp.float32)          # (B, c, V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, yc[..., None].astype(jnp.int32), axis=-1)[..., 0]
        nll = (lse - gold) * mc
        return nll.sum(), mc.sum()

    def body(carry, xs):
        tot, cnt = carry
        hc, yc, mc = xs
        s, c = one_chunk(hc, yc, mc)
        return (tot + s, cnt + c), None

    if label_mask is None:
        label_mask = jnp.ones_like(labels, jnp.float32)
    hs = h[:, : n_chunks * chunk].reshape(B, n_chunks, chunk, D)
    ys = labels[:, : n_chunks * chunk].reshape(B, n_chunks, chunk)
    ms = label_mask[:, : n_chunks * chunk].reshape(B, n_chunks, chunk)
    if unroll:
        tot = cnt = jnp.zeros((), jnp.float32)
        for i in range(n_chunks):
            s, c = one_chunk(hs[:, i], ys[:, i], ms[:, i])
            tot, cnt = tot + s, cnt + c
    else:
        (tot, cnt), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
            (hs.transpose(1, 0, 2, 3), ys.transpose(1, 0, 2),
             ms.transpose(1, 0, 2)),
        )
    if rem:
        s, c = one_chunk(h[:, -rem:], labels[:, -rem:], label_mask[:, -rem:])
        tot, cnt = tot + s, cnt + c
    return tot / jnp.maximum(cnt, 1.0)


def logits_last(h_last, embed):
    """(B, D) x (V, D) -> (B, V) logits for the decode step."""
    return (h_last @ embed.astype(h_last.dtype).T).astype(jnp.float32)
