"""Whisper-style encoder-decoder backbone.

The audio frontend (log-mel + conv downsampling) is a STUB per the
assignment: `input_specs()` provides precomputed frame embeddings
(B, S, d_model), and the encoder consumes them directly (sinusoidal
positions + bidirectional self-attention).  The decoder is a standard
causal transformer with cross-attention; output projection is tied to the
token embedding.  LayerNorm (with bias) matches the Whisper family; QKV
biases are omitted (noted in DESIGN.md).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import attention_apply, attention_init
from .common import Initializer, ModelConfig, split_tree
from .layers import (
    chunked_softmax_xent,
    layer_norm,
    logits_last,
    mlp_apply,
    mlp_init,
    sinusoidal_positions,
)
from .transformer import _Stacked


def _ln_init(ini, d):
    return {"w": ini.ones((d,), ("embed",)), "b": ini.zeros((d,), ("embed",))}


def _ln(x, p):
    return layer_norm(x, p["w"], p["b"])


def _enc_block_init(ini, cfg):
    return {
        "ln1": _ln_init(ini, cfg.d_model),
        "attn": attention_init(ini, cfg),
        "ln2": _ln_init(ini, cfg.d_model),
        "mlp": mlp_init(ini, cfg.d_model, cfg.d_ff, cfg.mlp_act),
    }


def _dec_block_init(ini, cfg):
    return {
        "ln1": _ln_init(ini, cfg.d_model),
        "self": attention_init(ini, cfg),
        "ln2": _ln_init(ini, cfg.d_model),
        "cross": attention_init(ini, cfg),
        "ln3": _ln_init(ini, cfg.d_model),
        "mlp": mlp_init(ini, cfg.d_model, cfg.d_ff, cfg.mlp_act),
    }


def init_whisper(cfg: ModelConfig, key, abstract: bool = False):
    ini = Initializer(key, cfg.param_dtype, abstract=abstract)
    enc_s = _Stacked(ini, cfg.enc_layers)
    dec_s = _Stacked(ini, cfg.dec_layers)
    tree = {
        "embed": ini.normal((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                            scale=0.02),
        "pos_dec": ini.normal((cfg.max_seq, cfg.d_model), (None, "embed"),
                              scale=0.02),
        "enc": {"blocks": _enc_block_init(enc_s, cfg),
                "ln": _ln_init(ini, cfg.d_model)},
        "dec": {"blocks": _dec_block_init(dec_s, cfg),
                "ln": _ln_init(ini, cfg.d_model)},
    }
    return split_tree(tree)


def encode(params, cfg: ModelConfig, frames):
    """frames: (B, S_enc, d_model) stub embeddings -> (B, S_enc, D)."""
    x = frames.astype(cfg.dtype)
    x = x + sinusoidal_positions(frames.shape[1], cfg.d_model).astype(x.dtype)

    def body(x, p):
        h, _ = attention_apply(p["attn"], cfg, _ln(x, p["ln1"]),
                               causal=False, rope=False)
        x = x + h
        x = x + mlp_apply(p["mlp"], _ln(x, p["ln2"]), cfg.mlp_act)
        return x, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    if cfg.unroll:
        for i in range(cfg.enc_layers):
            x, _ = body_fn(x, jax.tree.map(lambda t, i=i: t[i],
                                           params["enc"]["blocks"]))
    else:
        x, _ = jax.lax.scan(body_fn, x, params["enc"]["blocks"])
    return _ln(x, params["enc"]["ln"])


def decode_train(params, cfg: ModelConfig, tokens, enc_out):
    x = params["embed"].astype(cfg.dtype)[tokens]
    S = tokens.shape[1]
    x = x + params["pos_dec"][:S].astype(x.dtype)[None]

    def body(x, p):
        h, _ = attention_apply(p["self"], cfg, _ln(x, p["ln1"]),
                               causal=True, rope=False)
        x = x + h
        h, _ = attention_apply(p["cross"], cfg, _ln(x, p["ln2"]),
                               kv_x=enc_out, causal=False, rope=False)
        x = x + h
        x = x + mlp_apply(p["mlp"], _ln(x, p["ln3"]), cfg.mlp_act)
        return x, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    if cfg.unroll:
        for i in range(cfg.dec_layers):
            x, _ = body_fn(x, jax.tree.map(lambda t, i=i: t[i],
                                           params["dec"]["blocks"]))
    else:
        x, _ = jax.lax.scan(body_fn, x, params["dec"]["blocks"])
    return _ln(x, params["dec"]["ln"])


def whisper_loss(params, cfg: ModelConfig, batch):
    enc_out = encode(params, cfg, batch["frames"])
    h = decode_train(params, cfg, batch["tokens"], enc_out)
    return chunked_softmax_xent(h, params["embed"], batch["labels"],
                                chunk=cfg.xent_chunk)


# ------------------------------------------------------------------ decode
def whisper_init_cache(cfg: ModelConfig, batch: int, max_len: int,
                       enc_len: int | None = None):
    """Self-attention KV + precomputed cross KV per decoder layer."""
    enc_len = enc_len or max_len
    hd, Hkv, L = cfg.hd, cfg.n_kv_heads, cfg.dec_layers
    z = lambda t: jnp.zeros((L, batch, t, Hkv, hd), cfg.dtype)
    return {"k": z(max_len), "v": z(max_len), "xk": z(enc_len), "xv": z(enc_len)}


def whisper_prefill_cross(params, cfg, enc_out, cache):
    """Populate the cross-attention KV from encoder output."""
    B, S, _ = enc_out.shape
    hd, Hkv = cfg.hd, cfg.n_kv_heads

    def per_layer(p):
        k = (enc_out @ p["cross"]["wk"].astype(enc_out.dtype)).reshape(
            B, S, Hkv, hd)
        v = (enc_out @ p["cross"]["wv"].astype(enc_out.dtype)).reshape(
            B, S, Hkv, hd)
        return k, v

    xk, xv = jax.lax.map(per_layer, params["dec"]["blocks"])
    return {**cache, "xk": xk, "xv": xv}


def whisper_decode_step(params, cfg: ModelConfig, token, cache, index):
    """token (B,1); returns (logits (B,V), new_cache)."""
    from .attention import chunked_decode_attention

    B = token.shape[0]
    hd, Hkv, Hq = cfg.hd, cfg.n_kv_heads, cfg.n_heads
    x = params["embed"].astype(cfg.dtype)[token]
    x = x + jax.lax.dynamic_slice_in_dim(
        params["pos_dec"], index, 1, 0).astype(x.dtype)[None, 0]

    def body(x, xs):
        p, kc, vc, xk, xv = xs
        h = _ln(x, p["ln1"])
        q = (h @ p["self"]["wq"].astype(x.dtype)).reshape(B, 1, Hq, hd)
        k = (h @ p["self"]["wk"].astype(x.dtype)).reshape(B, 1, Hkv, hd)
        v = (h @ p["self"]["wv"].astype(x.dtype)).reshape(B, 1, Hkv, hd)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k, index, 1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v, index, 1)
        a = chunked_decode_attention(
            q[:, 0], kc, vc, length=index + 1,
            k_chunk=cfg.attn_k_chunk, unroll=cfg.unroll)
        x = x + a.reshape(B, 1, Hq * hd) @ p["self"]["wo"].astype(x.dtype)
        # cross attention against the precomputed encoder KV
        h = _ln(x, p["ln2"])
        q = (h @ p["cross"]["wq"].astype(x.dtype)).reshape(B, 1, Hq, hd)
        a = chunked_decode_attention(
            q[:, 0], xk, xv, length=xk.shape[1],
            k_chunk=cfg.attn_k_chunk, unroll=cfg.unroll)
        x = x + a.reshape(B, 1, Hq * hd) @ p["cross"]["wo"].astype(x.dtype)
        x = x + mlp_apply(p["mlp"], _ln(x, p["ln3"]), cfg.mlp_act)
        return x, (kc, vc)

    xs_all = (params["dec"]["blocks"], cache["k"], cache["v"], cache["xk"],
              cache["xv"])
    if cfg.unroll:
        ks, vs = [], []
        for i in range(cfg.dec_layers):
            x, (kc, vc) = body(x, jax.tree.map(lambda t, i=i: t[i], xs_all))
            ks.append(kc)
            vs.append(vc)
        nk, nv = jnp.stack(ks), jnp.stack(vs)
    else:
        x, (nk, nv) = jax.lax.scan(body, x, xs_all)
    x = _ln(x, params["dec"]["ln"])
    logits = logits_last(x[:, 0], params["embed"])
    return logits, {**cache, "k": nk, "v": nv}
