"""Blockwise (flash-style) attention in pure JAX.

Memory-bounded attention: scores are only ever materialized for one
(q_chunk x k_chunk) block per step of a lax.scan, with an online-softmax
running (max, denom, acc) state.  This is what lets prefill_32k and
long-context shapes lower without a (B, H, S, S) buffer.

GQA is computed natively (no KV head repetition): q is viewed as
(B, S, n_kv, group, hd) against k/v (B, T, n_kv, hd).

The baseline causal path iterates every (q,k) block pair and masks — the
block-triangular schedule that skips fully-masked blocks is a §Perf
optimization variant (see launch/dryrun.py --variant flags).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _block_attend(q, k, v, bias):
    """One block: q (B,H,qc,D), k/v (B,kc,H,D), bias (qc,kc) or None.

    Returns online-softmax pieces: m (B,H,qc), l (B,H,qc), o (B,H,qc,D).
    """
    s = jnp.einsum("bhqd,bkhd->bhqk", q, k).astype(jnp.float32)
    if bias is not None:
        s = s + bias
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bhqd", p.astype(v.dtype), v)
    return m, l, o.astype(jnp.float32)


def blockwise_attention(
    q, k, v, *, causal: bool, q_offset=0, k_offset=0,
    q_chunk: int = 512, k_chunk: int = 1024, kv_length=None,
    unroll: bool = False,
):
    """q: (B,S,Hq,D), k/v: (B,T,Hkv,D) -> (B,S,Hq,D).

    The q-head dimension is kept whole (TP shards it); KV heads are expanded
    to q heads chunk-by-chunk inside the scan (a broadcast for the local
    shard, never a materialized (B,T,Hq,D) buffer).  Each q-chunk body is
    rematerialized in the backward pass, so peak memory stays
    O(q_chunk x k_chunk) scores per step — flash-attention-style.

    q_offset/k_offset: absolute position of the first q/k element (decode &
    chunked prefill).  kv_length: optional valid KV prefix length (decode
    against a preallocated cache).
    """
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    assert Hq % Hkv == 0
    G = Hq // Hkv
    scale = 1.0 / (D ** 0.5)
    q_chunk = min(q_chunk, S)
    k_chunk = min(k_chunk, T)
    assert S % q_chunk == 0 and T % k_chunk == 0, (S, q_chunk, T, k_chunk)
    nq, nk = S // q_chunk, T // k_chunk

    qb = (q * scale).reshape(B, nq, q_chunk, Hq, D)
    qb = qb.transpose(1, 0, 3, 2, 4)              # (nq, B, Hq, qc, D)
    kb = k.reshape(B, nk, k_chunk, Hkv, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nk, k_chunk, Hkv, D).transpose(1, 0, 2, 3, 4)

    q_pos = jnp.arange(S).reshape(nq, q_chunk) + q_offset
    k_pos = jnp.arange(T).reshape(nk, k_chunk) + k_offset

    def per_qchunk(qc, qpos):
        def kstep(carry, xs):
            m, l, o = carry
            kc, vc, kpos = xs                     # (B, kc, Hkv, D)
            if G > 1:  # expand grouped KV to the (sharded) q heads
                kc = jnp.repeat(kc, G, axis=2)
                vc = jnp.repeat(vc, G, axis=2)
            bias = jnp.zeros((q_chunk, k_chunk), jnp.float32)
            if causal:
                bias = jnp.where(
                    qpos[:, None] >= kpos[None, :], 0.0, NEG_INF)
            if kv_length is not None:
                bias = bias + jnp.where(
                    kpos[None, :] < kv_length, 0.0, NEG_INF)
            bm, bl, bo = _block_attend(qc, kc, vc, bias)
            m_new = jnp.maximum(m, bm)
            alpha = jnp.exp(m - m_new)
            beta = jnp.exp(bm - m_new)
            l_new = l * alpha + bl * beta
            o_new = o * alpha[..., None] + bo * beta[..., None]
            return (m_new, l_new, o_new), None

        m0 = jnp.full((B, Hq, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hq, q_chunk), jnp.float32)
        o0 = jnp.zeros((B, Hq, q_chunk, D), jnp.float32)
        if unroll:
            carry = (m0, l0, o0)
            for j in range(nk):
                carry, _ = kstep(carry, (kb[j], vb[j], k_pos[j]))
            m, l, o = carry
        else:
            (m, l, o), _ = jax.lax.scan(kstep, (m0, l0, o0),
                                        (kb, vb, k_pos))
        out = o / jnp.maximum(l, 1e-30)[..., None]
        return out  # (B, Hq, qc, D)

    if unroll:
        outs = jnp.stack([per_qchunk(qb[i], q_pos[i]) for i in range(nq)])
    else:
        body = jax.checkpoint(per_qchunk)
        outs = jax.lax.map(lambda xs: body(*xs), (qb, q_pos))
    # (nq, B, Hq, qc, D) -> (B, S, Hq, D)
    out = outs.transpose(1, 0, 3, 2, 4).reshape(B, S, Hq, D)
    return out.astype(q.dtype)


def chunked_decode_attention(q, k_cache, v_cache, length,
                             k_chunk: int = 2048, unroll: bool = False):
    """Single-token decode: q (B,Hq,D) against cache (B,T,Hkv,D).

    `length` is the number of valid cache positions (scalar or (B,)).
    Works under pjit with the cache sharded along T (sequence parallel):
    the reductions become cross-shard collectives automatically.
    """
    B, Hq, D = q.shape
    out = blockwise_attention(
        q[:, None], k_cache, v_cache, causal=False,
        q_chunk=1, k_chunk=min(k_chunk, k_cache.shape[1]),
        kv_length=length, unroll=unroll,
    )
    return out[:, 0]


def attention_init(ini, cfg, prefix_axes=(), d_model=None):
    """Projection weights for (GQA) self/cross attention."""
    d = d_model or cfg.d_model
    hd, Hq, Hkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    ax = lambda *a: prefix_axes + a
    p = {
        "wq": ini.normal((d, Hq * hd), ax("embed", "heads")),
        "wk": ini.normal((d, Hkv * hd), ax("embed", "kv_heads")),
        "wv": ini.normal((d, Hkv * hd), ax("embed", "kv_heads")),
        "wo": ini.normal((Hq * hd, d), ax("heads", "embed")),
    }
    if cfg.qk_norm:
        p["q_norm"] = ini.ones((hd,), ax("head_dim"))
        p["k_norm"] = ini.ones((hd,), ax("head_dim"))
    return p


def attention_apply(
    p, cfg, x, *, kv_x=None, causal=True, positions=None, kv_positions=None,
    rope=True, cache=None, cache_index=None,
):
    """GQA attention. x: (B,S,D).

    kv_x: source for K/V (cross-attention) — defaults to x.
    cache: optional dict {k: (B,T,Hkv,hd), v: ...} for decode; cache_index is
      the write position (scalar int32). Returns (out, new_cache).
    """
    from .layers import apply_rope, rms_norm

    B, S, _ = x.shape
    hd, Hq, Hkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    kv_src = x if kv_x is None else kv_x
    Tkv = kv_src.shape[1]
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, Hq, hd)
    k = (kv_src @ p["wk"].astype(x.dtype)).reshape(B, Tkv, Hkv, hd)
    v = (kv_src @ p["wv"].astype(x.dtype)).reshape(B, Tkv, Hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if positions is None:
        positions = jnp.arange(S)[None, :]
    if kv_positions is None:
        # self-attention: K/V positions are the same tokens' positions
        # (crucial at decode time, where S==1 but position==index)
        kv_positions = positions if kv_x is None else \
            jnp.arange(Tkv)[None, :]
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, kv_positions, cfg.rope_theta)

    if cache is None and cfg.attn_gather:
        # Megatron-SP: one explicit seq gather here; all blockwise chunks
        # then slice locally (heads stay model-sharded)
        from ..runtime.sharding import constrain

        q = constrain(q, ("batch", None, "heads", None))
        k = constrain(k, ("batch", None, "kv_heads", None))
        v = constrain(v, ("batch", None, "kv_heads", None))

    new_cache = None
    if cache is not None:
        # decode: append current K/V at cache_index, attend over the prefix
        kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, cache_index, 1)
        vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, cache_index, 1)
        new_cache = {"k": kc, "v": vc}
        out = chunked_decode_attention(
            q[:, 0], kc, vc, length=cache_index + S,
            k_chunk=cfg.attn_k_chunk, unroll=cfg.unroll,
        )[:, None]
    else:
        out = blockwise_attention(
            q, k, v, causal=causal,
            q_chunk=cfg.attn_q_chunk, k_chunk=cfg.attn_k_chunk,
            unroll=cfg.unroll,
        )
    out = out.reshape(B, S, Hq * hd)
    return out @ p["wo"].astype(x.dtype), new_cache
