"""Unified model interface + analytic parameter counting.

`build(cfg)` returns a Model with a uniform API regardless of family:
  init(key) -> (params, axes)
  loss(params, batch) -> scalar
  init_cache(batch, max_len) -> decode cache pytree
  decode_step(params, token, cache, index, **kw) -> (logits, cache)
  input_specs(shape) -> ShapeDtypeStruct pytrees for the dry-run
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

from .common import ModelConfig
from . import transformer as tf
from . import whisper as wh


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "decode"


STANDARD_SHAPES = (
    ShapeSpec("train_4k", 4_096, 256, "train"),
    ShapeSpec("prefill_32k", 32_768, 32, "prefill"),  # forward-only
    ShapeSpec("decode_32k", 32_768, 128, "decode"),
    ShapeSpec("long_500k", 524_288, 1, "decode"),
)
SHAPES_BY_NAME = {s.name: s for s in STANDARD_SHAPES}


@dataclass(frozen=True)
class Model:
    config: ModelConfig
    init: Callable          # key -> (params, axes)
    loss: Callable          # (params, batch) -> scalar
    forward: Callable       # (params, batch) -> hidden states
    init_cache: Callable    # (batch, max_len) -> decode cache
    decode_step: Callable   # (params, token, cache, index) -> (logits, cache)

    def abstract_params(self):
        """(ShapeDtypeStruct params, logical axes) with ZERO allocation.

        Uses the Initializer's abstract mode — this is what the dry-run
        lowers 123B/400B-parameter models against on a CPU container.
        """
        if self.config.family == "encdec":
            return wh.init_whisper(self.config, None, abstract=True)
        return tf.init_lm(self.config, None, abstract=True)


def build(cfg: ModelConfig) -> Model:
    if cfg.family == "encdec":
        init = lambda key: wh.init_whisper(cfg, key)
        loss = lambda p, batch: wh.whisper_loss(p, cfg, batch)
        fwd = lambda p, batch: wh.decode_train(
            p, cfg, batch["tokens"], wh.encode(p, cfg, batch["frames"]))
        icache = lambda batch, max_len, **kw: wh.whisper_init_cache(
            cfg, batch, max_len, **kw)
        dstep = lambda p, tok, cache, idx, **kw: wh.whisper_decode_step(
            p, cfg, tok, cache, idx)
    else:
        init = lambda key: tf.init_lm(cfg, key)
        loss = lambda p, batch: tf.lm_loss(p, cfg, batch)
        fwd = lambda p, batch: tf.lm_forward(
            p, cfg, batch["tokens"],
            image_embeds=batch.get("image_embeds"))[0]
        icache = lambda batch, max_len, **kw: tf.init_cache(
            cfg, batch, max_len, **kw)
        dstep = lambda p, tok, cache, idx, **kw: tf.lm_decode_step(
            p, cfg, tok, cache, idx, **kw)

    return Model(config=cfg, init=init, loss=loss, forward=fwd,
                 init_cache=icache, decode_step=dstep)


# ----------------------------------------------------------- input specs
def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if shape.kind == "train":
            batch["labels"] = jax.ShapeDtypeStruct((B, S), i32)
        if cfg.family == "encdec":
            # decoder teacher-forced over S (DESIGN.md arch notes)
            batch["frames"] = jax.ShapeDtypeStruct(
                (B, S, cfg.d_model), cfg.dtype)
        if cfg.family == "vlm":
            batch["image_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_image_tokens, cfg.d_model), cfg.dtype)
        return batch
    # decode: one new token against a seq_len cache
    spec = {
        "token": jax.ShapeDtypeStruct((B, 1), i32),
        "index": jax.ShapeDtypeStruct((), i32),
    }
    if cfg.family == "vlm":
        spec["image_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_image_tokens, cfg.d_model), cfg.dtype)
    return spec


def cache_specs(cfg: ModelConfig, shape: ShapeSpec):
    m = build(cfg)
    return jax.eval_shape(
        lambda: m.init_cache(shape.global_batch, shape.seq_len))


# --------------------------------------------------------- param counting
def count_params(cfg: ModelConfig) -> int:
    d, f, v, hd = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.hd
    Hq, Hkv = cfg.n_heads, cfg.n_kv_heads
    attn = d * Hq * hd * 2 + d * Hkv * hd * 2
    mlp = d * f * (3 if cfg.mlp_act == "swiglu" else 2)
    if cfg.family == "encdec":
        enc = cfg.enc_layers * (attn + mlp)
        dec = cfg.dec_layers * (2 * attn + mlp)
        return v * d + enc + dec
    if cfg.family == "ssm":
        din, H = cfg.d_inner, cfg.ssm_heads
        N, G = cfg.ssm_state, cfg.ssm_ngroups
        per = d * din * 2 + 2 * d * G * N + d * H + din * d
        return v * d + cfg.n_layers * per
    if cfg.family == "hybrid":
        din, H = cfg.d_inner, cfg.ssm_heads
        N, G = cfg.ssm_state, cfg.ssm_ngroups
        per = d * din * 2 + 2 * d * G * N + d * H + din * d
        shared = attn + mlp
        return v * d + cfg.n_layers * per + shared
    if cfg.family == "moe":
        e_mlp = cfg.n_experts * mlp + d * cfg.n_experts
        sh = (cfg.shared_expert_ff * d
              * (3 if cfg.mlp_act == "swiglu" else 2))
        k = max(cfg.moe_every, 1)
        n_moe = cfg.n_layers // k
        n_dense = cfg.n_layers - n_moe
        return (v * d + cfg.n_layers * attn + n_dense * mlp
                + n_moe * (e_mlp + sh))
    per = attn + mlp
    if cfg.family == "vlm":
        k = max(cfg.cross_attn_every, 1)
        n_cross = cfg.n_layers // k
        return v * d + cfg.n_layers * per + n_cross * attn  # + cross extras
    return v * d + cfg.n_layers * per


def active_params(cfg: ModelConfig) -> int:
    """Active parameters per token (MoE: routed top-k + shared only)."""
    if cfg.family != "moe":
        return count_params(cfg)
    d, f = cfg.d_model, cfg.d_ff
    mlp = d * f * (3 if cfg.mlp_act == "swiglu" else 2)
    attn = (cfg.d_model * cfg.n_heads * cfg.hd * 2
            + cfg.d_model * cfg.n_kv_heads * cfg.hd * 2)
    sh = cfg.shared_expert_ff * d * (3 if cfg.mlp_act == "swiglu" else 2)
    k_every = max(cfg.moe_every, 1)
    n_moe = cfg.n_layers // k_every
    n_dense = cfg.n_layers - n_moe
    return (cfg.vocab * d + cfg.n_layers * attn + n_dense * mlp
            + n_moe * (cfg.top_k * mlp + sh + d * cfg.n_experts))
