"""Decoder-only LM covering the dense / moe / ssm / hybrid / vlm families.

The layer stack is organized as `n_supers` repetitions of a *super-block*
(a short list of block kinds), scanned with jax.lax.scan so compile time and
HLO size are O(1) in depth:

  dense   : ["dense"]                        x n_layers
  moe     : ["dense"]*(moe_every-1)+["moe"]  x n_layers/moe_every
  ssm     : ["ssm"]                          x n_layers
  hybrid  : ["ssm"]*attn_every + ["shared"]  x n_layers/attn_every
            ("shared" = zamba2-style transformer block whose parameters are
             shared across all invocations; each invocation has its own KV
             cache at decode time)
  vlm     : ["dense"]*(cross_every-1)+["cross"] x n_layers/cross_every
            ("cross" = cross-attention to stub image embeddings + MLP)

Decode caches mirror the stacked structure: every cached tensor has a
leading (n_supers, ...) dimension and the decode step scans over it in
lockstep with the parameters.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..runtime.sharding import constrain
from .attention import attention_apply, attention_init
from .common import Initializer, ModelConfig, split_tree
from .layers import chunked_softmax_xent, logits_last, mlp_apply, mlp_init, rms_norm
from .moe import moe_apply, moe_init
from .ssm import ssm_apply, ssm_decode_step, ssm_init, ssm_init_cache


class _Stacked:
    """Initializer proxy that prepends a ('layers', n) leading dimension."""

    def __init__(self, ini: Initializer, n: int):
        self._ini, self._n = ini, n

    def normal(self, shape, axes, scale=None):
        return self._ini.normal((self._n,) + tuple(shape),
                                ("layers",) + tuple(axes), scale)

    def zeros(self, shape, axes):
        return self._ini.zeros((self._n,) + tuple(shape),
                               ("layers",) + tuple(axes))

    def ones(self, shape, axes):
        return self._ini.ones((self._n,) + tuple(shape),
                              ("layers",) + tuple(axes))

    def const(self, value, axes):
        v = jnp.asarray(value)
        shape = (self._n,) + v.shape
        if self._ini.abstract:
            val = jax.ShapeDtypeStruct(shape, self._ini.param_dtype)
        else:
            val = jnp.broadcast_to(v, shape).astype(self._ini.param_dtype)
        return val, ("layers",) + tuple(axes)


def super_block_spec(cfg: ModelConfig) -> list[str]:
    fam = cfg.family
    if fam == "dense":
        return ["dense"]
    if fam == "moe":
        k = max(cfg.moe_every, 1)
        return ["dense"] * (k - 1) + ["moe"]
    if fam == "ssm":
        return ["ssm"]
    if fam == "hybrid":
        return ["ssm"] * max(cfg.attn_every, 1) + ["shared"]
    if fam == "vlm":
        k = max(cfg.cross_attn_every, 1)
        return ["dense"] * (k - 1) + ["cross"]
    raise ValueError(f"unknown family {fam!r}")


def n_supers(cfg: ModelConfig) -> int:
    spec = super_block_spec(cfg)
    per = len([k for k in spec if k != "shared"])
    assert cfg.n_layers % per == 0, (cfg.n_layers, spec)
    return cfg.n_layers // per


def _block_init(ini, cfg, kind: str) -> dict:
    if kind == "dense":
        return {
            "ln1": ini.ones((cfg.d_model,), ("embed",)),
            "attn": attention_init(ini, cfg),
            "ln2": ini.ones((cfg.d_model,), ("embed",)),
            "mlp": mlp_init(ini, cfg.d_model, cfg.d_ff, cfg.mlp_act),
        }
    if kind == "moe":
        return {
            "ln1": ini.ones((cfg.d_model,), ("embed",)),
            "attn": attention_init(ini, cfg),
            "ln2": ini.ones((cfg.d_model,), ("embed",)),
            "moe": moe_init(ini, cfg),
        }
    if kind == "ssm":
        return {
            "ln1": ini.ones((cfg.d_model,), ("embed",)),
            "ssm": ssm_init(ini, cfg),
        }
    if kind == "cross":
        return {
            "ln1": ini.ones((cfg.d_model,), ("embed",)),
            "xattn": attention_init(ini, cfg),
            "gate": ini.zeros((), ()),
            "ln2": ini.ones((cfg.d_model,), ("embed",)),
            "mlp": mlp_init(ini, cfg.d_model, cfg.d_ff, cfg.mlp_act),
        }
    raise ValueError(kind)


def init_lm(cfg: ModelConfig, key, abstract: bool = False):
    """Returns (params, logical_axes) trees."""
    ini = Initializer(key, cfg.param_dtype, abstract=abstract)
    spec = super_block_spec(cfg)
    ns = n_supers(cfg)
    sini = _Stacked(ini, ns)
    tree = {
        "embed": ini.normal((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                            scale=0.02),
        "final_ln": ini.ones((cfg.d_model,), ("embed",)),
        "blocks": {
            f"b{i}": _block_init(sini, cfg, kind)
            for i, kind in enumerate(spec) if kind != "shared"
        },
    }
    if "shared" in spec:
        tree["shared"] = _block_init(ini, cfg, "dense")
    return split_tree(tree)


# ----------------------------------------------------------------- forward
def _apply_block(p, cfg, kind, x, *, image_embeds=None, positions=None,
                 cache=None, cache_index=None):
    """One block; returns (x, aux_loss, new_cache)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache = None
    if kind in ("dense", "moe"):
        h, kv = attention_apply(
            p["attn"], cfg, rms_norm(x, p["ln1"]), positions=positions,
            cache=None if cache is None else cache["attn"],
            cache_index=cache_index,
        )
        x = x + h
        h2 = rms_norm(x, p["ln2"])
        if kind == "moe":
            y, aux = moe_apply(p["moe"], cfg, h2)
        else:
            y = mlp_apply(p["mlp"], h2, cfg.mlp_act)
        x = x + y
        new_cache = None if cache is None else {"attn": kv}
    elif kind == "ssm":
        if cache is None:
            x = x + ssm_apply(p["ssm"], cfg, rms_norm(x, p["ln1"]))
        else:
            y, sc = ssm_decode_step(p["ssm"], cfg, rms_norm(x, p["ln1"]),
                                    cache["ssm"])
            x = x + y
            new_cache = {"ssm": sc}
    elif kind == "cross":
        h, _ = attention_apply(
            p["xattn"], cfg, rms_norm(x, p["ln1"]), kv_x=image_embeds,
            causal=False, rope=False,
        )
        x = x + jnp.tanh(p["gate"]).astype(x.dtype) * h
        x = x + mlp_apply(p["mlp"], rms_norm(x, p["ln2"]), cfg.mlp_act)
        new_cache = None if cache is None else {}
    else:
        raise ValueError(kind)
    return x, aux, new_cache


def lm_forward(params, cfg: ModelConfig, tokens, image_embeds=None):
    """tokens (B,S) -> hidden states (B,S,D) + aux loss."""
    spec = super_block_spec(cfg)
    x = params["embed"].astype(cfg.dtype)[tokens]
    x = constrain(x, ("batch", "seq", "embed"))
    positions = jnp.arange(tokens.shape[1])[None, :]

    def super_body(carry, block_params):
        x, aux = carry
        for i, kind in enumerate(spec):
            if kind == "shared":
                x, a, _ = _apply_block(params["shared"], cfg, "dense", x,
                                       positions=positions,
                                       image_embeds=image_embeds)
            else:
                x, a, _ = _apply_block(block_params[f"b{i}"], cfg, kind, x,
                                       positions=positions,
                                       image_embeds=image_embeds)
            aux = aux + a
        x = constrain(x, ("batch", "seq", "embed"))
        return (x, aux), None

    body = jax.checkpoint(super_body) if cfg.remat else super_body
    carry = (x, jnp.zeros((), jnp.float32))
    if cfg.unroll:
        ns = n_supers(cfg)
        for i in range(ns):
            bp = jax.tree.map(lambda t, i=i: t[i], params["blocks"])
            carry, _ = body(carry, bp)
        x, aux = carry
    else:
        (x, aux), _ = jax.lax.scan(body, carry, params["blocks"])
    x = rms_norm(x, params["final_ln"])
    return x, aux


def lm_loss(params, cfg: ModelConfig, batch):
    """batch: {tokens (B,S), labels (B,S), [image_embeds]} -> scalar loss."""
    h, aux = lm_forward(params, cfg, batch["tokens"],
                        image_embeds=batch.get("image_embeds"))
    nll = chunked_softmax_xent(h, params["embed"], batch["labels"],
                               chunk=cfg.xent_chunk, unroll=cfg.unroll)
    return nll + 0.01 * aux


# ------------------------------------------------------------------ decode
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               image_embeds=None):
    """Stacked decode cache: every leaf has a leading (n_supers,) dim."""
    spec = super_block_spec(cfg)
    ns = n_supers(cfg)
    hd, Hkv = cfg.hd, cfg.n_kv_heads

    def kv(b):
        return {
            "k": jnp.zeros((ns, b, max_len, Hkv, hd), cfg.dtype),
            "v": jnp.zeros((ns, b, max_len, Hkv, hd), cfg.dtype),
        }

    cache = {}
    for i, kind in enumerate(spec):
        if kind in ("dense", "moe"):
            cache[f"b{i}"] = {"attn": kv(batch)}
        elif kind == "ssm":
            c = ssm_init_cache(cfg, batch)
            cache[f"b{i}"] = {
                "ssm": jax.tree.map(
                    lambda x: jnp.broadcast_to(
                        x[None], (ns,) + x.shape).astype(x.dtype), c)
            }
        elif kind == "shared":
            cache[f"b{i}"] = {"attn": kv(batch)}
        elif kind == "cross":
            cache[f"b{i}"] = {}
    return cache


def lm_decode_step(params, cfg: ModelConfig, token, cache, index,
                   image_embeds=None):
    """token (B,1) int32; index: scalar int32 current position.

    Returns (logits (B,V), new_cache).
    """
    spec = super_block_spec(cfg)
    x = params["embed"].astype(cfg.dtype)[token]
    positions = jnp.full((1, 1), index, jnp.int32)

    def super_body(x, xs):
        block_params, block_cache = xs
        new_cache = {}
        for i, kind in enumerate(spec):
            if kind == "shared":
                x, _, nc = _apply_block(
                    params["shared"], cfg, "dense", x, positions=positions,
                    cache=block_cache[f"b{i}"], cache_index=index)
            else:
                x, _, nc = _apply_block(
                    block_params.get(f"b{i}", {}), cfg, kind, x,
                    positions=positions, image_embeds=image_embeds,
                    cache=block_cache[f"b{i}"], cache_index=index)
            new_cache[f"b{i}"] = nc if nc is not None else {}
        return x, new_cache

    if cfg.unroll:
        ns = n_supers(cfg)
        caches = []
        for i in range(ns):
            bp = jax.tree.map(lambda t, i=i: t[i], params["blocks"])
            bc = jax.tree.map(lambda t, i=i: t[i], cache)
            x, nc = super_body(x, (bp, bc))
            caches.append(nc)
        new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
    else:
        x, new_cache = jax.lax.scan(super_body, x,
                                    (params["blocks"], cache))
    x = rms_norm(x, params["final_ln"])
    logits = logits_last(x[:, 0], params["embed"])
    return logits, new_cache
