"""AdamW with ZeRO-1-style optimizer-state sharding and grad clipping.

The optimizer state (m, v) mirrors the parameter tree; its sharding spec is
the parameter spec *plus* the data axis on the largest still-replicated
dimension (runtime.sharding.zero_spec), which is exactly ZeRO-1: every data
shard owns a slice of the moments, XLA inserts the reduce-scatter/all-gather
pair around the update.

Moments may be stored in bf16 (cfg.optimizer_dtype) for the 400B-class
models; the update math always runs in fp32.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclass
class TrainState:
    params: Any
    m: Any
    v: Any
    step: jax.Array
    dyn_counter: jax.Array  # Dynamic-CRAM-style gate for grad compression


def adamw_init(params, moment_dtype=jnp.float32) -> TrainState:
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    return TrainState(
        params=params,
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
        step=jnp.zeros((), jnp.int32),
        dyn_counter=jnp.asarray(2048 + 128, jnp.int32),
    )


def abstract_opt_state(param_shapes, moment_dtype=jnp.float32) -> TrainState:
    sds = lambda p: jax.ShapeDtypeStruct(p.shape, moment_dtype)
    return TrainState(
        params=param_shapes,
        m=jax.tree.map(sds, param_shapes),
        v=jax.tree.map(sds, param_shapes),
        step=jax.ShapeDtypeStruct((), jnp.int32),
        dyn_counter=jax.ShapeDtypeStruct((), jnp.int32),
    )


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)))


def adamw_update(
    state: TrainState, grads, *, lr, b1=0.9, b2=0.95, eps=1e-8,
    weight_decay=0.1, clip_norm=1.0,
) -> TrainState:
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * b1 + g * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + jnp.square(g) * (1 - b2)
        mhat = m32 / (1 - b1 ** step.astype(jnp.float32))
        vhat = v32 / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(
            jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    out = jax.tree.map(upd, state.params, grads, state.m, state.v)
    params = jax.tree.map(lambda t: t[0], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    m = jax.tree.map(lambda t: t[1], out,
                     is_leaf=lambda x: isinstance(x, tuple))
    v = jax.tree.map(lambda t: t[2], out,
                     is_leaf=lambda x: isinstance(x, tuple))
    return dataclasses.replace(state, params=params, m=m, v=v, step=step)


def cosine_lr(step, *, peak=3e-4, warmup=100, total=10_000, floor=3e-5):
    step = step.astype(jnp.float32)
    warm = peak * step / max(warmup, 1)
    t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < warmup, warm, cos)


def make_train_step(model, *, lr_peak=3e-4, lr_total=10_000,
                    grad_compress=None, microbatches=None):
    """Returns train_step(state, batch) -> (state, metrics).

    microbatches > 1 scans grad-accumulation over batch slices, cutting
    activation memory ~k-fold (the knob that fits the 123B/400B train cells
    in 16GB/chip).  grad_compress: optional callable grads->grads (e.g.
    int8 error-feedback compression in the explicit-collective path).
    """
    cfg = model.config

    def grads_of(params, batch):
        return jax.value_and_grad(model.loss)(params, batch)

    def train_step(state: TrainState, batch):
        B = jax.tree.leaves(batch)[0].shape[0]
        mb = microbatches or cfg.microbatches
        while B % mb:
            mb -= 1
        if mb <= 1:
            loss, grads = grads_of(state.params, batch)
        else:
            split = jax.tree.map(
                lambda x: x.reshape((mb, x.shape[0] // mb) + x.shape[1:]),
                batch)
            acc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)

            def body(carry, mbatch):
                lsum, acc = carry
                l, g = grads_of(state.params, mbatch)
                acc = jax.tree.map(
                    lambda a, gi: a + gi.astype(jnp.float32), acc, g)
                return (lsum + l, acc), None

            (lsum, acc), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), acc0), split)
            loss = lsum / mb
            grads = jax.tree.map(lambda g: g / mb, acc)
        if grad_compress is not None:
            grads = grad_compress(grads)
        lr = cosine_lr(state.step, peak=lr_peak, total=lr_total)
        new_state = adamw_update(state, grads, lr=lr)
        metrics = {"loss": loss, "lr": lr, "gnorm": global_norm(grads)}
        return new_state, metrics

    return train_step
