"""Optimizers and gradient-compression utilities."""

from .adamw import TrainState, adamw_init, adamw_update, make_train_step

__all__ = ["TrainState", "adamw_init", "adamw_update", "make_train_step"]
