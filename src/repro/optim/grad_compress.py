"""Dynamic compressed gradient collectives (the paper's §VI applied to DP).

int8 per-tensor quantization with error feedback around an explicit psum
(shard_map path).  THE Dynamic-CRAM saturating counter
(repro.compression.gate) gates the mechanism at runtime: benefit = bytes
saved on the wire, cost = quality signal (relative quantization error) — if
the gradient distribution makes int8 too lossy, compression turns itself
off, exactly like the paper's compression gate.  Lossless CRAM/BDI line
packing is also measured on the gradient bytes (reported by benchmarks;
real bf16 gradients rarely pack, which is itself a finding consistent with
Fig. 4's data-dependence).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..bandwidth.adapters import (
    grad_wire_event,
    int8_wire_bytes,
    tree_wire_bytes,
)
from ..compression.gate import (  # noqa: F401  (COUNTER_MAX re-exported)
    COUNTER_MAX,
    ENABLE_THRESHOLD,
    counter_step,
    wire_counter_step,
)

ENABLE = ENABLE_THRESHOLD  # legacy alias


def quantize_int8(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compress_tree(grads, err):
    """Error-feedback int8 compression of a gradient tree.

    Returns (dequantized grads, new error feedback, rel_err scalar).
    """
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, s = quantize_int8(g32)
        dq = dequantize(q, s)
        return dq.astype(g.dtype), g32 - dq

    flat = jax.tree.map(one, grads, err)
    dq = jax.tree.map(lambda t: t[0], flat,
                      is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda t: t[1], flat,
                           is_leaf=lambda x: isinstance(x, tuple))
    num = sum(jnp.sum(jnp.square(a.astype(jnp.float32)
                                 - b.astype(jnp.float32)))
              for a, b in zip(jax.tree.leaves(dq), jax.tree.leaves(grads),
                  strict=True))
    den = sum(jnp.sum(jnp.square(b.astype(jnp.float32)))
              for b in jax.tree.leaves(grads))
    rel_err = jnp.sqrt(num / jnp.maximum(den, 1e-30))
    return dq, new_err, rel_err


def gate_update(counter, rel_err, *, err_budget: float = 0.05,
                bytes_saving: float = 0.75):
    """Saturating-counter gate: wire-bytes saved vs quality cost.  The
    scaling constants live in compression.gate (§VI thresholds have one
    home); `bytes_saving` is the measured fractional wire-byte win."""
    return wire_counter_step(counter, bytes_saving, rel_err > err_budget,
                             jnp)


def gate_enabled(counter):
    return counter >= ENABLE_THRESHOLD


def make_dp_compressed_step(model, mesh, *, lr=1e-3,
                            policy: str = "dynamic", ledger=None):
    """Explicit-collective DP train step with gated int8 grad compression.

    shard_map over the 'data' axis: per-shard grads -> (optionally
    quantized) psum -> AdamW-style SGD update.  Used by tests and the
    grad-compression benchmark; the pjit path keeps XLA-inserted
    collectives.

    policy: "dynamic" (the §VI gate; "auto" is an alias — the AutoTuner's
    runtime decision rule IS the gate), "static" (always quantize), "off"
    (plain collectives).  A bandwidth `ledger` books each step's wire
    bytes (raw vs what the gate actually sent) under consumer "grad".
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    assert policy in ("dynamic", "static", "off", "auto")
    dynamic = policy in ("dynamic", "auto")

    def step(params, err, counter, batch):
        def shard_fn(params, err, counter, batch):
            loss, grads = jax.value_and_grad(model.loss)(params, batch)

            def reduce_plain(g):
                return jax.tree.map(
                    lambda x: jax.lax.pmean(x, "data"), g)

            def reduce_q(g, e):
                dq, new_e, rel = compress_tree(g, e)
                summed = jax.tree.map(
                    lambda x: jax.lax.pmean(x, "data"), dq)
                return summed, new_e, rel

            if dynamic:
                enabled = gate_enabled(counter)
            else:
                enabled = jnp.asarray(policy == "static")
            dq, new_err, rel = reduce_q(grads, err)
            plain = reduce_plain(grads)
            grads_out = jax.tree.map(
                lambda a, b: jnp.where(enabled, a, b), dq, plain)
            new_err = jax.tree.map(
                lambda e, z: jnp.where(enabled, e, z * 0.0),
                new_err, new_err)
            # measured wire-byte win of the int8 collective for THIS tree
            # (adapters own the byte math), fed to the §VI counter
            saving = 1.0 - int8_wire_bytes(grads) / tree_wire_bytes(grads)
            counter_new = (gate_update(counter, rel, bytes_saving=saving)
                           if dynamic else counter)
            new_params = jax.tree.map(
                lambda p, g: (p.astype(jnp.float32)
                              - lr * g.astype(jnp.float32)).astype(p.dtype),
                params, grads_out)
            return new_params, new_err, counter_new, jax.lax.pmean(
                loss, "data")

        return shard_map(
            shard_fn, mesh=mesh,
            in_specs=(P(), P(), P(), P("data")),
            out_specs=(P(), P(), P(), P()),
            check_rep=False,
        )(params, err, counter, batch)

    jit_step = jax.jit(step)
    if ledger is None:
        return jit_step

    def step_with_ledger(params, err, counter, batch):
        # the counter entering the step is what gated this step's wire
        enabled = (bool(np.asarray(counter) >= ENABLE_THRESHOLD)
                   if dynamic else policy == "static")
        out = jit_step(params, err, counter, batch)
        grad_wire_event(ledger, params, enabled=enabled)
        return out

    return step_with_ledger
