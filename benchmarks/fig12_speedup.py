"""Figs. 3/7/12/16/18: speedups of every scheme vs uncompressed baseline.

Validated claims:
  * ideal compression headroom ~ +9% geomean (Fig. 3)
  * explicit metadata erodes/ inverts the benefit (Fig. 7)
  * implicit+LLP (cram) recovers it (Fig. 12)
  * Dynamic-CRAM keeps the win AND avoids every slowdown (Fig. 16/18)

All numbers come from the one batched suite sweep (memsim_suite) through
the shared aggregation helpers in sweep_report.py.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

from .memsim_suite import suite_results
from .sweep_report import speedup_aggregates

OUT = Path(__file__).resolve().parents[1] / "experiments" / "memsim"


def run() -> list[tuple]:
    t0 = time.time()
    res = suite_results()
    dt = (time.time() - t0) * 1e6
    # the paper figures cover the six paper schemes; registry extras
    # (cram-nollp, cram@lct*) are reported via the sweep JSON's
    # llp_value / lct_sensitivity sections instead
    from repro.core.memsim import SCHEMES

    agg = speedup_aggregates(res["workloads"], include=SCHEMES)
    n = max(len(res["workloads"]), 1)
    rows = []
    for sch, g in agg["geomean"].items():
        rows.append((f"fig16/geomean_{sch}", dt / n, f"{g:.4f}"))
        rows.append((f"fig18/worst_{sch}", 0.0, f"{agg['worst'][sch]:.4f}"))
        rows.append((f"fig18/best_{sch}", 0.0, f"{agg['best'][sch]:.4f}"))
    for suite, per in agg["by_suite"].items():
        for sch, g in per.items():
            if sch in ("dynamic", "cram", "ideal", "explicit"):
                rows.append((f"fig12/{suite}_{sch}", 0.0, f"{g:.4f}"))
    # paper-claim checks (same aggregates as the fig16/18 rows)
    rows.append(("claims/dynamic_no_slowdown", 0.0,
                 f"worst={agg['worst']['dynamic']:.4f}"
                 " (paper: >=1.0 for all)"))
    rows.append(("claims/dynamic_vs_ideal", 0.0,
                 f"{agg['geomean']['dynamic']:.4f} vs "
                 f"{agg['geomean']['ideal']:.4f} (paper: 1.06 vs 1.09)"))
    # persist the per-workload s-curve for EXPERIMENTS.md
    (OUT / "speedups.json").write_text(json.dumps({
        wl: {sch: d["speedup"] for sch, d in r["schemes"].items()}
        for wl, r in res["workloads"].items()}, indent=1))
    return rows
