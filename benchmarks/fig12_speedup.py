"""Figs. 3/7/12/16/18: speedups of every scheme vs uncompressed baseline.

Validated claims:
  * ideal compression headroom ~ +9% geomean (Fig. 3)
  * explicit metadata erodes/ inverts the benefit (Fig. 7)
  * implicit+LLP (cram) recovers it (Fig. 12)
  * Dynamic-CRAM keeps the win AND avoids every slowdown (Fig. 16/18)
"""

from __future__ import annotations

import json
import time
from pathlib import Path

from .memsim_suite import geomean, suite_of, suite_results

OUT = Path(__file__).resolve().parents[1] / "experiments" / "memsim"


def run() -> list[tuple]:
    t0 = time.time()
    res = suite_results()
    dt = (time.time() - t0) * 1e6
    rows = []
    by_scheme: dict[str, list] = {}
    by_suite: dict[tuple, list] = {}
    worst: dict[str, float] = {}
    best: dict[str, float] = {}
    for wl, r in res["workloads"].items():
        for sch, d in r["schemes"].items():
            if sch == "baseline":
                continue
            s = d["speedup"]
            by_scheme.setdefault(sch, []).append(s)
            by_suite.setdefault((suite_of(wl), sch), []).append(s)
            worst[sch] = min(worst.get(sch, 9.9), s)
            best[sch] = max(best.get(sch, 0.0), s)
    for sch, xs in sorted(by_scheme.items()):
        rows.append((f"fig16/geomean_{sch}", dt / max(len(xs), 1),
                     f"{geomean(xs):.4f}"))
        rows.append((f"fig18/worst_{sch}", 0.0, f"{worst[sch]:.4f}"))
        rows.append((f"fig18/best_{sch}", 0.0, f"{best[sch]:.4f}"))
    for (suite, sch), xs in sorted(by_suite.items()):
        if sch in ("dynamic", "cram", "ideal", "explicit"):
            rows.append((f"fig12/{suite}_{sch}", 0.0,
                         f"{geomean(xs):.4f}"))
    # paper-claim checks
    dyn = by_scheme.get("dynamic", [1.0])
    rows.append(("claims/dynamic_no_slowdown", 0.0,
                 f"worst={min(dyn):.4f} (paper: >=1.0 for all)"))
    rows.append(("claims/dynamic_vs_ideal", 0.0,
                 f"{geomean(dyn):.4f} vs {geomean(by_scheme['ideal']):.4f}"
                 " (paper: 1.06 vs 1.09)"))
    # persist the per-workload s-curve for EXPERIMENTS.md
    (OUT / "speedups.json").write_text(json.dumps({
        wl: {sch: d["speedup"] for sch, d in r["schemes"].items()}
        for wl, r in res["workloads"].items()}, indent=1))
    return rows
