"""§Roofline: aggregate the dry-run JSONs into the per-cell roofline table.

For each (arch x shape x mesh): the three terms in seconds, the dominant
bottleneck, MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (inference)
and the MODEL/HLO flops ratio (compiled-compute usefulness)."""

from __future__ import annotations

import json
from pathlib import Path

DRYRUN = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def model_flops_per_chip(rec: dict) -> float:
    tokens = rec["global_batch"] * (
        rec["seq_len"] if rec["kind"] in ("train", "prefill") else 1)
    mult = 6 if rec["kind"] == "train" else 2
    return mult * rec["active_params"] * tokens / rec["chips"]


def rows_from_records(records) -> list[tuple]:
    rows = []
    for rec in records:
        if rec.get("skipped"):
            rows.append((f"roofline/{rec['tag']}", 0.0, "SKIP (long_500k "
                         "needs sub-quadratic attention)"))
            continue
        if not rec.get("ok"):
            rows.append((f"roofline/{rec['tag']}", 0.0,
                         f"FAIL {rec.get('error', '?')[:60]}"))
            continue
        r = rec["roofline"]
        mf = model_flops_per_chip(rec)
        hlo = rec["extrapolated"]["flops"]
        ratio = mf / hlo if hlo else 0.0
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        frac = r["compute_s"] / bound if bound else 0.0
        rows.append((
            f"roofline/{rec['tag']}", rec.get("compile_s", 0) * 1e6,
            f"c={r['compute_s']:.3f}s m={r['memory_s']:.3f}s "
            f"coll={r['collective_s']:.3f}s dom={r['dominant'][:4]} "
            f"useful={ratio:.2f} roofline={frac:.2f}",
        ))
    return rows


def load_records(mesh: str | None = None, variant: str = "base"):
    recs = []
    for p in sorted(DRYRUN.glob("*.json")):
        rec = json.loads(p.read_text())
        if mesh and rec.get("mesh") != mesh:
            continue
        if rec.get("variant", "base") != variant:
            continue
        recs.append(rec)
    return recs


def run() -> list[tuple]:
    recs = load_records(mesh="16x16")
    if not recs:
        return [("roofline/NO_DATA", 0.0,
                 "run python -m repro.launch.dryrun --all first")]
    return rows_from_records(recs)
