"""Table V: next-line prefetch vs Dynamic-CRAM, per suite.

Paper: prefetch -5.7% SPEC / -21.1% GAP / -9.7% ALL vs CRAM +8.5/+0.0/+5.5.
The mechanism difference: prefetch pays an extra access per miss; CRAM's
neighbor lines ride along for free.
"""

from __future__ import annotations

from .memsim_suite import geomean, suite_of, suite_results


def run() -> list[tuple]:
    res = suite_results()
    per = {}
    for wl, r in res["workloads"].items():
        s = suite_of(wl)
        per.setdefault(("nextline", s), []).append(
            r["schemes"]["nextline"]["speedup"])
        per.setdefault(("dynamic", s), []).append(
            r["schemes"]["dynamic"]["speedup"])
        per.setdefault(("nextline", "ALL"), []).append(
            r["schemes"]["nextline"]["speedup"])
        per.setdefault(("dynamic", "ALL"), []).append(
            r["schemes"]["dynamic"]["speedup"])
    rows = []
    for (sch, s), xs in sorted(per.items()):
        rows.append((f"table5/{s}_{sch}", 0.0,
                     f"{(geomean(xs) - 1) * 100:+.1f}%"))
    return rows
