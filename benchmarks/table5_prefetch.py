"""Table V: next-line prefetch vs Dynamic-CRAM, per suite.

Paper: prefetch -5.7% SPEC / -21.1% GAP / -9.7% ALL vs CRAM +8.5/+0.0/+5.5.
The mechanism difference: prefetch pays an extra access per miss; CRAM's
neighbor lines ride along for free.

Numbers come from sweep_report.prefetch_table over the batched suite sweep.
"""

from __future__ import annotations

from .memsim_suite import suite_results
from .sweep_report import prefetch_table


def run() -> list[tuple]:
    res = suite_results()
    table = prefetch_table(res["workloads"])
    return [(f"table5/{key}", 0.0, f"{pct:+.1f}%")
            for key, pct in table.items()]
