"""CRAM-KV kernel micro-bench: pack/unpack/fused-attention timings (CPU
interpret mode — structural, not TPU wall-clock) + the bandwidth savings on
compressible vs incompressible KV streams, plus the checkpoint codec's
compression ratio per tensor class (the Fig. 4 story on our own data)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.codec import cram_compress_bytes
from repro.kernels import ops
from repro.kv import CRAMKVCache


def _timeit(fn, *args, n=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / n * 1e6


def blockspec_sweep(*, batch=4, n_groups=8, page=8, hkv=1, d=32,
                    n_timing=5, seed=0) -> dict:
    """BlockSpec tuning for the batched fused decode kernel: time every
    block_groups tiling of the slot axis per lanes mode, with parity
    columns (numerics vs the jnp oracle, bytes bit-exact vs the analytic
    `hbm_bytes_moved` model) so a tiling that breaks semantics can never
    look fast.  CI runs this (`--sweep kernels`) and fails on any parity
    row; the committed snapshot is BENCH_kernels.json.

    Timings are CPU interpret-mode — structural (relative cost of the
    tilings and the fused-vs-reference gap), not TPU wall-clock."""
    rng = np.random.default_rng(seed)
    d2 = 2 * d

    def mk_group(lanes, compressible):
        base = 2.0 + rng.standard_normal((1, 1, hkv, d2)) * 0.25
        if compressible:
            x = base * (1 + rng.standard_normal(
                (lanes, page, hkv, d2)) * 1e-4)
        else:
            x = rng.standard_normal((lanes, page, hkv, d2))
        return np.asarray(jnp.asarray(x.astype(jnp.bfloat16))
                          .view(jnp.int16))

    report: dict = {"batch": batch, "n_groups": n_groups, "page": page,
                    "n_kv": hkv, "head_dim": d, "modes": {}}
    for lanes in (2, 4):
        build = (ops.build_cram_cache if lanes == 2
                 else ops.build_cram_cache_quad)
        caches, valids = [], []
        for _ in range(batch):
            pages = np.concatenate([
                mk_group(lanes, bool(rng.random() < 0.7))
                for _ in range(n_groups)])
            caches.append(build(jnp.asarray(pages)))
            tokens = int(rng.integers(1, lanes * n_groups * page + 1))
            valids.append(np.clip(
                tokens - np.arange(lanes * n_groups) * page,
                0, page).astype(np.int32))
        cache = {k: jnp.stack([c[k] for c in caches])
                 for k in ("slots", "slots_overflow", "strips",
                           "packed_mask")}
        cache["markers"] = caches[0]["markers"]
        vp = jnp.asarray(np.stack(valids))
        q = jnp.asarray(rng.standard_normal((batch, 4, d)), jnp.bfloat16)
        ref_fn = (ops.decode_attention_ref_batched if lanes == 2
                  else ops.decode_attention_quad_ref_batched)
        ref = np.asarray(ref_fn(q, cache, vp))
        bw = ops.hbm_bytes_moved(cache, vp, lanes=lanes)
        rows, best = [], None
        for bg in (1, 2, 4, n_groups, None):
            out, raw_s, cram_s = ops.decode_attention_fused(
                q, cache, vp, lanes=lanes, block_groups=bg, interpret=True)
            err = float(np.max(np.abs(np.asarray(out, np.float32) - ref)))
            bytes_ok = (np.array_equal(np.asarray(raw_s),
                                       bw["raw_per_seq"])
                        and np.array_equal(np.asarray(cram_s),
                                           bw["cram_per_seq"]))
            us = _timeit(lambda qq, lanes=lanes, bg=bg:
                         ops.decode_attention_fused(
                             qq, cache, vp, lanes=lanes, block_groups=bg,
                             interpret=True)[0], q, n=n_timing)
            row = {"block_groups": bg, "us_per_call": round(us, 1),
                   "max_err_vs_oracle": err,
                   "numerics_parity": err < 2e-2,
                   "bytes_bit_exact": bool(bytes_ok)}
            rows.append(row)
            if row["numerics_parity"] and row["bytes_bit_exact"] and (
                    best is None or us < best["us_per_call"]):
                best = row
        report["modes"][f"lanes{lanes}"] = {
            "rows": rows,
            "best_block_groups": best["block_groups"] if best else None,
            "saving_on_mix": round(bw["saving"], 4),
        }
    report["parity_ok"] = all(
        r["numerics_parity"] and r["bytes_bit_exact"]
        for m in report["modes"].values() for r in m["rows"])
    return report


def run() -> list[tuple]:
    rng = np.random.default_rng(0)
    rows = []
    page, hkv, d = 32, 2, 64
    d2 = 2 * d

    def mk_pages(n, compressible):
        base = (2.0 + rng.standard_normal((1, 1, hkv, d2)) * 0.25)
        if compressible:
            x = base * (1 + rng.standard_normal((n, page, hkv, d2)) * 2e-3)
        else:
            x = rng.standard_normal((n, page, hkv, d2))
        return jnp.asarray(x.astype(jnp.bfloat16)).view(jnp.int16)

    for label, comp in (("compressible", True), ("incompressible", False)):
        pages = mk_pages(8, comp)
        t_pack = _timeit(lambda p: ops.build_cram_cache(p)["slots"], pages)
        cache = ops.build_cram_cache(pages)
        valid = jnp.full((8,), page, jnp.int32)
        q = jnp.asarray(rng.standard_normal((2, 4, d)), jnp.float32)
        t_att = _timeit(lambda qq: ops.decode_attention(qq, cache, valid), q)
        err = float(jnp.max(jnp.abs(
            ops.decode_attention(q, cache, valid)
            - ops.decode_attention_ref(q, cache, valid))))
        bw = ops.hbm_bytes_moved(cache, valid)
        rows.append((f"kernel/pack_{label}", t_pack,
                     f"packed={int(np.asarray(cache['packed_mask']).sum())}/4"))
        rows.append((f"kernel/attend_{label}", t_att,
                     f"bw_saving={bw['saving']:.3f} err={err:.1e}"))

    # incremental CRAM-KV decode: per-step pack work is O(new pairs)
    from repro.kv import synthetic_kv_stream

    kvc = CRAMKVCache(max_pages=12, page=page, n_kv=hkv, head_dim=d,
                      policy="static")
    stream, _ = synthetic_kv_stream(np.random.default_rng(1), 1, 12 * page,
                                    hkv, d)
    kvc.append(stream[:, : 6 * page], stream[:, : 6 * page])
    kvc.account_step()
    kvc.append(stream[:, 6 * page:6 * page + 1],
               stream[:, 6 * page:6 * page + 1])
    kvc.account_step()          # warm-up: compile W=1 window before timing
    pairs0 = kvc.stats.pack_pairs_processed
    t0 = time.perf_counter()
    n_steps = 8
    for t in range(6 * page + 1, 6 * page + 1 + n_steps):
        kvc.append(stream[:, t:t + 1], stream[:, t:t + 1])
        kvc.account_step()
    t_step = (time.perf_counter() - t0) / n_steps * 1e6
    pack_per_step = (kvc.stats.pack_pairs_processed - pairs0) / n_steps
    rows.append(("kernel/kv_decode_step", t_step,
                 f"pack_pairs/step={pack_per_step:.1f} "
                 f"saving={kvc.saving():.3f}"))

    # checkpoint codec ratios per tensor class
    classes = {
        "zeros": np.zeros(1 << 16, np.uint8).tobytes(),
        "adam_moments": (lambda m: m.tobytes())(
            np.where(rng.random(1 << 14) < 0.7, 0,
                     rng.standard_normal(1 << 14) * 1e-9).astype("<f4")),
        "weights_fp32": (rng.standard_normal(1 << 14) * 0.02
                         ).astype("<f4").tobytes(),
        "token_ids": rng.integers(0, 32000, 1 << 14).astype(
            "<i4").tobytes(),
    }
    for name, raw in classes.items():
        t0 = time.perf_counter()
        blob = cram_compress_bytes(raw)
        dt = (time.perf_counter() - t0) * 1e6
        rows.append((f"ckpt_codec/{name}", dt,
                     f"ratio={len(raw)/len(blob):.2f}x"))
    return rows
