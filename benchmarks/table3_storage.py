"""Table III: storage overhead of the CRAM structures (<300B claim)."""

from __future__ import annotations

from repro.core.dynamic import COUNTER_BITS
from repro.core.lit import LIT
from repro.core.llp import LLP


def run() -> list[tuple]:
    lit = LIT()
    llp = LLP()
    items = {
        "marker_2to1": 4,
        "marker_4to1": 4,
        "marker_invalid_line": 64,
        "line_inversion_table": lit.storage_bytes,
        "line_location_predictor": llp.storage_bytes,
        "dynamic_counters": 8 * COUNTER_BITS // 8,  # 8 cores (per-core ext.)
    }
    total = sum(items.values())
    rows = [(f"table3/{k}", 0.0, f"{v} B") for k, v in items.items()]
    rows.append(("table3/total", 0.0,
                 f"{total} B (paper: 276 B, < 300 B)"))
    assert total < 300, total
    return rows
