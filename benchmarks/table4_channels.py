"""Table IV: sensitivity to the number of memory channels.

More channels raise baseline bandwidth, lowering the memory-bound fraction
f of every workload (f_c = mpki / (mpki + k*channels), DESIGN.md §2.2);
the access-count ratios are channel-invariant.  The paper's claim is that
the benefit persists (4.8/5.5/4.6% across 1/2/4 channels).
"""

from __future__ import annotations

from repro.core.memsim import speedup
from repro.core.traces import BY_NAME, MIXES

from .memsim_suite import geomean, suite_results


def run() -> list[tuple]:
    res = suite_results()
    rows = []
    for channels in (1, 2, 4):
        sps = []
        for wl, r in res["workloads"].items():
            if "dynamic" not in r["schemes"]:
                continue  # scheme-subset cache; noted below
            if wl in BY_NAME:
                mpki = BY_NAME[wl].mpki
            else:
                mix = dict(MIXES)[wl]
                mpki = sum(BY_NAME[m].mpki for m in mix) / len(mix)
            f = mpki / (mpki + 15.0 * channels / 2.0)
            sps.append(speedup(r["baseline_accesses"],
                               r["schemes"]["dynamic"]["accesses"], f))
        rows.append((f"table4/channels_{channels}", 0.0,
                     f"dynamic geomean {geomean(sps):.4f} "
                     "(paper ~1.05 across 1/2/4)" if sps
                     else "n/a (dynamic not in cached suite)"))
    return rows
