"""Chosen-vs-best-static policy sweep: the AutoTuner's no-slowdown audit.

For each consumer the `repro.bandwidth.AutoTuner` tunes, run the auto
policy against every static alternative on the same data and compare the
bytes actually moved (read off each run's bandwidth ledger):

  * KV decode — synthetic streams at three compressibility profiles
    (tight / loose / random), each decoded under static off / pair / quad
    and under `policy="auto"` (tuner probes the prefill, picks the
    packing, §VI gate runs over it);
  * checkpoint — the codec-sweep tensor classes stored under every
    registered line codec and under `codec="auto"` (per-leaf choice);
  * gradient collective — gaussian vs outlier-heavy gradients through the
    int8 wire codec; auto enables it only within the error budget.

The paper's guarantee (Fig. 18: Dynamic-CRAM never slows a workload down)
becomes: auto's bytes are never worse than static-off's on ANY workload.
The report carries a per-row `auto_not_worse_than_off` flag and a global
`guarantee` — CI fails the policy smoke job when it is false.

Wired as `benchmarks/run.py --sweep policy`.
"""

from __future__ import annotations

import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

_ROOT = Path(__file__).resolve().parents[1]
for _p in (str(_ROOT), str(_ROOT / "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from repro.bandwidth import AutoTuner  # noqa: E402
from repro.checkpoint.codec import (  # noqa: E402
    cram_compress_bytes,
    pad_to_lines,
)
from repro.compression import codec_names  # noqa: E402
from repro.kv import CRAMKVCache, synthetic_kv_stream  # noqa: E402
from repro.optim import grad_compress as gc  # noqa: E402

PAGE, HKV, HD = 8, 1, 32

KV_STREAMS = {
    # calibrated against the bf16 page codecs at this geometry: tight fits
    # int4 quads AND int8 pairs; loose (1e-2 relative noise ≈ a bf16 ulp
    # at the base magnitude) fits pairs but NOT quads; random fits nothing
    # — so the audit exercises all three distinct choices
    "kv_tight": dict(compressible=True, scale=2e-4),   # int4-quad territory
    "kv_loose": dict(compressible=True, scale=1e-2),   # int8-pair territory
    "kv_random": dict(compressible=False),             # nothing fits
}


def _kv_bytes(k, v, *, policy, packing, prefill, steps,
              auto_tuner=None) -> tuple[int, int, str]:
    """Decode trajectory bytes under one policy; returns (raw, compressed,
    packing actually used)."""
    t = prefill + steps
    n_need = (t + PAGE - 1) // PAGE
    if policy == "auto":
        cache, _ = CRAMKVCache.auto(
            auto_tuner, k[:, :prefill], v[:, :prefill],
            max_pages=max(n_need, 2), page=PAGE, n_kv=HKV, head_dim=HD,
            batch=k.shape[0])
    else:
        cache = CRAMKVCache(
            max_pages=max(n_need, 2), page=PAGE, n_kv=HKV, head_dim=HD,
            batch=k.shape[0], policy=policy, packing=packing)
    cache.append(k[:, :prefill], v[:, :prefill])
    cache.account_step()
    for i in range(prefill, t):
        cache.append(k[:, i:i + 1], v[:, i:i + 1])
        cache.account_step()
    tot = cache.ledger.total("read", consumer="kv")
    used = cache.packing if cache.policy != "off" else "off"
    return tot["raw_bytes"], tot["compressed_bytes"], used


def kv_policy_table(*, batch=2, prefill_pages=4, decode_steps=12,
                    seed=0) -> dict:
    out: dict = {}
    prefill = prefill_pages * PAGE
    total = prefill + decode_steps
    for sname, kw in KV_STREAMS.items():
        rng = np.random.default_rng(seed)
        k, v = synthetic_kv_stream(rng, batch, total, HKV, HD, **kw)
        statics = {}
        for label, (pol, pack) in {
            "off": ("off", "pair"),
            "pair": ("static", "pair"),
            "quad": ("static", "quad"),
        }.items():
            _, comp, _ = _kv_bytes(k, v, policy=pol, packing=pack,
                                   prefill=prefill, steps=decode_steps)
            statics[label] = comp
        tuner = AutoTuner()
        raw, auto_b, used = _kv_bytes(k, v, policy="auto", packing="pair",
                                      prefill=prefill, steps=decode_steps,
                                      auto_tuner=tuner)
        best = min(statics, key=lambda n: statics[n])
        out[sname] = {
            "chosen": used,
            "bytes": {**statics, "auto": auto_b},
            "raw_baseline_bytes": raw,
            "best_static": best,
            "regret_vs_best": round(
                auto_b / max(statics[best], 1) - 1.0, 4),
            "auto_not_worse_than_off": auto_b <= statics["off"],
        }
    return out


def _ckpt_tensors(seed=0) -> dict:
    """The codec-sweep tensor classes (same distributions)."""
    rng = np.random.default_rng(seed)
    n = 512 * 64
    w32 = (rng.standard_normal(n // 4) * 0.02).astype("<f4")
    moments = (rng.standard_normal(n // 4) * 1e-8).astype("<f4")
    moments[rng.random(moments.shape) < 0.6] = 0.0
    bf16 = np.ascontiguousarray(
        (w32.view("<u4") >> 16).astype("<u2")).view(np.uint8)
    return {
        "weights_fp32": w32.view(np.uint8).tobytes(),
        "weights_bf16": bf16.tobytes(),
        "adam_moments_fp32": moments.view(np.uint8).tobytes(),
        "random_bytes": rng.integers(0, 256, n, dtype=np.uint8).tobytes(),
    }


def ckpt_policy_table(seed=0) -> dict:
    out: dict = {}
    tuner = AutoTuner()
    for tname, raw in _ckpt_tensors(seed).items():
        # the static raw writer stores the PLAIN blob (no stream framing);
        # auto's raw fallback does the same, so the baseline must too
        stored = {c: len(cram_compress_bytes(raw, codec=c))
                  for c in codec_names("line64") if c != "raw"}
        stored["raw"] = len(raw)
        choice = tuner.choose_ckpt_codec(pad_to_lines(raw),
                                         tensor_class=tname)
        auto_b = (len(raw) if choice.choice == "raw"
                  else len(cram_compress_bytes(raw, codec=choice.choice)))
        best = min(stored, key=lambda n: stored[n])
        out[tname] = {
            "chosen": choice.choice,
            "stored": {**stored, "auto": auto_b},
            "best_static": best,
            "regret_vs_best": round(auto_b / max(stored[best], 1) - 1.0, 4),
            "auto_not_worse_than_off": auto_b <= stored["raw"],
        }
    return out


def grad_policy_table(seed=0) -> dict:
    from repro.bandwidth.adapters import int8_wire_bytes, tree_wire_bytes

    rng = np.random.default_rng(seed)
    # one outlier stretches the per-tensor int8 scale so every ~unit value
    # quantizes to zero: measured rel_err lands well OVER the 0.05 budget,
    # so the audit exercises the disable branch for real (a tuner that
    # regressed to always-int8 fails this row, and CI with it)
    outlier = rng.standard_normal((256, 256)).astype(np.float32)
    outlier[0, 0] = 2e3
    profiles = {
        "gaussian": rng.standard_normal((256, 256)).astype(np.float32),
        "outlier_over_budget": outlier,
    }
    out: dict = {}
    tuner = AutoTuner()
    budget = 0.05
    for pname, g in profiles.items():
        grads = {"w": jnp.asarray(g)}
        err = jax.tree.map(jnp.zeros_like, grads)
        _, _, rel = gc.compress_tree(grads, err)
        rel = float(rel)
        choice = tuner.choose_grad_codec(rel, err_budget=budget)
        raw_b = tree_wire_bytes(grads)
        int8_b = int8_wire_bytes(grads)
        auto_b = int8_b if choice.choice == "int8" else raw_b
        out[pname] = {
            "chosen": choice.choice,
            "rel_err": round(rel, 5),
            "wire_bytes": {"off": raw_b, "int8": int8_b, "auto": auto_b},
            # "worse than off" for the collective is a QUALITY regression:
            # auto must never ship int8 when the error is over budget
            "auto_not_worse_than_off": (choice.choice == "off"
                                        or rel <= budget),
        }
    # the audit itself must cover both branches: at least one profile over
    # budget (disable path) and one within it
    rels = [row["rel_err"] for row in out.values()]
    assert max(rels) > budget > min(rels), \
        f"grad audit profiles no longer straddle the budget: {rels}"
    return out


def sweep(*, batch=2, decode_steps=12, seed=0) -> dict:
    t0 = time.time()
    kv = kv_policy_table(batch=batch, decode_steps=decode_steps, seed=seed)
    ckpt = ckpt_policy_table(seed)
    grad = grad_policy_table(seed)
    ok = all(row["auto_not_worse_than_off"]
             for table in (kv, ckpt, grad) for row in table.values())
    return {
        "kv": kv, "checkpoint": ckpt, "grad": grad,
        "guarantee": ok,                 # the paper's no-slowdown claim
        "wall_s": round(time.time() - t0, 2),
    }


def run() -> list[tuple]:
    """Legacy-mode rows for benchmarks/run.py."""
    rep = sweep(decode_steps=8)
    rows = []
    for section in ("kv", "checkpoint", "grad"):
        for name, row in rep[section].items():
            key = "bytes" if section == "kv" else (
                "stored" if section == "checkpoint" else "wire_bytes")
            auto_b = row[key]["auto"]
            rows.append((f"policy/{section}/{name}", 0.0,
                         f"chosen={row['chosen']} auto={auto_b} "
                         f"ok={row['auto_not_worse_than_off']}"))
    rows.append(("policy/guarantee", 0.0,
                 f"auto_never_worse_than_off={rep['guarantee']}"))
    return rows
