"""Figs. 8/15: bandwidth breakdown (data / metadata / mispredict /
clean-writeback+invalidate), normalized to the uncompressed baseline."""

from __future__ import annotations

from .memsim_suite import suite_results


def run() -> list[tuple]:
    res = suite_results()
    rows = []
    for wl, r in sorted(res["workloads"].items()):
        base = r["baseline_accesses"]
        for sch in ("explicit", "cram"):
            b = r["schemes"][sch]["breakdown"]
            norm = {k: v / base for k, v in b.items()}
            fig = "fig8" if sch == "explicit" else "fig15"
            rows.append((
                f"{fig}/{wl}", 0.0,
                "data=%.2f meta=%.2f mispred=%.3f wbclean+inv=%.2f" % (
                    norm["data_reads"] + norm["wb_dirty"],
                    norm["metadata"], norm["mispredict_extra"],
                    norm["wb_clean+invalidate"]),
            ))
    return rows
