"""Figs. 8/15: bandwidth breakdown (data / metadata / mispredict /
clean-writeback+invalidate), normalized to the uncompressed baseline.

Breakdowns are computed once by sweep_report.bandwidth_breakdowns from
each scheme's bandwidth-ledger rows (`engine_traffic` -> the embedded
"traffic" dicts, re-categorized by `engine_breakdown`); this module only
formats them as CSV rows.  The figure therefore reads the SAME byte
accounting the autotune policy layer does — the legacy private counters
are no longer in the render path (pinned equal by
tests/test_benchmarks.py).
"""

from __future__ import annotations

from .memsim_suite import suite_results
from .sweep_report import bandwidth_breakdowns


def run() -> list[tuple]:
    res = suite_results()
    bw = bandwidth_breakdowns(res["workloads"])
    rows = []
    for sch, fig in (("explicit", "fig8"), ("cram", "fig15")):
        for wl, b in bw[sch].items():
            rows.append((
                f"{fig}/{wl}", 0.0,
                "data=%.2f meta=%.2f mispred=%.3f wbclean+inv=%.2f" % (
                    b["data"], b["metadata"], b["mispredict"],
                    b["wbclean+inv"]),
            ))
    return rows
