"""Fig. 4: probability of line pairs compressing to <=64B vs <=60B.

The paper reports 38% / 36% over its workload memory images; we measure the
same statistic over a corpus of realistic memory contents: model weights
(fp32/bf16), optimizer moments, integer token/ID arrays, zero-heavy
buffers, text bytes, and random data — plus the per-source breakdown, which
exposes the data-dependence the paper's Fig. 4 averages over.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.compress import compressed_sizes
from repro.core.mapping import PAYLOAD_BUDGET


def _corpus(n_lines_each: int = 4096, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    n_bytes = n_lines_each * 64
    out = {}
    w = (rng.standard_normal(n_bytes // 4) * 0.02).astype("<f4")
    out["weights_fp32"] = w.view(np.uint8)
    out["weights_bf16"] = np.ascontiguousarray(
        w.astype("<f4").view("<u4") >> 16).astype("<u2").view(np.uint8)
    m = (rng.standard_normal(n_bytes // 4) * 1e-8).astype("<f4")
    m[rng.random(m.shape) < 0.6] = 0.0
    out["adam_moments"] = m.view(np.uint8)
    ids = rng.integers(0, 32000, n_bytes // 4).astype("<i4")
    out["token_ids"] = ids.view(np.uint8)
    ptr = (2**20 + np.cumsum(rng.integers(0, 64, n_bytes // 8))).astype(
        "<i8")
    out["pointers"] = ptr.view(np.uint8)
    z = np.zeros(n_bytes, np.uint8)
    nz = rng.random(n_bytes) < 0.05
    z[nz] = rng.integers(1, 255, int(nz.sum()))
    out["sparse_zero"] = z
    txt = rng.choice(
        np.frombuffer(b"the quick brown fox jumps over 0123456789,. \n",
                      np.uint8), n_bytes)
    out["text_ascii"] = txt
    out["random"] = rng.integers(0, 256, n_bytes).astype(np.uint8)
    return {k: v[: n_bytes] for k, v in out.items()}


def pair_fit_stats(sizes) -> tuple[float, float]:
    """P(adjacent line pair compresses to <=64B, <=60B) — the Fig. 4
    statistic, shared with the run.py compress sweep."""
    sizes = np.asarray(sizes)
    n = sizes.shape[0] - sizes.shape[0] % 2
    pair = sizes[0:n:2] + sizes[1:n:2]
    return float((pair <= 64).mean()), float((pair <= PAYLOAD_BUDGET).mean())


def run() -> list[tuple]:
    t0 = time.time()
    per_source = {}
    all_sizes = []
    for name, raw in _corpus().items():
        lines = raw.reshape(-1, 64)
        sizes = np.asarray(compressed_sizes(lines))
        per_source[name] = pair_fit_stats(sizes)
        all_sizes.append(sizes)
    sizes = np.concatenate(all_sizes)
    p64, p60 = pair_fit_stats(sizes)
    dt = (time.time() - t0) * 1e6 / len(sizes)
    rows = [("fig4/pair_fits_64B", dt, f"{p64:.3f} (paper 0.38)"),
            ("fig4/pair_fits_60B", dt, f"{p60:.3f} (paper 0.36)"),
            ("fig4/marker_cost", dt, f"{p64 - p60:.3f} (paper ~0.02)")]
    for name, (a, b) in sorted(per_source.items()):
        rows.append((f"fig4/{name}", dt, f"p64={a:.3f} p60={b:.3f}"))
    return rows
