"""§Dry-run: per-cell compile/memory/collective-schedule summary table."""

from __future__ import annotations

import json
from pathlib import Path

DRYRUN = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"
GiB = 2**30


def markdown_table(mesh: str | None = None) -> str:
    rows = ["| cell | mesh | status | args GiB | temps GiB | compile s | "
            "collective ops (ag/ar/rs/a2a/cp) |",
            "|---|---|---|---|---|---|---|"]
    for p in sorted(DRYRUN.glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("variant", "base") != "base":
            continue
        if mesh and r.get("mesh") != mesh:
            continue
        tag = r["tag"].replace(f"__{r.get('mesh','')}", "")
        if r.get("skipped"):
            rows.append(f"| {tag} | {r.get('mesh','-')} | SKIP "
                        "(full-attn long-ctx) | - | - | - | - |")
            continue
        if not r.get("ok"):
            rows.append(f"| {tag} | {r['mesh']} | **FAIL** | - | - | - | "
                        f"{str(r.get('error'))[:40]} |")
            continue
        ma = r.get("memory_analysis", {})
        c = r.get("collectives", {}).get("counts_by_type", {})
        rows.append(
            f"| {tag} | {r['mesh']} | OK "
            f"| {ma.get('argument_size_in_bytes', 0) / GiB:.2f} "
            f"| {ma.get('temp_size_in_bytes', 0) / GiB:.2f} "
            f"| {r.get('compile_s', 0):.0f} "
            f"| {c.get('all-gather', 0)}/{c.get('all-reduce', 0)}"
            f"/{c.get('reduce-scatter', 0)}/{c.get('all-to-all', 0)}"
            f"/{c.get('collective-permute', 0)} |")
    return "\n".join(rows)


def run() -> list[tuple]:
    ok = fail = skip = 0
    for p in DRYRUN.glob("*.json"):
        r = json.loads(p.read_text())
        if r.get("variant", "base") != "base":
            continue
        if r.get("skipped"):
            skip += 1
        elif r.get("ok"):
            ok += 1
        else:
            fail += 1
    return [("dryrun/cells_ok", 0.0, ok),
            ("dryrun/cells_skipped_by_design", 0.0, skip),
            ("dryrun/cells_failed", 0.0, fail)]


if __name__ == "__main__":
    print(markdown_table())
