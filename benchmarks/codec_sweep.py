"""Per-codec x per-layout compression sweep over the registry.

Every registered line codec (raw / bdi / fpc / hybrid) is sized over the 27
workloads' synthetic line distributions (pair/quad compressibility tied to
each workload's Table II p2/p4, via the same traces._page_levels draw the
trace simulator uses) and folded through the GROUP4 layout's packing states
to get an effective lines-per-slot ratio; every registered page codec
(int8-delta / int4-delta) is measured over synthetic KV decode streams at
several compressibility scales via the KV_PAIR / KV_QUAD layouts; and the
line codecs are additionally rated on checkpoint/gradient tensor bytes
(the kernel_bench/fig4 tensor classes).

One registry, one sweep: adding a codec or layout to
repro.compression makes it appear in this table with no benchmark code.
"""

from __future__ import annotations

import time

import numpy as np

from repro.compression import codecs, layouts
from repro.compression.framing import LINE_BYTES, PAYLOAD_BUDGET
from repro.core.traces import WORKLOADS, _page_levels
from repro.kv.traffic import synthetic_kv_stream

LINES_PER_PAGE = 64


def _workload_image(spec, n_pages: int = 48, seed: int = 0) -> np.ndarray:
    """(n_pages*64, 64) uint8 image with the workload's compressibility.

    Page levels follow traces._page_levels (2 = quad-able, 1 = pair-able,
    0 = incompressible); line contents are drawn per level so the hybrid
    codec reproduces the level's packability, with per-line jitter.
    """
    rng = np.random.default_rng(seed ^ 0x51EE7)
    levels = _page_levels(n_pages, spec.p2, spec.p4, seed)
    n_lines = n_pages * LINES_PER_PAGE
    lines = rng.integers(0, 256, (n_lines, LINE_BYTES)).astype(np.uint8)
    lv = np.repeat(levels, LINES_PER_PAGE)
    # level 1: pairs fit in the payload budget — base+delta int32 streams
    m1 = lv == 1
    if m1.any():
        base = rng.integers(0, 2**24, (int(m1.sum()), 1))
        vals = (base + rng.integers(-100, 100, (int(m1.sum()), 16)))
        lines[m1] = vals.astype("<i4").view(np.uint8).reshape(-1, LINE_BYTES)
    # level 2: quads fit — near-zero small-int lines
    m2 = lv == 2
    if m2.any():
        vals = rng.integers(-4, 4, (int(m2.sum()), 16))
        lines[m2] = vals.astype("<i4").view(np.uint8).reshape(-1, LINE_BYTES)
    return lines


def _group4_stats(sizes: np.ndarray) -> dict:
    """Fold per-line sizes through the GROUP4 packing states."""
    n = sizes.shape[0] - sizes.shape[0] % 4
    g = sizes[:n].astype(np.int64).reshape(-1, 4)
    ab = g[:, 0] + g[:, 1] <= PAYLOAD_BUDGET
    cd = g[:, 2] + g[:, 3] <= PAYLOAD_BUDGET
    quad = g.sum(1) <= PAYLOAD_BUDGET
    # slots a group occupies per state: U=4, AB|CD=3, AB+CD=2, QUAD=1
    slots = np.where(quad, 1, 4 - ab.astype(int) - cd.astype(int))
    return {
        "pair_ab_rate": float(ab.mean()),
        "quad_rate": float(quad.mean()),
        "lines_per_slot": float(4.0 / slots.mean()),
    }


def line_codec_table(n_pages: int = 48, workloads=None) -> dict:
    """{workload: {codec: {mean_size, ratio, group4 stats}}} + throughput."""
    specs = [w for w in WORKLOADS
             if workloads is None or w.name in workloads]
    names = codecs.codec_names("line64")
    table: dict = {}
    thr: dict = {n: [0.0, 0] for n in names}
    for spec in specs:
        img = _workload_image(spec, n_pages)
        row = {}
        for cname in names:
            codec = codecs.get_codec(cname)
            t0 = time.time()
            sizes = np.asarray(codec.sizes(img))
            dt = time.time() - t0
            thr[cname][0] += dt
            thr[cname][1] += img.shape[0]
            row[cname] = {
                "mean_size": float(sizes.mean()),
                "ratio": float(LINE_BYTES / sizes.mean()),
                "group4": _group4_stats(sizes),
            }
        table[spec.name] = row
    throughput = {
        n: (cnt / max(dt, 1e-9)) / 1e6 for n, (dt, cnt) in thr.items()}
    return {"per_workload": table, "size_mlines_per_s": throughput}


def page_codec_table(seed: int = 0) -> dict:
    """Pack rates of the page codecs over KV streams x compressibility."""
    rng = np.random.default_rng(seed)
    streams = {
        "kv_tight": dict(compressible=True, scale=2e-4),
        "kv_loose": dict(compressible=True, scale=2e-3),
        "kv_random": dict(compressible=False),
    }
    page, n_kv, hd, n_tokens = 8, 2, 16, 64 * 8
    out: dict = {}
    for sname, kw in streams.items():
        k, v = synthetic_kv_stream(rng, 1, n_tokens, n_kv, hd, **kw)
        kv = np.concatenate([k, v], -1).astype("<f4")
        pages = np.ascontiguousarray(
            (kv.view("<u4") >> 16).astype("<u2").view("<i2")[0]
            .reshape(-1, page, n_kv, 2 * hd))
        row = {}
        for cname in codecs.codec_names("page"):
            codec = codecs.get_codec(cname)
            lanes = codec.group_lanes
            n_groups = pages.shape[0] // lanes
            fits = []
            for gi in range(n_groups):
                grp = pages[gi * lanes:(gi + 1) * lanes]
                ok, _, _ = codec.pack_pages(*grp, xp=np)
                fits.append(bool(ok))
            fit_rate = float(np.mean(fits)) if fits else 0.0
            layout = layouts.get_layout(
                "kv-pair" if lanes == 2 else "kv-quad")
            # slots per group: 1 when packed, `lanes` when raw
            slots = fit_rate * 1 + (1 - fit_rate) * lanes
            row[cname] = {
                "fit_rate": fit_rate,
                "layout": layout.name,
                "pages_per_slot": float(lanes / slots),
            }
        out[sname] = row
    return out


def tensor_table(seed: int = 0) -> dict:
    """Line-codec ratios over checkpoint/gradient tensor bytes."""
    rng = np.random.default_rng(seed)
    n_bytes = 2048 * LINE_BYTES
    w32 = (rng.standard_normal(n_bytes // 4) * 0.02).astype("<f4")
    grads = (rng.standard_normal(n_bytes // 4) * 1e-3).astype("<f4")
    moments = (rng.standard_normal(n_bytes // 4) * 1e-8).astype("<f4")
    moments[rng.random(moments.shape) < 0.6] = 0.0
    bf16 = lambda a: np.ascontiguousarray(
        (a.view("<u4") >> 16).astype("<u2")).view(np.uint8)
    tensors = {
        "weights_fp32": w32.view(np.uint8),
        "weights_bf16": bf16(w32),
        "grads_bf16": bf16(grads),
        "adam_moments_fp32": moments.view(np.uint8),
    }
    out: dict = {}
    for tname, raw in tensors.items():
        lines = raw[: len(raw) - len(raw) % LINE_BYTES].reshape(
            -1, LINE_BYTES)
        out[tname] = {
            cname: float(
                LINE_BYTES / np.asarray(
                    codecs.get_codec(cname).sizes(lines)).mean())
            for cname in codecs.codec_names("line64")
        }
    return out


def sweep(n_pages: int = 48, workloads=None) -> dict:
    t0 = time.time()
    report = {
        "line64": line_codec_table(n_pages, workloads),
        "kv_pages": page_codec_table(),
        "tensors": tensor_table(),
        "wall_s": None,
    }
    report["wall_s"] = round(time.time() - t0, 2)
    return report


def run() -> list[tuple]:
    """Legacy CSV rows: geomean ratio per codec over the workload images."""
    rep = sweep(n_pages=16)
    rows = []
    per_wl = rep["line64"]["per_workload"]
    for cname in codecs.codec_names("line64"):
        ratios = [per_wl[w][cname]["ratio"] for w in per_wl]
        geo = float(np.exp(np.mean(np.log(ratios))))
        thr = rep["line64"]["size_mlines_per_s"][cname]
        rows.append((f"codec_sweep/{cname}", 0.0,
                     f"geomean_ratio={geo:.3f} thr={thr:.2f}Ml/s"))
    for sname, row in rep["kv_pages"].items():
        for cname, d in row.items():
            rows.append((f"codec_sweep/{sname}/{cname}", 0.0,
                         f"fit={d['fit_rate']:.2f} "
                         f"pages_per_slot={d['pages_per_slot']:.2f}"))
    return rows
