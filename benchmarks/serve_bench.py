"""CRAM-KV serving bench: decode-bandwidth / packing-work curves vs
sequence length, batch size, and packing layout (pair 2:1 / quad 4:1)
through the batched incremental cache.

Each curve prefills a batch of sequences, then decodes token by token,
recording per step: the pairs actually re-packed (the incremental-repack
work — O(new pairs), where a full rebuild would pay O(total pairs) every
step), the CRAM vs raw bytes a decode step DMAs, and the bandwidth saving.

Sweep mode (`benchmarks/run.py --sweep serve`) emits the JSON curves plus
an incremental-vs-full-rebuild parity check; legacy mode
(`benchmarks/run.py serve_bench`) prints summary rows.
"""

from __future__ import annotations

import sys
import time
from pathlib import Path

import jax.numpy as jnp
import numpy as np

_ROOT = Path(__file__).resolve().parents[1]
for _p in (str(_ROOT), str(_ROOT / "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from repro.kv import CRAMKVCache, synthetic_kv_stream  # noqa: E402

PAGE, HKV, HD = 8, 1, 32


def _stream(rng, batch, n_tokens, compressible=True, scale=2e-3):
    return synthetic_kv_stream(rng, batch, n_tokens, HKV, HD,
                               compressible=compressible, scale=scale)


def decode_curve(policy="static", batch=1, prefill_pages=4, decode_steps=32,
                 compressible=True, seed=0, packing="pair") -> dict:
    """One decode trajectory; per-step pack work and bandwidth."""
    rng = np.random.default_rng(seed)
    prefill = prefill_pages * PAGE
    total = prefill + decode_steps + 1           # +1 warm-up step
    n_need = (total + PAGE - 1) // PAGE
    lanes = 2 if packing == "pair" else 4
    cache = CRAMKVCache(max_pages=n_need, page=PAGE, n_kv=HKV, head_dim=HD,
                        batch=batch, policy=policy, packing=packing)
    # SAME stream for both packings (2e-3 fits int8 pairs AND int4 quads
    # at this geometry), so pair-vs-quad curves in one report compare the
    # layouts, not the data
    cache.append(*_stream(rng, batch, prefill, compressible))
    cache.account_step()
    # one untimed decode step compiles the W=1 pack window and the T=1
    # append scatter, so the timed loop measures steady-state steps only
    cache.append(*_stream(rng, batch, 1, compressible))
    cache.account_step()
    seq_len, pack_pairs, total_pairs, cram_b, raw_b = [], [], [], [], []
    t0 = time.perf_counter()
    for _ in range(decode_steps):
        cache.append(*_stream(rng, batch, 1, compressible))
        before = cache.stats.pack_pairs_processed
        bw = cache.account_step()
        seq_len.append(cache.tokens)
        pack_pairs.append(cache.stats.pack_pairs_processed - before)
        total_pairs.append(batch * cache.n_active_pairs)
        cram_b.append(int(bw["cram_bytes"]))
        raw_b.append(int(bw["raw_bytes"]))
    wall = time.perf_counter() - t0
    mean_pack = float(np.mean(pack_pairs))
    mean_total = float(np.mean(total_pairs))
    # packing efficiency of the FINAL layout (transient partially-filled
    # groups re-pack raw many times; what matters is what the sequence
    # reached): pages_per_slot == lanes iff every active group packs
    pm = np.asarray(cache.state["packed_mask"][:, :cache.n_active_groups])
    fit_rate = float(pm.mean())
    pages_per_slot = float(lanes * pm.size
                           / (pm.sum() + lanes * (~pm).sum()))
    return {
        "policy": policy, "batch": batch, "compressible": compressible,
        "packing": packing,
        "fit_rate": round(fit_rate, 4),
        "pages_per_slot": round(pages_per_slot, 4),
        "prefill_tokens": prefill, "decode_steps": decode_steps,
        "seq_len": seq_len,
        "pack_pairs_per_step": pack_pairs,
        "total_pairs": total_pairs,
        "cram_bytes_per_step": cram_b,
        "raw_bytes_per_step": raw_b,
        "mean_pack_pairs_per_step": mean_pack,
        "mean_total_pairs": mean_total,
        "full_rebuild_work_ratio": mean_total / max(mean_pack, 1e-9),
        "final_saving": 1.0 - cram_b[-1] / max(raw_b[-1], 1),
        "cumulative_saving": cache.saving(),
        "decode_wall_s": round(wall, 4),
        "packed_pairs": cache.stats.packed_pairs,
        "raw_pairs": cache.stats.raw_pairs,
        "predictor_misses": cache.stats.predictor_misses,
    }


def _parity_check(seed=0) -> dict:
    """Incremental state vs from-scratch rebuild, and kernel vs oracle."""
    rng = np.random.default_rng(seed)
    cache = CRAMKVCache(max_pages=8, page=PAGE, n_kv=HKV, head_dim=HD,
                        batch=2, policy="static")
    for t in (2 * PAGE, 3, 1, PAGE):
        cache.append(*_stream(rng, 2, t))
        cache.repack()
    ref, act = cache.reference_rebuild(), cache.active_state()
    equal = all(bool(jnp.array_equal(act[k], ref[k])) for k in ref)
    q = jnp.asarray(rng.standard_normal((2, 4, HD)), jnp.float32)
    err = float(jnp.max(jnp.abs(cache.attend(q, account=False)
                                - cache.attend_ref(q))))
    return {"incremental_equals_rebuild": equal,
            "kernel_vs_oracle_err": err}


def sweep(policies=("static", "dynamic", "off"), batches=(1, 4),
          prefill_pages=4, decode_steps=32, seed=0,
          packings=("pair", "quad")) -> dict:
    curves = []
    for packing in packings:
        for policy in policies:
            for batch in batches:
                for compressible in (True, False):
                    curves.append(decode_curve(
                        policy=policy, batch=batch,
                        prefill_pages=prefill_pages,
                        decode_steps=decode_steps,
                        compressible=compressible, seed=seed,
                        packing=packing))
    static_comp = [c for c in curves if c["policy"] == "static"
                   and c["compressible"] and c["packing"] == "pair"]
    quad_static = [c for c in curves if c["policy"] == "static"
                   and c["packing"] == "quad"]
    return {
        "page": PAGE, "n_kv": HKV, "head_dim": HD,
        "curves": curves,
        "pack_work": {
            "mean_pack_pairs_per_step": float(np.mean(
                [c["mean_pack_pairs_per_step"] / c["batch"]
                 for c in curves])),
            "mean_total_pairs": float(np.mean(
                [c["mean_total_pairs"] / c["batch"] for c in curves])),
            "full_rebuild_work_ratio": float(np.mean(
                [c["full_rebuild_work_ratio"] for c in curves])),
        },
        "static_compressible_saving": float(np.mean(
            [c["cumulative_saving"] for c in static_comp])),
        # quad axis: pages-per-slot the 4:1 layout actually reached vs the
        # int4-delta fit rate on the same stream (ROADMAP item)
        "quad": {
            f"{'comp' if c['compressible'] else 'rand'}_b{c['batch']}": {
                "int4_fit_rate": c["fit_rate"],
                "pages_per_slot": c["pages_per_slot"],
                "saving": round(c["cumulative_saving"], 4),
            }
            for c in quad_static
        },
        "parity": _parity_check(seed),
    }


def run() -> list[tuple]:
    """Legacy-mode rows for benchmarks/run.py."""
    rep = sweep(batches=(1, 2), decode_steps=12)
    rows = []
    for c in rep["curves"]:
        name = (f"serve/{c['packing']}_{c['policy']}_b{c['batch']}"
                f"_{'comp' if c['compressible'] else 'rand'}")
        us = c["decode_wall_s"] / max(c["decode_steps"], 1) * 1e6
        rows.append((name, us,
                     f"pack/step={c['mean_pack_pairs_per_step']:.2f} "
                     f"saving={c['cumulative_saving']:.3f}"))
    p = rep["parity"]
    rows.append(("serve/parity", 0.0,
                 f"incr_eq_rebuild={p['incremental_equals_rebuild']} "
                 f"err={p['kernel_vs_oracle_err']:.1e}"))
    return rows
