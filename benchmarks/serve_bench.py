"""CRAM-KV serving bench: decode-bandwidth / packing-work curves vs
sequence length, batch size, and packing layout (pair 2:1 / quad 4:1)
through the batched incremental cache.

Each curve prefills a batch of sequences, then decodes token by token,
recording per step: the pairs actually re-packed (the incremental-repack
work — O(new pairs), where a full rebuild would pay O(total pairs) every
step), the CRAM vs raw bytes a decode step DMAs, and the bandwidth saving.

Sweep mode (`benchmarks/run.py --sweep serve`) emits the JSON curves plus
an incremental-vs-full-rebuild parity check; legacy mode
(`benchmarks/run.py serve_bench`) prints summary rows.

The churn tier (`--sweep serve-spill`, committed snapshot
BENCH_serve.json): a continuous-batching ServeLoop under sequence churn —
staggered admits into fewer slots than live sequences, so cold sequences
spill compressed to the host tier and wake on their next step.  Running
the SAME schedule under spill packing "off" vs "quad" isolates the link
bytes the compressed spill saves; the report carries the no-slowdown
flags CI enforces.
"""

from __future__ import annotations

import sys
import time
from pathlib import Path

import jax.numpy as jnp
import numpy as np

_ROOT = Path(__file__).resolve().parents[1]
for _p in (str(_ROOT), str(_ROOT / "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from repro.kv import CRAMKVCache, synthetic_kv_stream  # noqa: E402

PAGE, HKV, HD = 8, 1, 32


def _stream(rng, batch, n_tokens, compressible=True, scale=2e-3):
    return synthetic_kv_stream(rng, batch, n_tokens, HKV, HD,
                               compressible=compressible, scale=scale)


def _timed_decode_loop(cache, rng, batch, decode_steps, compressible):
    """The steady-state decode loop, timed with ZERO device->host syncs
    per step (analysis R3): pack-work tallies come from the host-only
    dispatch counters (`cache.host_stats`, not the device-syncing `stats`
    property), per-step byte duals stay device arrays until the timer
    stops, and the final step is synced before the wall-clock reads."""
    import jax

    seq_len, pack_pairs, total_pairs, bw_steps = [], [], [], []
    t0 = time.perf_counter()
    for _ in range(decode_steps):
        cache.append(*_stream(rng, batch, 1, compressible))
        before = cache.host_stats.pack_pairs_processed
        bw_steps.append(cache.account_step())
        seq_len.append(cache.tokens)
        pack_pairs.append(cache.host_stats.pack_pairs_processed - before)
        total_pairs.append(batch * cache.n_active_pairs)
    jax.block_until_ready((bw_steps, cache.state))
    wall = time.perf_counter() - t0
    cram_b = [int(bw["cram_bytes"]) for bw in bw_steps]
    raw_b = [int(bw["raw_bytes"]) for bw in bw_steps]
    return seq_len, pack_pairs, total_pairs, cram_b, raw_b, wall


def decode_curve(policy="static", batch=1, prefill_pages=4, decode_steps=32,
                 compressible=True, seed=0, packing="pair") -> dict:
    """One decode trajectory; per-step pack work and bandwidth."""
    rng = np.random.default_rng(seed)
    prefill = prefill_pages * PAGE
    total = prefill + decode_steps + 1           # +1 warm-up step
    n_need = (total + PAGE - 1) // PAGE
    lanes = 2 if packing == "pair" else 4
    cache = CRAMKVCache(max_pages=n_need, page=PAGE, n_kv=HKV, head_dim=HD,
                        batch=batch, policy=policy, packing=packing)
    # SAME stream for both packings (2e-3 fits int8 pairs AND int4 quads
    # at this geometry), so pair-vs-quad curves in one report compare the
    # layouts, not the data
    cache.append(*_stream(rng, batch, prefill, compressible))
    cache.account_step()
    # one untimed decode step compiles the W=1 pack window and the T=1
    # append scatter, so the timed loop measures steady-state steps only
    cache.append(*_stream(rng, batch, 1, compressible))
    cache.account_step()
    seq_len, pack_pairs, total_pairs, cram_b, raw_b, wall = \
        _timed_decode_loop(cache, rng, batch, decode_steps, compressible)
    mean_pack = float(np.mean(pack_pairs))
    mean_total = float(np.mean(total_pairs))
    # packing efficiency of the FINAL layout (transient partially-filled
    # groups re-pack raw many times; what matters is what the sequence
    # reached): pages_per_slot == lanes iff every active group packs
    pm = np.asarray(cache.state["packed_mask"][:, :cache.n_active_groups])
    fit_rate = float(pm.mean())
    pages_per_slot = float(lanes * pm.size
                           / (pm.sum() + lanes * (~pm).sum()))
    return {
        "policy": policy, "batch": batch, "compressible": compressible,
        "packing": packing,
        "fit_rate": round(fit_rate, 4),
        "pages_per_slot": round(pages_per_slot, 4),
        "prefill_tokens": prefill, "decode_steps": decode_steps,
        "seq_len": seq_len,
        "pack_pairs_per_step": pack_pairs,
        "total_pairs": total_pairs,
        "cram_bytes_per_step": cram_b,
        "raw_bytes_per_step": raw_b,
        "mean_pack_pairs_per_step": mean_pack,
        "mean_total_pairs": mean_total,
        "full_rebuild_work_ratio": mean_total / max(mean_pack, 1e-9),
        "final_saving": 1.0 - cram_b[-1] / max(raw_b[-1], 1),
        "cumulative_saving": cache.saving(),
        "decode_wall_s": round(wall, 4),
        "packed_pairs": cache.stats.packed_pairs,
        "raw_pairs": cache.stats.raw_pairs,
        "predictor_misses": cache.stats.predictor_misses,
    }


def _parity_check(seed=0) -> dict:
    """Incremental state vs from-scratch rebuild, and kernel vs oracle."""
    rng = np.random.default_rng(seed)
    cache = CRAMKVCache(max_pages=8, page=PAGE, n_kv=HKV, head_dim=HD,
                        batch=2, policy="static")
    for t in (2 * PAGE, 3, 1, PAGE):
        cache.append(*_stream(rng, 2, t))
        cache.repack()
    ref, act = cache.reference_rebuild(), cache.active_state()
    equal = all(bool(jnp.array_equal(act[k], ref[k])) for k in ref)
    q = jnp.asarray(rng.standard_normal((2, 4, HD)), jnp.float32)
    err = float(jnp.max(jnp.abs(cache.attend(q, account=False)
                                - cache.attend_ref(q))))
    return {"incremental_equals_rebuild": equal,
            "kernel_vs_oracle_err": err}


def sweep(policies=("static", "dynamic", "off"), batches=(1, 4),
          prefill_pages=4, decode_steps=32, seed=0,
          packings=("pair", "quad")) -> dict:
    curves = []
    for packing in packings:
        for policy in policies:
            for batch in batches:
                for compressible in (True, False):
                    curves.append(decode_curve(
                        policy=policy, batch=batch,
                        prefill_pages=prefill_pages,
                        decode_steps=decode_steps,
                        compressible=compressible, seed=seed,
                        packing=packing))
    static_comp = [c for c in curves if c["policy"] == "static"
                   and c["compressible"] and c["packing"] == "pair"]
    quad_static = [c for c in curves if c["policy"] == "static"
                   and c["packing"] == "quad"]
    return {
        "page": PAGE, "n_kv": HKV, "head_dim": HD,
        "curves": curves,
        "pack_work": {
            "mean_pack_pairs_per_step": float(np.mean(
                [c["mean_pack_pairs_per_step"] / c["batch"]
                 for c in curves])),
            "mean_total_pairs": float(np.mean(
                [c["mean_total_pairs"] / c["batch"] for c in curves])),
            "full_rebuild_work_ratio": float(np.mean(
                [c["full_rebuild_work_ratio"] for c in curves])),
        },
        "static_compressible_saving": float(np.mean(
            [c["cumulative_saving"] for c in static_comp])),
        # quad axis: pages-per-slot the 4:1 layout actually reached vs the
        # int4-delta fit rate on the same stream (ROADMAP item)
        "quad": {
            f"{'comp' if c['compressible'] else 'rand'}_b{c['batch']}": {
                "int4_fit_rate": c["fit_rate"],
                "pages_per_slot": c["pages_per_slot"],
                "saving": round(c["cumulative_saving"], 4),
            }
            for c in quad_static
        },
        "parity": _parity_check(seed),
    }


def churn_spill_curve(*, spill_packing="quad", slots=3, n_seqs=10,
                      max_pages=8, steps=48, admit_every=3,
                      policy="static", packing="pair", compressible=True,
                      seed=0) -> dict:
    """One continuous-batching churn trajectory with compressed spill.

    Every `admit_every` steps a new sequence joins (evicting the coldest
    to the spill tier when no slot is free); each step decodes one token
    for a seeded ~70% subset of live sequences (spilled ones wake first);
    sequences retire at their own target length.  The spill packing is
    the independent axis: the schedule (and therefore the raw-byte duals)
    is identical across packings for a fixed seed, so stored-byte deltas
    measure the LINK win alone."""
    from repro.serving import ServeLoop

    import jax

    rng = np.random.default_rng(seed)
    loop = ServeLoop(slots=slots, max_pages=max_pages, page=PAGE, n_kv=HKV,
                     head_dim=HD, policy=policy, packing=packing,
                     spill_packing=spill_packing)
    tokens, target, stream, next_sid = {}, {}, {}, 0
    # wall-clock throughput: the first loop iteration compiles the append
    # scatter / pack window / byte model, so the timer starts after it
    # (device work synced at both boundaries) and counts decode tokens
    # from then on
    decode_tokens, t_decode = 0, None
    t0 = time.perf_counter()
    for step_i in range(steps):
        if step_i == 1:
            jax.block_until_ready(loop.cache.state)
            t_decode = time.perf_counter()
        if step_i % admit_every == 0 and next_sid < n_seqs:
            t = int(rng.integers(PAGE, 3 * PAGE))
            tgt = int(rng.integers(4 * PAGE, (max_pages - 1) * PAGE))
            # one draw per sequence: a real sequence's KV hovers around
            # ITS OWN base, so its whole stream comes from one generator
            # call (per-step draws would redraw the base every token and
            # no page could delta-pack)
            ks, vs = _stream(rng, 1, tgt, compressible)
            loop.admit(next_sid, ks[0, :t], vs[0, :t])
            tokens[next_sid], target[next_sid] = t, tgt
            stream[next_sid] = (ks[0], vs[0])
            next_sid += 1
        live = sorted(loop.seqs)
        if not live:
            continue
        ids = [sid for sid in live if rng.random() < 0.7] or live[:1]
        kvs = {}
        for sid in ids:
            ks, vs = stream[sid]
            pos = tokens[sid]
            kvs[sid] = (ks[pos:pos + 1], vs[pos:pos + 1])
        loop.step_all(kvs)                   # wakes spilled ids first;
        # ids > slots runs in waves (one fused append per wave)
        if t_decode is not None:
            decode_tokens += len(ids)
        for sid in ids:
            tokens[sid] += 1
            if tokens[sid] >= target[sid]:
                loop.retire(sid)
                del stream[sid]
    jax.block_until_ready(loop.cache.state)
    wall = time.perf_counter() - t0
    decode_wall = (time.perf_counter() - t_decode
                   if t_decode is not None else wall)
    # wake-state parity: every surviving active slot must equal its own
    # rebuild oracle (spill round-trips included — the serve-tier analog
    # of incremental_equals_rebuild)
    loop.cache.repack()
    parity = all(
        all(bool(jnp.array_equal(a[kk], b[kk])) for kk in a)
        for a, b in (
            (loop.cache.slot_physical_state(loop.seqs[sid].slot),
             loop.cache.slot_reference_state(loop.seqs[sid].slot))
            for sid in loop.active_seqs())
    )
    sp = loop.spill.summary()
    loop.sync_ledger()          # fold the device traffic window before
    # reading the ledger rows below — the N-step run made zero host records
    return {
        "spill_packing": spill_packing, "slots": slots, "n_seqs": n_seqs,
        "steps": steps, "compressible": compressible, "policy": policy,
        "hot_packing": packing,
        **{f"count_{k}": v for k, v in loop.counts.items()},
        "spill": sp,
        "spill_events": {
            "evict": loop.ledger.total("spill", consumer="kv",
                                       tensor_class="kv-evict"),
            "restore": loop.ledger.total("spill", consumer="kv",
                                         tensor_class="kv-restore"),
        },
        "decode_saving": round(loop.ledger.saving("read", consumer="kv"), 4),
        "wake_state_parity": parity,
        "wall_s": round(wall, 4),
        "decode_tokens": decode_tokens,
        "tokens_per_s": round(decode_tokens / max(decode_wall, 1e-9), 2),
    }


def prefill_curve(*, prompt_tokens=512, policy="static", packing="pair",
                  compressible=True, seed=0) -> dict:
    """Fused chunked-prefill ingest vs token-by-token replay.

    Both paths ingest the SAME prompt into the same cache geometry; the
    fused path is ONE `prefill_slot` call (a single bulk-pack dispatch
    chain), the replay is `prompt_tokens` fused decode megasteps — the
    fastest pre-existing ingest.  Each path warms its traces on a
    throwaway cache first (the replay warm covers every pow2 window
    bucket it crosses), so the timed regions compare steady-state work,
    not compile time.  R3 discipline: device work synced at the timer
    boundaries only, zero host materialization inside.  The end states
    are compared bit-for-bit — the speedup only counts if the fused
    ingest produced EXACTLY the replayed cache."""
    import jax

    from repro.serving import SlotKVCache

    rng = np.random.default_rng(seed)
    n_pages = -(-prompt_tokens // PAGE)
    mk = dict(page=PAGE, n_kv=HKV, head_dim=HD, batch=1, policy=policy,
              packing=packing)
    ks, vs = _stream(rng, 1, prompt_tokens, compressible)
    k, v = ks[0], vs[0]
    ids = np.arange(1)

    warm = SlotKVCache(n_pages, **mk)
    warm.prefill_slot(0, k, v)             # compiles the T-bucket trace
    fused = SlotKVCache(n_pages, **mk)
    jax.block_until_ready((warm.state, fused.state))
    t0 = time.perf_counter()
    fused.prefill_slot(0, k, v)
    jax.block_until_ready(fused.state)
    fused_wall = time.perf_counter() - t0

    warm = SlotKVCache(n_pages, **mk)
    for i in range(prompt_tokens):
        warm.megastep(ids, k[None, i:i + 1], v[None, i:i + 1])
    replay = SlotKVCache(n_pages, **mk)
    jax.block_until_ready((warm.state, replay.state))
    t0 = time.perf_counter()
    for i in range(prompt_tokens):
        replay.megastep(ids, k[None, i:i + 1], v[None, i:i + 1])
    jax.block_until_ready(replay.state)
    replay_wall = time.perf_counter() - t0

    fused.repack()
    replay.repack()
    a, b = fused.slot_physical_state(0), replay.slot_physical_state(0)
    bit_identical = (
        all(bool(jnp.array_equal(a[kk], b[kk])) for kk in a)
        and bool(jnp.array_equal(fused.state["counter"],
                                 replay.state["counter"])))
    return {
        "prompt_tokens": prompt_tokens, "policy": policy,
        "packing": packing, "compressible": compressible,
        "fused": {"wall_s": round(fused_wall, 4), "dispatches": 1,
                  "tokens_per_s": round(prompt_tokens
                                        / max(fused_wall, 1e-9), 2)},
        "replay": {"wall_s": round(replay_wall, 4),
                   "dispatches": prompt_tokens,
                   "tokens_per_s": round(prompt_tokens
                                         / max(replay_wall, 1e-9), 2)},
        "speedup": round(replay_wall / max(fused_wall, 1e-9), 2),
        "bit_identical": bit_identical,
    }


def migration_churn_curve(*, mode="gate", slots=4, max_pages=128,
                          prefill_pages=96, steady_steps=32,
                          churn_steps=16, migrate_budget=1,
                          seed=0) -> dict:
    """Zero-stall live migration under decode load, phase by phase.

    One fused-megastep serve pool decodes through three phases — steady
    state, migrating (the hot-tier target flips mid-serve and converges
    at `migrate_budget` page-group columns per step), then spill churn
    (evict/wake crossings riding on the converged layout) — with an
    attend per step, so tokens/s measures the decode path a model would
    feel.  `mode="gate"` flips the §VI gate off (packed -> raw);
    `mode="repack"` live-switches the packing geometry (pair -> quad and
    re-promotes).  Timing is chunk-aggregate: device work is synced at
    chunk boundaries only (never per step), and each phase runs 2
    untimed warm-up steps so one-off retraces (the migration window's
    pow2 bucket) don't bill the steady rate.  The no-stall comparison
    uses the MEDIAN chunk rate per phase (a single GC pause inside one
    chunk must not fail the flag; the pool is sized so both modes
    migrate for 20+ timed steps / 3+ chunks), and the baseline is the
    SLOWER of the two steady phases bracketing the migration — whole-
    machine speed drift between phases (CPU frequency scaling, noisy
    neighbours on shared runners) slows steady and migrating alike, and
    must not fail the flag either.  The report carries the two flags CI
    enforces: `no_stall` — migrating median tokens/s >= 90% of the
    bracketing-steady baseline — and `bit_identical` — after
    convergence every slot's physical layout equals its from-scratch
    rebuild oracle."""
    import jax

    from repro.serving import ServeLoop

    assert mode in ("gate", "repack"), mode
    rng = np.random.default_rng(seed)
    loop = ServeLoop(slots=slots, max_pages=max_pages, page=PAGE, n_kv=HKV,
                     head_dim=HD, policy="static", packing="pair",
                     migrate_budget=migrate_budget)
    prefill = prefill_pages * PAGE
    stream, tokens = {}, {}
    for sid in range(slots):
        ks, vs = _stream(rng, 1, max_pages * PAGE)
        loop.admit(sid, ks[0, :prefill], vs[0, :prefill])
        stream[sid], tokens[sid] = (ks[0], vs[0]), prefill
    q = np.asarray(rng.standard_normal((4, HD)), np.float32)

    def decode_step():
        kvs = {}
        for sid in sorted(loop.seqs):
            ks, vs = stream[sid]
            pos = tokens[sid]
            kvs[sid] = (ks[pos:pos + 1], vs[pos:pos + 1])
            tokens[sid] += 1
        loop.step_all(kvs)
        loop.attend({sid: q for sid in loop.active_seqs()})
        return len(kvs)

    def run_phase(should_stop, *, warmup=2, churn_every=0, chunk=8):
        for _ in range(warmup):
            decode_step()
        jax.block_until_ready(loop.cache.state)
        n_tok, steps, rates = 0, 0, []
        c_tok, c_steps, t0 = 0, 0, time.perf_counter()

        def close_chunk():
            nonlocal c_tok, c_steps, t0
            jax.block_until_ready(loop.cache.state)
            w = time.perf_counter() - t0
            if c_steps:
                rates.append((c_tok, w))
            c_tok, c_steps, t0 = 0, 0, time.perf_counter()

        while not should_stop(steps):
            if churn_every and steps % churn_every == 0:
                loop.evict(loop.active_seqs()[0])  # the next decode_step
                # names it again, so the wake crossing rides in-phase
            t = decode_step()
            n_tok += t
            c_tok += t
            steps += 1
            c_steps += 1
            if c_steps >= chunk:
                close_chunk()
        close_chunk()
        wall = sum(w for _, w in rates)
        per_chunk = [round(tk / max(w, 1e-9), 2) for tk, w in rates]
        return {"steps": steps, "decode_tokens": n_tok,
                "wall_s": round(wall, 4),
                "tokens_per_s": round(n_tok / max(wall, 1e-9), 2),
                "chunk_tokens_per_s": per_chunk,
                "median_tokens_per_s": (round(float(np.median(per_chunk)),
                                              2) if per_chunk else 0.0)}

    phases = {}
    phases["steady"] = run_phase(lambda s: s >= steady_steps)
    if mode == "gate":
        loop.cache.set_gate_override(False)    # packed layout -> raw
    else:
        loop.migrate_to(packing="quad")        # pair -> quad, re-promote
    pending0 = loop.cache.migration_status()["pending_columns"]
    # convergence is polled on HOST state only (the derived pending mask
    # never touches the device), so the poll cannot serialize the stream
    phases["migrating"] = run_phase(
        lambda s: not loop.cache.migration_pending().any() or s > 200)
    converged = loop.cache.migration_status()
    # second steady phase on the CONVERGED layout: the no-stall baseline
    # is the slower of the two steady measurements bracketing the
    # migration, so machine-speed drift across phases cancels out
    phases["steady_converged"] = run_phase(lambda s: s >= steady_steps // 2)
    phases["spill_churn"] = run_phase(lambda s: s >= churn_steps,
                                      churn_every=4)
    loop.sync_ledger()
    bit_identical = all(
        all(bool(jnp.array_equal(a[kk], b[kk])) for kk in a)
        for a, b in ((loop.cache.slot_physical_state(loop.seqs[sid].slot),
                      loop.cache.slot_reference_state(loop.seqs[sid].slot))
                     for sid in loop.active_seqs()))
    steady = min(phases["steady"]["median_tokens_per_s"],
                 phases["steady_converged"]["median_tokens_per_s"])
    mig = phases["migrating"]["median_tokens_per_s"]
    return {
        "mode": mode, "slots": slots, "max_pages": max_pages,
        "prefill_pages": prefill_pages, "migrate_budget": migrate_budget,
        "pending_columns_at_flip": pending0,
        "converged": not converged["migrating"],
        "phases": phases,
        "migrating_over_steady": round(mig / max(steady, 1e-9), 4),
        # an empty timed region (everything converged inside warmup)
        # trivially satisfies zero-stall
        "no_stall": (phases["migrating"]["steps"] == 0
                     or mig >= 0.9 * steady),
        "bit_identical": bit_identical,
        "spills": loop.counts["evicted"], "wakes": loop.counts["woken"],
    }


def spill_sweep(spill_packings=("off", "pair", "quad"), steps=48,
                seed=0) -> dict:
    """The serve-spill report: one churn schedule per spill packing (same
    seed => same schedule => same raw-byte duals), plus the guarantee
    flags CI enforces:

      * compressed_moves_fewer_bytes — quad stored < off stored;
      * spill_no_slowdown            — stored never exceeds the raw dual
                                       by more than the fit-bitmap epsilon
                                       (holds on the INCOMPRESSIBLE churn
                                       too: raw groups cross untouched);
      * wake_state_parity            — every wake resurrected its slot
                                       bit-identical to the rebuild oracle;
      * migration_no_stall           — a mid-serve gate flip AND a live
                                       packing switch both keep migrating-
                                       phase tokens/s >= 90% of steady;
      * migration_bit_identical      — the converged layouts equal the
                                       per-slot rebuild oracle;
      * prefill_no_slower_than_replay — the ONE-dispatch bulk-pack ingest
                                       is at least as fast as replaying
                                       the prompt token by token, and the
                                       end state is bit-identical.
    """
    import jax

    curves = {spk: churn_spill_curve(spill_packing=spk, steps=steps,
                                     seed=seed)
              for spk in spill_packings}
    migration = {mode: migration_churn_curve(mode=mode, seed=seed)
                 for mode in ("gate", "repack")}
    prefill = prefill_curve(seed=seed)
    noise = churn_spill_curve(spill_packing="quad", steps=steps, seed=seed,
                              compressible=False)
    base = curves[spill_packings[0]]["spill"]
    same_schedule = all(
        c["spill"]["raw_bytes"] == base["raw_bytes"]
        and c["spill"]["spills"] == base["spills"]
        for c in curves.values())
    eps = 1.001                       # fit bitmap: 1 byte per spill group
    flags = {
        "same_schedule_across_packings": same_schedule,
        "compressed_moves_fewer_bytes":
            curves["quad"]["spill"]["stored_bytes"]
            < curves["off"]["spill"]["stored_bytes"]
            if {"off", "quad"} <= set(curves) else None,
        "spill_no_slowdown": all(
            c["spill"]["stored_bytes"] <= c["spill"]["raw_bytes"] * eps
            for c in (*curves.values(), noise)),
        "wake_state_parity": all(
            c["wake_state_parity"] for c in (*curves.values(), noise)),
        "migration_no_stall": all(m["no_stall"] and m["converged"]
                                  for m in migration.values()),
        "migration_bit_identical": all(m["bit_identical"]
                                       for m in migration.values()),
        "prefill_no_slower_than_replay": (prefill["bit_identical"]
                                          and prefill["speedup"] >= 1.0),
    }
    dev = jax.devices()[0]
    return {
        "page": PAGE, "n_kv": HKV, "head_dim": HD,
        "backend": {"platform": dev.platform,
                    "device_kind": dev.device_kind},
        "curves": curves,
        "incompressible_quad": noise,
        "migration": migration,
        "prefill": prefill,
        "spill_bytes": {spk: {"raw": c["spill"]["raw_bytes"],
                              "stored": c["spill"]["stored_bytes"],
                              "saving": c["spill"]["saving"]}
                        for spk, c in curves.items()},
        # post-warmup wall-clock decode throughput per churn trajectory
        # (interpret-mode structural numbers, comparable across packings
        # within one report, not across machines)
        "tokens_per_s": {**{spk: c["tokens_per_s"]
                            for spk, c in curves.items()},
                         "incompressible_quad": noise["tokens_per_s"]},
        "guarantee": flags,
    }


def run() -> list[tuple]:
    """Legacy-mode rows for benchmarks/run.py."""
    rep = sweep(batches=(1, 2), decode_steps=12)
    rows = []
    for c in rep["curves"]:
        name = (f"serve/{c['packing']}_{c['policy']}_b{c['batch']}"
                f"_{'comp' if c['compressible'] else 'rand'}")
        us = c["decode_wall_s"] / max(c["decode_steps"], 1) * 1e6
        rows.append((name, us,
                     f"pack/step={c['mean_pack_pairs_per_step']:.2f} "
                     f"saving={c['cumulative_saving']:.3f}"))
    p = rep["parity"]
    rows.append(("serve/parity", 0.0,
                 f"incr_eq_rebuild={p['incremental_equals_rebuild']} "
                 f"err={p['kernel_vs_oracle_err']:.1e}"))
    sp = spill_sweep(steps=16)
    for spk, b in sp["spill_bytes"].items():
        rows.append((f"serve/spill_{spk}", 0.0,
                     f"raw={b['raw']} stored={b['stored']} "
                     f"saving={b['saving']:.3f}"))
    g = sp["guarantee"]
    rows.append(("serve/spill_guarantee", 0.0,
                 f"fewer_bytes={g['compressed_moves_fewer_bytes']} "
                 f"no_slowdown={g['spill_no_slowdown']} "
                 f"wake_parity={g['wake_state_parity']}"))
    for mode, m in sp["migration"].items():
        rows.append((f"serve/migrate_{mode}", 0.0,
                     f"ratio={m['migrating_over_steady']:.3f} "
                     f"no_stall={m['no_stall']} "
                     f"bit_identical={m['bit_identical']}"))
    pf = sp["prefill"]
    rows.append(("serve/prefill", pf["fused"]["wall_s"] * 1e6,
                 f"T={pf['prompt_tokens']} speedup={pf['speedup']:.1f}x "
                 f"bit_identical={pf['bit_identical']}"))
    return rows
