"""Shared aggregations from a suite sweep to paper figure/table numbers.

One place owns the figure math: fig12_speedup.py, fig15_bandwidth.py and
table5_prefetch.py derive their CSV rows from these helpers, and
`run.py --sweep` emits them together as one consolidated JSON report.

All helpers take the `{workload: summary}` mapping produced by
repro.core.batchsim.sweep_workloads (== memsim.run_workload per entry).
The suite may carry registry extras beyond the six paper schemes
(cram-nollp, the cram@lct* config axis); the Fig. 12/16/18 aggregates
stay restricted to the paper schemes, while the extras feed the
dedicated llp_value / lct_sensitivity sections.
"""

from __future__ import annotations

import dataclasses

from repro.core import schemes as schemes_registry
from repro.core.memsim import SCHEMES as BASE_SCHEMES

from .memsim_suite import geomean, suite_of


def _lct_point(sch_name: str) -> "int | None":
    """The LCT size of `sch_name` if it is cram-modulo-LCT-size (the
    registry is the source of truth; cram itself is the full-size point),
    else None."""
    try:
        sch = schemes_registry.get(sch_name)
    except KeyError:
        return None
    cram = schemes_registry.get("cram")
    as_cram = dataclasses.replace(sch, name=cram.name, lct_size=cram.lct_size,
                                  description=cram.description)
    return sch.lct_size if as_cram == cram else None


def speedup_aggregates(workloads: dict, include=None) -> dict:
    """Fig. 12/16/18 aggregates: per-scheme geomean / worst / best and
    per-(suite, scheme) geomeans.  `include` restricts the scheme set
    (None = every scheme present)."""
    by_scheme: dict[str, list] = {}
    by_suite: dict[str, dict[str, list]] = {}
    for wl, r in workloads.items():
        for sch, d in r["schemes"].items():
            if sch == "baseline" or (include is not None and sch not in include):
                continue
            s = d["speedup"]
            by_scheme.setdefault(sch, []).append(s)
            by_suite.setdefault(suite_of(wl), {}).setdefault(sch, []).append(s)
    return {
        "geomean": {sch: geomean(xs) for sch, xs in sorted(by_scheme.items())},
        "worst": {sch: min(xs) for sch, xs in sorted(by_scheme.items())},
        "best": {sch: max(xs) for sch, xs in sorted(by_scheme.items())},
        "by_suite": {
            suite: {sch: geomean(xs) for sch, xs in sorted(per.items())}
            for suite, per in sorted(by_suite.items())
        },
    }


def bandwidth_breakdowns(workloads: dict,
                         schemes=("explicit", "cram")) -> dict:
    """Fig. 8/15 per-workload bandwidth breakdowns normalized to baseline.

    Computed from each scheme's embedded bandwidth-ledger rows
    ("traffic", `repro.bandwidth.adapters.engine_traffic`) via
    `engine_breakdown` — NOT from the legacy private counters — so the
    figures and the policy layer consume one view of the engine's byte
    economy.  tests/test_benchmarks.py pins this view equal to the
    legacy `SimResult.bandwidth_breakdown` category math."""
    from repro.bandwidth.adapters import engine_breakdown

    out: dict[str, dict] = {sch: {} for sch in schemes}
    for wl, r in sorted(workloads.items()):
        base = r["baseline_accesses"]
        for sch in schemes:
            if sch not in r["schemes"]:
                continue
            b = engine_breakdown(r["schemes"][sch]["traffic"])
            out[sch][wl] = {
                "data": b["data"] / base,
                "metadata": b["metadata"] / base,
                "mispredict": b["mispredict"] / base,
                "wbclean+inv": b["wbclean+inv"] / base,
                # the ledger rows partition the access count exactly, so
                # the normalized total IS accesses/baseline
                "total": b["total"] / base,
            }
    return out


def prefetch_table(workloads: dict) -> dict:
    """Table V: next-line prefetch vs Dynamic-CRAM gain per suite (in %)."""
    per: dict[tuple, list] = {}
    for wl, r in workloads.items():
        s = suite_of(wl)
        for sch in ("nextline", "dynamic"):
            if sch not in r["schemes"]:
                continue
            sp = r["schemes"][sch]["speedup"]
            per.setdefault((sch, s), []).append(sp)
            per.setdefault((sch, "ALL"), []).append(sp)
    return {
        f"{suite}_{sch}": (geomean(xs) - 1) * 100
        for (sch, suite), xs in sorted(per.items())
    }


def llp_value_table(workloads: dict) -> dict:
    """LLP predictor value: cram (learned LCT) vs cram-nollp (LCT frozen at
    level 0).  The gap is the bandwidth the predictor earns."""
    out: dict = {}
    for sch in ("cram", "cram-nollp"):
        sp = [r["schemes"][sch]["speedup"] for r in workloads.values()
              if sch in r["schemes"]]
        acc = [r["schemes"][sch]["llp_accuracy"] for r in workloads.values()
               if sch in r["schemes"]]
        if sp:
            out[sch] = {"geomean_speedup": geomean(sp),
                        "mean_one_access_rate": sum(acc) / len(acc)}
    if "cram" in out and "cram-nollp" in out:
        out["llp_gain_pct"] = (
            out["cram"]["geomean_speedup"]
            / out["cram-nollp"]["geomean_speedup"] - 1) * 100
    return out


def lct_sensitivity_table(workloads: dict) -> dict:
    """Fig. 14-style LCT-size sensitivity from the cram@lct* config axis
    (cram itself is the full 512-entry point)."""
    sizes: dict[int, str] = {}
    for r in workloads.values():
        for sch in r["schemes"]:
            point = _lct_point(sch)
            if point is not None:
                sizes[point] = sch
    out = {}
    for size, sch in sorted(sizes.items()):
        sp = [r["schemes"][sch]["speedup"] for r in workloads.values()
              if sch in r["schemes"]]
        acc = [r["schemes"][sch]["llp_accuracy"] for r in workloads.values()
               if sch in r["schemes"]]
        if sp:
            out[str(size)] = {"geomean_speedup": geomean(sp),
                              "mean_one_access_rate": sum(acc) / len(acc)}
    return out


def build_report(suite: dict) -> dict:
    """The consolidated sweep report (schema documented in run.py)."""
    workloads = suite["workloads"]
    agg = speedup_aggregates(workloads, include=BASE_SCHEMES)
    bw = bandwidth_breakdowns(workloads)
    return {
        "n_events": suite["n_events"],
        "sweep_wall_s": suite.get("sweep_wall_s"),
        "speedups": {
            wl: {sch: d["speedup"] for sch, d in r["schemes"].items()}
            for wl, r in workloads.items()
        },
        "fig12_by_suite": agg["by_suite"],
        "fig16_geomean": agg["geomean"],
        "fig18_worst": agg["worst"],
        "fig18_best": agg["best"],
        "fig8_explicit_bandwidth": bw.get("explicit", {}),
        "fig15_cram_bandwidth": bw.get("cram", {}),
        "table5_prefetch_pct": prefetch_table(workloads),
        "llp_value": llp_value_table(workloads),
        "lct_sensitivity": lct_sensitivity_table(workloads),
        "workloads": workloads,
    }
