"""Shared aggregations from a suite sweep to paper figure/table numbers.

One place owns the figure math: fig12_speedup.py, fig15_bandwidth.py and
table5_prefetch.py derive their CSV rows from these helpers, and
`run.py --sweep` emits them together as one consolidated JSON report.

All helpers take the `{workload: summary}` mapping produced by
repro.core.batchsim.sweep_workloads (== memsim.run_workload per entry).
"""

from __future__ import annotations

from .memsim_suite import geomean, suite_of


def speedup_aggregates(workloads: dict) -> dict:
    """Fig. 12/16/18 aggregates: per-scheme geomean / worst / best and
    per-(suite, scheme) geomeans."""
    by_scheme: dict[str, list] = {}
    by_suite: dict[str, dict[str, list]] = {}
    for wl, r in workloads.items():
        for sch, d in r["schemes"].items():
            if sch == "baseline":
                continue
            s = d["speedup"]
            by_scheme.setdefault(sch, []).append(s)
            by_suite.setdefault(suite_of(wl), {}).setdefault(sch, []).append(s)
    return {
        "geomean": {sch: geomean(xs) for sch, xs in sorted(by_scheme.items())},
        "worst": {sch: min(xs) for sch, xs in sorted(by_scheme.items())},
        "best": {sch: max(xs) for sch, xs in sorted(by_scheme.items())},
        "by_suite": {
            suite: {sch: geomean(xs) for sch, xs in sorted(per.items())}
            for suite, per in sorted(by_suite.items())
        },
    }


def bandwidth_breakdowns(workloads: dict,
                         schemes=("explicit", "cram")) -> dict:
    """Fig. 8/15 per-workload bandwidth breakdowns normalized to baseline."""
    out: dict[str, dict] = {sch: {} for sch in schemes}
    for wl, r in sorted(workloads.items()):
        base = r["baseline_accesses"]
        for sch in schemes:
            if sch not in r["schemes"]:
                continue
            b = r["schemes"][sch]["breakdown"]
            norm = {k: v / base for k, v in b.items()}
            out[sch][wl] = {
                "data": norm["data_reads"] + norm["wb_dirty"],
                "metadata": norm["metadata"],
                "mispredict": norm["mispredict_extra"],
                "wbclean+inv": norm["wb_clean+invalidate"],
                "total": r["schemes"][sch]["accesses"] / base,
            }
    return out


def prefetch_table(workloads: dict) -> dict:
    """Table V: next-line prefetch vs Dynamic-CRAM gain per suite (in %)."""
    per: dict[tuple, list] = {}
    for wl, r in workloads.items():
        s = suite_of(wl)
        for sch in ("nextline", "dynamic"):
            if sch not in r["schemes"]:
                continue
            sp = r["schemes"][sch]["speedup"]
            per.setdefault((sch, s), []).append(sp)
            per.setdefault((sch, "ALL"), []).append(sp)
    return {
        f"{suite}_{sch}": (geomean(xs) - 1) * 100
        for (sch, suite), xs in sorted(per.items())
    }


def build_report(suite: dict) -> dict:
    """The consolidated sweep report (schema documented in run.py)."""
    workloads = suite["workloads"]
    agg = speedup_aggregates(workloads)
    bw = bandwidth_breakdowns(workloads)
    return {
        "n_events": suite["n_events"],
        "sweep_wall_s": suite.get("sweep_wall_s"),
        "speedups": {
            wl: {sch: d["speedup"] for sch, d in r["schemes"].items()}
            for wl, r in workloads.items()
        },
        "fig12_by_suite": agg["by_suite"],
        "fig16_geomean": agg["geomean"],
        "fig18_worst": agg["worst"],
        "fig18_best": agg["best"],
        "fig8_explicit_bandwidth": bw.get("explicit", {}),
        "fig15_cram_bandwidth": bw.get("cram", {}),
        "table5_prefetch_pct": prefetch_table(workloads),
        "workloads": workloads,
    }
