"""Shared runner: one batched sweep covers the full 27-workload suite.

Every figure-level benchmark (fig 3/7/12/14/15/16/18, tables IV/V) reads
from this cache.  The suite is no longer a per-(scheme, workload) Python
loop: repro.core.batchsim stacks all traces and runs every scheme ×
workload pair inside a single jitted lax.scan dispatch, so a cold
`python benchmarks/run.py` costs one compilation + one device program.

The default scheme set is the six paper schemes plus the registry extras
(`cram-nollp` and the `cram@lct*` LCT-size config axis) — all riding in
the same single dispatch, since schemes and configs are just rows of the
engine's (flags, params) matrices.
"""

from __future__ import annotations

import json
import os
import time
from pathlib import Path

from repro.core.batchsim import sweep_workloads
from repro.core.memsim import SCHEMES
from repro.core.schemes import LCT_SENSITIVITY

CACHE = Path(__file__).resolve().parents[1] / "experiments" / "memsim"
N_EVENTS = int(os.environ.get("REPRO_BENCH_EVENTS", 300_000))

# registry extras riding in the same dispatch as the six base schemes
EXTRA_SCHEMES = ("cram-nollp",) + LCT_SENSITIVITY
DEFAULT_SCHEMES = SCHEMES + EXTRA_SCHEMES


def suite_results(force: bool = False, n_events: int | None = None,
                  workloads=None, schemes=DEFAULT_SCHEMES) -> dict:
    """Batched suite sweep, cached on disk per event count.

    The cache file is versioned (v2: deterministic trace seeding + registry
    extras); stale v1 caches are simply never read again.
    """
    n_events = N_EVENTS if n_events is None else n_events
    CACHE.mkdir(parents=True, exist_ok=True)
    path = CACHE / f"suite_v2_{n_events}.json"
    default_suite = workloads is None and tuple(schemes) == DEFAULT_SCHEMES
    if path.exists() and not force and default_suite:
        return json.loads(path.read_text())
    t0 = time.time()
    results = sweep_workloads(
        names=workloads, schemes=schemes, n_events=n_events)
    out = {
        "n_events": n_events,
        "schemes": list(schemes),
        "workloads": results,
        "sweep_wall_s": round(time.time() - t0, 2),
    }
    print(f"  memsim batched sweep ({len(results)} workloads x "
          f"{len(schemes)} schemes): {out['sweep_wall_s']}s", flush=True)
    if default_suite:
        path.write_text(json.dumps(out))
    return out


def geomean(xs) -> float:
    import numpy as np

    xs = np.asarray(list(xs), dtype=np.float64)
    return float(np.exp(np.log(np.maximum(xs, 1e-9)).mean()))


def suite_of(name: str) -> str:
    from repro.core.traces import BY_NAME

    if name in BY_NAME:
        return {"SPEC06": "SPEC", "SPEC17": "SPEC"}.get(
            BY_NAME[name].suite, BY_NAME[name].suite)
    return "MIX"
