"""Shared runner: simulate the full 27-workload suite once, cache results.

Every figure-level benchmark (fig 3/7/12/14/15/16/18, tables IV/V) reads
from this cache, so `python -m benchmarks.run` costs one suite pass.
"""

from __future__ import annotations

import json
import os
import time
from pathlib import Path

from repro.core.memsim import SCHEMES, SimConfig, run_workload
from repro.core.traces import all_workload_names

CACHE = Path(__file__).resolve().parents[1] / "experiments" / "memsim"
N_EVENTS = int(os.environ.get("REPRO_BENCH_EVENTS", 300_000))


def suite_results(force: bool = False) -> dict:
    CACHE.mkdir(parents=True, exist_ok=True)
    path = CACHE / f"suite_{N_EVENTS}.json"
    if path.exists() and not force:
        return json.loads(path.read_text())
    out = {"n_events": N_EVENTS, "workloads": {}, "wall_s": {}}
    for name in all_workload_names():
        t0 = time.time()
        out["workloads"][name] = run_workload(
            name, schemes=SCHEMES, n_events=N_EVENTS)
        out["wall_s"][name] = round(time.time() - t0, 2)
        print(f"  memsim {name}: {out['wall_s'][name]}s", flush=True)
    path.write_text(json.dumps(out))
    return out


def geomean(xs) -> float:
    import numpy as np

    xs = np.asarray(list(xs), dtype=np.float64)
    return float(np.exp(np.log(np.maximum(xs, 1e-9)).mean()))


def suite_of(name: str) -> str:
    from repro.core.traces import BY_NAME

    if name in BY_NAME:
        return {"SPEC06": "SPEC", "SPEC17": "SPEC"}.get(
            BY_NAME[name].suite, BY_NAME[name].suite)
    return "MIX"
