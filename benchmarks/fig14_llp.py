"""Fig. 14: LLP one-access accuracy vs 32KB metadata-cache hit rate.

Reads the cached suite sweep; when the cache was produced with a
`--schemes` subset that omits `cram` or `explicit`, the missing column is
skipped per-row and the omission is noted in the summary rows instead of
crashing with a KeyError.
"""

from __future__ import annotations

import numpy as np

from .memsim_suite import suite_results


def run() -> list[tuple]:
    res = suite_results()
    rows = []
    accs, hits = [], []
    missing = set()
    for wl, r in res["workloads"].items():
        schemes = r["schemes"]
        parts = []
        if "cram" in schemes:
            acc = schemes["cram"]["llp_accuracy"]
            accs.append(acc)
            parts.append(f"llp={acc:.3f}")
        else:
            missing.add("cram")
        if "explicit" in schemes:
            mhr = schemes["explicit"]["meta_hit_rate"]
            hits.append(mhr)
            parts.append(f"metaHR={mhr:.3f}")
        else:
            missing.add("explicit")
        rows.append((f"fig14/{wl}", 0.0, " ".join(parts) or "n/a"))
    rows.insert(0, ("fig14/mean_llp_accuracy", 0.0,
                    f"{np.mean(accs):.3f} (paper ~0.98)" if accs
                    else "n/a (cram not in cached suite)"))
    rows.insert(1, ("fig14/mean_meta_hit_rate", 0.0,
                    f"{np.mean(hits):.3f} (paper: lower than LLP)" if hits
                    else "n/a (explicit not in cached suite)"))
    if missing:
        rows.insert(2, ("fig14/omitted_schemes", 0.0,
                        "suite cache lacks: " + ",".join(sorted(missing))))
    return rows
