"""Fig. 14: LLP one-access accuracy vs 32KB metadata-cache hit rate."""

from __future__ import annotations

import numpy as np

from .memsim_suite import suite_results


def run() -> list[tuple]:
    res = suite_results()
    rows = []
    accs, hits = [], []
    for wl, r in res["workloads"].items():
        acc = r["schemes"]["cram"]["llp_accuracy"]
        mhr = r["schemes"]["explicit"]["meta_hit_rate"]
        accs.append(acc)
        hits.append(mhr)
        rows.append((f"fig14/{wl}", 0.0,
                     f"llp={acc:.3f} metaHR={mhr:.3f}"))
    rows.insert(0, ("fig14/mean_llp_accuracy", 0.0,
                    f"{np.mean(accs):.3f} (paper ~0.98)"))
    rows.insert(1, ("fig14/mean_meta_hit_rate", 0.0,
                    f"{np.mean(hits):.3f} (paper: lower than LLP)"))
    return rows
